"""``repro.api`` — the one public query surface.

Build a session once, query it everywhere (DESIGN.md §5):

    from repro.api import Scene, VectorIndex, make_ray

    scene = Scene.from_triangles(vertices, builder="sah")  # or "lbvh"
    engine = scene.engine()
    hits = engine.trace(rays)                     # closest-hit
    shadowed = engine.trace(rays, ray_type="shadow").hit
    scene.refit(moved_vertices)                   # animate: no rebuild,
    hits = engine.trace(rays)                     # no retrace (DESIGN §7)
    print(scene.stats())                          # SAH cost + jobs/ray

    index = VectorIndex.from_database(embeddings)
    engine = index.engine()
    res = engine.nearest(queries, k=8, metric="cosine")
    scores, idx, valid = res.scores, res.indices, res.valid
    in_range = engine.within(queries, radius=5.0, k=16)

3-D point clouds get the traversal-backed neighbor path (DESIGN.md §9):
the cloud is a BVH of AABB-per-point leaves, query radii ride as ray
extents, and ``backend="auto"`` picks tree-vs-brute per query::

    cloud = PointCloudScene.from_points(points, builder="lbvh")
    engine = cloud.engine()
    near = engine.nearest(queries, k=8)           # tree or brute, same ranks
    ball = engine.within(queries, radius=0.1, k=32)
    counts = engine.count_within(queries, radius=0.1)
    cloud.refit(moved_points)                     # animate: no rebuild

Backends are pluggable (``backend="per_ray" | "wavefront" | "pallas" |
"mxu" | "tree_wavefront" | "tree_pallas" | "auto"``) and every backend
returns the same result record; the legacy free functions in
``repro.core`` remain the semantic oracles.

Execution scales without changing results (DESIGN.md §6): pass
``shard="auto" | int`` to data-parallel a batch across local devices
(scene/index replicated; bit-identical output) and ``chunk_size=`` to
stream bigger-than-memory batches through fixed-size microbatches::

    engine = scene.engine(shard="auto", chunk_size=65536)
    hits = engine.trace(million_rays)        # sharded + chunked, bit-equal
"""
from .core.build import (  # noqa: F401
    BuildResult,
    TreeStats,
    builders,
    refit,
    refit_points,
    register_builder,
)
from .core.session import (  # noqa: F401
    CacheInfo,
    NearestResult,
    NeighborRecord,
    PointCloudScene,
    QueryEngine,
    Scene,
    TraceResult,
    VectorIndex,
    WithinResult,
    default_pad_multiple,
    distance_backends,
    neighbor_backends,
    register_distance_backend,
    register_neighbor_backend,
    register_trace_backend,
    trace_backends,
)
from .core.types import Box, Ray, Triangle, make_ray  # noqa: F401
from .core.wavefront import RAY_TYPES, SHADOW_T_MIN  # noqa: F401

__all__ = [
    "Box",
    "BuildResult",
    "CacheInfo",
    "NearestResult",
    "NeighborRecord",
    "PointCloudScene",
    "QueryEngine",
    "RAY_TYPES",
    "Ray",
    "SHADOW_T_MIN",
    "Scene",
    "TraceResult",
    "TreeStats",
    "Triangle",
    "VectorIndex",
    "WithinResult",
    "builders",
    "default_pad_multiple",
    "distance_backends",
    "make_ray",
    "neighbor_backends",
    "refit",
    "refit_points",
    "register_builder",
    "register_distance_backend",
    "register_neighbor_backend",
    "register_trace_backend",
    "trace_backends",
]
