"""Pallas TPU kernel: generalized distance modes on the MXU.

The paper's OpEuclidean/OpAngular stream one vector pair per beat through
shared adders/multipliers, accumulating partial sums across beats.  On TPU
the shared functional unit worth feeding is the **MXU**, so the batched form
is matmul-shaped (DESIGN.md §2):

    euclidean:  D[m, n] = ||q_m||^2 - 2 q_m.c_n + ||c_n||^2
    angular:    S[m, n] = q_m.c_n            and   N[n] = ||c_n||^2

The K (feature) dimension is blocked and accumulated in a VMEM scratch tile
across grid steps -- the direct analogue of the paper's multi-beat internal
accumulator (Table V), with the lane-validity bitmask realised as K-padding.

Grid iteration order is (m, n, k) with k innermost so the accumulator tile
lives in VMEM for the whole K sweep (revisiting semantics).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .common import resolve_interpret

# MXU-aligned default blocks.
BM, BN, BK = 256, 256, 512


def _distance_kernel(q_ref, c_ref, out_ref, acc_ref, *, mode: str, nk: int):
    """q (BM, BK), c (BN, BK) -> out (BM, BN); acc is VMEM f32 scratch.

    mode == 'euclidean': out = sum_k (q-c)^2 via the expanded matmul form.
    mode == 'angular':   out = sum_k q*c.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...].astype(jnp.float32)
    c = c_ref[...].astype(jnp.float32)
    # the shared multiplier array: one MXU pass per beat
    qc = jax.lax.dot_general(q, c, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    if mode == "euclidean":
        q2 = jnp.sum(q * q, axis=1, keepdims=True)  # (BM, 1)
        c2 = jnp.sum(c * c, axis=1, keepdims=True).T  # (1, BN)
        acc_ref[...] += q2 - 2.0 * qc + c2
    else:
        acc_ref[...] += qc

    @pl.when(k == nk - 1)
    def _done():
        out = acc_ref[...]
        if mode == "euclidean":
            out = jnp.maximum(out, 0.0)
        out_ref[...] = out


def _norm_kernel(c_ref, out_ref):
    """Row-norms ||c_n||^2: (BN, BK) tiles accumulated into (1, BN)."""
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    c = c_ref[...].astype(jnp.float32)
    out_ref[...] += jnp.sum(c * c, axis=1, keepdims=True).T


@functools.partial(jax.jit, static_argnames=("mode", "bm", "bn", "bk", "interpret"))
def distance_pallas(q, c, *, mode="euclidean", bm=BM, bn=BN, bk=BK, interpret=None):
    """Pairwise distance/dot scores.  q: (M, D), c: (N, D), padded to blocks.

    Returns (M, N) f32: squared Euclidean distances or dot products.
    ``interpret=None`` auto-selects: interpret off-TPU, compiled on TPU.
    """
    interpret = resolve_interpret(interpret)
    m, d = q.shape
    n, d2 = c.shape
    assert d == d2 and m % bm == 0 and n % bn == 0 and d % bk == 0, (q.shape, c.shape)
    nk = d // bk
    grid = (m // bm, n // bn, nk)
    kernel = functools.partial(_distance_kernel, mode=mode, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(q, c)


@functools.partial(jax.jit, static_argnames=("bn", "bk", "interpret"))
def norms_pallas(c, *, bn=BN, bk=BK, interpret=None):
    """||c_n||^2 for every row: (N, D) -> (1, N)."""
    interpret = resolve_interpret(interpret)
    n, d = c.shape
    assert n % bn == 0 and d % bk == 0, c.shape
    grid = (n // bn, d // bk)
    return pl.pallas_call(
        _norm_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bn, bk), lambda j, k: (j, k))],
        out_specs=pl.BlockSpec((1, bn), lambda j, k: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.float32),
        interpret=interpret,
    )(c)


def angular_pallas(q, c, **kw):
    """OpAngular batched: (dots (M,N), norms (1,N))."""
    return distance_pallas(q, c, mode="angular", **kw), norms_pallas(c, **{
        k: v for k, v in kw.items() if k in ("bn", "bk", "interpret")})
