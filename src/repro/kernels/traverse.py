"""Pallas TPU kernel: the *fused* wavefront-traversal loop (`trace` on-chip).

The batch-level engine (``core/wavefront.py``) already schedules one
OpQuadbox + one OpTriangle round per loop trip, but the loop itself is
ordinary jitted JAX: every round the full SoA ray state — the
``(R, STACK_SIZE)`` traversal stacks, stack pointers, best-hit registers,
job counters — is a ``while_loop`` carry that lives in HBM between rounds.
The hardware the paper models never spills that state: the whole
closest/any-hit loop sits behind one fixed-latency pipeline and the
per-ray context stays resident next to the functional units (the CrossRT
"one accelerated entry point per trace" shape).

This kernel is that residency, TPU-style.  One ``pallas_call`` tile owns
``LANES = 128`` rays; each lane's ray registers and its private
``(STACK_SIZE,)`` stack live in VMEM/VREGs as the carry of an *in-kernel*
``lax.while_loop``, and the full pop → OpQuadbox → OpTriangle → commit →
push round loop runs to completion before anything is written back — one
HBM read of rays + BVH in, one HBM write of hit records out, zero loop
round-trips in between (DESIGN.md §8).

Shared-FU principle
-------------------
The round body calls the *same* stage helpers in ``repro.core.datapath``
(:func:`ray_box_test`, :func:`ray_triangle_test`) as the per-ray and
wavefront engines — one implementation of each stage primitive, reused by
every engine, so hits *and* per-ray job counters bit-match the wavefront
oracle.  Mode selection is ``jax.lax.switch``-free: traversal interleaves
only two opcodes, and like the wavefront engine the tile computes the
OpQuadbox result every round and the OpTriangle round for leaf-parent
lanes, committing each under its ``is_leaf_parent`` mask — a 2-way
predicated datapath rather than a 4-way switched one.

Layout and residency notes
--------------------------
* Rays arrive as one ``(N_RAY_ROWS, LANES)`` union operand per tile
  (origin / direction / inv / shear / k / extent rows), the same
  rows-by-lanes convention as every other kernel here.
* The BVH (node boxes, leaf table, triangle soup) is a *runtime* operand
  mapped whole into every tile — ``Scene.refit`` therefore swaps geometry
  with zero retracing, exactly like the other backends.  The whole tree
  must fit on-chip (a few MB covers the benchmark scenes; production
  trees would stream subtrees, which is future work).
* Per-lane child-box / triangle fetches are cross-lane gathers
  (``jnp.take``).  Off-TPU the kernel runs in interpret mode
  (``kernels/common.resolve_interpret``) where gathers are native; on
  Mosaic they lower to the TPU dynamic-gather path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.bvh import BVH4, DatapathConfig, level_offset, resolve_config
from ..core.datapath import point_box_test, ray_box_test, ray_triangle_test
from ..core.knn import squared_norms
from ..core.neighbor import (
    NEIGHBOR_MODES,
    NeighborRecord,
    insert_sorted,
    leaf_dist_sq,
    prune_bound,
)
from ..core.traversal import STACK_SIZE
from ..core.types import Box, Ray, Triangle
from ..core.wavefront import RAY_TYPES, SHADOW_T_MIN, WavefrontRecord, _tile_ray
from .common import LANES, ceil_to, pad_cols, resolve_interpret

# Ray operand row layout: one (N_RAY_ROWS, LANES) union bundle per tile.
ROW_T_ORG = 0  # rows 0..2   origin
ROW_T_DIR = 3  # rows 3..5   direction (sign bits drive the slab swap)
ROW_T_INV = 6  # rows 6..8   inverse direction
ROW_T_SHEAR = 9  # rows 9..11  shear constants Sx,Sy,Sz
ROW_T_K = 12  # rows 12..14 kx,ky,kz as f32
ROW_T_EXT = 15  # row 15      extent
N_RAY_ROWS = 16  # multiple of 8 (f32 sublane tile)


def _unpack_ray(op: jax.Array) -> Ray:
    """(N_RAY_ROWS, L) operand rows -> an (L,)-batched :class:`Ray`."""
    return Ray(
        origin=op[ROW_T_ORG:ROW_T_ORG + 3].T,
        direction=op[ROW_T_DIR:ROW_T_DIR + 3].T,
        inv=op[ROW_T_INV:ROW_T_INV + 3].T,
        extent=op[ROW_T_EXT],
        kx=op[ROW_T_K].astype(jnp.int32),
        ky=op[ROW_T_K + 1].astype(jnp.int32),
        kz=op[ROW_T_K + 2].astype(jnp.int32),
        shear=op[ROW_T_SHEAR:ROW_T_SHEAR + 3].T,
    )


def _traverse_kernel(ray_ref, nlo_ref, nhi_ref, leaf_ref, tri_ref,
                     t_ref, tri_out_ref, qb_ref, ntri_ref, ovf_ref,
                     rounds_ref, *, depth: int, ray_type: str, t_min: float,
                     max_rounds: int, n_leaf: int, config: DatapathConfig):
    """One tile = 128 rays traversed to completion inside the kernel."""
    arity, stack_size = config.arity, config.stack_size
    ray = _unpack_ray(ray_ref[...])
    # (3, num_nodes_pad); bf16/compressed configs store real bf16 rows —
    # the upcast is lossless (values sit on the bf16 grid by construction),
    # so results stay bit-identical to the wavefront engine's f32 arrays
    node_lo = nlo_ref[...].astype(jnp.float32)
    node_hi = nhi_ref[...].astype(jnp.float32)
    leaf_tri_tab = leaf_ref[0, :]  # (n_leaf_pad,) i32
    tri_rows = tri_ref[...]  # (9, n_tri_pad): rows a.xyz | b.xyz | c.xyz

    leaf_parent_offset = level_offset(depth - 1, arity)
    leaf_offset = level_offset(depth, arity)
    lanes = jnp.arange(LANES, dtype=jnp.int32)
    quad = jnp.arange(arity, dtype=jnp.int32)

    # lane-private traversal state: stacks are (stack_size, LANES) columns,
    # everything is while-carry so it never leaves VMEM/VREGs mid-loop
    stack0 = jnp.zeros((stack_size, LANES), jnp.int32)  # root pre-pushed
    state0 = (stack0, jnp.ones((LANES,), jnp.int32),
              jnp.full((LANES,), jnp.inf, jnp.float32),
              jnp.full((LANES,), -1, jnp.int32),
              jnp.zeros((LANES,), jnp.int32), jnp.zeros((LANES,), jnp.int32),
              jnp.zeros((LANES,), bool), jnp.zeros((LANES,), bool),
              jnp.int32(0))

    def cond(state):
        _, sp, _, _, _, _, _, done, rounds = state
        return jnp.any((sp > 0) & ~done) & (rounds < max_rounds)

    def body(state):
        stack, sp, t_best, best_tri, n_qb, n_tri, overflow, done, rounds = state
        active = (sp > 0) & ~done

        # frontier pop (masked: retired lanes contribute no jobs)
        top = jnp.take_along_axis(stack, jnp.maximum(sp - 1, 0)[None, :],
                                  axis=0)[0]
        node = jnp.where(active, top, 0)
        sp = jnp.where(active, sp - 1, sp)
        is_leaf_parent = node >= leaf_parent_offset
        base = arity * node + 1

        # ---- box test: the popped node's `arity` child AABBs, per lane -----
        cidx = base[:, None] + quad[None, :]  # (L, arity)
        lo = jnp.moveaxis(jnp.take(node_lo, cidx, axis=1), 0, -1)  # (L,A,3)
        hi = jnp.moveaxis(jnp.take(node_hi, cidx, axis=1), 0, -1)
        qb = ray_box_test(ray, Box(lo=lo, hi=hi))  # shared stage helper

        # ---- OpTriangle round for leaf-parent lanes ------------------------
        leaf_pos = base[:, None] - leaf_offset + quad[None, :]
        leaf_pos = jnp.clip(leaf_pos, 0, n_leaf - 1)
        tri_idx = jnp.take(leaf_tri_tab, leaf_pos)  # (L, arity), -1 = padded
        tv = jnp.take(tri_rows, jnp.maximum(tri_idx, 0), axis=1)  # (9,L,A)
        tris = Triangle(a=jnp.moveaxis(tv[0:3], 0, -1),
                        b=jnp.moveaxis(tv[3:6], 0, -1),
                        c=jnp.moveaxis(tv[6:9], 0, -1))
        tr = ray_triangle_test(_tile_ray(ray, arity), tris)  # shared helper
        t = tr.t_num / tr.t_denom  # external division, as everywhere
        valid = (tr.hit & (tri_idx >= 0) & (t < t_best[:, None])
                 & (t <= ray.extent[:, None]) & (t >= t_min))
        t_masked = jnp.where(valid, t, jnp.inf)
        j = jnp.argmin(t_masked, axis=1)
        leaf_t = jnp.take_along_axis(t_masked, j[:, None], axis=1)[:, 0]
        leaf_better = active & is_leaf_parent & (leaf_t < t_best)
        t_best = jnp.where(leaf_better, leaf_t, t_best)
        best_tri = jnp.where(
            leaf_better,
            jnp.take_along_axis(tri_idx, j[:, None], axis=1)[:, 0], best_tri)
        if ray_type != "closest":  # any-hit: retire on first accepted hit
            done = done | leaf_better

        # ---- push hit children far-to-near (sort-network output order) -----
        for i in range(arity):
            slot = arity - 1 - i  # farthest first, nearest ends on top
            ok = (active & ~is_leaf_parent & qb.is_intersect[:, slot]
                  & (qb.tmin[:, slot] < t_best))
            child = base + qb.box_index[:, slot]
            can = ok & (sp < stack_size)  # drop-and-flag at capacity
            overflow = overflow | (ok & (sp >= stack_size))
            pos = jnp.minimum(sp, stack_size - 1)
            cur = jnp.take_along_axis(stack, pos[None, :], axis=0)[0]
            stack = stack.at[pos, lanes].set(jnp.where(can, child, cur))
            sp = jnp.where(can, sp + 1, sp)

        n_qb = n_qb + active.astype(jnp.int32)
        n_tri = n_tri + jnp.where(active & is_leaf_parent, arity, 0)
        return (stack, sp, t_best, best_tri, n_qb, n_tri, overflow, done,
                rounds + 1)

    (_, _, t_best, best_tri, n_qb, n_tri, overflow, _, rounds
     ) = jax.lax.while_loop(cond, body, state0)

    t_ref[0, :] = t_best
    tri_out_ref[0, :] = best_tri
    qb_ref[0, :] = n_qb
    ntri_ref[0, :] = n_tri
    ovf_ref[0, :] = overflow.astype(jnp.int32)
    rounds_ref[0, :] = jnp.full((LANES,), rounds, jnp.int32)


def _pad_cols_repeat(x: jax.Array, n_to: int) -> jax.Array:
    """Pad the last axis to ``n_to`` by repeating column 0 (a valid ray)."""
    pad = n_to - x.shape[-1]
    if pad == 0:
        return x
    rep = jnp.broadcast_to(x[..., :1], x.shape[:-1] + (pad,))
    return jnp.concatenate([x, rep], axis=-1)


def pack_rays(rays: Ray, n_pad: int) -> jax.Array:
    """(R,)-batched rays -> one (N_RAY_ROWS, n_pad) union operand, columns
    past R repeating ray 0 (always valid, results sliced off)."""
    op = jnp.zeros((N_RAY_ROWS, rays.origin.shape[0]), jnp.float32)
    op = op.at[ROW_T_ORG:ROW_T_ORG + 3].set(rays.origin.T)
    op = op.at[ROW_T_DIR:ROW_T_DIR + 3].set(rays.direction.T)
    op = op.at[ROW_T_INV:ROW_T_INV + 3].set(rays.inv.T)
    op = op.at[ROW_T_SHEAR:ROW_T_SHEAR + 3].set(rays.shear.T)
    op = op.at[ROW_T_K:ROW_T_K + 3].set(
        jnp.stack([rays.kx, rays.ky, rays.kz]).astype(jnp.float32))
    op = op.at[ROW_T_EXT].set(rays.extent)
    return _pad_cols_repeat(op, n_pad)


def pack_bvh(bvh: BVH4, config: DatapathConfig | None = None):
    """BVH -> the kernel's resident operands (node boxes transposed to
    rows-by-nodes, leaf table, triangle soup as 9 vertex rows), each
    column-padded to a lane multiple.  Padded node columns carry inverted
    boxes (can never intersect); padded leaf slots carry -1.

    Reduced-precision configs pack the node rows as genuine bf16 — the
    build-side codec already snapped every box to the bf16 grid, so the
    cast is lossless and the kernel's upcast recovers the wavefront
    engine's exact f32 values while halving resident node bytes."""
    config = resolve_config(config)
    n_nodes = bvh.node_lo.shape[0]
    nodes_pad = ceil_to(n_nodes, LANES)
    box_dtype = config.packed_box_dtype
    nlo = pad_cols(bvh.node_lo.T, nodes_pad, jnp.inf).astype(box_dtype)
    nhi = pad_cols(bvh.node_hi.T, nodes_pad, -jnp.inf).astype(box_dtype)
    leaf_pad = ceil_to(bvh.leaf_tri.shape[0], LANES)
    leaf = pad_cols(bvh.leaf_tri[None, :].astype(jnp.int32), leaf_pad, -1)
    tri_pad = ceil_to(bvh.triangles.a.shape[0], LANES)
    tri_rows = pad_cols(
        jnp.concatenate([bvh.triangles.a.T, bvh.triangles.b.T,
                         bvh.triangles.c.T], axis=0), tri_pad)
    return nlo, nhi, leaf, tri_rows


@functools.partial(jax.jit, static_argnames=("depth", "ray_type", "t_min",
                                             "max_rounds", "interpret",
                                             "config"))
def traverse_packed(packed, rays: Ray, depth: int, *,
                    ray_type: str = "closest", t_min: float | None = None,
                    max_rounds: int | None = None,
                    interpret: bool | None = None,
                    config: DatapathConfig | None = None) -> WavefrontRecord:
    """:func:`traverse_fused` on pre-packed BVH operands.

    ``packed`` is :func:`pack_bvh`'s output — the session engine prepares
    it once per scene version and re-feeds it per chunk/shard, so the
    O(scene) transpose/pad work is not re-executed inside every compiled
    call (the backend ``prepare`` hook, DESIGN.md §8).
    """
    if ray_type not in RAY_TYPES:
        raise ValueError(
            f"ray_type must be one of {RAY_TYPES}, got {ray_type!r}")
    config = resolve_config(config)
    if t_min is None:
        t_min = SHADOW_T_MIN if ray_type == "shadow" else 0.0
    if max_rounds is None:
        # exact bound: one pop per node
        max_rounds = level_offset(depth, config.arity)
    interpret = resolve_interpret(interpret)

    n = rays.origin.shape[0]
    if n == 0:
        z = jnp.zeros((0,), jnp.int32)
        return WavefrontRecord(t=jnp.zeros((0,), jnp.float32), tri_index=z,
                               hit=jnp.zeros((0,), bool), quadbox_jobs=z,
                               triangle_jobs=z,
                               stack_overflow=jnp.zeros((0,), bool),
                               rounds=jnp.int32(0))
    n_pad = ceil_to(n, LANES)
    ray_op = pack_rays(rays, n_pad)
    nlo, nhi, leaf, tri_rows = packed
    n_leaf = config.arity ** depth  # true (pre-padding) leaf count

    kernel = functools.partial(
        _traverse_kernel, depth=depth, ray_type=ray_type, t_min=float(t_min),
        max_rounds=int(max_rounds), n_leaf=n_leaf, config=config)
    whole = lambda shape: pl.BlockSpec(shape, lambda t: (0, 0))  # noqa: E731
    out_t, out_tri, out_qb, out_ntri, out_ovf, out_rounds = pl.pallas_call(
        kernel,
        grid=(n_pad // LANES,),
        in_specs=[
            pl.BlockSpec((N_RAY_ROWS, LANES), lambda t: (0, t)),
            whole(nlo.shape),
            whole(nhi.shape),
            whole(leaf.shape),
            whole(tri_rows.shape),
        ],
        out_specs=(
            pl.BlockSpec((1, LANES), lambda t: (0, t)),
            pl.BlockSpec((1, LANES), lambda t: (0, t)),
            pl.BlockSpec((1, LANES), lambda t: (0, t)),
            pl.BlockSpec((1, LANES), lambda t: (0, t)),
            pl.BlockSpec((1, LANES), lambda t: (0, t)),
            pl.BlockSpec((1, LANES), lambda t: (0, t)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((1, n_pad), jnp.float32),
            jax.ShapeDtypeStruct((1, n_pad), jnp.int32),
            jax.ShapeDtypeStruct((1, n_pad), jnp.int32),
            jax.ShapeDtypeStruct((1, n_pad), jnp.int32),
            jax.ShapeDtypeStruct((1, n_pad), jnp.int32),
            jax.ShapeDtypeStruct((1, n_pad), jnp.int32),
        ),
        interpret=interpret,
    )(ray_op, nlo, nhi, leaf, tri_rows)

    best_tri = out_tri[0, :n]
    # batch round count = max over tiles of the per-tile round count (a ray
    # is active for exactly quadbox_jobs consecutive rounds wherever it
    # runs, so this equals the wavefront engine's batch-level value)
    return WavefrontRecord(t=out_t[0, :n], tri_index=best_tri,
                           hit=best_tri >= 0,
                           quadbox_jobs=out_qb[0, :n],
                           triangle_jobs=out_ntri[0, :n],
                           stack_overflow=out_ovf[0, :n] > 0,
                           rounds=jnp.max(out_rounds))


# ---------------------------------------------------------------------------
# Fused neighbor traversal: kNN/radius queries with the loop on-chip
# ---------------------------------------------------------------------------
#
# The distance twin of `_traverse_kernel` (RTNN on the fused engine): same
# tile shape, same lane-private stack residency, same whole-tree runtime
# operands — but rounds order children by point-box *distance* and leaf
# visits feed a running top-k insertion network instead of a best-hit
# register.  The round body calls the same stage helpers as
# `core/neighbor.neighbor_wavefront` (point_box_test, leaf_dist_sq,
# insert_sorted, prune_bound), so both engines' leaf acceptance is the
# brute oracle's exact float comparison.


def pack_point_bvh(bvh: BVH4):
    """Point BVH4 -> the neighbor kernel's resident operands.

    Node boxes and the leaf table pack exactly like :func:`pack_bvh`; the
    cloud packs as 4 rows (x, y, z, ||c||^2) so each candidate gather
    also lands the precomputed squared norm the oracle form needs.
    Deriving the norms here — from the same array the tree holds — is
    what keeps refit safe: re-packing a refit BVH can't serve stale
    norms.
    """
    n_nodes = bvh.node_lo.shape[0]
    nodes_pad = ceil_to(n_nodes, LANES)
    nlo = pad_cols(bvh.node_lo.T, nodes_pad, jnp.inf)
    nhi = pad_cols(bvh.node_hi.T, nodes_pad, -jnp.inf)
    leaf_pad = ceil_to(bvh.leaf_tri.shape[0], LANES)
    leaf = pad_cols(bvh.leaf_tri[None, :].astype(jnp.int32), leaf_pad, -1)
    pts = bvh.triangles.a  # the cloud (see core/build/points.py)
    pts_pad = ceil_to(pts.shape[0], LANES)
    pt_rows = pad_cols(
        jnp.concatenate([pts.T, squared_norms(pts)[None, :]], axis=0),
        pts_pad)
    return nlo, nhi, leaf, pt_rows


def _neighbor_kernel(ray_ref, nlo_ref, nhi_ref, leaf_ref, pts_ref,
                     d_ref, i_ref, cnt_ref, bj_ref, pj_ref, rounds_ref, *,
                     depth: int, k: int, mode: str, max_rounds: int,
                     n_leaf: int):
    """One tile = 128 queries searched to completion inside the kernel."""
    ray = _unpack_ray(ray_ref[...])
    node_lo = nlo_ref[...]  # (3, num_nodes_pad)
    node_hi = nhi_ref[...]
    leaf_tab = leaf_ref[0, :]  # (n_leaf_pad,) i32
    pt_rows = pts_ref[...]  # (4, n_pts_pad): rows x | y | z | ||c||^2

    p = ray.origin  # (L, 3): the query points
    r_sq = ray.extent * ray.extent  # inf extent -> inf bound
    q_sq = jnp.sum(p * p, axis=-1)

    leaf_parent_offset = level_offset(depth - 1)
    leaf_offset = level_offset(depth)
    lanes = jnp.arange(LANES, dtype=jnp.int32)
    quad = jnp.arange(4, dtype=jnp.int32)

    stack0 = jnp.zeros((STACK_SIZE, LANES), jnp.int32)  # root pre-pushed
    state0 = (stack0, jnp.ones((LANES,), jnp.int32),
              jnp.full((k, LANES), jnp.inf, jnp.float32),
              jnp.full((k, LANES), -1, jnp.int32),
              jnp.zeros((LANES,), jnp.int32),
              jnp.zeros((LANES,), jnp.int32), jnp.zeros((LANES,), jnp.int32),
              jnp.int32(0))

    def cond(state):
        _, sp, _, _, _, _, _, rounds = state
        return jnp.any(sp > 0) & (rounds < max_rounds)

    def body(state):
        stack, sp, best_d, best_i, count, n_box, n_pt, rounds = state
        active = sp > 0

        # frontier pop (masked: retired lanes contribute no jobs)
        top = jnp.take_along_axis(stack, jnp.maximum(sp - 1, 0)[None, :],
                                  axis=0)[0]
        node = jnp.where(active, top, 0)
        sp = jnp.where(active, sp - 1, sp)
        is_leaf_parent = node >= leaf_parent_offset
        base = 4 * node + 1

        # ---- point-box job: the popped node's 4 child AABBs, per lane ----
        cidx = base[:, None] + quad[None, :]  # (L, 4)
        lo = jnp.moveaxis(jnp.take(node_lo, cidx, axis=1), 0, -1)  # (L,4,3)
        hi = jnp.moveaxis(jnp.take(node_hi, cidx, axis=1), 0, -1)
        pb = point_box_test(p, Box(lo=lo, hi=hi))  # shared stage helper

        # ---- point-distance round for leaf-parent lanes ------------------
        leaf_pos = base[:, None] - leaf_offset + quad[None, :]
        leaf_pos = jnp.clip(leaf_pos, 0, n_leaf - 1)
        cand = jnp.take(leaf_tab, leaf_pos)  # (L, 4), -1 = padded leaf
        pv = jnp.take(pt_rows, jnp.maximum(cand, 0), axis=1)  # (4, L, 4)
        pts = jnp.moveaxis(pv[0:3], 0, -1)  # (L, 4, 3)
        d_sq = leaf_dist_sq(p, pts, pv[3])  # oracle MXU form, (L, 4)
        in_r = (active[:, None] & is_leaf_parent[:, None]
                & (cand >= 0) & (d_sq <= r_sq[:, None]))
        count = count + jnp.sum(in_r, axis=1)
        for c in range(4):  # static: 4 insertion beats per round
            best_d, best_i = insert_sorted(
                best_d, best_i, d_sq[:, c], cand[:, c], in_r[:, c])

        # ---- push surviving children far-to-near -------------------------
        bound = prune_bound(r_sq, best_d[k - 1], q_sq, mode)
        for c in range(4):
            slot = 3 - c  # farthest first, nearest ends on top
            ok = (active & ~is_leaf_parent
                  & (pb.dist_sq[:, slot] <= bound))
            child = base + pb.box_index[:, slot]
            pos = jnp.minimum(sp, STACK_SIZE - 1)
            cur = jnp.take_along_axis(stack, pos[None, :], axis=0)[0]
            stack = stack.at[pos, lanes].set(jnp.where(ok, child, cur))
            sp = jnp.where(ok, sp + 1, sp)

        n_box = n_box + active.astype(jnp.int32)
        n_pt = n_pt + jnp.where(active & is_leaf_parent, 4, 0)
        return stack, sp, best_d, best_i, count, n_box, n_pt, rounds + 1

    (_, _, best_d, best_i, count, n_box, n_pt, rounds) = jax.lax.while_loop(
        cond, body, state0)

    d_ref[...] = best_d
    i_ref[...] = best_i
    cnt_ref[0, :] = count
    bj_ref[0, :] = n_box
    pj_ref[0, :] = n_pt
    rounds_ref[0, :] = jnp.full((LANES,), rounds, jnp.int32)


@functools.partial(jax.jit, static_argnames=("depth", "k", "mode",
                                             "max_rounds", "interpret"))
def neighbor_packed(packed, queries: Ray, depth: int, k: int, *,
                    mode: str = "within", max_rounds: int | None = None,
                    interpret: bool | None = None) -> NeighborRecord:
    """:func:`neighbor_fused` on pre-packed point-BVH operands.

    ``packed`` is :func:`pack_point_bvh`'s output — prepared once per
    cloud version by the session engine and re-fed per chunk/shard,
    mirroring :func:`traverse_packed`.
    """
    if mode not in NEIGHBOR_MODES:
        raise ValueError(
            f"mode must be one of {NEIGHBOR_MODES}, got {mode!r}")
    if k < 1:
        raise ValueError(f"k must be >= 1, got {k}")
    if max_rounds is None:
        max_rounds = level_offset(depth)  # exact bound: one pop per node
    interpret = resolve_interpret(interpret)

    n = queries.origin.shape[0]
    if n == 0:
        z = jnp.zeros((0,), jnp.int32)
        return NeighborRecord(
            dist_sq=jnp.zeros((0, k), jnp.float32),
            index=jnp.zeros((0, k), jnp.int32),
            valid=jnp.zeros((0, k), bool), count=z, box_jobs=z,
            point_jobs=z, rounds=jnp.int32(0))
    n_pad = ceil_to(n, LANES)
    ray_op = pack_rays(queries, n_pad)
    nlo, nhi, leaf, pt_rows = packed
    n_leaf = 4 ** depth  # true (pre-padding) leaf count

    kernel = functools.partial(
        _neighbor_kernel, depth=depth, k=int(k), mode=mode,
        max_rounds=int(max_rounds), n_leaf=n_leaf)
    whole = lambda shape: pl.BlockSpec(shape, lambda t: (0, 0))  # noqa: E731
    out_d, out_i, out_cnt, out_bj, out_pj, out_rounds = pl.pallas_call(
        kernel,
        grid=(n_pad // LANES,),
        in_specs=[
            pl.BlockSpec((N_RAY_ROWS, LANES), lambda t: (0, t)),
            whole(nlo.shape),
            whole(nhi.shape),
            whole(leaf.shape),
            whole(pt_rows.shape),
        ],
        out_specs=(
            pl.BlockSpec((k, LANES), lambda t: (0, t)),
            pl.BlockSpec((k, LANES), lambda t: (0, t)),
            pl.BlockSpec((1, LANES), lambda t: (0, t)),
            pl.BlockSpec((1, LANES), lambda t: (0, t)),
            pl.BlockSpec((1, LANES), lambda t: (0, t)),
            pl.BlockSpec((1, LANES), lambda t: (0, t)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((k, n_pad), jnp.float32),
            jax.ShapeDtypeStruct((k, n_pad), jnp.int32),
            jax.ShapeDtypeStruct((1, n_pad), jnp.int32),
            jax.ShapeDtypeStruct((1, n_pad), jnp.int32),
            jax.ShapeDtypeStruct((1, n_pad), jnp.int32),
            jax.ShapeDtypeStruct((1, n_pad), jnp.int32),
        ),
        interpret=interpret,
    )(ray_op, nlo, nhi, leaf, pt_rows)

    best_i = out_i[:, :n].T
    return NeighborRecord(dist_sq=out_d[:, :n].T, index=best_i,
                          valid=best_i >= 0, count=out_cnt[0, :n],
                          box_jobs=out_bj[0, :n], point_jobs=out_pj[0, :n],
                          rounds=jnp.max(out_rounds))


def neighbor_fused(bvh: BVH4, queries: Ray, depth: int, k: int, *,
                   mode: str = "within", max_rounds: int | None = None,
                   interpret: bool | None = None) -> NeighborRecord:
    """Neighbor-search a query batch with the whole round loop on-chip.

    Same contract as :func:`repro.core.neighbor.neighbor_wavefront`
    (whose record type it returns): ``queries`` are
    :func:`~repro.core.neighbor.point_queries` rays carrying the radius
    as extent; ``k`` / ``mode`` / ``max_rounds`` are static.  The packed
    BVH is a runtime argument, so ``PointCloudScene.refit`` re-enters the
    compiled kernel with zero retracing.  Convenience entry point packing
    per call; repeated queries should go through the session engine.
    """
    return neighbor_packed(pack_point_bvh(bvh), queries, depth, k,
                           mode=mode, max_rounds=max_rounds,
                           interpret=interpret)


def traverse_fused(bvh: BVH4, rays: Ray, depth: int, *,
                   ray_type: str = "closest", t_min: float | None = None,
                   max_rounds: int | None = None,
                   interpret: bool | None = None,
                   config: DatapathConfig | None = None) -> WavefrontRecord:
    """Traverse a ray batch with the whole round loop inside one kernel.

    Same contract as :func:`repro.core.wavefront.trace_wavefront` (whose
    record type it returns, bit for bit): ``rays`` carry one leading batch
    axis; ``ray_type`` / ``t_min`` / ``max_rounds`` are static, with the
    same defaults.  The BVH is a runtime argument, so ``Scene.refit``
    re-enters the compiled kernel with zero retracing.
    ``interpret=None`` auto-selects interpret mode off-TPU.

    Convenience entry point packing the BVH per call; repeated queries on
    one scene should go through the session engine, which prepares
    :func:`pack_bvh` once per scene version and calls
    :func:`traverse_packed`.
    """
    config = resolve_config(config)
    return traverse_packed(pack_bvh(bvh, config), rays, depth,
                           ray_type=ray_type, t_min=t_min,
                           max_rounds=max_rounds, interpret=interpret,
                           config=config)
