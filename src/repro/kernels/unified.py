"""Pallas TPU kernel: the *unified* mixed-opcode datapath stream.

This is the closest TPU analogue of the paper's top-level module: a single
``pallas_call`` consumes an in-order stream of jobs tagged with a 2-bit
opcode and produces the union output bundle, with per-mode accumulators that
survive across the stream (Table V semantics).

TPU adaptation (DESIGN.md §2)
-----------------------------
* The RTL pipelines jobs in *time* (II=1); the TPU kernel lays 128 parallel
  job streams across VPU *lanes* and steps through "time" along the grid
  axis: tile ``t`` holds beat ``t`` of every lane-stream.
* The RTL's per-job opcode becomes a **scalar-prefetched** per-tile opcode
  (``PrefetchScalarGridSpec``): the grid index maps to an opcode *before*
  the tile's operands are touched, and ``jax.lax.switch`` selects the mode
  datapath — so only one mode's FUs execute per tile, the time-sharing the
  paper gets from feeding one opcode per cycle.
* The per-mode accumulators are VMEM scratch rows that persist across grid
  steps.  Resets/isolation follow Table V exactly: a mode's accumulator
  only moves when a job of that mode passes, and ``reset`` clears only the
  current mode's accumulator(s).
* Operands arrive in the single union row layout of ``common.py`` — the
  Chisel "one bundle type, dead fields optimized away" choice (§III-C);
  Mosaic DCEs unread rows per opcode branch just like the RTL synthesizer.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .common import (
    LANES,
    N_OPERAND_ROWS,
    N_OUTPUT_ROWS,
    OUT_DOT,
    OUT_EUCLID,
    OUT_HIT,
    OUT_IDX,
    OUT_NORM,
    OUT_RESET,
    OUT_TDENOM,
    OUT_THIT,
    OUT_TMIN,
    OUT_TNUM,
    ROW_BOX_HI,
    ROW_BOX_LO,
    ROW_INV,
    ROW_K,
    ROW_MASK,
    ROW_NEG,
    ROW_ORG,
    ROW_RESET,
    ROW_SHEAR,
    ROW_TRI_A,
    ROW_TRI_B,
    ROW_TRI_C,
    ROW_VEC_A,
    ROW_VEC_B,
    fmax_rows,
    fmin_rows,
    quadsort_rows,
    resolve_interpret,
    round_stage,
    select_dim,
)

# Scratch rows: per-mode accumulators (euclid / angular-dot / angular-norm).
ACC_EUCLID, ACC_DOT, ACC_NORM = 0, 1, 2
N_ACC_ROWS = 8  # padded to f32 sublane tile


def _zeros_out(out_ref):
    out_ref[...] = jnp.zeros_like(out_ref)


def _triangle_branch(operand_ref, out_ref, acc_ref):
    """OpTriangle on a tile: Table VII 'Triangle' column (see raytri.py)."""
    org = operand_ref[ROW_ORG:ROW_ORG + 3, :]
    sx, sy, sz = (operand_ref[ROW_SHEAR, :], operand_ref[ROW_SHEAR + 1, :],
                  operand_ref[ROW_SHEAR + 2, :])
    kx, ky, kz = (operand_ref[ROW_K, :], operand_ref[ROW_K + 1, :],
                  operand_ref[ROW_K + 2, :])
    a = operand_ref[ROW_TRI_A:ROW_TRI_A + 3, :] - org  # stage 2
    b = operand_ref[ROW_TRI_B:ROW_TRI_B + 3, :] - org
    c = operand_ref[ROW_TRI_C:ROW_TRI_C + 3, :] - org

    def dims(v):
        return (select_dim(v[0], v[1], v[2], kx),
                select_dim(v[0], v[1], v[2], ky),
                select_dim(v[0], v[1], v[2], kz))

    a_kx, a_ky, a_kz = dims(a)
    b_kx, b_ky, b_kz = dims(b)
    c_kx, c_ky, c_kz = dims(c)

    az, bz, cz = sz * a_kz, sz * b_kz, sz * c_kz  # stage 3
    ax = a_kx - round_stage(sx * a_kz)  # stages 3|4 rounding boundary (§III-D)
    ay = a_ky - round_stage(sy * a_kz)
    bx = b_kx - round_stage(sx * b_kz)
    by = b_ky - round_stage(sy * b_kz)
    cx = c_kx - round_stage(sx * c_kz)
    cy = c_ky - round_stage(sy * c_kz)

    u = round_stage(cx * by) - round_stage(cy * bx)  # stages 5-6
    v = round_stage(ax * cy) - round_stage(ay * cx)
    w = round_stage(bx * ay) - round_stage(by * ax)
    t_denom = (u + v) + w  # stages 8-9
    t_num = (round_stage(u * az) + round_stage(v * bz)) + round_stage(w * cz)

    hit = ((t_num > 0.0) & (t_denom != 0.0)
           & (u >= 0.0) & (v >= 0.0) & (w >= 0.0))  # stage 10

    _zeros_out(out_ref)
    out_ref[OUT_TNUM, :] = t_num
    out_ref[OUT_TDENOM, :] = t_denom
    out_ref[OUT_THIT, :] = hit.astype(jnp.float32)


def _quadbox_branch(operand_ref, out_ref, acc_ref):
    """OpQuadbox on a tile: Table VII 'Box' column (see raybox.py)."""
    org = operand_ref[ROW_ORG:ROW_ORG + 3, :]
    inv = operand_ref[ROW_INV:ROW_INV + 3, :]
    neg = operand_ref[ROW_NEG:ROW_NEG + 3, :]

    tmins, tmaxs = [], []
    for bx in range(4):
        lo = operand_ref[ROW_BOX_LO + 3 * bx:ROW_BOX_LO + 3 * bx + 3, :]
        hi = operand_ref[ROW_BOX_HI + 3 * bx:ROW_BOX_HI + 3 * bx + 3, :]
        t_lo = (lo - org) * inv  # stages 2-3
        t_hi = (hi - org) * inv
        t_near = jnp.where(neg > 0.5, t_hi, t_lo)  # stage 4
        t_far = jnp.where(neg > 0.5, t_lo, t_hi)
        zero = jnp.zeros_like(t_near[0])
        tmin = fmax_rows(t_near[2], fmax_rows(t_near[1], fmax_rows(t_near[0], zero)))
        inf = jnp.full_like(tmin, jnp.inf)
        tmax = fmin_rows(t_far[2], fmin_rows(t_far[1], fmin_rows(t_far[0], inf)))
        tmins.append(tmin)
        tmaxs.append(tmax)

    hits = [(tmins[b] <= tmaxs[b]).astype(jnp.float32) for b in range(4)]  # st. 5
    idxs = [jnp.full_like(tmins[0], float(b)) for b in range(4)]
    keys, (idx_s, hit_s) = quadsort_rows(tmins, [idxs, hits])  # stage 10

    _zeros_out(out_ref)
    for i in range(4):
        out_ref[OUT_TMIN + i, :] = keys[i]
        out_ref[OUT_IDX + i, :] = idx_s[i]
        out_ref[OUT_HIT + i, :] = hit_s[i]


def _euclidean_branch(operand_ref, out_ref, acc_ref):
    """OpEuclidean beat: 16 masked lanes-of-dimension + stream accumulator."""
    mask = operand_ref[ROW_MASK, :]
    reset = operand_ref[ROW_RESET, :]
    d = [(operand_ref[ROW_VEC_A + i, :] - operand_ref[ROW_VEC_B + i, :])
         for i in range(16)]  # stage 2 (16 adders); mask = dead-lane zeroing
    d = [jnp.where(mask > float(i), round_stage(di * di), 0.0)
         for i, di in enumerate(d)]  # stage 3 (16 muls), §III-D boundary
    d = [d[i] + d[i + 8] for i in range(8)]  # stage 4
    d = [d[i] + d[i + 4] for i in range(4)]  # stage 6
    d = [d[i] + d[i + 2] for i in range(2)]  # stage 8
    partial = d[0] + d[1]  # stage 9

    acc_in = jnp.where(reset > 0.5, 0.0, acc_ref[ACC_EUCLID, :])
    out = partial + acc_in  # stage 10 (1 adder)
    acc_ref[ACC_EUCLID, :] = out  # angular accumulators untouched (isolation)

    _zeros_out(out_ref)
    out_ref[OUT_EUCLID, :] = out
    out_ref[OUT_RESET, :] = reset


def _angular_branch(operand_ref, out_ref, acc_ref):
    """OpAngular beat: 8 lanes (two multipliers each) + dual accumulators."""
    mask = operand_ref[ROW_MASK, :]
    reset = operand_ref[ROW_RESET, :]
    dot, nrm = [], []
    for i in range(8):
        q = operand_ref[ROW_VEC_A + i, :]
        c = operand_ref[ROW_VEC_B + i, :]
        live = mask > float(i)
        dot.append(jnp.where(live, round_stage(q * c), 0.0))  # stage 3
        nrm.append(jnp.where(live, round_stage(c * c), 0.0))
    dot = [dot[i] + dot[i + 4] for i in range(4)]  # stage 4
    nrm = [nrm[i] + nrm[i + 4] for i in range(4)]
    dot = [dot[i] + dot[i + 2] for i in range(2)]  # stage 6
    nrm = [nrm[i] + nrm[i + 2] for i in range(2)]
    dot_p = dot[0] + dot[1]  # stage 8
    nrm_p = nrm[0] + nrm[1]

    d_out = dot_p + jnp.where(reset > 0.5, 0.0, acc_ref[ACC_DOT, :])  # stage 9
    n_out = nrm_p + jnp.where(reset > 0.5, 0.0, acc_ref[ACC_NORM, :])
    acc_ref[ACC_DOT, :] = d_out
    acc_ref[ACC_NORM, :] = n_out

    _zeros_out(out_ref)
    out_ref[OUT_DOT, :] = d_out
    out_ref[OUT_NORM, :] = n_out
    out_ref[OUT_RESET, :] = reset


def unified_kernel(opcode_ref, operand_ref, out_ref, acc_ref):
    """One tile = 128 lane-streams × one beat, mode picked by prefetched opcode."""
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():  # stream start: accumulators power up at zero
        acc_ref[...] = jnp.zeros_like(acc_ref)

    op = opcode_ref[t]
    jax.lax.switch(
        op,
        [functools.partial(b, operand_ref, out_ref, acc_ref)
         for b in (_triangle_branch, _quadbox_branch,
                   _euclidean_branch, _angular_branch)],
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def unified_pallas(opcodes, operands, *, interpret=None):
    """Run a mixed-opcode job stream through the unified datapath kernel.

    opcodes:  (T,) i32 — one opcode per tile (beat) of 128 lane-streams.
    operands: (T * N_OPERAND_ROWS?, no) — (N_OPERAND_ROWS, T * LANES) f32,
              column ``t * LANES + l`` is beat t of lane-stream l, packed in
              the union row layout of ``common.py``.
    Returns (N_OUTPUT_ROWS, T * LANES) f32 in the union output layout.
    ``interpret=None`` auto-selects: interpret off-TPU, compiled on TPU.
    """
    interpret = resolve_interpret(interpret)
    rows, n = operands.shape
    assert rows == N_OPERAND_ROWS and n % LANES == 0, operands.shape
    t_tiles = n // LANES
    assert opcodes.shape == (t_tiles,), (opcodes.shape, t_tiles)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(t_tiles,),
        in_specs=[pl.BlockSpec((N_OPERAND_ROWS, LANES), lambda t, op: (0, t))],
        out_specs=pl.BlockSpec((N_OUTPUT_ROWS, LANES), lambda t, op: (0, t)),
        scratch_shapes=[pltpu.VMEM((N_ACC_ROWS, LANES), jnp.float32)],
    )
    return pl.pallas_call(
        unified_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((N_OUTPUT_ROWS, n), jnp.float32),
        interpret=interpret,
    )(opcodes, operands)
