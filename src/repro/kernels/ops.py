"""Jit'd public wrappers for the Pallas kernels: typed I/O, padding, layout.

The kernels speak the transposed row×lane layout; user code speaks the core
pytrees (Ray/Box/Triangle/DatapathJob).  These wrappers pack/unpack and pad
job counts to LANES multiples (padding jobs are benign: zero boxes, NaN-free)
so every call site stays shape-agnostic.

``interpret=None`` everywhere by default, meaning *auto*: interpret mode
off-TPU (CPU CI), compiled Mosaic on a real TPU — the same call sites are
correct on both.  Pass an explicit bool to override.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..core.stream import DatapathJob, DatapathOutput
from ..core.types import Box, QuadBoxResult, Ray, Triangle, TriangleResult
from .common import (
    LANES,
    N_OPERAND_ROWS,
    OUT_DOT,
    OUT_EUCLID,
    OUT_HIT,
    OUT_IDX,
    OUT_NORM,
    OUT_RESET,
    OUT_TDENOM,
    OUT_THIT,
    OUT_TMIN,
    OUT_TNUM,
    ROW_BOX_HI,
    ROW_BOX_LO,
    ROW_INV,
    ROW_K,
    ROW_MASK,
    ROW_NEG,
    ROW_ORG,
    ROW_RESET,
    ROW_SHEAR,
    ROW_TRI_A,
    ROW_VEC_A,
    ROW_VEC_B,
    ceil_to,
    pad_cols,
)
from .distance import angular_pallas, distance_pallas
from .raybox import raybox_pallas
from .raytri import raytri_pallas
from .unified import unified_pallas


# ---------------------------------------------------------------------------
# OpQuadbox
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("interpret",))
def ray_box_kernel(ray: Ray, boxes: Box, *, interpret=None) -> QuadBoxResult:
    """Kernel-backed ray-vs-4-AABB test.  ray fields (N,·); boxes (N,4,3)."""
    n = ray.origin.shape[0]
    n_pad = ceil_to(max(n, 1), LANES)
    org = pad_cols(ray.origin.T, n_pad)  # (3, N')
    inv = pad_cols(ray.inv.T, n_pad, 1.0)
    neg = pad_cols(jnp.signbit(ray.direction).astype(jnp.float32).T, n_pad)
    lo = pad_cols(boxes.lo.reshape(n, 12).T, n_pad)  # (12, N') rows: box-major
    hi = pad_cols(boxes.hi.reshape(n, 12).T, n_pad)
    tmin, idx, hit = raybox_pallas(org, inv, neg, lo, hi, interpret=interpret)
    return QuadBoxResult(tmin=tmin.T[:n], box_index=idx.T[:n],
                         is_intersect=hit.T[:n].astype(bool))


# ---------------------------------------------------------------------------
# OpTriangle
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("interpret",))
def ray_triangle_kernel(ray: Ray, tri: Triangle, *, interpret=None) -> TriangleResult:
    """Kernel-backed watertight ray-triangle test.  All batched (N, ·)."""
    n = ray.origin.shape[0]
    n_pad = ceil_to(max(n, 1), LANES)
    org = pad_cols(ray.origin.T, n_pad)
    shear = pad_cols(ray.shear.T, n_pad, 1.0)
    k = pad_cols(jnp.stack([ray.kx, ray.ky, ray.kz]).astype(jnp.float32), n_pad)
    va = pad_cols(tri.a.T, n_pad)
    vb = pad_cols(tri.b.T, n_pad)
    vc = pad_cols(tri.c.T, n_pad)
    t_num, t_denom, hit = raytri_pallas(org, shear, k, va, vb, vc,
                                        interpret=interpret)
    return TriangleResult(t_num=t_num[0, :n], t_denom=t_denom[0, :n],
                          hit=hit[0, :n].astype(bool))


# ---------------------------------------------------------------------------
# OpEuclidean / OpAngular (MXU batched form)
# ---------------------------------------------------------------------------


def _pad2d(x, bm, bk):
    m, k = x.shape
    return jnp.pad(x, ((0, ceil_to(m, bm) - m), (0, ceil_to(k, bk) - k)))


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def euclidean_kernel(q, c, *, bm=128, bn=128, bk=128, interpret=None):
    """Pairwise squared distances (M,D)x(N,D) -> (M,N), kernel-backed."""
    m, n = q.shape[0], c.shape[0]
    qp, cp = _pad2d(q, bm, bk), _pad2d(c, bn, bk)  # same D -> same padded K
    out = distance_pallas(qp, cp, mode="euclidean", bm=bm, bn=bn, bk=bk,
                          interpret=interpret)
    return out[:m, :n]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def angular_kernel(q, c, *, bm=128, bn=128, bk=128, interpret=None):
    """OpAngular batched: ((M,N) dots, (N,) norms), kernel-backed."""
    m, n = q.shape[0], c.shape[0]
    qp, cp = _pad2d(q, bm, bk), _pad2d(c, bn, bk)  # same D -> same padded K
    dots, norms = angular_pallas(qp, cp, bm=bm, bn=bn, bk=bk, interpret=interpret)
    return dots[:m, :n], norms[0, :n]


# ---------------------------------------------------------------------------
# Unified mixed-opcode stream
# ---------------------------------------------------------------------------


def pack_unified(jobs: DatapathJob) -> tuple[jax.Array, jax.Array]:
    """Pack a (T, L) job grid into (opcodes (T,), operands (48, T*L)).

    Beat t of lane-stream l lives at column t*LANES + l.  All lanes of a
    tile share jobs.opcode[t, 0] (one opcode per beat, as the HW takes one
    opcode per cycle).
    """
    t, l = jobs.opcode.shape
    assert l == LANES, f"lane axis must be {LANES}, got {l}"
    n = t * l

    def rows(x, r0, nrows):  # x: (T, L, nrows) -> scatter into layout rows
        return x.reshape(n, nrows).T, r0

    operands = jnp.zeros((N_OPERAND_ROWS, n), jnp.float32)

    def put(x, r0):
        nrows = x.shape[0]
        return operands.at[r0:r0 + nrows, :].set(x)

    operands = put(jobs.ray.origin.reshape(n, 3).T, ROW_ORG)
    # INV/SHEAR share rows; NEG/K share rows (union layout).  Quadbox tiles
    # need inv+neg; triangle tiles need shear+k.  Select per tile.
    is_tri = (jobs.opcode[:, :1] == 0)  # (T, 1)
    inv_or_shear = jnp.where(is_tri[..., None], jobs.ray.shear, jobs.ray.inv)
    operands = put(inv_or_shear.reshape(n, 3).T, ROW_INV)
    kvec = jnp.stack([jobs.ray.kx, jobs.ray.ky, jobs.ray.kz], axis=-1).astype(jnp.float32)
    neg = jnp.signbit(jobs.ray.direction).astype(jnp.float32)
    operands = put(jnp.where(is_tri[..., None], kvec, neg).reshape(n, 3).T, ROW_NEG)

    is_vec = (jobs.opcode[:, :1] >= 2)[..., None]  # (T,1,1)
    box_lo = jobs.boxes.lo.reshape(t, l, 12)
    box_hi = jobs.boxes.hi.reshape(t, l, 12)
    tri_rows = jnp.concatenate(
        [jobs.triangle.a, jobs.triangle.b, jobs.triangle.c], axis=-1)  # (T,L,9)
    tri_rows = jnp.pad(tri_rows, ((0, 0), (0, 0), (0, 3)))
    geo_lo = jnp.where(is_tri[..., None], tri_rows, box_lo)
    # rows 9..24: box_lo(12)+pad / triangle(9)+pad / vec_a(16)
    row_a = jnp.where(is_vec, jobs.vec_a,
                      jnp.pad(geo_lo, ((0, 0), (0, 0), (0, 4))))
    operands = put(row_a.reshape(n, 16).T, ROW_VEC_A)
    # rows 25..40: box_hi(12)+pad / vec_b(16)
    row_b = jnp.where(is_vec, jobs.vec_b,
                      jnp.pad(box_hi, ((0, 0), (0, 0), (0, 4))))
    operands = put(row_b.reshape(n, 16).T, ROW_VEC_B)

    # Lane-validity mask encoded as a count (the kernel compares mask > i),
    # which keeps it one row instead of 16.
    mask_count = jobs.mask.astype(jnp.float32).sum(-1)
    operands = put(mask_count.reshape(1, n), ROW_MASK)
    operands = put(jobs.reset_accum.astype(jnp.float32).reshape(1, n), ROW_RESET)
    return jobs.opcode[:, 0].astype(jnp.int32), operands


def unpack_unified(opcodes: jax.Array, out: jax.Array, t: int) -> DatapathOutput:
    """(16, T*L) kernel output -> DatapathOutput with (T, L) leaves."""
    def row(r):
        return out[r].reshape(t, LANES)

    def rows4(r0):
        return jnp.stack([out[r0 + i] for i in range(4)], -1).reshape(t, LANES, 4)

    op = jnp.broadcast_to(opcodes[:, None], (t, LANES)).astype(jnp.int32)
    return DatapathOutput(
        opcode=op,
        tmin=rows4(OUT_TMIN), box_index=rows4(OUT_IDX).astype(jnp.int32),
        is_intersect=rows4(OUT_HIT) > 0.5,
        t_num=row(OUT_TNUM), t_denom=row(OUT_TDENOM),
        triangle_hit=row(OUT_THIT) > 0.5,
        euclidean_accumulator=row(OUT_EUCLID),
        angular_dot_product=row(OUT_DOT), angular_norm=row(OUT_NORM),
        reset_accum=row(OUT_RESET) > 0.5,
    )


def unified_datapath(jobs: DatapathJob, *, interpret=None) -> DatapathOutput:
    """Mixed-opcode stream through the unified kernel.

    jobs: every leaf shaped (T, LANES, ...) — T beats of 128 lane-streams;
    each beat carries one opcode (jobs.opcode[:, 0] is used).
    """
    t = jobs.opcode.shape[0]
    opcodes, operands = pack_unified(jobs)
    out = unified_pallas(opcodes, operands, interpret=interpret)
    return unpack_unified(opcodes, out, t)
