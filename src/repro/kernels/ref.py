"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

Each oracle has the *same I/O signature* as its kernel wrapper in
``ops.py`` but routes through ``repro.core`` — an independent, brute-force
validated implementation (see tests/test_render.py's traversal-vs-bruteforce
check).  Kernels are asserted allclose (usually bit-exact: both sides follow
Table VII's association order in f32) against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.datapath import ray_box_test, ray_triangle_test
from ..core.knn import angular_scores, euclidean_scores
from ..core.stream import DatapathJob, DatapathOutput, unified_stream
from ..core.types import Box, QuadBoxResult, Ray, Triangle, TriangleResult


def ray_box_ref(ray: Ray, boxes: Box) -> QuadBoxResult:
    return ray_box_test(ray, boxes)


def ray_triangle_ref(ray: Ray, tri: Triangle) -> TriangleResult:
    return ray_triangle_test(ray, tri)


def euclidean_ref(q: jax.Array, c: jax.Array) -> jax.Array:
    """Same MXU-form math as the kernel (norms-expansion), (M,N) f32."""
    return euclidean_scores(q, c)


def euclidean_direct_ref(q: jax.Array, c: jax.Array) -> jax.Array:
    """The *paper's* form: sum_k (q-c)^2 directly (numerically strictest)."""
    q = q.astype(jnp.float32)
    c = c.astype(jnp.float32)
    return jnp.sum((q[:, None, :] - c[None, :, :]) ** 2, axis=-1)


def angular_ref(q: jax.Array, c: jax.Array):
    dots, norms = angular_scores(q, c)
    return dots, norms


def unified_ref(jobs: DatapathJob) -> DatapathOutput:
    """Per-lane-stream oracle: vmap the scalar in-order stream over lanes.

    jobs leaves: (T, LANES, ...).  Lane l is an independent stream of T
    in-order jobs — exactly the kernel's accumulator semantics.
    """
    def one_lane(lane_jobs):
        _, out = unified_stream(lane_jobs)
        return out

    # move lane axis to front for vmap: (T, L, ...) -> (L, T, ...)
    swapped = jax.tree.map(lambda x: jnp.swapaxes(x, 0, 1), jobs)
    out = jax.vmap(one_lane)(swapped)
    return jax.tree.map(lambda x: jnp.swapaxes(x, 0, 1), out)
