"""Pallas TPU kernel: OpQuadbox -- one ray vs four AABBs, 128 rays/tile.

Layout: SoA transposed so the job batch is the lane axis.  Per grid step one
``(rows, LANES)`` tile of rays+boxes is resident in VMEM; all arithmetic is
VPU row ops; the quad-sort is the paper's 5-CAS network vectorised across
lanes.  Stage structure (sub -> mul -> swap/minmax -> compare -> sort)
follows Table VII's "Box" column; see ``repro/core/datapath.py`` for the
stage-by-stage commentary.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import (LANES, fmax_rows, fmin_rows, quadsort_rows,
                     resolve_interpret)


def raybox_kernel(org_ref, inv_ref, neg_ref, lo_ref, hi_ref,
                  tmin_ref, idx_ref, hit_ref):
    """One tile: org/inv/neg (3, L); lo/hi (12, L) = 4 boxes x 3 dims."""
    org = org_ref[...]
    inv = inv_ref[...]
    neg = neg_ref[...]  # 1.0 where direction sign bit set

    tmins, tmaxs = [], []
    for b in range(4):
        lo = lo_ref[3 * b:3 * b + 3, :]
        hi = hi_ref[3 * b:3 * b + 3, :]
        # stage 2 (adders): translate planes; stage 3 (multipliers): slabs
        t_lo = (lo - org) * inv
        t_hi = (hi - org) * inv
        # stage 4: sign swap + min/max trees with comparator NaN semantics
        t_near = jnp.where(neg > 0.5, t_hi, t_lo)
        t_far = jnp.where(neg > 0.5, t_lo, t_hi)
        zero = jnp.zeros_like(t_near[0])
        tmin = fmax_rows(t_near[2], fmax_rows(t_near[1], fmax_rows(t_near[0], zero)))
        inf = jnp.full_like(tmin, jnp.inf)
        tmax = fmin_rows(t_far[2], fmin_rows(t_far[1], fmin_rows(t_far[0], inf)))
        tmins.append(tmin)
        tmaxs.append(tmax)

    # stage 5: intersect compares
    hits = [(tmins[b] <= tmaxs[b]).astype(jnp.float32) for b in range(4)]
    idxs = [jnp.full_like(tmins[0], float(b)) for b in range(4)]

    # stage 10: two quad-sorting networks (values + indices), hits ride along
    keys, (idx_s, hit_s) = quadsort_rows(tmins, [idxs, hits])

    tmin_ref[...] = jnp.stack(keys)
    idx_ref[...] = jnp.stack(idx_s).astype(jnp.int32)
    hit_ref[...] = jnp.stack(hit_s).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def raybox_pallas(org, inv, neg, box_lo, box_hi, *, interpret=None):
    """org/inv/neg: (3, N) f32; box_lo/hi: (12, N) f32.  N % LANES == 0.

    Returns (tmin (4,N) f32, idx (4,N) i32, hit (4,N) i32), tmin sorted.
    """
    interpret = resolve_interpret(interpret)
    n = org.shape[1]
    assert n % LANES == 0, n
    grid = (n // LANES,)

    def cols(r):
        return lambda i: (0, i)

    out_shape = (
        jax.ShapeDtypeStruct((4, n), jnp.float32),
        jax.ShapeDtypeStruct((4, n), jnp.int32),
        jax.ShapeDtypeStruct((4, n), jnp.int32),
    )
    return pl.pallas_call(
        raybox_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((3, LANES), cols(3)),
            pl.BlockSpec((3, LANES), cols(3)),
            pl.BlockSpec((3, LANES), cols(3)),
            pl.BlockSpec((12, LANES), cols(12)),
            pl.BlockSpec((12, LANES), cols(12)),
        ],
        out_specs=(
            pl.BlockSpec((4, LANES), cols(4)),
            pl.BlockSpec((4, LANES), cols(4)),
            pl.BlockSpec((4, LANES), cols(4)),
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(org, inv, neg, box_lo, box_hi)
