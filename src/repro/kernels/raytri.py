"""Pallas TPU kernel: OpTriangle -- watertight Woop test, 128 rays/tile.

The RTL's per-job ``A[kx]`` crossbar becomes a per-lane 3-way select mux
(:func:`repro.kernels.common.select_dim`) -- a gather would serialise on the
VPU, a select is one lane op.  Stage structure follows Table VII's
"Triangle" column.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import LANES, resolve_interpret, round_stage, select_dim


def raytri_kernel(org_ref, shear_ref, k_ref, va_ref, vb_ref, vc_ref,
                  tnum_ref, tdenom_ref, hit_ref):
    """org/shear/k: (3, L); va/vb/vc: (3, L) vertices; outputs (1, L)."""
    org = org_ref[...]
    sx, sy, sz = shear_ref[0], shear_ref[1], shear_ref[2]
    kx, ky, kz = k_ref[0], k_ref[1], k_ref[2]  # f32-encoded {0.,1.,2.}

    # stage 2: translate vertices (9 adders)
    a = va_ref[...] - org
    b = vb_ref[...] - org
    c = vc_ref[...] - org

    def dims(v):
        return (select_dim(v[0], v[1], v[2], kx),
                select_dim(v[0], v[1], v[2], ky),
                select_dim(v[0], v[1], v[2], kz))

    a_kx, a_ky, a_kz = dims(a)
    b_kx, b_ky, b_kz = dims(b)
    c_kx, c_ky, c_kz = dims(c)

    # stage 3: shear products (9 multipliers).  round_stage pins the paper's
    # §III-D per-FU rounding between stages 3 and 4 (see common.py).
    az = sz * a_kz
    bz = sz * b_kz
    cz = sz * c_kz
    # stage 4: shear subtract (6 adders)
    ax = a_kx - round_stage(sx * a_kz)
    ay = a_ky - round_stage(sy * a_kz)
    bx = b_kx - round_stage(sx * b_kz)
    by = b_ky - round_stage(sy * b_kz)
    cx = c_kx - round_stage(sx * c_kz)
    cy = c_ky - round_stage(sy * c_kz)

    # stages 5-6: edge functions (6 muls + 3 adds)
    u = round_stage(cx * by) - round_stage(cy * bx)
    v = round_stage(ax * cy) - round_stage(ay * cx)
    w = round_stage(bx * ay) - round_stage(by * ax)

    # stages 7-9: t_num / t_denom (3 muls + 4 adds)
    t_denom = (u + v) + w
    t_num = (round_stage(u * az) + round_stage(v * bz)) + round_stage(w * cz)

    # stage 10: hit decision (5 comparators, culling variant)
    hit = ((t_num > 0.0) & (t_denom != 0.0)
           & (u >= 0.0) & (v >= 0.0) & (w >= 0.0))

    tnum_ref[...] = t_num[None]
    tdenom_ref[...] = t_denom[None]
    hit_ref[...] = hit[None].astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def raytri_pallas(org, shear, k, va, vb, vc, *, interpret=None):
    """All inputs (3, N) f32 (k holds kx/ky/kz as f32).  N % LANES == 0.

    Returns (t_num (1,N) f32, t_denom (1,N) f32, hit (1,N) i32).
    """
    interpret = resolve_interpret(interpret)
    n = org.shape[1]
    assert n % LANES == 0, n
    grid = (n // LANES,)
    spec3 = pl.BlockSpec((3, LANES), lambda i: (0, i))
    spec1 = pl.BlockSpec((1, LANES), lambda i: (0, i))
    out_shape = (
        jax.ShapeDtypeStruct((1, n), jnp.float32),
        jax.ShapeDtypeStruct((1, n), jnp.float32),
        jax.ShapeDtypeStruct((1, n), jnp.int32),
    )
    return pl.pallas_call(
        raytri_kernel,
        grid=grid,
        in_specs=[spec3] * 6,
        out_specs=(spec1, spec1, spec1),
        out_shape=out_shape,
        interpret=interpret,
    )(org, shear, k, va, vb, vc)
