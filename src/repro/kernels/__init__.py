"""Pallas TPU kernels for the datapath hot-spots, each with ops.py wrapper
and ref.py pure-jnp oracle (validated in interpret mode on CPU)."""
from .common import LANES, round_stage  # noqa: F401
from .raybox import raybox_pallas  # noqa: F401
from .raytri import raytri_pallas  # noqa: F401
from .distance import angular_pallas, distance_pallas, norms_pallas  # noqa: F401
from .traverse import traverse_fused  # noqa: F401
from .unified import unified_pallas  # noqa: F401
from .ops import (  # noqa: F401
    angular_kernel,
    euclidean_kernel,
    ray_box_kernel,
    ray_triangle_kernel,
    unified_datapath,
)
from . import ref  # noqa: F401
