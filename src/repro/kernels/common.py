"""Shared kernel-side stage primitives and the unified operand row layout.

TPU adaptation notes (DESIGN.md §2)
-----------------------------------
The RTL pipeline lays jobs out in *time* (one job per cycle through shared
FUs).  The TPU kernels lay jobs out in *lanes*: a tile is ``(rows, LANES)``
with one job per lane, rows holding the job's fields.  ``LANES = 128``
matches the VPU lane width; row counts are padded to multiples of 8
(f32 sublane tiling), so every tile is VMEM/VREG aligned.

The compare-select helpers here have the same NaN semantics as the
hardware comparators (see ``repro.core.datapath``) and are shared by every
kernel -- the code-level analogue of the paper's shared functional units.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

LANES = 128  # jobs per tile (VPU lane width)


def round_stage(x: jax.Array) -> jax.Array:
    """Mark a per-stage rounding boundary (paper §III-D, choice (d)).

    On a real TPU every Mosaic VPU op rounds to f32 — the paper's
    round-at-every-functional-unit choice is *native*, and these markers
    delimit exactly where the RTL's rounding circuits sit.  In ``interpret``
    mode the kernel body is XLA-compiled for CPU, where LLVM contracts a
    ``mul`` feeding an ``add`` into an FMA (measured: not disabled by
    ``optimization_barrier`` nor any ``--xla_cpu_*`` flag), i.e. CPU
    validation sees *extra* precision at these boundaries.  Tests therefore
    compare kernel-vs-oracle with one-FMA ULP tolerances on t_num/t_denom
    and distance sums; everything reachable without a mul->add chain
    (ray-box, sort networks, hit logic) is compared bit-exactly.

    Kept as an identity seam: Mosaic has no lowering rule for
    ``lax.optimization_barrier``, so a hard barrier would break real-TPU
    compilation for zero benefit there.
    """
    return x

# ---------------------------------------------------------------------------
# Unified operand layout (rows x LANES), one job per lane.  Mirrors the
# paper's single union input bundle (Table V / §III-C): every mode's fields
# live at fixed rows; modes ignore rows they do not use.
# ---------------------------------------------------------------------------
ROW_ORG = 0  # rows 0..2   ray origin            (quadbox, triangle)
ROW_INV = 3  # rows 3..5   ray inverse direction (quadbox)
ROW_NEG = 6  # rows 6..8   ray direction sign    (quadbox: 1.0 if signbit)
ROW_SHEAR = 3  # rows 3..5   ray shear Sx,Sy,Sz  (triangle; reuses INV rows --
#                            the two modes never need both, like shared regs)
ROW_K = 6  # rows 6..8   kx,ky,kz as f32          (triangle; reuses NEG rows)
ROW_BOX_LO = 9  # rows 9..20   4 boxes x 3 dims (quadbox; shares VEC_A rows)
ROW_BOX_HI = 25  # rows 25..36  4 boxes x 3 dims (quadbox; shares VEC_B rows)
ROW_TRI_A = 9  # rows 9..11   vertex A (triangle)
ROW_TRI_B = 12  # rows 12..14  vertex B
ROW_TRI_C = 15  # rows 15..17  vertex C
ROW_VEC_A = 9  # rows 9..24   vector a / q, 16 lanes-of-dimension (euclid/ang)
ROW_VEC_B = 25  # rows 25..40  vector b / c
ROW_MASK = 41  # row 41       lane-validity mask (1.0/0.0)
ROW_RESET = 42  # row 42      accumulator reset flag (1.0/0.0)
N_OPERAND_ROWS = 48  # padded to a multiple of 8

# Unified output layout (rows x LANES).
OUT_TMIN = 0  # rows 0..3   sorted tmin          (quadbox)
OUT_IDX = 4  # rows 4..7    sorted box indices   (quadbox, as f32)
OUT_HIT = 8  # rows 8..11   sorted hit mask      (quadbox, as f32)
OUT_TNUM = 0  # row 0       t_num                (triangle)
OUT_TDENOM = 1  # row 1     t_denom              (triangle)
OUT_THIT = 2  # row 2       hit                  (triangle)
OUT_EUCLID = 0  # row 0     accumulator          (euclidean)
OUT_DOT = 0  # row 0        dot product          (angular)
OUT_NORM = 1  # row 1       norm                 (angular)
OUT_RESET = 12  # row 12    propagated reset     (euclid/angular)
N_OUTPUT_ROWS = 16


def fmax_rows(a, b):
    """Comparator-style max: keeps ``b`` when the compare is false (NaN a)."""
    return jnp.where(a > b, a, b)


def fmin_rows(a, b):
    return jnp.where(a < b, a, b)


def quadsort_rows(keys: list, payloads: list[list]):
    """The paper's 4-input sorting network over row vectors.

    ``keys``: list of 4 arrays (each one lane-row); ``payloads``: list of
    lists-of-4 permuted alongside.  5 compare-exchanges: (0,1)(2,3)(0,2)(1,3)(1,2).
    """
    keys = list(keys)
    payloads = [list(p) for p in payloads]
    for i, j in [(0, 1), (2, 3), (0, 2), (1, 3), (1, 2)]:
        lt = keys[i] < keys[j]
        keys[i], keys[j] = (jnp.where(lt, keys[i], keys[j]),
                            jnp.where(lt, keys[j], keys[i]))
        for p in payloads:
            p[i], p[j] = jnp.where(lt, p[i], p[j]), jnp.where(lt, p[j], p[i])
    return keys, payloads


def select_dim(vx, vy, vz, k):
    """TPU-native mux for per-lane dynamic dimension index k in {0,1,2}.

    The RTL uses a 3-way mux; a per-lane gather would be slow on the VPU, so
    we lower the same mux as two selects.
    """
    return jnp.where(k == 0.0, vx, jnp.where(k == 1.0, vy, vz))


def ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


def pad_cols(x: jax.Array, n_to: int, value=0.0) -> jax.Array:
    """Pad the last (lane) axis to ``n_to`` columns with a constant —
    the shared job-count padding every kernel wrapper applies."""
    pad = n_to - x.shape[-1]
    if pad == 0:
        return x
    return jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)],
                   constant_values=value)


def resolve_interpret(interpret: bool | None) -> bool:
    """The kernels' ``interpret=None`` default means *auto*: interpret mode
    off-TPU (the only thing the CPU backend supports), compiled Mosaic on a
    real TPU — so the same call site is correct on CPU CI and on device."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret
