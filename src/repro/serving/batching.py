"""Request coalescing for the ray-query server (DESIGN.md §10).

The compiled query kernels want full lane-multiple tiles; users send
four-ray requests.  The coalescer is the adapter: requests to the same
``(method, static-params)`` bucket accumulate until one of three
triggers flushes the bucket as a single batch —

* **full** — accumulated rows reached ``max_batch_rows`` (a whole batch
  is ready; waiting longer only adds latency),
* **timer** — the bucket's *oldest* request has waited ``max_wait``
  (bounded time-to-first-flush under trickle traffic),
* **deadline** — the bucket's *earliest* request deadline is within
  ``deadline_margin`` of now (deadline pressure overrides the timer:
  flush before the promise is broken, not after).

Everything here is a plain synchronous state machine driven by explicit
``now`` timestamps — no sleeps, no event loop, no wall clock — so the
flush semantics are unit-tested with a fake clock
(``tests/test_serving.py``); ``repro.serving.query_server`` wraps it
with real asyncio timers.  Batch *shapes* come from the engine's own
planner (``QueryEngine.plan_for`` / ``core.dispatch.make_plan``), and
responses are split back per request with the dispatch layer's
``slice_rows`` — the same pad/unpad contract every backend already
honors, which is what makes coalesced execution bit-identical to
per-request execution.
"""
from __future__ import annotations

import itertools
from typing import Any, NamedTuple, Optional

__all__ = [
    "FLUSH_DEADLINE",
    "FLUSH_DRAIN",
    "FLUSH_FULL",
    "FLUSH_TIMER",
    "Batch",
    "Coalescer",
    "Request",
]

FLUSH_FULL = "full"  # max_batch_rows reached
FLUSH_TIMER = "timer"  # oldest request waited max_wait
FLUSH_DEADLINE = "deadline"  # earliest deadline within deadline_margin
FLUSH_DRAIN = "drain"  # explicit flush_all (shutdown / drain)

_ids = itertools.count()


class Request(NamedTuple):
    """One admitted query request, as the coalescer sees it.

    ``params`` is the hashable static-argument tuple (the bucket key is
    ``(method, params)`` — only requests whose compiled program would be
    *identical* ever share a batch).  ``payload`` is the per-row pytree
    (a ray bundle or an ``(n_rows, d)`` query block).  ``deadline`` is
    absolute, on the coalescer's clock.  ``future``/``n_rows`` travel
    through untouched so the server can split and deliver the response.
    """

    id: int
    method: str
    params: tuple
    payload: Any
    n_rows: int
    enqueued: float
    deadline: Optional[float]
    future: Any


def make_request(method: str, params: tuple, payload, n_rows: int,
                 now: float, deadline: Optional[float] = None,
                 future=None) -> Request:
    return Request(next(_ids), method, params, payload, int(n_rows),
                   float(now), deadline, future)


class Batch(NamedTuple):
    """A flushed bucket: the requests whose payloads will be row-
    concatenated into one engine call, plus why the flush fired."""

    method: str
    params: tuple
    requests: tuple  # of Request, arrival order
    rows: int
    reason: str

    @property
    def sizes(self) -> list:
        """Per-request row counts — the ``slice_rows`` split spec."""
        return [r.n_rows for r in self.requests]


class _Bucket:
    __slots__ = ("method", "params", "requests", "rows", "oldest",
                 "earliest_deadline")

    def __init__(self, method: str, params: tuple):
        self.method = method
        self.params = params
        self.requests: list = []
        self.rows = 0
        self.oldest: Optional[float] = None
        self.earliest_deadline: Optional[float] = None

    def add(self, req: Request) -> None:
        self.requests.append(req)
        self.rows += req.n_rows
        if self.oldest is None:
            self.oldest = req.enqueued
        if req.deadline is not None:
            d = self.earliest_deadline
            self.earliest_deadline = (req.deadline if d is None
                                      else min(d, req.deadline))

    def refresh(self) -> None:
        """Recompute the cached extrema after an eviction."""
        self.oldest = min((r.enqueued for r in self.requests), default=None)
        ds = [r.deadline for r in self.requests if r.deadline is not None]
        self.earliest_deadline = min(ds) if ds else None

    def as_batch(self, reason: str) -> Batch:
        return Batch(self.method, self.params, tuple(self.requests),
                     self.rows, reason)


class Coalescer:
    """Per-(method, params) request buckets with full/timer/deadline
    flushing.  Drive it with ``add(req)`` (returns the request's bucket
    as a :class:`Batch` iff it just went full), ``poll(now)`` (returns
    every bucket whose timer or deadline fired), and ``next_due()``
    (when ``poll`` next needs to run — the async layer's wake-up time).
    """

    def __init__(self, *, max_batch_rows: int = 1024,
                 max_wait: float = 2e-3, deadline_margin: float = 1e-3):
        max_batch_rows = int(max_batch_rows)
        if max_batch_rows < 1:
            raise ValueError(
                f"max_batch_rows must be >= 1, got {max_batch_rows}")
        if max_wait < 0:
            raise ValueError(f"max_wait must be >= 0, got {max_wait}")
        if deadline_margin < 0:
            raise ValueError(
                f"deadline_margin must be >= 0, got {deadline_margin}")
        self.max_batch_rows = max_batch_rows
        self.max_wait = float(max_wait)
        self.deadline_margin = float(deadline_margin)
        self._buckets: dict = {}  # (method, params) -> _Bucket

    # -- state ------------------------------------------------------------

    @property
    def depth(self) -> int:
        """Requests currently waiting in buckets."""
        return sum(len(b.requests) for b in self._buckets.values())

    @property
    def pending_rows(self) -> int:
        return sum(b.rows for b in self._buckets.values())

    def depth_for(self, method: str) -> int:
        """Requests currently waiting in ``method``'s buckets."""
        return sum(len(b.requests)
                   for (m, _), b in self._buckets.items() if m == method)

    def __len__(self) -> int:
        return self.depth

    # -- the three flush triggers -----------------------------------------

    def add(self, req: Request) -> Optional[Batch]:
        """Queue ``req``; if its bucket just reached ``max_batch_rows``
        the whole bucket flushes immediately (reason ``"full"``) and is
        returned.  A single oversized request (> max_batch_rows rows)
        flushes by itself — the engine's ``chunk_size`` knob, not the
        coalescer, is the memory bound."""
        key = (req.method, req.params)
        bucket = self._buckets.get(key)
        if bucket is None:
            bucket = self._buckets[key] = _Bucket(req.method, req.params)
        bucket.add(req)
        if bucket.rows >= self.max_batch_rows:
            del self._buckets[key]
            return bucket.as_batch(FLUSH_FULL)
        return None

    def _flush_reason(self, bucket: _Bucket, now: float) -> Optional[str]:
        d = bucket.earliest_deadline
        if d is not None and d - self.deadline_margin <= now:
            return FLUSH_DEADLINE
        if bucket.oldest is not None and now - bucket.oldest >= self.max_wait:
            return FLUSH_TIMER
        return None

    def poll(self, now: float) -> list:
        """Flush every bucket whose max-wait timer expired or whose
        earliest deadline is within ``deadline_margin`` (deadline
        pressure wins the reason label when both hold)."""
        out = []
        for key in list(self._buckets):
            bucket = self._buckets[key]
            reason = self._flush_reason(bucket, now)
            if reason is not None:
                del self._buckets[key]
                out.append(bucket.as_batch(reason))
        return out

    def next_due(self) -> Optional[float]:
        """The earliest instant at which :meth:`poll` would flush
        something (None = nothing pending)."""
        due = None
        for bucket in self._buckets.values():
            t = bucket.oldest + self.max_wait
            if bucket.earliest_deadline is not None:
                t = min(t, bucket.earliest_deadline - self.deadline_margin)
            due = t if due is None else min(due, t)
        return due

    # -- drain / shed -----------------------------------------------------

    def flush_all(self, reason: str = FLUSH_DRAIN) -> list:
        """Flush every bucket now, regardless of triggers (server drain
        and shutdown)."""
        out = [b.as_batch(reason) for b in self._buckets.values()]
        self._buckets.clear()
        return out

    def evict_oldest(self) -> Optional[Request]:
        """Remove and return the longest-waiting queued request (the
        ``"shed"`` admission policy's victim) — None if nothing is
        queued.  Only *queued* requests are sheddable; once a batch has
        flushed its requests are in flight and untouchable."""
        victim_key, victim_bucket = None, None
        for key, bucket in self._buckets.items():
            if victim_bucket is None or bucket.oldest < victim_bucket.oldest:
                victim_key, victim_bucket = key, bucket
        if victim_bucket is None:
            return None
        victim = min(victim_bucket.requests, key=lambda r: r.enqueued)
        victim_bucket.requests.remove(victim)
        victim_bucket.rows -= victim.n_rows
        if victim_bucket.requests:
            victim_bucket.refresh()
        else:
            del self._buckets[victim_key]
        return victim

    def __repr__(self):
        return (f"Coalescer(buckets={len(self._buckets)}, "
                f"depth={self.depth}, rows={self.pending_rows}, "
                f"max_batch_rows={self.max_batch_rows}, "
                f"max_wait={self.max_wait}, "
                f"deadline_margin={self.deadline_margin})")
