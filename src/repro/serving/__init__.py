"""Serving layer: the LM token engine and the ray-query server.

* :class:`Engine` — batched prefill + decode for the model stack.
* :class:`QueryServer` (+ :class:`Coalescer`, :class:`AdmissionController`)
  — the async request-level server over ``repro.api.QueryEngine``:
  continuous batching of many small trace / nearest / within /
  count_within requests into full lane-multiple tiles, bit-identical to
  direct engine calls (DESIGN.md §10).
"""
from .admission import (  # noqa: F401
    AdmissionController,
    AdmissionStats,
    QueueFull,
    RequestShed,
)
from .batching import (  # noqa: F401
    FLUSH_DEADLINE,
    FLUSH_DRAIN,
    FLUSH_FULL,
    FLUSH_TIMER,
    Batch,
    Coalescer,
    Request,
)
from .engine import Engine  # noqa: F401
from .query_server import QueryServer, ServerStats  # noqa: F401

__all__ = [
    "AdmissionController",
    "AdmissionStats",
    "Batch",
    "Coalescer",
    "Engine",
    "FLUSH_DEADLINE",
    "FLUSH_DRAIN",
    "FLUSH_FULL",
    "FLUSH_TIMER",
    "QueryServer",
    "QueueFull",
    "Request",
    "RequestShed",
    "ServerStats",
]
