from .engine import Engine  # noqa: F401
