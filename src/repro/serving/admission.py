"""Admission control for the ray-query server (DESIGN.md §10).

A serving system dies by queue, not by kernel: under overload the only
choices are to make someone wait, to tell someone "no" fast, or to drop
the oldest work that nobody will wait for anyway.  This module is that
decision, factored out of the async machinery so it is a plain state
machine — unit-testable without an event loop, clock, or a single real
request (``tests/test_serving.py``).

:class:`AdmissionController` tracks one number — requests admitted but
not yet completed (queued in the coalescer **plus** in flight on the
engine) — against a fixed ``limit``, under one of three policies:

* ``"block"`` — the submitter waits for capacity (classic backpressure;
  the async server parks the caller on a condition variable).
* ``"reject"`` — fast-fail: the submitter gets :class:`QueueFull`
  immediately, keeping the queue short and tail latency bounded.
* ``"shed"`` — admit the new request by dropping the *oldest still
  coalescing* request (its future fails with :class:`RequestShed`);
  when nothing is sheddable (everything admitted is already executing)
  the verdict degrades to ``"reject"`` — in-flight work is never killed.
"""
from __future__ import annotations

from typing import NamedTuple

__all__ = [
    "POLICIES",
    "AdmissionController",
    "AdmissionStats",
    "QueueFull",
    "RequestShed",
]

#: verdicts :meth:`AdmissionController.try_admit` can return
ADMIT, WAIT, REJECT, SHED = "admit", "wait", "reject", "shed"

POLICIES = ("block", "reject", "shed")


class QueueFull(RuntimeError):
    """The admission queue is at its limit and the policy fast-fails."""


class RequestShed(RuntimeError):
    """This request was dropped from the queue to admit newer work
    (``policy="shed"``)."""


class AdmissionStats(NamedTuple):
    depth: int  # admitted - completed (queued + in flight), right now
    limit: int
    admitted: int  # total ever admitted
    rejected: int  # total fast-failed at the door
    shed: int  # total evicted from the queue to admit newer work
    blocked: int  # total admissions that had to wait for capacity first


class AdmissionController:
    """Bounded-queue accounting + overload policy (no event-loop state:
    the async server owns the actual waiting and eviction; this object
    only rules on them and keeps the counters)."""

    def __init__(self, limit: int, policy: str = "block"):
        limit = int(limit)
        if limit < 1:
            raise ValueError(f"admission limit must be >= 1, got {limit}")
        if policy not in POLICIES:
            raise ValueError(
                f"unknown admission policy {policy!r} (want one of "
                f"{POLICIES})")
        self.limit = limit
        self.policy = policy
        self._depth = 0
        self._admitted = 0
        self._rejected = 0
        self._shed = 0
        self._blocked = 0

    @property
    def depth(self) -> int:
        return self._depth

    @property
    def has_capacity(self) -> bool:
        return self._depth < self.limit

    def try_admit(self) -> str:
        """Rule on one incoming request.  ``"admit"`` takes the slot
        immediately; ``"wait"`` / ``"reject"`` / ``"shed"`` tell the
        caller what the policy demands — the caller performs it and (for
        wait/shed) comes back via :meth:`admit_after_wait` /
        :meth:`admit_after_shed`."""
        if self._depth < self.limit:
            self._depth += 1
            self._admitted += 1
            return ADMIT
        if self.policy == "block":
            return WAIT
        if self.policy == "reject":
            self._rejected += 1
            return REJECT
        return SHED

    def admit_after_wait(self) -> None:
        """A blocked submitter found capacity: take the slot (counted as
        a blocked admission)."""
        if self._depth >= self.limit:
            raise RuntimeError("admit_after_wait without capacity")
        self._depth += 1
        self._admitted += 1
        self._blocked += 1

    def admit_after_shed(self) -> None:
        """A queued victim was evicted to admit the newcomer: the
        victim's slot transfers, so depth is unchanged."""
        self._admitted += 1
        self._shed += 1

    def shed_failed(self) -> None:
        """Nothing was sheddable (all admitted work is in flight): the
        newcomer is rejected instead."""
        self._rejected += 1

    def release(self, n: int = 1) -> None:
        """``n`` admitted requests completed (responded, failed, or were
        shed): their slots free up."""
        if n < 0 or n > self._depth:
            raise ValueError(
                f"release({n}) with depth {self._depth}")
        self._depth -= n

    def stats(self) -> AdmissionStats:
        return AdmissionStats(self._depth, self.limit, self._admitted,
                              self._rejected, self._shed, self._blocked)

    def __repr__(self):
        return (f"AdmissionController(limit={self.limit}, "
                f"policy={self.policy!r}, depth={self._depth})")
