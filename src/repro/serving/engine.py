"""Serving engine: batched prefill + greedy/temperature decode loop.

Thin, deterministic, jit-cached: one compiled prefill per prompt length
bucket and one compiled decode step reused for every token.  The decode
step is exactly what the ``decode_32k`` / ``long_500k`` dry-run cells
lower.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..models import ModelConfig, decode_step, init_cache, prefill
from ..parallel.ctx import NO_PARALLEL, ParallelCtx


class Engine:
    def __init__(self, cfg: ModelConfig, params, ctx: ParallelCtx = NO_PARALLEL,
                 max_len: int = 512):
        self.cfg = cfg
        self.params = params
        self.ctx = ctx
        self.max_len = max_len
        # cache donation: the KV cache is updated in place every step
        self._prefill = jax.jit(
            lambda p, b, c: prefill(cfg, ctx, p, b, c), donate_argnums=(2,))
        self._decode = jax.jit(
            lambda p, c, t: decode_step(cfg, ctx, p, c, t),
            donate_argnums=(1,))

    def generate(self, tokens: jax.Array, max_new_tokens: int = 16,
                 temperature: float = 0.0, rng: Optional[jax.Array] = None,
                 extra_inputs: Optional[dict] = None):
        """tokens (B, T) i32 prompt.  Returns (B, max_new_tokens) i32."""
        b, t = tokens.shape
        if t + max_new_tokens > self.max_len:
            # a user-facing precondition, not an internal invariant: asserts
            # vanish under ``python -O``, so raise properly
            raise ValueError(
                f"prompt length {t} + max_new_tokens {max_new_tokens} "
                f"exceeds max_len {self.max_len}; construct the Engine "
                f"with a larger max_len")
        cache = init_cache(self.cfg, b, self.max_len)
        batch = {"tokens": tokens}
        if extra_inputs:
            batch.update(extra_inputs)
        logits, cache = self._prefill(self.params, batch, cache)

        out = []
        tok = self._sample(logits[:, -1], temperature, rng, 0)
        for i in range(max_new_tokens):
            out.append(tok)
            logits, cache = self._decode(self.params, cache, tok)
            tok = self._sample(logits[:, -1], temperature, rng, i + 1)
        return jnp.concatenate(out, axis=-1)

    def _sample(self, logits, temperature, rng, i):
        if temperature <= 0.0 or rng is None:
            return jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        key = jax.random.fold_in(rng, i)
        return jax.random.categorical(
            key, logits / temperature, -1)[:, None].astype(jnp.int32)
