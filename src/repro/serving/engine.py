"""Serving engine: batched prefill + greedy/temperature decode loop.

Thin, deterministic, jit-cached: one compiled prefill per prompt length
bucket and one compiled decode step reused for every token.  The decode
step is exactly what the ``decode_32k`` / ``long_500k`` dry-run cells
lower.

``batch_chunk=`` streams oversized request batches through fixed-size
microbatches — the serving-side twin of the query layer's ``chunk_size``
(``core/dispatch.py``): every chunk re-enters the same compiled
prefill/decode pair (one KV cache of ``batch_chunk`` rows live at a time,
bounding peak cache memory) and the last chunk pads by repeating its
row 0.  Rows are independent, so greedy decode (``temperature == 0``) is
bit-identical to the one-shot batch; sampled decode folds the chunk
offset into ``rng`` so chunks draw *independent* noise (the one-shot
batch's per-row noise positions cannot be reproduced chunk-locally).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..models import ModelConfig, decode_step, init_cache, prefill
from ..parallel.ctx import NO_PARALLEL, ParallelCtx


class Engine:
    def __init__(self, cfg: ModelConfig, params, ctx: ParallelCtx = NO_PARALLEL,
                 max_len: int = 512, batch_chunk: Optional[int] = None):
        self.cfg = cfg
        self.params = params
        self.ctx = ctx
        self.max_len = max_len
        if batch_chunk is not None:
            batch_chunk = int(batch_chunk)
            if batch_chunk < 1:
                raise ValueError(
                    f"batch_chunk must be >= 1, got {batch_chunk!r}")
        self.batch_chunk = batch_chunk
        # cache donation: the KV cache is updated in place every step
        self._prefill = jax.jit(
            lambda p, b, c: prefill(cfg, ctx, p, b, c), donate_argnums=(2,))
        self._decode = jax.jit(
            lambda p, c, t: decode_step(cfg, ctx, p, c, t),
            donate_argnums=(1,))

    def generate(self, tokens: jax.Array, max_new_tokens: int = 16,
                 temperature: float = 0.0, rng: Optional[jax.Array] = None,
                 extra_inputs: Optional[dict] = None):
        """tokens (B, T) i32 prompt.  Returns (B, max_new_tokens) i32."""
        b, t = tokens.shape
        if t + max_new_tokens > self.max_len:
            # a user-facing precondition, not an internal invariant: asserts
            # vanish under ``python -O``, so raise properly
            raise ValueError(
                f"prompt length {t} + max_new_tokens {max_new_tokens} "
                f"exceeds max_len {self.max_len}; construct the Engine "
                f"with a larger max_len")
        if b == 0:
            return jnp.zeros((0, max_new_tokens), jnp.int32)
        if self.batch_chunk is not None and b > self.batch_chunk:
            return self._generate_chunked(tokens, max_new_tokens,
                                          temperature, rng, extra_inputs)
        cache = init_cache(self.cfg, b, self.max_len)
        batch = {"tokens": tokens}
        if extra_inputs:
            batch.update(extra_inputs)
        logits, cache = self._prefill(self.params, batch, cache)

        out = []
        tok = self._sample(logits[:, -1], temperature, rng, 0)
        for i in range(max_new_tokens):
            out.append(tok)
            logits, cache = self._decode(self.params, cache, tok)
            tok = self._sample(logits[:, -1], temperature, rng, i + 1)
        return jnp.concatenate(out, axis=-1)

    def _generate_chunked(self, tokens, max_new_tokens, temperature, rng,
                          extra_inputs):
        """Fixed-size microbatches through one compiled prefill/decode:
        every chunk has exactly ``batch_chunk`` rows (the last padded by
        repeating its row 0) so no chunk recompiles; outputs are sliced
        back and concatenated in request order."""
        b = tokens.shape[0]
        chunk = self.batch_chunk
        outs = []
        for lo in range(0, b, chunk):
            tok = tokens[lo:lo + chunk]
            extra = ({k: v[lo:lo + chunk] for k, v in extra_inputs.items()}
                     if extra_inputs else None)
            n = tok.shape[0]
            if n < chunk:  # pad the tail chunk by repeating its row 0
                pad = lambda x: jnp.concatenate(  # noqa: E731
                    [x, jnp.broadcast_to(x[:1], (chunk - n,) + x.shape[1:])])
                tok = pad(tok)
                extra = ({k: pad(v) for k, v in extra.items()}
                         if extra else None)
            # distinct noise per chunk: identical prompts in different
            # chunks must not sample identical continuations
            chunk_rng = None if rng is None else jax.random.fold_in(rng, lo)
            # tok now has exactly batch_chunk rows, so this recursion takes
            # the direct path (b > batch_chunk is false)
            out = self.generate(tok, max_new_tokens, temperature, chunk_rng,
                                extra)
            outs.append(out[:n])
        return jnp.concatenate(outs, axis=0)

    def _sample(self, logits, temperature, rng, i):
        if temperature <= 0.0 or rng is None:
            return jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        key = jax.random.fold_in(rng, i)
        return jax.random.categorical(
            key, logits / temperature, -1)[:, None].astype(jnp.int32)
