"""Async ray-query server: continuous batching over ``QueryEngine``
(DESIGN.md §10).

The query library wants full lane-multiple tiles; a million users send
four-ray requests.  :class:`QueryServer` is the request-level adapter —
the query-side twin of the LM ``serving/engine.py``:

    queue -> coalesce -> pad -> dispatch -> split

* **queue** — requests enter through :class:`~repro.serving.admission.
  AdmissionController` (bounded; ``policy="block" | "reject" | "shed"``).
* **coalesce** — :class:`~repro.serving.batching.Coalescer` groups them
  per ``(method, static-params)`` bucket and flushes on batch-full /
  max-wait / deadline pressure.
* **pad** — the flushed batch is padded to the engine's own plan
  (``QueryEngine.plan_for`` -> ``core.dispatch.make_plan``), optionally
  quantized up a power-of-two size ladder so live traffic compiles
  O(log max_batch_rows) programs per bucket instead of one per row
  count.
* **dispatch** — one ``QueryEngine`` call per batch, on a worker thread
  so the event loop keeps admitting while the engine computes.
* **split** — the response is handed back per request with the dispatch
  layer's ``slice_rows`` (and, for traces, a per-request ``rounds``
  re-reduction), delivered through asyncio futures.

**The bit-parity contract** (``tests/test_serving.py``): every response
is bit-identical — hits, indices, scores, *and* job counters — to
calling ``QueryEngine`` directly with that request's payload.  This
falls out structurally: rows are independent in every backend, padding
repeats row 0, and a ray is active for exactly ``quadbox_jobs``
consecutive rounds, so the per-request round count is the max over its
own rays wherever those rays execute.
"""
from __future__ import annotations

import asyncio
import math
import time
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.dispatch import slice_rows
from ..core.knn import METRICS, RADIUS_METRICS, check_k, check_radius
from ..core.session import QueryEngine
from ..core.wavefront import RAY_TYPES, SHADOW_T_MIN
from ..obs import register_source
from ..obs.metrics import MetricsRegistry
from ..obs.trace import default_buffer
from .admission import (
    ADMIT,
    REJECT,
    SHED,
    AdmissionController,
    AdmissionStats,
    QueueFull,
    RequestShed,
)
from .batching import (
    FLUSH_DEADLINE,
    FLUSH_DRAIN,
    FLUSH_FULL,
    FLUSH_TIMER,
    Batch,
    Coalescer,
    make_request,
)

__all__ = ["QueryServer", "ServerStats"]


class ServerStats(NamedTuple):
    """Per-method serving statistics (:meth:`QueryServer.stats`)."""

    requests: int  # completed requests
    rows: int  # completed rows
    batches: int  # engine calls issued
    queue_depth: int  # requests coalescing right now
    requests_per_batch: float  # mean occupancy (> 1 = coalescing happens)
    mean_batch_rows: float  # mean user rows per engine call
    mean_fill: float  # user rows / padded rows actually executed
    flush_full: int
    flush_timer: int
    flush_deadline: int
    flush_drain: int
    shed: int  # requests dropped by the shed policy
    p50_ms: float
    p99_ms: float


class _MethodStats:
    """Pre-resolved per-method instruments on the server's private
    registry (``serving.{method}.*`` names).  The registry is
    *always-enabled* — ``stats()`` predates the telemetry plane and must
    keep counting with global telemetry off — and single-writer per
    instrument (the event loop / the one worker), so the counts stay
    exact.  ``repro.obs.snapshot()`` picks the same numbers up through
    the server's registered snapshot source."""

    __slots__ = ("requests", "rows", "batches", "batch_rows", "padded_rows",
                 "flushes", "shed", "latency_ms")

    def __init__(self, reg: MetricsRegistry, method: str):
        pre = f"serving.{method}."
        self.requests = reg.counter(pre + "requests")
        self.rows = reg.counter(pre + "rows")
        self.batches = reg.counter(pre + "batches")
        self.batch_rows = reg.counter(pre + "batch_rows")
        self.padded_rows = reg.counter(pre + "padded_rows")
        self.flushes = {reason: reg.counter(pre + "flush." + reason)
                        for reason in (FLUSH_FULL, FLUSH_TIMER,
                                       FLUSH_DEADLINE, FLUSH_DRAIN)}
        self.shed = reg.counter(pre + "shed")
        self.latency_ms = reg.histogram(pre + "latency_ms")


def _n_rows(payload) -> int:
    return int(jax.tree_util.tree_leaves(payload)[0].shape[0])


def _assemble_payload(requests, target: int):
    """Concatenate request payloads and pad to ``target`` rows (repeating
    row 0, exactly :func:`~repro.core.dispatch.pad_leading`'s rule) — on
    the *host*.  Batch compositions vary freely under open-loop traffic;
    assembling with device ops would jit-compile a throwaway program per
    ``(sizes, target)`` combination, so the adapter works in numpy and
    pays one ``device_put`` for the finished batch.  The engine sees the
    same bits either way; only its (already compiled, quantized-shape)
    call runs on device."""
    if len(requests) == 1 and requests[0].n_rows == target:
        return requests[0].payload
    rows = sum(r.n_rows for r in requests)

    def build(*xs):
        arrs = [np.asarray(x) for x in xs]
        if target > rows:
            arrs.append(np.repeat(arrs[0][:1], target - rows, axis=0))
        return jnp.asarray(np.concatenate(arrs, axis=0))

    return jax.tree_util.tree_map(build, *[r.payload for r in requests])


class QueryServer:
    """Continuous-batching request server over a :class:`QueryEngine`.

    Use as an async context manager (or ``await start()`` /
    ``await stop()``)::

        async with QueryServer(engine) as server:
            hit, near = await asyncio.gather(
                server.trace(rays),                  # (tiny) requests from
                server.nearest(points, k=8))         # many clients coalesce

    Knobs:

    * ``max_batch_rows`` — flush a bucket as soon as it holds this many
      rows (the "full" trigger; also the batch the compiled kernels see
      under load, so size it to a few tiles).
    * ``max_wait`` — seconds the oldest request in a bucket may wait
      before a timer flush (the latency cost of coalescing under
      trickle traffic).
    * ``deadline_margin`` — flush early when a request's deadline is
      this close (requests carry deadlines via ``timeout=``).
    * ``queue_limit`` / ``policy`` — admission control:
      ``"block"`` (backpressure), ``"reject"`` (fast-fail
      :class:`QueueFull`), ``"shed"`` (drop the oldest queued request,
      failing it with :class:`RequestShed`).
    * ``quantize_batches`` — pad flushed batches up a power-of-two row
      ladder (each step to the engine's own ``plan_for`` block) so a
      live server compiles O(log max_batch_rows) programs per bucket
      instead of one per distinct row count.  Padded rows repeat row 0
      and are sliced away, so responses are unchanged.
    """

    def __init__(self, engine: QueryEngine, *, max_batch_rows: int = 1024,
                 max_wait: float = 2e-3, deadline_margin: float = 1e-3,
                 queue_limit: int = 4096, policy: str = "block",
                 quantize_batches: bool = True, clock=time.monotonic):
        self.engine = engine
        self.coalescer = Coalescer(max_batch_rows=max_batch_rows,
                                   max_wait=max_wait,
                                   deadline_margin=deadline_margin)
        self.admission = AdmissionController(queue_limit, policy)
        self.quantize_batches = bool(quantize_batches)
        self._clock = clock
        self._stats: dict = {}
        # exact request accounting on a private always-enabled registry
        # (DESIGN.md §11); the global snapshot sees it as a weakly-held
        # named source, and request-lifecycle spans go to the global
        # trace buffer (which only records when telemetry is enabled)
        self._obs = MetricsRegistry(enabled=True, name="serving")
        self._trace = default_buffer()
        self._source_name = register_source("serving", self._obs_source)
        self._ready: Optional[asyncio.Queue] = None
        self._wake: Optional[asyncio.Event] = None
        self._capacity: Optional[asyncio.Condition] = None
        self._timer_task = None
        self._worker_task = None
        self._started = False
        self._closed = False

    # -- lifecycle ---------------------------------------------------------

    async def start(self) -> "QueryServer":
        if self._started:
            raise RuntimeError("QueryServer already started")
        self._ready = asyncio.Queue()
        self._wake = asyncio.Event()
        self._capacity = asyncio.Condition()
        self._timer_task = asyncio.create_task(self._timer_loop())
        self._worker_task = asyncio.create_task(self._worker_loop())
        self._started = True
        self._closed = False
        return self

    async def stop(self, drain: bool = True) -> None:
        """Shut down: by default drain (flush + execute + deliver every
        queued request) first, then cancel the loops."""
        if not self._started or self._closed:
            return
        if drain:
            await self.drain()
        self._closed = True
        for task in (self._timer_task, self._worker_task):
            task.cancel()
            try:
                await task
            except asyncio.CancelledError:
                pass
        # fail anything still queued (drain=False shutdowns)
        leftovers = self.coalescer.flush_all()
        n = 0
        for batch in leftovers:
            for req in batch.requests:
                n += 1
                if not req.future.done():
                    req.future.set_exception(
                        RuntimeError("QueryServer stopped"))
        if n:
            self.admission.release(n)
        async with self._capacity:
            self._capacity.notify_all()
        self._started = False

    async def drain(self) -> None:
        """Flush every coalescing bucket now and wait until the worker
        has delivered every in-flight response."""
        for batch in self.coalescer.flush_all(FLUSH_DRAIN):
            self._push(batch)
        await self._ready.join()

    async def __aenter__(self) -> "QueryServer":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    # -- typed request surface (one method per servable query) ------------

    async def trace(self, rays, ray_type: str = "closest", *,
                    t_min: float | None = None,
                    max_rounds: int | None = None,
                    backend: str | None = None,
                    timeout: float | None = None):
        """Serve one traced ray bundle; resolves to a
        :class:`~repro.core.session.TraceResult` bit-identical to
        ``engine.trace(rays, ...)`` (including per-ray job counters and
        the batch ``rounds`` reduced over *this request's* rays)."""
        if ray_type not in RAY_TYPES:
            raise ValueError(
                f"ray_type must be one of {RAY_TYPES}, got {ray_type!r}")
        # canonicalize t_min exactly like the engine so equal queries
        # share a bucket however the caller spelled them
        if t_min is None:
            t_min = SHADOW_T_MIN if ray_type == "shadow" else 0.0
        params = (("backend", backend), ("max_rounds", max_rounds),
                  ("ray_type", ray_type), ("t_min", float(t_min)))
        fut = await self.enqueue("trace", rays, params, timeout=timeout)
        return await fut

    async def nearest(self, queries, k: int, metric: str = "euclidean", *,
                      backend: str | None = None,
                      timeout: float | None = None):
        if metric not in METRICS:
            raise ValueError(f"unknown metric: {metric}")
        k = check_k(k)
        params = (("backend", backend), ("k", k), ("metric", metric))
        fut = await self.enqueue("nearest", jnp.asarray(queries), params,
                                 timeout=timeout)
        return await fut

    async def within(self, queries, radius: float, k: int,
                     metric: str = "euclidean", *,
                     backend: str | None = None,
                     timeout: float | None = None):
        if metric not in RADIUS_METRICS:
            raise ValueError(f"unknown radius metric: {metric}")
        radius = check_radius(radius, metric)
        k = check_k(k)
        params = (("backend", backend), ("k", k), ("metric", metric),
                  ("radius", float(radius)))
        fut = await self.enqueue("within", jnp.asarray(queries), params,
                                 timeout=timeout)
        return await fut

    async def count_within(self, queries, radius: float,
                           metric: str = "euclidean", *,
                           backend: str | None = None,
                           timeout: float | None = None):
        if metric not in RADIUS_METRICS:
            raise ValueError(f"unknown radius metric: {metric}")
        radius = check_radius(radius, metric)
        params = (("backend", backend), ("metric", metric),
                  ("radius", float(radius)))
        fut = await self.enqueue("count_within", jnp.asarray(queries),
                                 params, timeout=timeout)
        return await fut

    async def scores(self, queries, metric: str = "euclidean", *,
                     backend: str | None = None,
                     timeout: float | None = None):
        if metric not in METRICS:
            raise ValueError(f"unknown metric: {metric}")
        params = (("backend", backend), ("metric", metric))
        fut = await self.enqueue("scores", jnp.asarray(queries), params,
                                 timeout=timeout)
        return await fut

    # -- request intake ----------------------------------------------------

    async def enqueue(self, method: str, payload, params: tuple, *,
                      timeout: float | None = None) -> asyncio.Future:
        """Admit + coalesce one request and return the asyncio future its
        response will be delivered on — the streaming-friendly surface
        (fire many, ``await`` in any order); the typed methods above are
        ``await (await enqueue(...))`` conveniences."""
        if not self._started or self._closed:
            raise RuntimeError("QueryServer is not running (use "
                               "'async with QueryServer(engine):' or "
                               "await start())")
        if method not in self.engine.SERVABLE_METHODS:
            raise ValueError(
                f"unknown method {method!r} (servable: "
                f"{self.engine.SERVABLE_METHODS})")
        n_rows = _n_rows(payload)
        fut = asyncio.get_running_loop().create_future()
        if n_rows == 0:
            # typed empty result straight from the engine: nothing to
            # coalesce, nothing compiled, bit-identical trivially
            fut.set_result(self._call_engine(method, payload, dict(params)))
            return fut
        t_admit = self._clock()
        await self._admit()
        now = self._clock()
        deadline = None if timeout is None else now + float(timeout)
        req = make_request(method, params, payload, n_rows, now,
                           deadline=deadline, future=fut)
        if self._trace.enabled:
            self._trace.record("admit", t_admit, now - t_admit,
                               tid=req.id, cat="serving",
                               args={"method": method, "rows": n_rows})
        full = self.coalescer.add(req)
        if full is not None:
            self._push(full)
        self._wake.set()  # retime the flush timer around the new bucket
        return fut

    async def _admit(self) -> None:
        while True:
            verdict = self.admission.try_admit()
            if verdict == ADMIT:
                return
            if verdict == REJECT:
                raise QueueFull(
                    f"admission queue at limit {self.admission.limit} "
                    f"(policy='reject')")
            if verdict == SHED:
                victim = self.coalescer.evict_oldest()
                if victim is None:
                    self.admission.shed_failed()
                    raise QueueFull(
                        f"admission queue at limit {self.admission.limit} "
                        "and nothing left to shed (all in flight)")
                self.admission.admit_after_shed()
                self._mstats(victim.method).shed.inc()
                if not victim.future.done():
                    victim.future.set_exception(RequestShed(
                        "request shed to admit newer work "
                        f"(queued {self._clock() - victim.enqueued:.4f}s)"))
                return
            # WAIT: park until a batch completes and frees capacity
            async with self._capacity:
                await self._capacity.wait_for(
                    lambda: self.admission.has_capacity or self._closed)
            if self._closed:
                raise RuntimeError("QueryServer stopped while waiting "
                                   "for queue capacity")
            self.admission.admit_after_wait()
            return

    # -- flush + execute ---------------------------------------------------

    def _push(self, batch: Batch) -> None:
        ms = self._mstats(batch.method)
        ms.flushes[batch.reason].inc()
        self._ready.put_nowait(batch)

    async def _timer_loop(self) -> None:
        while True:
            for batch in self.coalescer.poll(self._clock()):
                self._push(batch)
            due = self.coalescer.next_due()
            delay = (None if due is None
                     else max(due - self._clock(), 0.0))
            try:
                await asyncio.wait_for(self._wake.wait(), delay)
            except asyncio.TimeoutError:
                pass
            self._wake.clear()

    async def _worker_loop(self) -> None:
        loop = asyncio.get_running_loop()
        while True:
            batch = await self._ready.get()
            try:
                results = await loop.run_in_executor(
                    None, self._execute, batch)
                now = self._clock()
                ms = self._mstats(batch.method)
                for req, res in zip(batch.requests, results):
                    ms.requests.inc()
                    ms.rows.inc(req.n_rows)
                    ms.latency_ms.observe((now - req.enqueued) * 1e3)
                    if not req.future.done():
                        req.future.set_result(res)
            except Exception as exc:  # fail the batch, keep serving
                for req in batch.requests:
                    if not req.future.done():
                        req.future.set_exception(exc)
            finally:
                self.admission.release(len(batch.requests))
                async with self._capacity:
                    self._capacity.notify_all()
                self._ready.task_done()

    def _target_rows(self, batch: Batch) -> int:
        """Rows the engine call will execute: the batch's own plan block,
        with the row count first quantized up a power-of-two ladder so
        row-count jitter between batches reuses compiled programs."""
        rows = batch.rows
        if self.quantize_batches and rows > 1:
            rows = 1 << (rows - 1).bit_length()
        p = dict(batch.params)
        plan = self.engine.plan_for(
            batch.method, rows, backend=p.get("backend"),
            ray_type=p.get("ray_type", "closest"),
            metric=p.get("metric", "euclidean"), k=p.get("k"),
            radius=p.get("radius"))
        return plan.block * plan.n_blocks

    def _execute(self, batch: Batch):
        """One engine call for the whole batch (worker thread), split
        back per request.  Bit-parity with per-request execution is the
        contract; see the module docstring for why it holds."""
        target = self._target_rows(batch)
        t_exec = self._clock()
        payload = _assemble_payload(batch.requests, target)
        result = self._call_engine(batch.method, payload,
                                   dict(batch.params))
        jax.block_until_ready(result)
        ms = self._mstats(batch.method)
        ms.batches.inc()
        ms.batch_rows.inc(batch.rows)
        ms.padded_rows.inc(max(target, batch.rows))
        t_split = self._clock()
        parts = self._split(batch.method, result, batch.sizes)
        if self._trace.enabled:
            # one span chain per request (tid = request id): how long it
            # coalesced, the shared engine execution, the host-side split
            t_done = self._clock()
            for req in batch.requests:
                self._trace.record(
                    "coalesce", req.enqueued, t_exec - req.enqueued,
                    tid=req.id, cat="serving",
                    args={"reason": batch.reason,
                          "batch_requests": len(batch.requests)})
                self._trace.record(
                    "execute", t_exec, t_split - t_exec,
                    tid=req.id, cat="serving",
                    args={"method": batch.method, "batch_rows": batch.rows,
                          "target_rows": target})
                self._trace.record("split", t_split, t_done - t_split,
                                   tid=req.id, cat="serving")
        return parts

    def _call_engine(self, method: str, payload, p: dict):
        e = self.engine
        if method == "trace":
            return e.trace(payload, p.get("ray_type", "closest"),
                           backend=p.get("backend"), t_min=p.get("t_min"),
                           max_rounds=p.get("max_rounds"))
        if method == "nearest":
            return e.nearest(payload, p["k"], p.get("metric", "euclidean"),
                             backend=p.get("backend"))
        if method == "within":
            return e.within(payload, p["radius"], p["k"],
                            p.get("metric", "euclidean"),
                            backend=p.get("backend"))
        if method == "count_within":
            return e.count_within(payload, p["radius"],
                                  p.get("metric", "euclidean"),
                                  backend=p.get("backend"))
        if method == "scores":
            return e.scores(payload, p.get("metric", "euclidean"),
                            backend=p.get("backend"))
        raise ValueError(f"unknown method {method!r}")

    def _split(self, method: str, result, sizes):
        # split on the host for the same reason _assemble_payload builds
        # there: device slicing would compile per (shape, range) combo
        rounds_dtype = None
        if method == "trace":
            rounds_dtype = jnp.asarray(result.rounds).dtype
            result = result._replace(rounds=None)
        host = jax.tree_util.tree_map(np.asarray, result)
        parts = [jax.tree_util.tree_map(jnp.asarray, p)
                 for p in slice_rows(host, sizes)]
        if method == "trace":
            # rounds is the one batch-coupled field: re-reduce it per
            # request (a ray is active for exactly quadbox_jobs
            # consecutive rounds, so the request-level round count is the
            # max over its own rays — the same invariant chunked dispatch
            # already relies on)
            parts = [p._replace(rounds=jnp.asarray(
                np.max(np.asarray(p.quadbox_jobs)), dtype=rounds_dtype))
                for p in parts]
        return parts

    # -- observability -----------------------------------------------------

    def _mstats(self, method: str) -> _MethodStats:
        ms = self._stats.get(method)
        if ms is None:
            ms = self._stats[method] = _MethodStats(self._obs, method)
        return ms

    def stats(self) -> dict:
        """Per-method :class:`ServerStats` for every method seen — a view
        over the server's metrics registry (the instrument values *are*
        the counts; this dict shape predates the telemetry plane and is
        pinned by ``tests/test_obs.py``)."""
        out = {}
        for method, ms in self._stats.items():
            requests, batches = ms.requests.value, ms.batches.value
            batch_rows, padded = ms.batch_rows.value, ms.padded_rows.value
            out[method] = ServerStats(
                requests=requests, rows=ms.rows.value, batches=batches,
                queue_depth=self.coalescer.depth_for(method),
                requests_per_batch=(requests / batches
                                    if batches else 0.0),
                mean_batch_rows=(batch_rows / batches
                                 if batches else 0.0),
                mean_fill=(batch_rows / padded if padded else 0.0),
                flush_full=ms.flushes[FLUSH_FULL].value,
                flush_timer=ms.flushes[FLUSH_TIMER].value,
                flush_deadline=ms.flushes[FLUSH_DEADLINE].value,
                flush_drain=ms.flushes[FLUSH_DRAIN].value,
                shed=ms.shed.value,
                p50_ms=ms.latency_ms.percentile(0.50),
                p99_ms=ms.latency_ms.percentile(0.99))
        return out

    def admission_stats(self) -> AdmissionStats:
        return self.admission.stats()

    def _obs_source(self) -> dict:
        """This server's section of ``repro.obs.snapshot()`` (JSON-able:
        the non-finite percentile placeholders become None)."""

        def clean(v):
            return None if (isinstance(v, float)
                            and not math.isfinite(v)) else v

        out = {method: {k: clean(v) for k, v in s._asdict().items()}
               for method, s in self.stats().items()}
        out["admission"] = self.admission.stats()._asdict()
        return out

    def __repr__(self):
        return (f"QueryServer(engine={self.engine!r}, "
                f"coalescer={self.coalescer!r}, "
                f"admission={self.admission!r}, "
                f"started={self._started})")
