"""Mixture-of-Experts block with the datapath's angular mode as the router.

Paper integration (DESIGN.md §4): router scores between token activations
and expert embeddings are exactly the paper's **OpAngular** jobs — dot
products q·eᵢ, optionally normalized into full cosine similarity by the
"external divider" epilogue.  The router literally queries a session-API
``repro.core.session.VectorIndex`` over the expert embeddings, the same
code path validated against the datapath kernels.

Expert parallelism (EP): experts are sharded over the ``model`` mesh axis.
Tokens stay replicated across that axis (they already are — attention
output is TP-all-reduced to the full d_model), each shard computes *its*
experts' contributions via capacity-gather, and one ``psum`` over 'model'
combines.  This avoids the (tokens, E, capacity) one-hot dispatch tensor of
GShard-style einsum MoE — with E=256 (deepseek) that tensor is O(10^13)
elements; the capacity-gather form is O(tokens·E) for routing metadata and
O(E_local·C·d) for compute.  Implemented as a ``shard_map`` so the gather/
scatter stay shard-local instead of tripping GSPMD's gather partitioner.

Capacity: per-shard per-expert C = ceil(tokens_local · top_k / E · cf);
overflow tokens are dropped (GShard semantics; deviation from DeepSeek's
dropless balancing is recorded in DESIGN.md).  A load-balance aux loss
(Switch-style) is returned for training.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core.session import VectorIndex
from ..parallel.compat import shard_map
from .config import ModelConfig, MoEConfig
from .layers import dense_init, split


def moe_init(rng, cfg: ModelConfig):
    m: MoEConfig = cfg.moe
    d, f, e = cfg.d_model, m.d_ff_expert, m.num_experts
    ks = split(rng, 6)
    p = {
        # router: expert embeddings — the OpAngular "candidate points"
        "router": dense_init(ks[0], (e, d), in_axis=1),
        "wi": dense_init(ks[1], (e, d, f), in_axis=1),
        "wg": dense_init(ks[2], (e, d, f), in_axis=1),
        "wo": dense_init(ks[3], (e, f, d), in_axis=1),
    }
    if m.num_shared:
        p["shared_wi"] = dense_init(ks[4], (d, f * m.num_shared))
        p["shared_wg"] = dense_init(ks[5], (d, f * m.num_shared))
        p["shared_wo"] = dense_init(
            jax.random.fold_in(rng, 9), (f * m.num_shared, d))
    return p


def router_scores(m: MoEConfig, x_flat: jax.Array, router_w: jax.Array):
    """Datapath OpAngular jobs: scores[n, e] = x_n · router_e (or cosine).

    The expert table is a session-API :class:`VectorIndex` (the OpAngular
    candidate points) built in-trace — its ``||e||^2`` norms are computed
    once and shared by the cosine epilogue instead of re-reduced per call.
    """
    index = VectorIndex.from_database(router_w.astype(jnp.float32))
    queries = x_flat.astype(jnp.float32)
    if m.router_metric == "cosine":
        return index.cosine_similarity(queries)
    return index.dots(queries)


def router_topk(m: MoEConfig, scores: jax.Array):
    """Top-k gating.  Returns (weights (N,k), experts (N,k), aux_loss)."""
    n, e = scores.shape
    if m.router == "sigmoid":  # deepseek-v3 gating
        probs = jax.nn.sigmoid(scores)
        w, idx = jax.lax.top_k(probs, m.top_k)
        w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-20) * m.route_scale
        full = probs / jnp.maximum(probs.sum(-1, keepdims=True), 1e-20)
    else:
        probs = jax.nn.softmax(scores, axis=-1)
        w, idx = jax.lax.top_k(probs, m.top_k)
        full = probs
    # Switch-style load-balance loss: E * sum_e f_e * P_e
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.float32)  # (N,k,E)
    f_e = onehot.sum((0, 1)) / jnp.maximum(n * m.top_k, 1)
    p_e = full.mean(0)
    aux = e * jnp.sum(f_e * p_e)
    return w.astype(jnp.float32), idx, aux


def _expert_ffn(cfg: ModelConfig, wi, wg, wo, xs):
    """xs (E_local, C, D) through per-expert gated MLP."""
    dt = xs.dtype
    h = jnp.einsum("ecd,edf->ecf", xs, wi.astype(dt))
    g = jnp.einsum("ecd,edf->ecf", xs, wg.astype(dt))
    h = jax.nn.silu(g) * h
    return jnp.einsum("ecf,efd->ecd", h, wo.astype(dt))


def moe_local(cfg: ModelConfig, x_flat, weights, experts, wi, wg, wo,
              expert_offset: int, capacity: int):
    """Capacity-gather MoE over a *local* expert slice [offset, offset+E_loc).

    x_flat (N, D); weights/experts (N, k); expert weights (E_loc, D, F) etc.
    Returns (N, D) partial output — contributions of local experts only.
    """
    n, d = x_flat.shape
    e_loc = wi.shape[0]
    k = experts.shape[1]
    flat_e = experts.reshape(-1)  # (N*k,)
    flat_w = weights.reshape(-1)
    local = flat_e - expert_offset  # index into local slice
    in_range = (local >= 0) & (local < e_loc)
    local = jnp.where(in_range, local, 0)

    # slot position of each (token, choice) within its expert, via cumsum of
    # one-hot over local experts (N*k, E_loc) — the dispatch bookkeeping.
    onehot = jax.nn.one_hot(local, e_loc, dtype=jnp.int32) * in_range[:, None]
    pos = jnp.cumsum(onehot, axis=0) - 1  # position within expert
    slot = jnp.take_along_axis(pos, local[:, None], axis=1)[:, 0]
    keep = in_range & (slot < capacity)

    # scatter token ids into the (E_loc, C) dispatch table; -1 = empty
    table = jnp.full((e_loc, capacity), n, jnp.int32)  # n = padding token id
    gather_w = jnp.zeros((e_loc, capacity), jnp.float32)
    token_of = jnp.arange(n * k, dtype=jnp.int32) // k
    se = jnp.where(keep, local, e_loc)  # overflow -> dropped row
    ss = jnp.where(keep, slot, 0)
    table = table.at[se, ss].set(jnp.where(keep, token_of, n), mode="drop")
    gather_w = gather_w.at[se, ss].set(jnp.where(keep, flat_w, 0.0), mode="drop")

    x_pad = jnp.concatenate([x_flat, jnp.zeros((1, d), x_flat.dtype)], 0)
    xs = x_pad[table]  # (E_loc, C, D)
    ys = _expert_ffn(cfg, wi, wg, wo, xs)
    ys = ys * gather_w[..., None].astype(ys.dtype)

    # combine: scatter-add back over tokens
    out = jnp.zeros((n + 1, d), ys.dtype)
    out = out.at[table.reshape(-1)].add(ys.reshape(-1, d), mode="drop")
    return out[:n]


def moe_apply(cfg: ModelConfig, ctx, p, x):
    """Full MoE block.  x (B, T, D) -> (y (B, T, D), aux_loss)."""
    m: MoEConfig = cfg.moe
    b, t, d = x.shape
    x_flat = x.reshape(b * t, d)

    scores = router_scores(m, x_flat, p["router"])  # OpAngular jobs
    weights, experts, aux = router_topk(m, scores)

    ep = (ctx.mesh is not None and ctx.model_axis is not None
          and m.num_experts % ctx.model_size == 0 and ctx.model_size > 1)
    if ep:
        y = _moe_ep(cfg, ctx, p, x_flat, weights, experts)
    else:
        n_loc = x_flat.shape[0]
        cap = _capacity(m, n_loc)
        y = moe_local(cfg, x_flat, weights, experts,
                      p["wi"], p["wg"], p["wo"], 0, cap)

    if m.num_shared:
        dt = x_flat.dtype
        h = x_flat @ p["shared_wi"].astype(dt)
        g = x_flat @ p["shared_wg"].astype(dt)
        y = y + (jax.nn.silu(g) * h) @ p["shared_wo"].astype(dt)
    return y.reshape(b, t, d).astype(x.dtype), aux


def _capacity(m: MoEConfig, n_tokens: int) -> int:
    per = n_tokens * m.top_k / m.num_experts * m.capacity_factor
    return max(8, -(-int(per) // 8) * 8)


def _moe_ep(cfg: ModelConfig, ctx, p, x_flat, weights, experts):
    """Expert-parallel path: shard_map over (batch-axes × model axis)."""
    m: MoEConfig = cfg.moe
    mesh = ctx.mesh
    batch_axes = tuple(a for a in (
        (ctx.batch_axes if isinstance(ctx.batch_axes, tuple)
         else (ctx.batch_axes,))) if a in mesh.shape)
    model_axis = ctx.model_axis
    e_loc = m.num_experts // mesh.shape[model_axis]
    n_shards = 1
    for a in batch_axes:
        n_shards *= mesh.shape[a]
    # token axis must divide the data shards to shard it; tiny token counts
    # (e.g. single-token decode) fall back to replicated routing — every
    # shard routes all tokens over its local experts, psum still combines.
    if x_flat.shape[0] % n_shards != 0 or n_shards == 1:
        batch_axes = ()
        n_shards = 1
    n_local = x_flat.shape[0] // n_shards
    cap = _capacity(m, n_local)

    def shard_fn(xl, wl, el, wi, wg, wo):
        # local expert slice index along 'model'
        midx = jax.lax.axis_index(model_axis)
        offset = midx * e_loc
        y = moe_local(cfg, xl, wl, el, wi, wg, wo, offset, cap)
        # combine expert contributions living on other model shards;
        # combine_dtype='bfloat16' halves the dominant EP traffic
        cd = jnp.dtype(m.combine_dtype)
        return jax.lax.psum(y.astype(cd), model_axis)

    tok_spec = P(batch_axes if batch_axes else None, None)
    out = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(tok_spec, tok_spec, tok_spec,
                  P(model_axis, None, None), P(model_axis, None, None),
                  P(model_axis, None, None)),
        out_specs=tok_spec,
    )(x_flat, weights, experts, p["wi"], p["wg"], p["wo"])
    return out


def count_moe_params(cfg: ModelConfig) -> tuple[int, int]:
    """(total, active-per-token) params of one MoE block (excl. router)."""
    m = cfg.moe
    per_expert = 3 * cfg.d_model * m.d_ff_expert
    total = m.num_experts * per_expert + m.num_experts * cfg.d_model
    shared = m.num_shared * 3 * cfg.d_model * m.d_ff_expert
    active = m.top_k * per_expert + shared
    return total + shared, active
