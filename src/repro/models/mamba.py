"""Mamba (selective SSM) mixer — the Jamba hybrid's attention-free layer.

TPU mapping: the recurrence h_t = Ā_t h_{t-1} + B̄_t x_t is diagonal over
(d_inner, d_state), so it parallelises as an *associative scan* within
time-chunks (tree combine on the VPU — materialising (B, chunk, d, N)
tiles in VMEM-sized pieces) with a tiny (B, d, N) carry scanned across
chunks.  That keeps HLO small (one while loop over T/chunk) while the
inside of each chunk is straight-line vector code.  ``cfg.scan_seq=False``
python-unrolls the chunk loop for the exact-HLO costing path.

Jamba details reproduced: RMSNorm on the dt/B/C projections, silu-gated
output, conv1d causal depthwise frontend (d_conv=4), softplus dt with
learned bias, S4D-real A init.  TP: d_inner is sharded over the model axis
(all per-channel ops shard cleanly; in/out projections are column/row
parallel).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .config import MambaConfig, ModelConfig
from .layers import dense_init, norm_apply, split


def mamba_dims(cfg: ModelConfig):
    m: MambaConfig = cfg.mamba
    d_inner = m.expand * cfg.d_model
    return d_inner, m.d_state, cfg.dt_rank_


def mamba_init(rng, cfg: ModelConfig):
    m: MambaConfig = cfg.mamba
    d = cfg.d_model
    d_in, n, dt_rank = mamba_dims(cfg)
    ks = split(rng, 8)
    # S4D-real A init: A[d, n] = -(1..n)
    a = jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32), (d_in, n))
    dt_bias = jnp.log(jnp.expm1(jnp.full((d_in,), 0.01, jnp.float32)))  # softplus^-1
    return {
        "in_proj": dense_init(ks[0], (d, 2 * d_in)),
        "conv_w": dense_init(ks[1], (m.d_conv, d_in), in_axis=0),
        "conv_b": jnp.zeros((d_in,), jnp.float32),
        "x_proj": dense_init(ks[2], (d_in, dt_rank + 2 * n)),
        "dt_proj": dense_init(ks[3], (dt_rank, d_in), scale=dt_rank ** -0.5),
        "dt_bias": dt_bias,
        "a_log": jnp.log(a),
        "d_skip": jnp.ones((d_in,), jnp.float32),
        "out_proj": dense_init(ks[4], (d_in, d)),
        "dt_norm": {"scale": jnp.ones((dt_rank,), jnp.float32)},
        "b_norm": {"scale": jnp.ones((n,), jnp.float32)},
        "c_norm": {"scale": jnp.ones((n,), jnp.float32)},
    }


def _conv1d(p, x, conv_state=None):
    """Causal depthwise conv over time.  x (B,T,Din); state (B,K-1,Din)."""
    k = p["conv_w"].shape[0]
    if conv_state is None:
        xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * p["conv_w"][i].astype(x.dtype)
            for i in range(k))
    new_state = xp[:, -(k - 1):, :] if k > 1 else xp[:, :0, :]
    return y + p["conv_b"].astype(x.dtype), new_state


def _ssm_params(cfg: ModelConfig, p, xc):
    """From conv'd activations to (dt, B, C) with Jamba's inner RMSNorms."""
    _, n, dt_rank = mamba_dims(cfg)
    dt = x_dbc = xc @ p["x_proj"].astype(xc.dtype)
    dt = x_dbc[..., :dt_rank]
    b = x_dbc[..., dt_rank:dt_rank + n]
    c = x_dbc[..., dt_rank + n:]
    dt = norm_apply(cfg, p["dt_norm"], dt)
    b = norm_apply(cfg, p["b_norm"], b)
    c = norm_apply(cfg, p["c_norm"], c)
    dt = jax.nn.softplus(dt @ p["dt_proj"].astype(dt.dtype)
                         + p["dt_bias"].astype(dt.dtype))  # (B,T,Din) f32
    return dt.astype(jnp.float32), b.astype(jnp.float32), c.astype(jnp.float32)


def _chunk_scan(a_c, bx_c, h0):
    """Associative scan within one chunk.

    a_c, bx_c: (B, c, Din, N); h0: (B, Din, N).
    Returns (h_all (B, c, Din, N), h_end).  h_t = a_t h_{t-1} + bx_t.
    """
    def combine(l, r):
        (a1, m1), (a2, m2) = l, r
        return a1 * a2, a2 * m1 + m2

    a_cum, m_cum = jax.lax.associative_scan(combine, (a_c, bx_c), axis=1)
    h_all = a_cum * h0[:, None] + m_cum
    return h_all, h_all[:, -1]


def selective_scan(cfg: ModelConfig, dt, b, c, xc, p, h0=None):
    """The selective SSM.  dt (B,T,Din) f32, b/c (B,T,N), xc (B,T,Din).

    Returns (y (B,T,Din), h_end (B,Din,N)).
    """
    m: MambaConfig = cfg.mamba
    bsz, t, d_in = dt.shape
    n = b.shape[-1]
    a = -jnp.exp(p["a_log"].astype(jnp.float32))  # (Din, N)
    if h0 is None:
        h0 = jnp.zeros((bsz, d_in, n), jnp.float32)

    chunk = min(m.chunk, t)
    pad = -(-t // chunk) * chunk - t
    if pad:
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
        xc = jnp.pad(xc, ((0, 0), (0, pad), (0, 0)))
    nt = (t + pad) // chunk

    @jax.checkpoint  # recompute the (B,c,Din,N) chunk tensors in backward
    def chunk_step(h, idx):
        sl = lambda z: jax.lax.dynamic_slice_in_dim(z, idx * chunk, chunk, 1)
        dt_c, b_c, c_c, x_c = sl(dt), sl(b), sl(c), sl(xc).astype(jnp.float32)
        a_c = jnp.exp(dt_c[..., None] * a)  # (B,c,Din,N)  Ā = exp(Δ A)
        bx_c = (dt_c * x_c)[..., None] * b_c[:, :, None, :]  # B̄x = Δ B x
        h_all, h_end = _chunk_scan(a_c, bx_c, h)
        y_c = jnp.einsum("bcdn,bcn->bcd", h_all, c_c)  # y = C·h
        return h_end, y_c

    if cfg.scan_seq:
        h_end, ys = jax.lax.scan(chunk_step, h0, jnp.arange(nt))
        y = jnp.moveaxis(ys, 0, 1).reshape(bsz, nt * chunk, d_in)
    else:  # exact-HLO costing path
        h, parts = h0, []
        for i in range(nt):
            h, y_c = chunk_step(h, i)
            parts.append(y_c)
        h_end = h
        y = jnp.concatenate(parts, axis=1)
    y = y[:, :t] + xc.astype(jnp.float32)[:, :t] * p["d_skip"]
    return y, h_end


def mamba_apply(cfg: ModelConfig, ctx, p, x, ssm_state=None, conv_state=None):
    """Full-sequence Mamba mixer.  x (B,T,D) -> (y, (conv_state, ssm_state))."""
    dt_ = x.dtype
    xz = x @ p["in_proj"].astype(dt_)  # (B,T,2Din)
    d_in = xz.shape[-1] // 2
    x_in, z = xz[..., :d_in], xz[..., d_in:]
    x_in = ctx.act_btf(x_in)
    z = ctx.act_btf(z)
    xc, conv_state = _conv1d(p, x_in, conv_state)
    xc = jax.nn.silu(xc)
    dt, b, c = _ssm_params(cfg, p, xc)
    # the selective scan is a time recurrence: gather seq for its operands
    # (d_inner keeps its tensor-parallel sharding; the scan is pointwise
    # over d_inner, only the time axis must not be partitioned)
    dt = ctx.act_recurrent(dt, ctx.model_axis)
    xc = ctx.act_recurrent(xc, ctx.model_axis)
    b = ctx.act_recurrent(b)
    c = ctx.act_recurrent(c)
    y, h_end = selective_scan(cfg, dt, b, c, xc, p, ssm_state)
    # pin the scan's stacked output too: a seq-sharded consumer would
    # propagate its sharding back into the scan body
    y = ctx.act_recurrent(y, ctx.model_axis)
    y = (y.astype(dt_) * jax.nn.silu(z))
    y = ctx.act_btf(y)
    return y @ p["out_proj"].astype(dt_), (conv_state, h_end)


def mamba_decode(cfg: ModelConfig, ctx, p, x, conv_state, ssm_state):
    """Single-token step.  x (B,1,D); conv_state (B,K-1,Din) bf16;
    ssm_state (B,Din,N) f32."""
    y, (conv_state, h) = mamba_apply(
        cfg, ctx, p, x, ssm_state=ssm_state, conv_state=conv_state)
    return y, conv_state.astype(conv_state.dtype), h


def mamba_state_shapes(cfg: ModelConfig, batch: int):
    m: MambaConfig = cfg.mamba
    d_in, n, _ = mamba_dims(cfg)
    return ((batch, m.d_conv - 1, d_in), (batch, d_in, n))
