"""Shared neural layers: norms, RoPE, embeddings, MLPs, chunked LM loss.

Pure functional style: ``*_init(rng, ...) -> params`` and
``*_apply(params, x, ...) -> y``.  Params are plain dicts of jax arrays so
the whole model is a pytree that pjit/scan/checkpoint handle natively.
Compute runs in ``cfg.compute_dtype`` (bf16 by default) with f32 master
params and f32 reductions (norms, softmax, loss).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .config import ModelConfig

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(rng, shape, in_axis=0, dtype=jnp.float32, scale=1.0):
    """Fan-in scaled truncated-normal init (maps well to all archs here)."""
    fan_in = (shape[in_axis] if isinstance(in_axis, int)
              else math.prod(shape[a] for a in in_axis))
    std = scale / math.sqrt(max(fan_in, 1))
    return (jax.random.truncated_normal(rng, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def split(rng, n):
    return list(jax.random.split(rng, n))


# ---------------------------------------------------------------------------
# normalization
# ---------------------------------------------------------------------------


def norm_init(cfg: ModelConfig, d=None):
    d = d or cfg.d_model
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def norm_apply(cfg: ModelConfig, p, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    else:  # rmsnorm
        ms = (xf * xf).mean(-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"]
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary / sinusoidal positions
# ---------------------------------------------------------------------------


def rope_freqs(cfg: ModelConfig, head_dim: int) -> jax.Array:
    """Inverse frequencies for the rotated sub-dimension."""
    rot = int(head_dim * cfg.rope_fraction) // 2 * 2
    return 1.0 / (cfg.rope_theta ** (jnp.arange(0, rot, 2, jnp.float32) / rot))


def apply_rope(cfg: ModelConfig, x: jax.Array, positions: jax.Array) -> jax.Array:
    """Rotate the first ``rope_fraction`` of head_dim; pass the rest through.

    x: (B, T, H, hd); positions: (B, T) or (T,).
    ``rope_fraction=0.5`` reproduces ChatGLM's 2d/partial rotary,
    ``1.0`` the llama-family full rotary.
    """
    hd = x.shape[-1]
    rot = int(hd * cfg.rope_fraction) // 2 * 2
    if rot == 0:
        return x
    inv = rope_freqs(cfg, hd)
    pos = positions.astype(jnp.float32)
    ang = pos[..., None] * inv  # (..., T, rot/2)
    if ang.ndim == 2:  # (T, r) -> broadcast batch
        ang = ang[None]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    xr = x[..., :rot].astype(jnp.float32)
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    rotated = jnp.stack([r1, r2], axis=-1).reshape(x.shape[:-1] + (rot,))
    return jnp.concatenate([rotated.astype(x.dtype), x[..., rot:]], axis=-1)


def sinusoidal_pos(length: int, d_model: int) -> jax.Array:
    """Whisper-style fixed sinusoidal embeddings (length, d_model)."""
    half = d_model // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32)
                    / max(half - 1, 1))
    args = jnp.arange(length, dtype=jnp.float32)[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(args), jnp.cos(args)], axis=-1)


# ---------------------------------------------------------------------------
# MLP (gated SwiGLU or plain)
# ---------------------------------------------------------------------------


def mlp_init(rng, cfg: ModelConfig, d_ff=None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = split(rng, 3)
    p = {"wi": dense_init(ks[0], (d, f)), "wo": dense_init(ks[1], (f, d))}
    if cfg.mlp_gated:
        p["wg"] = dense_init(ks[2], (d, f))
    return p


def _act(cfg: ModelConfig, x):
    if cfg.act == "silu":
        return jax.nn.silu(x)
    if cfg.act == "gelu":
        return jax.nn.gelu(x)
    if cfg.act == "relu_sq":
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(cfg.act)


def mlp_apply(cfg: ModelConfig, ctx, p, x):
    dt = x.dtype
    h = x @ p["wi"].astype(dt)
    if cfg.mlp_gated:
        h = _act(cfg, x @ p["wg"].astype(dt)) * h
    else:
        h = _act(cfg, h)
    h = ctx.act_btf(h)
    return h @ p["wo"].astype(dt)


# ---------------------------------------------------------------------------
# embeddings + chunked LM loss
# ---------------------------------------------------------------------------


def embed_init(rng, cfg: ModelConfig):
    p = {"tok": dense_init(rng, (cfg.vocab_size, cfg.d_model), in_axis=1)}
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(
            jax.random.fold_in(rng, 1), (cfg.d_model, cfg.vocab_size))
    return p


def embed_apply(cfg: ModelConfig, p, tokens):
    return p["tok"].astype(jnp.dtype(cfg.compute_dtype))[tokens]


def unembed_matrix(cfg: ModelConfig, p):
    return (p["tok"].T if cfg.tie_embeddings else p["unembed"])


def logits_apply(cfg: ModelConfig, ctx, p, h):
    w = unembed_matrix(cfg, p).astype(h.dtype)
    return ctx.act_btv(h @ w)


def lm_loss(cfg: ModelConfig, ctx, embed_params, h, labels, mask=None,
            z_weight=1e-4):
    """Chunked softmax cross-entropy (+ z-loss) over the sequence.

    h: (B, T, D) final hidden; labels: (B, T) int32 (-1 = ignore).
    Chunking over T bounds the (B, c, V) logits tensor — with V up to 129k
    (deepseek) full-sequence logits would dominate activation memory.
    """
    b, t, d = h.shape
    c = min(cfg.logit_chunk, t)
    n_chunks = -(-t // c)
    pad = n_chunks * c - t
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
        if mask is not None:
            mask = jnp.pad(mask, ((0, 0), (0, pad)))
    hc = jnp.moveaxis(h.reshape(b, n_chunks, c, d), 1, 0)
    lc = jnp.moveaxis(labels.reshape(b, n_chunks, c), 1, 0)
    mc = (jnp.moveaxis(mask.reshape(b, n_chunks, c), 1, 0)
          if mask is not None else jnp.ones_like(lc, jnp.float32))
    w = unembed_matrix(cfg, embed_params)

    @jax.checkpoint  # recompute (B,c,V) logits in backward: O(B·c·D) saved
    def chunk_body(hx, lx, mx):
        logits = ctx.act_btv(hx @ w.astype(hx.dtype)).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(
            logits, jnp.maximum(lx, 0)[..., None], axis=-1)[..., 0]
        valid = (lx >= 0).astype(jnp.float32) * mx
        nll = (lse - ll) * valid
        zl = z_weight * (lse ** 2) * valid
        return nll.sum(), valid.sum(), zl.sum()

    def chunk_loss(carry, xs):
        hx, lx, mx = xs
        nll, valid, zl = chunk_body(hx, lx, mx)
        tot, cnt, z = carry
        return (tot + nll, cnt + valid, z + zl), None

    init = (jnp.float32(0), jnp.float32(0), jnp.float32(0))
    if cfg.scan_seq:
        (tot, cnt, z), _ = jax.lax.scan(chunk_loss, init, (hc, lc, mc))
    else:  # exact-HLO costing path: python-unrolled chunks
        carry = init
        for i in range(n_chunks):
            carry, _ = chunk_loss(carry, (hc[i], lc[i], mc[i]))
        tot, cnt, z = carry
    cnt = jnp.maximum(cnt, 1.0)
    return tot / cnt, {"nll": tot / cnt, "z_loss": z / cnt, "tokens": cnt}
