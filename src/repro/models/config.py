"""Model configuration: one dataclass family covering all 10 assigned archs.

A config is pure data — every architecture in ``repro.configs`` is an
instance of :class:`ModelConfig`.  The layer stack is described by a
repeating ``layer_pattern`` (mixer kind per position) and ``moe_pattern``
(whether the FFN at that position is MoE), from which
:func:`derive_segments` produces homogeneous *segments* that the forward
pass scans over (stacked params, small HLO even for 88-layer models).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared: int = 0  # always-on shared expert(s), deepseek-style
    router: str = "softmax"  # 'softmax' | 'sigmoid' (deepseek v3 gating)
    capacity_factor: float = 1.25
    router_metric: str = "angular"  # datapath mode for scores: 'angular'|'cosine'
    route_scale: float = 1.0  # deepseek routed_scaling_factor
    combine_dtype: str = "float32"  # EP psum payload; 'bfloat16' halves the
    # per-MoE-layer combine traffic (outputs are bf16 anyway) -- a Perf lever


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2/V3)."""

    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128
    absorb: bool = False  # decode-time weight absorption (perf variant)


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 -> ceil(d_model / 16)
    chunk: int = 128  # time-chunk for the selective-scan


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    head_size: int = 64
    decay_lora: int = 64
    gate_lora: int = 0  # 0 -> d_model // 8 (unused placeholder for variants)
    chunk: int = 64  # time-chunk (chunked wkv: MXU form; 0 = pure recurrence)


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Encoder tower for enc-dec archs; the modality frontend is a STUB —
    ``input_specs`` feeds precomputed frame/patch embeddings."""

    num_layers: int
    seq_len: int  # e.g. whisper: 1500 mel-frame embeddings


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    attention: str = "gqa"  # 'gqa' | 'mla'
    layer_pattern: Tuple[str, ...] = ("attn",)  # mixer per position, cycled
    moe_pattern: Tuple[bool, ...] = (False,)  # FFN-is-MoE per position, cycled
    moe_first_dense: int = 0  # leading layers forced dense (deepseek: 3)
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0  # fraction of head_dim rotated (chatglm .5)
    pos_emb: str = "rope"  # 'rope' | 'sinusoidal' | 'none'
    norm: str = "rmsnorm"  # 'rmsnorm' | 'layernorm'
    causal: bool = True  # False: bidirectional self-attention (encoders)
    act: str = "silu"
    mlp_gated: bool = True  # SwiGLU-style gated MLP
    qkv_bias: bool = False
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    mamba: Optional[MambaConfig] = None
    rwkv: Optional[RWKVConfig] = None
    encoder: Optional[EncoderConfig] = None
    vision_tokens: int = 0  # VLM: stub patch embeddings prepended
    mtp_depth: int = 0  # deepseek multi-token-prediction heads
    mtp_weight: float = 0.3
    compute_dtype: str = "bfloat16"
    param_dtype: str = "float32"
    logit_chunk: int = 1024  # seq chunk for the (chunked) LM loss
    attn_chunk: int = 512  # q/kv chunk for flash-style chunked attention
    remat: str = "block"  # 'none' | 'block' (checkpoint each scanned block)
    # Lowering-shape switches.  XLA's cost_analysis counts a while-loop body
    # ONCE (measured; see benchmarks/roofline.py), so the roofline harness
    # lowers *unrolled* per-layer bodies for exact FLOP/byte/collective
    # accounting while production lowering keeps scans (small HLO):
    scan_layers: bool = True  # lax.scan over stacked layer params
    scan_seq: bool = True  # lax.scan over time-chunks (ssm/rwkv/attn/loss)
    attn_unroll: bool = False  # python-unroll the kv-chunk loop (costing)

    # ---- derived ---------------------------------------------------------

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def dt_rank_(self) -> int:
        assert self.mamba is not None
        return self.mamba.dt_rank or -(-self.d_model // 16)

    def layer_specs(self) -> list["LayerSpec"]:
        """Fully unrolled per-layer spec list (len == num_layers)."""
        out = []
        for i in range(self.num_layers):
            mixer = self.layer_pattern[i % len(self.layer_pattern)]
            is_moe = (self.moe is not None
                      and i >= self.moe_first_dense
                      and self.moe_pattern[i % len(self.moe_pattern)])
            out.append(LayerSpec(mixer=mixer, moe=is_moe))
        return out

    def param_count(self) -> int:
        """Approximate parameter count (embedding + blocks), for 6ND math."""
        from . import model  # lazy; avoids cycle
        return model.count_params(self)


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: str  # 'attn' | 'mamba' | 'rwkv'
    moe: bool


def derive_segments(cfg: ModelConfig) -> list[tuple[Tuple[LayerSpec, ...], int]]:
    """Group layers into (pattern, repeats) segments with identical structure.

    The forward pass scans each segment with stacked params: one segment for
    uniform stacks, [dense-prefix, moe-rest] for deepseek, one 8-layer
    pattern x 9 for jamba.
    """
    specs = cfg.layer_specs()
    segments: list[tuple[Tuple[LayerSpec, ...], int]] = []
    i = 0
    while i < len(specs):
        # Pick the period p whose repeated prefix covers the most layers;
        # only genuinely-repeating periods (r >= 2, or p == 1) count, so a
        # trailing one-shot "period = everything" never wins and params stay
        # stackable for lax.scan.
        best = (1, 1)  # (period, repeats)
        rest = specs[i:]
        for p in range(1, len(rest) // 2 + 2):
            pat = rest[:p]
            r = 1
            while (r + 1) * p <= len(rest) and rest[r * p:(r + 1) * p] == pat:
                r += 1
            if r >= 2 or p == 1:
                if r * p > best[0] * best[1] or (
                        r * p == best[0] * best[1] and p < best[0]):
                    best = (p, r)
        p, r = best
        segments.append((tuple(rest[:p]), r))
        i += p * r
    assert sum(len(pat) * r for pat, r in segments) == cfg.num_layers
    return segments


def lcm(a: int, b: int) -> int:
    return a * b // math.gcd(a, b)
