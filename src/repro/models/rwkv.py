"""RWKV6 "Finch" mixer: linear attention with data-dependent decay.

The per-head recurrence (head size K = V = 64)

    y_t = r_t · (S_{t-1} + (u ⊙ k_t) ⊗ v_t)
    S_t = diag(w_t) S_{t-1} + k_t ⊗ v_t        w_t = exp(-exp(lora_w(x_t)))

is linear in S, so within a time-chunk it is an associative scan over
(decay, outer-product) pairs; the (B, H, K, V) state is the only carry
across chunks — O(1) in sequence length, which is what makes the
``long_500k`` cell runnable for this arch.  The data-dependent decay (the
Finch contribution vs RWKV5) is the low-rank ``w_lora`` path.

Simplification vs the reference implementation (recorded in DESIGN.md):
static per-channel token-shift mixing coefficients (RWKV5-style) instead of
the rank-32 data-dependent ddlerp on all five branches; the decay itself
*is* data-dependent as in Finch.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig, RWKVConfig
from .layers import dense_init, split


def rwkv_heads(cfg: ModelConfig):
    r: RWKVConfig = cfg.rwkv
    assert cfg.d_model % r.head_size == 0
    return cfg.d_model // r.head_size, r.head_size


def rwkv_time_init(rng, cfg: ModelConfig):
    r: RWKVConfig = cfg.rwkv
    d = cfg.d_model
    ks = split(rng, 8)
    ramp = jnp.arange(d, dtype=jnp.float32) / d
    return {
        "mu_r": 0.5 * (1 + ramp), "mu_k": 0.5 * (1 + ramp),
        "mu_v": 0.5 * (1 + ramp), "mu_w": 0.5 * (1 + ramp),
        "mu_g": 0.5 * (1 + ramp),
        "wr": dense_init(ks[0], (d, d)),
        "wk": dense_init(ks[1], (d, d)),
        "wv": dense_init(ks[2], (d, d)),
        "wg": dense_init(ks[3], (d, d)),
        "wo": dense_init(ks[4], (d, d)),
        # data-dependent decay lora (Finch): w = exp(-exp(base + lora(x)))
        "w_base": jnp.zeros((d,), jnp.float32) - 6.0 + 5.0 * ramp,
        "w_lora_a": dense_init(ks[5], (d, r.decay_lora)),
        "w_lora_b": dense_init(ks[6], (r.decay_lora, d), scale=0.1),
        "u": jnp.zeros((d,), jnp.float32) + 0.5 * ramp,
        # per-head group-norm after wkv
        "gn_scale": jnp.ones((d,), jnp.float32),
        "gn_bias": jnp.zeros((d,), jnp.float32),
    }


def rwkv_channel_init(rng, cfg: ModelConfig):
    d, f = cfg.d_model, cfg.d_ff
    ks = split(rng, 3)
    ramp = jnp.arange(d, dtype=jnp.float32) / d
    return {
        "mu_k": 0.5 * (1 + ramp), "mu_r": 0.5 * (1 + ramp),
        "wk": dense_init(ks[0], (d, f)),
        "wv": dense_init(ks[1], (f, d)),
        "wr": dense_init(ks[2], (d, d)),
    }


def _shift(x, x_prev=None):
    """Token shift: value of the previous position (0 / carried state)."""
    if x_prev is None:
        return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    return jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)


def _mix(x, xs, mu):
    return x + (xs - x) * mu.astype(x.dtype)


def _group_norm(p, x, heads, eps=1e-5):
    """Per-head layer norm over the head channel (RWKV's GroupNorm(H))."""
    b, t, d = x.shape
    xh = x.reshape(b, t, heads, d // heads).astype(jnp.float32)
    mu = xh.mean(-1, keepdims=True)
    var = ((xh - mu) ** 2).mean(-1, keepdims=True)
    y = ((xh - mu) * jax.lax.rsqrt(var + eps)).reshape(b, t, d)
    return (y * p["gn_scale"] + p["gn_bias"]).astype(x.dtype)


def _wkv_chunk(r_c, k_c, v_c, w_c, u, s0):
    """One chunk of the wkv recurrence via associative scan.

    r/k/v (B,c,H,K), w (B,c,H,K) in (0,1); s0 (B,H,K,V) f32.
    Returns (y (B,c,H,V), s_end).
    """
    kv = k_c[..., :, None] * v_c[..., None, :]  # (B,c,H,K,V)

    def combine(l, rgt):
        (a1, m1), (a2, m2) = l, rgt
        return a1 * a2, a2 * m1 + m2

    w_b = w_c[..., :, None]  # (B,c,H,K,1) broadcasting over V
    a_cum, m_cum = jax.lax.associative_scan(
        combine, (jnp.broadcast_to(w_b, kv.shape), kv), axis=1)
    s_all = a_cum * s0[:, None] + m_cum  # S_t (inclusive of step t)
    s_end = s_all[:, -1]
    # S_{t-1}: shift right, S_{-1} = s0
    s_prev = jnp.concatenate([s0[:, None], s_all[:, :-1]], axis=1)
    y = jnp.einsum("bchk,bchkv->bchv", r_c, s_prev)
    bonus = jnp.einsum("bchk,bchk->bch", r_c, u * k_c)[..., None] * v_c
    return y + bonus, s_end


def rwkv_time_apply(cfg: ModelConfig, ctx, p, x, state=None, x_prev=None):
    """RWKV6 time-mix.  x (B,T,D) -> (y, (x_last, S_end))."""
    r_cfg: RWKVConfig = cfg.rwkv
    h, hs = rwkv_heads(cfg)
    b, t, d = x.shape
    dt_ = x.dtype
    xs = _shift(x, x_prev)
    r = _mix(x, xs, p["mu_r"]) @ p["wr"].astype(dt_)
    k = _mix(x, xs, p["mu_k"]) @ p["wk"].astype(dt_)
    v = _mix(x, xs, p["mu_v"]) @ p["wv"].astype(dt_)
    g = _mix(x, xs, p["mu_g"]) @ p["wg"].astype(dt_)
    xw = _mix(x, xs, p["mu_w"])
    w_log = (p["w_base"].astype(jnp.float32)
             + (xw @ p["w_lora_a"].astype(dt_)).astype(jnp.float32)
             @ p["w_lora_b"])  # (B,T,D) data-dependent decay (Finch)
    w = jnp.exp(-jnp.exp(w_log))  # in (0,1)

    def to_heads(z):
        return z.reshape(b, t, h, hs)

    r_h = ctx.shard(to_heads(r).astype(jnp.float32), ctx.batch_axes, None,
                    ctx.model_axis, None)
    k_h = ctx.shard(to_heads(k).astype(jnp.float32), ctx.batch_axes, None,
                    ctx.model_axis, None)
    v_h = ctx.shard(to_heads(v).astype(jnp.float32), ctx.batch_axes, None,
                    ctx.model_axis, None)
    # like r/k/v above: the wkv recurrence needs seq gathered (act_recurrent
    # rationale) -- without this constraint w_h stays seq-sharded and drags
    # the scan into the partitioned-recurrence lowering
    w_h = ctx.shard(to_heads(w), ctx.batch_axes, None, ctx.model_axis, None)
    u_h = p["u"].reshape(h, hs)

    s0 = (jnp.zeros((b, h, hs, hs), jnp.float32) if state is None else state)
    chunk = min(r_cfg.chunk or t, t)
    pad = -(-t // chunk) * chunk - t
    if pad:
        r_h, k_h, v_h, w_h = (jnp.pad(z, ((0, 0), (0, pad), (0, 0), (0, 0)))
                              for z in (r_h, k_h, v_h, w_h))
        w_h = w_h + (jnp.arange(t + pad) >= t).astype(w_h.dtype)[None, :, None, None]
    nt = (t + pad) // chunk

    @jax.checkpoint  # recompute the (B,c,H,K,V) chunk tensors in backward
    def chunk_step(s, idx):
        sl = lambda z: jax.lax.dynamic_slice_in_dim(z, idx * chunk, chunk, 1)
        y_c, s_end = _wkv_chunk(sl(r_h), sl(k_h), sl(v_h), sl(w_h), u_h, s)
        return s_end, y_c

    if cfg.scan_seq:
        s_end, ys = jax.lax.scan(chunk_step, s0, jnp.arange(nt))
        y = jnp.moveaxis(ys, 0, 1).reshape(b, nt * chunk, h, hs)
    else:  # exact-HLO costing path
        s, parts = s0, []
        for i in range(nt):
            s, y_c = chunk_step(s, i)
            parts.append(y_c)
        s_end = s
        y = jnp.concatenate(parts, axis=1)
    y = y[:, :t].reshape(b, t, d).astype(dt_)
    y = ctx.act_recurrent(y)  # pin the scan output (act_recurrent rationale)
    y = _group_norm(p, y, h)
    y = y * jax.nn.silu(g)
    return y @ p["wo"].astype(dt_), (x[:, -1], s_end)


def rwkv_channel_apply(cfg: ModelConfig, ctx, p, x, x_prev=None):
    """RWKV channel-mix (the arch's FFN).  Returns (y, x_last)."""
    dt_ = x.dtype
    xs = _shift(x, x_prev)
    k = _mix(x, xs, p["mu_k"]) @ p["wk"].astype(dt_)
    k = ctx.act_btf(k)
    k = jnp.square(jax.nn.relu(k))
    kv = k @ p["wv"].astype(dt_)
    rgate = jax.nn.sigmoid(_mix(x, xs, p["mu_r"]) @ p["wr"].astype(dt_))
    return rgate * kv, x[:, -1]


def rwkv_state_shapes(cfg: ModelConfig, batch: int):
    h, hs = rwkv_heads(cfg)
    return ((batch, cfg.d_model),  # time-mix shift state
            (batch, h, hs, hs),  # wkv state
            (batch, cfg.d_model))  # channel-mix shift state
