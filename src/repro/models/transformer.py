"""Block assembly and the segment-scanned layer stack.

A *block* is one layer: pre-norm mixer (attn / mla / mamba / rwkv) plus
pre-norm FFN (mlp / moe / rwkv channel-mix).  Layers are grouped into
homogeneous *segments* (``config.derive_segments``) whose params are stacked
on a leading axis and traversed with ``lax.scan`` + optional per-block
remat — an 88-layer model lowers to a few hundred HLO ops.  Setting
``cfg.scan_layers=False`` python-unrolls the stack (exact-HLO costing).

Three modes share the block code:
  'train'   — full sequence, no cache, returns MoE aux losses.
  'prefill' — full sequence, fills the per-layer cache at position 0.
  'decode'  — one token against the cache at position ``length``.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from . import attention as attn
from . import mamba as mam
from . import moe as moe_mod
from . import rwkv as rk
from .config import LayerSpec, ModelConfig, derive_segments
from .layers import dense_init, mlp_init, mlp_apply, norm_apply, norm_init, split


# ---------------------------------------------------------------------------
# per-block init
# ---------------------------------------------------------------------------


def block_init(rng, cfg: ModelConfig, spec: LayerSpec, cross: bool = False):
    ks = split(rng, 6)
    p: dict[str, Any] = {"norm1": norm_init(cfg)}
    if spec.mixer == "attn":
        p["mixer"] = (attn.mla_init(ks[0], cfg) if cfg.attention == "mla"
                      else attn.gqa_init(ks[0], cfg))
    elif spec.mixer == "mamba":
        p["mixer"] = mam.mamba_init(ks[0], cfg)
    elif spec.mixer == "rwkv":
        p["mixer"] = rk.rwkv_time_init(ks[0], cfg)
    else:
        raise ValueError(spec.mixer)
    if cross:
        p["norm_x"] = norm_init(cfg)
        p["xattn"] = attn.gqa_init(ks[1], cfg)
    p["norm2"] = norm_init(cfg)
    if spec.mixer == "rwkv":
        p["ffn"] = rk.rwkv_channel_init(ks[2], cfg)
    elif spec.moe:
        p["ffn"] = moe_mod.moe_init(ks[2], cfg)
    else:
        p["ffn"] = mlp_init(ks[2], cfg)
    return p


# ---------------------------------------------------------------------------
# per-block cache
# ---------------------------------------------------------------------------


def block_cache_shapes(cfg: ModelConfig, spec: LayerSpec, batch: int,
                       max_len: int, cross_len: int = 0):
    """Dict of (shape, dtype) for this block's decode cache."""
    cd = jnp.dtype(cfg.compute_dtype)
    out: dict[str, tuple] = {}
    if spec.mixer == "attn":
        if cfg.attention == "mla":
            m = cfg.mla
            out["ckv"] = ((batch, max_len, m.kv_lora_rank), cd)
            out["krope"] = ((batch, max_len, m.qk_rope_head_dim), cd)
        else:
            hkv, hd = cfg.num_kv_heads, cfg.head_dim_
            out["k"] = ((batch, max_len, hkv, hd), cd)
            out["v"] = ((batch, max_len, hkv, hd), cd)
        if cross_len:
            hkv, hd = cfg.num_kv_heads, cfg.head_dim_
            out["ck"] = ((batch, cross_len, hkv, hd), cd)
            out["cv"] = ((batch, cross_len, hkv, hd), cd)
    elif spec.mixer == "mamba":
        conv_s, ssm_s = mam.mamba_state_shapes(cfg, batch)
        out["conv"] = (conv_s, cd)
        out["ssm"] = (ssm_s, jnp.float32)
    elif spec.mixer == "rwkv":
        xt, s, xc = rk.rwkv_state_shapes(cfg, batch)
        out["xt"] = (xt, cd)
        out["s"] = (s, jnp.float32)
        out["xc"] = (xc, cd)
    return out


# ---------------------------------------------------------------------------
# per-block apply (train / prefill / decode)
# ---------------------------------------------------------------------------


def block_apply(cfg: ModelConfig, ctx, spec: LayerSpec, p, h, positions,
                mode: str, cache, length, enc_h):
    """Returns (h, new_cache, aux)."""
    new_cache = dict(cache) if cache is not None else None
    aux = jnp.float32(0)
    x = norm_apply(cfg, p["norm1"], h)

    if spec.mixer == "attn":
        if mode == "decode":
            if cfg.attention == "mla":
                y, ckv, krope = attn.mla_decode(
                    cfg, ctx, p["mixer"], x, cache["ckv"], cache["krope"], length)
                new_cache.update(ckv=ckv, krope=krope)
            else:
                y, ck, cv = attn.gqa_decode(
                    cfg, ctx, p["mixer"], x, cache["k"], cache["v"], length)
                new_cache.update(k=ck, v=cv)
        else:
            if cfg.attention == "mla":
                y, (c_kv, k_rope) = attn.mla_apply(cfg, ctx, p["mixer"], x,
                                                   positions,
                                                   causal=cfg.causal)
                if mode == "prefill":
                    new_cache["ckv"] = _fill(cache["ckv"], c_kv)
                    new_cache["krope"] = _fill(cache["krope"], k_rope)
            else:
                y, (k, v) = attn.gqa_apply(cfg, ctx, p["mixer"], x, positions,
                                           causal=cfg.causal)
                if mode == "prefill":
                    new_cache["k"] = ctx.kv_cache(_fill(cache["k"], k))
                    new_cache["v"] = ctx.kv_cache(_fill(cache["v"], v))
    elif spec.mixer == "mamba":
        if mode == "decode":
            y, conv_s, ssm_s = mam.mamba_decode(
                cfg, ctx, p["mixer"], x, cache["conv"], cache["ssm"])
            new_cache.update(conv=conv_s.astype(cache["conv"].dtype), ssm=ssm_s)
        else:
            y, (conv_s, ssm_s) = mam.mamba_apply(cfg, ctx, p["mixer"], x)
            if mode == "prefill":
                new_cache.update(conv=conv_s.astype(cache["conv"].dtype),
                                 ssm=ssm_s)
    elif spec.mixer == "rwkv":
        if mode == "decode":
            y, (xt, s) = rk.rwkv_time_apply(cfg, ctx, p["mixer"], x,
                                            state=cache["s"],
                                            x_prev=cache["xt"].astype(x.dtype))
            new_cache.update(xt=xt.astype(cache["xt"].dtype), s=s)
        else:
            y, (xt, s) = rk.rwkv_time_apply(cfg, ctx, p["mixer"], x)
            if mode == "prefill":
                new_cache.update(xt=xt.astype(cache["xt"].dtype), s=s)
    h = h + y

    # cross-attention (enc-dec decoder blocks)
    if "xattn" in p:
        xq = norm_apply(cfg, p["norm_x"], h)
        if mode == "decode":
            kv = (cache["ck"], cache["cv"])
        else:
            kv = attn.cross_attn_kv(cfg, p["xattn"], enc_h)
            if mode == "prefill":
                new_cache["ck"] = kv[0].astype(cache["ck"].dtype)
                new_cache["cv"] = kv[1].astype(cache["cv"].dtype)
        h = h + attn.cross_attn_apply(cfg, ctx, p["xattn"], xq, kv)

    # FFN
    x2 = norm_apply(cfg, p["norm2"], h)
    if spec.mixer == "rwkv":
        if mode == "decode":
            y2, xc = rk.rwkv_channel_apply(cfg, ctx, p["ffn"], x2,
                                           x_prev=cache["xc"].astype(x2.dtype))
            new_cache["xc"] = xc.astype(cache["xc"].dtype)
        else:
            y2, xc = rk.rwkv_channel_apply(cfg, ctx, p["ffn"], x2)
            if mode == "prefill":
                new_cache["xc"] = xc.astype(cache["xc"].dtype)
    elif spec.moe:
        y2, aux = moe_mod.moe_apply(cfg, ctx, p["ffn"], x2)
    else:
        y2 = mlp_apply(cfg, ctx, p["ffn"], x2)
    h = ctx.act_btd(h + y2)
    return h, new_cache, aux


def _fill(cache_arr, new_vals):
    """Write full-sequence values at position 0 of the cache."""
    t = new_vals.shape[1]
    s = cache_arr.shape[1]
    vals = new_vals.astype(cache_arr.dtype)
    if t == s:
        return vals
    pad = [(0, 0), (0, s - t)] + [(0, 0)] * (vals.ndim - 2)
    return jnp.pad(vals, pad)


# ---------------------------------------------------------------------------
# segment traversal (scan over stacked layers)
# ---------------------------------------------------------------------------


def segment_init(rng, cfg: ModelConfig, pattern, repeats, cross=False):
    """Stacked params: each leaf gets a leading ``repeats`` axis."""
    def one(r):
        ks = split(r, len(pattern))
        return [block_init(k, cfg, spec, cross=cross)
                for k, spec in zip(ks, pattern)]

    return jax.vmap(one)(jnp.stack(split(rng, repeats)))


def run_segment(cfg: ModelConfig, ctx, pattern, repeats, seg_params, h,
                positions, mode, seg_cache, length, enc_h):
    """Apply ``pattern`` x ``repeats`` layers.  seg_cache leaves are stacked
    (repeats, ...).  Returns (h, new_seg_cache, aux_sum)."""

    def body(carry, xs):
        h = carry
        p_list, c_list = xs
        aux = jnp.float32(0)
        new_c = []
        for spec, p_blk, c_blk in zip(pattern, p_list, c_list):
            h, c_new, a = block_apply(cfg, ctx, spec, p_blk, h, positions,
                                      mode, c_blk, length, enc_h)
            aux = aux + a
            new_c.append(c_new if c_new is not None else {})
        return h, (new_c, aux)

    none_cache = seg_cache is None

    if cfg.scan_layers and repeats > 1:
        fn = body
        if mode == "train" and cfg.remat == "block":
            fn = jax.checkpoint(body)
        if none_cache:
            def fn2(carry, p_list):
                return fn(carry, (p_list, [None] * len(pattern)))
            h, (_, auxs) = jax.lax.scan(fn2, h, seg_params)
            return h, None, auxs.sum()

        # The cache rides in the scan CARRY (not xs/ys): while-loop carry
        # buffers alias across iterations and with the donated input, so the
        # multi-GB KV cache stays a single in-place buffer.  xs/ys would
        # double-buffer it (input stack + output stack).
        def fn_carry(carry, xs):
            h, cache_full = carry
            p_list, idx = xs
            c_list = jax.tree.map(
                lambda x: jax.lax.dynamic_index_in_dim(x, idx, 0,
                                                       keepdims=False),
                cache_full)
            h, (new_c, aux) = fn(h, (p_list, c_list))
            cache_full = jax.tree.map(
                lambda full, new: jax.lax.dynamic_update_index_in_dim(
                    full, new.astype(full.dtype), idx, 0),
                cache_full, new_c)
            return (h, cache_full), aux

        (h, new_cache), auxs = jax.lax.scan(
            fn_carry, (h, seg_cache),
            (seg_params, jnp.arange(repeats, dtype=jnp.int32)))
        return h, new_cache, auxs.sum()

    # unrolled path (also used when repeats == 1)
    fn = body
    if mode == "train" and cfg.remat == "block" and cfg.scan_layers:
        fn = jax.checkpoint(body)
    aux_tot = jnp.float32(0)
    per_layer = []
    for r in range(repeats):
        p_list = jax.tree.map(lambda x: x[r], seg_params)
        c_list = (None if none_cache
                  else jax.tree.map(lambda x: x[r], seg_cache))
        h, (new_c, aux) = fn(h, (p_list,
                                 c_list if c_list is not None
                                 else [None] * len(pattern)))
        aux_tot = aux_tot + aux
        if not none_cache:
            per_layer.append(new_c)
    new_stacked = None
    if not none_cache:  # single stack at the end (one copy, not O(R^2))
        new_stacked = jax.tree.map(lambda *xs: jnp.stack(xs, 0), *per_layer)
    return h, new_stacked, aux_tot


def stack_init(rng, cfg: ModelConfig, cross=False):
    """Init all segments.  Returns list of stacked segment params."""
    segs = derive_segments(cfg)
    ks = split(rng, len(segs))
    return [segment_init(k, cfg, pattern, repeats, cross=cross)
            for k, (pattern, repeats) in zip(ks, segs)]


def stack_apply(cfg: ModelConfig, ctx, segments_params, h, positions, mode,
                caches=None, length=None, enc_h=None):
    """Run the whole layer stack.  Returns (h, new_caches, aux_sum)."""
    segs = derive_segments(cfg)
    aux_tot = jnp.float32(0)
    new_caches = []
    for si, (pattern, repeats) in enumerate(segs):
        seg_cache = caches[si] if caches is not None else None
        h, new_c, aux = run_segment(
            cfg, ctx, pattern, repeats, segments_params[si], h, positions,
            mode, seg_cache, length, enc_h)
        aux_tot = aux_tot + aux
        new_caches.append(new_c)
    return h, (new_caches if caches is not None else None), aux_tot


def stack_cache_shapes(cfg: ModelConfig, batch: int, max_len: int,
                       cross_len: int = 0):
    """Stacked cache shape/dtype pytree matching stack_apply's traversal."""
    out = []
    for pattern, repeats in derive_segments(cfg):
        seg = []
        for spec in pattern:
            shapes = block_cache_shapes(cfg, spec, batch, max_len, cross_len)
            seg.append({k: ((repeats,) + s, d) for k, (s, d) in shapes.items()})
        out.append(seg)
    return out
