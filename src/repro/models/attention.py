"""Attention mixers: GQA/MQA/MHA (chunked flash-style) and DeepSeek MLA.

Two execution regimes share the math:

* ``*_apply``  — full-sequence (training / prefill).  Causal attention runs
  chunked with an online-softmax accumulator: q-chunks are a *python* loop
  (so each q-chunk only scans the kv-chunks at or before it — no wasted
  upper-triangle FLOPs, and the HLO stays small because the inner kv sweep
  is a ``lax.scan``), keeping the (qc, kc) score tile bounded for 32k
  prefill without a Pallas dependency.
* ``*_decode`` — one new token against a cached KV of up to 512k tokens.
  The cache layout is sharding-friendly: heads TP normally, sequence over
  'data' for batch=1 long-context (ctx.kv_cache); softmax over a sharded
  sequence axis lowers to the partial-max/partial-sum collective combine.

MLA (DeepSeek-V3) caches the *compressed* latent (kv_lora + k_rope) and
supports two decode paths: naive (expand k/v per step) and *absorbed*
(fold W_uk into the query and W_uv into the output projection, attending in
latent space) — the latter is the §Perf variant.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .config import MLAConfig, ModelConfig
from .layers import apply_rope, dense_init, norm_apply, norm_init, split

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# chunked causal attention (online softmax), grouped heads
# ---------------------------------------------------------------------------


def _attend_chunk(q, k, v, bias):
    """q (B,Tq,G,Hkv,hd), k (B,Tk,Hkv,hd), v (B,Tk,Hkv,hv) -> scores/update.

    Returns (scores (B,G,Hkv,Tq,Tk) f32 pre-softmax with bias added).
    """
    s = jnp.einsum("btghd,bshd->bghts", q, k,
                   preferred_element_type=jnp.float32)
    return s + bias


def chunked_causal_attention(q, k, v, *, chunk: int, causal: bool = True,
                             kv_len=None, scale: float | None = None,
                             unroll: bool = False):
    """Flash-style attention.  q (B,T,Hq,hd), k/v (B,S,Hkv,hd|hv).

    Hq must be a multiple of Hkv (GQA groups).  ``kv_len`` optionally masks
    positions >= kv_len (ragged cache).  Returns (B,T,Hq,hv).
    """
    b, t, hq, hd = q.shape
    hkv = k.shape[2]
    hv = v.shape[-1]
    g = hq // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)

    # pad q and kv to chunk multiples; padded kv columns are masked below.
    qc = min(chunk, t)
    kc = min(chunk, k.shape[1])
    t_pad = -(-t // qc) * qc - t
    s_pad = -(-k.shape[1] // kc) * kc - k.shape[1]
    if kv_len is None and s_pad:
        kv_len = k.shape[1]
    if t_pad:
        q = jnp.pad(q, ((0, 0), (0, t_pad), (0, 0), (0, 0)))
    if s_pad:
        k = jnp.pad(k, ((0, 0), (0, s_pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, s_pad), (0, 0), (0, 0)))
    t_full, s_len = t + t_pad, k.shape[1]
    qg = (q * scale).reshape(b, t_full, g, hkv, hd)
    nq, nk = t_full // qc, s_len // kc

    out = []
    for i in range(nq):  # python loop: per-q-chunk static kv bound
        qi = qg[:, i * qc:(i + 1) * qc]
        # kv chunks 0..hi-1 (inclusive of the diagonal chunk when causal)
        hi = min(((i + 1) * qc + kc - 1) // kc, nk) if causal else nk

        @jax.checkpoint  # flash-style: recompute (qc,kc) scores in backward
        def kv_step(carry, j):
            m, l, acc = carry
            kj = jax.lax.dynamic_slice_in_dim(k, j * kc, kc, axis=1)
            vj = jax.lax.dynamic_slice_in_dim(v, j * kc, kc, axis=1)
            pos_q = i * qc + jnp.arange(qc)
            pos_k = j * kc + jnp.arange(kc)
            bias = jnp.zeros((qc, kc), jnp.float32)
            if causal:
                bias = jnp.where(pos_q[:, None] >= pos_k[None, :], 0.0, NEG_INF)
            if kv_len is not None:
                bias = bias + jnp.where(pos_k[None, :] < kv_len, 0.0, NEG_INF)
            s = _attend_chunk(qi, kj, vj, bias)  # (B,G,Hkv,qc,kc)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(-1)
            pv = jnp.einsum("bghts,bshd->bghtd", p.astype(vj.dtype), vj,
                            preferred_element_type=jnp.float32)
            acc_new = acc * alpha[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, g, hkv, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, g, hkv, qc), jnp.float32)
        a0 = jnp.zeros((b, g, hkv, qc, hv), jnp.float32)
        if unroll:  # exact-HLO costing path (see config.attn_unroll)
            carry = (m0, l0, a0)
            for j in range(hi):
                carry, _ = kv_step(carry, j)
            m, l, acc = carry
        else:
            (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(hi))
        o = acc / jnp.maximum(l, 1e-30)[..., None]  # (B,G,Hkv,qc,hv)
        out.append(jnp.moveaxis(o, 3, 1).reshape(b, qc, hq, hv))
    res = jnp.concatenate(out, axis=1) if len(out) > 1 else out[0]
    return res[:, :t].astype(q.dtype)


def decode_attention(q, k_cache, v_cache, length, scale: float | None = None):
    """Single-step attention: q (B,1,Hq,hd) vs cache (B,S,Hkv,hd|hv)."""
    b, _, hq, hd = q.shape
    s = k_cache.shape[1]
    hkv = k_cache.shape[2]
    g = hq // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qg = (q * scale).reshape(b, 1, g, hkv, hd)
    sc = jnp.einsum("btghd,bshd->bghts", qg, k_cache,
                    preferred_element_type=jnp.float32)
    mask = jnp.arange(s) < length  # (S,)
    sc = jnp.where(mask[None, None, None, None, :], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bghts,bshd->bghtd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return jnp.moveaxis(o, 3, 1).reshape(b, 1, hq, v_cache.shape[-1]).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA layer
# ---------------------------------------------------------------------------


def gqa_init(rng, cfg: ModelConfig):
    d, h, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
    ks = split(rng, 4)
    p = {
        "wq": dense_init(ks[0], (d, h, hd)),
        "wk": dense_init(ks[1], (d, hkv, hd)),
        "wv": dense_init(ks[2], (d, hkv, hd)),
        "wo": dense_init(ks[3], (h, hd, d), in_axis=(0, 1)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), jnp.float32)
        p["bk"] = jnp.zeros((hkv, hd), jnp.float32)
        p["bv"] = jnp.zeros((hkv, hd), jnp.float32)
    return p


def _qkv(cfg, p, x, positions, rope=True):
    dt = x.dtype
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"].astype(dt))
    k = jnp.einsum("btd,dhk->bthk", x, p["wk"].astype(dt))
    v = jnp.einsum("btd,dhk->bthk", x, p["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    if rope and cfg.pos_emb == "rope":
        q = apply_rope(cfg, q, positions)
        k = apply_rope(cfg, k, positions)
    return q, k, v


def gqa_apply(cfg: ModelConfig, ctx, p, x, positions, *, causal=True,
              kv_override=None):
    """Full-sequence GQA.  ``kv_override=(k, v)`` turns this into
    cross-attention (whisper decoder -> encoder memory)."""
    q, k, v = _qkv(cfg, p, x, positions, rope=kv_override is None)
    if kv_override is not None:
        k, v = kv_override
    q = ctx.act_bthd(q)
    k = ctx.act_bthd(k)
    v = ctx.act_bthd(v)
    o = chunked_causal_attention(q, k, v, chunk=cfg.attn_chunk, causal=causal,
                                 unroll=cfg.attn_unroll)
    o = ctx.act_bthd(o)
    return jnp.einsum("bthk,hkd->btd", o, p["wo"].astype(x.dtype)), (k, v)


def gqa_decode(cfg: ModelConfig, ctx, p, x, cache_k, cache_v, length):
    """One-token decode.  x (B,1,D); cache (B,S,Hkv,hd); length () i32."""
    pos = jnp.full((x.shape[0], 1), length, jnp.int32)
    q, k, v = _qkv(cfg, p, x, pos)
    cache_k = ctx.kv_cache(jax.lax.dynamic_update_slice_in_dim(
        cache_k, k.astype(cache_k.dtype), length, axis=1))
    cache_v = ctx.kv_cache(jax.lax.dynamic_update_slice_in_dim(
        cache_v, v.astype(cache_v.dtype), length, axis=1))
    o = decode_attention(q, cache_k, cache_v, length + 1)
    return (jnp.einsum("bthk,hkd->btd", o, p["wo"].astype(x.dtype)),
            cache_k, cache_v)


def cross_attn_kv(cfg: ModelConfig, p, enc_h):
    """Project encoder memory into this layer's cross k/v (cached at prefill)."""
    dt = enc_h.dtype
    k = jnp.einsum("btd,dhk->bthk", enc_h, p["wk"].astype(dt))
    v = jnp.einsum("btd,dhk->bthk", enc_h, p["wv"].astype(dt))
    if cfg.qkv_bias:
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    return k, v


def cross_attn_apply(cfg: ModelConfig, ctx, p, x, kv):
    """Decoder->encoder cross attention (non-causal, no rope)."""
    dt = x.dtype
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
    k, v = kv
    q = ctx.act_bthd(q)
    o = chunked_causal_attention(q, k, v, chunk=cfg.attn_chunk, causal=False,
                                 unroll=cfg.attn_unroll)
    return jnp.einsum("bthk,hkd->btd", o, p["wo"].astype(dt))


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3): low-rank q/kv with decoupled rope, latent KV cache
# ---------------------------------------------------------------------------


def mla_init(rng, cfg: ModelConfig):
    m: MLAConfig = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    ks = split(rng, 8)
    return {
        "wdq": dense_init(ks[0], (d, m.q_lora_rank)),
        "q_norm": norm_init(cfg, m.q_lora_rank),
        "wuq": dense_init(ks[1], (m.q_lora_rank, h, dn + dr)),
        "wdkv": dense_init(ks[2], (d, m.kv_lora_rank + dr)),
        "kv_norm": norm_init(cfg, m.kv_lora_rank),
        "wuk": dense_init(ks[3], (m.kv_lora_rank, h, dn)),
        "wuv": dense_init(ks[4], (m.kv_lora_rank, h, dv)),
        "wo": dense_init(ks[5], (h, dv, d), in_axis=(0, 1)),
    }


def _mla_qkv(cfg, p, x, positions):
    m: MLAConfig = cfg.mla
    dn, dr = m.qk_nope_head_dim, m.qk_rope_head_dim
    dt = x.dtype
    cq = norm_apply(cfg, p["q_norm"], x @ p["wdq"].astype(dt))
    q = jnp.einsum("btr,rhk->bthk", cq, p["wuq"].astype(dt))
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(cfg, q_rope, positions)

    dkv = x @ p["wdkv"].astype(dt)  # (B,T, kv_lora + dr)
    c_kv = norm_apply(cfg, p["kv_norm"], dkv[..., :m.kv_lora_rank])
    k_rope = apply_rope(cfg, dkv[..., None, m.kv_lora_rank:], positions)  # 1 head
    return q_nope, q_rope, c_kv, k_rope[..., 0, :]


def mla_apply(cfg: ModelConfig, ctx, p, x, positions, *, causal=True):
    """Full-sequence MLA (training / prefill).  Returns (out, (c_kv, k_rope))."""
    m: MLAConfig = cfg.mla
    b, t, _ = x.shape
    h = cfg.num_heads
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(cfg, p, x, positions)

    dt = x.dtype
    k_nope = jnp.einsum("btr,rhk->bthk", c_kv, p["wuk"].astype(dt))
    v = jnp.einsum("btr,rhk->bthk", c_kv, p["wuv"].astype(dt))
    q = ctx.act_bthd(jnp.concatenate([q_nope, q_rope], axis=-1))
    k = ctx.act_bthd(jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (b, t, h, dr))],
        axis=-1))
    v = ctx.act_bthd(v)
    o = chunked_causal_attention(q, k, v, chunk=cfg.attn_chunk, causal=causal,
                                 scale=1.0 / math.sqrt(dn + dr),
                                 unroll=cfg.attn_unroll)
    o = ctx.act_bthd(o)
    return jnp.einsum("bthk,hkd->btd", o, p["wo"].astype(dt)), (c_kv, k_rope)


def mla_decode(cfg: ModelConfig, ctx, p, x, cache_ckv, cache_krope, length):
    """One-token MLA decode over the *latent* cache (B,S,kv_lora)+(B,S,dr).

    ``cfg.mla.absorb`` switches between the naive path (expand k/v for all
    cached positions each step — memory-light, compute-heavy) and the
    absorbed path (attend in latent space; W_uk folded into q, W_uv folded
    into the output) — the MLA trick that makes the latent cache *cheaper*
    to attend to than a materialized one.
    """
    m: MLAConfig = cfg.mla
    b = x.shape[0]
    h = cfg.num_heads
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    pos = jnp.full((b, 1), length, jnp.int32)
    q_nope, q_rope, c_kv_new, k_rope_new = _mla_qkv(cfg, p, x, pos)
    cache_ckv = jax.lax.dynamic_update_slice_in_dim(
        cache_ckv, c_kv_new.astype(cache_ckv.dtype), length, axis=1)
    cache_krope = jax.lax.dynamic_update_slice_in_dim(
        cache_krope, k_rope_new.astype(cache_krope.dtype), length, axis=1)
    s_max = cache_ckv.shape[1]
    dt = x.dtype
    mask = (jnp.arange(s_max) < length + 1)

    scale = 1.0 / math.sqrt(dn + dr)
    if m.absorb:
        # q' = q_nope @ W_uk  -> latent-space query (B,1,H,R)
        q_lat = jnp.einsum("bthk,rhk->bthr", q_nope, p["wuk"].astype(dt))
        s_lat = jnp.einsum("bthr,bsr->bhts", q_lat, cache_ckv.astype(dt),
                           preferred_element_type=jnp.float32)
        s_rope = jnp.einsum("bthk,bsk->bhts", q_rope, cache_krope.astype(dt),
                            preferred_element_type=jnp.float32)
        sc = (s_lat + s_rope) * scale
        sc = jnp.where(mask[None, None, None, :], sc, NEG_INF)
        pby = jax.nn.softmax(sc, axis=-1)
        o_lat = jnp.einsum("bhts,bsr->bthr", pby.astype(dt), cache_ckv.astype(dt),
                           preferred_element_type=jnp.float32).astype(dt)
        # out = (o_lat @ W_uv) @ W_o  == o_lat @ (W_uv·W_o)  (absorbable)
        o = jnp.einsum("bthr,rhk->bthk", o_lat, p["wuv"].astype(dt))
    else:
        k_nope = jnp.einsum("bsr,rhk->bshk", cache_ckv.astype(dt),
                            p["wuk"].astype(dt))
        v = jnp.einsum("bsr,rhk->bshk", cache_ckv.astype(dt),
                       p["wuv"].astype(dt))
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(cache_krope[:, :, None, :].astype(dt),
                                      k_nope.shape[:3] + (dr,))], axis=-1)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        o = decode_attention(q, k, v, length + 1, scale=scale)
    out = jnp.einsum("bthk,hkd->btd", o, p["wo"].astype(dt))
    return out, cache_ckv, cache_krope
