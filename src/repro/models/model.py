"""Public model API: init / train_loss / prefill / decode_step / init_cache.

Handles per-family input assembly:
  LM (dense/moe/hybrid/ssm):  batch = {tokens, labels}
  audio (whisper enc-dec):    batch = {frames (stub embeddings), tokens, labels}
  vlm (internvl2):            batch = {patches (stub embeddings), tokens, labels}

Caches are dicts: {"segs": [per-segment stacked block caches], "len": i32,
optionally "enc_h" for enc-dec}.  Everything is a pytree — pjit, scan,
checkpointing and the dry-run all treat models uniformly.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from .config import ModelConfig, derive_segments
from .layers import (embed_apply, embed_init, lm_loss, logits_apply,
                     norm_apply, norm_init, sinusoidal_pos, dense_init, split)
from .transformer import (block_apply, block_init, stack_apply, stack_cache_shapes,
                          stack_init)
from .config import LayerSpec
from .moe import count_moe_params


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_params(rng, cfg: ModelConfig):
    ks = split(rng, 6)
    params: dict[str, Any] = {
        "embed": embed_init(ks[0], cfg),
        "segments": stack_init(ks[1], cfg, cross=cfg.encoder is not None),
        "final_norm": norm_init(cfg),
    }
    if cfg.encoder is not None:
        enc_cfg = encoder_cfg(cfg)
        params["encoder"] = {
            "segments": stack_init(ks[2], enc_cfg),
            "final_norm": norm_init(enc_cfg),
        }
    if cfg.mtp_depth > 0:
        params["mtp"] = {
            "proj": dense_init(ks[3], (2 * cfg.d_model, cfg.d_model)),
            "norm_h": norm_init(cfg),
            "norm_e": norm_init(cfg),
            "block": jax.tree.map(
                lambda x: x[None],
                block_init(ks[4], cfg, LayerSpec(mixer="attn", moe=False))),
            "final_norm": norm_init(cfg),
        }
    return params


def encoder_cfg(cfg: ModelConfig) -> ModelConfig:
    """Derived config for the (bidirectional) encoder tower."""
    import dataclasses
    return dataclasses.replace(
        cfg, num_layers=cfg.encoder.num_layers, layer_pattern=("attn",),
        moe_pattern=(False,), encoder=None, mtp_depth=0,
        pos_emb="sinusoidal", causal=False)


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------


def _embed_inputs(cfg: ModelConfig, ctx, params, batch):
    """Token / stub-frontend embedding assembly.  Returns (h, labels, positions)."""
    if cfg.family == "vlm":
        tok = embed_apply(cfg, params["embed"], batch["tokens"])
        h = jnp.concatenate(
            [batch["patches"].astype(tok.dtype), tok], axis=1)
        labels = batch.get("labels")
        if labels is not None:
            # loss only over text positions; vision positions ignored
            pad = jnp.full(batch["patches"].shape[:2], -1, labels.dtype)
            labels = jnp.concatenate([pad, labels], axis=1)
    else:
        h = embed_apply(cfg, params["embed"], batch["tokens"])
        labels = batch.get("labels")
    t = h.shape[1]
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), h.shape[:2])
    if cfg.pos_emb == "sinusoidal":
        h = h + sinusoidal_pos(t, cfg.d_model).astype(h.dtype)
    return ctx.act_btd(h), labels, positions


def _encode(cfg: ModelConfig, ctx, params, batch):
    """Encoder tower over stub frame embeddings (whisper)."""
    ecfg = encoder_cfg(cfg)
    frames = batch["frames"].astype(jnp.dtype(cfg.compute_dtype))
    h = frames + sinusoidal_pos(frames.shape[1], cfg.d_model).astype(frames.dtype)
    h = ctx.act_btd(h)
    pos = jnp.broadcast_to(
        jnp.arange(h.shape[1], dtype=jnp.int32), h.shape[:2])
    h, _, _ = stack_apply(ecfg, ctx, params["encoder"]["segments"], h, pos,
                          "train")
    return norm_apply(ecfg, params["encoder"]["final_norm"], h)


def forward(cfg: ModelConfig, ctx, params, batch, mode="train", caches=None):
    """Backbone forward.  Returns (h_final, labels, aux, new_caches, enc_h)."""
    enc_h = None
    if cfg.encoder is not None:
        enc_h = (caches or {}).get("enc_h")
        if enc_h is None:
            enc_h = _encode(cfg, ctx, params, batch)
    h, labels, positions = _embed_inputs(cfg, ctx, params, batch)
    segs_cache = caches["segs"] if caches is not None else None
    length = caches["len"] if caches is not None else None
    h, new_segs, aux = stack_apply(cfg, ctx, params["segments"], h, positions,
                                   mode, segs_cache, length, enc_h)
    h = norm_apply(cfg, params["final_norm"], h)
    return h, labels, aux, new_segs, enc_h


def train_loss(cfg: ModelConfig, ctx, params, batch, aux_weight=0.01):
    """Scalar loss + metrics.  batch per family docstring."""
    h, labels, aux, _, _ = forward(cfg, ctx, params, batch, mode="train")
    loss, metrics = lm_loss(cfg, ctx, params["embed"], h, labels)
    if cfg.mtp_depth > 0:
        mtp_loss = _mtp_loss(cfg, ctx, params, h, batch, labels)
        loss = loss + cfg.mtp_weight * mtp_loss
        metrics["mtp"] = mtp_loss
    n_moe = sum(1 for s in cfg.layer_specs() if s.moe)
    if n_moe:
        aux = aux / n_moe
        loss = loss + aux_weight * aux
        metrics["moe_aux"] = aux
    metrics["loss"] = loss
    return loss, metrics


def _mtp_loss(cfg: ModelConfig, ctx, params, h, batch, labels):
    """DeepSeek MTP (depth 1): predict token t+2 from h_t and emb(t+1)."""
    p = params["mtp"]
    tok = batch["tokens"]
    emb_next = embed_apply(cfg, params["embed"], tok)  # (B,T,D) of t's token
    # position t uses emb of token t+1: shift left
    emb_next = jnp.concatenate(
        [emb_next[:, 1:], jnp.zeros_like(emb_next[:, :1])], axis=1)
    hin = jnp.concatenate(
        [norm_apply(cfg, p["norm_h"], h),
         norm_apply(cfg, p["norm_e"], emb_next)], axis=-1)
    h2 = hin @ p["proj"].astype(hin.dtype)
    pos = jnp.broadcast_to(
        jnp.arange(h2.shape[1], dtype=jnp.int32), h2.shape[:2])
    spec = LayerSpec(mixer="attn", moe=False)
    blk = jax.tree.map(lambda x: x[0], p["block"])
    h2, _, _ = block_apply(cfg, ctx, spec, blk, h2, pos, "train", None, None,
                           None)
    h2 = norm_apply(cfg, p["final_norm"], h2)
    # labels for t+2: shift main labels left by one more position
    mtp_labels = jnp.concatenate(
        [labels[:, 1:], jnp.full_like(labels[:, :1], -1)], axis=1)
    loss, _ = lm_loss(cfg, ctx, params["embed"], h2, mtp_labels)
    return loss


# ---------------------------------------------------------------------------
# serving: cache init / prefill / decode
# ---------------------------------------------------------------------------


def cache_shapes(cfg: ModelConfig, batch: int, max_len: int):
    """Shape/dtype pytree of the decode cache (for init and dry-run specs)."""
    cross_len = cfg.encoder.seq_len if cfg.encoder is not None else 0
    shapes = {"segs": stack_cache_shapes(cfg, batch, max_len, cross_len),
              "len": ((), jnp.int32)}
    if cfg.encoder is not None:
        shapes["enc_h"] = ((batch, cfg.encoder.seq_len, cfg.d_model),
                           jnp.dtype(cfg.compute_dtype))
    return shapes


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    return jax.tree.map(
        lambda sd: jnp.zeros(sd[0], sd[1]), cache_shapes(cfg, batch, max_len),
        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
        and isinstance(x[0], tuple))


def prefill(cfg: ModelConfig, ctx, params, batch, cache):
    """Fill the cache from a full prompt; returns (last-token logits, cache)."""
    h, _, _, new_segs, enc_h = forward(cfg, ctx, params, batch,
                                       mode="prefill", caches=cache)
    logits = logits_apply(cfg, ctx, params["embed"], h[:, -1:])
    t = batch["tokens"].shape[1] + (
        batch["patches"].shape[1] if cfg.family == "vlm" else 0)
    new_cache = dict(cache)
    new_cache["segs"] = new_segs
    new_cache["len"] = jnp.int32(t)
    if enc_h is not None:
        new_cache["enc_h"] = enc_h
    return logits, new_cache


def decode_step(cfg: ModelConfig, ctx, params, cache, tokens):
    """One decode step.  tokens (B, 1) i32.  Returns (logits, cache)."""
    batch = {"tokens": tokens}
    if cfg.family == "vlm":
        batch = {"tokens": tokens,
                 "patches": jnp.zeros((tokens.shape[0], 0, cfg.d_model),
                                      jnp.dtype(cfg.compute_dtype))}
    h, _, _, new_segs, _ = forward(cfg, ctx, params, batch, mode="decode",
                                   caches=cache)
    logits = logits_apply(cfg, ctx, params["embed"], h)
    new_cache = dict(cache)
    new_cache["segs"] = new_segs
    new_cache["len"] = cache["len"] + 1
    return logits, new_cache


# ---------------------------------------------------------------------------
# analytic parameter count (6ND roofline term)
# ---------------------------------------------------------------------------


def count_params(cfg: ModelConfig) -> int:
    d = cfg.d_model
    norm_p = 2 * d if cfg.norm == "layernorm" else d

    def attn_params():
        if cfg.attention == "mla":
            m = cfg.mla
            dn, dr, dv = (m.qk_nope_head_dim, m.qk_rope_head_dim,
                          m.v_head_dim)
            t = d * m.q_lora_rank + m.q_lora_rank
            t += m.q_lora_rank * cfg.num_heads * (dn + dr)
            t += d * (m.kv_lora_rank + dr) + m.kv_lora_rank
            t += m.kv_lora_rank * cfg.num_heads * (dn + dv)
            t += cfg.num_heads * dv * d
            return t
        hd = cfg.head_dim_
        t = d * hd * (cfg.num_heads + 2 * cfg.num_kv_heads)
        t += cfg.num_heads * hd * d
        if cfg.qkv_bias:
            t += hd * (cfg.num_heads + 2 * cfg.num_kv_heads)
        return t

    def mlp_params():
        n_mats = 3 if cfg.mlp_gated else 2
        return n_mats * d * cfg.d_ff

    total = cfg.vocab_size * d
    if not cfg.tie_embeddings:
        total += cfg.vocab_size * d
    for spec in cfg.layer_specs():
        total += norm_p  # norm1
        if spec.mixer == "attn":
            total += attn_params()
            if cfg.encoder is not None:  # decoder cross-attention
                total += norm_p + attn_params()
        elif spec.mixer == "mamba":
            from .mamba import mamba_dims
            d_in, n, dt_rank = mamba_dims(cfg)
            total += d * 2 * d_in + cfg.mamba.d_conv * d_in + d_in
            total += d_in * (dt_rank + 2 * n) + dt_rank * d_in + d_in
            total += d_in * n + d_in + d_in * d
            total += dt_rank + 2 * n  # dt/b/c inner rmsnorms
        elif spec.mixer == "rwkv":
            total += 5 * d + 5 * d * d + 2 * d * cfg.rwkv.decay_lora
            total += 4 * d  # w_base, u, gn scale/bias
        total += norm_p  # norm2
        if spec.mixer == "rwkv":
            total += 2 * d + 2 * d * cfg.d_ff + d * d
        elif spec.moe:
            tot, _ = count_moe_params(cfg)
            total += tot
        else:
            total += mlp_params()
    total += norm_p  # final norm
    if cfg.encoder is not None:
        e = cfg.encoder
        per = attn_params() + mlp_params() + 2 * norm_p
        total += e.num_layers * per + norm_p
    if cfg.mtp_depth > 0:
        total += 2 * d * d + 2 * norm_p  # proj + norms
        total += attn_params() + mlp_params() + 2 * norm_p  # mtp block
        total += norm_p
    return int(total)


def count_active_params(cfg: ModelConfig) -> int:
    """Active (per-token) params — the N in 6ND for MoE models."""
    if cfg.moe is None:
        return count_params(cfg)
    total = count_params(cfg)
    tot_moe, active_moe = count_moe_params(cfg)
    n_moe = sum(1 for s in cfg.layer_specs() if s.moe)
    return int(total - n_moe * (tot_moe - active_moe))
