"""Model zoo: config-driven architectures (dense / MoE / hybrid / SSM /
enc-dec / VLM) in pure functional JAX."""
from .config import (  # noqa: F401
    EncoderConfig,
    LayerSpec,
    MLAConfig,
    MambaConfig,
    ModelConfig,
    MoEConfig,
    RWKVConfig,
    derive_segments,
)
from .model import (  # noqa: F401
    cache_shapes,
    count_active_params,
    count_params,
    decode_step,
    init_cache,
    init_params,
    prefill,
    train_loss,
)
