"""Deterministic synthetic token pipeline: sharded, prefetched, resumable.

Design mirrors a production loader's contract without the storage layer:

* **Deterministic & counter-based** — batch ``i`` is a pure function of
  (seed, i), so any host can materialise exactly its shard of any step:
  restart-safe and elastic (a host joining at step k needs no history).
* **Checkpointable** — iterator state is one integer (next_step) saved
  alongside params; bit-exact resume is tested.
* **Sharded** — ``host_slice`` yields only this host's batch rows given
  (host_id, num_hosts), matching the batch PartitionSpec.
* **Prefetched** — a background thread keeps a small queue of ready batches
  (the CPU-side analogue of double-buffered host->device transfer).

The token stream is a mixture of repeated n-grams and uniform noise so that
language models have actual structure to learn (pure uniform noise has no
learnable signal; the n-gram mixture gives a loss floor below uniform
entropy — used by the convergence tests).
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import numpy as np


class SyntheticLM:
    """Deterministic synthetic LM batches."""

    def __init__(self, vocab_size: int, batch: int, seq_len: int,
                 seed: int = 0, ngram: int = 8, noise: float = 0.2,
                 host_id: int = 0, num_hosts: int = 1,
                 extra_specs: Optional[dict] = None):
        assert batch % num_hosts == 0
        self.vocab_size = vocab_size
        self.batch = batch
        self.seq_len = seq_len
        self.seed = seed
        self.ngram = ngram
        self.noise = noise
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.extra_specs = extra_specs or {}
        self.next_step = 0
        # fixed n-gram codebook shared by all hosts
        cb_rng = np.random.default_rng(seed)
        self.codebook = cb_rng.integers(
            0, vocab_size, size=(64, ngram), dtype=np.int32)

    # ---- deterministic materialisation -----------------------------------

    def batch_at(self, step: int) -> dict:
        """The full global batch for ``step`` (pure function of seed+step)."""
        rng = np.random.default_rng((self.seed, step))
        b, t = self.batch, self.seq_len
        n_slots = -(-t // self.ngram)
        picks = rng.integers(0, len(self.codebook), size=(b, n_slots))
        toks = self.codebook[picks].reshape(b, -1)[:, :t].astype(np.int32)
        noise_mask = rng.random((b, t)) < self.noise
        noise_toks = rng.integers(0, self.vocab_size, size=(b, t), dtype=np.int32)
        toks = np.where(noise_mask, noise_toks, toks)
        labels = np.concatenate([toks[:, 1:], toks[:, :1]], axis=1)
        out = {"tokens": toks, "labels": labels}
        for name, (shape, dtype) in self.extra_specs.items():
            out[name] = rng.standard_normal((b,) + tuple(shape)).astype(dtype)
        return out

    def host_slice(self, global_batch: dict) -> dict:
        per = self.batch // self.num_hosts
        lo = self.host_id * per
        return {k: v[lo:lo + per] for k, v in global_batch.items()}

    # ---- iterator protocol with checkpointable state ----------------------

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        out = self.host_slice(self.batch_at(self.next_step))
        self.next_step += 1
        return out

    def state_dict(self) -> dict:
        return {"next_step": self.next_step, "seed": self.seed}

    def load_state_dict(self, state: dict):
        assert state["seed"] == self.seed, "seed mismatch on resume"
        self.next_step = int(state["next_step"])


class Prefetcher:
    """Background-thread prefetch queue over any stateful iterator.

    Checkpoint-correct: ``state_dict`` reports the *consumed* position, not
    the inner iterator's (which runs ahead by the queue depth), so resume
    replays exactly the batches the training loop never saw.
    """

    def __init__(self, it, depth: int = 2):
        self.it = it
        self.depth = depth
        self._consumed = 0
        self._base = it.state_dict() if hasattr(it, "state_dict") else None
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self.thread = threading.Thread(target=self._fill, daemon=True)
        self.thread.start()

    def _fill(self):
        while not self._stop.is_set():
            try:
                item = next(self.it)
            except StopIteration:
                self.q.put(None)
                return
            self.q.put(item)

    def __iter__(self):
        return self

    def __next__(self):
        item = self.q.get()
        if item is None:
            raise StopIteration
        self._consumed += 1
        return item

    # ---- checkpointable-state protocol -------------------------------------

    def state_dict(self) -> dict:
        assert self._base is not None, "inner iterator is not checkpointable"
        st = dict(self._base)
        st["next_step"] = int(self._base["next_step"]) + self._consumed
        return st

    def load_state_dict(self, state: dict):
        # stop the old thread, rewind the inner iterator, restart
        self.close()
        self.it.load_state_dict(state)
        self._base = self.it.state_dict()
        self._consumed = 0
        self.q = queue.Queue(maxsize=self.depth)
        self._stop = threading.Event()
        self.thread = threading.Thread(target=self._fill, daemon=True)
        self.thread.start()

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self.thread.join(timeout=5)
