"""Production mesh + per-cell parallel plans.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state): 16x16 = 256 chips per pod, and the multi-pod variant
adds a leading pod=2 axis (512 chips).
"""
from __future__ import annotations

import jax

from ..configs.shapes import ShapeSpec
from ..models import ModelConfig, count_params
from ..parallel.sharding import ParallelPlan

# FSDP threshold: params above this can't live TP-sharded alone on 16 chips.
FSDP_PARAM_THRESHOLD = 8e9


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_plan(cfg: ModelConfig, shape: ShapeSpec, *, multi_pod: bool) -> ParallelPlan:
    """Distribution decisions for one (arch x shape x mesh) cell."""
    batch_axes = ("pod", "data") if multi_pod else ("data",)
    n_params = count_params(cfg)
    big = n_params > FSDP_PARAM_THRESHOLD
    huge = n_params > 100e9
    seq_axis = None
    if shape.name == "long_500k":
        # batch=1: the KV/sequence axis carries the data-parallel shards
        seq_axis = batch_axes
    accum = 1
    if shape.kind == "train":
        # microbatching bounds saved per-layer residuals (B/8 per micro):
        # the production default for every arch — without it even the 6B
        # models blow the 16G HBM on activations at batch 16x4096/device
        n_shards = 32 if multi_pod else 16
        accum = max(1, min(8, shape.global_batch // n_shards))
    return ParallelPlan(
        batch_axes=batch_axes,
        model_axis="model",
        seq_axis=seq_axis,
        fsdp_axes=batch_axes if big else (),
        zero1=True,
        remat="block" if shape.kind == "train" else "none",
        accum_steps=accum,
        moments_dtype="bfloat16" if huge else "float32",
    )
