import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST be the first two lines: jax locks the device count on first init.
# The 512 placeholder host devices exist ONLY for this dry-run process.
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the *production* step function (train_step /
prefill / decode_step) with full published configs, shards every input with
the rules in ``parallel.sharding``, lowers against ShapeDtypeStruct inputs
(no allocation), compiles for the 16x16 (single-pod, 256 chips) and
2x16x16 (multi-pod, 512 chips) meshes, and records:

  memory_analysis()      -> bytes per device (proves it fits / doesn't)
  cost_analysis()        -> HLO FLOPs / bytes (roofline inputs)
  compiled.as_text()     -> collective schedule inventory (hlo_analysis)

Failures (sharding mismatch, OOM at compile, unsupported collective) are
bugs in the system — the record keeps the error so the table shows exactly
what broke.

Usage:
  python -m repro.launch.dryrun --arch smollm-360m --shape train_4k
  python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import SHAPES, applicable, get_config, input_specs
from ..configs.registry import ARCH_IDS
from ..models import (count_active_params, count_params, decode_step,
                      init_params, prefill)
from ..optim import adamw
from ..parallel.sharding import make_rules
from ..train import make_train_step
from . import hlo_analysis as ha
from .mesh import make_plan, make_production_mesh


def params_shapes(cfg):
    return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))


def build_cell(cfg, shape, mesh, *, multi_pod: bool):
    """Returns (fn, args, in_shardings, out_shardings, rules)."""
    plan = make_plan(cfg, shape, multi_pod=multi_pod)
    ctx = plan.ctx(mesh)
    rules = make_rules(mesh, plan)
    params_s = params_shapes(cfg)
    psh = rules.params(params_s)

    if shape.kind == "train":
        (batch_s,) = input_specs(cfg, shape)
        opt_cfg = adamw.AdamWConfig(moments_dtype=plan.moments_dtype)
        opt_s = jax.eval_shape(
            lambda: adamw.init(params_s, plan.moments_dtype))
        osh = adamw.OptState(m=rules.opt_state(params_s),
                             v=rules.opt_state(params_s),
                             step=NamedSharding(mesh, P()))
        bsh = rules.batch(batch_s)
        fn = make_train_step(cfg, ctx, opt_cfg,
                             accum_steps=plan.accum_steps)
        # donate params+opt: the step updates them in place (production
        # memory contract; halves the apparent footprint)
        return (fn, (params_s, opt_s, batch_s), (psh, osh, bsh),
                (psh, osh, None), rules, (0, 1))

    if shape.kind == "prefill":
        batch_s, cache_s = input_specs(cfg, shape)
        bsh = rules.batch(batch_s)
        csh = rules.cache(cache_s)

        def fn(p, b, c):
            return prefill(cfg, ctx, p, b, c)

        return (fn, (params_s, batch_s, cache_s), (psh, bsh, csh),
                (None, csh), rules, (2,))  # donate the cache

    # decode
    cache_s, tok_s = input_specs(cfg, shape)
    csh = rules.cache(cache_s)
    tsh = rules.batch({"tokens": tok_s})["tokens"]

    def fn(p, c, t):
        return decode_step(cfg, ctx, p, c, t)

    return (fn, (params_s, cache_s, tok_s), (psh, csh, tsh), (None, csh),
            rules, (1,))  # donate the cache: in-place KV update


def model_flops(cfg, shape) -> float:
    """Analytic MODEL_FLOPS for the cell (6ND train, 2ND per fwd token)."""
    n_act = count_active_params(cfg)
    if shape.kind == "train":
        return 6.0 * n_act * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_act * shape.global_batch * shape.seq_len
    return 2.0 * n_act * shape.global_batch  # decode: 1 token / sequence


def run_cell(arch: str, shape_name: str, *, multi_pod: bool) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "kind": shape.kind, "ok": False,
           "n_params": count_params(cfg),
           "n_active_params": count_active_params(cfg),
           "model_flops": model_flops(cfg, shape)}
    if not applicable(cfg, shape):
        rec["skipped"] = "long_500k needs sub-quadratic mixing (DESIGN.md)"
        return rec
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        rec["chips"] = mesh.size
        fn, args, in_sh, out_sh, rules, donate = build_cell(
            cfg, shape, mesh, multi_pod=multi_pod)
        t0 = time.time()
        lowered = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                          donate_argnums=donate).lower(*args)
        rec["lower_s"] = round(time.time() - t0, 2)
        t0 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t0, 2)

        mem = ha.memory_analysis_dict(compiled)
        print(compiled.memory_analysis())  # proves it fits (or not)
        cost = compiled.cost_analysis() or {}
        print({k: cost.get(k) for k in ("flops", "bytes accessed")})
        txt = compiled.as_text()
        rec.update(
            ok=True,
            memory=mem,
            hlo_flops=float(cost.get("flops", 0.0)),
            hlo_bytes=float(cost.get("bytes accessed", 0.0)),
            collectives=ha.collective_summary(txt),
            n_while_loops=txt.count(" while("),
            fallbacks=rules.fallbacks,
        )
    except Exception as e:  # recorded, not raised: the table shows the bug
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    cells = []
    archs = ARCH_IDS if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    os.makedirs(args.out, exist_ok=True)

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                name = f"{arch}__{shape}__{'multi' if mp else 'single'}"
                print(f"=== {name} ===", flush=True)
                rec = run_cell(arch, shape, multi_pod=mp)
                path = os.path.join(args.out, name + ".json")
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                status = ("OK" if rec.get("ok")
                          else rec.get("skipped") or rec.get("error", "?"))
                print(f"--> {status} "
                      f"(lower {rec.get('lower_s', '-')}s, "
                      f"compile {rec.get('compile_s', '-')}s)", flush=True)
                cells.append(rec)

    n_ok = sum(1 for c in cells if c.get("ok"))
    n_skip = sum(1 for c in cells if "skipped" in c)
    print(f"\n{n_ok} ok / {n_skip} skipped-by-design / "
          f"{len(cells) - n_ok - n_skip} FAILED of {len(cells)} cells")


if __name__ == "__main__":
    main()
