"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On this CPU container it trains the *smoke* config of any arch end-to-end
(synthetic data, checkpointing, fault-tolerant supervisor); on a real
cluster the same entry point takes ``--full --mesh data,model`` and the
production mesh.  Everything below the flag parsing is the deployable path:
sharding rules, supervisor, async checkpoints.
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import SHAPES
from ..configs.registry import ARCH_IDS, get_config, get_smoke
from ..data import Prefetcher, SyntheticLM
from ..models import init_params, count_params
from ..optim import adamw
from ..parallel.ctx import NO_PARALLEL
from ..parallel.sharding import ParallelPlan, make_rules
from ..runtime import Supervisor, SupervisorConfig
from ..train import make_train_step


def extra_data_specs(cfg):
    out = {}
    if cfg.family == "audio":
        out["frames"] = ((cfg.encoder.seq_len, cfg.d_model), np.float32)
    if cfg.family == "vlm":
        out["patches"] = ((cfg.vision_tokens, cfg.d_model), np.float32)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="smollm-360m")
    ap.add_argument("--full", action="store_true",
                    help="full published config (needs a real cluster)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--mesh", default="",
                    help="e.g. '2x4' -> axes (data, model)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else get_smoke(args.arch)
    t_text = args.seq - cfg.vision_tokens if cfg.family == "vlm" else args.seq

    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split("x"))
        axes = ("data", "model")[:len(dims)]
        mesh = jax.make_mesh(dims, axes,
                             axis_types=(jax.sharding.AxisType.Auto,) * len(dims))
        plan = ParallelPlan(batch_axes=("data",),
                            model_axis="model" if len(dims) > 1 else None)
        ctx = plan.ctx(mesh)
        rules = make_rules(mesh, plan)
    else:
        mesh = rules = None
        ctx = NO_PARALLEL

    print(f"arch={cfg.name} params={count_params(cfg):,} "
          f"steps={args.steps} batch={args.batch}x{args.seq}")

    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    opt_state = adamw.init(params)
    shardings = None
    if rules is not None:
        psh = rules.params(params)
        osh = adamw.OptState(rules.opt_state(params), rules.opt_state(params),
                             NamedSharding(mesh, P()))
        params = jax.device_put(params, psh)
        opt_state = jax.device_put(opt_state, osh)
        shardings = (psh, osh)

    opt_cfg = adamw.AdamWConfig(lr=args.lr, warmup_steps=min(100, args.steps // 10 + 1),
                                total_steps=args.steps)
    step_fn = jax.jit(make_train_step(cfg, ctx, opt_cfg))

    data = SyntheticLM(cfg.vocab_size, args.batch, t_text, seed=args.seed,
                       extra_specs=extra_data_specs(cfg))
    sup = Supervisor(
        SupervisorConfig(ckpt_dir=os.path.join(args.ckpt_dir, cfg.name),
                         ckpt_every=args.ckpt_every,
                         heartbeat_path=os.path.join(args.ckpt_dir, "heartbeat")),
        step_fn, Prefetcher(data), params, opt_state, shardings)

    history = []

    def log(step, metrics, dt):
        if step % args.log_every == 0 or step == 1:
            loss = float(metrics["loss"])
            history.append({"step": step, "loss": loss, "dt": dt})
            print(f"step {step:5d}  loss {loss:.4f}  "
                  f"gnorm {float(metrics.get('grad_norm', 0)):.3f}  {dt*1e3:.0f}ms",
                  flush=True)

    sup.run(args.steps, metrics_cb=log)
    print(f"done. restarts={sup.restarts} stragglers={len(sup.stragglers)}")
    return history


if __name__ == "__main__":
    main()
