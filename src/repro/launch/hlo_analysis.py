"""Post-SPMD HLO analysis: collective inventory and roofline terms.

Parses ``compiled.as_text()`` (optimized, partitioned HLO) and sums the
bytes each collective moves, deriving per-device link traffic under ring
algorithms.  NOTE (measured, see DESIGN.md): both ``cost_analysis()`` and
this text parse count a while-loop (lax.scan) body ONCE — the roofline
harness therefore costs *unrolled per-layer bodies* and multiplies by the
static repeat counts; the whole-program parse here is the collective
*schedule* proof for the dry-run record.

Hardware model (TPU v5e-like, per chip):
  peak bf16 compute  197 TFLOP/s
  HBM bandwidth      819 GB/s
  ICI link bandwidth  50 GB/s (per link; 'pod' axis crossings use DCI and
                      are reported separately)
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Optional

PEAK_FLOPS = 197e12  # bf16 FLOP/s per chip
HBM_BW = 819e9  # B/s per chip
ICI_BW = 50e9  # B/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _type_bytes(type_str: str) -> int:
    """Bytes of 'f32[32,64]{1,0}' or a '(t1, t2)' tuple string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    count: int = 0
    result_bytes: int = 0  # per-device result tensor bytes, summed over ops
    link_bytes: float = 0.0  # ring-model bytes over the busiest link


def parse_collectives(hlo_text: str) -> dict:
    """Inventory of collective ops in (post-partitioning) HLO text.

    Returns {op_kind: CollectiveStats}.  ``link_bytes`` uses ring-algorithm
    per-device traffic: all-reduce 2B(S-1)/S, all-gather/all-to-all
    B(S-1)/S, reduce-scatter B_in(S-1)/S, permute B.
    """
    stats: dict[str, CollectiveStats] = defaultdict(CollectiveStats)
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"(?:ROOT )?%[\w.\-]+ = ((?:\([^)]*\))|(?:\S+)) "
                     r"([\w\-]+)(?:-start)?\(", stripped)
        if not m:
            continue
        kind = m.group(2)
        if kind.endswith("-start"):
            kind = kind[:-6]
        if kind not in _COLLECTIVES:
            continue
        rbytes = _type_bytes(m.group(1))
        gm = _GROUPS_RE.search(stripped)
        if gm:
            group_size = int(gm.group(2))
        else:
            gb = _GROUPS_BRACE_RE.search(stripped)
            group_size = (len(gb.group(1).split(",")) if gb else 1)
        s = max(group_size, 1)
        if kind == "all-reduce":
            link = 2.0 * rbytes * (s - 1) / s
        elif kind == "all-gather":
            link = rbytes * (s - 1) / s  # result is the gathered tensor
        elif kind == "reduce-scatter":
            link = rbytes * (s - 1)  # result is the scattered shard
        elif kind == "all-to-all":
            link = rbytes * (s - 1) / s
        else:  # collective-permute
            link = float(rbytes)
        st = stats[kind]
        st.count += 1
        st.result_bytes += rbytes
        st.link_bytes += link
    return dict(stats)


def collective_summary(hlo_text: str) -> dict:
    """JSON-friendly summary."""
    stats = parse_collectives(hlo_text)
    return {k: {"count": v.count, "result_bytes": v.result_bytes,
                "link_bytes": v.link_bytes} for k, v in stats.items()}


def total_link_bytes(hlo_text: str) -> float:
    return sum(v.link_bytes for v in parse_collectives(hlo_text).values())


def roofline_terms(flops: float, hbm_bytes: float, link_bytes: float,
                   chips: int = 1) -> dict:
    """The three roofline times in seconds (whole-step totals are per-device
    already after SPMD, so ``chips`` stays 1 unless aggregating)."""
    return {
        "compute_s": flops / (chips * PEAK_FLOPS),
        "memory_s": hbm_bytes / (chips * HBM_BW),
        "collective_s": link_bytes / (chips * ICI_BW),
    }


def dominant_term(terms: dict) -> str:
    return max(("compute_s", "memory_s", "collective_s"),
               key=lambda k: terms[k])


def memory_analysis_dict(compiled) -> Optional[dict]:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return None
    if ma is None:
        return None
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes")
    out = {k: int(getattr(ma, k, 0)) for k in keys}
    out["total_bytes_per_device"] = (
        out["argument_size_in_bytes"] + out["output_size_in_bytes"]
        + out["temp_size_in_bytes"] - out["alias_size_in_bytes"])
    return out
