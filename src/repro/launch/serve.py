"""Serving launcher: batched generation with the smoke (or full) config.

``python -m repro.launch.serve --arch rwkv6-7b --batch 4 --new 32``
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.registry import ARCH_IDS, get_config, get_smoke
from ..models import init_params
from ..serving import Engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="smollm-360m")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else get_smoke(args.arch)
    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    rng = np.random.default_rng(args.seed)

    extra = {}
    if cfg.family == "audio":
        extra["frames"] = jnp.asarray(
            rng.normal(size=(args.batch, cfg.encoder.seq_len, cfg.d_model)),
            jnp.float32)
    if cfg.family == "vlm":
        extra["patches"] = jnp.asarray(
            rng.normal(size=(args.batch, cfg.vision_tokens, cfg.d_model)),
            jnp.float32)

    eng = Engine(cfg, params, max_len=args.prompt_len + args.new + 8)
    prompt = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)

    t0 = time.time()
    out = eng.generate(prompt, max_new_tokens=args.new,
                       temperature=args.temperature,
                       rng=jax.random.PRNGKey(args.seed), extra_inputs=extra)
    dt = time.time() - t0
    toks = args.batch * args.new
    print(f"arch={cfg.name} generated {out.shape} in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s incl. compile)")
    print("sample:", np.asarray(out)[0][:16])


if __name__ == "__main__":
    main()
