"""Checkpointing: async, atomic, per-leaf files, elastic restore.

Layout:   <dir>/step_%08d/
            manifest.json       {step, leaves: {flatkey: {shape,dtype,file}},
                                 extra: {...}}       (written LAST)
            <flatkey>.npy       one file per pytree leaf

Guarantees engineered for the 1000-node posture:

* **Atomic** — written into ``step_X.tmp`` then ``os.rename``'d; a manifest
  only exists for complete checkpoints, so a crash mid-save can never
  produce a checkpoint that restores (restore scans for the newest
  directory WITH a manifest).
* **Async** — ``save(...)`` snapshots to host memory (device_get) and
  returns; a writer thread does the I/O.  ``wait()`` joins (tested:
  training continues during the write, bit-exact restore afterwards).
* **Elastic** — leaves are stored unsharded (np arrays); ``restore`` takes
  an optional shardings pytree and ``device_put``s each leaf onto the *new*
  mesh, so a checkpoint saved on mesh A restores onto mesh B (resharding on
  restore is exactly how single-controller JAX deployments rescale).
  On a multi-host deployment each host would read only its shard slices —
  the manifest carries shapes so hosts can index; here (single-process) a
  full read + device_put expresses the same contract.
* **Retention** — keeps the newest ``keep`` checkpoints, deletes older.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _key_str(k) -> str:
    for attr in ("key", "idx", "name"):
        if hasattr(k, attr):
            return str(getattr(k, attr))
    return str(k)


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(_key_str(k) for k in path)
        out[key] = leaf
    return out, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ---- save -------------------------------------------------------------

    def save(self, step: int, tree: Any, extra: Optional[dict] = None,
             block: bool = False):
        """Snapshot now, write in the background (or block=True)."""
        self.wait()  # one outstanding save at a time
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        if block:
            self._write(step, host_tree, extra or {})
            return
        self._thread = threading.Thread(
            target=self._write_guarded, args=(step, host_tree, extra or {}),
            daemon=True)
        self._thread.start()

    def _write_guarded(self, step, host_tree, extra):
        try:
            self._write(step, host_tree, extra)
        except BaseException as e:  # surfaced on next wait()/save()
            self._error = e

    def _write(self, step: int, host_tree, extra: dict):
        name = f"step_{step:08d}"
        tmp = os.path.join(self.dir, name + ".tmp")
        final = os.path.join(self.dir, name)
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        flat, _ = _flatten(host_tree)
        manifest = {"step": step, "extra": extra, "leaves": {}}
        for key, leaf in flat.items():
            fname = key.replace("/", "__") + ".npy"
            np.save(os.path.join(tmp, fname), leaf)
            manifest["leaves"][key] = {
                "shape": list(leaf.shape), "dtype": str(leaf.dtype),
                "file": fname}
        # manifest last: its existence marks completeness
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # ---- restore ------------------------------------------------------------

    def all_steps(self):
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, d, "manifest.json")):
                    out.append(int(d[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target: Any, shardings: Any = None):
        """Restore into the structure of ``target`` (a pytree of arrays or
        ShapeDtypeStructs).  ``shardings``: matching pytree of NamedSharding
        for elastic placement onto the current mesh."""
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        flat_t, treedef = _flatten(target)
        leaves = {}
        for key in flat_t:
            info = manifest["leaves"][key]
            arr = np.load(os.path.join(d, info["file"]))
            leaves[key] = arr
        restored_flat = [leaves[k] for k in flat_t]
        tree = jax.tree.unflatten(treedef, restored_flat)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        return tree, manifest["extra"]

    def restore_latest(self, target: Any, shardings: Any = None):
        step = self.latest_step()
        if step is None:
            return None
        tree, extra = self.restore(step, target, shardings)
        return step, tree, extra
