"""AdamW with warmup-cosine schedule and global-norm clipping, from scratch.

State is a plain pytree {m, v, step} in f32; with ZeRO-1 the sharding rules
place m/v shards over the data axes (GSPMD inserts the gather/scatter that
ZeRO-1 implies around the elementwise update).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moments_dtype: str = "float32"  # 'bfloat16' halves optimizer HBM (the
    # standard large-model memory trade; recorded per-cell in EXPERIMENTS)


class OptState(NamedTuple):
    m: Any
    v: Any
    step: jax.Array


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup -> cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def init(params, moments_dtype="float32") -> OptState:
    dt = jnp.dtype(moments_dtype)
    z = jax.tree.map(lambda p: jnp.zeros(p.shape, dt), params)
    return OptState(m=z, v=jax.tree.map(jnp.copy, z), step=jnp.zeros((), jnp.int32))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def _decay_mask(path) -> bool:
    """No weight decay on norms, biases, scalars-per-channel."""
    last = str(path[-1].key) if hasattr(path[-1], "key") else ""
    return last not in ("scale", "bias", "dt_bias", "conv_b", "u", "w_base",
                        "gn_scale", "gn_bias", "mu_r", "mu_k", "mu_v", "mu_w",
                        "mu_g", "a_log", "d_skip")


def update(cfg: AdamWConfig, grads, state: OptState, params):
    """Returns (new_params, new_state, stats)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    mdt = jnp.dtype(cfg.moments_dtype)

    def upd(path, p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * (g * g)
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if _decay_mask(path):
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                m.astype(mdt), v.astype(mdt))

    flat = jax.tree_util.tree_map_with_path(
        lambda path, p, g, m, v: upd(path, p, g, m, v),
        params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    stats = {"grad_norm": gnorm, "lr": lr}
    return new_params, OptState(new_m, new_v, step), stats
