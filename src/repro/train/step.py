"""Train step builders: fused fwd+bwd+update, with microbatch accumulation.

``make_train_step`` returns a pure function
    (params, opt_state, batch) -> (params, opt_state, metrics)
suitable for jit with in/out shardings from ``parallel.sharding.Rules``.

Gradient accumulation: ``accum_steps > 1`` splits the global batch on axis 0
and lax.scan's the fwd/bwd, summing grads — the standard way to fit a large
global batch per optimizer step (and the hook where pipeline-parallel
microbatching would attach).
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..models import ModelConfig, train_loss
from ..optim import adamw
from ..parallel.ctx import ParallelCtx


def make_loss_fn(cfg: ModelConfig, ctx: ParallelCtx):
    def loss_fn(params, batch):
        return train_loss(cfg, ctx, params, batch)

    return loss_fn


def make_train_step(cfg: ModelConfig, ctx: ParallelCtx,
                    opt_cfg: adamw.AdamWConfig, accum_steps: int = 1):
    loss_fn = make_loss_fn(cfg, ctx)

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return loss, metrics, grads

    def train_step(params, opt_state, batch):
        if accum_steps == 1:
            loss, metrics, grads = grads_of(params, batch)
        else:
            def micro(carry, mb):
                g_acc, l_acc = carry
                loss, _, grads = grads_of(params, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, grads)
                return (g_acc, l_acc + loss), None

            def to_micro(x):
                x = x.reshape((accum_steps, x.shape[0] // accum_steps)
                              + x.shape[1:])
                # keep the per-microbatch batch dim sharded over data axes
                return ctx.shard(x, None, ctx.batch_axes,
                                 *([None] * (x.ndim - 2)))

            micro_batches = jax.tree.map(to_micro, batch)
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), _ = jax.lax.scan(
                micro, (g0, jnp.float32(0)), micro_batches)
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
            loss = loss_sum / accum_steps
            metrics = {"loss": loss}

        new_params, new_opt, stats = adamw.update(
            opt_cfg, grads, opt_state, params)
        metrics = dict(metrics)
        metrics.update(stats)
        return new_params, new_opt, metrics

    return train_step


def make_eval_step(cfg: ModelConfig, ctx: ParallelCtx):
    loss_fn = make_loss_fn(cfg, ctx)

    def eval_step(params, batch):
        loss, metrics = loss_fn(params, batch)
        return metrics

    return eval_step
