"""Cross-pod gradient compression: int8 all-reduce with error feedback.

At 2+ pods the gradient all-reduce crosses the (slow) inter-pod links; the
standard distributed-optimization trick is to quantize the cross-pod leg to
int8 with a per-tensor scale and carry the quantization error into the next
step (error feedback keeps SGD/Adam convergence).  Implemented as a
``shard_map`` over the 'pod' axis: the f32 within-pod reduction stays
untouched (GSPMD handles it as part of backward); only the pod-axis psum
runs on int8 payloads (accumulated in int32 — exact for <=2^23 pods).

Validated in tests/test_compression.py: (a) dequantized psum error is
bounded by the quantization step, (b) error feedback makes the *cumulative*
compressed sum track the true sum.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..parallel.compat import shard_map


def quantize(x: jax.Array):
    """Per-tensor symmetric int8 quantization.  Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-20) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum_leaf(x, axis_name: str):
    """int8-quantized psum over ``axis_name`` (inside shard_map).

    Scales differ per shard, so each shard dequantizes with its own scale
    after an int32 psum of q and a f32 psum of scales... exactness requires
    a shared scale: we psum-max the scale first (one scalar per tensor —
    negligible traffic), then quantize against the shared scale.
    """
    scale = jax.lax.pmax(jnp.max(jnp.abs(x)), axis_name) / 127.0
    scale = jnp.maximum(scale, 1e-20)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    s = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return s.astype(jnp.float32) * scale


def compressed_crosspod_allreduce(grads_stacked, mesh, pod_axis: str = "pod",
                                  error_fb=None):
    """Mean-all-reduce per-pod gradients over the pod axis, int8 payloads +
    error feedback.

    ``grads_stacked`` leaves are (n_pod, ...) — one slice per pod, sharded
    over ``pod_axis`` on axis 0 (each pod's within-pod reduction result).
    ``error_fb`` has the same shape (zeros at step 0).

    Returns (mean_grads (leaves (1, ...), replicated), new_error_fb).
    """
    if error_fb is None:
        error_fb = jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads_stacked)

    n_pod = mesh.shape[pod_axis]

    def leaf_fn(g, e):  # local views: (1, ...)
        x = g.astype(jnp.float32) + e  # error feedback: re-inject residual
        scale = jax.lax.pmax(jnp.max(jnp.abs(x)), pod_axis) / 127.0
        scale = jnp.maximum(scale, 1e-20)
        q = jnp.clip(jnp.round(x / scale), -127, 127)
        new_e = x - q * scale  # residual carried to next step
        summed = jax.lax.psum(q.astype(jnp.int32), pod_axis)
        mean = summed.astype(jnp.float32) * scale / n_pod
        return mean.astype(g.dtype), new_e

    flat, treedef = jax.tree.flatten(grads_stacked)
    eflat, _ = jax.tree.flatten(error_fb)

    def body(gs, es):
        outs = [leaf_fn(g, e) for g, e in zip(gs, es)]
        return tuple(o[0] for o in outs), tuple(o[1] for o in outs)

    pod_spec = lambda x: P(*([pod_axis] + [None] * (x.ndim - 1)))
    rep_spec = lambda x: P(*([None] * x.ndim))
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(tuple(pod_spec(x) for x in flat),
                  tuple(pod_spec(x) for x in eflat)),
        out_specs=(tuple(rep_spec(x) for x in flat),
                   tuple(pod_spec(x) for x in eflat)),
    )
    synced, new_e = fn(tuple(flat), tuple(eflat))
    return (jax.tree.unflatten(treedef, list(synced)),
            jax.tree.unflatten(treedef, list(new_e)))
