from .step import make_eval_step, make_loss_fn, make_train_step  # noqa: F401
from . import compress  # noqa: F401
