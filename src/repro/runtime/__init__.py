from .supervisor import InjectedFailure, Supervisor, SupervisorConfig  # noqa: F401
