"""Fault-tolerant training supervisor: restart-on-failure, stragglers,
heartbeats, failure injection.

``Supervisor.run`` drives the train loop with the posture a 1000-node fleet
needs, scaled down to one process:

* **auto-resume**   — on entry, restores the newest complete checkpoint
  (params, opt state, data-iterator state) and continues from there.
* **restart policy**— a step raising ``InjectedFailure`` (tests) or any
  transient error is retried by restoring the last checkpoint, up to
  ``max_restarts``; training is bit-exact across the restart because the
  data pipeline is counter-based.
* **straggler detection** — per-step wall time feeds an EWMA; steps slower
  than ``straggler_factor`` x EWMA are recorded and surfaced via callback
  (on a fleet this triggers re-dispatch / hot-spare swap; here it feeds the
  tests and metrics).
* **heartbeat**     — a timestamp file is touched every step; an external
  watchdog (or another pod) declares the worker dead when it goes stale.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Any, Callable, Optional

import jax

from ..checkpoint.manager import CheckpointManager


class InjectedFailure(RuntimeError):
    """Raised by failure-injection hooks to simulate a node loss."""


@dataclasses.dataclass
class SupervisorConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    max_restarts: int = 3
    straggler_factor: float = 3.0
    ewma_alpha: float = 0.2
    heartbeat_path: Optional[str] = None


class Supervisor:
    def __init__(self, cfg: SupervisorConfig, train_step: Callable,
                 data_iter, params: Any, opt_state: Any,
                 shardings: Optional[tuple] = None):
        self.cfg = cfg
        self.train_step = train_step
        self.data = data_iter
        self.params = params
        self.opt_state = opt_state
        self.shardings = shardings  # (param_shardings, opt_shardings) or None
        self.ckpt = CheckpointManager(cfg.ckpt_dir)
        self.step = 0
        self.restarts = 0
        self.stragglers: list[tuple[int, float, float]] = []
        self.ewma: Optional[float] = None
        self.on_straggler: Optional[Callable] = None
        self.failure_hook: Optional[Callable[[int], None]] = None  # tests

    # ---- checkpoint glue ----------------------------------------------------

    def _save(self, block=False):
        self.ckpt.save(
            self.step, {"params": self.params, "opt": self.opt_state},
            extra={"step": self.step, "data": self.data.state_dict()},
            block=block)

    def _try_resume(self) -> bool:
        target = {"params": self.params, "opt": self.opt_state}
        sh = None
        if self.shardings is not None:
            sh = {"params": self.shardings[0], "opt": self.shardings[1]}
        res = self.ckpt.restore_latest(target, sh)
        if res is None:
            return False
        step, tree, extra = res
        self.params, self.opt_state = tree["params"], tree["opt"]
        self.data.load_state_dict(extra["data"])
        self.step = int(extra["step"])
        return True

    def _heartbeat(self):
        if self.cfg.heartbeat_path:
            with open(self.cfg.heartbeat_path, "w") as f:
                json.dump({"step": self.step, "t": time.time()}, f)

    # ---- main loop ----------------------------------------------------------

    def run(self, num_steps: int, metrics_cb: Optional[Callable] = None):
        if not self._try_resume():
            self._save(block=True)  # guaranteed restore point at step 0
        while self.step < num_steps:
            try:
                self._run_span(num_steps, metrics_cb)
            except InjectedFailure:
                self.restarts += 1
                if self.restarts > self.cfg.max_restarts:
                    raise
                self.ckpt.wait()
                assert self._try_resume(), "no checkpoint to restart from"
        self.ckpt.wait()
        return self.params, self.opt_state

    def _run_span(self, num_steps: int, metrics_cb):
        for batch in self.data:
            if self.step >= num_steps:
                return
            if self.failure_hook is not None:
                self.failure_hook(self.step)  # may raise InjectedFailure
            t0 = time.perf_counter()
            self.params, self.opt_state, metrics = self.train_step(
                self.params, self.opt_state, batch)
            jax.block_until_ready(metrics)
            dt = time.perf_counter() - t0
            self._track_straggler(dt)
            self.step += 1
            self._heartbeat()
            if metrics_cb:
                metrics_cb(self.step, metrics, dt)
            if self.step % self.cfg.ckpt_every == 0:
                self._save()
        # data exhausted
        return

    def _track_straggler(self, dt: float):
        if self.ewma is None:
            self.ewma = dt
            return
        if dt > self.cfg.straggler_factor * self.ewma:
            self.stragglers.append((self.step, dt, self.ewma))
            if self.on_straggler:
                self.on_straggler(self.step, dt, self.ewma)
        a = self.cfg.ewma_alpha
        self.ewma = (1 - a) * self.ewma + a * dt
