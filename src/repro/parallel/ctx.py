"""Parallelism context: logical-axis sharding threaded through the models.

One small immutable object carries everything the model stack needs to know
about the mesh.  ``ctx.shard(x, *axes)`` places a ``with_sharding_constraint``
using *logical* axis names resolved against the mesh; with no mesh (unit
tests, single-CPU smoke) every call is an identity, so model code is written
once and runs anywhere.

Logical activation axes used by the model stack:

  batch   -> ctx.batch_axes      (('pod','data') on the multi-pod mesh)
  seq     -> ctx.seq_axis        (None normally; 'data' for batch=1
                                  long-context decode, sharding the KV cache
                                  and attention across the pod)
  heads / d_ff / experts / vocab -> ctx.model_axis  (tensor parallel)
  d_model -> replicated

Weight sharding is decided by rules in ``parallel.sharding`` (not here) so
the dry-run can build param shardings without instantiating the model.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ParallelCtx:
    mesh: Optional[Mesh] = None
    batch_axes: Tuple[str, ...] = ("data",)
    model_axis: Optional[str] = "model"
    seq_axis: Optional[str] = None  # shard sequence/KV (long-context decode)
    fsdp_axes: Tuple[str, ...] = ()  # extra axes sharding big weight matrices

    # ---- helpers ---------------------------------------------------------

    @property
    def model_size(self) -> int:
        if self.mesh is None or self.model_axis is None:
            return 1
        return self.mesh.shape[self.model_axis]

    def axis_ok(self, axis, size: int) -> bool:
        """Can dimension of ``size`` be sharded over ``axis``?"""
        if self.mesh is None or axis is None:
            return False
        if isinstance(axis, str):
            n = self.mesh.shape[axis]
        else:
            n = 1
            for a in axis:
                n *= self.mesh.shape[a]
        return size % n == 0

    def spec(self, *axes) -> P:
        return P(*axes)

    def shard(self, x, *axes):
        """Constrain ``x`` to PartitionSpec(*axes); identity without a mesh.

        ``axes`` entries are mesh axis names / tuples / None, one per dim.
        Dims whose size does not divide the axis fall back to replicated.
        """
        if self.mesh is None:
            return x
        fixed = []
        used: set = set()  # a mesh axis may appear in at most one dim
        for d, a in enumerate(axes):
            names = () if a is None else ((a,) if isinstance(a, str) else tuple(a))
            if (a is not None and not (used & set(names))
                    and self.axis_ok(a, x.shape[d])):
                fixed.append(a)
                used.update(names)
            else:
                fixed.append(None)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*fixed)))

    # Activation conventions -------------------------------------------------

    def act_btd(self, x):
        """(batch, seq, d_model): batch over data axes, d_model replicated."""
        return self.shard(x, self.batch_axes, self.seq_axis, None)

    def act_bthd(self, x):
        """(batch, seq, heads, head_dim): heads tensor-parallel."""
        return self.shard(x, self.batch_axes, None, self.model_axis, None)

    def act_btf(self, x):
        """(batch, seq, d_ff): feed-forward hidden tensor-parallel."""
        return self.shard(x, self.batch_axes, self.seq_axis, self.model_axis)

    def act_btv(self, x):
        """(batch, seq, vocab): vocab (logit) tensor-parallel."""
        return self.shard(x, self.batch_axes, None, self.model_axis)

    def act_recurrent(self, x, *trailing):
        """(batch, seq, ...) operand entering a time-recurrent scan (Mamba
        SSM, RWKV wkv): the sequence axis must be *gathered*.  A recurrence
        partitioned over time is collective-bound, and the partitioned
        scan lowering miscompiles on older XLA (observed on jaxlib 0.4.36
        CPU: interior positions of each seq shard combine the wrong
        prefix).  Batch stays sharded; ``trailing`` gives the specs of the
        dims after seq (pass ``self.model_axis`` for tensor-parallel dims
        so only the time axis is gathered); unspecified dims replicate.
        """
        trailing = trailing + (None,) * (x.ndim - 2 - len(trailing))
        return self.shard(x, self.batch_axes, None, *trailing)

    def kv_cache(self, x):
        """(batch, s_max, kv_heads, head_dim) KV cache; seq sharded when
        ``seq_axis`` is set (long-context decode), else heads TP."""
        if self.seq_axis is not None:
            return self.shard(x, self.batch_axes, self.seq_axis, None, None)
        return self.shard(x, self.batch_axes, None, self.model_axis, None)


NO_PARALLEL = ParallelCtx()
