"""Version-tolerant aliases for jax APIs that moved between 0.4.x and 0.5+.

``jax.shard_map`` was promoted out of ``jax.experimental.shard_map`` after
0.4.x; the keyword signature (``mesh=, in_specs=, out_specs=``) is identical
in both homes, so a simple alias suffices.  The test-side twin of this shim
is ``tests/conftest.py:make_test_mesh`` (for ``jax.sharding.AxisType``).
"""
from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map  # noqa: F401
