"""Version-tolerant aliases for jax APIs that moved between 0.4.x and 0.5+.

``jax.shard_map`` was promoted out of ``jax.experimental.shard_map`` after
0.4.x; the keyword signature (``mesh=, in_specs=, out_specs=``) is identical
in both homes, so a simple alias suffices.  The test-side twin of this shim
is ``tests/conftest.py:make_test_mesh`` (for ``jax.sharding.AxisType``).
"""
from __future__ import annotations

import jax
import numpy as np

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map  # noqa: F401


def shard_map_unchecked(f, mesh, in_specs, out_specs):
    """``shard_map`` with replication checking off, version-tolerantly.

    The dispatch layer closes over replicated operands (BVH arrays, vector
    databases) instead of threading them as explicit arguments; the
    replication checker flags such closures on some jax versions.  The
    disable knob was renamed ``check_rep`` -> ``check_vma`` when shard_map
    was promoted, so feature-probe both before falling back to checked.
    """
    for kw in ("check_rep", "check_vma"):
        try:
            return shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **{kw: False})
        except TypeError:
            continue
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)


def make_device_mesh(n_devices: int, axis_name: str = "shards"):
    """A 1-D mesh over the first ``n_devices`` local devices.

    Source-side twin of ``tests/conftest.py:make_test_mesh``: jax >= 0.5
    wants ``axis_types=``, 0.4.x predates it (every axis implicitly Auto).
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh((n_devices,), (axis_name,),
                             axis_types=(axis_type.Auto,))
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh((n_devices,), (axis_name,))
    devices = np.asarray(jax.devices()[:n_devices])
    return jax.sharding.Mesh(devices, (axis_name,))
