"""Weight / optimizer / batch / cache sharding rules (GSPMD PartitionSpecs).

Rules are *name-and-shape* driven over the params pytree, with divisibility
fallbacks (a head count that does not divide the TP axis degrades that
matrix to replicated — e.g. smollm's 15 heads, MQA's single KV head — and
the rule engine records what fell back, so EXPERIMENTS.md can report it).

Layout recap (leading ``R`` = stacked scan axis, never sharded):
  attention   wq (R,D,H,hd): heads->model     wo (R,H,hd,D): heads->model
              wk/wv (R,D,Hkv,hd): kv->model when divisible else replicated
  MLA         wuq/wuk/wuv: heads->model; latent projections replicated
  MLP         wi/wg (R,D,F): F->model         wo (R,F,D): F->model
  MoE         wi/wg/wo (R,E,D,F): E->model (expert parallelism)
  mamba       d_inner->model everywhere it appears
  rwkv        square mixers: col-parallel in, row-parallel out
  embed       (V,D): V->model                 unembed (D,V): V->model
  FSDP        optionally shard D (or the largest free axis) over data axes
  ZeRO-1      optimizer moments additionally sharded over data axes
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .ctx import ParallelCtx


@dataclasses.dataclass(frozen=True)
class ParallelPlan:
    """Everything the launcher decides about distribution for one cell."""

    batch_axes: Tuple[str, ...] = ("data",)
    model_axis: Optional[str] = "model"
    seq_axis: Optional[str] = None  # long-context decode: shard KV sequence
    fsdp_axes: Tuple[str, ...] = ()  # shard params over data axes too
    zero1: bool = True  # shard optimizer moments over data axes
    remat: str = "block"
    accum_steps: int = 1  # gradient-accumulation microbatches
    moments_dtype: str = "float32"  # optimizer moments precision

    def ctx(self, mesh: Mesh) -> ParallelCtx:
        return ParallelCtx(mesh=mesh, batch_axes=self.batch_axes,
                           model_axis=self.model_axis, seq_axis=self.seq_axis,
                           fsdp_axes=self.fsdp_axes)


def _axsize(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    return int(np.prod([mesh.shape[a] for a in axes])) if axes else 1


def _leaf_name(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
    return "/".join(parts)


class Rules:
    """Param PartitionSpec assignment with divisibility fallbacks."""

    def __init__(self, mesh: Mesh, plan: ParallelPlan):
        self.mesh = mesh
        self.plan = plan
        self.tp = _axsize(mesh, plan.model_axis)
        self.fsdp = _axsize(mesh, plan.fsdp_axes) if plan.fsdp_axes else 1
        self.fallbacks: list[str] = []

    def _tp(self, size: int, name: str):
        if self.plan.model_axis and size % self.tp == 0 and self.tp > 1:
            return self.plan.model_axis
        if self.tp > 1:
            self.fallbacks.append(f"{name}: dim {size} !% tp {self.tp}")
        return None

    def _fsdp(self, size: int):
        if self.plan.fsdp_axes and size % self.fsdp == 0 and self.fsdp > 1:
            return self.plan.fsdp_axes
        return None

    def param_spec(self, path, leaf) -> P:
        name = _leaf_name(path)
        base = name.rsplit("/", 1)[-1]
        shape = leaf.shape
        nd = len(shape)
        in_segment = "segments" in name or "block" in name
        off = 1 if in_segment else 0  # leading stacked-repeat axis

        def pad(spec_tail):
            return P(*([None] * off + spec_tail + [None] * (nd - off - len(spec_tail))))

        d = shape[off] if nd > off else 0
        if base == "wq" and nd - off == 3:  # (D, H, hd)
            return pad([self._fsdp(d), self._tp(shape[off + 1], name), None])
        if base in ("wk", "wv") and nd - off == 3:  # (D, Hkv, hd)
            return pad([self._fsdp(d), self._tp(shape[off + 1], name), None])
        if base == "wo" and nd - off == 3:  # (H, hd, D) attention out
            return pad([self._tp(shape[off], name), None, self._fsdp(shape[off + 2])])
        if base in ("wuq", "wuk", "wuv"):  # MLA up: (rank, H, hd)
            return pad([None, self._tp(shape[off + 1], name), None])
        if base in ("wdq", "wdkv"):  # MLA down: (D, rank)
            return pad([self._fsdp(d), None])
        if base in ("wi", "wg") and nd - off == 3:  # MoE experts (E, D, F)
            return pad([self._tp(shape[off], name), self._fsdp(shape[off + 1]), None])
        if base == "wo" and nd - off == 3 and "ffn" in name:  # handled above
            return pad([self._tp(shape[off], name), None, None])
        if base in ("wi", "wg"):  # MLP (D, F)
            return pad([self._fsdp(d), self._tp(shape[off + 1], name)])
        if base == "wo" and nd - off == 2:  # MLP out (F, D)
            return pad([self._tp(d, name), self._fsdp(shape[off + 1])])
        if base == "router":  # (E, D) expert embeddings: small, replicate
            return pad([None, None])
        if base in ("shared_wi", "shared_wg"):
            return pad([self._fsdp(d), self._tp(shape[off + 1], name)])
        if base == "shared_wo":
            return pad([self._tp(d, name), self._fsdp(shape[off + 1])])
        # mamba
        if base == "in_proj":
            return pad([self._fsdp(d), self._tp(shape[off + 1], name)])
        if base == "conv_w":
            return pad([None, self._tp(shape[off + 1], name)])
        if base in ("conv_b", "dt_bias", "d_skip"):
            return pad([self._tp(d, name)])
        if base == "x_proj":
            return pad([self._tp(d, name), None])
        if base == "dt_proj":
            return pad([None, self._tp(shape[off + 1], name)])
        if base == "a_log":
            return pad([self._tp(d, name), None])
        if base == "out_proj":
            return pad([self._tp(d, name), self._fsdp(shape[off + 1])])
        # rwkv square mixers: col-parallel r/k/v/g, row-parallel o
        if base in ("wr", "wk", "wv", "wg") and nd - off == 2 and "ffn" not in name:
            return pad([self._fsdp(d), self._tp(shape[off + 1], name)])
        if base == "wo" and nd - off == 2:
            return pad([self._tp(d, name), self._fsdp(shape[off + 1])])
        if base in ("wk",) and "ffn" in name:  # rwkv channel-mix (D, F)
            return pad([self._fsdp(d), self._tp(shape[off + 1], name)])
        if base in ("wv",) and "ffn" in name:  # (F, D)
            return pad([self._tp(d, name), self._fsdp(shape[off + 1])])
        # embeddings
        if base == "tok":
            return P(self._tp(shape[0], name), None)
        if base == "unembed":
            return P(self._fsdp(shape[0]), self._tp(shape[1], name))
        if base == "proj" and "mtp" in name:
            return P(self._fsdp(shape[0]), None)
        # norms, biases, vectors: replicated
        return P(*([None] * nd))

    # ---- public builders ---------------------------------------------------

    def params(self, params_tree) -> Any:
        return jax.tree_util.tree_map_with_path(
            lambda p, x: NamedSharding(self.mesh, self.param_spec(p, x)),
            params_tree)

    def opt_state(self, params_tree) -> Any:
        """ZeRO-1: moments get the param spec plus 'data' on the first free,
        divisible axis."""
        def spec(path, leaf):
            ps = self.param_spec(path, leaf)
            if not self.plan.zero1:
                return NamedSharding(self.mesh, ps)
            parts = list(ps) + [None] * (len(leaf.shape) - len(ps))
            # axes already consumed by the param spec (TP and/or FSDP) can't
            # be reused on another dim of the same tensor
            used = set()
            for p_ in parts:
                if p_ is None:
                    continue
                used.update(p_ if isinstance(p_, tuple) else (p_,))
            dp = tuple(a for a in self.plan.batch_axes if a not in used)
            dp_size = _axsize(self.mesh, dp) if dp else 1
            for i, (cur, dim) in enumerate(zip(parts, leaf.shape)):
                if cur is None and dp_size > 1 and dim % dp_size == 0:
                    parts[i] = dp if len(dp) > 1 else dp[0]
                    break
            return NamedSharding(self.mesh, P(*parts))

        return jax.tree_util.tree_map_with_path(spec, params_tree)

    def batch(self, batch_tree) -> Any:
        def spec(_, leaf):
            parts = [None] * leaf.ndim
            if leaf.shape[0] % _axsize(self.mesh, self.plan.batch_axes) == 0:
                parts[0] = self.plan.batch_axes
            return NamedSharding(self.mesh, P(*parts))

        return jax.tree_util.tree_map_with_path(spec, batch_tree)

    def cache(self, cache_tree) -> Any:
        """Decode cache: batch over data axes; KV seq over seq_axis (long
        decode) else kv-heads over model; SSM states: d_inner over model."""
        bsz_axes = self.plan.batch_axes

        def spec(path, leaf):
            name = _leaf_name(path).rsplit("/", 1)[-1]
            nd = leaf.ndim
            parts: list = [None] * nd
            if nd == 0:
                return NamedSharding(self.mesh, P())
            # leading stacked-layer axis for seg caches: (R, B, ...)
            boff = 1 if "segs" in _leaf_name(path) else 0
            if nd > boff and leaf.shape[boff] % _axsize(self.mesh, bsz_axes) == 0:
                parts[boff] = bsz_axes
            if name in ("k", "v", "ck", "cv", "ckv", "krope"):
                if self.plan.seq_axis and nd > boff + 1 and (
                        leaf.shape[boff + 1] % _axsize(self.mesh, self.plan.seq_axis) == 0):
                    parts[boff + 1] = self.plan.seq_axis
                elif name in ("k", "v", "ck", "cv") and nd > boff + 2:
                    h = leaf.shape[boff + 2]
                    if self.plan.model_axis and h % self.tp == 0 and self.tp > 1:
                        parts[boff + 2] = self.plan.model_axis
            if name in ("conv", "ssm") and nd > boff + 1:
                # (B, K-1, Din) / (B, Din, N): shard Din over model
                din_ax = boff + 2 if name == "conv" else boff + 1
                if din_ax < nd and leaf.shape[din_ax] % self.tp == 0 and self.tp > 1:
                    parts[din_ax] = self.plan.model_axis
            if name == "s" and nd >= boff + 4:  # rwkv (B, H, K, V)
                if leaf.shape[boff + 1] % self.tp == 0 and self.tp > 1:
                    parts[boff + 1] = self.plan.model_axis
            if name == "enc_h":
                parts = [None] * nd
                if leaf.shape[0] % _axsize(self.mesh, bsz_axes) == 0:
                    parts[0] = bsz_axes
            return NamedSharding(self.mesh, P(*parts))

        return jax.tree_util.tree_map_with_path(spec, cache_tree)


def make_rules(mesh: Mesh, plan: ParallelPlan) -> Rules:
    return Rules(mesh, plan)


# ---------------------------------------------------------------------------
# Generic tree placement (used by the query dispatch layer, core/dispatch.py)
# ---------------------------------------------------------------------------


def replicated(mesh: Mesh, tree) -> Any:
    """Place every leaf fully replicated across ``mesh`` (the query layer's
    scene/index placement: one copy of the BVH / database per device)."""
    return jax.device_put(tree, NamedSharding(mesh, P()))


def batch_sharded(mesh: Mesh, tree, axis: str = "shards") -> Any:
    """Shard every leaf's leading (batch) axis over ``axis`` — the
    data-parallel ray/query placement.  Leading dims must divide the axis
    size (the dispatch layer pads them first)."""
    return jax.device_put(tree, NamedSharding(mesh, P(axis)))
