from .ctx import NO_PARALLEL, ParallelCtx  # noqa: F401
from .sharding import ParallelPlan, Rules, make_rules  # noqa: F401
