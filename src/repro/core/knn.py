"""Generalized distance modes as large-scale vector search (kNN / retrieval).

The paper's OpEuclidean/OpAngular process one vector pair per beat on the
VPU-equivalent lanes.  On TPU the profitable mapping of the *same* math is
matmul-shaped so it runs on the MXU (DESIGN.md §2):

    ||q - c||^2 = ||q||^2 + ||c||^2 - 2 q.c          (Euclidean mode)
    scores      = Q @ C^T,  norms = rowsum(C*C)      (angular mode)

Both forms are exposed here, plus a beat-exact path through
``repro.core.datapath`` for parity testing, plus the Pallas kernel path
(``repro.kernels.distance``) for the tiled/accumulated version that mirrors
the hardware's multi-beat accumulator.

Structure (DESIGN.md §5): every query is *score computation* followed by
*selection*.  ``pairwise_scores`` produces the (M, N) score matrix for any
metric; ``select_topk`` / ``select_within`` / ``count_within_scores`` are
the selection epilogues.  The free functions below (``knn``,
``radius_search``, ...) compose the two and stay the oracle API; the
session layer (``repro.core.session``) reuses the same pieces with
precomputed candidate norms (``c_sq_norms``) so ``||c||^2`` is paid once
per index instead of once per query batch.

This module is what the MoE routers call: router logits are OpAngular jobs
(query = token activation, candidates = expert embeddings).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

METRICS = ("euclidean", "angular", "cosine")
RADIUS_METRICS = ("euclidean", "cosine")


# ---------------------------------------------------------------------------
# Eager query-parameter validation (shared by the free functions and the
# session layer, so every entry point rejects bad parameters identically)
# ---------------------------------------------------------------------------


def check_k(k) -> int:
    """Validate a top-k slot count eagerly.

    ``k`` must be a positive int; it is *not* required to be <= the
    candidate count — :func:`select_topk` / :func:`select_within` clamp
    internally and pad the excess slots (a ``k > N`` used to surface as a
    cryptic ``lax.top_k`` shape error mid-trace, and ``k <= 0`` silently
    produced zero-width results).
    """
    k = int(k)
    if k <= 0:
        raise ValueError(f"k must be >= 1, got {k}")
    return k


def check_radius(radius, metric: str = "euclidean") -> float:
    """Validate a query radius eagerly, naming the offending value.

    NaN never compares true, so an unvalidated NaN radius silently
    returned empty results from every radius query; a negative euclidean
    radius was squared away into ``|radius|``.  Both now raise.  Cosine
    radii are *minimum similarities*, so any non-NaN value (including
    negatives: "at least -0.5 similar") is legal there.
    """
    r = float(radius)
    if math.isnan(r):
        raise ValueError(f"radius must not be NaN (got {radius!r})")
    if metric == "euclidean" and r < 0.0:
        raise ValueError(
            f"euclidean radius must be >= 0, got {r} (distances are "
            "non-negative, so a negative radius can match nothing)")
    return r


def _pad_slots(x: jax.Array, k: int, fill) -> jax.Array:
    """Pad the trailing top-k axis from ``min(k, N)`` back out to ``k``."""
    pad = k - x.shape[-1]
    if pad == 0:
        return x
    return jnp.concatenate(
        [x, jnp.full(x.shape[:-1] + (pad,), fill, x.dtype)], axis=-1)


def squared_norms(x: jax.Array) -> jax.Array:
    """Row-wise ||x||^2 — the OpAngular norm output.  (N, D) -> (N,)."""
    x = x.astype(jnp.float32)
    return jnp.sum(x * x, axis=-1)


def euclidean_scores(queries: jax.Array, database: jax.Array,
                     precision=jax.lax.Precision.HIGHEST, *,
                     c_sq_norms: jax.Array | None = None) -> jax.Array:
    """Pairwise squared Euclidean distances, MXU form.  (M,D),(N,D) -> (M,N).

    ``c_sq_norms`` optionally supplies precomputed ``||c||^2`` (a
    ``VectorIndex`` owns them); omitted, they are derived inline.
    """
    q = queries.astype(jnp.float32)
    c = database.astype(jnp.float32)
    q2 = jnp.sum(q * q, axis=-1, keepdims=True)  # (M, 1)
    c2 = squared_norms(c) if c_sq_norms is None else c_sq_norms  # (N,)
    qc = jnp.dot(q, c.T, precision=precision)  # (M, N) on the MXU
    return jnp.maximum(q2 - 2.0 * qc + c2[None, :], 0.0)


def angular_scores(queries: jax.Array, database: jax.Array,
                   precision=jax.lax.Precision.HIGHEST, *,
                   c_sq_norms: jax.Array | None = None):
    """OpAngular outputs for all pairs: (Q.C^T, ||c||^2).  (M,D),(N,D).

    Zero-norm vectors are unproblematic here (their dots and norms are
    simply 0 — nothing divides); only the cosine normalization needs the
    zero-norm convention, applied in :func:`cosine_epilogue`."""
    q = queries.astype(jnp.float32)
    c = database.astype(jnp.float32)
    dots = jnp.dot(q, c.T, precision=precision)  # (M, N)
    norms = squared_norms(c) if c_sq_norms is None else c_sq_norms  # (N,)
    return dots, norms


def cosine_epilogue(dots: jax.Array, c_sq_norms: jax.Array,
                    queries: jax.Array) -> jax.Array:
    """The external-divider epilogue of Eq. (8): dot / (||q|| ||c||).
    One definition of the normalization (incl. the 1e-30 clamp) shared by
    every backend that produces (dots, ||c||^2) pairs.

    Zero-norm convention: a pair involving a zero-norm vector (either
    side; "zero" meaning the squared norm underflows to 0.0 in f32) has
    no defined angle, so its similarity is pinned to ``-inf`` — such rows
    rank strictly *last* under ``top_k`` and never satisfy a
    minimum-similarity radius.  The raw division produced 0/eps garbage
    (and NaN without the clamp) that ``top_k`` happily sorted first.
    """
    q_sq = jnp.sum(queries.astype(jnp.float32) ** 2, axis=-1)
    denom = jnp.maximum(
        jnp.sqrt(q_sq)[:, None] * jnp.sqrt(c_sq_norms)[None, :], 1e-30)
    degenerate = (q_sq == 0.0)[:, None] | (c_sq_norms == 0.0)[None, :]
    return jnp.where(degenerate, -jnp.inf, dots / denom)


def cosine_similarity(queries: jax.Array, database: jax.Array, *,
                      c_sq_norms: jax.Array | None = None,
                      precision=jax.lax.Precision.HIGHEST) -> jax.Array:
    """Full cosine-similarity matrix: OpAngular outputs + external divider.

    Rows/columns with zero norm score ``-inf`` (rank strictly last, never
    in any radius) rather than NaN — see :func:`cosine_epilogue`."""
    dots, c_norms = angular_scores(queries, database, precision,
                                   c_sq_norms=c_sq_norms)
    return cosine_epilogue(dots, c_norms, queries)


def pairwise_scores(queries: jax.Array, database: jax.Array,
                    metric: str = "euclidean", *,
                    c_sq_norms: jax.Array | None = None,
                    precision=jax.lax.Precision.HIGHEST) -> jax.Array:
    """The (M, N) score matrix for any metric: squared distances for
    ``euclidean`` (lower = closer), similarities for ``angular``/``cosine``
    (higher = closer)."""
    if metric == "euclidean":
        return euclidean_scores(queries, database, precision,
                                c_sq_norms=c_sq_norms)
    if metric == "angular":
        return angular_scores(queries, database, precision,
                              c_sq_norms=c_sq_norms)[0]
    if metric == "cosine":
        return cosine_similarity(queries, database, c_sq_norms=c_sq_norms,
                                 precision=precision)
    raise ValueError(f"unknown metric: {metric} (want one of {METRICS})")


# ---------------------------------------------------------------------------
# Selection epilogues (shared by the free functions and the session API)
# ---------------------------------------------------------------------------


def select_topk(scores: jax.Array, k: int, metric: str = "euclidean"):
    """Top-k selection on a score matrix: ascending for euclidean distances,
    descending for angular/cosine similarities.  Returns (scores, indices).

    ``k`` is clamped to the candidate count N: slots past N pad with the
    metric's worst score (+inf distance / -inf similarity) and index
    ``-1``, so over-asking never crashes inside ``lax.top_k`` — callers
    needing a validity mask use ``indices >= 0``.  ``k <= 0`` raises."""
    k = check_k(k)
    kk = min(k, scores.shape[-1])
    if metric == "euclidean":
        neg, idx = jax.lax.top_k(-scores, kk)
        out, fill = -neg, jnp.inf
    else:
        out, idx = jax.lax.top_k(scores, kk)
        fill = -jnp.inf
    return _pad_slots(out, k, fill), _pad_slots(idx, k, -1)


def select_within(scores: jax.Array, radius: float, k: int,
                  metric: str = "euclidean"):
    """Range-limited top-k: the best k candidates inside the radius.
    Returns (scores, indices, within) — ``within`` marks which of the k
    slots actually fall inside the radius.

    ``k`` clamps to the candidate count exactly as in :func:`select_topk`
    (padded slots carry ``within=False`` and index ``-1``); ``radius`` is
    validated per :func:`check_radius`."""
    k = check_k(k)
    radius = check_radius(radius, metric)
    kk = min(k, scores.shape[-1])
    if metric == "euclidean":
        inside = scores <= radius * radius
        neg, idx = jax.lax.top_k(jnp.where(inside, -scores, -jnp.inf), kk)
        out, within, fill = -neg, jnp.isfinite(neg), jnp.inf
    elif metric == "cosine":
        inside = scores >= radius
        out, idx = jax.lax.top_k(jnp.where(inside, scores, -jnp.inf), kk)
        within, fill = jnp.isfinite(out), -jnp.inf
    else:
        raise ValueError(
            f"unknown radius metric: {metric} (want one of {RADIUS_METRICS})")
    return (_pad_slots(out, k, fill), _pad_slots(idx, k, -1),
            _pad_slots(within, k, False))


def count_within_scores(scores: jax.Array, radius: float,
                        metric: str = "euclidean") -> jax.Array:
    """Number of candidates inside the radius, per query row.  (M,N)->(M,)."""
    radius = check_radius(radius, metric)
    if metric == "euclidean":
        inside = scores <= radius * radius
    elif metric == "cosine":
        inside = scores >= radius
    else:
        raise ValueError(
            f"unknown radius metric: {metric} (want one of {RADIUS_METRICS})")
    return jnp.sum(inside, axis=-1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Free-function oracle API (score + select composed per call)
# ---------------------------------------------------------------------------


def radius_search(queries: jax.Array, database: jax.Array, radius: float,
                  k: int, metric: str = "euclidean", *,
                  c_sq_norms: jax.Array | None = None):
    """Fixed-radius neighbor query: up to ``k`` neighbors within ``radius``.

    This is the vector-search twin of the traversal engine's extent-limited
    shadow rays (``repro.core.wavefront``): just as a shadow ray accepts any
    hit with ``t <= extent``, a radius query accepts any candidate with
    distance <= radius — the RTNN mapping of neighbor search onto
    ray-tracing-style range-limited queries.

    Returns ``(scores, indices, within)``: ``scores``/``indices`` are the
    (padded) top-k by proximity, ``within`` marks which of the k actually
    fall inside the radius.  ``scores`` are squared distances for euclidean
    (ascending) and similarities for cosine (descending, ``radius`` is the
    minimum similarity).
    """
    if metric not in RADIUS_METRICS:
        raise ValueError(f"unknown radius_search metric: {metric}")
    scores = pairwise_scores(queries, database, metric, c_sq_norms=c_sq_norms)
    return select_within(scores, radius, k, metric)


def radius_count(queries: jax.Array, database: jax.Array, radius: float,
                 metric: str = "euclidean", *,
                 c_sq_norms: jax.Array | None = None) -> jax.Array:
    """Number of database points within ``radius`` of each query (the
    occlusion-test analogue: "does anything fall inside the extent" plus
    multiplicity).  (M, D), (N, D) -> (M,) i32."""
    if metric not in RADIUS_METRICS:
        raise ValueError(f"unknown radius_count metric: {metric}")
    scores = pairwise_scores(queries, database, metric, c_sq_norms=c_sq_norms)
    return count_within_scores(scores, radius, metric)


def knn(queries: jax.Array, database: jax.Array, k: int,
        metric: str = "euclidean", *, c_sq_norms: jax.Array | None = None):
    """Exact k-nearest-neighbour search on the datapath's distance modes.

    Returns (scores, indices) with scores ascending for euclidean and
    descending (most similar first) for angular/cosine.
    """
    if metric not in METRICS:
        raise ValueError(f"unknown metric: {metric}")
    scores = pairwise_scores(queries, database, metric, c_sq_norms=c_sq_norms)
    return select_topk(scores, k, metric)
