"""Generalized distance modes as large-scale vector search (kNN / retrieval).

The paper's OpEuclidean/OpAngular process one vector pair per beat on the
VPU-equivalent lanes.  On TPU the profitable mapping of the *same* math is
matmul-shaped so it runs on the MXU (DESIGN.md §2):

    ||q - c||^2 = ||q||^2 + ||c||^2 - 2 q.c          (Euclidean mode)
    scores      = Q @ C^T,  norms = rowsum(C*C)      (angular mode)

Both forms are exposed here, plus a beat-exact path through
``repro.core.datapath`` for parity testing, plus the Pallas kernel path
(``repro.kernels.distance``) for the tiled/accumulated version that mirrors
the hardware's multi-beat accumulator.

Structure (DESIGN.md §5): every query is *score computation* followed by
*selection*.  ``pairwise_scores`` produces the (M, N) score matrix for any
metric; ``select_topk`` / ``select_within`` / ``count_within_scores`` are
the selection epilogues.  The free functions below (``knn``,
``radius_search``, ...) compose the two and stay the oracle API; the
session layer (``repro.core.session``) reuses the same pieces with
precomputed candidate norms (``c_sq_norms``) so ``||c||^2`` is paid once
per index instead of once per query batch.

This module is what the MoE routers call: router logits are OpAngular jobs
(query = token activation, candidates = expert embeddings).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

METRICS = ("euclidean", "angular", "cosine")
RADIUS_METRICS = ("euclidean", "cosine")


def squared_norms(x: jax.Array) -> jax.Array:
    """Row-wise ||x||^2 — the OpAngular norm output.  (N, D) -> (N,)."""
    x = x.astype(jnp.float32)
    return jnp.sum(x * x, axis=-1)


def euclidean_scores(queries: jax.Array, database: jax.Array,
                     precision=jax.lax.Precision.HIGHEST, *,
                     c_sq_norms: jax.Array | None = None) -> jax.Array:
    """Pairwise squared Euclidean distances, MXU form.  (M,D),(N,D) -> (M,N).

    ``c_sq_norms`` optionally supplies precomputed ``||c||^2`` (a
    ``VectorIndex`` owns them); omitted, they are derived inline.
    """
    q = queries.astype(jnp.float32)
    c = database.astype(jnp.float32)
    q2 = jnp.sum(q * q, axis=-1, keepdims=True)  # (M, 1)
    c2 = squared_norms(c) if c_sq_norms is None else c_sq_norms  # (N,)
    qc = jnp.dot(q, c.T, precision=precision)  # (M, N) on the MXU
    return jnp.maximum(q2 - 2.0 * qc + c2[None, :], 0.0)


def angular_scores(queries: jax.Array, database: jax.Array,
                   precision=jax.lax.Precision.HIGHEST, *,
                   c_sq_norms: jax.Array | None = None):
    """OpAngular outputs for all pairs: (Q.C^T, ||c||^2).  (M,D),(N,D)."""
    q = queries.astype(jnp.float32)
    c = database.astype(jnp.float32)
    dots = jnp.dot(q, c.T, precision=precision)  # (M, N)
    norms = squared_norms(c) if c_sq_norms is None else c_sq_norms  # (N,)
    return dots, norms


def cosine_epilogue(dots: jax.Array, c_sq_norms: jax.Array,
                    queries: jax.Array) -> jax.Array:
    """The external-divider epilogue of Eq. (8): dot / (||q|| ||c||).
    One definition of the normalization (incl. the 1e-30 clamp) shared by
    every backend that produces (dots, ||c||^2) pairs."""
    q_norms = jnp.sqrt(jnp.sum(queries.astype(jnp.float32) ** 2, axis=-1))
    denom = jnp.maximum(
        q_norms[:, None] * jnp.sqrt(c_sq_norms)[None, :], 1e-30)
    return dots / denom


def cosine_similarity(queries: jax.Array, database: jax.Array, *,
                      c_sq_norms: jax.Array | None = None,
                      precision=jax.lax.Precision.HIGHEST) -> jax.Array:
    """Full cosine-similarity matrix: OpAngular outputs + external divider."""
    dots, c_norms = angular_scores(queries, database, precision,
                                   c_sq_norms=c_sq_norms)
    return cosine_epilogue(dots, c_norms, queries)


def pairwise_scores(queries: jax.Array, database: jax.Array,
                    metric: str = "euclidean", *,
                    c_sq_norms: jax.Array | None = None,
                    precision=jax.lax.Precision.HIGHEST) -> jax.Array:
    """The (M, N) score matrix for any metric: squared distances for
    ``euclidean`` (lower = closer), similarities for ``angular``/``cosine``
    (higher = closer)."""
    if metric == "euclidean":
        return euclidean_scores(queries, database, precision,
                                c_sq_norms=c_sq_norms)
    if metric == "angular":
        return angular_scores(queries, database, precision,
                              c_sq_norms=c_sq_norms)[0]
    if metric == "cosine":
        return cosine_similarity(queries, database, c_sq_norms=c_sq_norms,
                                 precision=precision)
    raise ValueError(f"unknown metric: {metric} (want one of {METRICS})")


# ---------------------------------------------------------------------------
# Selection epilogues (shared by the free functions and the session API)
# ---------------------------------------------------------------------------


def select_topk(scores: jax.Array, k: int, metric: str = "euclidean"):
    """Top-k selection on a score matrix: ascending for euclidean distances,
    descending for angular/cosine similarities.  Returns (scores, indices)."""
    if metric == "euclidean":
        neg, idx = jax.lax.top_k(-scores, k)
        return -neg, idx
    return jax.lax.top_k(scores, k)


def select_within(scores: jax.Array, radius: float, k: int,
                  metric: str = "euclidean"):
    """Range-limited top-k: the best k candidates inside the radius.
    Returns (scores, indices, within) — ``within`` marks which of the k
    slots actually fall inside the radius."""
    if metric == "euclidean":
        inside = scores <= radius * radius
        neg, idx = jax.lax.top_k(jnp.where(inside, -scores, -jnp.inf), k)
        return -neg, idx, jnp.isfinite(neg)
    if metric == "cosine":
        inside = scores >= radius
        top, idx = jax.lax.top_k(jnp.where(inside, scores, -jnp.inf), k)
        return top, idx, jnp.isfinite(top)
    raise ValueError(
        f"unknown radius metric: {metric} (want one of {RADIUS_METRICS})")


def count_within_scores(scores: jax.Array, radius: float,
                        metric: str = "euclidean") -> jax.Array:
    """Number of candidates inside the radius, per query row.  (M,N)->(M,)."""
    if metric == "euclidean":
        inside = scores <= radius * radius
    elif metric == "cosine":
        inside = scores >= radius
    else:
        raise ValueError(
            f"unknown radius metric: {metric} (want one of {RADIUS_METRICS})")
    return jnp.sum(inside, axis=-1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Free-function oracle API (score + select composed per call)
# ---------------------------------------------------------------------------


def radius_search(queries: jax.Array, database: jax.Array, radius: float,
                  k: int, metric: str = "euclidean", *,
                  c_sq_norms: jax.Array | None = None):
    """Fixed-radius neighbor query: up to ``k`` neighbors within ``radius``.

    This is the vector-search twin of the traversal engine's extent-limited
    shadow rays (``repro.core.wavefront``): just as a shadow ray accepts any
    hit with ``t <= extent``, a radius query accepts any candidate with
    distance <= radius — the RTNN mapping of neighbor search onto
    ray-tracing-style range-limited queries.

    Returns ``(scores, indices, within)``: ``scores``/``indices`` are the
    (padded) top-k by proximity, ``within`` marks which of the k actually
    fall inside the radius.  ``scores`` are squared distances for euclidean
    (ascending) and similarities for cosine (descending, ``radius`` is the
    minimum similarity).
    """
    if metric not in RADIUS_METRICS:
        raise ValueError(f"unknown radius_search metric: {metric}")
    scores = pairwise_scores(queries, database, metric, c_sq_norms=c_sq_norms)
    return select_within(scores, radius, k, metric)


def radius_count(queries: jax.Array, database: jax.Array, radius: float,
                 metric: str = "euclidean", *,
                 c_sq_norms: jax.Array | None = None) -> jax.Array:
    """Number of database points within ``radius`` of each query (the
    occlusion-test analogue: "does anything fall inside the extent" plus
    multiplicity).  (M, D), (N, D) -> (M,) i32."""
    if metric not in RADIUS_METRICS:
        raise ValueError(f"unknown radius_count metric: {metric}")
    scores = pairwise_scores(queries, database, metric, c_sq_norms=c_sq_norms)
    return count_within_scores(scores, radius, metric)


def knn(queries: jax.Array, database: jax.Array, k: int,
        metric: str = "euclidean", *, c_sq_norms: jax.Array | None = None):
    """Exact k-nearest-neighbour search on the datapath's distance modes.

    Returns (scores, indices) with scores ascending for euclidean and
    descending (most similar first) for angular/cosine.
    """
    if metric not in METRICS:
        raise ValueError(f"unknown metric: {metric}")
    scores = pairwise_scores(queries, database, metric, c_sq_norms=c_sq_norms)
    return select_topk(scores, k, metric)
