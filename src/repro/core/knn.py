"""Generalized distance modes as large-scale vector search (kNN / retrieval).

The paper's OpEuclidean/OpAngular process one vector pair per beat on the
VPU-equivalent lanes.  On TPU the profitable mapping of the *same* math is
matmul-shaped so it runs on the MXU (DESIGN.md §2):

    ||q - c||^2 = ||q||^2 + ||c||^2 - 2 q.c          (Euclidean mode)
    scores      = Q @ C^T,  norms = rowsum(C*C)      (angular mode)

Both forms are exposed here, plus a beat-exact path through
``repro.core.datapath`` for parity testing, plus the Pallas kernel path
(``repro.kernels.distance``) for the tiled/accumulated version that mirrors
the hardware's multi-beat accumulator.

This module is what the MoE routers call: router logits are OpAngular jobs
(query = token activation, candidates = expert embeddings).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def euclidean_scores(queries: jax.Array, database: jax.Array,
                     precision=jax.lax.Precision.HIGHEST) -> jax.Array:
    """Pairwise squared Euclidean distances, MXU form.  (M,D),(N,D) -> (M,N)."""
    q = queries.astype(jnp.float32)
    c = database.astype(jnp.float32)
    q2 = jnp.sum(q * q, axis=-1, keepdims=True)  # (M, 1)
    c2 = jnp.sum(c * c, axis=-1)  # (N,)
    qc = jnp.dot(q, c.T, precision=precision)  # (M, N) on the MXU
    return jnp.maximum(q2 - 2.0 * qc + c2[None, :], 0.0)


def angular_scores(queries: jax.Array, database: jax.Array,
                   precision=jax.lax.Precision.HIGHEST):
    """OpAngular outputs for all pairs: (Q.C^T, ||c||^2).  (M,D),(N,D)."""
    q = queries.astype(jnp.float32)
    c = database.astype(jnp.float32)
    dots = jnp.dot(q, c.T, precision=precision)  # (M, N)
    norms = jnp.sum(c * c, axis=-1)  # (N,)
    return dots, norms


def cosine_similarity(queries: jax.Array, database: jax.Array) -> jax.Array:
    """The external-divider epilogue of Eq. (8): dot / (||q|| ||c||)."""
    dots, c_norms = angular_scores(queries, database)
    q_norms = jnp.sqrt(jnp.sum(queries.astype(jnp.float32) ** 2, axis=-1))
    denom = jnp.maximum(q_norms[:, None] * jnp.sqrt(c_norms)[None, :], 1e-30)
    return dots / denom


def radius_search(queries: jax.Array, database: jax.Array, radius: float,
                  k: int, metric: str = "euclidean"):
    """Fixed-radius neighbor query: up to ``k`` neighbors within ``radius``.

    This is the vector-search twin of the traversal engine's extent-limited
    shadow rays (``repro.core.wavefront``): just as a shadow ray accepts any
    hit with ``t <= extent``, a radius query accepts any candidate with
    distance <= radius — the RTNN mapping of neighbor search onto
    ray-tracing-style range-limited queries.

    Returns ``(scores, indices, within)``: ``scores``/``indices`` are the
    (padded) top-k by proximity, ``within`` marks which of the k actually
    fall inside the radius.  ``scores`` are squared distances for euclidean
    (ascending) and similarities for cosine (descending, ``radius`` is the
    minimum similarity).
    """
    if metric == "euclidean":
        d = euclidean_scores(queries, database)
        inside = d <= radius * radius
        neg, idx = jax.lax.top_k(jnp.where(inside, -d, -jnp.inf), k)
        return -neg, idx, jnp.isfinite(neg)
    if metric == "cosine":
        sims = cosine_similarity(queries, database)
        inside = sims >= radius
        top, idx = jax.lax.top_k(jnp.where(inside, sims, -jnp.inf), k)
        return top, idx, jnp.isfinite(top)
    raise ValueError(f"unknown radius_search metric: {metric}")


def radius_count(queries: jax.Array, database: jax.Array, radius: float,
                 metric: str = "euclidean") -> jax.Array:
    """Number of database points within ``radius`` of each query (the
    occlusion-test analogue: "does anything fall inside the extent" plus
    multiplicity).  (M, D), (N, D) -> (M,) i32."""
    if metric == "euclidean":
        inside = euclidean_scores(queries, database) <= radius * radius
    elif metric == "cosine":
        inside = cosine_similarity(queries, database) >= radius
    else:
        raise ValueError(f"unknown radius_count metric: {metric}")
    return jnp.sum(inside, axis=-1).astype(jnp.int32)


def knn(queries: jax.Array, database: jax.Array, k: int, metric: str = "euclidean"):
    """Exact k-nearest-neighbour search on the datapath's distance modes.

    Returns (scores, indices) with scores ascending for euclidean and
    descending (most similar first) for angular/cosine.
    """
    if metric == "euclidean":
        d = euclidean_scores(queries, database)
        neg, idx = jax.lax.top_k(-d, k)
        return -neg, idx
    if metric == "angular":
        dots, _ = angular_scores(queries, database)
        return jax.lax.top_k(dots, k)
    if metric == "cosine":
        sims = cosine_similarity(queries, database)
        return jax.lax.top_k(sims, k)
    raise ValueError(f"unknown metric: {metric}")
