"""The unified Ray Tracer Datapath, stage-for-stage per paper Table VII.

Each mode is written as a sequence of named stage functions so that the
arithmetic *and its association order* match the hardware pipeline exactly:
the Pallas kernels in ``repro.kernels`` share these stage helpers, which is
the TPU analogue of the paper's "functional units are shared" design choice
(§III-B) — one implementation of each stage primitive, reused by every mode.

FP semantics
------------
* The hardware rounds after every functional unit (§III-D); on TPU every
  VPU op rounds to f32, so computing in f32 reproduces that choice natively.
* Hardware comparators (`RecFNCompareSelect`) return *false* on NaN inputs,
  so min/max built from compare-and-select keep the previous operand when a
  NaN appears.  We mirror that with explicit ``jnp.where(a < b, ...)``
  selects rather than ``jnp.minimum`` (which propagates NaN).  This also
  reproduces the tavianator "boundaries" robustness the paper's ray-box
  algorithm relies on (0 * inf = NaN slabs are ignored, not propagated).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .types import (
    ANGULAR_LANES,
    VECTOR_LANES,
    AngularResult,
    Box,
    DatapathState,
    EuclideanResult,
    PointBoxResult,
    QuadBoxResult,
    Ray,
    Triangle,
    TriangleResult,
)

# ---------------------------------------------------------------------------
# Shared stage primitives (the "functional units")
# ---------------------------------------------------------------------------


def cmp_select(a: jax.Array, b: jax.Array, lt: jax.Array | None = None):
    """Hardware-style compare-and-swap: returns (min-ish, max-ish).

    NaN behaviour matches a comparator+mux: if the compare is false (as it is
    for NaN), the operands pass through unswapped.
    """
    if lt is None:
        lt = a < b
    return jnp.where(lt, a, b), jnp.where(lt, b, a)


def fmax(a: jax.Array, b: jax.Array) -> jax.Array:
    """max via comparator: returns ``b`` when the compare is false (incl. NaN a)."""
    return jnp.where(a > b, a, b)


def fmin(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.where(a < b, a, b)


# Compare-exchange schedules per sort width.  4 is the paper's
# QuadSortRecFN network; 8 is Batcher's odd-even merge sort (19 CE) for the
# BVH8 datapath twin (DatapathConfig.arity == 8).
SORT_NETWORKS = {
    4: [(0, 1), (2, 3), (0, 2), (1, 3), (1, 2)],
    8: [(0, 1), (2, 3), (4, 5), (6, 7),
        (0, 2), (1, 3), (4, 6), (5, 7),
        (1, 2), (5, 6),
        (0, 4), (1, 5), (2, 6), (3, 7),
        (2, 4), (3, 5),
        (1, 2), (3, 4), (5, 6)],
}


def boxsort(keys: jax.Array, *payloads: jax.Array):
    """Fixed-width sorting network over the trailing axis.

    ``keys``: (..., W) with ``W`` in :data:`SORT_NETWORKS`.  Payload arrays
    are permuted alongside the keys.  Width 4 runs the paper's exact
    QuadSortRecFN schedule (see :func:`quadsort`); width 8 runs Batcher's
    odd-even merge network.
    """
    width = keys.shape[-1]
    pairs = SORT_NETWORKS[width]
    cols = [keys[..., i] for i in range(width)]
    pl = [[p[..., i] for i in range(width)] for p in payloads]

    def cas(i, j):
        lt = cols[i] < cols[j]
        cols[i], cols[j] = jnp.where(lt, cols[i], cols[j]), jnp.where(lt, cols[j], cols[i])
        for p in pl:
            p[i], p[j] = jnp.where(lt, p[i], p[j]), jnp.where(lt, p[j], p[i])

    for i, j in pairs:
        cas(i, j)
    out_keys = jnp.stack(cols, axis=-1)
    out_payloads = tuple(jnp.stack(p, axis=-1) for p in pl)
    return (out_keys, *out_payloads)


def quadsort(keys: jax.Array, *payloads: jax.Array):
    """Paper's QuadSortRecFN: 4-input sorting network (5 compare-exchanges).

    ``keys``: (..., 4).  Payload arrays are permuted alongside the keys (this
    is QuadSortRecFNWithIndex when a payload is ``arange(4)``).  Stable for
    the (0,1)(2,3)(0,2)(1,3)(1,2) network under ``<`` compares.
    """
    assert keys.shape[-1] == 4, keys.shape
    return boxsort(keys, *payloads)


# ---------------------------------------------------------------------------
# OpQuadbox: one ray vs four AABBs (Table VII "Box" column)
# ---------------------------------------------------------------------------


def ray_box_test(ray: Ray, boxes: Box) -> QuadBoxResult:
    """Batched ray-vs-W-AABB intersection (W = 4 or 8 child boxes).

    ray fields: (...,) batch; boxes: (..., W, 3) lo/hi.  W is the BVH
    arity (``DatapathConfig.arity``): the 4-wide case is the paper's
    OpQuadbox bit-for-bit; 8-wide swaps in the 8-input sort network.
    """
    o = ray.origin[..., None, :]  # (..., 1, 3)
    inv = ray.inv[..., None, :]

    # stage 2: 24 adders -- translate box planes into ray space
    lo = boxes.lo - o  # (..., 4, 3)
    hi = boxes.hi - o

    # stage 3: 24 multipliers -- slab distances
    t_lo = lo * inv
    t_hi = hi * inv

    # stage 4: sign-based swap + min/max trees (36 comparators) + clamp
    # Paper: if (ray.dir < 0) swap(t_min, t_max).  We key the swap off the
    # sign bit so that dir == -0.0 (inv == -inf) also swaps.
    neg = jnp.signbit(ray.direction)[..., None, :]
    t_near = jnp.where(neg, t_hi, t_lo)  # (..., 4, 3)
    t_far = jnp.where(neg, t_lo, t_hi)

    # tmin = max(t_near_x, t_near_y, t_near_z, 0.0f) -- comparator semantics
    # drop NaN slabs (0 * inf), reproducing the branchless boundary handling.
    zero = jnp.zeros_like(t_near[..., 0])
    tmin = fmax(t_near[..., 2], fmax(t_near[..., 1], fmax(t_near[..., 0], zero)))
    inf = jnp.full_like(tmin, jnp.inf)
    tmax = fmin(t_far[..., 2], fmin(t_far[..., 1], fmin(t_far[..., 0], inf)))

    # stage 5: intersect = (tmin <= tmax)   (W comparators)
    intersect = tmin <= tmax  # (..., W)

    # stage 10: two sorting networks (values and indices) over tmin
    width = boxes.lo.shape[-2]
    idx = jnp.broadcast_to(jnp.arange(width, dtype=jnp.int32), tmin.shape)
    hit_i = intersect.astype(jnp.int32)
    tmin_sorted, idx_sorted, hit_sorted = boxsort(tmin, idx, hit_i)
    return QuadBoxResult(tmin=tmin_sorted, box_index=idx_sorted,
                         is_intersect=hit_sorted.astype(bool))


def point_box_test(point: jax.Array, boxes: Box) -> PointBoxResult:
    """Batched point-vs-4-AABB squared distance: the neighbor-query twin of
    :func:`ray_box_test` (RTNN traverses by box *distance*, not slab entry).

    point: (..., 3); boxes: (..., 4, 3) lo/hi.  Per axis the gap to the box
    is ``max(lo - p, p - hi, 0)`` — comparator semantics, so an inverted
    empty-pad box (lo=+inf, hi=-inf) yields +inf**2 = +inf and sorts last,
    exactly like a missed slab in the ray path.  The same quad-sort network
    orders the four children near-to-far for the traversal push.
    """
    p = point[..., None, :]  # (..., 1, 3)

    # stage 2: 24 adders -- per-axis signed gaps to both faces
    below = boxes.lo - p  # (..., 4, 3)
    above = p - boxes.hi

    # stage 4: comparator trees clamp to the outside gap (0 inside the slab)
    zero = jnp.zeros_like(below)
    gap = fmax(below, fmax(above, zero))

    # stage 3/8: 12 multipliers + pairwise adds -> squared distance
    sq = gap * gap
    d2 = (sq[..., 0] + sq[..., 1]) + sq[..., 2]  # (..., 4)

    # stage 10: the same quad-sorting network as OpQuadbox
    idx = jnp.broadcast_to(jnp.arange(4, dtype=jnp.int32), d2.shape)
    d2_sorted, idx_sorted = quadsort(d2, idx)
    return PointBoxResult(dist_sq=d2_sorted, box_index=idx_sorted)


# ---------------------------------------------------------------------------
# OpTriangle: Woop/Benthin/Wald watertight test (Table VII "Triangle" column)
# ---------------------------------------------------------------------------


def _gather_dim(v: jax.Array, k: jax.Array) -> jax.Array:
    """v: (..., 3), k: (...,) int -> v[..., k] elementwise over the batch."""
    return jnp.take_along_axis(v, k[..., None], axis=-1)[..., 0]


def ray_triangle_test(ray: Ray, tri: Triangle) -> TriangleResult:
    """Batched watertight ray-triangle intersection (backface-culling variant).

    Outputs t_num / t_denom; the division is explicitly *not* performed, as in
    the paper (an external unit divides when needed).
    """
    sx = ray.shear[..., 0]
    sy = ray.shear[..., 1]
    sz = ray.shear[..., 2]

    # stage 2: translate vertices by ray origin (9 adders)
    a = tri.a - ray.origin
    b = tri.b - ray.origin
    c = tri.c - ray.origin

    a_kx, a_ky, a_kz = (_gather_dim(a, ray.kx), _gather_dim(a, ray.ky), _gather_dim(a, ray.kz))
    b_kx, b_ky, b_kz = (_gather_dim(b, ray.kx), _gather_dim(b, ray.ky), _gather_dim(b, ray.kz))
    c_kx, c_ky, c_kz = (_gather_dim(c, ray.kx), _gather_dim(c, ray.ky), _gather_dim(c, ray.kz))

    # stage 3: shear products (9 multipliers)
    ax_s = sx * a_kz
    ay_s = sy * a_kz
    az = sz * a_kz
    bx_s = sx * b_kz
    by_s = sy * b_kz
    bz = sz * b_kz
    cx_s = sx * c_kz
    cy_s = sy * c_kz
    cz = sz * c_kz

    # stage 4: shear-subtract (6 adders)
    ax = a_kx - ax_s
    ay = a_ky - ay_s
    bx = b_kx - bx_s
    by = b_ky - by_s
    cx = c_kx - cx_s
    cy = c_ky - cy_s

    # stage 5: edge-function products (6 multipliers)
    u = cx * by
    v = ax * cy
    w = bx * ay
    u_sub = cy * bx
    v_sub = ay * cx
    w_sub = by * ax

    # stage 6: edge functions (3 adders)
    u = u - u_sub
    v = v - v_sub
    w = w - w_sub

    # stage 7: scaled z products (3 multipliers)
    t_num_1 = u * az
    t_num_2 = v * bz
    t_num_3 = w * cz

    # stage 8: (2 adders)
    t_denom = u + v
    t_num = t_num_1 + t_num_2

    # stage 9: (2 adders)
    t_denom = t_denom + w
    t_num = t_num + t_num_3

    # stage 10: hit decision (5 comparators) -- backface-culling variant
    hit = (t_num > 0.0) & (t_denom != 0.0) & (u >= 0.0) & (v >= 0.0) & (w >= 0.0)
    return TriangleResult(t_num=t_num, t_denom=t_denom, hit=hit)


# ---------------------------------------------------------------------------
# OpEuclidean / OpAngular (Table VII columns 3-4): masked lanes + adder tree
# ---------------------------------------------------------------------------


def _mask_lanes(x: jax.Array, mask: jax.Array | None, lanes: int) -> jax.Array:
    x = x[..., :lanes]
    if mask is not None:
        x = jnp.where(mask[..., :lanes], x, 0.0)
    return x


def euclidean_partial(a: jax.Array, b: jax.Array, mask: jax.Array | None = None) -> jax.Array:
    """One beat of OpEuclidean: sum over <=16 lanes of (a-b)^2.

    The reduction is the hardware's pairwise adder tree (16->8->4->2->1),
    reproduced exactly so the kernel/ref/HW agree bit-for-bit in f32.
    """
    d = _mask_lanes(a, mask, VECTOR_LANES) - _mask_lanes(b, mask, VECTOR_LANES)  # stage 2
    d = d * d  # stage 3 (16 muls)
    d = d[..., :8] + d[..., 8:16]  # stage 4 (8 adds)
    d = d[..., :4] + d[..., 4:8]  # stage 6 (4 adds)
    d = d[..., :2] + d[..., 2:4]  # stage 8 (2 adds)
    return d[..., 0] + d[..., 1]  # stage 9 (1 add)


def angular_partial(q: jax.Array, c: jax.Array, mask: jax.Array | None = None):
    """One beat of OpAngular: (sum q*c, sum c*c) over <=8 lanes."""
    qm = _mask_lanes(q, mask, ANGULAR_LANES)
    cm = _mask_lanes(c, mask, ANGULAR_LANES)
    dot = qm * cm  # stage 3 (8 muls)
    nrm = cm * cm  # stage 3 (8 muls)
    dot = dot[..., :4] + dot[..., 4:8]  # stage 4
    nrm = nrm[..., :4] + nrm[..., 4:8]
    dot = dot[..., :2] + dot[..., 2:4]  # stage 6
    nrm = nrm[..., :2] + nrm[..., 2:4]
    dot = dot[..., 0] + dot[..., 1]  # stage 8
    nrm = nrm[..., 0] + nrm[..., 1]
    return dot, nrm


def euclidean_beat(state: DatapathState, a, b, mask=None, reset=False):
    """Full OpEuclidean job incl. accumulator semantics (Table V).

    ``reset`` clears the Euclidean accumulator *for this job* (the angular
    accumulators are untouched -- per-mode isolation).
    """
    partial = euclidean_partial(a, b, mask)
    reset = jnp.asarray(reset)
    accum_in = jnp.where(reset, 0.0, state.euclid_accum)
    out = partial + accum_in  # stage 10 (1 add)
    new_state = state._replace(euclid_accum=out)
    return new_state, EuclideanResult(accumulator=out, reset_accum=reset)


def angular_beat(state: DatapathState, q, c, mask=None, reset=False):
    """Full OpAngular job incl. dual accumulators (dot product and norm)."""
    dot_p, nrm_p = angular_partial(q, c, mask)
    reset = jnp.asarray(reset)
    dot = dot_p + jnp.where(reset, 0.0, state.dot_accum)  # stage 9 (2 adds)
    nrm = nrm_p + jnp.where(reset, 0.0, state.norm_accum)
    new_state = state._replace(dot_accum=dot, norm_accum=nrm)
    return new_state, AngularResult(dot_product=dot, norm=nrm, reset_accum=reset)


def euclidean_distance_sq(a: jax.Array, b: jax.Array) -> jax.Array:
    """Arbitrary-dimension Euclidean distance**2 via multi-beat accumulation.

    a, b: (..., D).  D is padded to a multiple of 16 with masked lanes, then
    scanned 16 lanes per beat exactly like feeding the hardware.
    """
    a, b, mask, beats = _beats(a, b, VECTOR_LANES)

    def step(carry, xs):
        ab, bb, mb, first = xs
        out = euclidean_partial(ab, bb, mb) + jnp.where(first, 0.0, carry)
        return out, None

    first = jnp.arange(beats) == 0
    out, _ = jax.lax.scan(step, jnp.zeros(a.shape[1:-1], jnp.float32), (a, b, mask, first))
    return out


def angular_distance_parts(q: jax.Array, c: jax.Array):
    """Arbitrary-dimension (q . c, ||c||^2) via 8-lane beats."""
    q, c, mask, beats = _beats(q, c, ANGULAR_LANES)

    def step(carry, xs):
        qb, cb, mb, first = xs
        dot_c, nrm_c = carry
        d, n = angular_partial(qb, cb, mb)
        d = d + jnp.where(first, 0.0, dot_c)
        n = n + jnp.where(first, 0.0, nrm_c)
        return (d, n), None

    first = jnp.arange(beats) == 0
    z = jnp.zeros(q.shape[1:-1], jnp.float32)
    (dot, nrm), _ = jax.lax.scan(step, (z, z), (q, c, mask, first))
    return dot, nrm


def _beats(a, b, lanes):
    d = a.shape[-1]
    beats = max(1, -(-d // lanes))
    pad = beats * lanes - d
    af = jnp.pad(a.astype(jnp.float32), [(0, 0)] * (a.ndim - 1) + [(0, pad)])
    bf = jnp.pad(b.astype(jnp.float32), [(0, 0)] * (b.ndim - 1) + [(0, pad)])
    mask = jnp.arange(beats * lanes) < d
    # reshape to (beats, ..., lanes) for scan
    def to_beats(x):
        x = x.reshape(x.shape[:-1] + (beats, lanes))
        return jnp.moveaxis(x, -2, 0)

    mask = jnp.broadcast_to(mask, af.shape[:-1] + (beats * lanes,))
    return to_beats(af), to_beats(bf), to_beats(mask), beats
