"""Core: the paper's unified ray-tracer datapath, generalized modes, BVH."""
from .types import (  # noqa: F401
    ANGULAR_LANES,
    OP_ANGULAR,
    OP_EUCLIDEAN,
    OP_QUADBOX,
    OP_TRIANGLE,
    OPCODE_NAMES,
    QUAD,
    VECTOR_LANES,
    AngularResult,
    Box,
    DatapathState,
    EuclideanResult,
    QuadBoxResult,
    Ray,
    Triangle,
    TriangleResult,
    aabb_of_triangles,
    init_datapath_state,
    make_ray,
)
from .datapath import (  # noqa: F401
    angular_beat,
    angular_distance_parts,
    angular_partial,
    euclidean_beat,
    euclidean_distance_sq,
    euclidean_partial,
    quadsort,
    ray_box_test,
    ray_triangle_test,
)
from .stream import DatapathJob, DatapathOutput, make_jobs, unified_stream  # noqa: F401
from .bvh import BVH4, bvh4_depth, child_boxes, fit_nodes  # noqa: F401
from .traversal import HitRecord, trace_ray, trace_rays  # noqa: F401
from .wavefront import (  # noqa: F401
    RAY_TYPES,
    WavefrontRecord,
    occlusion_test,
    trace_wavefront,
)
from .build import (  # noqa: F401
    BuildResult,
    TreeStats,
    build,
    build_bvh4,
    builders,
    get_builder,
    mean_jobs_per_ray,
    refit,
    register_builder,
    sah_cost,
    tree_stats,
)
from .knn import (  # noqa: F401
    angular_scores,
    cosine_similarity,
    count_within_scores,
    euclidean_scores,
    knn,
    pairwise_scores,
    radius_count,
    radius_search,
    select_topk,
    select_within,
    squared_norms,
)
from .session import (  # noqa: F401
    CacheInfo,
    NearestResult,
    QueryEngine,
    Scene,
    TraceResult,
    VectorIndex,
    WithinResult,
    default_pad_multiple,
    distance_backends,
    register_distance_backend,
    register_trace_backend,
    trace_backends,
)
