"""Sharded + chunked query execution mechanics (DESIGN.md §6).

The paper's datapath scales by replicating one shared pipeline; RTNN's
batched-query formulation is what keeps such a pipeline saturated.  This
module is the session layer's version of that replication: it decides how a
query batch is *placed* (data-parallel over a 1-D device mesh, scene/index
replicated) and *scheduled* (fixed-size microbatch chunks sharing one
compiled program), without touching any backend's arithmetic.

The execution pipeline for one query is::

    pad -> shard -> query -> unshard -> unpad

* **pad** — each chunk is padded so every *shard* receives a lane multiple
  of rows (``block = shards * ceil(rows_per_shard to pad_multiple)``), by
  repeating the chunk's row 0 (always a valid element; empty guard lives in
  the session layer, which never dispatches 0 rows here).
* **shard** — the chunk's leading axis is split over the mesh
  (``parallel.sharding.batch_sharded``); the scene/index operands are
  replicated once per mesh (``parallel.sharding.replicated``) and closed
  over, so the per-shard computation is *literally* the single-device
  computation on that shard's rows.  No collectives: bit-parity with the
  single-device path is structural, not numerical luck
  (``tests/test_fuzz_backends.py`` fuzzes it).
* **query** — one jitted ``shard_map`` per (backend, static config, block
  shape); every chunk re-enters the same compiled program, so a
  million-ray batch pays one trace and ``n_blocks`` executions with peak
  memory bounded by the block size.
* **unshard/unpad** — per-row outputs concatenate across chunks and slice
  back to the caller's row count; per-chunk scalar statistics (wavefront
  ``rounds``) reduce by ``max``, which matches the single-device value
  exactly (a ray is active for exactly ``quadbox_jobs`` consecutive
  rounds, so the batch round count is the max over rays wherever those
  rays execute).
"""
from __future__ import annotations

import math
import numbers
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..parallel.compat import make_device_mesh, shard_map_unchecked
from ..parallel.sharding import batch_sharded, replicated  # noqa: F401

#: mesh axis name carrying the data-parallel ray/query batch
BATCH_AXIS = "shards"

_MESHES: dict[tuple[str, int], Mesh] = {}


def available_devices() -> int:
    """Device count the ``shard="auto"`` policy sees."""
    return jax.local_device_count()


def check_count(name: str, value, minimum: int = 1) -> Optional[int]:
    """Eagerly validate an integral execution knob (``shard`` /
    ``chunk_size``): ``None`` passes through (= knob unset); anything else
    must be a true integer (no bools, no floats — ``chunk_size=2.5`` used
    to silently truncate inside the plan math) that is ``>= minimum``.
    Returns the value as a plain ``int``."""
    if value is None:
        return None
    if isinstance(value, bool) or not isinstance(value, numbers.Integral):
        raise ValueError(
            f"{name} must be an int >= {minimum}, got {value!r} "
            f"({type(value).__name__})")
    if value < minimum:
        raise ValueError(f"{name} must be >= {minimum}, got {value!r}")
    return int(value)


def resolve_shards(shard, n_rows: Optional[int] = None) -> int:
    """``shard="auto" | int | None`` -> a concrete shard count.

    ``"auto"`` learns the local device count (capped at the batch size —
    a 3-ray batch on 8 devices gains nothing from 5 idle replicas);
    an explicit value must be a positive integer (validated eagerly, at
    call time) and must not exceed the device count.
    """
    if shard is None:
        return 1
    if shard == "auto":
        shards = available_devices()
        if n_rows is not None:
            shards = max(1, min(shards, n_rows))
        return shards
    shards = check_count("shard", shard)
    if shards == 1:
        return 1
    n_dev = available_devices()
    if shards > n_dev:
        raise ValueError(
            f"shard={shards} exceeds the {n_dev} available device(s)")
    return shards


def device_mesh(shards: int, axis_name: str = BATCH_AXIS) -> Mesh:
    """The (cached) 1-D query mesh over the first ``shards`` devices."""
    key = (axis_name, shards)
    mesh = _MESHES.get(key)
    if mesh is None:
        mesh = _MESHES[key] = make_device_mesh(shards, axis_name)
    return mesh


# ---------------------------------------------------------------------------
# Padding policy (one definition; the session layer imports from here)
# ---------------------------------------------------------------------------


def ceil_to(n: int, multiple: int) -> int:
    return max(1, -(-n // multiple) * multiple)


def pad_leading(tree, n_to: int):
    """Pad every leading-axis leaf to ``n_to`` rows by repeating row 0
    (always a valid element, so padded lanes trace/score harmlessly).
    Empty batches pad with zeros — rows are independent in every backend,
    so a degenerate lane is harmless and sliced away on unpad."""
    def pad(x):
        n = x.shape[0]
        if n == n_to:
            return x
        if n:
            rep = jnp.broadcast_to(x[:1], (n_to - n,) + x.shape[1:])
        else:
            rep = jnp.zeros((n_to - n,) + x.shape[1:], x.dtype)
        return jnp.concatenate([x, rep], axis=0)

    return jax.tree_util.tree_map(pad, tree)


# ---------------------------------------------------------------------------
# Execution plan: how one query batch is padded, chunked and sharded
# ---------------------------------------------------------------------------


class ExecPlan(NamedTuple):
    """A resolved (rows, chunking, sharding) schedule for one query."""

    n: int  # caller's row count (> 0; empty batches never reach dispatch)
    block: int  # rows per executed call; shards * lane-multiple per shard
    n_blocks: int  # ceil(n / block) chunked calls through one compiled fn
    shards: int  # 1 = single-device (no shard_map wrapping)

    @property
    def key(self) -> tuple:
        """The plan's contribution to the compiled-function cache key."""
        return (self.shards, self.block)

    @property
    def mesh(self) -> Optional[Mesh]:
        return device_mesh(self.shards) if self.shards > 1 else None


def make_plan(n: int, *, pad_multiple: int, shards: int = 1,
              chunk_size: Optional[int] = None,
              lane_multiple: Optional[int] = None) -> ExecPlan:
    """Schedule ``n`` rows into fixed-size blocks.

    The block is ``chunk_size`` (the whole batch when None) rounded up so
    that each of the ``shards`` shards receives a lane multiple of rows —
    per-shard padding composing with the pad-to-lane policy.  With
    ``shards=1, chunk_size=None`` this degenerates to the original
    single-call ``ceil_to(n, pad_multiple)`` behavior.

    ``lane_multiple`` is a backend-declared hard tile width (e.g. the
    fused Pallas traversal kernel's 128-lane tiles): the effective
    per-shard multiple becomes ``max(pad_multiple, lane_multiple)``, so a
    kernel backend always receives whole tiles per shard per chunk and
    never re-pads internally.  Padding stays the row-0-repeat identity,
    so results are unchanged — only the schedule is.
    """
    if n <= 0:
        raise ValueError("make_plan needs n >= 1; guard empty batches first")
    chunk_size = check_count("chunk_size", chunk_size)
    multiple = (pad_multiple if lane_multiple is None
                else max(pad_multiple, int(lane_multiple)))
    rows = n if chunk_size is None else min(chunk_size, n)
    per_shard = ceil_to(math.ceil(rows / shards), multiple)
    block = per_shard * shards
    return ExecPlan(n=n, block=block, n_blocks=-(-n // block), shards=shards)


def split_blocks(tree, plan: ExecPlan):
    """Yield the plan's padded (and, on a mesh, batch-sharded) blocks.

    Every yielded block has exactly ``plan.block`` rows — the last one
    padded by repeating its own row 0 — so all blocks re-enter one
    compiled function.
    """
    mesh = plan.mesh
    for i in range(plan.n_blocks):
        lo = i * plan.block
        chunk = jax.tree_util.tree_map(
            lambda x: x[lo:lo + plan.block], tree)
        chunk = pad_leading(chunk, plan.block)
        if mesh is not None:
            chunk = batch_sharded(mesh, chunk, BATCH_AXIS)
        yield chunk


def slice_rows(tree, sizes):
    """Split per-row leaves into consecutive row groups of ``sizes`` —
    the batch-slice/unpad contract the serving coalescer reuses
    (``repro.serving.batching``): a response computed for a coalesced
    batch is handed back per request by slicing the same row ranges that
    were concatenated on the way in.  Rows beyond ``sum(sizes)`` (lane
    padding) are dropped, so ``slice_rows(padded_result, [n])[0]`` is
    exactly the unpad step of :func:`concat_rows`.  Row independence —
    the property every backend already guarantees for pad -> query ->
    unpad — is what makes this split bit-exact per request."""
    out, lo = [], 0
    for s in sizes:
        s = int(s)
        if s < 0:
            raise ValueError(f"slice sizes must be >= 0, got {s}")
        hi = lo + s
        out.append(jax.tree_util.tree_map(
            lambda x, lo=lo, hi=hi: x[lo:hi], tree))
        lo = hi
    return out


def concat_rows(blocks: list, n: int):
    """Unshard + unpad: stitch per-row block results back together and
    slice to the caller's ``n`` rows.  All leaves must be per-row."""
    if len(blocks) == 1:
        out = blocks[0]
    else:
        out = jax.tree_util.tree_map(
            lambda *xs: jnp.concatenate(xs, axis=0), *blocks)
    return jax.tree_util.tree_map(lambda x: x[:n], out)


def shard_rows(fn, mesh: Mesh, axis: str = BATCH_AXIS):
    """Data-parallel ``fn`` over rows: each device runs the unchanged
    single-device computation on its row shard (scene/index operands are
    closed over, replicated).  Every output leaf must carry the row axis
    first — scalar statistics must be lifted to a length-1 axis so they
    come back as one value per shard."""
    return shard_map_unchecked(fn, mesh, in_specs=(P(axis),),
                               out_specs=P(axis))


def shard_rows_ctx(fn, mesh: Mesh, axis: str = BATCH_AXIS):
    """:func:`shard_rows` for ``fn(ctx, rows)``: the first argument is a
    replicated context operand (a BVH4 under animation, or a backend's
    prepared form of it — threaded as a runtime argument, not closed
    over, so ``Scene.refit`` swaps its arrays without retracing), the
    second is row-sharded as usual."""
    return shard_map_unchecked(fn, mesh, in_specs=(P(), P(axis)),
                               out_specs=P(axis))
