"""Data representations of the Ray Tracer Datapath (paper Tables I-IV).

Everything is stored SoA-style as JAX arrays with an arbitrary batch prefix
``(...,)`` so the same structures flow through vmap, pjit and Pallas kernels.

Faithfulness notes
------------------
* ``Ray`` carries the paper's derived convenience fields (Table III): the
  element-wise inverse of the direction, the max-dimension indices
  ``kx/ky/kz`` and the shear constants ``Sx/Sy/Sz`` — computed in
  :func:`make_ray` with exactly the pseudocode of §II-B3.
* ``Box`` is a min/max vertex pair (Table I); ``Triangle`` is three vertices
  (Table II); vector jobs (Table IV) are plain ``(..., dim)`` arrays with a
  validity mask capped at :data:`VECTOR_LANES` lanes per beat.
* Opcodes mirror Table V's 2-bit opcode.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Opcodes (Table V: 2-bit opcode)
# ---------------------------------------------------------------------------
OP_TRIANGLE = 0
OP_QUADBOX = 1
OP_EUCLIDEAN = 2
OP_ANGULAR = 3

OPCODE_NAMES = {
    OP_TRIANGLE: "OpTriangle",
    OP_QUADBOX: "OpQuadbox",
    OP_EUCLIDEAN: "OpEuclidean",
    OP_ANGULAR: "OpAngular",
}

# Table IV: vector dimension is capped at 16 per beat; the angular mode
# processes half that many lanes per beat (each lane needs two multipliers).
VECTOR_LANES = 16
ANGULAR_LANES = VECTOR_LANES // 2

# Number of boxes per quad-box job (Table V: aabb_0..aabb_3).
QUAD = 4


class Box(NamedTuple):
    """An axis-aligned bounding box (Table I): minimum and maximum vertices."""

    lo: jax.Array  # (..., 3) f32  [x_min, y_min, z_min]
    hi: jax.Array  # (..., 3) f32  [x_max, y_max, z_max]


class Triangle(NamedTuple):
    """A triangle in 3D (Table II): three vertices."""

    a: jax.Array  # (..., 3) f32
    b: jax.Array  # (..., 3) f32
    c: jax.Array  # (..., 3) f32


class Ray(NamedTuple):
    """A ray plus the paper's precomputed convenience fields (Table III)."""

    origin: jax.Array  # (..., 3) f32
    direction: jax.Array  # (..., 3) f32
    inv: jax.Array  # (..., 3) f32   element-wise inverse of direction
    extent: jax.Array  # (...,)   f32   how far the ray travels
    kx: jax.Array  # (...,)   i32   \
    ky: jax.Array  # (...,)   i32    } permuted max-dimension indices
    kz: jax.Array  # (...,)   i32   /
    shear: jax.Array  # (..., 3) f32   [Sx, Sy, Sz]


def make_ray(origin: jax.Array, direction: jax.Array, extent=None) -> Ray:
    """Ray setup: derive inv/k-indices/shear exactly per Table III pseudocode.

    This corresponds to the external "ray setup" the paper assumes happens
    before jobs enter the datapath (the derived fields are inputs in Table V).
    """
    origin = jnp.asarray(origin, jnp.float32)
    direction = jnp.asarray(direction, jnp.float32)
    if extent is None:
        extent = jnp.full(origin.shape[:-1], jnp.inf, jnp.float32)
    else:
        extent = jnp.broadcast_to(jnp.asarray(extent, jnp.float32), origin.shape[:-1])

    inv = 1.0 / direction  # inv_x <- 1/dir_x etc. (div-by-zero -> +-inf, as in HW)

    # maxInd <- dimension of greatest direction component (strict '>' chain per
    # the paper's pseudocode; ties resolve to the earliest dimension).  The
    # magnitude is what matters -- Woop et al. take argmax(|dir|); the paper's
    # subsequent "if dir[kz] < 0 swap(kx, ky)" step only makes sense under the
    # absolute-value reading.
    dx, dy, dz = (jnp.abs(direction[..., 0]), jnp.abs(direction[..., 1]),
                  jnp.abs(direction[..., 2]))
    max_ind = jnp.zeros(dx.shape, jnp.int32)
    max_val = dx
    max_ind = jnp.where(dy > max_val, 1, max_ind)
    max_val = jnp.where(dy > max_val, dy, max_val)
    max_ind = jnp.where(dz > max_val, 2, max_ind)

    kz = max_ind
    kx = (kz + 1) % 3
    ky = (kx + 1) % 3
    # if dir[kz] < 0 then swap(kx, ky)  -- preserves winding for watertight test
    dir_kz = jnp.take_along_axis(direction, kz[..., None], axis=-1)[..., 0]
    neg = dir_kz < 0.0
    kx, ky = jnp.where(neg, ky, kx), jnp.where(neg, kx, ky)

    # Shear constants: Sx = dir[kx]/dir[kz]; Sy = dir[ky]/dir[kz]; Sz = 1/dir[kz]
    dir_kx = jnp.take_along_axis(direction, kx[..., None], axis=-1)[..., 0]
    dir_ky = jnp.take_along_axis(direction, ky[..., None], axis=-1)[..., 0]
    shear = jnp.stack([dir_kx / dir_kz, dir_ky / dir_kz, 1.0 / dir_kz], axis=-1)

    return Ray(origin, direction, inv, extent, kx, ky, kz, shear)


class QuadBoxResult(NamedTuple):
    """Output bundle of an OpQuadbox job (Table V, opcode==opQuadbox fields).

    ``tmin`` is sorted ascending; ``box_index[i]`` links slot i back to the
    input box; ``is_intersect[i]`` says whether that (sorted) slot hit.
    """

    tmin: jax.Array  # (..., 4) f32 sorted ascending
    box_index: jax.Array  # (..., 4) i32
    is_intersect: jax.Array  # (..., 4) bool


class PointBoxResult(NamedTuple):
    """Output bundle of a point/quad-box distance job (the RTNN analogue of
    :class:`QuadBoxResult`: neighbor queries traverse by *box distance*
    instead of slab-test entry distance).

    ``dist_sq`` is the squared Euclidean distance from the query point to
    each box (0 inside), sorted ascending; ``box_index[i]`` links sorted
    slot i back to the input box.  Inverted (empty-pad) boxes report +inf
    and therefore sort last / never pass a radius bound.
    """

    dist_sq: jax.Array  # (..., 4) f32 sorted ascending
    box_index: jax.Array  # (..., 4) i32


class TriangleResult(NamedTuple):
    """Output bundle of an OpTriangle job: t = t_num / t_denom is external."""

    t_num: jax.Array  # (...,) f32
    t_denom: jax.Array  # (...,) f32
    hit: jax.Array  # (...,) bool


class EuclideanResult(NamedTuple):
    accumulator: jax.Array  # (...,) f32  running sum of squares
    reset_accum: jax.Array  # (...,) bool (propagated from input)


class AngularResult(NamedTuple):
    dot_product: jax.Array  # (...,) f32  running sum of products
    norm: jax.Array  # (...,) f32  running sum of candidate squares
    reset_accum: jax.Array  # (...,) bool (propagated from input)


class DatapathState(NamedTuple):
    """Internal accumulators (Table V: per-mode, isolated from each other)."""

    euclid_accum: jax.Array  # () or (lanes_of_stream,) f32
    dot_accum: jax.Array
    norm_accum: jax.Array


def init_datapath_state(shape=()) -> DatapathState:
    z = jnp.zeros(shape, jnp.float32)
    return DatapathState(z, z, z)


def aabb_of_triangles(tri: Triangle) -> Box:
    """Convenience: tight AABB of each triangle (used by the BVH builder)."""
    v = jnp.stack([tri.a, tri.b, tri.c], axis=-2)  # (..., 3verts, 3)
    return Box(lo=v.min(axis=-2), hi=v.max(axis=-2))
