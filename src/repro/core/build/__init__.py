"""Acceleration-structure construction subsystem (DESIGN.md §7).

The datapath is only half a ray tracer — what it chews on is the
acceleration structure, and tree quality is a workload trade-off (RTNN),
while build *and* update are first-class API surface alongside trace
(CrossRT).  This package is the layer between geometry and the datapath:

* a **builder registry** mirroring the session layer's backend registry
  (:func:`register_builder`, names ``"lbvh" | "sah"``) with a shared
  :class:`BuildResult` record;
* :mod:`~repro.core.build.lbvh` — the Morton-order LBVH builder (fast,
  quality-agnostic), refactored out of ``core/bvh.py``;
* :mod:`~repro.core.build.sah` — a pure-JAX, jittable binned-SAH top-down
  builder (4-wide via two levels of binary splits per tree level);
* :mod:`~repro.core.build.refit` — O(depth) topology-preserving AABB
  refit for dynamic scenes (``Scene.refit``: zero retraces per frame);
* :mod:`~repro.core.build.quality` — SAH cost + measured mean datapath
  jobs/ray, the portable tree-quality metrics behind ``Scene.stats()``.

Every builder emits the *same* implicit :class:`~repro.core.bvh.BVH4`
layout, so every traversal engine, backend, sharding knob and Pallas
kernel consumes any builder's tree unchanged — quality becomes a knob,
not a fork.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

from ..bvh import (
    BVH4,
    DEFAULT_CONFIG,
    DatapathConfig,
    bvh_depth,
    bvh4_depth,
    resolve_config,
)
from ..types import Triangle

# name -> builder(tri: Triangle, depth: int, config: DatapathConfig) -> BVH4
# (jittable; depth and config are static)
_BUILDERS: dict[str, Callable] = {}


class BuildResult(NamedTuple):
    """What every registered builder hands the session layer."""

    bvh: BVH4
    builder: str  # registry name that produced the tree
    depth: int  # static tree depth (arity**depth leaf slots)
    config: DatapathConfig = DEFAULT_CONFIG  # datapath knobs the tree targets


def register_builder(name: str):
    """Register an acceleration-structure builder under ``name``.  The
    builder receives ``(triangles, depth, config)`` with static depth and
    :class:`~repro.core.bvh.DatapathConfig`, and must return a
    :class:`BVH4` in the shared implicit layout at ``config.arity`` with
    the config's node-box codec applied."""
    def deco(fn):
        _BUILDERS[name] = fn
        return fn
    return deco


def builders() -> tuple[str, ...]:
    return tuple(_BUILDERS)


def get_builder(name: str) -> Callable:
    if name not in _BUILDERS:
        raise ValueError(
            f"unknown builder {name!r} (registered: {builders()})")
    return _BUILDERS[name]


def build(triangles: Triangle, builder: str = "lbvh",
          depth: int | None = None,
          config: DatapathConfig | None = None) -> BuildResult:
    """Build an acceleration structure with a registered builder.

    ``depth`` must be static; it defaults to the smallest depth whose
    ``config.arity**depth`` leaf slots fit the soup.  ``config`` selects
    the datapath twin the tree is built for (arity + node-box codec);
    ``None`` is the seed-equivalent BVH4/fp32 default.
    """
    fn = get_builder(builder)
    config = resolve_config(config)
    n = triangles.a.shape[0]
    if depth is None:
        depth = bvh_depth(n, config.arity)
    if config.arity**depth < n:
        raise ValueError(
            f"depth={depth} gives {config.arity**depth} leaf slots"
            f" < {n} triangles")
    return BuildResult(bvh=fn(triangles, depth, config), builder=builder,
                       depth=depth, config=config)


# builder modules self-register on import (like the session backends)
from . import lbvh, sah  # noqa: E402,F401
from .lbvh import build_bvh4  # noqa: E402,F401  (legacy name, re-exported)
from .quality import (  # noqa: E402,F401
    TreeStats,
    clustered_soup,
    mean_branching_factor,
    mean_jobs_per_ray,
    probe_rays,
    sah_cost,
    tree_stats,
)
from .points import (  # noqa: E402,F401
    build_point_bvh,
    point_boxes,
    refit_points,
)
from .refit import refit  # noqa: E402,F401

__all__ = [
    "BuildResult",
    "TreeStats",
    "build",
    "build_bvh4",
    "build_point_bvh",
    "builders",
    "clustered_soup",
    "get_builder",
    "mean_branching_factor",
    "mean_jobs_per_ray",
    "point_boxes",
    "probe_rays",
    "refit",
    "register_builder",
    "sah_cost",
    "tree_stats",
]
