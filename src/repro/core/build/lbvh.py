"""LBVH -> BVH4: the Morton-order builder, pure JAX.

The fast, quality-agnostic baseline (Lauterbach-style LBVH):

1. Morton-code the triangle centroids (30-bit, 10 bits/axis).
2. Sort primitives along the Z-order curve (``jnp.argsort`` -- a radix sort
   on TPU).
3. Lay the sorted leaves into the implicit complete 4-ary tree and fit
   AABBs bottom-up with ``depth`` fully-vectorised reduction sweeps
   (:func:`repro.core.bvh.fit_nodes`).

Spatial locality comes entirely from the Z-order curve, so clustered
(non-uniform) soups pay for it in traversal jobs — that trade-off is what
:mod:`repro.core.build.sah` exists to buy back, and what
``benchmarks/bench_build.py`` measures.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..bvh import (
    BVH4,
    DatapathConfig,
    bvh_depth,
    encode_nodes,
    fit_nodes,
    leaf_arrays,
    nondegenerate_mask,
    resolve_config,
)
from ..types import Box, Triangle, aabb_of_triangles
from . import register_builder


def _expand_bits(v: jax.Array) -> jax.Array:
    """Spread the low 10 bits of v so there are 2 zero bits between each."""
    u = jnp.uint32
    v = (v * u(0x00010001)) & u(0xFF0000FF)
    v = (v * u(0x00000101)) & u(0x0F00F00F)
    v = (v * u(0x00000011)) & u(0xC30C30C3)
    v = (v * u(0x00000005)) & u(0x49249249)
    return v


def morton3d(points01: jax.Array) -> jax.Array:
    """30-bit Morton codes for points in [0, 1]^3.  points01: (N, 3)."""
    scaled = jnp.clip(points01 * 1024.0, 0.0, 1023.0).astype(jnp.uint32)
    x = _expand_bits(scaled[:, 0])
    y = _expand_bits(scaled[:, 1])
    z = _expand_bits(scaled[:, 2])
    return (x << 2) | (y << 1) | z


def lbvh_leaf_perm(boxes: Box, depth: int, arity: int = 4) -> jax.Array:
    """Morton-order leaf-slot assignment over per-primitive AABBs.

    The primitive-agnostic core of the LBVH builder: everything up to the
    leaf-array scatter needs only each primitive's bounding box, so
    triangle soups and point clouds (:mod:`repro.core.build.points`,
    whose "boxes" are the points themselves) share it.  Returns the
    ``(arity**depth,)`` slot permutation (-1 = empty pad slot).
    """
    n = boxes.lo.shape[0]
    n_leaves = arity**depth
    centroid = 0.5 * (boxes.lo + boxes.hi)
    scene_lo = jnp.min(boxes.lo, axis=0)
    scene_hi = jnp.max(boxes.hi, axis=0)
    extent = jnp.maximum(scene_hi - scene_lo, 1e-12)
    codes = morton3d((centroid - scene_lo) / extent)

    order = jnp.argsort(codes).astype(jnp.int32)  # (N,)
    pad = n_leaves - n
    return jnp.concatenate([order, jnp.full((pad,), -1, jnp.int32)])


@register_builder("lbvh")
def build_bvh4(tri: Triangle, depth: int | None = None,
               config: DatapathConfig | None = None) -> BVH4:
    """Build a wide BVH over a triangle soup.  ``depth`` must be static if
    given; ``config`` picks the arity and node-box codec (default BVH4/fp32)."""
    config = resolve_config(config)
    n = tri.a.shape[0]
    if depth is None:
        depth = bvh_depth(n, config.arity)

    boxes = aabb_of_triangles(tri)
    leaf_perm = lbvh_leaf_perm(boxes, depth, config.arity)
    # degenerate cull: zero-area triangles become padded leaves (tri -1,
    # inverted box) so no engine can ever report them as hits
    leaf_tri, leaf_lo, leaf_hi = leaf_arrays(leaf_perm, boxes,
                                             nondegenerate_mask(tri))
    node_lo, node_hi = fit_nodes(leaf_lo, leaf_hi, depth, config.arity)
    node_lo, node_hi = encode_nodes(node_lo, node_hi, depth, config)
    return BVH4(node_lo=node_lo, node_hi=node_hi, leaf_tri=leaf_tri,
                triangles=tri, leaf_perm=leaf_perm)
