"""Tree-quality metrics: SAH cost + measured datapath jobs per ray.

Two complementary lenses on the same question — "how much datapath work
does this tree cost per query?":

* :func:`sah_cost` is the *model*: the classic Surface Area Heuristic
  expectation (box-test and triangle-test terms weighted by surface area
  relative to the root), computable from the tree alone in O(nodes).
* :func:`mean_jobs_per_ray` is the *measurement*: trace a probe batch and
  read back the per-ray ``quadbox_jobs`` / ``triangle_jobs`` counters the
  engines already maintain.  Deterministic, device-free (integer job
  counts, bit-identical across backends and shardings by the DESIGN.md §5
  contract) — which is exactly why it is the portable quality metric this
  repo optimises for, rather than wall-clock on whatever host CI lands on.

``Scene.stats()`` surfaces both as a :class:`TreeStats` record, and
``benchmarks/bench_build.py`` tracks them per builder across PRs.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from functools import partial

from ..bvh import BVH4, DatapathConfig, depth_of, level_offset, resolve_config
from ..types import Ray, Triangle, make_ray
from ..wavefront import trace_wavefront
from .sah import _half_area


class TreeStats(NamedTuple):
    """One builder's tree, summarised (``Scene.stats()``)."""

    builder: str
    n_triangles: int
    depth: int
    n_nodes: int
    n_leaves: int
    occupancy: float  # occupied fraction of the arity**depth leaf slots
    sah_cost: float  # model: SAH expectation relative to the root box
    mean_quadbox_jobs: float  # measured: box-test jobs per probe ray
    mean_triangle_jobs: float  # measured: OpTriangle jobs per probe ray
    mean_jobs: float  # the headline number: quadbox + triangle
    # --- per-config fields (DatapathConfig; DESIGN.md §12) ---
    arity: int  # BVH branching factor the tree was built at
    bytes_per_node: int  # analytic node-box storage (config codec)
    compression_ratio: float  # raw-f32 24 B/node over bytes_per_node
    mean_branching_factor: float  # mean live children per live internal node


def sah_cost(bvh: BVH4, c_box: float = 1.0, c_tri: float = 1.0,
             arity: int | None = None) -> float:
    """SAH expected traversal cost of the tree.

    ``sum_internal c_box * A(n) / A(root) + sum_leaf c_tri * A(l) / A(root)``
    with empty (inverted-box) nodes contributing zero.  Leaves hold one
    triangle each in this layout, so the triangle term needs no
    primitive-count weight.
    """
    arity = 4 if arity is None else arity
    depth = depth_of(bvh, arity)
    leaf_start = level_offset(depth, arity)
    area = _half_area(bvh.node_lo, bvh.node_hi)
    valid = jnp.all(bvh.node_hi >= bvh.node_lo, axis=-1)
    area = jnp.where(valid, area, 0.0)
    root_area = jnp.maximum(area[0], 1e-30)
    occupied = bvh.leaf_tri >= 0
    cost = (c_box * jnp.sum(area[:leaf_start])
            + c_tri * jnp.sum(area[leaf_start:] * occupied)) / root_area
    return float(cost)


def clustered_soup(rng, n_clusters: int = 8, per_cluster: int = 40):
    """The canonical non-uniform quality workload: tight triangle clusters
    flung across a wide volume, where Z-order leaf runs straddle clusters
    and SAH splits pay off.  One definition, so the margin
    ``tests/test_build.py`` asserts and the margin
    ``benchmarks/bench_build.py`` reports measure the same scene family."""
    centers = rng.uniform(-4, 4, (n_clusters, 3)).astype(np.float32)
    ctr = (np.repeat(centers, per_cluster, axis=0)
           + rng.normal(scale=0.06, size=(n_clusters * per_cluster, 3))
           ).astype(np.float32)
    d1 = rng.normal(scale=0.03, size=ctr.shape).astype(np.float32)
    d2 = rng.normal(scale=0.03, size=ctr.shape).astype(np.float32)
    return Triangle(a=jnp.asarray(ctr), b=jnp.asarray(ctr + d1),
                    c=jnp.asarray(ctr + d2))


def probe_rays(bvh: BVH4, n: int = 256, seed: int = 0) -> Ray:
    """A deterministic probe batch for job measurement: origins on a
    sphere outside the scene box, aimed at points inside it — every probe
    enters the tree, so the counters measure traversal, not misses."""
    rng = np.random.default_rng(seed)
    lo = np.asarray(bvh.node_lo[0])
    hi = np.asarray(bvh.node_hi[0])
    center = 0.5 * (lo + hi)
    radius = 1.25 * float(np.linalg.norm(hi - lo)) + 1e-3
    d = rng.normal(size=(n, 3)).astype(np.float32)
    d /= np.maximum(np.linalg.norm(d, axis=1, keepdims=True), 1e-12)
    org = (center + radius * d).astype(np.float32)
    tgt = rng.uniform(lo, hi, (n, 3)).astype(np.float32)
    return make_ray(jnp.asarray(org), jnp.asarray(tgt - org))


@partial(jax.jit, static_argnums=(2,))
def _probe_trace(bvh: BVH4, rays: Ray, config: DatapathConfig):
    rec = trace_wavefront(bvh, rays, depth_of(bvh, config.arity),
                          config=config)
    return rec.quadbox_jobs, rec.triangle_jobs


def mean_jobs_per_ray(bvh: BVH4, rays: Ray | None = None,
                      probes: int = 256,
                      config: DatapathConfig | None = None
                      ) -> tuple[float, float]:
    """Measured (mean box-test, mean OpTriangle) jobs per ray — the
    deterministic tree-quality metric.  Uses :func:`probe_rays` when no
    ray batch is given."""
    if rays is None:
        rays = probe_rays(bvh, probes)
    qb, tr = _probe_trace(bvh, rays, resolve_config(config))
    return float(jnp.mean(qb.astype(jnp.float32))), \
        float(jnp.mean(tr.astype(jnp.float32)))


def mean_branching_factor(bvh: BVH4, arity: int = 4) -> float:
    """Mean live (non-empty-box) children per live internal node — how
    full the tree keeps each box-test job's `arity` lanes."""
    depth = depth_of(bvh, arity)
    n_internal = level_offset(depth, arity)
    valid = jnp.all(bvh.node_hi >= bvh.node_lo, axis=-1)
    # children of internal node k are nodes arity*k+1 .. arity*k+arity,
    # contiguous and in parent order over nodes 1..num_nodes-1
    child_live = valid[1:].reshape(n_internal, arity).sum(axis=1)
    live_internal = valid[:n_internal]
    denom = jnp.maximum(jnp.sum(live_internal), 1)
    return float(jnp.sum(jnp.where(live_internal, child_live, 0)) / denom)


def tree_stats(bvh: BVH4, builder: str = "?", rays: Ray | None = None,
               probes: int = 256,
               config: DatapathConfig | None = None) -> TreeStats:
    """Everything :class:`TreeStats` reports, from one tree."""
    config = resolve_config(config)
    depth = depth_of(bvh, config.arity)
    n_leaves = int(bvh.leaf_tri.shape[0])
    occupied = int(jnp.sum(bvh.leaf_tri >= 0))
    qb, tr = mean_jobs_per_ray(bvh, rays, probes, config)
    return TreeStats(
        builder=builder,
        n_triangles=int(bvh.triangles.a.shape[0]),
        depth=depth,
        n_nodes=int(bvh.node_lo.shape[0]),
        n_leaves=n_leaves,
        occupancy=occupied / n_leaves,
        sah_cost=sah_cost(bvh, arity=config.arity),
        mean_quadbox_jobs=qb,
        mean_triangle_jobs=tr,
        mean_jobs=qb + tr,
        arity=config.arity,
        bytes_per_node=config.box_bytes_per_node,
        compression_ratio=24.0 / config.box_bytes_per_node,
        mean_branching_factor=mean_branching_factor(bvh, config.arity),
    )
