"""Binned-SAH -> BVH4: a pure-JAX, jittable top-down quality builder.

LBVH's Z-order curve treats space uniformly, so clustered soups get leaf
runs that straddle clusters and internal boxes with huge overlap — every
straddling box is extra OpQuadbox/OpTriangle jobs per ray.  The classic
answer is a top-down builder that greedily minimises the Surface Area
Heuristic over binned candidate planes.  The catch for this repo: the tree
must land in the *implicit complete 4-ary layout* every engine already
consumes, and the build must be jittable (static shapes, no recursion on
data-dependent sizes).

Both constraints fall to the same observation: in an implicit complete
tree the only degree of freedom a builder has is the **permutation of
triangles into leaf slots**.  A node at level ``l`` owns a contiguous
range of ``4**(depth-l)`` slots, so top-down construction is ``2*depth``
*binary* split rounds (two binary levels per 4-ary level — the 4-wide
split emerges from consecutive binary ones), where round ``j`` partitions
each of the ``2**j`` statically-known segments:

1. per-segment centroid bounds -> widest axis (``jax.ops.segment_min/max``
   with a static segment count);
2. bin every triangle's centroid into ``bins`` buckets along that axis;
   per-(segment, bin) counts and AABBs by one more segment reduction;
3. SAH sweep over the ``bins - 1`` candidate planes via prefix/suffix
   ``cummin``/``cummax`` box accumulations: ``cost(k) = N_L(k) A_L(k) +
   N_R(k) A_R(k)``;
4. turn the winning plane into a **rank split**: sort triangles within
   each segment by (bin, centroid), then send ranks ``< target`` left.
   The target is the plane's cumulative count *clamped to the child slot
   capacity* — the one concession to the complete layout (a clamp only
   binds when a child would overflow its ``4**level`` slot quarter, where
   it degrades toward a median split; otherwise the split is exactly the
   binned-SAH one).

After round ``2*depth`` every triangle holds a unique leaf slot; leaf
boxes scatter in and :func:`repro.core.bvh.fit_nodes` sweeps bottom-up,
identical to LBVH.  Everything is static-shaped in ``depth``, so the whole
builder jits once per (soup size, depth).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..bvh import (
    BVH4,
    DatapathConfig,
    bvh_depth,
    encode_nodes,
    fit_nodes,
    leaf_arrays,
    nondegenerate_mask,
    resolve_config,
)
from ..types import Box, Triangle, aabb_of_triangles
from . import register_builder

#: candidate planes per split = BINS - 1 (the usual 8-32 sweet spot)
BINS = 16


def _half_area(lo: jax.Array, hi: jax.Array) -> jax.Array:
    """Half surface area of boxes (..., 3); the SAH cost weight."""
    d = hi - lo
    return d[..., 0] * d[..., 1] + d[..., 1] * d[..., 2] + d[..., 2] * d[..., 0]


def sah_leaf_perm(boxes: Box, depth: int, bins: int = BINS,
                  arity: int = 4) -> jax.Array:
    """Binned-SAH leaf-slot assignment over per-primitive AABBs.

    The primitive-agnostic core of the SAH builder (steps 1-4 of the
    module docstring): the whole split recursion consumes only boxes and
    centroids, so triangle soups and point clouds
    (:mod:`repro.core.build.points`) share it.  The ``arity``-wide split
    emerges from ``log2(arity)`` consecutive binary rounds per tree level
    (2 for BVH4, 3 for BVH8).  Returns the ``(arity**depth,)`` slot
    permutation (-1 = empty pad slot).
    """
    n = boxes.lo.shape[0]
    n_leaves = arity**depth
    binary_rounds = depth * {4: 2, 8: 3}[arity]
    centroid = 0.5 * (boxes.lo + boxes.hi)
    tri_ids = jnp.arange(n, dtype=jnp.int32)

    # seg[i]: which node of the current binary level triangle i sits in
    seg = jnp.zeros((n,), jnp.int32)
    for level in range(binary_rounds):
        n_seg = 2**level  # static: the complete tree fixes the node count
        cap_child = n_leaves // 2**(level + 1)  # leaf slots per child

        # -- 1. per-segment centroid bounds -> split axis -----------------
        seg_lo = jax.ops.segment_min(centroid, seg, num_segments=n_seg)
        seg_hi = jax.ops.segment_max(centroid, seg, num_segments=n_seg)
        ext = seg_hi - seg_lo  # (n_seg, 3); empty segments are never indexed
        axis = jnp.argmax(ext, axis=-1).astype(jnp.int32)  # (n_seg,)

        # -- 2. bin centroids along each segment's axis -------------------
        c = jnp.take_along_axis(centroid, axis[seg][:, None], axis=1)[:, 0]
        lo_t = jnp.take_along_axis(seg_lo, axis[:, None], axis=1)[:, 0][seg]
        ext_t = jnp.take_along_axis(ext, axis[:, None], axis=1)[:, 0][seg]
        rel = (c - lo_t) / jnp.maximum(ext_t, 1e-12)
        b = jnp.clip((rel * bins).astype(jnp.int32), 0, bins - 1)  # (N,)

        sb = seg * bins + b
        counts = (jnp.zeros((n_seg * bins,), jnp.int32)
                  .at[sb].add(1).reshape(n_seg, bins))
        bin_lo = jax.ops.segment_min(
            boxes.lo, sb, num_segments=n_seg * bins).reshape(n_seg, bins, 3)
        bin_hi = jax.ops.segment_max(
            boxes.hi, sb, num_segments=n_seg * bins).reshape(n_seg, bins, 3)

        # -- 3. SAH sweep over the bins-1 candidate planes ----------------
        cum = jnp.cumsum(counts, axis=1)  # count through bin k
        n_l = cum[:, :-1]  # split after bin k, k = 0..bins-2
        n_r = cum[:, -1:] - n_l
        area_l = _half_area(jax.lax.cummin(bin_lo, axis=1)[:, :-1],
                            jax.lax.cummax(bin_hi, axis=1)[:, :-1])
        area_r = _half_area(
            jnp.flip(jax.lax.cummin(jnp.flip(bin_lo, 1), axis=1), 1)[:, 1:],
            jnp.flip(jax.lax.cummax(jnp.flip(bin_hi, 1), axis=1), 1)[:, 1:])
        # empty sides carry inverted (+-inf) boxes: mask their weight to 0
        cost = (n_l * jnp.where(n_l > 0, area_l, 0.0)
                + n_r * jnp.where(n_r > 0, area_r, 0.0))
        k_best = jnp.argmin(cost, axis=1).astype(jnp.int32)  # (n_seg,)

        # -- 4. rank split, clamped to the child slot capacity ------------
        seg_cnt = cum[:, -1]
        target = jnp.take_along_axis(cum, k_best[:, None], axis=1)[:, 0]
        target = jnp.clip(target, jnp.maximum(seg_cnt - cap_child, 0),
                          jnp.minimum(seg_cnt, cap_child))
        # stable two-pass argsort = order by (segment, bin, centroid)
        o1 = jnp.argsort(c, stable=True)
        order = o1[jnp.argsort(sb[o1], stable=True)]
        pos = jnp.zeros((n,), jnp.int32).at[order].set(tri_ids)
        starts = jnp.cumsum(seg_cnt) - seg_cnt  # exclusive segment starts
        rank = pos - starts[seg]
        seg = 2 * seg + (rank >= target[seg]).astype(jnp.int32)

    # seg is now a unique leaf slot per primitive (capacity clamps enforce
    # <= 1 per slot); scatter the assignment in
    return jnp.full((n_leaves,), -1, jnp.int32).at[seg].set(tri_ids)


@register_builder("sah")
def build_sah(tri: Triangle, depth: int | None = None,
              config: DatapathConfig | None = None,
              bins: int = BINS) -> BVH4:
    """Build a wide BVH with binned-SAH splits.  ``depth``/``config``/
    ``bins`` are static."""
    config = resolve_config(config)
    n = tri.a.shape[0]
    if depth is None:
        depth = bvh_depth(n, config.arity)

    boxes = aabb_of_triangles(tri)
    leaf_perm = sah_leaf_perm(boxes, depth, bins, config.arity)
    leaf_tri, leaf_lo, leaf_hi = leaf_arrays(leaf_perm, boxes,
                                             nondegenerate_mask(tri))
    node_lo, node_hi = fit_nodes(leaf_lo, leaf_hi, depth, config.arity)
    node_lo, node_hi = encode_nodes(node_lo, node_hi, depth, config)
    return BVH4(node_lo=node_lo, node_hi=node_hi, leaf_tri=leaf_tri,
                triangles=tri, leaf_perm=leaf_perm)
