"""Refit: O(depth) AABB update for dynamic scenes, topology preserved.

Animating a scene by rebuilding pays the full builder (a sort or a SAH
sweep) *and* — far worse for a jitted pipeline — a fresh tree means fresh
constants unless the engine threads the BVH as a runtime argument.  Refit
is the classic cheap alternative (CrossRT's ``update`` verb): keep the
triangle-to-leaf assignment exactly as built and re-sweep only the AABBs
bottom-up — ``depth`` vectorised 4-to-1 reductions, the same
:func:`~repro.core.bvh.fit_nodes` every builder ends with.

Because the leaf permutation, array shapes and static depth are all
unchanged, a refit BVH4 is *pytree-compatible* with its build: every
compiled trace re-enters the existing jit cache with **zero retracing**
(``Scene.refit``; asserted by the tracing-counter test in
``tests/test_build.py``).  With identical triangles the output is
bit-identical to a fresh build by the same builder; under motion the
boxes stay exactly fitted (refit recomputes them from scratch — no
monotone growth across frames), only the *topology* quality decays as
triangles migrate away from where the builder placed them.

The degenerate cull stays frame-accurate: the BVH4 carries the builder's
pre-cull slot assignment (``leaf_perm``), so each refit re-evaluates the
zero-area mask for the *current* vertices — a triangle that collapses
under motion disappears exactly as a rebuild would cull it, and one that
was degenerate at build time reappears the moment motion gives it area.
"""
from __future__ import annotations

from ..bvh import (
    BVH4,
    DatapathConfig,
    depth_of,
    encode_nodes,
    fit_nodes,
    leaf_arrays,
    nondegenerate_mask,
    resolve_config,
)
from ..types import Triangle, aabb_of_triangles


def refit(bvh: BVH4, triangles: Triangle,
          config: DatapathConfig | None = None) -> BVH4:
    """Re-fit ``bvh``'s boxes around ``triangles``, keeping its topology.

    ``triangles`` must be the same soup with moved vertices (same count,
    same order — index ``i`` still means triangle ``i``).  Jittable; the
    depth is recovered statically from the leaf array length.  ``config``
    must match the build's config: the arity fixes the implicit layout and
    the node-box codec is re-applied each frame, so a refit frame encodes
    exactly as a fresh build of the moved soup would.
    """
    config = resolve_config(config)
    n = triangles.a.shape[0]
    n_built = bvh.triangles.a.shape[0]
    if n != n_built:
        raise ValueError(
            f"refit needs the built soup's {n_built} triangles, got {n} "
            "(topology is preserved -- rebuild to change the soup)")
    depth = depth_of(bvh, config.arity)

    leaf_tri, leaf_lo, leaf_hi = leaf_arrays(
        bvh.leaf_perm, aabb_of_triangles(triangles),
        nondegenerate_mask(triangles))
    node_lo, node_hi = fit_nodes(leaf_lo, leaf_hi, depth, config.arity)
    node_lo, node_hi = encode_nodes(node_lo, node_hi, depth, config)
    return BVH4(node_lo=node_lo, node_hi=node_hi, leaf_tri=leaf_tri,
                triangles=triangles, leaf_perm=bvh.leaf_perm)
