"""Point-cloud acceleration structures: AABB-per-point leaves (RTNN).

RTNN's observation is that neighbor search *is* traversal: wrap every
point in a degenerate AABB (lo == hi == the point), build the usual box
tree over those leaves, and a fixed-radius query becomes an extent-limited
walk — exactly the shape the datapath's OpQuadbox/OpEuclidean units
already serve.  This module maps point clouds onto the repo's existing
construction subsystem:

* the **leaf-slot assignment** reuses the triangle builders' primitive-
  agnostic cores (:func:`~repro.core.build.lbvh.lbvh_leaf_perm`,
  :func:`~repro.core.build.sah.sah_leaf_perm` — both consume only
  per-primitive boxes/centroids), so LBVH vs SAH stays a quality knob for
  clouds exactly as for soups;
* the result is an ordinary :class:`~repro.core.bvh.BVH4` — the point is
  stored at all three ``triangles`` vertices so every BVH4 consumer
  (packers, refit, stats plumbing) sees a structurally valid soup, and
  the neighbor engines read the cloud back as ``bvh.triangles.a``;
* the one divergence from the triangle path is the **degenerate cull**:
  a point-leaf is *always* zero-area, so the builders' zero-area mask
  would cull the entire cloud.  Point builds/refits pass an all-live
  mask instead (:func:`build_point_bvh` / :func:`refit_points`).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..bvh import (
    BVH4,
    DatapathConfig,
    bvh4_depth,
    depth_of,
    encode_nodes,
    fit_nodes,
    leaf_arrays,
    resolve_config,
)
from ..types import Box, Triangle
from . import BuildResult
from .lbvh import lbvh_leaf_perm
from .sah import sah_leaf_perm

# the primitive-agnostic slot-assignment cores shared with the triangle
# builders (same registry names, so ``builder=`` means the same thing for
# Scene.from_triangles and PointCloudScene.from_points)
POINT_BUILDERS = {
    "lbvh": lbvh_leaf_perm,
    "sah": sah_leaf_perm,
}


def point_boxes(points: jax.Array) -> Box:
    """Degenerate AABB per point (lo == hi == the point): the RTNN mapping
    of a point cloud onto box-tree primitives."""
    return Box(lo=points, hi=points)


def _point_soup(points: jax.Array) -> Triangle:
    """Store each point at all three vertices so the BVH4 record stays a
    structurally valid soup; neighbor engines read points as ``.a``."""
    return Triangle(points, points, points)


def _check_points(points: jax.Array, where: str) -> jax.Array:
    points = jnp.asarray(points, jnp.float32)
    if points.ndim != 2 or points.shape[-1] != 3:
        raise ValueError(
            f"{where}: expected an (N, 3) point cloud, got "
            f"{tuple(points.shape)} (the tree path is the 3-D RTNN "
            "mapping; higher-dimensional data stays on the brute path)")
    return points


def _check_point_config(config, where: str) -> DatapathConfig:
    """Point clouds accept the node-box codec knobs but stay 4-wide: the
    neighbor engines traverse the paper's fixed quad-box datapath.  The
    codecs are safe here — membership is decided by exact point distance
    at the leaves, so conservatively widened boxes only add visited nodes,
    never neighbors."""
    config = resolve_config(config)
    if config.arity != 4:
        raise ValueError(
            f"{where}: point-cloud trees are 4-wide (the neighbor engines "
            f"traverse the quad-box datapath); got arity={config.arity}")
    return config


def build_point_bvh(points: jax.Array, builder: str = "lbvh",
                    depth: int | None = None,
                    config: DatapathConfig | None = None) -> BuildResult:
    """Build a BVH4 over a point cloud with a registered builder core.

    ``depth`` must be static; it defaults to the smallest depth whose
    ``4**depth`` leaf slots fit the cloud.  Jittable per (size, depth).
    """
    points = _check_points(points, "build_point_bvh")
    config = _check_point_config(config, "build_point_bvh")
    n = points.shape[0]
    if builder not in POINT_BUILDERS:
        raise ValueError(f"unknown point builder {builder!r} "
                         f"(registered: {tuple(POINT_BUILDERS)})")
    if depth is None:
        depth = bvh4_depth(n)
    if 4**depth < n:
        raise ValueError(
            f"depth={depth} gives {4**depth} leaf slots < {n} points")

    boxes = point_boxes(points)
    leaf_perm = POINT_BUILDERS[builder](boxes, depth)
    # every point is live: the triangle zero-area cull must NOT apply
    # (a point's box is legitimately degenerate)
    leaf_tri, leaf_lo, leaf_hi = leaf_arrays(leaf_perm, boxes,
                                             jnp.ones((n,), bool))
    node_lo, node_hi = fit_nodes(leaf_lo, leaf_hi, depth)
    node_lo, node_hi = encode_nodes(node_lo, node_hi, depth, config)
    bvh = BVH4(node_lo=node_lo, node_hi=node_hi, leaf_tri=leaf_tri,
               triangles=_point_soup(points), leaf_perm=leaf_perm)
    return BuildResult(bvh=bvh, builder=builder, depth=depth, config=config)


def refit_points(bvh: BVH4, points: jax.Array,
                 config: DatapathConfig | None = None) -> BVH4:
    """Topology-preserving refit for a moved cloud (same count, same order).

    The triangle :func:`~repro.core.build.refit.refit` re-evaluates the
    zero-area cull each frame — which would cull every point — so clouds
    refit through this cull-free twin.  Same zero-retrace contract: all
    shapes and the leaf permutation are preserved, so a refit BVH4 is
    pytree-compatible with its build.
    """
    points = _check_points(points, "refit_points")
    config = _check_point_config(config, "refit_points")
    n_built = bvh.triangles.a.shape[0]
    if points.shape[0] != n_built:
        raise ValueError(
            f"refit_points needs the built cloud's {n_built} points, got "
            f"{points.shape[0]} (topology is preserved -- rebuild to "
            "change the cloud)")
    depth = depth_of(bvh)

    boxes = point_boxes(points)
    leaf_tri, leaf_lo, leaf_hi = leaf_arrays(bvh.leaf_perm, boxes,
                                             jnp.ones((n_built,), bool))
    node_lo, node_hi = fit_nodes(leaf_lo, leaf_hi, depth)
    node_lo, node_hi = encode_nodes(node_lo, node_hi, depth, config)
    return BVH4(node_lo=node_lo, node_hi=node_hi, leaf_tri=leaf_tri,
                triangles=_point_soup(points), leaf_perm=bvh.leaf_perm)
