"""Traversal-backed neighbor search: kNN/radius queries *on* the BVH walk.

The paper's thesis is that one RT datapath serves both tracing and
distance workloads; RTNN closes the loop by showing neighbor search can
run on the *traversal* side of that datapath rather than as brute-force
pairwise scoring.  The mapping (mirrored from the builders' side in
:mod:`repro.core.build.points`):

* each database point is an AABB-per-point leaf of an ordinary
  :class:`~repro.core.bvh.BVH4`;
* a query is a :class:`~repro.core.types.Ray` whose ``extent`` is the
  search radius (direction is irrelevant — traversal orders by *box
  distance*, :func:`~repro.core.datapath.point_box_test`, the neighbor
  twin of OpQuadbox);
* a leaf visit issues OpEuclidean-style jobs against <=4 candidate
  points and folds them into a per-query sorted top-k insertion network
  (the QuadSort analogue for running best lists).

Two engines share this module's stage helpers, exactly like the trace
side: :func:`neighbor_wavefront` here (batch-level frontier loop) and the
fused Pallas kernel in :mod:`repro.kernels.traverse` — so their leaf
arithmetic is bit-identical by construction.

Oracle contract
---------------
The brute-force :mod:`repro.core.knn` path stays the bit-level oracle
for the in-radius set: :func:`leaf_dist_sq` reproduces the MXU scoring
form ``max(||q||^2 - 2 q.c + ||c||^2, 0)`` term-for-term, so the leaf
acceptance test ``d^2 <= r^2`` is the *same float comparison* the oracle
makes.  Node pruning, by contrast, uses geometric box distance — a
different computation — so the pruning bound carries conservative slack
(:data:`PRUNE_SLACK`): a too-loose bound only costs extra visits, never
a missed in-radius point.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .bvh import BVH4, child_boxes, level_offset
from .datapath import fmin, point_box_test
from .traversal import STACK_SIZE
from .types import Ray, make_ray

NEIGHBOR_MODES = ("within", "nearest")

#: Relative + scaled-absolute slack on the node-pruning bound.  The brute
#: MXU form loses ~eps * (||q||^2 + ||c||^2) to cancellation, so a point
#: the oracle counts as in-radius can have geometric box distance a hair
#: *above* r^2.  bound = b*(1+S) + S*||q||^2 with S = 1e-5 >> f32 eps
#: covers that gap with orders of magnitude to spare; the cost is a few
#: extra node visits near the boundary, never a correctness loss.
PRUNE_SLACK = 1e-5


class NeighborRecord(NamedTuple):
    """Per-query results plus the frontier-level scheduling statistics."""

    dist_sq: jax.Array  # (R, k) f32 squared distances, ascending, inf pad
    index: jax.Array  # (R, k) i32 database indices, -1 pad
    valid: jax.Array  # (R, k) bool slot holds a real neighbor
    count: jax.Array  # (R,) i32 exact in-radius count ("within" mode)
    box_jobs: jax.Array  # (R,) i32 per-query point-box jobs issued
    point_jobs: jax.Array  # (R,) i32 per-query point-distance jobs issued
    rounds: jax.Array  # ()   i32 batched rounds


def point_queries(points: jax.Array, radius=None) -> Ray:
    """Wrap query points as extent-limited "rays" for the neighbor engines.

    The direction is a dummy +x axis: neighbor traversal never consumes
    it (ordering comes from box distance), but packing a full Ray keeps
    every downstream pipe — dispatch padding, the Pallas ray operand
    layout — identical to the trace path.
    """
    points = jnp.asarray(points, jnp.float32)
    direction = jnp.broadcast_to(
        jnp.asarray([1.0, 0.0, 0.0], jnp.float32), points.shape)
    extent = jnp.inf if radius is None else radius
    return make_ray(points, direction, extent)


def leaf_dist_sq(p: jax.Array, pts: jax.Array,
                 p_sq_norms: jax.Array) -> jax.Array:
    """Query-to-candidate squared distances in the oracle's exact form.

    p: (..., 3) queries; pts: (..., 4, 3) candidates; p_sq_norms:
    (..., 4) precomputed ``||c||^2``.  This is term-for-term the brute
    path's MXU expression ``max(||q||^2 - 2 q.c + ||c||^2, 0)`` so tree
    leaf acceptance and the oracle make the *same float comparison*.
    """
    q2 = jnp.sum(p * p, axis=-1)
    qc = jnp.sum(p[..., None, :] * pts, axis=-1)
    return jnp.maximum(q2[..., None] - 2.0 * qc + p_sq_norms, 0.0)


def insert_sorted(best_d: jax.Array, best_i: jax.Array, d: jax.Array,
                  i: jax.Array, accept: jax.Array):
    """One compare-shift-insert beat of the running top-k network.

    best_d/best_i: (k, L) sorted-ascending running lists (inf / -1 in
    empty slots); d/i/accept: (L,) one candidate per lane.  An accepted
    candidate lands in its rank slot and everything below shifts down one
    — the sequential-insertion analogue of the QuadSort network, O(k)
    comparators per beat with no data-dependent control flow.
    """
    ins = accept[None, :] & (d[None, :] < best_d)  # monotone down the k axis
    first = ins & ~jnp.concatenate(
        [jnp.zeros_like(ins[:1]), ins[:-1]], axis=0)
    shift_d = jnp.concatenate([best_d[:1], best_d[:-1]], axis=0)
    shift_i = jnp.concatenate([best_i[:1], best_i[:-1]], axis=0)
    new_d = jnp.where(first, d[None, :], jnp.where(ins, shift_d, best_d))
    new_i = jnp.where(first, i[None, :], jnp.where(ins, shift_i, best_i))
    return new_d, new_i


def prune_bound(r_sq: jax.Array, kth_best: jax.Array, q_sq: jax.Array,
                mode: str) -> jax.Array:
    """Node-visit bound: a child is pushed iff its box distance is <= this.

    ``"within"`` prunes on the radius alone (every in-radius point must
    be found — the k-th best can't shrink the search).  ``"nearest"``
    additionally contracts to the current k-th best distance once the
    list fills.  The slack term keeps the geometric bound conservative
    w.r.t. the oracle's MXU-form arithmetic (see :data:`PRUNE_SLACK`);
    the form ``b*(1+S) + S*q^2`` is inf-safe (no subtraction).
    """
    b = r_sq if mode == "within" else fmin(r_sq, kth_best)
    return b * (1.0 + PRUNE_SLACK) + PRUNE_SLACK * q_sq


def neighbor_wavefront(bvh: BVH4, sq_norms: jax.Array, queries: Ray,
                       depth: int, k: int, mode: str = "within",
                       max_rounds: int | None = None) -> NeighborRecord:
    """Batch-level neighbor traversal (the wavefront engine's distance twin).

    ``bvh`` must be a point BVH (:func:`~repro.core.build.points.
    build_point_bvh`): the cloud is read back as ``bvh.triangles.a`` and
    ``sq_norms`` are its precomputed ``||c||^2`` (pass
    ``knn.squared_norms(bvh.triangles.a)`` — derived from the *same*
    array the tree holds, so refits can't serve stale norms).

    ``queries`` carry the radius as ``extent`` (:func:`point_queries`);
    ``k``/``mode``/``max_rounds`` are static.  Like
    :func:`~repro.core.wavefront.trace_wavefront`, each round pops the
    whole active frontier, issues one batched point-box job and one
    batched round of <=4 point-distance jobs, and pushes surviving
    children far-to-near so the nearest child is explored first.
    """
    if mode not in NEIGHBOR_MODES:
        raise ValueError(
            f"mode must be one of {NEIGHBOR_MODES}, got {mode!r}")
    if k < 1:
        raise ValueError(f"k must be >= 1, got {k}")
    leaf_parent_offset = level_offset(depth - 1)
    leaf_offset = level_offset(depth)
    if max_rounds is None:
        max_rounds = level_offset(depth)  # = number of internal nodes

    points = bvh.triangles.a
    p = queries.origin  # (R, 3)
    r_sq = queries.extent * queries.extent  # inf extent -> inf bound
    q_sq = jnp.sum(p * p, axis=-1)
    n_q = p.shape[0]
    rows = jnp.arange(n_q, dtype=jnp.int32)

    stack0 = jnp.zeros((n_q, STACK_SIZE), jnp.int32)  # root pre-pushed
    state0 = (stack0, jnp.ones((n_q,), jnp.int32),
              jnp.full((k, n_q), jnp.inf, jnp.float32),
              jnp.full((k, n_q), -1, jnp.int32),
              jnp.zeros((n_q,), jnp.int32),
              jnp.zeros((n_q,), jnp.int32), jnp.zeros((n_q,), jnp.int32),
              jnp.int32(0))

    def cond(state):
        _, sp, _, _, _, _, _, rounds = state
        return jnp.any(sp > 0) & (rounds < max_rounds)

    def body(state):
        stack, sp, best_d, best_i, count, n_box, n_pt, rounds = state
        active = sp > 0

        # frontier pop (masked compaction, as in trace_wavefront)
        node = jnp.where(active, stack[rows, jnp.maximum(sp - 1, 0)], 0)
        sp = jnp.where(active, sp - 1, sp)
        is_leaf_parent = node >= leaf_parent_offset

        # ---- one batched point-box job over the whole frontier ----------
        pb = point_box_test(p, child_boxes(bvh, node))

        # ---- batched point-distance round for the leaf-parent queries ---
        leaf_pos = (4 * node[:, None] + 1 - leaf_offset
                    + jnp.arange(4, dtype=jnp.int32))
        leaf_pos = jnp.clip(leaf_pos, 0, bvh.leaf_tri.shape[0] - 1)
        cand = bvh.leaf_tri[leaf_pos]  # (R, 4), -1 = padded leaf
        safe = jnp.maximum(cand, 0)
        d_sq = leaf_dist_sq(p, points[safe], sq_norms[safe])  # (R, 4)
        in_r = (active[:, None] & is_leaf_parent[:, None]
                & (cand >= 0) & (d_sq <= r_sq[:, None]))
        count = count + jnp.sum(in_r, axis=1)
        for c in range(4):  # static: 4 insertion beats per round
            best_d, best_i = insert_sorted(
                best_d, best_i, d_sq[:, c], cand[:, c], in_r[:, c])

        # ---- push surviving children far-to-near ------------------------
        bound = prune_bound(r_sq, best_d[k - 1], q_sq, mode)

        def push_child(c, carry):
            stack, sp = carry
            slot = 3 - c  # reverse order: farthest first, nearest on top
            ok = (active & ~is_leaf_parent
                  & (pb.dist_sq[:, slot] <= bound))
            child = 4 * node + 1 + pb.box_index[:, slot]
            pos = jnp.minimum(sp, STACK_SIZE - 1)
            cur = stack[rows, pos]
            stack = stack.at[rows, pos].set(jnp.where(ok, child, cur))
            sp = jnp.where(ok, sp + 1, sp)
            return stack, sp

        stack, sp = jax.lax.fori_loop(0, 4, push_child, (stack, sp))
        n_box = n_box + active.astype(jnp.int32)
        n_pt = n_pt + jnp.where(active & is_leaf_parent, 4, 0)
        return stack, sp, best_d, best_i, count, n_box, n_pt, rounds + 1

    (_, _, best_d, best_i, count, n_box, n_pt, rounds) = jax.lax.while_loop(
        cond, body, state0)
    return NeighborRecord(dist_sq=best_d.T, index=best_i.T,
                          valid=(best_i >= 0).T, count=count,
                          box_jobs=n_box, point_jobs=n_pt, rounds=rounds)
