"""Unified datapath stream: heterogeneous in-order job processing.

This is the JAX mirror of the paper's top-level ``UnifiedDatapath`` module:
jobs of all four opcodes enter one pipeline in order; per-mode accumulators
persist across (and only across) jobs of their own mode, so multi-beat
Euclidean/angular jobs can be interleaved with box/triangle work "over an
indefinite time frame" (Table V).

Two execution strategies, same semantics:

* :func:`unified_stream` — a ``lax.scan`` over jobs.  Exactly reproduces the
  hardware's in-order accumulator behaviour; this is the oracle the tests and
  the Pallas unified kernel are validated against.
* For throughput work, use the batched per-mode ops in ``repro.core.datapath``
  or the Pallas kernels (``repro.kernels``) which group jobs by opcode per
  tile — the TPU analogue of the shared-FU pipeline (see DESIGN.md §2).

Like the paper's single union bundle type (§III-C), :class:`DatapathJob`
carries every mode's fields; XLA dead-code-eliminates unused ones per
program, exactly as the Chisel compiler prunes dead bundle fields.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .datapath import angular_partial, euclidean_partial, ray_box_test, ray_triangle_test
from .types import (
    OP_ANGULAR,
    OP_EUCLIDEAN,
    OP_QUADBOX,
    OP_TRIANGLE,
    VECTOR_LANES,
    Box,
    DatapathState,
    Ray,
    Triangle,
    init_datapath_state,
)


class DatapathJob(NamedTuple):
    """Union input bundle (Table V inputs), batched over a leading axis."""

    opcode: jax.Array  # (N,) i32
    ray: Ray  # fields (N, ...) -- used by OpTriangle / OpQuadbox
    boxes: Box  # (N, 4, 3) -- OpQuadbox
    triangle: Triangle  # (N, 3) -- OpTriangle
    vec_a: jax.Array  # (N, 16) -- OpEuclidean (a) / OpAngular (q, lanes 0..7)
    vec_b: jax.Array  # (N, 16) -- OpEuclidean (b) / OpAngular (c, lanes 0..7)
    mask: jax.Array  # (N, 16) bool
    reset_accum: jax.Array  # (N,) bool


class DatapathOutput(NamedTuple):
    """Union output bundle (Table V outputs).  Fields are valid per-opcode."""

    opcode: jax.Array  # (N,)
    # OpQuadbox
    tmin: jax.Array  # (N, 4) sorted
    box_index: jax.Array  # (N, 4)
    is_intersect: jax.Array  # (N, 4) bool
    # OpTriangle
    t_num: jax.Array  # (N,)
    t_denom: jax.Array  # (N,)
    triangle_hit: jax.Array  # (N,) bool
    # OpEuclidean
    euclidean_accumulator: jax.Array  # (N,)
    # OpAngular
    angular_dot_product: jax.Array  # (N,)
    angular_norm: jax.Array  # (N,)
    reset_accum: jax.Array  # (N,) bool (propagated)


def make_jobs(n: int) -> DatapathJob:
    """An all-zero job batch to be filled in (convenience for tests/benches)."""
    f = jnp.zeros
    ray = Ray(
        origin=f((n, 3), jnp.float32), direction=jnp.ones((n, 3), jnp.float32),
        inv=jnp.ones((n, 3), jnp.float32), extent=jnp.full((n,), jnp.inf),
        kx=f((n,), jnp.int32), ky=f((n,), jnp.int32), kz=f((n,), jnp.int32),
        shear=jnp.ones((n, 3), jnp.float32))
    return DatapathJob(
        opcode=f((n,), jnp.int32), ray=ray,
        boxes=Box(f((n, 4, 3), jnp.float32), f((n, 4, 3), jnp.float32)),
        triangle=Triangle(f((n, 3), jnp.float32), f((n, 3), jnp.float32), f((n, 3), jnp.float32)),
        vec_a=f((n, VECTOR_LANES), jnp.float32), vec_b=f((n, VECTOR_LANES), jnp.float32),
        mask=jnp.ones((n, VECTOR_LANES), bool), reset_accum=f((n,), bool))


def _job_compute(state: DatapathState, job: DatapathJob):
    """One pipeline traversal: all four mode datapaths run on the shared FUs;
    outputs and accumulator updates are selected by opcode (Table V validity).
    """
    op = job.opcode
    qb = ray_box_test(job.ray, job.boxes)
    tr = ray_triangle_test(job.ray, job.triangle)
    e_partial = euclidean_partial(job.vec_a, job.vec_b, job.mask)
    a_dot, a_nrm = angular_partial(job.vec_a, job.vec_b, job.mask)

    reset = job.reset_accum
    is_e = op == OP_EUCLIDEAN
    is_a = op == OP_ANGULAR

    e_in = jnp.where(reset, 0.0, state.euclid_accum)
    d_in = jnp.where(reset, 0.0, state.dot_accum)
    n_in = jnp.where(reset, 0.0, state.norm_accum)

    e_out = e_partial + e_in
    d_out = a_dot + d_in
    n_out = a_nrm + n_in

    # Per-mode accumulator isolation: a mode's accumulator only moves when a
    # job of that mode passes through.
    new_state = DatapathState(
        euclid_accum=jnp.where(is_e, e_out, state.euclid_accum),
        dot_accum=jnp.where(is_a, d_out, state.dot_accum),
        norm_accum=jnp.where(is_a, n_out, state.norm_accum),
    )
    out = DatapathOutput(
        opcode=op,
        tmin=qb.tmin, box_index=qb.box_index, is_intersect=qb.is_intersect,
        t_num=tr.t_num, t_denom=tr.t_denom, triangle_hit=tr.hit,
        euclidean_accumulator=e_out,
        angular_dot_product=d_out, angular_norm=n_out,
        reset_accum=reset,
    )
    return new_state, out


def unified_stream(jobs: DatapathJob, state: DatapathState | None = None):
    """Process a job stream in order; returns (final_state, outputs).

    jobs: leading axis N = time order (one job per initiation interval).
    """
    if state is None:
        state = init_datapath_state()

    def step(carry, job):
        return _job_compute(carry, job)

    return jax.lax.scan(step, state, jobs)


unified_stream_jit = jax.jit(unified_stream)
