"""Session-style query API: one surface over every query engine.

The paper's core design concept is the "defined-once-instantiated-
everywhere" shared datapath: all four opcodes flow through one job/result
schema on shared functional units.  This module applies the same idea one
layer up, at the *query API* (DESIGN.md §5).  Instead of every call site
threading ``(bvh, depth)`` by hand, recomputing ``||c||^2`` per query
batch, and hand-rolling its own ``jax.jit`` wrapper, a session is built
once and queried many times — the RTNN/CrossRT model of declaring queries
against a prepared acceleration structure:

* :class:`Scene` — built once from a triangle soup; owns the ``BVH4``, its
  static ``depth``, and device placement.  Construction is pluggable
  (``builder="lbvh" | "sah"``, the :mod:`repro.core.build` registry,
  DESIGN.md §7), geometry is updatable in place (``Scene.refit`` — zero
  retraces per animation frame, because every trace backend threads the
  BVH as a runtime argument rather than a closure constant), and
  ``Scene.stats()`` reports tree quality (SAH cost + measured jobs/ray).
* :class:`VectorIndex` — built once from a database matrix; owns the
  precomputed ``||c||^2`` norms reused by every distance query.
* :class:`QueryEngine` — the single typed entry point
  (``trace`` / ``nearest`` / ``within`` / ``count_within`` / ``scores``),
  with a pluggable backend registry (``"per_ray"`` oracle, ``"wavefront"``,
  ``"pallas"`` — the fused traversal kernel for traces, the tiled distance
  kernels for scores, DESIGN.md §8 — and ``"auto"``), per-(shape, backend, query)
  compiled-function caching modeled on ``serving/engine.py``, and
  automatic pad-to-lane-multiple batching with result unpadding — the
  padding policy defined once instead of ad hoc in every example.

Execution placement and scheduling live one layer down, in
:mod:`repro.core.dispatch` (DESIGN.md §6): ``shard="auto" | int`` fans a
batch data-parallel across a device mesh (scene/index replicated, rays /
queries row-sharded) and ``chunk_size=`` streams it through fixed-size
microbatch blocks that all re-enter one compiled function.  Both knobs
compose with the padding policy and preserve the bit-parity contract —
the per-shard computation is literally the single-device computation on a
row subset (``tests/test_fuzz_backends.py`` fuzzes the equivalence).

Every backend returns the same result record (:class:`TraceResult`,
:class:`NearestResult`, :class:`WithinResult`), and results are
*bit-identical* to the legacy free functions (``trace_rays``,
``trace_wavefront``, ``knn``, ``radius_search``) — enforced by
``tests/test_session.py`` — so the free functions remain the oracles and
the engine remains swappable.
"""
from __future__ import annotations

import math
import time
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from ..obs.metrics import default_registry as _obs_registry
from ..obs.trace import annotate as _obs_annotate
from .build import build as build_structure
from .build import refit as refit_bvh
from .build import tree_stats
from .build.points import build_point_bvh, refit_points
from .build.quality import TreeStats
from .bvh import BVH4, DEFAULT_CONFIG, DatapathConfig, resolve_config
from .dispatch import (
    ExecPlan,
    check_count,
    concat_rows,
    make_plan,
    replicated,
    resolve_shards,
    shard_rows,
    shard_rows_ctx,
    split_blocks,
)
from .knn import (
    METRICS,
    RADIUS_METRICS,
    angular_scores,
    check_k,
    check_radius,
    cosine_epilogue,
    cosine_similarity,
    count_within_scores,
    knn,
    pairwise_scores,
    radius_count,
    radius_search,
    select_topk,
    select_within,
    squared_norms,
)
from .neighbor import NeighborRecord, neighbor_wavefront, point_queries
from .traversal import trace_rays
from .types import Triangle
from .wavefront import RAY_TYPES, SHADOW_T_MIN, trace_wavefront

__all__ = [
    "CacheInfo",
    "NearestResult",
    "NeighborRecord",
    "PointCloudScene",
    "QueryEngine",
    "Scene",
    "TraceResult",
    "VectorIndex",
    "WithinResult",
    "default_pad_multiple",
    "distance_backends",
    "neighbor_backends",
    "register_distance_backend",
    "register_neighbor_backend",
    "register_trace_backend",
    "trace_backend_ray_types",
    "trace_backends",
]


# ---------------------------------------------------------------------------
# Telemetry (DESIGN.md §11): instruments are resolved once at import so the
# recording sites are pre-bound; with the registry disabled (the default)
# every site below is one attribute check + branch and records nothing —
# results are bit-identical either way (tests/test_obs.py pins this).
# ---------------------------------------------------------------------------

_OBS = _obs_registry()
_OBS_CACHE_HITS = _OBS.counter("engine.cache.hits")
_OBS_CACHE_MISSES = _OBS.counter("engine.cache.misses")
_OBS_ROWS_REAL = _OBS.counter("engine.rows.real")
_OBS_ROWS_PADDED = _OBS.counter("engine.rows.padded")
_OBS_CHUNKS = _OBS.counter("engine.chunks")
_OBS_SHARDS = _OBS.gauge("engine.shards")


# ---------------------------------------------------------------------------
# Shared result records (one schema per query kind, whatever the backend)
# ---------------------------------------------------------------------------


class TraceResult(NamedTuple):
    """Unified traversal result: identical fields for every trace backend."""

    t: jax.Array  # (R,) f32  hit distance (inf = miss)
    tri_index: jax.Array  # (R,) i32  index into the soup, -1 = miss
    hit: jax.Array  # (R,) bool
    quadbox_jobs: jax.Array  # (R,) i32  per-ray box-test jobs issued
    triangle_jobs: jax.Array  # (R,) i32  per-ray OpTriangle jobs issued
    stack_overflow: jax.Array  # (R,) bool  a push was dropped at capacity
    rounds: jax.Array  # ()   i32  batch-level rounds (= max per-ray jobs)


class NearestResult(NamedTuple):
    """k-nearest result: scores ascending (euclidean) / descending (angular,
    cosine), indices into the database.

    ``valid`` masks the slots that hold a real neighbor — ``k`` is clamped
    to the database size, so with ``k > N`` the trailing slots carry the
    pad convention (inf / -inf score, index -1) and ``valid`` is False."""

    scores: jax.Array  # (M, k) f32
    indices: jax.Array  # (M, k) i32
    valid: jax.Array  # (M, k) bool  which slots hold real neighbors


class WithinResult(NamedTuple):
    """Fixed-radius result: top-k by proximity with an in-radius mask."""

    scores: jax.Array  # (M, k) f32
    indices: jax.Array  # (M, k) i32
    within: jax.Array  # (M, k) bool  which of the k slots are in range


class CacheInfo(NamedTuple):
    hits: int
    misses: int
    entries: int


# ---------------------------------------------------------------------------
# Padding policy (defined once, in core/dispatch; every query flows
# through an ExecPlan built there)
# ---------------------------------------------------------------------------


def default_pad_multiple() -> int:
    """Lane multiple for batch padding: TPU vector lanes, else a small
    sublane multiple so CPU tests exercise the same path cheaply."""
    return 128 if jax.default_backend() == "tpu" else 8


def _elem_key(tree) -> tuple:
    """Per-row signature: trailing shapes + dtypes.  Combined with the
    plan's (shards, block) this pins the full padded operand shapes."""
    return tuple((tuple(x.shape[1:]), str(x.dtype))
                 for x in jax.tree_util.tree_leaves(tree))


# ---------------------------------------------------------------------------
# Backend registries
# ---------------------------------------------------------------------------

# name -> (supported ray types,
#          builder(scene, ray_type, t_min, max_rounds, interpret)
#          returning fn(ctx, rays) -> TraceResult — ``ctx`` is a *runtime*
#          argument (the BVH, or the backend's prepared form of it), not
#          closed over, so Scene.refit swaps in new boxes with zero
#          retracing,
#          lane multiple the backend wants per shard,
#          optional prepare(scene) -> fn(bvh) -> ctx hook: computed once
#          per scene version — not per chunk — and replicated per mesh)
_TRACE_BACKENDS: dict[str, tuple] = {}

#: tile width of the fused Pallas traversal kernel (= kernels.common.LANES,
#: kept literal here so the registry needs no kernel import at module init;
#: tests/test_session.py pins the equality)
PALLAS_TRACE_LANES = 128

# name -> builder(index, metric, interpret) returning fn(queries) -> (M, N)
# score matrix (squared distances for euclidean, similarities otherwise)
_DISTANCE_BACKENDS: dict[str, Callable] = {}

# name -> (builder(cloud, mode, k, interpret) returning fn(ctx, rays) ->
#          NeighborRecord — ``ctx`` is a runtime argument, not closed over,
#          so PointCloudScene.refit swaps clouds with zero retracing,
#          lane multiple the backend wants per shard,
#          optional prepare(cloud) -> fn(bvh) -> ctx hook, once per version)
_NEIGHBOR_BACKENDS: dict[str, tuple] = {}


def register_trace_backend(name: str, ray_types=RAY_TYPES,
                           lane_multiple: int | None = None,
                           prepare: Callable | None = None):
    """Register a traversal backend under ``name``.  The builder receives
    the static query config — ``build(scene, ray_type, t_min, max_rounds,
    interpret)`` — and returns a jit-able ``fn(ctx, rays)``: the scene
    provides static structure (depth), the context arrays arrive per call
    so animated (refit) scenes re-enter the compiled cache.

    ``lane_multiple`` (optional) is the per-shard row multiple the backend
    wants its batches padded to (e.g. the fused Pallas kernel's 128-lane
    tile width); the dispatch planner folds it into every ExecPlan so
    kernel-backed backends always receive whole tiles.

    ``prepare`` (optional) is ``prepare(scene) -> fn(bvh) -> ctx``: a
    jit-able transform of the BVH into the backend's resident operand
    form (the fused kernel's packed rows-by-lanes arrays).  The engine
    runs it once per scene version and feeds the result to every
    chunk/shard, so O(scene) packing is never re-executed per block;
    backends without one receive the BVH itself as ``ctx``."""
    def deco(build):
        _TRACE_BACKENDS[name] = (tuple(ray_types), build, lane_multiple,
                                 prepare)
        return build
    return deco


def trace_backend_ray_types(name: str) -> tuple[str, ...]:
    """The ray types a registered trace backend supports (used by the
    golden-trace suite to iterate every backend × ray type)."""
    if name not in _TRACE_BACKENDS:
        raise ValueError(f"unknown trace backend {name!r} "
                         f"(registered: {trace_backends()})")
    return _TRACE_BACKENDS[name][0]


def register_distance_backend(name: str):
    """Register a distance backend: ``build(index, metric, interpret)`` must
    return a jit-able ``fn(queries) -> (M, N) scores``."""
    def deco(build):
        _DISTANCE_BACKENDS[name] = build
        return build
    return deco


def register_neighbor_backend(name: str, lane_multiple: int | None = None,
                              prepare: Callable | None = None):
    """Register a tree-backed neighbor backend under ``name``.  The builder
    receives the static query config — ``build(cloud, mode, k, interpret)``
    with ``mode`` in :data:`repro.core.neighbor.NEIGHBOR_MODES` — and
    returns a jit-able ``fn(ctx, rays)`` producing a
    :class:`~repro.core.neighbor.NeighborRecord`.  ``lane_multiple`` and
    ``prepare`` mean exactly what they do for trace backends (the rays
    here are :func:`~repro.core.neighbor.point_queries` bundles, so the
    same dispatch padding applies)."""
    def deco(build):
        _NEIGHBOR_BACKENDS[name] = (build, lane_multiple, prepare)
        return build
    return deco


def trace_backends() -> tuple[str, ...]:
    return tuple(_TRACE_BACKENDS)


def distance_backends() -> tuple[str, ...]:
    return tuple(_DISTANCE_BACKENDS)


def neighbor_backends() -> tuple[str, ...]:
    return tuple(_NEIGHBOR_BACKENDS)


@register_trace_backend("per_ray", ray_types=("closest",))
def _build_per_ray(scene: "Scene", ray_type: str, t_min: float,
                   max_rounds, interpret=None):
    """The vmapped per-ray ``while_loop`` oracle (closest-hit only;
    pure jnp, so ``interpret`` does not apply)."""
    if t_min:
        raise ValueError("per_ray backend has no t_min support; "
                         "use backend='wavefront'")
    if max_rounds is not None:
        raise ValueError("per_ray backend has no max_rounds support; "
                         "use backend='wavefront'")

    def run(bvh, rays):
        rec = trace_rays(bvh, rays, scene.depth, scene.config)
        # a ray is active for exactly quadbox_jobs consecutive rounds, so
        # the batch-level round count is the max per-ray job count
        return TraceResult(rec.t, rec.tri_index, rec.hit, rec.quadbox_jobs,
                           rec.triangle_jobs, rec.stack_overflow,
                           jnp.max(rec.quadbox_jobs))

    return run


@register_trace_backend("wavefront", ray_types=RAY_TYPES)
def _build_wavefront(scene: "Scene", ray_type: str, t_min: float,
                     max_rounds, interpret=None):
    """Batch-level frontier loop: closest / any / shadow rays (pure jnp,
    so ``interpret`` does not apply)."""
    def run(bvh, rays):
        rec = trace_wavefront(bvh, rays, scene.depth,
                              ray_type=ray_type, t_min=t_min,
                              max_rounds=max_rounds, config=scene.config)
        return TraceResult(*rec)  # field-for-field identical record

    return run


def _prepare_pallas_trace(scene: "Scene"):
    """The fused kernel's ``prepare`` hook: pack the BVH into its
    resident rows-by-lanes operands once per scene version (the scene's
    config picks the packed node dtype — bf16 configs halve node bytes)."""
    from ..kernels.traverse import pack_bvh  # deferred (circular init)
    return lambda bvh: pack_bvh(bvh, scene.config)


@register_trace_backend("pallas", ray_types=RAY_TYPES,
                        lane_multiple=PALLAS_TRACE_LANES,
                        prepare=_prepare_pallas_trace)
def _build_pallas_trace(scene: "Scene", ray_type: str, t_min: float,
                        max_rounds, interpret=None):
    """Fused Pallas traversal (``kernels/traverse.py``, DESIGN.md §8): the
    whole pop → OpQuadbox → OpTriangle → commit round loop runs inside one
    kernel with per-lane ray state and the traversal stack on-chip, built
    from the same ``core/datapath`` stage helpers — hits and job counters
    bit-match the wavefront engine.  ``ctx`` is the prepared
    (``pack_bvh``) operand form; ``interpret=None`` auto-selects
    interpret mode off-TPU (the engine-wide ``interpret`` knob threads
    through, same as the distance kernels)."""
    # deferred import: repro.kernels imports repro.core submodules, so a
    # top-level import here would be circular during package init
    from ..kernels.traverse import traverse_packed

    depth = scene.depth

    config = scene.config

    def run(ctx, rays):
        rec = traverse_packed(ctx, rays, depth, ray_type=ray_type,
                              t_min=t_min, max_rounds=max_rounds,
                              interpret=interpret, config=config)
        return TraceResult(*rec)  # WavefrontRecord: field-for-field match

    return run


@register_distance_backend("mxu")
def _build_mxu_scores(index: "VectorIndex", metric: str, interpret):
    """MXU matmul form with the index's precomputed ||c||^2 (DESIGN.md §2)."""
    db, c2 = index.database, index.sq_norms
    return lambda q: pairwise_scores(q, db, metric, c_sq_norms=c2)


@register_distance_backend("pallas")
def _build_pallas_scores(index: "VectorIndex", metric: str, interpret):
    """Tiled Pallas kernels (``repro.kernels.distance``): the multi-beat
    accumulator path.  ``interpret=None`` auto-selects interpret mode
    off-TPU."""
    # deferred import: repro.kernels imports repro.core submodules, so a
    # top-level import here would be circular during package init
    from ..kernels import ops as kops

    db = index.database
    if metric == "euclidean":
        return lambda q: kops.euclidean_kernel(q, db, interpret=interpret)
    if metric == "angular":
        # only dots are consumed; the kernel's norms output is DCE'd
        return lambda q: kops.angular_kernel(q, db, interpret=interpret)[0]
    if metric == "cosine":
        c2 = index.sq_norms  # precomputed once, not re-reduced in-kernel

        def cosine(q):
            dots = kops.angular_kernel(q, db, interpret=interpret)[0]
            return cosine_epilogue(dots, c2, q)
        return cosine
    raise ValueError(f"unknown metric: {metric} (want one of {METRICS})")


def _prepare_tree_wavefront(cloud: "PointCloudScene"):
    """Derive the wavefront neighbor engine's ctx from the *runtime* BVH:
    the ``||c||^2`` norms come from the same array the tree holds, so a
    refit can never serve stale norms."""
    return lambda bvh: (bvh, squared_norms(bvh.triangles.a))


@register_neighbor_backend("tree_wavefront", prepare=_prepare_tree_wavefront)
def _build_tree_wavefront(cloud: "PointCloudScene", mode: str, k: int,
                          interpret=None):
    """Batch-level neighbor frontier loop (``core/neighbor.py``): the
    wavefront engine's distance twin (pure jnp, so ``interpret`` does not
    apply)."""
    depth = cloud.depth

    def run(ctx, rays):
        bvh, sq = ctx
        return neighbor_wavefront(bvh, sq, rays, depth, k=k, mode=mode)

    return run


def _prepare_tree_pallas(cloud: "PointCloudScene"):
    from ..kernels.traverse import pack_point_bvh  # deferred (circular init)
    return pack_point_bvh


@register_neighbor_backend("tree_pallas", lane_multiple=PALLAS_TRACE_LANES,
                           prepare=_prepare_tree_pallas)
def _build_tree_pallas(cloud: "PointCloudScene", mode: str, k: int,
                       interpret=None):
    """Fused Pallas neighbor traversal (``kernels/traverse.py``): the whole
    pop → point-box → point-distance → insert → push round loop runs
    inside one kernel with the per-lane top-k registers and traversal
    stack on-chip — results bit-match the wavefront neighbor engine."""
    # deferred import: repro.kernels imports repro.core submodules, so a
    # top-level import here would be circular during package init
    from ..kernels.traverse import neighbor_packed

    depth = cloud.depth

    def run(ctx, rays):
        return neighbor_packed(ctx, rays, depth, k, mode=mode,
                               interpret=interpret)

    return run


# ---------------------------------------------------------------------------
# Scene / VectorIndex: built once, queried everywhere
# ---------------------------------------------------------------------------


def _as_triangles(triangles) -> Triangle:
    """Coerce a :class:`Triangle` soup or ``(N, 3, 3)`` vertex array."""
    if isinstance(triangles, Triangle):
        return triangles
    arr = jnp.asarray(triangles, jnp.float32)
    if arr.ndim != 3 or arr.shape[1:] != (3, 3):
        raise ValueError(
            f"expected Triangle or (N, 3, 3) vertices, got {arr.shape}")
    return Triangle(arr[:, 0], arr[:, 1], arr[:, 2])


def _validate_finite(tri: Triangle, where: str) -> None:
    """Reject non-finite vertices eagerly: a single NaN/inf poisons the
    scene root box, every Morton code / SAH bin, and every traversal that
    follows.  Skipped under tracing so the builders stay jittable."""
    if any(isinstance(f, jax.core.Tracer) for f in tri):
        return
    if not bool(jnp.all(jnp.isfinite(jnp.stack([tri.a, tri.b, tri.c])))):
        raise ValueError(
            f"{where}: triangle vertices must be finite (no NaN/inf) — "
            "a single bad vertex poisons the scene bounds and every "
            "acceleration-structure build")


# refit is jittable with static shapes, so one jit here means every
# animation frame after the first re-enters one compiled sweep
_refit_jit = jax.jit(refit_bvh, static_argnames=("config",))
_refit_points_jit = jax.jit(refit_points)


def _validate_points_finite(points: jax.Array, where: str) -> None:
    """Reject non-finite points eagerly (same rationale as triangle
    scenes: one NaN poisons the root box and every Morton/SAH decision).
    Skipped under tracing so cloud builds stay jittable."""
    if isinstance(points, jax.core.Tracer):
        return
    if not bool(jnp.all(jnp.isfinite(points))):
        raise ValueError(
            f"{where}: points must be finite (no NaN/inf) — a single bad "
            "point poisons the cloud bounds and every tree build")


class Scene:
    """A prepared triangle scene: ``BVH4`` + its static traversal depth.

    Callers stop threading ``(bvh, depth)`` manually — the pair travels
    together, optionally placed on a device at build time.  The
    acceleration structure itself is pluggable
    (``builder="lbvh" | "sah"``, the :mod:`repro.core.build` registry) and
    updatable in place (:meth:`refit` — dynamic scenes without rebuild or
    retrace); :meth:`stats` reports the tree-quality metrics.
    """

    def __init__(self, bvh: BVH4, depth: int, device=None,
                 builder: str = "lbvh",
                 config: DatapathConfig | None = None):
        if device is not None:
            bvh = jax.device_put(bvh, device)
        self.bvh = bvh
        self.depth = int(depth)
        self.builder = builder
        #: the datapath twin the tree was built for (arity, stack size,
        #: box precision, node codec) — every engine traces with it
        self.config = resolve_config(config)
        #: bumped by :meth:`refit`; engines key their replicated copies on
        #: it so sharded queries pick up the new boxes
        self.version = 0

    @classmethod
    def from_triangles(cls, triangles, depth: int | None = None,
                       device=None, builder: str = "lbvh",
                       config: DatapathConfig | None = None) -> "Scene":
        """Build from a :class:`Triangle` soup or an ``(N, 3, 3)`` array of
        per-triangle vertices, with the named registered builder.
        ``config`` selects the datapath twin (arity / stack size / box
        precision / node codec); ``None`` is the BVH4-fp32 default."""
        triangles = _as_triangles(triangles)
        _validate_finite(triangles, "Scene.from_triangles")
        res = build_structure(triangles, builder, depth, config=config)
        return cls(res.bvh, res.depth, device, builder=res.builder,
                   config=res.config)

    def refit(self, triangles) -> "Scene":
        """Update the scene's geometry in place, keeping its topology.

        Re-sweeps the AABBs bottom-up around the moved ``triangles`` (same
        soup, same order; ``depth`` vectorised reductions) without
        re-sorting or re-binning.  All shapes are preserved, and engines
        thread the BVH as a runtime argument, so every compiled query on
        this scene re-enters the jit cache with **zero retracing** —
        the contract animated scenes rely on (``tests/test_build.py``).
        Returns ``self`` for chaining.
        """
        triangles = _as_triangles(triangles)
        _validate_finite(triangles, "Scene.refit")
        # the soup-size precondition lives in refit() itself (shape-static,
        # so it raises identically through the jitted path)
        self.bvh = _refit_jit(self.bvh, triangles, config=self.config)
        self.version += 1
        return self

    def stats(self, rays=None, probes: int = 256) -> TreeStats:
        """Tree-quality metrics: SAH cost plus mean datapath jobs per ray
        measured on ``rays`` (or a deterministic probe batch)."""
        return tree_stats(self.bvh, self.builder, rays=rays, probes=probes,
                          config=self.config)

    @property
    def num_triangles(self) -> int:
        return int(self.bvh.triangles.a.shape[0])

    def engine(self, **kwargs) -> "QueryEngine":
        return QueryEngine(scene=self, **kwargs)

    def __repr__(self):
        return (f"Scene(num_triangles={self.num_triangles}, "
                f"depth={self.depth}, builder={self.builder!r}, "
                f"config={self.config.tag!r})")


class VectorIndex:
    """A prepared vector database: candidate matrix + precomputed ||c||^2.

    The norms are the OpAngular second output; computing them at build time
    means every subsequent ``knn`` / ``radius_search`` / ``radius_count`` /
    ``cosine_similarity`` call reuses them instead of re-reducing the whole
    database per query batch.
    """

    def __init__(self, database: jax.Array,
                 sq_norms: jax.Array | None = None, device=None):
        database = jnp.asarray(database)
        if device is not None:
            database = jax.device_put(database, device)
        self.database = database
        self.sq_norms = squared_norms(database) if sq_norms is None else sq_norms

    @classmethod
    def from_database(cls, database, device=None) -> "VectorIndex":
        return cls(database, device=device)

    @property
    def size(self) -> int:
        return int(self.database.shape[0])

    @property
    def dim(self) -> int:
        return int(self.database.shape[-1])

    # -- direct (unjitted, unpadded) query methods: the session engine wraps
    #    these with caching + padding; the MoE router calls them in-trace --

    def dots(self, queries: jax.Array) -> jax.Array:
        """OpAngular dot products only (router logits).  (M,D) -> (M,N)."""
        return angular_scores(queries, self.database,
                              c_sq_norms=self.sq_norms)[0]

    def cosine_similarity(self, queries: jax.Array) -> jax.Array:
        return cosine_similarity(queries, self.database,
                                 c_sq_norms=self.sq_norms)

    def knn(self, queries: jax.Array, k: int, metric: str = "euclidean"):
        return knn(queries, self.database, k, metric,
                   c_sq_norms=self.sq_norms)

    def radius_search(self, queries: jax.Array, radius: float, k: int,
                      metric: str = "euclidean"):
        return radius_search(queries, self.database, radius, k, metric,
                             c_sq_norms=self.sq_norms)

    def radius_count(self, queries: jax.Array, radius: float,
                     metric: str = "euclidean"):
        return radius_count(queries, self.database, radius, metric,
                            c_sq_norms=self.sq_norms)

    def engine(self, **kwargs) -> "QueryEngine":
        return QueryEngine(index=self, **kwargs)

    def __repr__(self):
        return f"VectorIndex(size={self.size}, dim={self.dim})"


class PointCloudScene:
    """A prepared point cloud: a BVH4 over AABB-per-point leaves *plus* the
    equivalent :class:`VectorIndex` over the same points.

    The RTNN unification surface (DESIGN.md §9): one object serves both
    the traversal-backed neighbor engines (``tree_wavefront`` /
    ``tree_pallas``, which walk the tree with query radii as ray extents)
    and the brute-force distance backends (``mxu`` / ``pallas``, the
    bit-level oracle) — ``QueryEngine`` routes between them per query
    (``backend="auto"``) without the caller re-staging data.

    Construction is pluggable exactly like :class:`Scene`
    (``builder="lbvh" | "sah"``, sharing the triangle builders' slot-
    assignment cores), and clouds are updatable in place (:meth:`refit` —
    same zero-retrace contract, via the cull-free
    :func:`~repro.core.build.points.refit_points`).
    """

    def __init__(self, bvh: BVH4, depth: int, device=None,
                 builder: str = "lbvh",
                 config: DatapathConfig | None = None):
        if device is not None:
            bvh = jax.device_put(bvh, device)
        self.bvh = bvh
        self.depth = int(depth)
        self.builder = builder
        #: the datapath twin the tree was built for (arity, stack size,
        #: box precision, node codec) — every engine traces with it
        self.config = resolve_config(config)
        #: bumped by :meth:`refit`; engines key replicated copies, packed
        #: kernel operands and brute-path closures on it
        self.version = 0
        #: the same points as a brute-force index (shared ||c||^2 norms)
        self.index = VectorIndex(bvh.triangles.a)
        self._root_vol: float | None = None

    @classmethod
    def from_points(cls, points, depth: int | None = None, device=None,
                    builder: str = "lbvh") -> "PointCloudScene":
        """Build from an ``(N, 3)`` point array with the named builder
        core (the tree path is 3-D; higher-dimensional data belongs in a
        plain :class:`VectorIndex`)."""
        points = jnp.asarray(points, jnp.float32)
        _validate_points_finite(points, "PointCloudScene.from_points")
        res = build_point_bvh(points, builder, depth)
        return cls(res.bvh, res.depth, device, builder=res.builder)

    def refit(self, points) -> "PointCloudScene":
        """Update the cloud's points in place, keeping its topology (same
        count, same order).  Zero retraces, like :meth:`Scene.refit`:
        every neighbor backend threads the BVH as a runtime argument, and
        the brute path re-derives its norms through the version bump.
        Returns ``self`` for chaining."""
        points = jnp.asarray(points, jnp.float32)
        _validate_points_finite(points, "PointCloudScene.refit")
        self.bvh = _refit_points_jit(self.bvh, points)
        self.index = VectorIndex(self.bvh.triangles.a)
        self.version += 1
        self._root_vol = None
        return self

    @property
    def points(self) -> jax.Array:
        return self.bvh.triangles.a

    @property
    def size(self) -> int:
        return int(self.bvh.triangles.a.shape[0])

    def root_volume(self) -> float:
        """Volume of the root AABB (cached per version) — the denominator
        of the "auto" policy's radius-selectivity estimate."""
        if self._root_vol is None:
            ext = jnp.maximum(self.bvh.node_hi[0] - self.bvh.node_lo[0],
                              0.0)
            self._root_vol = float(ext[0] * ext[1] * ext[2])
        return self._root_vol

    def engine(self, **kwargs) -> "QueryEngine":
        return QueryEngine(cloud=self, **kwargs)

    def __repr__(self):
        return (f"PointCloudScene(size={self.size}, depth={self.depth}, "
                f"builder={self.builder!r})")


# ---------------------------------------------------------------------------
# QueryEngine: the single typed entry point
# ---------------------------------------------------------------------------


class QueryEngine:
    """Jit-cached session over a :class:`Scene` and/or :class:`VectorIndex`.

    Modeled on ``serving/engine.py``: compiled functions are cached per
    (query kind, backend, static config, padded operand shapes), so
    repeated same-shape queries re-enter the compiled program directly.
    Batches are padded to ``pad_multiple`` (row-0 repetition for rays,
    which is always a valid ray) and results are sliced back — per-ray /
    per-query state is row-independent in every backend, so the pad →
    query → unpad round trip is an identity (``tests/test_session.py``).

    ``backend="auto"`` picks per query: wavefront for traced batches
    (per-ray oracle for tiny closest-hit batches), Pallas kernels for
    distance queries on TPU and the MXU jnp form elsewhere.

    Two execution knobs ride on every query (``core/dispatch.py``,
    DESIGN.md §6), settable engine-wide here or overridden per call:

    * ``shard="auto" | int`` — data-parallel the batch's rows over a 1-D
      device mesh; the scene / index is replicated once per mesh and the
      per-shard computation is the unchanged single-device computation on
      that shard's rows (no collectives, so results stay bit-identical;
      ``"auto"`` = all local devices, capped at the batch size; ``1``
      disables).
    * ``chunk_size=`` — execute in fixed-size microbatch blocks that all
      re-enter one compiled function (one engine-cache entry however many
      chunks), bounding peak intermediate memory for million-ray batches;
      results are assembled across chunks and wavefront ``rounds`` reduces
      by max, which equals the single-device value exactly.

    Zero-row batches short-circuit to empty typed results without
    compiling or executing anything.
    """

    #: closest-hit batches up to this size go to the per-ray oracle under
    #: "auto" (the batch loop only pays off once the frontier is wide)
    AUTO_PER_RAY_MAX = 8

    #: "auto" routes TPU traces to the fused Pallas kernel only while the
    #: scene's resident operands (node boxes + leaf table + triangle soup,
    #: mapped whole into every tile) fit comfortably in VMEM (~16 MB/core);
    #: past this budget the wavefront engine handles the scene unchanged
    AUTO_PALLAS_SCENE_BYTES = 8 * 2**20

    #: below this cloud size "auto" keeps neighbor queries on the brute
    #: path: one small MXU matmul beats any traversal's pointer chasing
    AUTO_TREE_MIN_POINTS = 4096

    #: "auto" routes a neighbor query to the tree only while its expected
    #: selectivity (fraction of the cloud each query touches: k/N for
    #: nearest, ball volume / root volume for radius queries) stays under
    #: this — a query that touches most of the cloud visits most of the
    #: tree, and the brute matmul wins
    AUTO_TREE_MAX_SELECTIVITY = 0.05

    def __init__(self, scene: Scene | None = None,
                 index: VectorIndex | None = None,
                 cloud: "PointCloudScene | None" = None, *,
                 backend: str = "auto", pad_multiple: int | None = None,
                 shard: str | int = "auto", chunk_size: int | None = None,
                 interpret: bool | None = None):
        self.scene = scene
        self._index = index
        self.cloud = cloud
        self.default_backend = backend
        # execution knobs are validated eagerly, here and per call — a bad
        # chunk_size/shard must never flow silently into the plan math
        # (floats used to truncate; 0 used to slip past empty batches)
        if shard not in (None, "auto"):
            check_count("shard", shard)
        self.default_shard = shard
        self.default_chunk_size = check_count("chunk_size", chunk_size)
        self.pad_multiple = (default_pad_multiple() if pad_multiple is None
                             else max(1, int(pad_multiple)))
        self.interpret = interpret  # None = auto (off-TPU -> interpret)
        self._cache: dict = {}
        self._placed: dict = {}  # (kind, shards) -> replicated Scene/index
        self._hits = 0
        self._misses = 0

    @property
    def index(self) -> VectorIndex | None:
        """The engine's vector index: the explicit one, else the cloud's
        (a :class:`PointCloudScene` carries its brute-oracle twin, so
        distance queries on a cloud engine need no separate index)."""
        if self._index is None and self.cloud is not None:
            return self.cloud.index
        return self._index

    def _index_version(self) -> int:
        """Version of the backing index data: a cloud refit swaps the
        brute path's database, so closures over it must re-key."""
        if self._index is None and self.cloud is not None:
            return self.cloud.version
        return 0

    # -- cache ------------------------------------------------------------

    def cache_info(self) -> CacheInfo:
        return CacheInfo(self._hits, self._misses, len(self._cache))

    def cache_clear(self) -> None:
        self._cache.clear()
        self._placed.clear()  # replicated scene/index copies are the big
        self._hits = self._misses = 0  # objects; release them too

    def _compiled(self, key, build):
        fn = self._cache.get(key)
        if fn is None:
            self._misses += 1
            _OBS_CACHE_MISSES.inc()
            fn = jax.jit(build())
            self._cache[key] = fn
        else:
            self._hits += 1
            _OBS_CACHE_HITS.inc()
        return fn

    def _obs_record(self, method: str, backend: str, plan: ExecPlan,
                    t0: float, result, jobs=()) -> None:
        """Record one executed query into the default registry (callers
        gate on ``_OBS.enabled``): wall time to a per-method histogram —
        after blocking on the result, so the clock covers device work —
        real vs padded rows (the pad-waste numerator/denominator in
        ``obs.snapshot()``), chunk/shard fan-out, a per-(method, backend)
        call counter, and whatever datapath job totals the backend
        reports (quadbox/triangle for traces, box/point for neighbor
        queries)."""
        jax.block_until_ready(result)
        dt_ms = (time.perf_counter() - t0) * 1e3
        _OBS.histogram(f"engine.call_ms.{method}").observe(dt_ms)
        _OBS.counter(f"engine.calls.{method}.{backend}").inc()
        _OBS_ROWS_REAL.inc(plan.n)
        _OBS_ROWS_PADDED.inc(plan.block * plan.n_blocks)
        _OBS_CHUNKS.inc(plan.n_blocks)
        _OBS_SHARDS.set(plan.shards)
        for job_name, per_row in jobs:
            _OBS.counter(f"engine.jobs.{job_name}.{backend}").inc(
                int(jnp.sum(per_row)))

    # -- backend resolution ----------------------------------------------

    def resolve_trace_backend(self, ray_type: str, n_rays: int,
                              t_min: float = 0.0,
                              max_rounds: int | None = None,
                              shards: int = 1) -> str:
        """The backend "auto" picks for a trace: per-ray oracle for tiny
        plain closest-hit batches; every other query — including ones the
        oracle cannot express (t_min, max_rounds) and any sharded batch
        (a multi-device frontier is by definition not tiny) — goes to a
        batch engine: the fused Pallas traversal kernel on TPU (the loop
        state stays on-chip) while the scene fits the kernel's on-chip
        budget, the wavefront engine everywhere else (off-TPU interpret
        mode would only add overhead; an over-budget tree would overflow
        VMEM).  All three return bit-identical results, so the policy is
        pure scheduling."""
        if (shards == 1 and ray_type == "closest"
                and n_rays <= self.AUTO_PER_RAY_MAX
                and not t_min and max_rounds is None):
            return "per_ray"
        if (jax.default_backend() == "tpu"
                and self._scene_resident_bytes() <= self.AUTO_PALLAS_SCENE_BYTES):
            return "pallas"
        return "wavefront"

    def _scene_resident_bytes(self) -> int:
        """Bytes the fused traversal kernel keeps resident per tile: node
        boxes (at the scene config's packed dtype — bf16 configs pack
        2 B/scalar) + leaf table + triangle soup (f32/i32 = 4 B each)."""
        if self.scene is None:
            return 0
        bvh = self.scene.bvh
        n_nodes = bvh.node_lo.shape[0]
        box_bytes = jnp.dtype(self.scene.config.packed_box_dtype).itemsize
        return (box_bytes * 2 * n_nodes * 3
                + 4 * (bvh.leaf_tri.shape[0] + 9 * bvh.triangles.a.shape[0]))

    def resolve_distance_backend(self) -> str:
        """The backend "auto" picks for distance queries: compiled Pallas
        kernels on TPU, the MXU jnp form elsewhere (interpret mode would
        only add overhead)."""
        return "pallas" if jax.default_backend() == "tpu" else "mxu"

    def resolve_neighbor_backend(self, kind: str, metric: str,
                                 k: int | None = None,
                                 radius: float | None = None) -> str:
        """The backend "auto" picks for ``nearest`` / ``within`` /
        ``count_within``: tree-vs-brute by N, dimension and selectivity.

        The tree path needs a :class:`PointCloudScene` (which pins the
        dimension to 3 — higher-dimensional indexes have no cloud and stay
        brute) and a euclidean metric; below
        :data:`AUTO_TREE_MIN_POINTS` points, or when the query's expected
        selectivity (k/N for nearest; search-ball volume over root-box
        volume for radius queries) exceeds
        :data:`AUTO_TREE_MAX_SELECTIVITY`, the brute matmul wins and
        "auto" stays on the distance backends.  Otherwise: the fused
        Pallas neighbor kernel on TPU while the packed cloud fits its
        on-chip budget, the wavefront neighbor engine everywhere else.
        Either way every route returns the same in-radius sets and
        neighbor ranks, so the policy is pure scheduling."""
        if self.cloud is None or metric != "euclidean":
            return self.resolve_distance_backend()
        n = self.cloud.size
        if n < self.AUTO_TREE_MIN_POINTS:
            return self.resolve_distance_backend()
        if kind == "nearest":
            selectivity = (1 if k is None else int(k)) / n
        else:
            r = float(radius)
            ball = 4.0 / 3.0 * math.pi * r**3
            vol = self.cloud.root_volume()
            selectivity = ball / vol if (vol > 0.0
                                         and math.isfinite(ball)) else 1.0
        if selectivity > self.AUTO_TREE_MAX_SELECTIVITY:
            return self.resolve_distance_backend()
        if (jax.default_backend() == "tpu"
                and self._cloud_resident_bytes()
                <= self.AUTO_PALLAS_SCENE_BYTES):
            return "tree_pallas"
        return "tree_wavefront"

    def _cloud_resident_bytes(self) -> int:
        """Bytes the fused neighbor kernel keeps resident per tile: node
        boxes + leaf table + packed point rows (x, y, z, ||c||^2)."""
        if self.cloud is None:
            return 0
        bvh = self.cloud.bvh
        n_nodes = bvh.node_lo.shape[0]
        return 4 * (2 * n_nodes * 3 + bvh.leaf_tri.shape[0]
                    + 4 * bvh.triangles.a.shape[0])

    # -- execution planning (sharding + chunking, core/dispatch.py) -------

    def _resolve_shards(self, shard, n: int) -> int:
        return resolve_shards(
            self.default_shard if shard is None else shard, n)

    def _plan(self, n: int, shards: int, chunk_size,
              lane_multiple: int | None = None) -> ExecPlan:
        if chunk_size is None:
            chunk_size = self.default_chunk_size
        return make_plan(n, pad_multiple=self.pad_multiple, shards=shards,
                         chunk_size=chunk_size, lane_multiple=lane_multiple)

    # -- plan introspection (what the serving coalescer sizes batches by) --

    #: the query methods the serving layer coalesces (one bucket space per
    #: method; ``repro.serving.query_server`` exposes exactly these)
    SERVABLE_METHODS = ("trace", "nearest", "within", "count_within",
                        "scores")

    def _method_lane_multiple(self, method: str, backend: str | None, *,
                              ray_type: str = "closest",
                              metric: str = "euclidean", n: int = 1 << 20,
                              k: int | None = None,
                              radius: float | None = None) -> int | None:
        """The backend-declared tile width a ``method`` query would pad
        to (None = no hard tile; the plain pad multiple applies).
        ``backend=None/"auto"`` resolves through the same auto policy the
        query itself would use, with a large nominal ``n`` so tiny-batch
        special cases don't leak into sizing decisions."""
        name = backend or self.default_backend
        if method == "trace":
            if name == "auto":
                name = self.resolve_trace_backend(ray_type, n)
            if name not in _TRACE_BACKENDS:
                raise ValueError(f"unknown trace backend {name!r} "
                                 f"(registered: {trace_backends()})")
            return _TRACE_BACKENDS[name][2]
        if method in ("nearest", "within", "count_within", "scores"):
            if name == "auto":
                if method == "scores" or (method != "nearest"
                                          and radius is None):
                    # scores is brute-only; a radius query introspected
                    # without its radius can't be selectivity-routed —
                    # assume the brute path (no hard tile) conservatively
                    name = self.resolve_distance_backend()
                else:
                    name = self.resolve_neighbor_backend(
                        method, metric, k=k, radius=radius)
            if name in _NEIGHBOR_BACKENDS:
                return _NEIGHBOR_BACKENDS[name][1]
            if name in _DISTANCE_BACKENDS:
                return None
            raise ValueError(
                f"unknown distance/neighbor backend {name!r} (registered: "
                f"{distance_backends() + neighbor_backends()})")
        raise ValueError(f"unknown query method {method!r} "
                         f"(servable: {self.SERVABLE_METHODS})")

    def batch_multiple(self, method: str = "trace",
                       backend: str | None = None, *,
                       ray_type: str = "closest",
                       metric: str = "euclidean", k: int | None = None,
                       radius: float | None = None) -> int:
        """The effective per-shard row multiple queries of ``method`` are
        padded to — ``max(pad_multiple, backend tile width)``.  The
        serving coalescer sizes its batch targets with this so a flushed
        batch fills whole lanes/tiles instead of padding them away."""
        lane = self._method_lane_multiple(method, backend,
                                          ray_type=ray_type, metric=metric,
                                          k=k, radius=radius)
        return max(self.pad_multiple, lane or 1)

    def plan_for(self, method: str, n: int, *,
                 backend: str | None = None, ray_type: str = "closest",
                 metric: str = "euclidean", k: int | None = None,
                 radius: float | None = None, shard=None,
                 chunk_size: int | None = None) -> ExecPlan:
        """Introspection: the :class:`~repro.core.dispatch.ExecPlan` an
        ``n``-row ``method`` query would execute under — without
        dispatching anything.  The serving layer uses ``plan.block`` (the
        padded rows actually executed) to quantize batch shapes and to
        report batch occupancy; callers get the same plan the query path
        itself builds, so the numbers cannot drift."""
        if n < 1:
            raise ValueError(f"plan_for needs n >= 1, got {n}")
        shards = self._resolve_shards(shard, n)
        chunk_size = check_count("chunk_size", chunk_size)
        lane = self._method_lane_multiple(method, backend,
                                          ray_type=ray_type, metric=metric,
                                          n=n, k=k, radius=radius)
        return self._plan(n, shards, chunk_size, lane_multiple=lane)

    def _placed_scene(self, plan: ExecPlan) -> "Scene":
        """The scene with its BVH replicated across the plan's mesh
        (placed once per shard count and scene version — a refit bumps the
        version, so animated scenes re-place the new boxes without
        recompiling anything)."""
        if plan.shards == 1:
            return self.scene
        key = ("scene", plan.shards, self.scene.version)
        placed = self._placed.get(key)
        if placed is None:
            self._placed = {k: v for k, v in self._placed.items()
                            if k[0] != "scene" or k[1] != plan.shards}
            placed = Scene(replicated(plan.mesh, self.scene.bvh),
                           self.scene.depth, builder=self.scene.builder)
            self._placed[key] = placed
        return placed

    def _trace_ctx(self, name: str, prepare, plan: ExecPlan):
        """The backend's trace context operand: the (replicated) BVH by
        default, or — when the backend registered a ``prepare`` hook —
        its prepared form (the fused kernel's packed operands), computed
        through one jitted prepare function per backend, once per scene
        version and mesh, then re-fed to every chunk and shard.  A refit
        bumps the version, so animated scenes re-pack (one compiled
        re-execution, zero retraces) without recompiling anything."""
        if prepare is None:
            return self._placed_scene(plan).bvh
        key = ("trace_ctx", name, plan.shards, self.scene.version)
        ctx = self._placed.get(key)
        if ctx is None:
            self._placed = {k: v for k, v in self._placed.items()
                            if k[0] != "trace_ctx" or k[1] != name
                            or k[2] != plan.shards}
            fn = self._compiled(("prepare", name),
                                lambda: prepare(self.scene))
            ctx = fn(self.scene.bvh)
            if plan.shards > 1:
                ctx = replicated(plan.mesh, ctx)
            self._placed[key] = ctx
        return ctx

    def _placed_index(self, plan: ExecPlan) -> "VectorIndex":
        """The index with database + precomputed norms replicated across
        the plan's mesh (keyed on the index version: a cloud refit swaps
        the database, so stale replicas are evicted)."""
        if plan.shards == 1:
            return self.index
        key = ("index", plan.shards, self._index_version())
        placed = self._placed.get(key)
        if placed is None:
            self._placed = {k: v for k, v in self._placed.items()
                            if k[0] != "index" or k[1] != plan.shards}
            index = self.index
            placed = VectorIndex(
                replicated(plan.mesh, index.database),
                sq_norms=replicated(plan.mesh, index.sq_norms))
            self._placed[key] = placed
        return placed

    def _neighbor_ctx(self, name: str, prepare, plan: ExecPlan):
        """The neighbor backend's context operand, mirroring
        :meth:`_trace_ctx`: prepared once per cloud version and mesh
        (packed kernel operands / derived norms), re-fed to every chunk
        and shard.  A refit bumps the version, so moved clouds re-prepare
        (one compiled re-execution, zero retraces) without recompiling."""
        if prepare is None:
            bvh = self.cloud.bvh
            if plan.shards == 1:
                return bvh
        key = ("neighbor_ctx", name, plan.shards, self.cloud.version)
        ctx = self._placed.get(key)
        if ctx is None:
            self._placed = {k: v for k, v in self._placed.items()
                            if k[0] != "neighbor_ctx" or k[1] != name
                            or k[2] != plan.shards}
            if prepare is None:
                ctx = self.cloud.bvh
            else:
                fn = self._compiled(("prepare", name),
                                    lambda: prepare(self.cloud))
                ctx = fn(self.cloud.bvh)
            if plan.shards > 1:
                ctx = replicated(plan.mesh, ctx)
            self._placed[key] = ctx
        return ctx

    # -- traversal queries -------------------------------------------------

    def trace(self, rays, ray_type: str = "closest", *,
              backend: str | None = None, t_min: float | None = None,
              max_rounds: int | None = None, shard=None,
              chunk_size: int | None = None) -> TraceResult:
        """Traverse a ray batch.  ``ray_type`` is ``"closest"`` | ``"any"``
        | ``"shadow"`` (CrossRT-style split); results are bit-identical to
        the legacy ``trace_rays`` / ``trace_wavefront`` entry points —
        whatever ``shard`` / ``chunk_size`` (None = the engine defaults)
        schedule the batch onto."""
        if self.scene is None:
            raise ValueError("QueryEngine has no Scene; construct with "
                             "QueryEngine(scene=...) or Scene.engine()")
        if ray_type not in RAY_TYPES:
            raise ValueError(
                f"ray_type must be one of {RAY_TYPES}, got {ray_type!r}")
        if t_min is None:
            t_min = SHADOW_T_MIN if ray_type == "shadow" else 0.0
        t_min = float(t_min)
        n = rays.origin.shape[0]
        shards = self._resolve_shards(shard, n)
        chunk_size = check_count("chunk_size", chunk_size)
        name = backend or self.default_backend
        if name == "auto":
            name = self.resolve_trace_backend(ray_type, n, t_min, max_rounds,
                                              shards=shards)
        if name not in _TRACE_BACKENDS:
            raise ValueError(f"unknown trace backend {name!r} "
                             f"(registered: {trace_backends()})")
        supported, build, lane_multiple, prepare = _TRACE_BACKENDS[name]
        if ray_type not in supported:
            raise ValueError(f"backend {name!r} supports ray types "
                             f"{supported}, got {ray_type!r}")
        if n == 0:  # empty guard: typed empty result, nothing compiled
            return TraceResult(
                t=jnp.zeros((0,), jnp.float32),
                tri_index=jnp.zeros((0,), jnp.int32),
                hit=jnp.zeros((0,), bool),
                quadbox_jobs=jnp.zeros((0,), jnp.int32),
                triangle_jobs=jnp.zeros((0,), jnp.int32),
                stack_overflow=jnp.zeros((0,), bool),
                rounds=jnp.int32(0))

        plan = self._plan(n, shards, chunk_size,
                          lane_multiple=lane_multiple)
        key = ("trace", name, ray_type, t_min, max_rounds) + plan.key \
            + _elem_key(rays)

        def build_fn():
            run = build(self.scene, ray_type, t_min, max_rounds,
                        self.interpret)
            if plan.shards == 1:
                return run

            def per_shard(ctx, r):
                rec = run(ctx, r)
                # lift the scalar round count to a length-1 row axis so the
                # shard_map returns one value per shard (reduced below)
                return rec._replace(rounds=jnp.atleast_1d(rec.rounds))

            return shard_rows_ctx(per_shard, plan.mesh)

        fn = self._compiled(key, build_fn)
        ctx = self._trace_ctx(name, prepare, plan)
        t0 = time.perf_counter() if _OBS.enabled else 0.0
        with _obs_annotate("engine.trace"):
            outs = [fn(ctx, block) for block in split_blocks(rays, plan)]
            # streamed assembly: per-ray rows concatenate across chunks; the
            # batch-level round count is the max over chunks and shards, which
            # equals the single-device value (a ray is active for exactly
            # quadbox_jobs consecutive rounds wherever it executes)
            rounds = jnp.max(jnp.stack(
                [jnp.max(jnp.atleast_1d(o.rounds)) for o in outs]))
            rows = concat_rows([o._replace(rounds=None) for o in outs], n)
            res = rows._replace(rounds=rounds)
        if _OBS.enabled:
            self._obs_record("trace", name, plan, t0, res,
                             jobs=(("quadbox", res.quadbox_jobs),
                                   ("triangle", res.triangle_jobs)))
        return res

    def occluded(self, rays, *, t_min: float = SHADOW_T_MIN,
                 backend: str | None = None, shard=None,
                 chunk_size: int | None = None) -> jax.Array:
        """Boolean shadow/visibility query (extent-limited any-hit)."""
        return self.trace(rays, ray_type="shadow", t_min=t_min,
                          backend=backend, shard=shard,
                          chunk_size=chunk_size).hit

    # -- distance queries --------------------------------------------------

    def _distance_fn(self, kind: str, queries, metric: str,
                     backend: str | None, statics: tuple, epilogue,
                     empty, shard=None, chunk_size: int | None = None):
        if self.index is None:
            raise ValueError("QueryEngine has no VectorIndex; construct "
                             "with QueryEngine(index=...) or "
                             "VectorIndex.engine()")
        name = backend or self.default_backend
        if name == "auto":
            name = self.resolve_distance_backend()
        if name not in _DISTANCE_BACKENDS:
            raise ValueError(f"unknown distance backend {name!r} "
                             f"(registered: {distance_backends()})")
        q = jnp.asarray(queries)
        n = q.shape[0]
        shards = self._resolve_shards(shard, n)  # validates before guard
        chunk_size = check_count("chunk_size", chunk_size)
        if n == 0:  # empty guard: typed empty result, nothing compiled
            return empty()
        plan = self._plan(n, shards, chunk_size)
        key = ((kind, name, metric, self._index_version()) + statics
               + plan.key + _elem_key(q))
        build_scores = _DISTANCE_BACKENDS[name]

        def build():
            score_fn = build_scores(self._placed_index(plan), metric,
                                    self.interpret)
            run = lambda qq: epilogue(score_fn(qq))  # noqa: E731
            if plan.shards == 1:
                return run
            return shard_rows(run, plan.mesh)

        fn = self._compiled(key, build)
        t0 = time.perf_counter() if _OBS.enabled else 0.0
        with _obs_annotate("engine.distance"):
            res = concat_rows(
                [fn(block) for block in split_blocks(q, plan)], n)
        if _OBS.enabled:
            self._obs_record(kind, name, plan, t0, res)
        return res

    def _tree_neighbor(self, kind: str, queries, k: int, radius,
                       name: str, shard=None,
                       chunk_size: int | None = None) -> NeighborRecord:
        """Run a neighbor query through a registered tree backend: pad /
        shard / chunk the query batch exactly like a trace (queries ride
        as :func:`point_queries` ray bundles; the radius is a *runtime*
        extent, so sweeping radii re-enters one compiled function)."""
        if self.cloud is None:
            raise ValueError(
                f"backend {name!r} needs a PointCloudScene; construct "
                "with QueryEngine(cloud=...) or PointCloudScene.engine()")
        mode = "nearest" if kind == "nearest" else "within"
        build, lane_multiple, prepare = _NEIGHBOR_BACKENDS[name]
        q = jnp.asarray(queries, jnp.float32)
        if q.ndim != 2 or q.shape[-1] != 3:
            raise ValueError(
                f"tree-backed {kind} expects (M, 3) queries, got "
                f"{tuple(q.shape)}")
        # clamp the top-k register count to the cloud (k > N pads below)
        kk = max(1, min(int(k), self.cloud.size))
        n = q.shape[0]
        shards = self._resolve_shards(shard, n)
        chunk_size = check_count("chunk_size", chunk_size)
        if n == 0:  # empty guard: typed empty result, nothing compiled
            z = jnp.zeros((0,), jnp.int32)
            return NeighborRecord(
                dist_sq=jnp.zeros((0, k), jnp.float32),
                index=jnp.zeros((0, k), jnp.int32),
                valid=jnp.zeros((0, k), bool), count=z, box_jobs=z,
                point_jobs=z, rounds=jnp.int32(0))
        rays = point_queries(q, radius)
        plan = self._plan(n, shards, chunk_size,
                          lane_multiple=lane_multiple)
        key = ("neighbor", name, mode, kk) + plan.key + _elem_key(rays)

        def build_fn():
            run = build(self.cloud, mode, kk, self.interpret)
            if plan.shards == 1:
                return run

            def per_shard(ctx, r):
                rec = run(ctx, r)
                return rec._replace(rounds=jnp.atleast_1d(rec.rounds))

            return shard_rows_ctx(per_shard, plan.mesh)

        fn = self._compiled(key, build_fn)
        ctx = self._neighbor_ctx(name, prepare, plan)
        t0 = time.perf_counter() if _OBS.enabled else 0.0
        with _obs_annotate("engine.neighbor"):
            outs = [fn(ctx, block) for block in split_blocks(rays, plan)]
            rounds = jnp.max(jnp.stack(
                [jnp.max(jnp.atleast_1d(o.rounds)) for o in outs]))
            rec = concat_rows([o._replace(rounds=None) for o in outs], n)
            rec = rec._replace(rounds=rounds)
        if _OBS.enabled:
            self._obs_record(kind, name, plan, t0, rec,
                             jobs=(("box", rec.box_jobs),
                                   ("point", rec.point_jobs)))
        if kk < k:  # pad the clamped top-k axis back out (k > N)
            pad = k - kk
            rec = rec._replace(
                dist_sq=jnp.concatenate(
                    [rec.dist_sq,
                     jnp.full((n, pad), jnp.inf, jnp.float32)], axis=1),
                index=jnp.concatenate(
                    [rec.index, jnp.full((n, pad), -1, jnp.int32)],
                    axis=1),
                valid=jnp.concatenate(
                    [rec.valid, jnp.zeros((n, pad), bool)], axis=1))
        return rec

    def _resolve_neighbor_name(self, kind: str, metric: str, backend,
                               k=None, radius=None) -> str:
        name = backend or self.default_backend
        if name == "auto":
            name = self.resolve_neighbor_backend(kind, metric, k=k,
                                                 radius=radius)
        if name in _NEIGHBOR_BACKENDS and metric != "euclidean":
            raise ValueError(
                f"tree backend {name!r} supports metric='euclidean' "
                f"only, got {metric!r} (use the mxu/pallas brute "
                "backends for angular/cosine)")
        return name

    def neighbor_search(self, queries, k: int, radius=None, *,
                        mode: str = "within",
                        backend: str | None = None, shard=None,
                        chunk_size: int | None = None) -> NeighborRecord:
        """Direct tree-backed neighbor query returning the full
        :class:`~repro.core.neighbor.NeighborRecord` (distances, indices,
        exact in-radius counts *and* per-query job statistics — what the
        benchmarks plot).  ``nearest`` / ``within`` / ``count_within``
        are the typed convenience views over this."""
        k = check_k(k)
        if radius is not None:
            radius = check_radius(radius, "euclidean")
        name = backend or self.default_backend
        if name == "auto":
            name = ("tree_pallas"
                    if (jax.default_backend() == "tpu"
                        and self._cloud_resident_bytes()
                        <= self.AUTO_PALLAS_SCENE_BYTES)
                    else "tree_wavefront")
        if name not in _NEIGHBOR_BACKENDS:
            raise ValueError(f"unknown neighbor backend {name!r} "
                             f"(registered: {neighbor_backends()})")
        kind = "nearest" if mode == "nearest" else "within"
        return self._tree_neighbor(kind, queries, k, radius, name,
                                   shard=shard, chunk_size=chunk_size)

    def nearest(self, queries, k: int, metric: str = "euclidean", *,
                backend: str | None = None, shard=None,
                chunk_size: int | None = None) -> NearestResult:
        """Exact k-nearest neighbours.  ``k`` is validated eagerly
        (``ValueError`` on ``k <= 0``) and clamped to the database size —
        ``k > N`` pads the trailing slots (inf/-inf score, index -1,
        ``valid`` False) instead of crashing inside ``lax.top_k``.

        With a :class:`PointCloudScene`, ``backend="auto"`` routes
        euclidean queries through the BVH (``tree_wavefront`` /
        ``tree_pallas``) when the tree wins; the brute backends
        (``mxu`` / ``pallas``) remain the rank-equivalent oracle."""
        if metric not in METRICS:
            raise ValueError(f"unknown metric: {metric}")
        k = check_k(k)
        name = self._resolve_neighbor_name("nearest", metric, backend,
                                           k=k)
        if name in _NEIGHBOR_BACKENDS:
            rec = self._tree_neighbor("nearest", queries, k, None, name,
                                      shard=shard, chunk_size=chunk_size)
            return NearestResult(rec.dist_sq, rec.index, rec.valid)

        def topk(s):
            scores, idx = select_topk(s, k, metric)
            return NearestResult(scores, idx, idx >= 0)

        return self._distance_fn(
            "nearest", queries, metric, name, (k,), topk,
            lambda: NearestResult(jnp.zeros((0, k), jnp.float32),
                                  jnp.zeros((0, k), jnp.int32),
                                  jnp.zeros((0, k), bool)),
            shard=shard, chunk_size=chunk_size)

    def within(self, queries, radius: float, k: int,
               metric: str = "euclidean", *,
               backend: str | None = None, shard=None,
               chunk_size: int | None = None) -> WithinResult:
        """Fixed-radius query: best ``k`` in-range neighbours (the
        extent-limited shadow-ray twin, DESIGN.md §3).  ``radius`` and
        ``k`` are validated eagerly (``ValueError`` on NaN / negative
        euclidean radius and on ``k <= 0``); ``k > N`` pads like
        :meth:`nearest`.  Routing is as in :meth:`nearest`: tree-backed
        for euclidean cloud queries when the tree wins, in-radius
        membership bit-exact against the brute oracle either way."""
        if metric not in RADIUS_METRICS:
            raise ValueError(f"unknown radius metric: {metric}")
        radius = check_radius(radius, metric)
        k = check_k(k)
        name = self._resolve_neighbor_name("within", metric, backend,
                                           k=k, radius=radius)
        if name in _NEIGHBOR_BACKENDS:
            rec = self._tree_neighbor("within", queries, k, radius, name,
                                      shard=shard, chunk_size=chunk_size)
            return WithinResult(rec.dist_sq, rec.index, rec.valid)
        return self._distance_fn(
            "within", queries, metric, name, (radius, k),
            lambda s: WithinResult(*select_within(s, radius, k, metric)),
            lambda: WithinResult(jnp.zeros((0, k), jnp.float32),
                                 jnp.zeros((0, k), jnp.int32),
                                 jnp.zeros((0, k), bool)),
            shard=shard, chunk_size=chunk_size)

    def count_within(self, queries, radius: float,
                     metric: str = "euclidean", *,
                     backend: str | None = None, shard=None,
                     chunk_size: int | None = None) -> jax.Array:
        """How many database points fall within ``radius`` per query.
        ``radius`` is validated eagerly (``ValueError`` on NaN / negative
        euclidean radius); routing is as in :meth:`within`, and the
        tree-backed count is exact (the traversal counts every in-radius
        leaf acceptance, not just the best ``k``)."""
        if metric not in RADIUS_METRICS:
            raise ValueError(f"unknown radius metric: {metric}")
        radius = check_radius(radius, metric)
        name = self._resolve_neighbor_name("count_within", metric,
                                           backend, radius=radius)
        if name in _NEIGHBOR_BACKENDS:
            return self._tree_neighbor(
                "count_within", queries, 1, radius, name,
                shard=shard, chunk_size=chunk_size).count
        return self._distance_fn(
            "count_within", queries, metric, name, (radius,),
            lambda s: count_within_scores(s, radius, metric),
            lambda: jnp.zeros((0,), jnp.int32),
            shard=shard, chunk_size=chunk_size)

    def scores(self, queries, metric: str = "euclidean", *,
               backend: str | None = None, shard=None,
               chunk_size: int | None = None) -> jax.Array:
        """The raw (M, N) score matrix (squared distances / similarities) —
        what MoE routers consume as logits."""
        if metric not in METRICS:
            raise ValueError(f"unknown metric: {metric}")
        return self._distance_fn(
            "scores", queries, metric, backend, (), lambda s: s,
            lambda: jnp.zeros((0, self.index.size), jnp.float32),
            shard=shard, chunk_size=chunk_size)

    def similarity(self, queries, *, backend: str | None = None,
                   shard=None, chunk_size: int | None = None) -> jax.Array:
        """Full cosine-similarity matrix (external-divider epilogue)."""
        return self.scores(queries, "cosine", backend=backend, shard=shard,
                           chunk_size=chunk_size)

    def __repr__(self):
        return (f"QueryEngine(scene={self.scene!r}, index={self.index!r}, "
                f"cloud={self.cloud!r}, "
                f"backend={self.default_backend!r}, "
                f"pad_multiple={self.pad_multiple}, "
                f"shard={self.default_shard!r}, "
                f"chunk_size={self.default_chunk_size}, "
                f"cache={self.cache_info()})")
