"""LBVH -> BVH4 builder, pure JAX.

The paper's OpQuadbox tests one ray against *four* AABBs because a hardware
ray tracer traverses a 4-wide BVH (RayCore-style unified pipeline).  To make
the datapath exercisable end-to-end we build that BVH here:

1. Morton-code the triangle centroids (30-bit, 10 bits/axis).
2. Sort primitives along the Z-order curve (``jnp.argsort`` -- a radix sort
   on TPU).
3. Build an *implicit* complete 4-ary tree over the sorted leaves and fit
   AABBs bottom-up with log4(N) fully-vectorised reduction sweeps.

The implicit layout keeps the builder allocation-free and jittable: node ``k``
has children ``4k+1 .. 4k+4``; level ``l`` starts at offset ``(4^l - 1) / 3``.
Empty (padded) leaves carry inverted boxes (lo=+inf, hi=-inf) which can never
intersect, so traversal needs no validity bitmap.

Exactly-degenerate triangles (zero area: ``(b-a) x (c-a) == 0``, covering
point and exactly-colinear soups) are culled into the same padded-leaf slot
at build time.  In exact arithmetic they can never be hit (every edge
function is 0, so ``t_denom == 0``), but under XLA's CPU mul->add FMA
contraction (see ``kernels/common.py: round_stage``) the fused edge
functions keep a rounding residue and a "hit" at a garbage t can slip
through the jitted engines.  Culling at build is exact, engine-independent,
and free at query time (``tests/test_degenerate.py`` pins it).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .types import Box, Triangle, aabb_of_triangles


class BVH4(NamedTuple):
    node_lo: jax.Array  # (num_nodes, 3) f32 -- implicit 4-ary heap, root first
    node_hi: jax.Array  # (num_nodes, 3) f32
    leaf_tri: jax.Array  # (4**depth,) i32 -- triangle index per leaf, -1 = pad
    triangles: Triangle  # original (unsorted) triangle soup, (N, 3)


def bvh4_depth(n_triangles: int) -> int:
    """Static tree depth: smallest D with 4**D >= n (min 1)."""
    return max(1, math.ceil(math.log(max(n_triangles, 2), 4)))


def level_offset(level: int) -> int:
    return (4**level - 1) // 3


def num_nodes(depth: int) -> int:
    return level_offset(depth + 1)


def _expand_bits(v: jax.Array) -> jax.Array:
    """Spread the low 10 bits of v so there are 2 zero bits between each."""
    u = jnp.uint32
    v = (v * u(0x00010001)) & u(0xFF0000FF)
    v = (v * u(0x00000101)) & u(0x0F00F00F)
    v = (v * u(0x00000011)) & u(0xC30C30C3)
    v = (v * u(0x00000005)) & u(0x49249249)
    return v


def morton3d(points01: jax.Array) -> jax.Array:
    """30-bit Morton codes for points in [0, 1]^3.  points01: (N, 3)."""
    scaled = jnp.clip(points01 * 1024.0, 0.0, 1023.0).astype(jnp.uint32)
    x = _expand_bits(scaled[:, 0])
    y = _expand_bits(scaled[:, 1])
    z = _expand_bits(scaled[:, 2])
    return (x << 2) | (y << 1) | z


def build_bvh4(tri: Triangle, depth: int | None = None) -> BVH4:
    """Build a BVH4 over a triangle soup.  ``depth`` must be static if given."""
    n = tri.a.shape[0]
    if depth is None:
        depth = bvh4_depth(n)
    n_leaves = 4**depth

    boxes = aabb_of_triangles(tri)
    centroid = 0.5 * (boxes.lo + boxes.hi)
    scene_lo = jnp.min(boxes.lo, axis=0)
    scene_hi = jnp.max(boxes.hi, axis=0)
    extent = jnp.maximum(scene_hi - scene_lo, 1e-12)
    codes = morton3d((centroid - scene_lo) / extent)

    order = jnp.argsort(codes).astype(jnp.int32)  # (N,)
    pad = n_leaves - n
    # degenerate cull: zero-area triangles become padded leaves (tri -1,
    # inverted box) so no engine can ever report them as hits
    nondegen = jnp.any(jnp.cross(tri.b - tri.a, tri.c - tri.a) != 0.0,
                       axis=-1)[order]
    leaf_tri = jnp.concatenate(
        [jnp.where(nondegen, order, -1), jnp.full((pad,), -1, jnp.int32)])
    leaf_lo = jnp.concatenate(
        [jnp.where(nondegen[:, None], boxes.lo[order], jnp.inf),
         jnp.full((pad, 3), jnp.inf, jnp.float32)])
    leaf_hi = jnp.concatenate(
        [jnp.where(nondegen[:, None], boxes.hi[order], -jnp.inf),
         jnp.full((pad, 3), -jnp.inf, jnp.float32)])

    # Bottom-up AABB fit: D vectorised sweeps (4-to-1 reductions).
    levels_lo, levels_hi = [leaf_lo], [leaf_hi]
    cur_lo, cur_hi = leaf_lo, leaf_hi
    for _ in range(depth):
        cur_lo = cur_lo.reshape(-1, 4, 3).min(axis=1)
        cur_hi = cur_hi.reshape(-1, 4, 3).max(axis=1)
        levels_lo.append(cur_lo)
        levels_hi.append(cur_hi)
    node_lo = jnp.concatenate(levels_lo[::-1], axis=0)  # root (level 0) first
    node_hi = jnp.concatenate(levels_hi[::-1], axis=0)
    return BVH4(node_lo=node_lo, node_hi=node_hi, leaf_tri=leaf_tri, triangles=tri)


def child_boxes(bvh: BVH4, node_idx: jax.Array) -> Box:
    """The 4 child AABBs of an internal node -- one OpQuadbox operand."""
    base = 4 * node_idx + 1
    idx = base[..., None] + jnp.arange(4, dtype=jnp.int32)
    return Box(lo=bvh.node_lo[idx], hi=bvh.node_hi[idx])
