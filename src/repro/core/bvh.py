"""BVH4/BVH8: the implicit wide acceleration structure the datapath
traverses.

The paper's OpQuadbox tests one ray against *four* AABBs because a hardware
ray tracer traverses a 4-wide BVH (RayCore-style unified pipeline).  This
module is the **engine-facing contract** for that structure: the
:class:`BVH4` record, the implicit-layout helpers, and :func:`child_boxes`
(one OpQuadbox operand).  *Construction* lives one layer up, in
:mod:`repro.core.build` — a registry of pluggable builders (``"lbvh"``,
``"sah"``) that all emit this same layout, so every traversal engine,
backend, sharding knob and Pallas kernel consumes any builder's tree
unchanged.

The implicit layout keeps builders and refit allocation-free and jittable:
for arity ``A``, node ``k`` has children ``A*k+1 .. A*k+A``; level ``l``
starts at offset ``(A^l - 1) / (A - 1)``.  Empty (padded) leaves carry
inverted boxes (lo=+inf, hi=-inf) which can never intersect, so traversal
needs no validity bitmap.

:class:`DatapathConfig` is the paper's research program in one record: the
datapath knobs RayFlex sweeps in RTL (pipeline widths, stack sizing,
shared node formats) as their software twins — BVH arity, traversal stack
depth, box-test precision, and the node box format.  It is defined once
here and threaded (as a *static* argument, like ``depth``) through
builders, both engines, the fused Pallas kernel and the session API.
The reduced-precision formats are **conservative**: boxes are only ever
widened, so traversal under any config visits a superset of the exact
tree's nodes — closest-hit results stay bit-identical to fp32 while job
counters may grow (the tested contract; see DESIGN.md §12).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .types import Box, Triangle

BOX_PRECISIONS = ("fp32", "bf16")
NODE_FORMATS = ("fp32", "compressed")


class DatapathConfig(NamedTuple):
    """Static datapath configuration (hashable: python scalars only).

    * ``arity`` — BVH branching factor (4 or 8); one box-test job covers
      ``arity`` child AABBs.
    * ``stack_size`` — per-ray traversal stack slots.  Pushing past
      capacity drops the push and sets the per-ray ``stack_overflow``
      flag (identically in every engine) instead of corrupting the walk.
    * ``precision`` — box storage precision: ``"fp32"`` (exact) or
      ``"bf16"`` (boxes conservatively widened onto the bf16 grid, so
      the Pallas kernel can keep them as real bf16 rows in VMEM).
    * ``node_format`` — ``"fp32"`` or ``"compressed"``: parent-relative
      8-bit quantized child boxes (decoded at build into conservative
      bf16-grid f32 arrays; 6 analytic bytes/node vs 24).
    """
    arity: int = 4
    stack_size: int = 64
    precision: str = "fp32"
    node_format: str = "fp32"

    @property
    def tag(self) -> str:
        """Stable id used in golden keys, bench rows and cache keys."""
        return (f"bvh{self.arity}_s{self.stack_size}"
                f"_{self.precision}_{self.node_format}")

    @property
    def exact_boxes(self) -> bool:
        """True iff node boxes are bit-exact f32 (no conservative widen)."""
        return self.precision == "fp32" and self.node_format == "fp32"

    @property
    def packed_box_dtype(self):
        """Storage dtype for node-box rows in the packed Pallas operand.

        bf16 and compressed boxes land exactly on the bf16 grid by
        construction, so storing bf16 rows halves VMEM with a lossless
        upcast in-kernel (parity with the wavefront engine preserved).
        """
        return jnp.float32 if self.exact_boxes else jnp.bfloat16

    @property
    def box_bytes_per_node(self) -> int:
        """Analytic node-box storage cost (lo+hi, 3 axes) per node."""
        if self.node_format == "compressed":
            return 6                      # u8 per axis per bound
        return 12 if self.precision == "bf16" else 24

    def validate(self) -> "DatapathConfig":
        if self.arity not in (4, 8):
            raise ValueError(f"arity must be 4 or 8, got {self.arity}")
        if self.stack_size < 1:
            raise ValueError(f"stack_size must be >= 1, got {self.stack_size}")
        if self.precision not in BOX_PRECISIONS:
            raise ValueError(f"precision must be one of {BOX_PRECISIONS}, "
                             f"got {self.precision!r}")
        if self.node_format not in NODE_FORMATS:
            raise ValueError(f"node_format must be one of {NODE_FORMATS}, "
                             f"got {self.node_format!r}")
        return self


DEFAULT_CONFIG = DatapathConfig()


def resolve_config(config: DatapathConfig | None) -> DatapathConfig:
    """``None`` -> the seed-equivalent default (BVH4 / fp32 / fp32)."""
    if config is None:
        return DEFAULT_CONFIG
    return config.validate()


class BVH4(NamedTuple):
    node_lo: jax.Array  # (num_nodes, 3) f32 -- implicit A-ary heap, root first
    node_hi: jax.Array  # (num_nodes, 3) f32
    leaf_tri: jax.Array  # (A**depth,) i32 -- triangle index per leaf, -1 = pad
    triangles: Triangle  # original (unsorted) triangle soup, (N, 3)
    leaf_perm: jax.Array  # (A**depth,) i32 -- the builder's slot assignment
    # *before* the degenerate cull (-1 = genuinely empty pad slot), so refit
    # can re-evaluate the cull for the current geometry each frame


def bvh_depth(n_triangles: int, arity: int = 4) -> int:
    """Static tree depth: smallest D with arity**D >= n (min 1)."""
    return max(1, math.ceil(math.log(max(n_triangles, 2), arity)))


def bvh4_depth(n_triangles: int) -> int:
    """Static tree depth: smallest D with 4**D >= n (min 1)."""
    return bvh_depth(n_triangles, 4)


def level_offset(level: int, arity: int = 4) -> int:
    return (arity**level - 1) // (arity - 1)


def num_nodes(depth: int, arity: int = 4) -> int:
    return level_offset(depth + 1, arity)


def depth_of(bvh: BVH4, arity: int = 4) -> int:
    """Recover the static depth from the leaf array length (arity**depth)."""
    return bvh_depth(bvh.leaf_tri.shape[0], arity)


def fit_nodes(leaf_lo: jax.Array, leaf_hi: jax.Array,
              depth: int, arity: int = 4) -> tuple[jax.Array, jax.Array]:
    """Bottom-up AABB fit over the implicit tree: ``depth`` vectorised
    ``arity``-to-1 reduction sweeps from ``(arity**depth, 3)`` leaf boxes to
    the full ``(num_nodes, 3)`` node arrays (root first).  Shared by every
    builder and by :func:`repro.core.build.refit` — inverted (empty) leaves
    propagate as inverted internal boxes for free.
    """
    levels_lo, levels_hi = [leaf_lo], [leaf_hi]
    cur_lo, cur_hi = leaf_lo, leaf_hi
    for _ in range(depth):
        cur_lo = cur_lo.reshape(-1, arity, 3).min(axis=1)
        cur_hi = cur_hi.reshape(-1, arity, 3).max(axis=1)
        levels_lo.append(cur_lo)
        levels_hi.append(cur_hi)
    node_lo = jnp.concatenate(levels_lo[::-1], axis=0)  # root (level 0) first
    node_hi = jnp.concatenate(levels_hi[::-1], axis=0)
    return node_lo, node_hi


def nondegenerate_mask(tri: Triangle) -> jax.Array:
    """Which triangles have exactly nonzero area (``(b-a) x (c-a) != 0``).

    Exactly-degenerate triangles (point and exactly-colinear soups) are
    culled into padded-leaf slots at build time.  In exact arithmetic they
    can never be hit (every edge function is 0, so ``t_denom == 0``), but
    under XLA's CPU mul->add FMA contraction (see ``kernels/common.py:
    round_stage``) the fused edge functions keep a rounding residue and a
    "hit" at a garbage t can slip through the jitted engines.  Culling at
    build is exact, engine-independent, and free at query time
    (``tests/test_degenerate.py`` pins it).
    """
    return jnp.any(jnp.cross(tri.b - tri.a, tri.c - tri.a) != 0.0, axis=-1)


def leaf_arrays(leaf_perm: jax.Array, boxes: Box,
                nondegen: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """``(leaf_tri, leaf_lo, leaf_hi)`` from a builder's slot assignment,
    with the degenerate cull applied to the *current* geometry — shared by
    both builders and by refit, so a refit frame culls (and un-culls)
    exactly as a fresh build of the same triangles would."""
    safe = jnp.maximum(leaf_perm, 0)
    live = (leaf_perm >= 0) & nondegen[safe]
    leaf_tri = jnp.where(live, leaf_perm, -1)
    leaf_lo = jnp.where(live[:, None], boxes.lo[safe], jnp.inf)
    leaf_hi = jnp.where(live[:, None], boxes.hi[safe], -jnp.inf)
    return leaf_tri, leaf_lo, leaf_hi


def child_boxes(bvh: BVH4, node_idx: jax.Array, arity: int = 4) -> Box:
    """The ``arity`` child AABBs of an internal node -- one box-test job."""
    base = arity * node_idx + 1
    idx = base[..., None] + jnp.arange(arity, dtype=jnp.int32)
    return Box(lo=bvh.node_lo[idx], hi=bvh.node_hi[idx])


# ---------------------------------------------------------------------------
# Conservative node-box codecs (DatapathConfig.precision / .node_format).
#
# Both codecs are *decode-at-build*: the stored BVH always carries plain f32
# node arrays, but for reduced-precision configs those f32 values are the
# exact decode of the narrow format (every value lands on the bf16 grid).
# Every engine therefore consumes identical arrays — wavefront / per-ray /
# fused-Pallas parity under any config is structural, not re-proven per
# engine — while the Pallas packer is free to store the rows as genuine
# bf16 (lossless upcast) for the VMEM saving the format exists for.
#
# Conservativeness: lo is only ever moved down, hi only up, so a decoded
# box is a superset of the exact box.  Traversal can then only *add*
# visited nodes (never cull a node containing the true closest hit), which
# is the superset contract the fuzz/golden tests pin.
# ---------------------------------------------------------------------------

_BF16_REL = 2.0**-7   # widening bias; dominates the bf16 half-ulp of 2^-9
_BF16_ABS = 1e-30     # absolute floor so exact-zero bounds still move


def _bf16_down(x: jax.Array) -> jax.Array:
    """Largest-practical bf16-grid value <= x (widen-then-round: the bias
    2^-7 strictly dominates the cast's half-ulp 2^-9, so the rounded result
    provably stays below x).  Non-finite values pass through unchanged —
    padded leaves keep their inverted (+inf, -inf) boxes."""
    widened = x - _BF16_REL * jnp.abs(x) - _BF16_ABS
    snapped = widened.astype(jnp.bfloat16).astype(jnp.float32)
    return jnp.where(jnp.isfinite(x), snapped, x)


def _bf16_up(x: jax.Array) -> jax.Array:
    """Smallest-practical bf16-grid value >= x (mirror of :func:`_bf16_down`)."""
    widened = x + _BF16_REL * jnp.abs(x) + _BF16_ABS
    snapped = widened.astype(jnp.bfloat16).astype(jnp.float32)
    return jnp.where(jnp.isfinite(x), snapped, x)


def quantize_boxes_bf16(lo: jax.Array, hi: jax.Array
                        ) -> tuple[jax.Array, jax.Array]:
    """Conservatively widen boxes onto the bf16 grid (lo down, hi up)."""
    return _bf16_down(lo), _bf16_up(hi)


def compress_nodes(node_lo: jax.Array, node_hi: jax.Array, depth: int,
                   arity: int = 4) -> tuple[jax.Array, jax.Array]:
    """Parent-relative 8-bit child-box quantization, decoded at build.

    Top-down, level by level: the root keeps its f32 box; every other
    node's bounds are snapped to a 256-step grid spanning its (already
    decoded) parent's box, with a one-step conservative fixup so
    ``decoded_lo <= lo`` and ``decoded_hi >= hi`` always hold.  The chain
    parent->child uses *decoded* parent bounds, exactly as a hardware
    decoder walking the compressed tree would.  Finally every bound is
    snapped conservatively onto the bf16 grid so the packed Pallas operand
    can store 16-bit rows losslessly.  Analytic cost: 6 bytes/node
    (u8 x 3 axes x lo/hi) vs 24 for raw f32.
    """
    out_lo, out_hi = [node_lo[:1]], [node_hi[:1]]  # root stays exact
    for level in range(1, depth + 1):
        start, stop = level_offset(level, arity), level_offset(level + 1, arity)
        lo, hi = node_lo[start:stop], node_hi[start:stop]
        # decoded parent boxes, repeated over each parent's `arity` children
        p_lo = jnp.repeat(out_lo[-1], arity, axis=0)
        p_hi = jnp.repeat(out_hi[-1], arity, axis=0)
        step = (p_hi - p_lo) / 255.0
        safe = jnp.where(step > 0.0, step, 1.0)
        q_lo = jnp.clip(jnp.floor((lo - p_lo) / safe), 0.0, 255.0)
        q_hi = jnp.clip(jnp.ceil((hi - p_lo) / safe), 0.0, 255.0)
        d_lo = p_lo + q_lo * safe
        d_hi = p_lo + q_hi * safe
        # one-step fixup: f32 rounding in the divide can land one grid
        # step short of conservative; nudge and clamp to the parent box
        d_lo = jnp.maximum(p_lo, jnp.where(d_lo > lo, d_lo - safe, d_lo))
        d_hi = jnp.minimum(p_hi, jnp.where(d_hi < hi, d_hi + safe, d_hi))
        # degenerate (step == 0) and non-finite (empty-pad) boxes pass
        # through: an empty parent's children are empty, a zero-extent
        # parent's children equal the parent bound
        d_lo = jnp.where((step > 0.0) & jnp.isfinite(lo), d_lo, lo)
        d_hi = jnp.where((step > 0.0) & jnp.isfinite(hi), d_hi, hi)
        out_lo.append(_bf16_down(d_lo))
        out_hi.append(_bf16_up(d_hi))
    return jnp.concatenate(out_lo, axis=0), jnp.concatenate(out_hi, axis=0)


def encode_nodes(node_lo: jax.Array, node_hi: jax.Array, depth: int,
                 config: DatapathConfig | None) -> tuple[jax.Array, jax.Array]:
    """Apply the config's node-box codec to freshly fit node arrays.

    The single post-:func:`fit_nodes` hook every builder and refit path
    calls, so a refit frame encodes exactly as a fresh build would (the
    zero-retrace contract extends to every config)."""
    config = resolve_config(config)
    if config.node_format == "compressed":
        return compress_nodes(node_lo, node_hi, depth, config.arity)
    if config.precision == "bf16":
        return quantize_boxes_bf16(node_lo, node_hi)
    return node_lo, node_hi
