"""BVH4: the implicit 4-wide acceleration structure the datapath traverses.

The paper's OpQuadbox tests one ray against *four* AABBs because a hardware
ray tracer traverses a 4-wide BVH (RayCore-style unified pipeline).  This
module is the **engine-facing contract** for that structure: the
:class:`BVH4` record, the implicit-layout helpers, and :func:`child_boxes`
(one OpQuadbox operand).  *Construction* lives one layer up, in
:mod:`repro.core.build` — a registry of pluggable builders (``"lbvh"``,
``"sah"``) that all emit this same layout, so every traversal engine,
backend, sharding knob and Pallas kernel consumes any builder's tree
unchanged.

The implicit layout keeps builders and refit allocation-free and jittable:
node ``k`` has children ``4k+1 .. 4k+4``; level ``l`` starts at offset
``(4^l - 1) / 3``.  Empty (padded) leaves carry inverted boxes
(lo=+inf, hi=-inf) which can never intersect, so traversal needs no
validity bitmap.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .types import Box, Triangle


class BVH4(NamedTuple):
    node_lo: jax.Array  # (num_nodes, 3) f32 -- implicit 4-ary heap, root first
    node_hi: jax.Array  # (num_nodes, 3) f32
    leaf_tri: jax.Array  # (4**depth,) i32 -- triangle index per leaf, -1 = pad
    triangles: Triangle  # original (unsorted) triangle soup, (N, 3)
    leaf_perm: jax.Array  # (4**depth,) i32 -- the builder's slot assignment
    # *before* the degenerate cull (-1 = genuinely empty pad slot), so refit
    # can re-evaluate the cull for the current geometry each frame


def bvh4_depth(n_triangles: int) -> int:
    """Static tree depth: smallest D with 4**D >= n (min 1)."""
    return max(1, math.ceil(math.log(max(n_triangles, 2), 4)))


def level_offset(level: int) -> int:
    return (4**level - 1) // 3


def num_nodes(depth: int) -> int:
    return level_offset(depth + 1)


def depth_of(bvh: BVH4) -> int:
    """Recover the static depth from the leaf array length (4**depth)."""
    return bvh4_depth(bvh.leaf_tri.shape[0])


def fit_nodes(leaf_lo: jax.Array, leaf_hi: jax.Array,
              depth: int) -> tuple[jax.Array, jax.Array]:
    """Bottom-up AABB fit over the implicit tree: ``depth`` vectorised
    4-to-1 reduction sweeps from ``(4**depth, 3)`` leaf boxes to the full
    ``(num_nodes, 3)`` node arrays (root first).  Shared by every builder
    and by :func:`repro.core.build.refit` — inverted (empty) leaves
    propagate as inverted internal boxes for free.
    """
    levels_lo, levels_hi = [leaf_lo], [leaf_hi]
    cur_lo, cur_hi = leaf_lo, leaf_hi
    for _ in range(depth):
        cur_lo = cur_lo.reshape(-1, 4, 3).min(axis=1)
        cur_hi = cur_hi.reshape(-1, 4, 3).max(axis=1)
        levels_lo.append(cur_lo)
        levels_hi.append(cur_hi)
    node_lo = jnp.concatenate(levels_lo[::-1], axis=0)  # root (level 0) first
    node_hi = jnp.concatenate(levels_hi[::-1], axis=0)
    return node_lo, node_hi


def nondegenerate_mask(tri: Triangle) -> jax.Array:
    """Which triangles have exactly nonzero area (``(b-a) x (c-a) != 0``).

    Exactly-degenerate triangles (point and exactly-colinear soups) are
    culled into padded-leaf slots at build time.  In exact arithmetic they
    can never be hit (every edge function is 0, so ``t_denom == 0``), but
    under XLA's CPU mul->add FMA contraction (see ``kernels/common.py:
    round_stage``) the fused edge functions keep a rounding residue and a
    "hit" at a garbage t can slip through the jitted engines.  Culling at
    build is exact, engine-independent, and free at query time
    (``tests/test_degenerate.py`` pins it).
    """
    return jnp.any(jnp.cross(tri.b - tri.a, tri.c - tri.a) != 0.0, axis=-1)


def leaf_arrays(leaf_perm: jax.Array, boxes: Box,
                nondegen: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """``(leaf_tri, leaf_lo, leaf_hi)`` from a builder's slot assignment,
    with the degenerate cull applied to the *current* geometry — shared by
    both builders and by refit, so a refit frame culls (and un-culls)
    exactly as a fresh build of the same triangles would."""
    safe = jnp.maximum(leaf_perm, 0)
    live = (leaf_perm >= 0) & nondegen[safe]
    leaf_tri = jnp.where(live, leaf_perm, -1)
    leaf_lo = jnp.where(live[:, None], boxes.lo[safe], jnp.inf)
    leaf_hi = jnp.where(live[:, None], boxes.hi[safe], -jnp.inf)
    return leaf_tri, leaf_lo, leaf_hi


def child_boxes(bvh: BVH4, node_idx: jax.Array) -> Box:
    """The 4 child AABBs of an internal node -- one OpQuadbox operand."""
    base = 4 * node_idx + 1
    idx = base[..., None] + jnp.arange(4, dtype=jnp.int32)
    return Box(lo=bvh.node_lo[idx], hi=bvh.node_hi[idx])
