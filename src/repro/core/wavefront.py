"""Wavefront BVH4 traversal: one batched datapath job stream per round.

:func:`repro.core.traversal.trace_rays` vmaps a per-ray ``lax.while_loop``:
every ray owns a private loop, so under vmap the whole batch iterates until
the *slowest* ray's stack drains and every other lane idles along masked.
The hardware the paper models does the opposite — a scheduler keeps one
fixed-latency pipeline full of heterogeneous jobs drawn from *all* in-flight
rays (RTNN-style wavefront/batched query scheduling).

This module is that scheduler's TPU analogue.  The loop lives at the *batch*
level and each round issues:

* one batched **OpQuadbox** job over the whole active frontier (every active
  ray pops its stack top and tests the node's 4 child AABBs at once), and
* one batched round of **OpTriangle** jobs (4 per active leaf-parent ray),

both through the shared stage helpers in :mod:`repro.core.datapath` — the
same functional units the per-ray engine uses, so closest-hit results
bit-match :func:`trace_rays` (it remains the semantic oracle).

State is SoA across the batch (stacks ``(R, STACK_SIZE)``, stack pointers
``(R,)``); terminated rays are compacted out of each round via masking, and
the loop carries a fixed round bound with early exit once the frontier is
empty (DESIGN.md §3).

Three query types (CrossRT-style closest-hit/any-hit split):

* ``"closest"`` — full closest-hit traversal (identical results to
  :func:`trace_rays`),
* ``"any"``     — any-hit / occlusion: a ray retires on its *first* accepted
  hit inside the extent; no closest-hit ordering is paid for,
* ``"shadow"``  — any-hit for extent-limited shadow rays, with a ``t_min``
  epsilon so a ray leaving a surface does not re-hit it at t ~ 0.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .bvh import BVH4, DatapathConfig, child_boxes, level_offset, resolve_config
from .datapath import ray_box_test, ray_triangle_test
from .traversal import STACK_SIZE, _gather_triangles

RAY_TYPES = ("closest", "any", "shadow")


class WavefrontRecord(NamedTuple):
    """Per-ray results plus the frontier-level scheduling statistics."""

    t: jax.Array  # (R,) f32  hit distance (inf = miss)
    tri_index: jax.Array  # (R,) i32  index into the soup, -1 = miss
    hit: jax.Array  # (R,) bool
    quadbox_jobs: jax.Array  # (R,) i32  per-ray OpQuadbox jobs issued
    triangle_jobs: jax.Array  # (R,) i32  per-ray OpTriangle jobs issued
    stack_overflow: jax.Array  # (R,) bool  a push was dropped at capacity
    rounds: jax.Array  # ()   i32  batched rounds = batched OpQuadbox jobs


def _tile_ray(rays, width: int):
    """(R,)-batched Ray -> (R, width)-batched Ray (shared across lanes)."""
    return type(rays)(*[
        jnp.broadcast_to(f[:, None, ...], (f.shape[0], width) + f.shape[1:])
        for f in rays
    ])


SHADOW_T_MIN = 1e-3  # default self-intersection epsilon for shadow rays


def trace_wavefront(bvh: BVH4, rays, depth: int, ray_type: str = "closest",
                    t_min: float | None = None,
                    max_rounds: int | None = None,
                    config: DatapathConfig | None = None) -> WavefrontRecord:
    """Traverse a whole ray batch with one batch-level loop.

    ``rays`` must carry a single leading batch axis (flatten first).
    ``ray_type`` and ``max_rounds`` are static; ``max_rounds`` defaults to the
    internal-node count (each node is popped at most once per ray, so that
    bound is exact, not a heuristic).  ``t_min`` rejects hits nearer than the
    epsilon; it defaults to 0 (accept everything — hits always have t > 0)
    except for ``"shadow"`` rays, which default to :data:`SHADOW_T_MIN` so a
    ray leaving a surface does not re-hit it at t ~ 0.
    """
    if ray_type not in RAY_TYPES:
        raise ValueError(f"ray_type must be one of {RAY_TYPES}, got {ray_type!r}")
    if t_min is None:
        t_min = SHADOW_T_MIN if ray_type == "shadow" else 0.0
    config = resolve_config(config)
    arity, stack_size = config.arity, config.stack_size
    leaf_parent_offset = level_offset(depth - 1, arity)
    leaf_offset = level_offset(depth, arity)
    if max_rounds is None:
        max_rounds = level_offset(depth, arity)  # = number of internal nodes

    n_rays = rays.origin.shape[0]
    rows = jnp.arange(n_rays, dtype=jnp.int32)
    t_min = jnp.float32(t_min)

    stack0 = jnp.zeros((n_rays, stack_size), jnp.int32)  # root pre-pushed
    state0 = (stack0, jnp.ones((n_rays,), jnp.int32),
              jnp.full((n_rays,), jnp.inf, jnp.float32),
              jnp.full((n_rays,), -1, jnp.int32),
              jnp.zeros((n_rays,), jnp.int32), jnp.zeros((n_rays,), jnp.int32),
              jnp.zeros((n_rays,), bool), jnp.zeros((n_rays,), bool),
              jnp.int32(0))

    def cond(state):
        _, sp, _, _, _, _, _, done, rounds = state
        return jnp.any((sp > 0) & ~done) & (rounds < max_rounds)

    def body(state):
        stack, sp, t_best, best_tri, n_qb, n_tri, overflow, done, rounds = state
        active = (sp > 0) & ~done

        # frontier pop (masked compaction: retired rays contribute no jobs)
        node = jnp.where(active, stack[rows, jnp.maximum(sp - 1, 0)], 0)
        sp = jnp.where(active, sp - 1, sp)
        is_leaf_parent = node >= leaf_parent_offset

        # ---- one batched box-test job over the whole frontier ---------------
        boxes = child_boxes(bvh, node, arity)  # (R, arity, lo/hi)
        qb = ray_box_test(rays, boxes)

        # ---- batched OpTriangle round for the leaf-parent rays --------------
        leaf_pos = (arity * node[:, None] + 1 - leaf_offset
                    + jnp.arange(arity, dtype=jnp.int32))
        leaf_pos = jnp.clip(leaf_pos, 0, bvh.leaf_tri.shape[0] - 1)
        tri_idx = bvh.leaf_tri[leaf_pos]  # (R, arity), -1 = padded leaf
        tris = _gather_triangles(bvh.triangles, tri_idx)
        tr = ray_triangle_test(_tile_ray(rays, arity), tris)
        t = tr.t_num / tr.t_denom  # external division, as in trace_ray
        valid = (tr.hit & (tri_idx >= 0) & (t < t_best[:, None])
                 & (t <= rays.extent[:, None]) & (t >= t_min))
        t_masked = jnp.where(valid, t, jnp.inf)
        j = jnp.argmin(t_masked, axis=1)
        leaf_t = t_masked[rows, j]
        leaf_better = active & is_leaf_parent & (leaf_t < t_best)
        t_best = jnp.where(leaf_better, leaf_t, t_best)
        best_tri = jnp.where(leaf_better, tri_idx[rows, j], best_tri)
        if ray_type != "closest":  # any-hit: retire on the first accepted hit
            done = done | leaf_better

        # ---- push hit children far-to-near (sort-network output order) ------
        def push_child(i, carry):
            stack, sp, overflow = carry
            slot = arity - 1 - i  # reverse: farthest first, nearest on top
            ok = (active & ~is_leaf_parent & qb.is_intersect[:, slot]
                  & (qb.tmin[:, slot] < t_best))
            child = arity * node + 1 + qb.box_index[:, slot]
            can = ok & (sp < stack_size)  # drop-and-flag at capacity
            overflow = overflow | (ok & (sp >= stack_size))
            pos = jnp.minimum(sp, stack_size - 1)
            cur = stack[rows, pos]
            stack = stack.at[rows, pos].set(jnp.where(can, child, cur))
            sp = jnp.where(can, sp + 1, sp)
            return stack, sp, overflow

        stack, sp, overflow = jax.lax.fori_loop(
            0, arity, push_child, (stack, sp, overflow))
        n_qb = n_qb + active.astype(jnp.int32)
        n_tri = n_tri + jnp.where(active & is_leaf_parent, arity, 0)
        return (stack, sp, t_best, best_tri, n_qb, n_tri, overflow, done,
                rounds + 1)

    (_, _, t_best, best_tri, n_qb, n_tri, overflow, _, rounds
     ) = jax.lax.while_loop(cond, body, state0)
    return WavefrontRecord(t=t_best, tri_index=best_tri, hit=best_tri >= 0,
                           quadbox_jobs=n_qb, triangle_jobs=n_tri,
                           stack_overflow=overflow, rounds=rounds)


def occlusion_test(bvh: BVH4, rays, depth: int,
                   t_min: float = SHADOW_T_MIN) -> jax.Array:
    """Boolean shadow/visibility query: is anything hit within each ray's
    extent?  Rays should be built with ``extent=`` distance-to-light for
    point lights (extent-limited) or inf for directional lights."""
    return trace_wavefront(bvh, rays, depth, ray_type="shadow", t_min=t_min).hit
