"""Batched BVH traversal: the unified Traversal-and-Intersection loop.

Each traversal step issues exactly the jobs the paper's datapath serves:

* internal node  -> one **box-test** job (the node's ``arity`` child AABBs,
  sorted-hit output drives near-to-far ordering via the datapath's sorting
  network — the paper's quad-sort for BVH4, the 8-wide network for BVH8),
* leaf parent    -> ``arity`` **OpTriangle** jobs (watertight Woop test);
  the deferred division ``t = t_num / t_denom`` happens here, *outside* the
  datapath, exactly as the paper prescribes.

The loop is a fixed-size-stack ``lax.while_loop`` vmapped over rays; on TPU
the whole wavefront executes in lockstep which mirrors a fixed-latency
pipeline fed by a scheduler.  The stack size is a
:class:`~repro.core.bvh.DatapathConfig` knob: pushing past capacity drops
the push and raises the per-ray ``stack_overflow`` flag instead of
silently corrupting the walk (every engine implements the identical
drop-and-flag semantics, so results stay bit-equal even when overflowing).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .bvh import BVH4, DatapathConfig, child_boxes, level_offset, resolve_config
from .datapath import ray_box_test, ray_triangle_test
from .types import Ray, Triangle

STACK_SIZE = 64  # DatapathConfig default (DEFAULT_CONFIG.stack_size)


class HitRecord(NamedTuple):
    t: jax.Array  # (...,) f32  distance of closest hit (inf = miss)
    tri_index: jax.Array  # (...,) i32  index into the original soup, -1 = miss
    hit: jax.Array  # (...,) bool
    quadbox_jobs: jax.Array  # (...,) i32  datapath job accounting
    triangle_jobs: jax.Array  # (...,) i32
    stack_overflow: jax.Array  # (...,) bool  a push was dropped at capacity


def _broadcast_ray(ray: Ray, shape: tuple) -> Ray:
    return Ray(*[jnp.broadcast_to(f, shape + f.shape) for f in ray])


def _gather_triangles(tri: Triangle, idx: jax.Array) -> Triangle:
    safe = jnp.maximum(idx, 0)
    return Triangle(a=tri.a[safe], b=tri.b[safe], c=tri.c[safe])


def trace_ray(bvh: BVH4, ray: Ray, depth: int,
              config: DatapathConfig | None = None) -> HitRecord:
    """Closest-hit traversal for a single ray (vmap over this for batches)."""
    config = resolve_config(config)
    arity, stack_size = config.arity, config.stack_size
    leaf_parent_offset = level_offset(depth - 1, arity)
    leaf_offset = level_offset(depth, arity)

    stack0 = jnp.zeros((stack_size,), jnp.int32)  # root = node 0 pre-pushed
    state0 = (stack0, jnp.int32(1), jnp.float32(jnp.inf), jnp.int32(-1),
              jnp.int32(0), jnp.int32(0), jnp.bool_(False))

    def cond(state):
        _, sp, _, _, _, _, _ = state
        return sp > 0

    def body(state):
        stack, sp, t_best, best_tri, n_qb, n_tri, overflow = state
        node = stack[sp - 1]
        sp = sp - 1

        is_leaf_parent = node >= leaf_parent_offset

        # ---- box-test job on the `arity` children ---------------------------
        boxes = child_boxes(bvh, node, arity)
        qb = ray_box_test(ray, boxes)

        # ---- `arity` OpTriangle jobs when children are leaves ---------------
        leaf_pos = (arity * node + 1 - leaf_offset
                    + jnp.arange(arity, dtype=jnp.int32))
        leaf_pos = jnp.clip(leaf_pos, 0, bvh.leaf_tri.shape[0] - 1)
        tri_idx = bvh.leaf_tri[leaf_pos]  # (arity,), -1 = padded leaf
        tris = _gather_triangles(bvh.triangles, tri_idx)
        tr = ray_triangle_test(_broadcast_ray(ray, (arity,)), tris)
        # external division (the datapath outputs num/denom only)
        t = tr.t_num / tr.t_denom
        valid = tr.hit & (tri_idx >= 0) & (t < t_best) & (t <= ray.extent)
        t_masked = jnp.where(valid, t, jnp.inf)
        j = jnp.argmin(t_masked)
        leaf_t = t_masked[j]
        leaf_better = is_leaf_parent & (leaf_t < t_best)
        t_best = jnp.where(leaf_better, leaf_t, t_best)
        best_tri = jnp.where(leaf_better, tri_idx[j], best_tri)

        # ---- push hit children far-to-near (sorted output of the network) --
        def push_child(i, carry):
            stack, sp, overflow = carry
            slot = arity - 1 - i  # reverse order: farthest first, nearest top
            ok = (~is_leaf_parent) & qb.is_intersect[slot] & (qb.tmin[slot] < t_best)
            child = arity * node + 1 + qb.box_index[slot]
            can = ok & (sp < stack_size)
            overflow = overflow | (ok & (sp >= stack_size))
            pos = jnp.minimum(sp, stack_size - 1)  # in-bounds even when full
            stack = jnp.where(can, stack.at[pos].set(child), stack)
            sp = jnp.where(can, sp + 1, sp)
            return stack, sp, overflow

        stack, sp, overflow = jax.lax.fori_loop(
            0, arity, push_child, (stack, sp, overflow))
        n_qb = n_qb + 1
        n_tri = n_tri + jnp.where(is_leaf_parent, arity, 0)
        return stack, sp, t_best, best_tri, n_qb, n_tri, overflow

    (stack, sp, t_best, best_tri,
     n_qb, n_tri, overflow) = jax.lax.while_loop(cond, body, state0)
    return HitRecord(t=t_best, tri_index=best_tri, hit=best_tri >= 0,
                     quadbox_jobs=n_qb, triangle_jobs=n_tri,
                     stack_overflow=overflow)


def trace_rays(bvh: BVH4, rays: Ray, depth: int,
               config: DatapathConfig | None = None) -> HitRecord:
    """Wavefront traversal: vmap of :func:`trace_ray` over a ray batch."""
    return jax.vmap(lambda r: trace_ray(bvh, r, depth, config))(rays)
