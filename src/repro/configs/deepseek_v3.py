"""deepseek-v3-671b — MLA + 256-expert MoE + MTP.

[arXiv:2412.19437; hf:deepseek-ai/DeepSeek-V3]
61L, d_model 7168, 128 MLA heads, vocab 129280.  First 3 layers dense
(d_ff 18432); remaining 58 layers MoE: 1 shared + 256 routed experts,
top-8, expert d_ff 2048 (the assignment's d_ff=2048 is the expert width),
sigmoid gating with routed scaling 2.5.  MLA: q_lora 1536, kv_lora 512,
nope/rope head dims 128/64, v_head 128.  One MTP depth-1 head.

Deviations (recorded in DESIGN.md): capacity-based top-k dispatch instead
of dropless aux-loss-free balancing; no node-limited routing (the EP scheme
here keeps tokens local and psum-combines instead of all-to-all).
"""
from repro.models import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b", family="moe",
    num_layers=61, d_model=7168, num_heads=128, num_kv_heads=128,
    d_ff=18432, vocab_size=129280,
    attention="mla",
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
    moe_pattern=(True,), moe_first_dense=3,
    moe=MoEConfig(num_experts=256, top_k=8, d_ff_expert=2048, num_shared=1,
                  router="sigmoid", route_scale=2.5),
    mtp_depth=1,
)

SMOKE = ModelConfig(
    name="deepseek-smoke", family="moe",
    num_layers=4, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=192, vocab_size=256,
    attention="mla",
    mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
                  qk_rope_head_dim=8, v_head_dim=16),
    moe_pattern=(True,), moe_first_dense=1,
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=64, num_shared=1,
                  router="sigmoid", route_scale=2.5),
    mtp_depth=1, attn_chunk=16, logit_chunk=32,
)
