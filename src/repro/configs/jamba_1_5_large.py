"""jamba-1.5-large-398b — hybrid Mamba + attention MoE LM.

[arXiv:2403.19887 (Jamba), 2408.12570 (1.5); hf:ai21labs/AI21-Jamba-1.5-Large]
72L, d_model 8192, 64 heads (GQA kv=8, head_dim 128), d_ff 24576,
vocab 65536.  Layer pattern: 1 attention : 7 mamba per 8-layer period
(attention at position 4); MoE (16 experts, top-2, expert d_ff = d_ff)
every other layer.  No explicit positional encoding (mamba provides order).
Mamba: d_state 16, d_conv 4, expand 2.
"""
from repro.models import MambaConfig, ModelConfig, MoEConfig

_PATTERN = ("mamba", "mamba", "mamba", "mamba",
            "attn", "mamba", "mamba", "mamba")

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    num_layers=72, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=24576, vocab_size=65536, head_dim=128,
    layer_pattern=_PATTERN, moe_pattern=(False, True),
    moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=24576),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2, chunk=128),
    pos_emb="none",
)

SMOKE = ModelConfig(
    name="jamba-smoke", family="hybrid",
    num_layers=8, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=256, head_dim=16,
    layer_pattern=_PATTERN, moe_pattern=(False, True),
    moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=128),
    mamba=MambaConfig(d_state=4, d_conv=4, expand=2, chunk=8),
    pos_emb="none", attn_chunk=16, logit_chunk=32,
)
