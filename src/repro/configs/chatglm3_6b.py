"""chatglm3-6b — GLM-family dense LM with partial (2d) RoPE and GQA.

[arXiv:2406.12793; hf:THUDM/chatglm3-6b]
28L, d_model 4096, 32 heads (GQA kv=2, head_dim 128), d_ff 13696,
vocab 65024.  RMSNorm, SwiGLU, QKV bias, RoPE over half the head dim
(rope_fraction=0.5 — the GLM "2d" rotary).
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b", family="dense",
    num_layers=28, d_model=4096, num_heads=32, num_kv_heads=2,
    d_ff=13696, vocab_size=65024, head_dim=128,
    rope_fraction=0.5, qkv_bias=True,
)

SMOKE = ModelConfig(
    name="chatglm3-smoke", family="dense",
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
    d_ff=256, vocab_size=256, head_dim=32,
    rope_fraction=0.5, qkv_bias=True, attn_chunk=16, logit_chunk=32,
)
