"""whisper-small — encoder-decoder speech model (backbone only).

[arXiv:2212.04356; hf:openai/whisper-small]
12L encoder + 12L decoder, d_model 768, 12 heads (kv=12, head_dim 64),
d_ff 3072, vocab 51865.  LayerNorm, GELU, QKV bias, sinusoidal positions,
cross-attention from decoder to the 1500-frame encoder memory.

The conv mel frontend is a STUB per the assignment: ``input_specs`` feeds
precomputed (B, 1500, 768) frame embeddings.
"""
from repro.models import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="audio",
    num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
    d_ff=3072, vocab_size=51865, head_dim=64,
    norm="layernorm", act="gelu", mlp_gated=False, qkv_bias=True,
    pos_emb="sinusoidal", encoder=EncoderConfig(num_layers=12, seq_len=1500),
)

SMOKE = ModelConfig(
    name="whisper-smoke", family="audio",
    num_layers=2, d_model=96, num_heads=3, num_kv_heads=3,
    d_ff=192, vocab_size=256, head_dim=32,
    norm="layernorm", act="gelu", mlp_gated=False, qkv_bias=True,
    pos_emb="sinusoidal", encoder=EncoderConfig(num_layers=2, seq_len=24),
    attn_chunk=16, logit_chunk=32,
)
