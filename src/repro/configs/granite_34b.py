"""granite-34b — IBM Granite Code 34B (GPT-BigCode-style dense, MQA).

[arXiv:2405.04324; hf:ibm-granite/granite-34b-code-base]
88L, d_model 6144, 48 heads (MQA kv=1, head_dim 128), d_ff 24576,
vocab 49152.  LayerNorm, GELU, non-gated MLP.

Deviation (recorded): upstream uses learned absolute positions; we use the
fixed sinusoidal table (the assignment treats positional scheme as
backbone detail; no parameter-shape impact beyond dropping the table).
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b", family="dense",
    num_layers=88, d_model=6144, num_heads=48, num_kv_heads=1,
    d_ff=24576, vocab_size=49152, head_dim=128,
    norm="layernorm", act="gelu", mlp_gated=False, pos_emb="sinusoidal",
)

SMOKE = ModelConfig(
    name="granite-smoke", family="dense",
    num_layers=3, d_model=128, num_heads=4, num_kv_heads=1,
    d_ff=512, vocab_size=256, head_dim=32,
    norm="layernorm", act="gelu", mlp_gated=False, pos_emb="sinusoidal",
    attn_chunk=16, logit_chunk=32,
)
