"""phi3.5-moe-42b-a6.6b — Microsoft Phi-3.5-MoE (16 experts, top-2).

[hf:microsoft/Phi-3.5-MoE-instruct]
32L, d_model 4096, 32 heads (GQA kv=8, head_dim 128), expert d_ff 6400,
vocab 32064.  Every layer's FFN is MoE (16e top-2).  LayerNorm (upstream),
SwiGLU experts, full RoPE.

Deviation (recorded): upstream routes with SparseMixer-v2; we use standard
top-2 softmax gating over the datapath's angular-mode scores.
"""
from repro.models import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=6400, vocab_size=32064, head_dim=128,
    norm="layernorm",
    moe_pattern=(True,),
    moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=6400),
)

SMOKE = ModelConfig(
    name="phi3.5-moe-smoke", family="moe",
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
    d_ff=256, vocab_size=256, head_dim=32,
    norm="layernorm",
    moe_pattern=(True,),
    moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=64),
    attn_chunk=16, logit_chunk=32,
)
