"""smollm-360m — llama-arch small dense LM.

[hf:HuggingFaceTB/SmolLM-360M; family per assignment]
32L, d_model 960, 15 heads (GQA kv=5, head_dim 64), d_ff 2560, vocab 49152.
Tied embeddings, RMSNorm, SwiGLU, full RoPE.

TP note: 15 q-heads / 5 kv-heads do not divide the 16-way model axis — the
sharding rules fall back to replicated attention weights for this arch
(d_ff 2560 = 160/chip and vocab 49152 = 3072/chip still shard).
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m", family="dense",
    num_layers=32, d_model=960, num_heads=15, num_kv_heads=5,
    d_ff=2560, vocab_size=49152, head_dim=64,
    rope_theta=10000.0, tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="smollm-smoke", family="dense",
    num_layers=2, d_model=96, num_heads=3, num_kv_heads=1,
    d_ff=256, vocab_size=256, head_dim=32,
    tie_embeddings=True, attn_chunk=16, logit_chunk=32,
)
