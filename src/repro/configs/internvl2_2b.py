"""internvl2-2b — InternViT + InternLM2-1.8B VLM (backbone only).

[arXiv:2404.16821; hf:OpenGVLab/InternVL2-2B]
24L, d_model 2048, 16 heads (GQA kv=8, head_dim 128), d_ff 8192,
vocab 92553.  RMSNorm, SwiGLU, full RoPE.

The InternViT vision tower is a STUB per the assignment: ``input_specs``
feeds 256 precomputed patch embeddings per image, prepended to the text
tokens (so total sequence = assigned seq_len).
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b", family="vlm",
    num_layers=24, d_model=2048, num_heads=16, num_kv_heads=8,
    d_ff=8192, vocab_size=92553, head_dim=128,
    vision_tokens=256,
)

SMOKE = ModelConfig(
    name="internvl2-smoke", family="vlm",
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
    d_ff=256, vocab_size=256, head_dim=32,
    vision_tokens=8, attn_chunk=16, logit_chunk=32,
)
