"""rwkv6-7b — RWKV-6 "Finch": attention-free linear RNN with
data-dependent decay.

[arXiv:2404.05892; hf:RWKV/rwkv-6-world-7b]
32L, d_model 4096 (64 heads of size 64), d_ff 14336, vocab 65536.
Time-mix (wkv) + channel-mix blocks; decay lora rank 64.
"""
from repro.models import ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", family="ssm",
    num_layers=32, d_model=4096, num_heads=64, num_kv_heads=64,
    d_ff=14336, vocab_size=65536, head_dim=64,
    layer_pattern=("rwkv",), pos_emb="none",
    rwkv=RWKVConfig(head_size=64, decay_lora=64, chunk=64),
)

SMOKE = ModelConfig(
    name="rwkv6-smoke", family="ssm",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=256, head_dim=16,
    layer_pattern=("rwkv",), pos_emb="none",
    rwkv=RWKVConfig(head_size=16, decay_lora=8, chunk=8),
    logit_chunk=32,
)
