"""Assigned input shapes and per-cell input specs (ShapeDtypeStruct only).

Each architecture is paired with four shapes; ``input_specs`` builds the
exact abstract inputs a cell's step function lowers against — no device
allocation ever happens for full configs (dry-run contract).

  train_4k     seq 4,096   global_batch 256   -> train_step
  prefill_32k  seq 32,768  global_batch 32    -> serve prefill
  decode_32k   seq 32,768  global_batch 128   -> serve decode (1 new token,
                                                 KV cache of seq_len)
  long_500k    seq 524,288 global_batch 1     -> decode; SSM/hybrid only
                                                 (sub-quadratic state), see
                                                 DESIGN.md §Arch-applicability
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..models import ModelConfig, cache_shapes


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # 'train' | 'prefill' | 'decode'
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

# long_500k needs sub-quadratic sequence mixing: run for SSM/hybrid archs,
# skip (by design) for pure full-attention archs.
LONG_OK_FAMILIES = ("hybrid", "ssm")


def applicable(cfg: ModelConfig, shape: ShapeSpec) -> bool:
    if shape.name == "long_500k":
        return cfg.family in LONG_OK_FAMILIES
    return True


def cells(cfg: ModelConfig):
    return [s for s in SHAPES.values() if applicable(cfg, s)]


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _batch_specs(cfg: ModelConfig, b: int, t: int, with_labels: bool):
    """Token batch + modality stubs (frames/patches are *precomputed
    embeddings* — the frontend is a stub per the assignment)."""
    cd = jnp.dtype(cfg.compute_dtype)
    t_text = t - cfg.vision_tokens if cfg.family == "vlm" else t
    batch = {"tokens": _sds((b, t_text), jnp.int32)}
    if with_labels:
        batch["labels"] = _sds((b, t_text), jnp.int32)
    if cfg.family == "audio":
        batch["frames"] = _sds((b, cfg.encoder.seq_len, cfg.d_model), cd)
    if cfg.family == "vlm":
        batch["patches"] = _sds((b, cfg.vision_tokens, cfg.d_model), cd)
    return batch


def _cache_specs(cfg: ModelConfig, b: int, max_len: int):
    shapes = cache_shapes(cfg, b, max_len)
    return jax.tree.map(
        lambda sd: _sds(sd[0], sd[1]), shapes,
        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
        and isinstance(x[0], tuple))


def input_specs(cfg: ModelConfig, shape: ShapeSpec):
    """Abstract inputs for the cell's step function.

    train  -> (batch,)
    prefill-> (batch, cache)           cache sized seq_len (+ a little slack)
    decode -> (cache, tokens (B,1))    cache sized seq_len, length==seq-1
    """
    b, t = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return (_batch_specs(cfg, b, t, with_labels=True),)
    if shape.kind == "prefill":
        return (_batch_specs(cfg, b, t, with_labels=False),
                _cache_specs(cfg, b, t))
    # decode: one new token against a cache of seq_len
    return (_cache_specs(cfg, b, t), _sds((b, 1), jnp.int32))
