"""Assigned architecture configs (exact published hyperparameters) + shapes."""
from .registry import ARCH_IDS, all_configs, get_config, get_smoke  # noqa: F401
from .shapes import SHAPES, ShapeSpec, applicable, cells, input_specs  # noqa: F401
