"""Architecture registry: ``--arch <id>`` resolution for every launcher."""
from __future__ import annotations

import importlib

from ..models import ModelConfig

_MODULES = {
    "smollm-360m": "smollm_360m",
    "granite-34b": "granite_34b",
    "chatglm3-6b": "chatglm3_6b",
    "stablelm-1.6b": "stablelm_1_6b",
    "whisper-small": "whisper_small",
    "jamba-1.5-large-398b": "jamba_1_5_large",
    "rwkv6-7b": "rwkv6_7b",
    "internvl2-2b": "internvl2_2b",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe",
    "deepseek-v3-671b": "deepseek_v3",
}

ARCH_IDS = tuple(_MODULES)


def _mod(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")


def get_config(arch_id: str) -> ModelConfig:
    """The full published configuration (dry-run / AOT only)."""
    return _mod(arch_id).CONFIG


def get_smoke(arch_id: str) -> ModelConfig:
    """Reduced same-family configuration (CPU-runnable smoke tests)."""
    return _mod(arch_id).SMOKE


def all_configs():
    return {a: get_config(a) for a in ARCH_IDS}
