"""stablelm-1.6b — StableLM-2 1.6B dense LM (full MHA).

[hf:stabilityai/stablelm-2-1_6b; unverified tier per assignment]
24L, d_model 2048, 32 heads (kv=32 — full multi-head, head_dim 64),
d_ff 5632, vocab 100352.  LayerNorm, SwiGLU, partial rotary (25%).
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b", family="dense",
    num_layers=24, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=5632, vocab_size=100352, head_dim=64,
    norm="layernorm", rope_fraction=0.25,
)

SMOKE = ModelConfig(
    name="stablelm-smoke", family="dense",
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
    d_ff=256, vocab_size=256, head_dim=32,
    norm="layernorm", rope_fraction=0.25, attn_chunk=16, logit_chunk=32,
)
