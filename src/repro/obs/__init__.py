"""``repro.obs`` — the telemetry plane (DESIGN.md §11).

One switch, three signals, one export surface:

* **Metrics** — :class:`MetricsRegistry` counters / gauges / streaming
  histograms (``obs/metrics.py``).  The engine records per-call wall
  time, jit-cache hits vs compiles, pad waste (padded vs real rows),
  chunk/shard fan-out, and aggregate datapath job counts per backend;
  the serving layer routes its request accounting through a registry.
* **Compile events** — :class:`CompileTracker` (``obs/compile.py``): the
  test suite's jit tracing-cache-miss counter promoted to a public
  window over a process-wide retrace count, so "steady-state compiles
  == 0" is a servable metric, not just a test assertion.
* **Traces** — request-lifecycle spans (admit → coalesce → execute →
  split per served request) in a bounded buffer, exported as
  Chrome-trace/Perfetto JSON (``obs/trace.py``).

Everything is **off by default** and free while off: recording sites
pre-resolve their instruments and the disabled path is one attribute
check + branch, so engine and serving results are bit-identical (and
latency indistinguishable) with telemetry disabled — the contract
``tests/test_obs.py`` pins.

Quickstart::

    from repro import obs

    obs.enable()
    ... run queries / serve traffic ...
    print(obs.snapshot())                    # JSON-able dict
    obs.export_chrome_trace("trace.json")    # open in Perfetto

    with obs.CompileTracker() as t:
        engine.trace(rays)                   # warm steady state
    assert t.compiles == 0

``python -m repro.obs.dump`` pretty-prints a snapshot (current process,
or a previously saved file).
"""
from __future__ import annotations

import weakref
from typing import Callable

from .compile import CompileTracker, hook_installed, install_hook, total_compiles  # noqa: F401
from .metrics import Counter, Gauge, Histogram, MetricsRegistry, default_registry  # noqa: F401
from .trace import TraceBuffer, annotate, default_buffer, export_chrome_trace  # noqa: F401

__all__ = [
    "CompileTracker",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "TraceBuffer",
    "annotate",
    "default_buffer",
    "default_registry",
    "disable",
    "enable",
    "export_chrome_trace",
    "install_hook",
    "is_enabled",
    "register_source",
    "registry",
    "snapshot",
    "total_compiles",
    "unregister_source",
    "write_snapshot",
]

#: named snapshot sources: subsystems that keep their own always-on
#: registries (the serving layer) attach a zero-arg dict provider here;
#: stored as weak references so a dropped QueryServer vanishes from
#: snapshots instead of pinning the object alive
_SOURCES: dict[str, object] = {}


def registry() -> MetricsRegistry:
    """The process-global default registry (disabled until
    :func:`enable`)."""
    return default_registry()


def enable() -> None:
    """Turn the telemetry plane on: the default registry records, the
    span buffer records, and the compile hook goes in (so
    ``snapshot()['jit']['compiles']`` counts from here on)."""
    install_hook()
    default_registry().enable()


def disable() -> None:
    """Turn recording off.  The compile hook stays installed (removing
    it would cold-start jax's tracing cache and miscount later), but it
    only bumps one integer per retrace — stock-jax behavior otherwise."""
    default_registry().disable()


def is_enabled() -> bool:
    return default_registry().enabled


def register_source(name: str, provider: Callable[[], dict]) -> str:
    """Attach a named snapshot section: ``provider()`` must return a
    JSON-able dict; it is held weakly (bound methods via ``WeakMethod``)
    and called at :func:`snapshot` time.  Returns the (possibly
    ``#n``-suffixed, if taken) name actually registered."""
    base, n = name, 1
    while name in _SOURCES and _deref(_SOURCES[name]) is not None:
        n += 1
        name = f"{base}#{n}"
    try:
        ref: object = weakref.WeakMethod(provider)  # bound method
    except TypeError:
        ref = weakref.ref(provider)  # plain function / callable object
    _SOURCES[name] = ref
    return name


def unregister_source(name: str) -> None:
    _SOURCES.pop(name, None)


def _deref(ref):
    try:
        return ref()
    except Exception:
        return None


def snapshot() -> dict:
    """One stable JSON-able view of the whole telemetry plane::

        {
          "enabled": bool,
          "jit": {"hook_installed": bool, "compiles": int},
          "counters" / "gauges" / "histograms": {...},   # default registry
          "derived": {"pad_waste_fraction": float|None,
                      "cache_hit_rate": float|None},
          "trace": {"spans": int},
          "sources": {"serving": {...}, ...},            # live attachments
        }

    ``pad_waste_fraction`` is 1 - real/padded over every engine call
    recorded so far; ``cache_hit_rate`` is hits/(hits+misses) of the
    engine's compiled-function cache.  Both are None until the engine
    has recorded at least one call.
    """
    reg = default_registry()
    snap = reg.snapshot()
    counters = snap["counters"]
    real = counters.get("engine.rows.real", 0)
    padded = counters.get("engine.rows.padded", 0)
    hits = counters.get("engine.cache.hits", 0)
    misses = counters.get("engine.cache.misses", 0)
    sources = {}
    for name, ref in list(_SOURCES.items()):
        provider = _deref(ref)
        if provider is None:
            _SOURCES.pop(name, None)
            continue
        sources[name] = provider()
    return {
        "enabled": reg.enabled,
        "jit": {"hook_installed": hook_installed(),
                "compiles": total_compiles()},
        "counters": counters,
        "gauges": snap["gauges"],
        "histograms": snap["histograms"],
        "derived": {
            "pad_waste_fraction": (1.0 - real / padded) if padded else None,
            "cache_hit_rate": (hits / (hits + misses)
                               if (hits + misses) else None),
        },
        "trace": {"spans": len(default_buffer())},
        "sources": sources,
    }


def write_snapshot(path: str) -> dict:
    """Dump :func:`snapshot` as JSON at ``path`` (CI artifact form);
    returns the snapshot."""
    import json
    snap = snapshot()
    with open(path, "w") as f:
        json.dump(snap, f, indent=2, sort_keys=True)
        f.write("\n")
    return snap
