"""Request-lifecycle trace spans + Chrome-trace/Perfetto export.

Metrics say *how much*; traces say *where the time went*.  This module
keeps a bounded in-memory buffer of completed spans — each a named
``(ts, dur)`` interval on a track — and exports them in the Chrome trace
event format (the JSON both ``chrome://tracing`` and Perfetto load
directly), so a serving run can be opened as a timeline: every request a
track, its admit → coalesce → execute → split phases laid end to end
(DESIGN.md §11).

Recording is gated the same way as metrics: the global buffer follows
the default registry's enabled flag, so with telemetry off a
``record()`` call is one attribute check + branch and touches nothing.
Timestamps are caller-provided floats in *seconds* on whatever monotonic
clock the caller runs (the serving layer records on its own injectable
clock — fake-clock tests produce perfectly consistent traces); export
converts to the microseconds the trace format wants.  Spans on one track
share a clock by construction; tracks from different subsystems may use
different clocks, which Chrome renders fine (each track is internally
ordered — the cross-track offset just isn't meaningful).

``annotate(name)`` additionally scopes a ``jax.profiler.TraceAnnotation``
around device work when telemetry is enabled, so an active jax profiler
(``jax.profiler.trace``) shows engine execute windows on the device
timeline alongside its XLA events; with telemetry off (or no profiler
machinery) it is a no-op context.
"""
from __future__ import annotations

import json
import threading
from collections import deque
from contextlib import nullcontext
from typing import Optional

from .metrics import default_registry

__all__ = ["Span", "TraceBuffer", "annotate", "default_buffer",
           "export_chrome_trace"]

#: spans kept in the bounded global buffer (oldest dropped first — a
#: long-lived server exports windows, not unbounded history)
MAX_SPANS = 200_000


class Span:
    """One completed interval: ``name`` on track ``tid`` from ``ts`` for
    ``dur`` (seconds), with JSON-able ``args`` attached."""

    __slots__ = ("name", "cat", "ts", "dur", "tid", "args")

    def __init__(self, name: str, cat: str, ts: float, dur: float,
                 tid: int, args: Optional[dict] = None):
        self.name = name
        self.cat = cat
        self.ts = float(ts)
        self.dur = float(dur)
        self.tid = int(tid)
        self.args = args

    def to_event(self) -> dict:
        """This span as one Chrome trace 'complete' (``ph: "X"``) event;
        seconds -> integer microseconds."""
        ev = {
            "name": self.name,
            "cat": self.cat,
            "ph": "X",
            "ts": int(round(self.ts * 1e6)),
            "dur": int(round(self.dur * 1e6)),
            "pid": 0,
            "tid": self.tid,
        }
        if self.args:
            ev["args"] = self.args
        return ev

    def __repr__(self):
        return (f"Span({self.name!r}, tid={self.tid}, ts={self.ts:.6f}, "
                f"dur={self.dur:.6f})")


class TraceBuffer:
    """Bounded, thread-safe span sink.

    ``enabled=None`` (the global default buffer) follows the default
    metrics registry's switch; an explicit bool pins it (tests construct
    private always-on buffers).  ``record`` may be called from any
    thread — the serving worker records execute/split spans off the event
    loop."""

    def __init__(self, enabled: Optional[bool] = None,
                 max_spans: int = MAX_SPANS):
        self._enabled = enabled
        self._spans: deque = deque(maxlen=max_spans)
        self._lock = threading.Lock()

    @property
    def enabled(self) -> bool:
        if self._enabled is None:
            return default_registry().enabled
        return self._enabled

    def record(self, name: str, ts: float, dur: float, *, tid: int = 0,
               cat: str = "repro", args: Optional[dict] = None) -> None:
        if not self.enabled:
            return
        with self._lock:
            self._spans.append(Span(name, cat, ts, dur, tid, args))

    def spans(self) -> list:
        with self._lock:
            return list(self._spans)

    def clear(self) -> None:
        with self._lock:
            self._spans.clear()

    def __len__(self) -> int:
        return len(self._spans)

    # -- export ------------------------------------------------------------

    def to_chrome_trace(self) -> dict:
        """The buffer as a Chrome trace object: ``{"traceEvents": [...],
        "displayTimeUnit": "ms"}`` — the shape Perfetto and
        chrome://tracing both open as-is."""
        return {
            "traceEvents": [s.to_event() for s in self.spans()],
            "displayTimeUnit": "ms",
        }

    def export_chrome_trace(self, path: str) -> int:
        """Write the Chrome-trace JSON to ``path``; returns the number of
        events written."""
        trace = self.to_chrome_trace()
        with open(path, "w") as f:
            json.dump(trace, f)
            f.write("\n")
        return len(trace["traceEvents"])

    def __repr__(self):
        return f"TraceBuffer(spans={len(self)}, enabled={self.enabled})"


_DEFAULT = TraceBuffer(enabled=None)


def default_buffer() -> TraceBuffer:
    return _DEFAULT


def export_chrome_trace(path: str,
                        buffer: Optional[TraceBuffer] = None) -> int:
    """Export a trace buffer (the global one by default) as Chrome-trace
    JSON at ``path``; returns the event count."""
    return (buffer or _DEFAULT).export_chrome_trace(path)


def annotate(name: str):
    """A ``jax.profiler.TraceAnnotation`` scope when telemetry is
    enabled (so an active profiler labels the device work), a no-op
    context otherwise."""
    if not default_registry().enabled:
        return nullcontext()
    try:
        import jax.profiler
        return jax.profiler.TraceAnnotation(name)
    except Exception:  # profiler machinery unavailable: stay silent
        return nullcontext()
