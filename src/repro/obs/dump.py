"""``python -m repro.obs.dump [PATH]`` — pretty-print a telemetry
snapshot.

Without arguments, prints the *current process's* ``obs.snapshot()``
(useful at the end of a driver script, or to see the stable empty-state
schema).  With a path, pretty-prints a snapshot previously saved with
``obs.write_snapshot`` (the CI artifact), so the uploaded JSON reads
back through the same tool.
"""
from __future__ import annotations

import argparse
import json
import sys

from . import snapshot


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.dump",
        description="pretty-print a repro.obs telemetry snapshot")
    ap.add_argument("path", nargs="?", default=None,
                    help="a saved snapshot JSON to print (default: the "
                         "current process's live snapshot)")
    args = ap.parse_args(argv)
    if args.path is None:
        snap = snapshot()
    else:
        with open(args.path) as f:
            snap = json.load(f)
    json.dump(snap, sys.stdout, indent=2, sort_keys=True)
    sys.stdout.write("\n")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
