"""Compile-event tracking: count jit retraces as a first-class metric.

The repo's zero-retrace contracts (``Scene.refit`` animation frames,
chunked dispatch re-entering one compiled function, the serving ladder's
O(log) program count) were guarded by a test-only closure-counter trick:
``jax._src.test_util.count_jit_tracing_cache_miss`` monkey-patched
around each assertion.  This module promotes that trick into a public,
always-available :class:`CompileTracker` (DESIGN.md §11) so the same
signal that gates the tests can be *served* — exported in
``obs.snapshot()``, attached to benchmark rows, and asserted by CI
against a live serving run.

Mechanism: one process-wide hook around ``jax``'s pjit jaxpr-creation
step — the function that runs exactly once per (fun, abstract-args)
tracing-cache miss, i.e. per retrace.  The hook is installed lazily on
first use and then **never removed**: the wrapper is ``lu.cache``-d like
the original, so uninstalling/reinstalling would cold-start that cache
and miscount warm functions as fresh compiles.  Until something installs
it, tracked totals read 0 and the interpreter runs byte-for-byte stock
jax (telemetry disabled really is disabled).

:class:`CompileTracker` is a window over the monotonic process total::

    with CompileTracker() as t:
        engine.trace(rays)        # steady state: everything cached
    assert t.compiles == 0

Nested and overlapping trackers are fine — each just subtracts its own
baseline.  When the global registry is enabled, every retrace also
increments the ``jit.retraces`` counter there.
"""
from __future__ import annotations

from typing import Optional

from .metrics import default_registry

__all__ = ["CompileTracker", "hook_installed", "install_hook",
           "total_compiles"]

#: monotonic process-wide retrace count (valid once the hook is in)
_COUNT = [0]
_INSTALLED = False

#: pre-created so the hook's registry path is one attribute check
_RETRACES = default_registry().counter("jit.retraces")


def install_hook() -> bool:
    """Install the retrace-counting hook (idempotent).  Returns whether
    the hook is active — False only when this jax version lacks the
    internals, in which case tracked counts stay 0 and every consumer
    degrades gracefully."""
    global _INSTALLED
    if _INSTALLED:
        return True
    try:  # jax-internal surface: feature-detect, never hard-require
        from jax._src import linear_util as lu
        from jax._src import pjit as pjit_lib
        original = pjit_lib._create_pjit_jaxpr
    except (ImportError, AttributeError):
        return False

    @lu.cache
    def create_pjit_jaxpr_and_count(*args):
        _COUNT[0] += 1
        _RETRACES.inc()
        return original(*args)

    pjit_lib._create_pjit_jaxpr = create_pjit_jaxpr_and_count
    _INSTALLED = True
    return True


def hook_installed() -> bool:
    return _INSTALLED


def total_compiles() -> int:
    """Process-wide retraces since the hook went in (0 before)."""
    return _COUNT[0]


class CompileTracker:
    """A window over the process retrace counter.

    Use as a context manager (the test idiom the suite runs on) or via
    explicit :meth:`start` / :meth:`stop`; :attr:`compiles` is the number
    of jit tracings that happened inside the window.  Constructing a
    tracker installs the hook if it is not in yet.
    """

    def __init__(self):
        self.available = install_hook()
        self._start: Optional[int] = None
        self._stop: Optional[int] = None

    def start(self) -> "CompileTracker":
        self._start = _COUNT[0]
        self._stop = None
        return self

    def stop(self) -> int:
        self._stop = _COUNT[0]
        return self.compiles

    @property
    def compiles(self) -> int:
        """Retraces since :meth:`start` (live while the window is open,
        frozen once stopped; 0 before the window opens)."""
        if self._start is None:
            return 0
        end = _COUNT[0] if self._stop is None else self._stop
        return end - self._start

    def __enter__(self) -> "CompileTracker":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def __repr__(self):
        return (f"CompileTracker(compiles={self.compiles}, "
                f"available={self.available})")
