"""Metrics registry: counters, gauges, and streaming histograms.

The paper's whole methodology is instrumentation — per-FU job counts and
op censuses are what make the datapath's trade-offs visible.  This module
is the software twin of that discipline (DESIGN.md §11): one registry for
every counter the repo keeps, instead of the three disconnected
mechanisms that grew organically (per-ray job counters, the serving
layer's ad-hoc stats dicts, and the test-only jit tracing counters).

Design constraints, in order:

1. **Disabled is free.**  The process-global default registry starts
   ``enabled=False``.  Instruments exist either way (callers pre-create
   them at import time and hold direct references), but every hot-path
   mutator (``Counter.inc`` / ``Gauge.set`` / ``Histogram.observe``)
   begins with one attribute read + branch and returns without touching
   any state.  No dict lookups, no allocation, no formatting — the
   engine's per-call overhead with telemetry off is a handful of
   predictable branches (``tests/test_obs.py`` pins the no-op
   behavior and the engine-result bit-parity on/off).
2. **Dependency-free.**  Plain Python; histograms are fixed
   log-spaced bins, not a sketch library.
3. **JSON-able.**  ``MetricsRegistry.snapshot()`` returns nothing but
   dicts / lists / numbers / strings, so it can be dumped, uploaded as a
   CI artifact, and diffed across runs.

Instruments are identified by flat dotted names (``engine.cache.hits``,
``serving.trace.requests``); asking a registry for the same name twice
returns the *same* instrument object (identity fast path — callers may
re-resolve per call without growing anything).

Thread-safety: increments are plain Python read-modify-writes under the
GIL.  Concurrent writers can lose an increment under contention; that is
the standard telemetry trade and never perturbs query results.  The
serving layer keeps its exact request accounting on a private
always-enabled registry with a single writer per instrument.
"""
from __future__ import annotations

import math
from typing import Optional

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "default_registry",
]

#: histogram bucket geometry: value -> bucket ``floor(log2(v / V0))``,
#: clamped to [0, BINS).  V0 = 1e-6 with 64 doubling bins spans 1e-6 ..
#: ~1.8e13 in whatever unit the caller observes (ms, rows, jobs) — wide
#: enough that the clamp is never the interesting signal.
HIST_V0 = 1e-6
HIST_BINS = 64


class Counter:
    """Monotonic counter.  ``inc`` is a no-op while the owning registry
    is disabled."""

    __slots__ = ("name", "_reg", "value")

    def __init__(self, name: str, reg: "MetricsRegistry"):
        self.name = name
        self._reg = reg
        self.value = 0

    def inc(self, n: int = 1) -> None:
        if self._reg.enabled:
            self.value += n

    def __repr__(self):
        return f"Counter({self.name}={self.value})"


class Gauge:
    """Last-write-wins scalar (shard fan-out, queue depth, ...)."""

    __slots__ = ("name", "_reg", "value")

    def __init__(self, name: str, reg: "MetricsRegistry"):
        self.name = name
        self._reg = reg
        self.value = 0.0

    def set(self, v: float) -> None:
        if self._reg.enabled:
            self.value = float(v)

    def __repr__(self):
        return f"Gauge({self.name}={self.value})"


class Histogram:
    """Streaming histogram over fixed log2-spaced bins.

    O(1) ``observe``, O(bins) percentile queries.  A percentile answer is
    the *upper edge* of the bucket holding that rank, clamped to the
    observed [min, max] — so ``percentile(q)`` is always within one
    bucket factor (2x) of the true order statistic, which is the
    resolution latency telemetry needs (``tests/test_obs.py`` pins the
    bound).  Values below ``HIST_V0`` (including 0) land in bucket 0 and
    report via the min clamp exactly.
    """

    __slots__ = ("name", "_reg", "count", "sum", "min", "max", "buckets")

    def __init__(self, name: str, reg: "MetricsRegistry"):
        self.name = name
        self._reg = reg
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self.buckets = [0] * HIST_BINS

    def observe(self, v: float) -> None:
        if not self._reg.enabled:
            return
        v = float(v)
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        if v <= HIST_V0:
            idx = 0
        else:
            idx = min(HIST_BINS - 1, int(math.log2(v / HIST_V0)))
        self.buckets[idx] += 1

    def percentile(self, q: float) -> float:
        """Upper-edge estimate of the ``q``-quantile (0 <= q <= 1);
        NaN when nothing was observed."""
        if self.count == 0:
            return math.nan
        rank = max(1, math.ceil(q * self.count))
        seen = 0
        for i, b in enumerate(self.buckets):
            seen += b
            if seen >= rank:
                upper = HIST_V0 * (2.0 ** (i + 1))
                return max(self.min, min(self.max, upper))
        return self.max  # unreachable: counts sum to self.count

    def mean(self) -> float:
        return self.sum / self.count if self.count else math.nan

    def __repr__(self):
        return (f"Histogram({self.name}: n={self.count}, "
                f"p50={self.percentile(0.5):.4g})")


class MetricsRegistry:
    """A named family of instruments with one on/off switch.

    The process-global default (``default_registry()``) ships disabled;
    ``repro.obs.enable()`` flips it.  Subsystems that must always count
    (the serving layer's request accounting, whose ``stats()`` surface
    predates telemetry) own private ``MetricsRegistry(enabled=True)``
    instances and attach them to the global snapshot as *sources*
    (``repro.obs.register_source``).
    """

    def __init__(self, enabled: bool = False, name: str = ""):
        self.enabled = bool(enabled)
        self.name = name
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._histograms: dict[str, Histogram] = {}

    # -- instrument resolution (same name -> same object, any time) -------

    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter(name, self)
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge(name, self)
        return g

    def histogram(self, name: str) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            h = self._histograms[name] = Histogram(name, self)
        return h

    # -- lifecycle ---------------------------------------------------------

    def enable(self) -> None:
        self.enabled = True

    def disable(self) -> None:
        self.enabled = False

    def reset(self) -> None:
        """Zero every instrument (identity preserved: held references
        stay valid — their values reset in place)."""
        for c in self._counters.values():
            c.value = 0
        for g in self._gauges.values():
            g.value = 0.0
        for h in self._histograms.values():
            h.count = 0
            h.sum = 0.0
            h.min = math.inf
            h.max = -math.inf
            h.buckets = [0] * HIST_BINS

    # -- export ------------------------------------------------------------

    def snapshot(self) -> dict:
        """Stable JSON-able view: ``{"counters": {...}, "gauges": {...},
        "histograms": {name: {count,sum,min,max,mean,p50,p99}}}``.
        Instruments that never fired are included at their zero state, so
        the key set is stable once the process has created them."""
        hists = {}
        for name, h in sorted(self._histograms.items()):
            hists[name] = {
                "count": h.count,
                "sum": h.sum,
                "min": None if h.count == 0 else h.min,
                "max": None if h.count == 0 else h.max,
                "mean": None if h.count == 0 else h.mean(),
                "p50": None if h.count == 0 else h.percentile(0.50),
                "p99": None if h.count == 0 else h.percentile(0.99),
            }
        return {
            "counters": {n: c.value
                         for n, c in sorted(self._counters.items())},
            "gauges": {n: g.value for n, g in sorted(self._gauges.items())},
            "histograms": hists,
        }

    def __repr__(self):
        return (f"MetricsRegistry(name={self.name!r}, "
                f"enabled={self.enabled}, "
                f"instruments={len(self._counters) + len(self._gauges) + len(self._histograms)})")


#: the process-global registry every built-in subsystem records into
#: (disabled by default: telemetry is strictly opt-in)
_DEFAULT = MetricsRegistry(enabled=False, name="default")


def default_registry() -> MetricsRegistry:
    return _DEFAULT
