"""§Perf hillclimb driver: hypothesis → change → re-lower → record.

Each variant is a (name, cfg_overrides, plan_overrides, hypothesis) tuple;
the driver re-runs the roofline costing for the cell with the overrides and
appends a record to experiments/perf/<cell>.jsonl.  The EXPERIMENTS.md
§Perf table is written from these records.

Usage:
  python experiments/hillclimb.py --cell smollm-360m__train_4k
  python experiments/hillclimb.py --all
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import dataclasses
import json
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.roofline import cost_cell  # noqa: E402
from repro.configs import SHAPES, get_config  # noqa: E402
from repro.launch.mesh import make_plan, make_production_mesh  # noqa: E402


def _cfg_with(cfg, overrides: dict):
    moe_over = overrides.pop("moe", None)
    mla_over = overrides.pop("mla", None)
    if moe_over:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, **moe_over))
    if mla_over:
        cfg = dataclasses.replace(cfg, mla=dataclasses.replace(cfg.mla, **mla_over))
    return dataclasses.replace(cfg, **overrides) if overrides else cfg


# (name, cfg_overrides, plan_overrides, hypothesis)
VARIANTS = {
    # worst roofline fraction among train cells: compute-dominated by
    # attention quadratic + remat recompute for a tiny d_model
    "smollm-360m__train_4k": [
        ("no_block_remat", {"remat": "none"}, {},
         "block remat re-runs the forward inside backward: ~25% of compute;"
         " a 360M model's activations fit at accum=8, so drop remat ->"
         " compute term x0.75"),
        ("no_remat+chunk256", {"remat": "none", "attn_chunk": 256}, {},
         "also halve the attention chunk: the causal diagonal chunk wastes"
         " qc/2 columns (12.5%->6% of attention flops)"),
    ],
    # most collective-bound: FSDP weight all-gathers per layer per micro
    "granite-34b__train_4k": [
        ("accum_2", {}, {"accum_steps": 2},
         "FSDP re-gathers every weight each microbatch: accum 8->2 cuts"
         " gather traffic 4x; residual memory x4 (2->8 seq/device, "
         " 88L x 8seq x 4096 x 6144 x 2B = 3.5G, fits)"),
        ("no_fsdp+bf16_moments", {}, {"fsdp_axes": (),
                                      "moments_dtype": "bfloat16"},
         "34B f32 = 8.5G/chip TP-only: no per-layer weight gathers at all;"
         " bf16 moments recover the HBM the FSDP removal costs"),
    ],
    # most representative of the paper's technique: MoE router = OpAngular;
    # EP combine psum dominates collectives
    "phi3.5-moe-42b-a6.6b__train_4k": [
        ("bf16_combine", {"moe": {"combine_dtype": "bfloat16"}}, {},
         "the EP combine psum moves (tokens x d_model) f32 per MoE layer;"
         " outputs are bf16 anyway -> halve the payload"),
        ("bf16_combine+accum2", {"moe": {"combine_dtype": "bfloat16"}},
         {"accum_steps": 2},
         "then attack the FSDP weight re-gathers: accum 8->2 cuts them 4x"),
    ],
}


def run_cell(cell: str, out_dir: str):
    arch, shape = cell.rsplit("__", 1)
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, cell + ".jsonl")
    done = set()
    if os.path.exists(path):
        with open(path) as f:
            done = {json.loads(line)["variant"] for line in f}

    def record(rec):
        with open(path, "a") as f:
            f.write(json.dumps(rec) + "\n")

    if "baseline" not in done:
        t0 = time.time()
        rec = cost_cell(arch, shape)
        rec.update(variant="baseline", hypothesis="paper-faithful baseline",
                   wall_s=round(time.time() - t0, 1))
        record(rec)
        print(f"[baseline] c={rec['compute_s']:.3f} m={rec['memory_s']:.3f} "
              f"n={rec['collective_s']:.3f} dom={rec['bottleneck']} "
              f"roofline={rec['roofline_fraction']:.4f}", flush=True)

    for name, cfg_over, plan_over, hyp in VARIANTS.get(cell, []):
        if name in done:
            continue
        cfg = _cfg_with(get_config(arch), dict(cfg_over))
        plan = make_plan(cfg, SHAPES[shape], multi_pod=False)
        if plan_over:
            plan = dataclasses.replace(plan, **plan_over)
        t0 = time.time()
        try:
            rec = cost_cell(arch, shape, cfg_override=cfg, plan_override=plan)
            rec.update(variant=name, hypothesis=hyp,
                       wall_s=round(time.time() - t0, 1))
            record(rec)
            print(f"[{name}] c={rec['compute_s']:.3f} m={rec['memory_s']:.3f}"
                  f" n={rec['collective_s']:.3f} dom={rec['bottleneck']} "
                  f"roofline={rec['roofline_fraction']:.4f}", flush=True)
        except Exception as e:
            record({"variant": name, "hypothesis": hyp,
                    "error": f"{type(e).__name__}: {e}"})
            print(f"[{name}] FAILED {e}", flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", choices=list(VARIANTS))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/perf")
    args = ap.parse_args()
    cells = list(VARIANTS) if args.all or not args.cell else [args.cell]
    for cell in cells:
        print(f"===== {cell} =====", flush=True)
        run_cell(cell, args.out)


if __name__ == "__main__":
    main()
