"""Render the dry-run and roofline JSON records into markdown tables.

Usage: python experiments/summarize.py [--dryrun-dir d] [--roofline-dir d]
Prints markdown to stdout (pasted into EXPERIMENTS.md by the maintainer).
"""
from __future__ import annotations

import argparse
import glob
import json
import os

GB = 1 << 30
HBM_PER_CHIP = 16 * GB  # v5e


def load(d):
    out = []
    for f in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(f) as fh:
            out.append(json.load(fh))
    return out


ARCH_ORDER = ["smollm-360m", "granite-34b", "chatglm3-6b", "stablelm-1.6b",
              "whisper-small", "jamba-1.5-large-398b", "rwkv6-7b",
              "internvl2-2b", "phi3.5-moe-42b-a6.6b", "deepseek-v3-671b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def _key(r):
    return (ARCH_ORDER.index(r["arch"]), SHAPE_ORDER.index(r["shape"]),
            r.get("mesh", ""))


def dryrun_table(records):
    print("| arch | shape | mesh | status | compile s | HLO GF/dev | "
          "bytes/dev (arg+out+tmp) | fits 16G | collectives (top) |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in sorted(records, key=_key):
        if "skipped" in r:
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                  f"skip-by-design | - | - | - | - | - |")
            continue
        if not r.get("ok"):
            err = r.get("error", "?")[:60]
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                  f"FAILED: {err} | - | - | - | - | - |")
            continue
        mem = r.get("memory") or {}
        tot = mem.get("total_bytes_per_device", 0)
        colls = r.get("collectives", {})
        top = sorted(colls.items(), key=lambda kv: -kv[1]["link_bytes"])[:2]
        cstr = ";".join(f"{k}x{v['count']}" for k, v in top) or "none"
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | OK | "
              f"{r.get('compile_s', 0):.0f} | "
              f"{r.get('hlo_flops', 0) / 1e9:.0f} | "
              f"{tot / GB:.1f} GiB | "
              f"{'Y' if tot <= HBM_PER_CHIP else 'N'} | {cstr} |")


def roofline_table(records):
    print("| arch | shape | compute s | memory s | collective s | bottleneck"
          " | useful frac | roofline frac |")
    print("|---|---|---|---|---|---|---|---|")
    for r in sorted(records, key=_key):
        print(f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | "
              f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
              f"{r['bottleneck'].replace('_s', '')} | "
              f"{r['useful_flops_frac']:.3f} | "
              f"{r['roofline_fraction']:.4f} |")


def perf_table(perf_dir):
    import io
    rows = []
    for f in sorted(glob.glob(os.path.join(perf_dir, "*.jsonl"))):
        cell = os.path.basename(f)[:-6]
        with open(f) as fh:
            recs = [json.loads(line) for line in fh]
        base = next((r for r in recs if r.get("variant") == "baseline"), None)
        print(f"\n#### {cell}\n")
        print("| variant | hypothesis | compute s | memory s | collective s |"
              " bottleneck | roofline frac | Δ dominant vs baseline |")
        print("|---|---|---|---|---|---|---|---|")
        for r in recs:
            if "error" in r:
                print(f"| {r['variant']} | {r['hypothesis'][:70]} | - | - |"
                      f" - | FAILED: {r['error'][:40]} | - | - |")
                continue
            delta = ""
            if base and r is not base:
                dom = base["bottleneck"]
                delta = f"{(r[dom] / base[dom] - 1) * 100:+.0f}%"
            print(f"| {r['variant']} | {r.get('hypothesis', '')[:70]} | "
                  f"{r['compute_s']:.3f} | {r['memory_s']:.3f} | "
                  f"{r['collective_s']:.3f} | "
                  f"{r['bottleneck'].replace('_s', '')} | "
                  f"{r['roofline_fraction']:.4f} | {delta} |")


def _capture(fn, *args):
    import contextlib
    import io
    buf = io.StringIO()
    with contextlib.redirect_stdout(buf):
        fn(*args)
    return buf.getvalue()


def write_into_experiments(md_path, dr, rf, perf_dir):
    """Replace the <!-- *_TABLE --> placeholders in EXPERIMENTS.md."""
    with open(md_path) as f:
        text = f.read()
    anchors = {
        "<!-- DRYRUN_TABLE -->": _capture(dryrun_table, dr) if dr else "",
        "<!-- ROOFLINE_TABLE -->": _capture(roofline_table, rf) if rf else "",
        "<!-- PERF_TABLE -->": (_capture(perf_table, perf_dir)
                                if glob.glob(os.path.join(perf_dir, "*.jsonl"))
                                else ""),
    }
    for anchor, table in anchors.items():
        if table and anchor in text:
            text = text.replace(anchor, anchor + "\n" + table)
    with open(md_path, "w") as f:
        f.write(text)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun-dir", default="experiments/dryrun")
    ap.add_argument("--roofline-dir", default="experiments/roofline")
    ap.add_argument("--perf-dir", default="experiments/perf")
    ap.add_argument("--write", metavar="EXPERIMENTS_MD",
                    help="insert tables at the placeholder anchors")
    args = ap.parse_args()
    dr = load(args.dryrun_dir)
    rf = load(args.roofline_dir)
    if args.write:
        write_into_experiments(args.write, dr, rf, args.perf_dir)
        print(f"wrote tables into {args.write}")
        return
    if dr:
        print(f"### Dry-run matrix ({len(dr)} cells)\n")
        dryrun_table(dr)
        print()
    if rf:
        print(f"### Roofline table ({len(rf)} cells, single-pod 16x16)\n")
        roofline_table(rf)
        print()
    if glob.glob(os.path.join(args.perf_dir, "*.jsonl")):
        print("### Perf hillclimb\n")
        perf_table(args.perf_dir)


if __name__ == "__main__":
    main()
