"""Supervisor: failure injection -> bit-exact resume; stragglers; heartbeat."""
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.data import SyntheticLM
from repro.models import init_params
from repro.optim import adamw
from repro.parallel.ctx import NO_PARALLEL as ctx
from repro.runtime import InjectedFailure, Supervisor, SupervisorConfig
from repro.train import make_train_step


def _setup():
    cfg = get_smoke("smollm-360m")
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw.init(params)
    ocfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=100)
    step_fn = jax.jit(make_train_step(cfg, ctx, ocfg))
    data = lambda: SyntheticLM(cfg.vocab_size, 4, 32, seed=7)
    return params, opt, step_fn, data


def test_failure_injection_bitexact_resume(tmp_path):
    params, opt, step_fn, data = _setup()
    ref = Supervisor(SupervisorConfig(ckpt_dir=str(tmp_path / "a"),
                                      ckpt_every=5),
                     step_fn, data(), params, opt)
    p_ref, _ = ref.run(12)

    sup = Supervisor(SupervisorConfig(ckpt_dir=str(tmp_path / "b"),
                                      ckpt_every=5),
                     step_fn, data(), params, opt)
    fired = []

    def hook(s):
        if s == 8 and not fired:
            fired.append(s)
            raise InjectedFailure("simulated node loss")

    sup.failure_hook = hook
    p_got, _ = sup.run(12)
    assert sup.restarts == 1
    for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restart_budget_exhausted(tmp_path):
    params, opt, step_fn, data = _setup()
    sup = Supervisor(SupervisorConfig(ckpt_dir=str(tmp_path), ckpt_every=5,
                                      max_restarts=1),
                     step_fn, data(), params, opt)
    sup.failure_hook = lambda s: (_ for _ in ()).throw(InjectedFailure("dead"))
    try:
        sup.run(10)
        assert False, "should have raised"
    except InjectedFailure:
        pass
    assert sup.restarts == 2  # 1 allowed + the fatal one


def test_straggler_detector(tmp_path):
    params, opt, step_fn, data = _setup()
    sup = Supervisor(SupervisorConfig(ckpt_dir=str(tmp_path), ckpt_every=100,
                                      straggler_factor=2.5),
                     step_fn, data(), params, opt)
    inner = sup.train_step
    # warm up the EWMA with the compiled step time before injecting delay
    _ = inner(params, opt, {k: jnp.asarray(v)
                            for k, v in next(data()).items()})

    def slow(p, o, b):
        if sup.step == 5:
            time.sleep(2.0)
        return inner(p, o, b)

    sup.train_step = slow
    sup.run(8)
    assert any(s == 5 for s, _, _ in sup.stragglers)


def test_heartbeat(tmp_path):
    params, opt, step_fn, data = _setup()
    hb = tmp_path / "hb.json"
    sup = Supervisor(SupervisorConfig(ckpt_dir=str(tmp_path / "c"),
                                      ckpt_every=100,
                                      heartbeat_path=str(hb)),
                     step_fn, data(), params, opt)
    sup.run(3)
    beat = json.loads(hb.read_text())
    assert beat["step"] == 3 and abs(time.time() - beat["t"]) < 60
