"""End-to-end: LBVH->BVH4 build + wavefront traversal vs brute force."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (Triangle, build_bvh4, bvh4_depth, make_ray,
                        ray_triangle_test, trace_rays)


def _soup(rng, n_tri):
    ctr = rng.uniform(-1, 1, (n_tri, 3)).astype(np.float32)
    d1 = rng.normal(scale=0.15, size=(n_tri, 3)).astype(np.float32)
    d2 = rng.normal(scale=0.15, size=(n_tri, 3)).astype(np.float32)
    return Triangle(a=jnp.asarray(ctr), b=jnp.asarray(ctr + d1),
                    c=jnp.asarray(ctr + d2))


def _brute_force(tri, org, dirs):
    n = org.shape[0]
    m = tri.a.shape[0]
    ray = make_ray(jnp.asarray(np.repeat(org, m, 0)),
                   jnp.asarray(np.repeat(dirs, m, 0)))
    t_all = ray_triangle_test(ray, jax.tree.map(
        lambda x: jnp.tile(x, (n, 1)), tri))
    with np.errstate(divide="ignore", invalid="ignore"):
        t = np.where(np.asarray(t_all.hit),
                     np.asarray(t_all.t_num) / np.asarray(t_all.t_denom), np.inf)
    t = t.reshape(n, m)
    best = t.argmin(1)
    tb = t[np.arange(n), best]
    return np.where(np.isfinite(tb), tb, np.inf), np.where(np.isfinite(tb), best, -1)


def test_traversal_matches_bruteforce():
    rng = np.random.default_rng(3)
    tri = _soup(rng, 230)
    bvh = build_bvh4(tri)
    depth = bvh4_depth(230)
    n = 80
    org = rng.uniform(-3, -2, (n, 3)).astype(np.float32)
    tgt = rng.uniform(-0.5, 0.5, (n, 3)).astype(np.float32)
    dirs = (tgt - org).astype(np.float32)
    rec = trace_rays(bvh, make_ray(jnp.asarray(org), jnp.asarray(dirs)), depth)
    t_ref, _ = _brute_force(tri, org, dirs)
    t_got = np.where(np.asarray(rec.hit), np.asarray(rec.t), np.inf)
    both = np.isfinite(t_ref) & np.isfinite(t_got)
    assert (np.isfinite(t_ref) == np.isfinite(t_got)).all()
    np.testing.assert_allclose(t_got[both], t_ref[both], rtol=1e-5)
    assert np.asarray(rec.hit).sum() > 5  # scene actually hit


def test_traversal_prunes_vs_bruteforce():
    """The BVH must test far fewer quad-box jobs than leaves exist."""
    rng = np.random.default_rng(4)
    tri = _soup(rng, 1000)
    bvh = build_bvh4(tri)
    depth = bvh4_depth(1000)
    org = np.tile(np.asarray([[-3, 0, 0]], np.float32), (16, 1))
    dirs = rng.normal(size=(16, 3)).astype(np.float32) * 0.1 + np.asarray(
        [[1, 0, 0]], np.float32)
    rec = trace_rays(bvh, make_ray(jnp.asarray(org), jnp.asarray(dirs)), depth)
    total_nodes = (4 ** (depth + 1) - 1) // 3
    assert float(rec.quadbox_jobs.mean()) < total_nodes / 4


def test_render_sphere_image():
    """Tiny render: ray-sphere mesh produces a sane depth map."""
    rng = np.random.default_rng(5)
    # icosphere-ish: random triangles on the unit sphere shell
    n_tri = 512
    u = rng.normal(size=(n_tri, 3)); u /= np.linalg.norm(u, axis=1, keepdims=True)
    t1 = np.cross(u, rng.normal(size=(n_tri, 3))); t1 /= np.linalg.norm(t1, axis=1, keepdims=True)
    t2 = np.cross(u, t1)
    a = (u).astype(np.float32)
    b = (u + 0.15 * t1).astype(np.float32)
    c = (u + 0.15 * t2).astype(np.float32)
    for arr in (b, c):
        arr /= np.linalg.norm(arr, axis=1, keepdims=True)
    tri = Triangle(jnp.asarray(a), jnp.asarray(b), jnp.asarray(c))
    bvh = build_bvh4(tri)
    depth = bvh4_depth(n_tri)
    res = 24
    ys, xs = np.meshgrid(np.linspace(-1.2, 1.2, res),
                         np.linspace(-1.2, 1.2, res), indexing="ij")
    org = np.stack([xs.ravel(), ys.ravel(), np.full(res * res, -3.0)], -1).astype(np.float32)
    dirs = np.tile(np.asarray([[0, 0, 1]], np.float32), (res * res, 1))
    # two-sided: trace both windings by tracing reversed copy too
    rec = trace_rays(bvh, make_ray(jnp.asarray(org), jnp.asarray(dirs)), depth)
    tri_rev = Triangle(tri.a, tri.c, tri.b)
    bvh2 = build_bvh4(tri_rev)
    rec2 = trace_rays(bvh2, make_ray(jnp.asarray(org), jnp.asarray(dirs)), depth)
    hit = np.asarray(rec.hit) | np.asarray(rec2.hit)
    img = hit.reshape(res, res)
    center = img[res // 3:2 * res // 3, res // 3:2 * res // 3]
    corners = img[:3, :3].sum() + img[-3:, -3:].sum()
    assert center.mean() > 0.5, "sphere center not hit"
    assert corners == 0, "rays outside the sphere must miss"
