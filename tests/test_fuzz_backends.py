"""Differential fuzzing: every backend against its oracle, under hypothesis.

The parity contract (DESIGN.md §5–§6) says results are *bit-identical*
across execution strategies, not merely close.  This suite hammers that
with hypothesis-generated random scenes / rays / databases:

* every trace backend × ray type × **acceleration-structure builder**
  (``"lbvh"`` / ``"sah"``, drawn as a hypothesis parameter) against the
  per-ray / free-function oracles (``trace_rays``, ``trace_wavefront``)
  on that builder's own tree, bit for bit including the per-ray job
  counters and the batch round count — including the fused Pallas
  traversal kernel (``backend="pallas"``, interpret mode off-TPU), which
  shares the ``core/datapath`` stage helpers and so carries no ulp
  caveat, unlike the tiled distance kernels below;
* every distance backend × metric against the jitted free functions fed
  precomputed ``||c||^2`` — bit-exact for the MXU form, and for the Pallas
  tiled accumulator the documented score caveat (rank-equivalent
  neighbours, scores to ~1e-4);
* the sharded + chunked dispatch paths on a forced 8-device host mesh
  (``XLA_FLAGS=--xla_force_host_platform_device_count=8`` subprocess),
  against the single-device unchunked engine.

Scenes / databases are drawn from a small seeded domain and cached per
(seed, size) so the compile count stays bounded while the geometry itself
remains hypothesis-chosen.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="install via requirements-dev.txt")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.api import PointCloudScene, Scene, VectorIndex, make_ray
from repro.core import (Triangle, knn, radius_count, radius_search,
                        trace_rays, trace_wavefront)
from repro.core.bvh import DatapathConfig
from repro.core.build import build

TRACE_FIELDS = ("t", "tri_index", "hit", "quadbox_jobs", "triangle_jobs",
                "stack_overflow")

# small seeded domains so engines/BVHs cache across hypothesis examples
N_TRI = (1, 3, 17, 230)  # single-triangle, root-is-leaf-parent, mid, deep
SCENE_SEEDS = (0, 1, 2, 3)
BUILDERS = ("lbvh", "sah")
DB_SHAPES = ((37, 8), (211, 24))

_scenes: dict = {}
_indexes: dict = {}


def _scene(seed, n_tri, builder="lbvh"):
    key = (seed, n_tri, builder)
    if key not in _scenes:
        rng = np.random.default_rng(1000 * seed + n_tri)
        ctr = rng.uniform(-1, 1, (n_tri, 3)).astype(np.float32)
        d1 = rng.normal(scale=0.2, size=(n_tri, 3)).astype(np.float32)
        d2 = rng.normal(scale=0.2, size=(n_tri, 3)).astype(np.float32)
        tri = Triangle(jnp.asarray(ctr), jnp.asarray(ctr + d1),
                       jnp.asarray(ctr + d2))
        scene = Scene.from_triangles(tri, builder=builder)
        _scenes[key] = (scene, scene.engine(pad_multiple=8, shard=1),
                        scene.engine(pad_multiple=8, shard=1, chunk_size=8))
    return _scenes[key]


def _index(seed, shape):
    key = (seed, shape)
    if key not in _indexes:
        rng = np.random.default_rng(7000 + 100 * seed + shape[0])
        db = jnp.asarray(rng.normal(size=shape).astype(np.float32))
        index = VectorIndex.from_database(db)
        _indexes[key] = (index, index.engine(pad_multiple=8, shard=1),
                         index.engine(pad_multiple=8, shard=1, chunk_size=8))
    return _indexes[key]


def _rays(rng, n):
    org = rng.uniform(-3, -2, (n, 3)).astype(np.float32)
    tgt = rng.uniform(-0.6, 0.6, (n, 3)).astype(np.float32)
    extent = np.where(rng.uniform(size=n) < 0.3,
                      rng.uniform(1.0, 6.0, n), np.inf).astype(np.float32)
    return make_ray(jnp.asarray(org), jnp.asarray(tgt - org),
                    extent=jnp.asarray(extent))


# ---------------------------------------------------------------------------
# trace backends × ray types vs the per-ray / free-function oracles
# ---------------------------------------------------------------------------


@given(scene_seed=st.sampled_from(SCENE_SEEDS),
       n_tri=st.sampled_from(N_TRI),
       builder=st.sampled_from(BUILDERS),
       ray_seed=st.integers(0, 2**31 - 1),
       n_rays=st.integers(1, 24),
       ray_type=st.sampled_from(["closest", "any", "shadow"]))
@settings(max_examples=25, deadline=None)
def test_fuzz_trace_backends_bitmatch_oracles(scene_seed, n_tri, builder,
                                              ray_seed, n_rays, ray_type):
    scene, engine, chunked = _scene(scene_seed, n_tri, builder)
    rays = _rays(np.random.default_rng(ray_seed), n_rays)

    ref = trace_wavefront(scene.bvh, rays, scene.depth, ray_type=ray_type)
    results = {
        "engine/wavefront": engine.trace(rays, ray_type=ray_type,
                                         backend="wavefront"),
        "engine/wavefront/chunked": chunked.trace(rays, ray_type=ray_type,
                                                  backend="wavefront"),
        # the fused Pallas traversal (interpret mode off-TPU) carries NO
        # score caveat, unlike the tiled distance kernels: it calls the
        # same core/datapath stage helpers as the wavefront engine, so
        # hits AND job counters are compared bit-for-bit
        "engine/pallas": engine.trace(rays, ray_type=ray_type,
                                      backend="pallas"),
        "engine/pallas/chunked": chunked.trace(rays, ray_type=ray_type,
                                               backend="pallas"),
    }
    if ray_type == "closest":
        # the vmapped per-ray while_loop is the semantic oracle: the
        # wavefront free function and both engine backends must bit-match
        oracle = trace_rays(scene.bvh, rays, scene.depth)
        for f in TRACE_FIELDS:
            np.testing.assert_array_equal(
                np.asarray(getattr(ref, f)), np.asarray(getattr(oracle, f)),
                err_msg=f"wavefront vs per-ray oracle: {f}")
        results["engine/per_ray"] = engine.trace(rays, backend="per_ray")
        results["engine/per_ray/chunked"] = chunked.trace(
            rays, backend="per_ray")
    for name, got in results.items():
        for f in TRACE_FIELDS:
            np.testing.assert_array_equal(
                np.asarray(getattr(got, f)), np.asarray(getattr(ref, f)),
                err_msg=f"{name}: {f}")
        if "per_ray" not in name:
            assert int(got.rounds) == int(ref.rounds), name


# ---------------------------------------------------------------------------
# datapath config twins: every (arity, stack, precision, codec) draw vs
# the BVH4-fp32 oracle
# ---------------------------------------------------------------------------

# drawn as strategy components so hypothesis explores the grid while the
# per-(config, builder) scenes/engines cache across examples
CONFIG_ARITIES = (4, 8)
CONFIG_STACKS = (16, 64)
CONFIG_CODECS = (("fp32", "fp32"), ("bf16", "fp32"), ("bf16", "compressed"))

_config_scenes: dict = {}


def _config_scene(seed, n_tri, builder, config):
    key = (seed, n_tri, builder, config)
    if key not in _config_scenes:
        rng = np.random.default_rng(1000 * seed + n_tri)
        ctr = rng.uniform(-1, 1, (n_tri, 3)).astype(np.float32)
        d1 = rng.normal(scale=0.2, size=(n_tri, 3)).astype(np.float32)
        d2 = rng.normal(scale=0.2, size=(n_tri, 3)).astype(np.float32)
        tri = Triangle(jnp.asarray(ctr), jnp.asarray(ctr + d1),
                       jnp.asarray(ctr + d2))
        scene = Scene.from_triangles(tri, builder=builder, config=config)
        _config_scenes[key] = (scene, scene.engine(pad_multiple=8, shard=1))
    return _config_scenes[key]


@given(scene_seed=st.sampled_from(SCENE_SEEDS[:2]),
       n_tri=st.sampled_from((17, 230)),
       builder=st.sampled_from(BUILDERS),
       arity=st.sampled_from(CONFIG_ARITIES),
       stack_size=st.sampled_from(CONFIG_STACKS),
       codec=st.sampled_from(CONFIG_CODECS),
       ray_seed=st.integers(0, 2**31 - 1),
       n_rays=st.integers(1, 24),
       ray_type=st.sampled_from(["closest", "any", "shadow"]))
@settings(max_examples=30, deadline=None)
def test_fuzz_datapath_configs_honor_contracts(scene_seed, n_tri, builder,
                                               arity, stack_size, codec,
                                               ray_seed, n_rays, ray_type):
    """Every drawn :class:`DatapathConfig` twin honors its contract:

    * wavefront and fused-Pallas engines bit-match on EVERY field under
      every config (cross-engine parity is structural, not fp32-only);
    * closest-hit ``t``/``tri_index``/``hit`` bit-match the default
      BVH4-fp32 wavefront oracle — the conservative codecs only widen
      boxes, and triangle tests stay exact f32, so reduced precision can
      add visited nodes but never change the committed hit;
    * any/shadow ``hit`` flags agree with the oracle (the accepted ``t``
      of an any-hit may legitimately differ — first hit found wins);
    * job counters are a superset (>=) of the SAME builder+arity's exact
      fp32 twin — the conservative-interval cost is measurable, ordered
      and never negative.
    """
    precision, node_format = codec
    config = DatapathConfig(arity=arity, stack_size=stack_size,
                            precision=precision, node_format=node_format)
    scene, engine = _config_scene(scene_seed, n_tri, builder, config)
    rays = _rays(np.random.default_rng(ray_seed), n_rays)

    ref = trace_wavefront(scene.bvh, rays, scene.depth, ray_type=ray_type,
                          config=config)
    got = engine.trace(rays, ray_type=ray_type, backend="pallas")
    for f in TRACE_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(got, f)), np.asarray(getattr(ref, f)),
            err_msg=f"pallas vs wavefront under {config.tag}: {f}")
    assert int(got.rounds) == int(ref.rounds), config.tag
    if ray_type == "closest":
        oracle = trace_rays(scene.bvh, rays, scene.depth, config)
        for f in TRACE_FIELDS:
            np.testing.assert_array_equal(
                np.asarray(getattr(ref, f)), np.asarray(getattr(oracle, f)),
                err_msg=f"wavefront vs per-ray under {config.tag}: {f}")

    # --- contracts vs the default BVH4-fp32 oracle scene -------------------
    base_scene, _ = _scene(scene_seed, n_tri, builder)[:2]
    base = trace_wavefront(base_scene.bvh, rays, base_scene.depth,
                           ray_type=ray_type)
    np.testing.assert_array_equal(np.asarray(ref.hit), np.asarray(base.hit),
                                  err_msg=f"{config.tag}: hit flags")
    if ray_type == "closest":
        for f in ("t", "tri_index"):
            np.testing.assert_array_equal(
                np.asarray(getattr(ref, f)), np.asarray(getattr(base, f)),
                err_msg=f"{config.tag}: closest-hit {f} vs BVH4-fp32 oracle")

    # --- superset contract: vs the exact-precision twin of the SAME tree --
    if not config.exact_boxes:
        exact = DatapathConfig(arity=arity, stack_size=stack_size)
        exact_scene, _ = _config_scene(scene_seed, n_tri, builder, exact)
        ex = trace_wavefront(exact_scene.bvh, rays, exact_scene.depth,
                             ray_type=ray_type)
        if ray_type == "closest":  # any-hit walks stop at different nodes
            assert np.all(np.asarray(ref.quadbox_jobs)
                          >= np.asarray(ex.quadbox_jobs)), config.tag
            assert np.all(np.asarray(ref.triangle_jobs)
                          >= np.asarray(ex.triangle_jobs)), config.tag


# ---------------------------------------------------------------------------
# distance backends × metrics vs the jitted free functions
# ---------------------------------------------------------------------------


@given(db_seed=st.sampled_from(SCENE_SEEDS),
       shape=st.sampled_from(DB_SHAPES),
       q_seed=st.integers(0, 2**31 - 1),
       n_q=st.integers(1, 24),
       k=st.integers(1, 8),
       metric=st.sampled_from(["euclidean", "angular", "cosine"]))
@settings(max_examples=25, deadline=None)
def test_fuzz_mxu_backend_bitmatches_free_functions(db_seed, shape, q_seed,
                                                    n_q, k, metric):
    index, engine, chunked = _index(db_seed, shape)
    rng = np.random.default_rng(q_seed)
    q = jnp.asarray(rng.normal(size=(n_q, shape[1])).astype(np.float32))

    ref_s, ref_i = jax.jit(
        lambda qq, cc, nn: knn(qq, cc, k, metric, c_sq_norms=nn))(
            q, index.database, index.sq_norms)
    for eng in (engine, chunked):
        got = eng.nearest(q, k, metric, backend="mxu")
        np.testing.assert_array_equal(np.asarray(got.scores),
                                      np.asarray(ref_s))
        np.testing.assert_array_equal(np.asarray(got.indices),
                                      np.asarray(ref_i))
    if metric != "angular":
        radius = 4.0 if metric == "euclidean" else 0.1
        ref = jax.jit(lambda qq, cc, nn: radius_search(
            qq, cc, radius, k, metric, c_sq_norms=nn))(
                q, index.database, index.sq_norms)
        got = chunked.within(q, radius, k, metric, backend="mxu")
        for a, b, name in zip(got, ref, ("scores", "indices", "within")):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=name)
        np.testing.assert_array_equal(
            np.asarray(chunked.count_within(q, radius, metric,
                                            backend="mxu")),
            np.asarray(jax.jit(lambda qq, cc, nn: radius_count(
                qq, cc, radius, metric, c_sq_norms=nn))(
                    q, index.database, index.sq_norms)))


@given(db_seed=st.sampled_from(SCENE_SEEDS[:2]),
       q_seed=st.integers(0, 2**31 - 1),
       n_q=st.integers(1, 16),
       metric=st.sampled_from(["euclidean", "angular", "cosine"]))
@settings(max_examples=10, deadline=None)
def test_fuzz_pallas_backend_rank_equivalent(db_seed, q_seed, n_q, metric):
    """The Pallas tiled accumulator carries the documented score caveat
    (block-summed K), so neighbours are checked by *rank equivalence*:
    every returned neighbour's oracle score matches the oracle's k-th
    scores to kernel tolerance — exact index equality would flake on ties.
    """
    index, engine, _ = _index(db_seed, (211, 24))
    rng = np.random.default_rng(q_seed)
    q = jnp.asarray(rng.normal(size=(n_q, 24)).astype(np.float32))
    k = 5
    ref = engine.nearest(q, k, metric, backend="mxu")
    got = engine.nearest(q, k, metric, backend="pallas")
    np.testing.assert_allclose(np.asarray(got.scores),
                               np.asarray(ref.scores), rtol=1e-4, atol=1e-4)
    oracle_scores = np.asarray(engine.scores(q, metric, backend="mxu"))
    picked = np.take_along_axis(oracle_scores, np.asarray(got.indices), 1)
    np.testing.assert_allclose(picked, np.asarray(ref.scores),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# tree-backed neighbor path: engines vs each other (bit) and the oracle
# ---------------------------------------------------------------------------

CLOUD_SIZES = (5, 61, 230)
CLOUD_RADII = (0.0, 0.5, 1.25)
NEIGHBOR_FIELDS = ("dist_sq", "index", "valid", "count", "box_jobs",
                   "point_jobs")

_clouds: dict = {}


def _cloud(seed, n, builder):
    key = (seed, n, builder)
    if key not in _clouds:
        rng = np.random.default_rng(3000 * seed + n)
        pts = jnp.asarray(rng.normal(size=(n, 3)).astype(np.float32))
        cloud = PointCloudScene.from_points(pts, builder=builder)
        _clouds[key] = (cloud, cloud.engine(pad_multiple=8, shard=1),
                        cloud.engine(pad_multiple=8, shard=1, chunk_size=8))
    return _clouds[key]


@given(seed=st.sampled_from(SCENE_SEEDS[:2]),
       n=st.sampled_from(CLOUD_SIZES),
       builder=st.sampled_from(BUILDERS),
       q_seed=st.integers(0, 2**31 - 1),
       n_q=st.integers(1, 16),
       radius=st.sampled_from(CLOUD_RADII))
@settings(max_examples=15, deadline=None)
def test_fuzz_tree_neighbors_match_brute(seed, n, builder, q_seed, n_q,
                                         radius):
    """Both tree backends vs the brute oracle on hypothesis clouds.

    The two tree engines (and the chunked twin) share stage helpers and
    must bit-match each other, *job counters included*.  Against the
    brute oracle the leaf test reuses the MXU arithmetic form, but its
    ``q.c`` term is an elementwise sum rather than a HIGHEST-precision
    ``jnp.dot`` — a ~1-ulp contraction difference — so membership is
    compared exactly away from the radius boundary and left free inside
    a +-tol band (deterministic-seed exactness lives in
    ``test_neighbor.py``).
    """
    cloud, engine, chunked = _cloud(seed, n, builder)
    rng = np.random.default_rng(q_seed)
    q = jnp.asarray(rng.normal(size=(n_q, 3)).astype(np.float32))

    # k = N so the record can hold every in-radius point: set comparisons
    # are meaningful (k < count would truncate legitimately)
    ref = engine.neighbor_search(q, n, radius=radius,
                                 backend="tree_wavefront")
    others = {
        "tree_pallas": engine.neighbor_search(q, n, radius=radius,
                                              backend="tree_pallas"),
        "tree_wavefront/chunked": chunked.neighbor_search(
            q, n, radius=radius, backend="tree_wavefront"),
    }
    for name, rec in others.items():
        for f in NEIGHBOR_FIELDS:
            np.testing.assert_array_equal(
                np.asarray(getattr(rec, f)), np.asarray(getattr(ref, f)),
                err_msg=f"{name}: {f}")
        assert int(rec.rounds) == int(ref.rounds), name

    oracle = np.asarray(engine.scores(q, "euclidean", backend="mxu"))
    r_sq = radius * radius
    tol = 1e-5 * (1.0 + r_sq)
    w = np.asarray(ref.valid)
    idx = np.asarray(ref.index)
    for i in range(n_q):
        got = set(idx[i][w[i]])
        must = set(np.flatnonzero(oracle[i] <= r_sq - tol))
        may = set(np.flatnonzero(oracle[i] <= r_sq + tol))
        assert must <= got <= may, (i, got, must, may)
    counts = np.asarray(ref.count)
    assert ((oracle <= r_sq - tol).sum(1) <= counts).all()
    assert (counts <= (oracle <= r_sq + tol).sum(1)).all()

    # nearest: rank-equivalent vs the brute top-k (near-ties may permute
    # under the contraction difference, so compare through oracle scores)
    k = min(5, n)
    brute = engine.nearest(q, k, backend="mxu")
    for backend in ("tree_wavefront", "tree_pallas"):
        tree = engine.nearest(q, k, backend=backend)
        picked = np.take_along_axis(oracle, np.asarray(tree.indices), 1)
        np.testing.assert_allclose(picked, np.asarray(brute.scores),
                                   rtol=1e-4, atol=1e-5,
                                   err_msg=backend)


# ---------------------------------------------------------------------------
# sharded + chunked dispatch on a forced 8-device host mesh
# ---------------------------------------------------------------------------


def test_fuzz_sharded_trace_8dev(multidev):
    multidev("""
import numpy as np, jax, jax.numpy as jnp
assert jax.local_device_count() == 8
from hypothesis import given, settings, strategies as st
from repro.api import Scene, make_ray
from repro.core import Triangle

_cache = {}
def scene_pair(seed, n_tri):
    key = (seed, n_tri)
    if key not in _cache:
        rng = np.random.default_rng(1000 * seed + n_tri)
        ctr = rng.uniform(-1, 1, (n_tri, 3)).astype(np.float32)
        d1 = rng.normal(scale=0.2, size=(n_tri, 3)).astype(np.float32)
        d2 = rng.normal(scale=0.2, size=(n_tri, 3)).astype(np.float32)
        s = Scene.from_triangles(Triangle(jnp.asarray(ctr),
                                          jnp.asarray(ctr + d1),
                                          jnp.asarray(ctr + d2)))
        _cache[key] = (s.engine(pad_multiple=8, shard=1),
                       s.engine(pad_multiple=8, shard=8, chunk_size=16))
    return _cache[key]

FIELDS = ("t", "tri_index", "hit", "quadbox_jobs", "triangle_jobs")

@given(seed=st.sampled_from((0, 1)), n_tri=st.sampled_from((3, 230)),
       ray_seed=st.integers(0, 2**31 - 1), n_rays=st.integers(1, 40),
       ray_type=st.sampled_from(["closest", "any", "shadow"]))
@settings(max_examples=10, deadline=None)
def check(seed, n_tri, ray_seed, n_rays, ray_type):
    single, sharded = scene_pair(seed, n_tri)
    rng = np.random.default_rng(ray_seed)
    org = rng.uniform(-3, -2, (n_rays, 3)).astype(np.float32)
    tgt = rng.uniform(-0.6, 0.6, (n_rays, 3)).astype(np.float32)
    rays = make_ray(jnp.asarray(org), jnp.asarray(tgt - org))
    ref = single.trace(rays, ray_type=ray_type, backend="wavefront")
    got = sharded.trace(rays, ray_type=ray_type, backend="wavefront")
    for f in FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(got, f)),
                                      np.asarray(getattr(ref, f)),
                                      err_msg=f"{ray_type}: {f}")
    assert int(got.rounds) == int(ref.rounds)

check()

# fused Pallas traversal on the same 8-way mesh: fixed cases (the kernel
# pads each shard to its 128-lane tile, so one shape covers them all)
single, sharded = scene_pair(0, 230)
for ray_seed, ray_type in ((7, "closest"), (8, "any"), (9, "shadow")):
    rng = np.random.default_rng(ray_seed)
    org = rng.uniform(-3, -2, (40, 3)).astype(np.float32)
    tgt = rng.uniform(-0.6, 0.6, (40, 3)).astype(np.float32)
    rays = make_ray(jnp.asarray(org), jnp.asarray(tgt - org))
    ref = single.trace(rays, ray_type=ray_type, backend="wavefront")
    got = sharded.trace(rays, ray_type=ray_type, backend="pallas")
    for f in FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(got, f)),
                                      np.asarray(getattr(ref, f)),
                                      err_msg=f"pallas {ray_type}: {f}")
    assert int(got.rounds) == int(ref.rounds)
print("sharded trace fuzz OK")
""", n_devices=8)


def test_fuzz_sharded_distance_8dev(multidev):
    multidev("""
import numpy as np, jax, jax.numpy as jnp
assert jax.local_device_count() == 8
from hypothesis import given, settings, strategies as st
from repro.api import VectorIndex

rng0 = np.random.default_rng(42)
db = jnp.asarray(rng0.normal(size=(211, 24)).astype(np.float32))
index = VectorIndex.from_database(db)
single = index.engine(pad_multiple=8, shard=1)
sharded = index.engine(pad_multiple=8, shard=8, chunk_size=16)

@given(q_seed=st.integers(0, 2**31 - 1), n_q=st.integers(1, 40),
       k=st.sampled_from((1, 5)),
       metric=st.sampled_from(["euclidean", "angular", "cosine"]))
@settings(max_examples=10, deadline=None)
def check(q_seed, n_q, k, metric):
    rng = np.random.default_rng(q_seed)
    q = jnp.asarray(rng.normal(size=(n_q, 24)).astype(np.float32))
    a = single.nearest(q, k, metric, backend="mxu")
    b = sharded.nearest(q, k, metric, backend="mxu")
    np.testing.assert_array_equal(np.asarray(a.scores), np.asarray(b.scores))
    np.testing.assert_array_equal(np.asarray(a.indices),
                                  np.asarray(b.indices))
    if metric != "angular":
        radius = 4.0 if metric == "euclidean" else 0.1
        for x, y in zip(single.within(q, radius, k, metric, backend="mxu"),
                        sharded.within(q, radius, k, metric, backend="mxu")):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        np.testing.assert_array_equal(
            np.asarray(single.count_within(q, radius, metric,
                                           backend="mxu")),
            np.asarray(sharded.count_within(q, radius, metric,
                                            backend="mxu")))

check()
# pallas sharded: indices rank-equivalent, scores to the documented caveat
rng = np.random.default_rng(0)
q = jnp.asarray(rng.normal(size=(21, 24)).astype(np.float32))
a = single.nearest(q, 5, "euclidean", backend="pallas")
b = sharded.nearest(q, 5, "euclidean", backend="pallas")
np.testing.assert_allclose(np.asarray(a.scores), np.asarray(b.scores),
                           rtol=1e-6, atol=1e-4)
oracle = np.asarray(single.scores(q, "euclidean", backend="mxu"))
picked = np.take_along_axis(oracle, np.asarray(b.indices), 1)
np.testing.assert_allclose(picked, np.asarray(a.scores), rtol=1e-4,
                           atol=1e-4)
print("sharded distance fuzz OK")
""", n_devices=8)
