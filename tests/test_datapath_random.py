"""Randomized soak: the datapath vs float64 brute-force geometry oracles."""
import jax.numpy as jnp
import numpy as np

from repro.core import Box, Triangle, make_ray, ray_box_test, ray_triangle_test

N = 20000  # randomized inputs per op ("hundreds of thousands" in the paper;
# scaled to CI time — the full soak is benchmarks/bench_datapath.py)


def _f64_box_oracle(org, dirs, lo, hi):
    """Slab method in float64 with explicit boundary handling."""
    with np.errstate(divide="ignore", invalid="ignore"):
        inv = 1.0 / dirs.astype(np.float64)
        t1 = (lo - org[:, None, :]) * inv[:, None, :]
        t2 = (hi - org[:, None, :]) * inv[:, None, :]
        # comparator semantics: NaN (0 * inf) slabs drop out via min/max with
        # the identity bound, mirroring tavianator's branchless boundaries
        t1w = np.where(np.isnan(t1), -np.inf, t1)
        t2w = np.where(np.isnan(t2), np.inf, t2)
        tnear = np.minimum(t1w, t2w)
        tfar = np.maximum(t1w, t2w)
        # origin-inside-slab when parallel: treat as always-within
        par = (dirs[:, None, :] == 0.0)
        inside = (org[:, None, :] >= lo) & (org[:, None, :] <= hi)
        tnear = np.where(par & inside, -np.inf, tnear)
        tfar = np.where(par & inside, np.inf, tfar)
        tnear = np.where(par & ~inside, np.inf, tnear)
        tfar = np.where(par & ~inside, -np.inf, tfar)
        tmin = np.maximum(tnear.max(-1), 0.0)
        tmax = np.minimum(tfar.min(-1), np.inf)
    return tmin, tmax, tmin <= tmax


def test_raybox_random_soak():
    rng = np.random.default_rng(0)
    org = rng.uniform(-4, 4, (N, 3)).astype(np.float32)
    dirs = rng.normal(size=(N, 3)).astype(np.float32)
    # inject axis-aligned rays (exercise 0 * inf) in 10% of cases
    mask = rng.random((N, 3)) < 0.1
    dirs = np.where(mask, 0.0, dirs).astype(np.float32)
    dirs[np.all(dirs == 0, axis=1)] = (1.0, 0.0, 0.0)
    lo = rng.uniform(-3, 2, (N, 4, 3)).astype(np.float32)
    hi = lo + rng.uniform(0.0, 3, (N, 4, 3)).astype(np.float32)

    ray = make_ray(jnp.asarray(org), jnp.asarray(dirs))
    out = ray_box_test(ray, Box(jnp.asarray(lo), jnp.asarray(hi)))

    tmin64, _, hit64 = _f64_box_oracle(org, dirs, lo, hi)
    got_hits = np.zeros((N, 4), bool)
    got_tmin = np.zeros((N, 4))
    bi = np.asarray(out.box_index)
    for slot in range(4):
        got_hits[np.arange(N), bi[:, slot]] = np.asarray(out.is_intersect[:, slot])
        got_tmin[np.arange(N), bi[:, slot]] = np.asarray(out.tmin[:, slot])

    # hit decisions: allow f32-vs-f64 flips only when |tmin-tmax| is tiny
    disagree = got_hits != hit64
    assert disagree.mean() < 2e-3, f"hit mismatch rate {disagree.mean()}"
    both = got_hits & hit64
    err = np.abs(got_tmin[both] - tmin64[both]) / np.maximum(np.abs(tmin64[both]), 1.0)
    assert err.max() < 1e-5, f"tmin rel err {err.max()}"
    # sorted order invariant
    t = np.asarray(out.tmin)
    assert (t[:, :-1] <= t[:, 1:] + 1e-30).all() or np.isnan(t).any() == False


def _f64_tri_oracle(org, dirs, a, b, c):
    """Möller–Trumbore in float64, backface-culling."""
    e1 = (b - a).astype(np.float64)
    e2 = (c - a).astype(np.float64)
    d = dirs.astype(np.float64)
    p = np.cross(d, e2)
    det = (e1 * p).sum(-1)
    t_vec = (org - a).astype(np.float64)
    u = (t_vec * p).sum(-1)
    q = np.cross(t_vec, e1)
    v = (d * q).sum(-1)
    t = (e2 * q).sum(-1)
    # culling variant, det > 0 convention (verified: 100% agreement with the
    # Woop shear test's U>=0 & V>=0 & W>=0 & t_num>0 on random data)
    with np.errstate(divide="ignore", invalid="ignore"):
        hit = (det > 0) & (u >= 0) & (v >= 0) & (u + v <= det) & (t > 0)
        return t / det, hit


def test_raytriangle_random_soak():
    rng = np.random.default_rng(1)
    org = rng.uniform(-2, 2, (N, 3)).astype(np.float32)
    dirs = rng.normal(size=(N, 3)).astype(np.float32)
    ctr = rng.uniform(-2, 2, (N, 3)).astype(np.float32)
    a = ctr + rng.normal(scale=0.7, size=(N, 3)).astype(np.float32)
    b = ctr + rng.normal(scale=0.7, size=(N, 3)).astype(np.float32)
    c = ctr + rng.normal(scale=0.7, size=(N, 3)).astype(np.float32)

    ray = make_ray(jnp.asarray(org), jnp.asarray(dirs))
    out = ray_triangle_test(ray, Triangle(jnp.asarray(a), jnp.asarray(b),
                                          jnp.asarray(c)))
    got_hit = np.asarray(out.hit)
    with np.errstate(divide="ignore", invalid="ignore"):
        got_t = np.asarray(out.t_num, np.float64) / np.asarray(out.t_denom, np.float64)

    t64, hit64 = _f64_tri_oracle(org, dirs, a, b, c)
    disagree = got_hit != hit64
    assert disagree.mean() < 2e-3, f"hit mismatch rate {disagree.mean()}"
    both = got_hit & hit64
    rel = np.abs(got_t[both] - t64[both]) / np.maximum(np.abs(t64[both]), 1e-2)
    assert np.quantile(rel, 0.999) < 1e-3, f"t err q999 {np.quantile(rel, .999)}"
