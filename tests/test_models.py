"""Model-layer unit tests: attention equivalences, decode-vs-full parity."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import MLAConfig, MambaConfig, ModelConfig, RWKVConfig
from repro.models.attention import (chunked_causal_attention, gqa_apply,
                                    gqa_decode, gqa_init, mla_apply,
                                    mla_decode, mla_init)
from repro.models.mamba import mamba_apply, mamba_init, mamba_state_shapes
from repro.models.rwkv import (rwkv_channel_apply, rwkv_channel_init,
                               rwkv_time_apply, rwkv_time_init)
from repro.parallel.ctx import NO_PARALLEL as ctx


def _naive_attention(q, k, v, causal, scale=None):
    b, t, hq, hd = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    scale = scale or hd ** -0.5
    qg = (q * scale).reshape(b, t, g, hkv, hd)
    s = np.einsum("btghd,bshd->bghts", qg, k).astype(np.float64)
    if causal:
        mask = np.tril(np.ones((t, k.shape[1]), bool))
        s = np.where(mask, s, -np.inf)
    p = np.exp(s - s.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    o = np.einsum("bghts,bshd->bghtd", p, v)
    return np.moveaxis(o, 3, 1).reshape(b, t, hq, v.shape[-1])


def test_chunked_attention_vs_naive():
    rng = np.random.default_rng(0)
    for (t, s, hq, hkv, chunk, causal) in [
            (16, 16, 4, 2, 4, True), (16, 16, 4, 4, 16, True),
            (12, 20, 6, 3, 5, False), (33, 33, 2, 1, 8, True)]:
        q = rng.normal(size=(2, t, hq, 8)).astype(np.float32)
        k = rng.normal(size=(2, s, hkv, 8)).astype(np.float32)
        v = rng.normal(size=(2, s, hkv, 8)).astype(np.float32)
        got = chunked_causal_attention(jnp.asarray(q), jnp.asarray(k),
                                       jnp.asarray(v), chunk=chunk,
                                       causal=causal)
        want = _naive_attention(q, k, v, causal and t == s)
        if causal and t != s:
            continue
        np.testing.assert_allclose(np.asarray(got), want, rtol=2e-4,
                                   atol=2e-5, err_msg=str((t, s, hq, hkv)))


def _gqa_cfg(**kw):
    d = dict(name="t", family="dense", num_layers=1, d_model=32, num_heads=4,
             num_kv_heads=2, d_ff=64, vocab_size=64, head_dim=8, attn_chunk=8)
    d.update(kw)
    return ModelConfig(**d)


def test_gqa_decode_matches_full_forward():
    """Prefill+decode over the cache == full forward at every position."""
    cfg = _gqa_cfg()
    p = gqa_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    t = 10
    x = jnp.asarray(rng.normal(size=(2, t, 32)).astype(np.float32))
    pos = jnp.broadcast_to(jnp.arange(t), (2, t))
    y_full, (k, v) = gqa_apply(cfg, ctx, p, x, pos)

    s_max = t
    ck = jnp.zeros((2, s_max, 2, 8), jnp.float32)
    cv = jnp.zeros((2, s_max, 2, 8), jnp.float32)
    outs = []
    for i in range(t):
        y_i, ck, cv = gqa_decode(cfg, ctx, p, x[:, i:i + 1], ck, cv,
                                 jnp.int32(i))
        outs.append(y_i)
    y_step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_full),
                               rtol=2e-3, atol=2e-4)


def test_mla_absorb_equals_naive_decode():
    """The weight-absorbed MLA decode == the naive expand-then-attend path."""
    mla = MLAConfig(q_lora_rank=16, kv_lora_rank=12, qk_nope_head_dim=8,
                    qk_rope_head_dim=4, v_head_dim=8)
    cfg_n = _gqa_cfg(attention="mla", mla=mla)
    cfg_a = _gqa_cfg(attention="mla",
                     mla=dataclasses.replace(mla, absorb=True))
    p = mla_init(jax.random.PRNGKey(0), cfg_n)
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(2, 1, 32)).astype(np.float32))
    ckv = jnp.asarray(rng.normal(size=(2, 6, 12)).astype(np.float32)) * 0.3
    krope = jnp.asarray(rng.normal(size=(2, 6, 4)).astype(np.float32)) * 0.3
    y_n, _, _ = mla_decode(cfg_n, ctx, p, x, ckv, krope, jnp.int32(4))
    y_a, _, _ = mla_decode(cfg_a, ctx, p, x, ckv, krope, jnp.int32(4))
    np.testing.assert_allclose(np.asarray(y_a), np.asarray(y_n), rtol=2e-3,
                               atol=2e-4)


def test_mla_prefill_then_decode_consistent():
    """mla_apply's latent cache feeds mla_decode correctly."""
    mla = MLAConfig(q_lora_rank=16, kv_lora_rank=12, qk_nope_head_dim=8,
                    qk_rope_head_dim=4, v_head_dim=8)
    cfg = _gqa_cfg(attention="mla", mla=mla)
    p = mla_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    t = 8
    x = jnp.asarray(rng.normal(size=(1, t, 32)).astype(np.float32))
    pos = jnp.broadcast_to(jnp.arange(t), (1, t))
    y_full, (ckv, krope) = mla_apply(cfg, ctx, p, x, pos)
    # decode position t-1 using the cache of 0..t-2
    ckv_c = jnp.zeros((1, t, 12), jnp.float32).at[:, :t - 1].set(ckv[:, :t - 1])
    kr_c = jnp.zeros((1, t, 4), jnp.float32).at[:, :t - 1].set(krope[:, :t - 1])
    y_d, _, _ = mla_decode(cfg, ctx, p, x[:, t - 1:], ckv_c, kr_c,
                           jnp.int32(t - 1))
    np.testing.assert_allclose(np.asarray(y_d[:, 0]),
                               np.asarray(y_full[:, -1]), rtol=2e-3, atol=3e-4)


def _mamba_cfg():
    return ModelConfig(name="t", family="ssm", num_layers=1, d_model=16,
                       num_heads=1, num_kv_heads=1, d_ff=32, vocab_size=64,
                       layer_pattern=("mamba",),
                       mamba=MambaConfig(d_state=4, d_conv=3, expand=2, chunk=4))


def test_mamba_stepwise_equals_full():
    cfg = _mamba_cfg()
    p = mamba_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(4)
    t = 11
    x = jnp.asarray(rng.normal(size=(2, t, 16)).astype(np.float32))
    y_full, _ = mamba_apply(cfg, ctx, p, x)
    conv_s, ssm_s = mamba_state_shapes(cfg, 2)
    conv = jnp.zeros(conv_s, jnp.float32)
    ssm = jnp.zeros(ssm_s, jnp.float32)
    outs = []
    for i in range(t):
        y_i, (conv, ssm) = mamba_apply(cfg, ctx, p, x[:, i:i + 1],
                                       ssm_state=ssm, conv_state=conv)
        outs.append(y_i)
    y_step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_full),
                               rtol=2e-3, atol=2e-4)


def _rwkv_cfg(chunk=4):
    return ModelConfig(name="t", family="ssm", num_layers=1, d_model=16,
                       num_heads=2, num_kv_heads=2, d_ff=32, vocab_size=64,
                       layer_pattern=("rwkv",),
                       rwkv=RWKVConfig(head_size=8, decay_lora=4, chunk=chunk))


def test_rwkv_stepwise_equals_full():
    cfg = _rwkv_cfg()
    p = rwkv_time_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(5)
    t = 9
    x = jnp.asarray(rng.normal(size=(2, t, 16)).astype(np.float32))
    y_full, (xt, s) = rwkv_time_apply(cfg, ctx, p, x)
    state = jnp.zeros((2, 2, 8, 8), jnp.float32)
    x_prev = jnp.zeros((2, 16), jnp.float32)
    outs = []
    for i in range(t):
        y_i, (x_prev, state) = rwkv_time_apply(cfg, ctx, p, x[:, i:i + 1],
                                               state=state, x_prev=x_prev)
        outs.append(y_i)
    y_step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_full),
                               rtol=2e-3, atol=2e-4)
    np.testing.assert_allclose(np.asarray(state), np.asarray(s), rtol=2e-3,
                               atol=2e-4)


def test_rwkv_chunk_size_invariance():
    """Chunked wkv (MXU form) must not depend on the chunk size."""
    rng = np.random.default_rng(6)
    t = 12
    x = jnp.asarray(rng.normal(size=(1, t, 16)).astype(np.float32))
    outs = []
    for chunk in (1, 3, 4, 12):
        cfg = _rwkv_cfg(chunk)
        p = rwkv_time_init(jax.random.PRNGKey(0), cfg)
        y, _ = rwkv_time_apply(cfg, ctx, p, x)
        outs.append(np.asarray(y))
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=2e-3, atol=2e-4)


def test_rwkv_channel_shift_state():
    cfg = _rwkv_cfg()
    p = rwkv_channel_init(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(size=(1, 6, 16)).astype(np.float32))
    y_full, x_last = rwkv_channel_apply(cfg, ctx, p, x)
    # stepwise
    xp = jnp.zeros((1, 16), jnp.float32)
    outs = []
    for i in range(6):
        y_i, xp = rwkv_channel_apply(cfg, ctx, p, x[:, i:i + 1], x_prev=xp)
        outs.append(y_i)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(outs, 1)),
                               np.asarray(y_full), rtol=2e-3, atol=2e-4)
