"""Degenerate-geometry regressions, pinned across every backend.

Edge geometry is where traversal engines usually diverge: zero-area
triangles (t_denom == 0 in the Woop test), axis-aligned rays whose
direction inverse is ±inf in two lanes, shadow rays whose acceptance
window [t_min, extent] collapses to a point, and trees small enough that
the root is already the leaf parent.  Each case pins (a) bit-agreement
between the per-ray oracle, the wavefront engine, and the session
backends, and (b) the concrete semantics where they are well defined
(inclusive extent/t_min comparisons, misses on degenerate geometry).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import Scene, make_ray
from repro.core import Triangle, trace_rays, trace_wavefront

TRACE_FIELDS = ("t", "tri_index", "hit", "quadbox_jobs", "triangle_jobs")


def _assert_all_backends_agree(scene, rays):
    """Closest-hit: per-ray oracle == wavefront free fn == both engine
    backends, bit for bit.  Any/shadow: engine == wavefront free fn."""
    engine = scene.engine(pad_multiple=8, shard=1)
    chunked = scene.engine(pad_multiple=8, shard=1, chunk_size=8)
    oracle = trace_rays(scene.bvh, rays, scene.depth)
    candidates = {
        "free/wavefront": trace_wavefront(scene.bvh, rays, scene.depth),
        "engine/per_ray": engine.trace(rays, backend="per_ray"),
        "engine/wavefront": engine.trace(rays, backend="wavefront"),
        "engine/chunked": chunked.trace(rays, backend="wavefront"),
    }
    for name, got in candidates.items():
        for f in TRACE_FIELDS:
            np.testing.assert_array_equal(
                np.asarray(getattr(got, f)), np.asarray(getattr(oracle, f)),
                err_msg=f"{name}: {f}")
    for ray_type in ("any", "shadow"):
        ref = trace_wavefront(scene.bvh, rays, scene.depth,
                              ray_type=ray_type)
        got = engine.trace(rays, ray_type=ray_type)
        for f in TRACE_FIELDS:
            np.testing.assert_array_equal(
                np.asarray(getattr(got, f)), np.asarray(getattr(ref, f)),
                err_msg=f"{ray_type}: {f}")
    return oracle


def _rays_at(targets, origin=(-3.0, 0.1, 0.2)):
    org = np.tile(np.asarray(origin, np.float32), (len(targets), 1))
    tgt = np.asarray(targets, np.float32)
    return make_ray(jnp.asarray(org), jnp.asarray(tgt - org))


# ---------------------------------------------------------------------------
# zero-area triangles
# ---------------------------------------------------------------------------


def test_all_degenerate_scene_never_hits():
    """A soup of point- and line-degenerate triangles: every backend
    agrees, and nothing is ever hit (t_denom == 0 -> no accepted hit)."""
    p = np.asarray([[0.3, 0.1, 0.2]], np.float32)
    tris = np.concatenate([
        np.repeat(p, 3, 0)[None],  # point triangle: a == b == c
        np.stack([p[0], p[0] + [1, 0, 0], p[0] + [2, 0, 0]])[None],  # colinear
        np.stack([p[0], p[0], p[0] + [0, 1, 0]])[None],  # edge: a == b
    ]).astype(np.float32)
    scene = Scene.from_triangles(tris)
    rays = _rays_at([[0.3, 0.1, 0.2], [0.35, 0.1, 0.2], [1.0, 0.0, 0.0]])
    rec = _assert_all_backends_agree(scene, rays)
    assert not np.asarray(rec.hit).any(), "degenerate triangle was hit"
    assert (np.asarray(rec.tri_index) == -1).all()
    assert np.isinf(np.asarray(rec.t)).all()


def test_degenerate_triangles_mixed_with_real_ones():
    """Degenerate triangles sharing a BVH with real ones must not mask or
    corrupt hits on the real geometry."""
    rng = np.random.default_rng(5)
    ctr = rng.uniform(-1, 1, (29, 3)).astype(np.float32)
    d1 = rng.normal(scale=0.2, size=(29, 3)).astype(np.float32)
    d2 = rng.normal(scale=0.2, size=(29, 3)).astype(np.float32)
    real = np.stack([ctr, ctr + d1, ctr + d2], axis=1)
    degen = np.repeat(ctr[:7, None, :], 3, axis=1)  # point triangles
    both = np.concatenate([real, degen]).astype(np.float32)

    scene_real = Scene.from_triangles(real)
    scene_both = Scene.from_triangles(both)
    org = rng.uniform(-3, -2, (16, 3)).astype(np.float32)
    tgt = rng.uniform(-0.5, 0.5, (16, 3)).astype(np.float32)
    rays = make_ray(jnp.asarray(org), jnp.asarray(tgt - org))

    rec_b = _assert_all_backends_agree(scene_both, rays)
    rec_r = trace_rays(scene_real.bvh, rays, scene_real.depth)
    np.testing.assert_array_equal(np.asarray(rec_b.t), np.asarray(rec_r.t))
    np.testing.assert_array_equal(np.asarray(rec_b.tri_index),
                                  np.asarray(rec_r.tri_index))


# ---------------------------------------------------------------------------
# axis-aligned rays on exact box faces
# ---------------------------------------------------------------------------


def _axis_quad(x=1.0, half=1.0):
    """Two triangles spanning the square x == x0, |y|,|z| <= half, wound so
    the normal faces -x (the datapath backface-culls; rays travel +x)."""
    c = np.asarray([[x, -half, -half], [x, half, -half],
                    [x, half, half], [x, -half, half]], np.float32)
    return np.stack([np.stack([c[0], c[2], c[1]]),
                     np.stack([c[0], c[3], c[2]])])


def test_axis_aligned_rays_exact_face_hits():
    """Rays along +x with zero y/z direction (inv = ±inf lanes) against an
    axis-aligned quad: interior hits land at exactly t = distance, and
    every backend agrees on the boundary rays that graze the AABB face."""
    scene = Scene.from_triangles(_axis_quad(x=1.0))
    targets = [
        [1.0, 0.0, 0.0],  # interior
        [1.0, 0.25, -0.5],  # interior, off-center
        [1.0, 1.0, 0.0],  # exactly on the quad's +y edge
        [1.0, -1.0, -1.0],  # exactly on a corner
        [1.0, 1.5, 0.0],  # outside, same plane
    ]
    org = np.asarray([[0.0, t[1], t[2]] for t in targets], np.float32)
    rays = make_ray(jnp.asarray(org),
                    jnp.asarray(np.tile([[1.0, 0.0, 0.0]], (5, 1)),
                                jnp.float32))
    rec = _assert_all_backends_agree(scene, rays)
    hit = np.asarray(rec.hit)
    assert hit[0] and hit[1], "interior axis-aligned hits missed"
    assert not hit[4], "ray outside the quad reported a hit"
    # interior hits are exact: origin x=0, plane x=1, direction (1,0,0)
    np.testing.assert_array_equal(np.asarray(rec.t)[:2],
                                  np.ones(2, np.float32))


def test_axis_aligned_ray_parallel_to_face_plane():
    """A ray sliding exactly *in* the quad's plane (direction +y at x == 1)
    never produces a NaN-poisoned record, and all backends agree."""
    scene = Scene.from_triangles(_axis_quad(x=1.0))
    org = np.asarray([[1.0, -3.0, 0.0], [0.5, -3.0, 0.0]], np.float32)
    dirs = np.tile([[0.0, 1.0, 0.0]], (2, 1)).astype(np.float32)
    rays = make_ray(jnp.asarray(org), jnp.asarray(dirs))
    rec = _assert_all_backends_agree(scene, rays)
    t = np.asarray(rec.t)
    assert not np.isnan(t).any(), "NaN leaked out of a parallel-ray trace"
    assert not np.asarray(rec.hit)[1], "ray off the plane hit the quad"


# ---------------------------------------------------------------------------
# t_min == extent shadow rays
# ---------------------------------------------------------------------------


def test_shadow_ray_collapsed_acceptance_window():
    """Shadow rays whose [t_min, extent] window collapses to the exact hit
    distance: both comparisons are inclusive, so t == t_min == extent is
    still occluded; shrinking either bound by one ulp clears it."""
    scene = Scene.from_triangles(_axis_quad(x=2.0))
    org = jnp.asarray([[0.0, 0.0, 0.0]], jnp.float32)
    d = jnp.asarray([[1.0, 0.0, 0.0]], jnp.float32)
    t_hit = float(scene.engine(shard=1).trace(make_ray(org, d)).t[0])
    assert t_hit == 2.0  # exact: axis-aligned plane at x=2 from x=0

    engine = scene.engine(pad_multiple=8, shard=1)
    below = float(np.nextafter(np.float32(t_hit), np.float32(0)))
    above = float(np.nextafter(np.float32(t_hit), np.float32(4)))

    def occluded(extent, t_min):
        rays = make_ray(org, d, extent=jnp.asarray([extent], jnp.float32))
        got = bool(engine.occluded(rays, t_min=t_min)[0])
        ref = bool(trace_wavefront(scene.bvh, rays, scene.depth,
                                   ray_type="shadow", t_min=t_min).hit[0])
        assert got == ref, f"engine/free-fn disagree at {extent=} {t_min=}"
        return got

    assert occluded(extent=t_hit, t_min=t_hit)  # window == {t_hit}
    assert not occluded(extent=below, t_min=below)  # window below the hit
    assert not occluded(extent=above, t_min=above)  # window above the hit
    assert occluded(extent=above, t_min=below)  # window straddles the hit
    # and an empty window (t_min > extent) can never be occluded
    assert not occluded(extent=below, t_min=above)


# ---------------------------------------------------------------------------
# minimal trees: single triangle, root-is-leaf-parent
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_tri", [1, 2, 3, 4])
def test_single_node_bvh_all_backends(n_tri):
    """Soups small enough that the whole tree is one internal node (the
    root) over <= 4 leaves; padded leaves (tri_index == -1) must never be
    reported as hits."""
    rng = np.random.default_rng(n_tri)
    ctr = rng.uniform(-0.5, 0.5, (n_tri, 3)).astype(np.float32)
    d1 = rng.normal(scale=0.4, size=(n_tri, 3)).astype(np.float32)
    d2 = rng.normal(scale=0.4, size=(n_tri, 3)).astype(np.float32)
    tris = np.stack([ctr, ctr + d1, ctr + d2], axis=1).astype(np.float32)
    scene = Scene.from_triangles(tris)
    assert scene.depth == 1  # root is already the leaf parent

    org = rng.uniform(-3, -2, (12, 3)).astype(np.float32)
    tgt = rng.uniform(-0.5, 0.5, (12, 3)).astype(np.float32)
    rays = make_ray(jnp.asarray(org), jnp.asarray(tgt - org))
    rec = _assert_all_backends_agree(scene, rays)
    tri_idx = np.asarray(rec.tri_index)
    assert (tri_idx < n_tri).all(), "hit a padded (nonexistent) leaf"
    assert ((tri_idx >= 0) == np.asarray(rec.hit)).all()
    # with one internal node, every ray issues exactly one quadbox job
    np.testing.assert_array_equal(np.asarray(rec.quadbox_jobs),
                                  np.ones(12, np.int32))


def test_single_triangle_direct_hit_and_miss():
    # wound so the normal faces -x (rays come from x < 0; backface culling)
    tri = np.asarray([[[0.0, -1.0, -1.0], [0.0, 0.0, 1.0],
                       [0.0, 1.0, -1.0]]], np.float32)
    scene = Scene.from_triangles(tri)
    rays = _rays_at([[0.0, 0.0, 0.0], [0.0, 5.0, 5.0]],
                    origin=(-2.0, 0.0, 0.0))
    rec = _assert_all_backends_agree(scene, rays)
    hit = np.asarray(rec.hit)
    assert hit[0] and not hit[1]
    assert np.asarray(rec.tri_index)[0] == 0
