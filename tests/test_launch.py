"""Launch-layer units: HLO collective parser, mesh plans, model flops."""
import jax
import numpy as np

from repro.configs import SHAPES, get_config
from repro.launch import hlo_analysis as ha
from repro.launch.mesh import make_plan

HLO = """
ENTRY %main {
  %p0 = f32[256,128]{1,0} parameter(0)
  %all-reduce = f32[256,128]{1,0} all-reduce(%p0), channel_id=1, replica_groups=[16,16]<=[256], use_global_device_ids=true, to_apply=%add
  %ag = bf16[64,512]{1,0} all-gather(%p1), channel_id=2, replica_groups=[32,8]<=[256], dimensions={1}
  %rs = f32[8,16]{1,0} reduce-scatter(%p2), channel_id=3, replica_groups=[1,4]<=[4], to_apply=%add
  %cp = f32[64]{0} collective-permute(%p3), channel_id=4
  %cp2 = f32[128]{0} collective-permute(%p3), source_target_pairs={{0,1}}
  %a2a = f32[32,32]{1,0} all-to-all(%p4), channel_id=5, replica_groups={{0,1,2,3}}
}
"""


def test_collective_parser_kinds_and_bytes():
    stats = ha.parse_collectives(HLO)
    assert stats["all-reduce"].count == 1
    assert stats["all-reduce"].result_bytes == 256 * 128 * 4
    # ring all-reduce over group size 16: 2*B*(15/16)
    np.testing.assert_allclose(stats["all-reduce"].link_bytes,
                               2 * 256 * 128 * 4 * 15 / 16)
    assert stats["all-gather"].count == 1
    assert stats["all-gather"].result_bytes == 64 * 512 * 2
    np.testing.assert_allclose(stats["all-gather"].link_bytes,
                               64 * 512 * 2 * 7 / 8)
    assert stats["reduce-scatter"].link_bytes == 8 * 16 * 4 * 3
    assert stats["all-to-all"].count == 1
    np.testing.assert_allclose(stats["all-to-all"].link_bytes,
                               32 * 32 * 4 * 3 / 4)
    assert stats["collective-permute"].count == 2


def test_roofline_terms_and_dominance():
    terms = ha.roofline_terms(197e12, 819e9 * 2, 50e9 * 0.5)
    assert abs(terms["compute_s"] - 1.0) < 1e-9
    assert abs(terms["memory_s"] - 2.0) < 1e-9
    assert abs(terms["collective_s"] - 0.5) < 1e-9
    assert ha.dominant_term(terms) == "memory_s"


def test_make_plan_policies():
    small = get_config("smollm-360m")
    big = get_config("deepseek-v3-671b")
    p_small = make_plan(small, SHAPES["train_4k"], multi_pod=False)
    p_big = make_plan(big, SHAPES["train_4k"], multi_pod=True)
    assert p_small.fsdp_axes == () and p_big.fsdp_axes == ("pod", "data")
    assert p_small.accum_steps == 8 and p_big.accum_steps == 8
    assert p_big.moments_dtype == "bfloat16"
    # long-context decode shards the sequence
    jamba = get_config("jamba-1.5-large-398b")
    p_long = make_plan(jamba, SHAPES["long_500k"], multi_pod=False)
    assert p_long.seq_axis == ("data",)
    p_dec = make_plan(jamba, SHAPES["decode_32k"], multi_pod=False)
    assert p_dec.seq_axis is None and p_dec.accum_steps == 1


def test_model_flops_definitions():
    from repro.launch.dryrun import model_flops
    from repro.models import count_active_params
    cfg = get_config("phi3.5-moe-42b-a6.6b")
    na = count_active_params(cfg)
    assert na < 8e9  # active ~6.6B of 42B
    tf = model_flops(cfg, SHAPES["train_4k"])
    assert abs(tf - 6 * na * 256 * 4096) / tf < 1e-9
    df = model_flops(cfg, SHAPES["decode_32k"])
    assert abs(df - 2 * na * 128) / df < 1e-9


def test_input_specs_are_abstract():
    """input_specs never allocates: everything is ShapeDtypeStruct."""
    from repro.configs import input_specs
    for arch in ("whisper-small", "internvl2-2b", "deepseek-v3-671b"):
        cfg = get_config(arch)
        for shape in SHAPES.values():
            from repro.configs import applicable
            if not applicable(cfg, shape):
                continue
            specs = input_specs(cfg, shape)
            for leaf in jax.tree.leaves(
                    specs, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct)):
                assert isinstance(leaf, jax.ShapeDtypeStruct), leaf
