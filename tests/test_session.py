"""Session query API: backend parity, padding round-trips, jit-cache hits.

The contract (DESIGN.md §5): ``QueryEngine`` is a *session* over the same
engines the free functions expose — every backend must return the shared
result record with values bit-identical to its legacy entry point
(``trace_rays`` / ``trace_wavefront`` / ``knn`` / ``radius_search``), the
pad → query → unpad round trip must be an identity, and repeated
same-shape queries must re-enter the compiled cache without retracing.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.obs import CompileTracker

from repro.api import (QueryEngine, Scene, VectorIndex, distance_backends,
                       make_ray, trace_backends)
from repro.core import (Triangle, cosine_similarity, knn, radius_count,
                        radius_search, trace_rays, trace_wavefront)

TRACE_FIELDS = ("t", "tri_index", "hit", "quadbox_jobs", "triangle_jobs")


def _soup(rng, n_tri, scale=0.15):
    ctr = rng.uniform(-1, 1, (n_tri, 3)).astype(np.float32)
    d1 = rng.normal(scale=scale, size=(n_tri, 3)).astype(np.float32)
    d2 = rng.normal(scale=scale, size=(n_tri, 3)).astype(np.float32)
    return Triangle(a=jnp.asarray(ctr), b=jnp.asarray(ctr + d1),
                    c=jnp.asarray(ctr + d2))


def _rays(rng, n):
    org = rng.uniform(-3, -2, (n, 3)).astype(np.float32)
    tgt = rng.uniform(-0.5, 0.5, (n, 3)).astype(np.float32)
    return make_ray(jnp.asarray(org), jnp.asarray(tgt - org))


def _scene_and_rays(seed, n_tri, n_rays):
    rng = np.random.default_rng(seed)
    scene = Scene.from_triangles(_soup(rng, n_tri))
    return scene, _rays(rng, n_rays)


def _vectors(seed=0, n_q=17, n_db=211, dim=24):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(n_q, dim)).astype(np.float32))
    db = jnp.asarray(rng.normal(size=(n_db, dim)).astype(np.float32))
    return q, db


SCENES = [(7, 230, 64), (17, 3, 32)]  # random soup + root-is-leaf-parent


# ---------------------------------------------------------------------------
# trace: every backend x ray type bit-matches its legacy entry point
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed,n_tri,n_rays", SCENES)
@pytest.mark.parametrize("backend,ray_type", [
    ("per_ray", "closest"),
    ("wavefront", "closest"),
    ("wavefront", "any"),
    ("wavefront", "shadow"),
    ("pallas", "closest"),
    ("pallas", "any"),
    ("pallas", "shadow"),
])
def test_trace_bitmatches_legacy(seed, n_tri, n_rays, backend, ray_type):
    scene, rays = _scene_and_rays(seed, n_tri, n_rays)
    engine = scene.engine(pad_multiple=16)  # 64 -> 64, 32 -> 32 (+ pad path)
    got = engine.trace(rays, ray_type=ray_type, backend=backend)
    if backend == "per_ray":
        ref = trace_rays(scene.bvh, rays, scene.depth)
    else:
        # the wavefront free function is the oracle for both the batch
        # engine and the fused Pallas kernel (shared stage helpers)
        ref = trace_wavefront(scene.bvh, rays, scene.depth,
                              ray_type=ray_type)
    for field in TRACE_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(got, field)),
            np.asarray(getattr(ref, field)), err_msg=field)
    if backend == "per_ray":
        # per-ray oracle reports the equivalent batch-round count
        assert int(got.rounds) == int(np.asarray(ref.quadbox_jobs).max())
    else:
        assert int(got.rounds) == int(ref.rounds)


@pytest.mark.parametrize("ray_type", ["closest", "any", "shadow"])
def test_trace_padded_roundtrip_identity(ray_type):
    """pad -> query -> unpad is an identity: a padded batch returns exactly
    the unpadded batch's results (rays are row-independent in every
    backend)."""
    scene, rays = _scene_and_rays(7, 230, 50)  # 50 pads to 64
    tight = scene.engine(pad_multiple=1)
    padded = scene.engine(pad_multiple=16)
    a = tight.trace(rays, ray_type=ray_type)
    b = padded.trace(rays, ray_type=ray_type)
    assert b.t.shape == (50,)
    for field in TRACE_FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(a, field)),
                                      np.asarray(getattr(b, field)),
                                      err_msg=field)
    assert int(a.rounds) == int(b.rounds)


def test_trace_occluded_matches_occlusion_test():
    from repro.core import occlusion_test
    scene, rays = _scene_and_rays(23, 230, 64)
    got = scene.engine(pad_multiple=8).occluded(rays, t_min=1e-3)
    ref = occlusion_test(scene.bvh, rays, scene.depth, t_min=1e-3)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_trace_backend_validation():
    scene, rays = _scene_and_rays(11, 100, 8)
    engine = scene.engine()
    with pytest.raises(ValueError, match="per_ray"):
        engine.trace(rays, ray_type="any", backend="per_ray")
    with pytest.raises(ValueError, match="unknown trace backend"):
        engine.trace(rays, backend="warp")
    with pytest.raises(ValueError, match="ray_type"):
        engine.trace(rays, ray_type="refracted")
    with pytest.raises(ValueError, match="no Scene"):
        QueryEngine().trace(rays)
    assert "per_ray" in trace_backends() and "wavefront" in trace_backends()
    assert "pallas" in trace_backends()


def test_trace_backend_registry_metadata():
    """The registry knows each backend's ray types and the fused kernel's
    lane multiple matches the kernel's actual tile width."""
    from repro.core.session import PALLAS_TRACE_LANES, trace_backend_ray_types
    from repro.kernels.common import LANES

    assert PALLAS_TRACE_LANES == LANES
    assert trace_backend_ray_types("per_ray") == ("closest",)
    assert set(trace_backend_ray_types("pallas")) == {"closest", "any",
                                                      "shadow"}
    assert set(trace_backend_ray_types("wavefront")) == {"closest", "any",
                                                         "shadow"}
    with pytest.raises(ValueError, match="unknown trace backend"):
        trace_backend_ray_types("warp")


def test_auto_backend_policy():
    scene, rays = _scene_and_rays(11, 100, 8)
    engine = scene.engine()
    # off-TPU the batch engine wins; on TPU the fused kernel keeps the
    # loop state on-chip (all three bit-match, so the policy is pure
    # scheduling)
    batch = "pallas" if jax.default_backend() == "tpu" else "wavefront"
    assert engine.resolve_trace_backend("closest", 4) == "per_ray"
    assert engine.resolve_trace_backend("closest", 500) == batch
    assert engine.resolve_trace_backend("shadow", 4) == batch
    # queries the per-ray oracle cannot express route to the batch
    # engine, so a tiny closest-hit batch with an epsilon/round cap must
    # still work
    assert engine.resolve_trace_backend("closest", 4, t_min=1e-3) == batch
    assert engine.resolve_trace_backend("closest", 4,
                                        max_rounds=2) == batch
    # ...and so does any sharded batch (a multi-device frontier is not tiny)
    assert engine.resolve_trace_backend("closest", 4, shards=2) == batch
    small = jax.tree_util.tree_map(lambda x: x[:4], rays)
    rec = engine.trace(small, t_min=1e-3)  # auto: must not hit per_ray
    assert rec.t.shape == (4,)
    with pytest.raises(ValueError, match="max_rounds"):
        engine.trace(small, backend="per_ray", max_rounds=2)
    assert engine.resolve_distance_backend() == (
        "pallas" if jax.default_backend() == "tpu" else "mxu")
    # an engine-wide default backend overrides the auto policy...
    # (shard=1 pins the single-device policy whatever mesh the host has)
    forced = scene.engine(backend="wavefront", shard=1)
    forced.trace(small)
    assert all(key[1] == "wavefront" for key in forced._cache)
    # ...and a per-call backend="auto" re-enables it
    forced.trace(small, backend="auto")
    assert any(key[1] == "per_ray" for key in forced._cache)


def test_pallas_prepared_ctx_cached_per_version():
    """The fused backend's packed BVH operands are prepared once per
    scene version (not per chunk/call) through one jitted prepare
    function; a refit evicts the stale version's ctx and re-packs with
    zero new compiles."""
    from repro.core import Triangle as Tri

    scene, rays = _scene_and_rays(7, 230, 64)
    engine = scene.engine(pad_multiple=8, shard=1, chunk_size=16)
    a = engine.trace(rays, backend="pallas")  # 4 chunks, 1 prepare
    misses0 = engine.cache_info().misses
    keys = [k for k in engine._placed if k[0] == "trace_ctx"]
    assert len(keys) == 1 and keys[0][3] == 0  # (kind, name, shards, ver)
    ctx0 = engine._placed[keys[0]]
    engine.trace(rays, backend="pallas")
    assert engine.cache_info().misses == misses0  # fully cached
    assert engine._placed[keys[0]] is ctx0  # same prepared operands
    tri = scene.bvh.triangles
    scene.refit(Tri(tri.a + 0.25, tri.b + 0.25, tri.c + 0.25))
    b = engine.trace(rays, backend="pallas")
    assert engine.cache_info().misses == misses0  # zero-retrace refit
    keys = [k for k in engine._placed if k[0] == "trace_ctx"]
    assert len(keys) == 1 and keys[0][3] == 1  # old version evicted
    ref = trace_wavefront(scene.bvh, rays, scene.depth)
    for field in TRACE_FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(b, field)),
                                      np.asarray(getattr(ref, field)),
                                      err_msg=field)


def test_auto_backend_tpu_routes_to_fused_kernel_within_budget(monkeypatch):
    """On TPU, "auto" batch traces go to the fused Pallas kernel — but
    only while the scene's resident operands (mapped whole into every
    kernel tile) fit the on-chip budget; past it the wavefront engine
    keeps serving the scene unchanged."""
    scene, _ = _scene_and_rays(11, 100, 8)
    engine = scene.engine()
    assert engine._scene_resident_bytes() > 0
    assert QueryEngine(index=None)._scene_resident_bytes() == 0
    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
    assert engine.resolve_trace_backend("closest", 500) == "pallas"
    assert engine.resolve_trace_backend("shadow", 4) == "pallas"
    assert engine.resolve_trace_backend("closest", 4) == "per_ray"  # tiny
    monkeypatch.setattr(engine, "AUTO_PALLAS_SCENE_BYTES", 0)
    assert engine.resolve_trace_backend("closest", 500) == "wavefront"


# ---------------------------------------------------------------------------
# compiled-function cache: same shape re-enters without retracing
# ---------------------------------------------------------------------------


def test_same_shape_query_hits_compiled_cache():
    scene, rays = _scene_and_rays(7, 230, 64)
    # shard=1: this test pins the *single-device* pad-bucket policy (under
    # auto-sharding the shard rounding merges more shapes into one bucket,
    # which tests/test_dispatch.py covers)
    engine = scene.engine(pad_multiple=8, shard=1)
    first = engine.trace(rays)
    assert engine.cache_info().misses == 1
    # second same-shape call: engine cache hit AND zero new jit traces
    with CompileTracker() as tracker:
        second = engine.trace(rays)
    assert tracker.compiles == 0, "same-shape query retraced its compiled function"
    info = engine.cache_info()
    assert info.hits == 1 and info.misses == 1 and info.entries == 1
    np.testing.assert_array_equal(np.asarray(first.t), np.asarray(second.t))

    # a different shape (not a pad-multiple neighbour) compiles a new entry
    sub = jax.tree_util.tree_map(lambda x: x[:16], rays)
    engine.trace(sub)
    assert engine.cache_info().entries == 2
    # ...but shapes inside the same pad bucket share one entry (the first
    # call only traces the eager pad ops; the compiled query fn is reused)
    sub9 = jax.tree_util.tree_map(lambda x: x[:9], rays)
    engine.trace(sub9)  # pads to 16: same compiled fn as sub
    assert engine.cache_info().entries == 2
    with CompileTracker() as tracker:
        engine.trace(sub9)
    assert tracker.compiles == 0


def test_distance_cache_and_stats():
    q, db = _vectors()
    engine = VectorIndex.from_database(db).engine(pad_multiple=8)
    engine.nearest(q, 5)
    with CompileTracker() as tracker:
        engine.nearest(q, 5)
    assert tracker.compiles == 0
    assert engine.cache_info().hits == 1
    engine.nearest(q, 7)  # different k -> different compiled fn
    assert engine.cache_info().entries == 2
    engine.cache_clear()
    assert engine.cache_info() == (0, 0, 0)


# ---------------------------------------------------------------------------
# nearest / within / count_within / similarity vs the legacy free functions
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("metric", ["euclidean", "angular", "cosine"])
def test_nearest_matches_knn(metric):
    q, db = _vectors()
    index = VectorIndex.from_database(db)
    engine = index.engine(pad_multiple=8)
    got = engine.nearest(q, 5, metric)
    # the engine IS the legacy oracle jitted with the index's precomputed
    # norms: bit-identical
    ref_s, ref_i = jax.jit(
        lambda qq, cc, nn: knn(qq, cc, 5, metric, c_sq_norms=nn))(
            q, db, index.sq_norms)
    np.testing.assert_array_equal(np.asarray(got.scores), np.asarray(ref_s))
    np.testing.assert_array_equal(np.asarray(got.indices), np.asarray(ref_i))
    # vs the plain legacy call (inline norms): identical neighbours; scores
    # may differ by one FMA contraction (precomputed norms arrive as an
    # input, so XLA fuses the combine differently)
    leg_s, leg_i = knn(q, db, 5, metric)
    np.testing.assert_array_equal(np.asarray(got.indices), np.asarray(leg_i))
    np.testing.assert_allclose(np.asarray(got.scores), np.asarray(leg_s),
                               rtol=1e-5, atol=1e-5)


def test_mxu_backend_is_jitted_legacy_bitwise():
    """The defining identity: every engine distance query == jax.jit of the
    legacy free function fed the index's precomputed ||c||^2."""
    q, db = _vectors()
    index = VectorIndex.from_database(db)
    engine = index.engine(pad_multiple=8)
    ref = jax.jit(lambda qq, cc, nn: radius_search(
        qq, cc, 5.0, 12, c_sq_norms=nn))(q, db, index.sq_norms)
    got = engine.within(q, 5.0, 12)
    for a, b in zip(got, ref):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    sim = jax.jit(lambda qq, cc, nn: cosine_similarity(
        qq, cc, c_sq_norms=nn))(q, db, index.sq_norms)
    np.testing.assert_array_equal(np.asarray(engine.similarity(q)),
                                  np.asarray(sim))


def test_within_matches_radius_search():
    q, db = _vectors(seed=3, n_q=9, n_db=120, dim=16)
    index = VectorIndex.from_database(db)
    engine = index.engine(pad_multiple=8)
    for metric, radius in (("euclidean", 5.0), ("cosine", 0.2)):
        got = engine.within(q, radius, 12, metric)
        ref = jax.jit(lambda qq, cc, nn: radius_search(
            qq, cc, radius, 12, metric, c_sq_norms=nn))(
                q, db, index.sq_norms)
        for a, b, name in zip(got, ref, ("scores", "indices", "within")):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=name)
        counts = engine.count_within(q, radius, metric)
        np.testing.assert_array_equal(
            np.asarray(counts),
            np.asarray(jax.jit(lambda qq, cc, nn: radius_count(
                qq, cc, radius, metric, c_sq_norms=nn))(
                    q, db, index.sq_norms)))
        # the in-range sets agree with the plain (eager) legacy call too
        leg = radius_search(q, db, radius, 12, metric)
        np.testing.assert_array_equal(np.asarray(got.indices),
                                      np.asarray(leg[1]))


def test_distance_padded_roundtrip_identity():
    """Padded query batches return exactly the unpadded results."""
    q, db = _vectors(n_q=21)  # pads to 24
    index = VectorIndex.from_database(db)
    tight = index.engine(pad_multiple=1)
    padded = index.engine(pad_multiple=8)
    for metric in ("euclidean", "angular", "cosine"):
        a = tight.nearest(q, 5, metric)
        b = padded.nearest(q, 5, metric)
        assert b.scores.shape == (21, 5)
        np.testing.assert_array_equal(np.asarray(a.scores),
                                      np.asarray(b.scores))
        np.testing.assert_array_equal(np.asarray(a.indices),
                                      np.asarray(b.indices))
    np.testing.assert_array_equal(
        np.asarray(tight.count_within(q, 5.0)),
        np.asarray(padded.count_within(q, 5.0)))


def test_empty_batches_return_empty_results():
    """Zero-row queries short-circuit to typed empty results: correct
    shapes and dtypes, nothing compiled or executed (the old path padded
    a dummy lane and paid a full compile for a no-op query)."""
    q, db = _vectors()
    engine = VectorIndex.from_database(db).engine(pad_multiple=8)
    res = engine.nearest(q[:0], 4)
    assert res.scores.shape == (0, 4) and res.indices.shape == (0, 4)
    assert res.scores.dtype == jnp.float32
    assert res.indices.dtype == jnp.int32
    counts = engine.count_within(q[:0], 5.0)
    assert counts.shape == (0,) and counts.dtype == jnp.int32
    w = engine.within(q[:0], 5.0, 3)
    assert w.within.shape == (0, 3) and w.within.dtype == bool
    assert engine.scores(q[:0]).shape == (0, db.shape[0])
    assert engine.cache_info().entries == 0, "empty query compiled something"
    # validation still fires before the empty short-circuit
    with pytest.raises(ValueError, match="unknown distance backend"):
        engine.nearest(q[:0], 4, backend="warp")

    scene, rays = _scene_and_rays(11, 100, 8)
    empty = jax.tree_util.tree_map(lambda x: x[:0], rays)
    tre = scene.engine(pad_multiple=8)
    rec = tre.trace(empty)
    assert rec.t.shape == (0,) and rec.tri_index.shape == (0,)
    assert rec.hit.dtype == bool and rec.quadbox_jobs.dtype == jnp.int32
    assert int(rec.rounds) == 0
    assert tre.cache_info().entries == 0, "empty trace compiled something"
    assert tre.occluded(empty).shape == (0,)
    with pytest.raises(ValueError, match="ray_type"):
        tre.trace(empty, ray_type="refracted")


# ---------------------------------------------------------------------------
# sharding / chunking knobs (single-device semantics; the sharded paths are
# fuzzed on an 8-device mesh in test_fuzz_backends.py / test_dispatch.py)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("ray_type", ["closest", "any", "shadow"])
def test_chunked_trace_is_bit_identical(ray_type):
    """chunk_size microbatching returns exactly the one-shot results,
    including the batch round counter, through ONE compiled entry."""
    scene, rays = _scene_and_rays(7, 230, 50)
    ref = scene.engine(pad_multiple=8, shard=1).trace(
        rays, ray_type=ray_type, backend="wavefront")
    chunked = scene.engine(pad_multiple=8, shard=1, chunk_size=16)
    got = chunked.trace(rays, ray_type=ray_type, backend="wavefront")
    for field in TRACE_FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(got, field)),
                                      np.asarray(getattr(ref, field)),
                                      err_msg=field)
    assert int(got.rounds) == int(ref.rounds)
    # 50 rays in 16-row blocks = 4 chunked calls, one compiled function
    assert chunked.cache_info() == (0, 1, 1)
    with CompileTracker() as tracker:
        chunked.trace(rays, ray_type=ray_type, backend="wavefront")
    assert tracker.compiles == 0, "chunked re-query retraced its compiled function"


def test_chunked_distance_is_bit_identical():
    q, db = _vectors()  # 17 queries
    index = VectorIndex.from_database(db)
    ref = index.engine(pad_multiple=8, shard=1)
    chunked = index.engine(pad_multiple=8, shard=1, chunk_size=4)
    for metric in ("euclidean", "angular", "cosine"):
        a = ref.nearest(q, 5, metric)
        b = chunked.nearest(q, 5, metric)
        np.testing.assert_array_equal(np.asarray(a.scores),
                                      np.asarray(b.scores), err_msg=metric)
        np.testing.assert_array_equal(np.asarray(a.indices),
                                      np.asarray(b.indices), err_msg=metric)
    np.testing.assert_array_equal(
        np.asarray(ref.count_within(q, 5.0)),
        np.asarray(chunked.count_within(q, 5.0)))
    # per-call override beats the engine default
    np.testing.assert_array_equal(
        np.asarray(ref.scores(q)), np.asarray(chunked.scores(q, chunk_size=7)))


def test_shard_and_chunk_validation():
    scene, rays = _scene_and_rays(11, 100, 8)
    engine = scene.engine()
    with pytest.raises(ValueError, match="exceeds"):
        engine.trace(rays, shard=jax.local_device_count() + 1)
    with pytest.raises(ValueError, match="shard"):
        engine.trace(rays, shard=0)
    with pytest.raises(ValueError, match="chunk_size"):
        engine.trace(rays, chunk_size=0)
    # shard=1 / shard="auto" always valid, whatever the host mesh
    engine.trace(rays, shard=1)
    engine.trace(rays, shard="auto")


def test_similarity_matches_cosine():
    q, db = _vectors()
    index = VectorIndex.from_database(db)
    got = index.engine(pad_multiple=8).similarity(q)
    ref = cosine_similarity(q, db, c_sq_norms=index.sq_norms)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-6)


def test_vector_index_owns_norms():
    _, db = _vectors()
    index = VectorIndex.from_database(db)
    np.testing.assert_array_equal(
        np.asarray(index.sq_norms),
        np.asarray(jnp.sum(db.astype(jnp.float32) ** 2, axis=-1)))
    assert index.size == 211 and index.dim == 24


def test_pallas_backend_agrees():
    """The Pallas kernel backend returns the same neighbours (scores to
    kernel tolerance: the tiled accumulator sums K in blocks)."""
    assert "pallas" in distance_backends() and "mxu" in distance_backends()
    q, db = _vectors(n_q=16, n_db=64, dim=32)
    engine = VectorIndex.from_database(db).engine(pad_multiple=8)
    ref = engine.nearest(q, 5, "euclidean", backend="mxu")
    got = engine.nearest(q, 5, "euclidean", backend="pallas")
    np.testing.assert_array_equal(np.asarray(got.indices),
                                  np.asarray(ref.indices))
    np.testing.assert_allclose(np.asarray(got.scores),
                               np.asarray(ref.scores), rtol=1e-4, atol=1e-4)
    sim_ref = engine.similarity(q, backend="mxu")
    sim_got = engine.similarity(q, backend="pallas")
    np.testing.assert_allclose(np.asarray(sim_got), np.asarray(sim_ref),
                               rtol=1e-4, atol=1e-5)


def test_distance_validation():
    q, db = _vectors()
    engine = VectorIndex.from_database(db).engine()
    with pytest.raises(ValueError, match="unknown metric"):
        engine.nearest(q, 5, "manhattan")
    with pytest.raises(ValueError, match="radius metric"):
        engine.within(q, 1.0, 5, "angular")
    with pytest.raises(ValueError, match="unknown distance backend"):
        engine.nearest(q, 5, backend="gpu")
    with pytest.raises(ValueError, match="no VectorIndex"):
        QueryEngine().nearest(q, 5)


# ---------------------------------------------------------------------------
# satellites: serving precondition
# ---------------------------------------------------------------------------


def test_serving_engine_rejects_overlong_prompt():
    """The max_len precondition must be a ValueError (asserts vanish under
    ``python -O``), raised before any compute touches the model."""
    from repro.serving import Engine
    eng = Engine(cfg=None, params=None, max_len=8)  # cfg unused pre-check
    with pytest.raises(ValueError, match="max_len"):
        eng.generate(jnp.zeros((1, 6), jnp.int32), max_new_tokens=4)
    with pytest.raises(ValueError, match="batch_chunk"):
        Engine(cfg=None, params=None, batch_chunk=0)


def test_serving_engine_batch_chunk_matches_unchunked():
    """batch_chunk microbatching (the serving twin of the query layer's
    chunk_size) returns the same tokens as the one-shot batch, and empty
    request batches short-circuit."""
    from repro.configs import get_smoke
    from repro.models import init_params
    from repro.serving import Engine
    cfg = get_smoke("smollm-360m")
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (5, 8)), jnp.int32)
    ref = Engine(cfg, params, max_len=16).generate(toks, max_new_tokens=4)
    chunked = Engine(cfg, params, max_len=16, batch_chunk=2)
    got = chunked.generate(toks, max_new_tokens=4)  # 2 + 2 + 1(pad to 2)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    assert chunked.generate(toks[:0], max_new_tokens=4).shape == (0, 4)
    # sampled decode folds the chunk offset into rng: identical prompts in
    # different chunks must not draw identical "random" continuations
    same = jnp.broadcast_to(toks[:1], (4, toks.shape[1]))
    sampled = chunked.generate(same, max_new_tokens=4, temperature=1.0,
                               rng=jax.random.PRNGKey(7))
    assert not np.array_equal(np.asarray(sampled[0]), np.asarray(sampled[2]))
