"""Sharding rules: validity for every arch + sharded==unsharded numerics."""
import jax
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config


def test_param_specs_valid_for_all_archs(multidev):
    """Every arch's param tree gets a well-formed NamedSharding (spec rank
    <= leaf rank, axes divisible or replicated) on the production mesh."""
    multidev("""
import jax
from repro.configs import ARCH_IDS, get_config, SHAPES
from repro.launch.mesh import make_plan
from repro.models import init_params
from repro.parallel.sharding import make_rules
from conftest import make_test_mesh
mesh = make_test_mesh((2, 2, 2), ("pod", "data", "model"))
for arch in ARCH_IDS:
    cfg = get_config(arch)
    plan = make_plan(cfg, SHAPES["train_4k"], multi_pod=True)
    rules = make_rules(mesh, plan)
    shapes = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    shardings = rules.params(shapes)
    flat_s, _ = jax.tree.flatten(shapes)
    flat_sh, _ = jax.tree.flatten(shardings)
    for s, sh in zip(flat_s, flat_sh):
        spec = sh.spec
        assert len(spec) <= len(s.shape), (arch, s.shape, spec)
        for dim, ax in zip(s.shape, spec):
            if ax is None:
                continue
            n = 1
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                n *= mesh.shape[a]
            assert dim % n == 0, (arch, s.shape, spec)
    opt_sh = rules.opt_state(shapes)
print("all arch specs valid")
""", n_devices=8)


def test_sharded_training_matches_unsharded(multidev):
    """One train step on a (data, model) mesh == the single-device step."""
    multidev("""
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_smoke
from repro.models import init_params
from repro.parallel import ParallelPlan, make_rules
from repro.parallel.ctx import NO_PARALLEL
from repro.train import make_loss_fn
# f32 compute: GSPMD is semantics-preserving up to fp reassociation, so the
# equivalence check runs in f32 where reassociation noise is ~1e-6 (verified:
# bf16 amplifies it to ~1e-1 on logits)
cfg = dataclasses.replace(get_smoke("chatglm3-6b"), compute_dtype="float32")
rng = np.random.default_rng(0)
toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)), jnp.int32)
batch = {"tokens": toks, "labels": toks}
params = init_params(jax.random.PRNGKey(0), cfg)
loss_fn = make_loss_fn(cfg, NO_PARALLEL)
(l1, _), g1 = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)

from conftest import make_test_mesh
mesh = make_test_mesh((2, 2), ("data", "model"))
plan = ParallelPlan(batch_axes=("data",))
rules = make_rules(mesh, plan)
psh = rules.params(params)
p_s = jax.device_put(params, psh)
b_s = jax.device_put(batch, rules.batch(batch))
loss_fn2 = make_loss_fn(cfg, plan.ctx(mesh))
(l2, _), g2 = jax.jit(jax.value_and_grad(loss_fn2, has_aux=True),
                      in_shardings=(psh, rules.batch(batch)))(p_s, b_s)
assert abs(float(l1) - float(l2)) < 1e-4, (float(l1), float(l2))
num = sum(float(jnp.sum((a - b) ** 2))
          for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)))
den = sum(float(jnp.sum(b ** 2)) for b in jax.tree.leaves(g1))
assert (num / max(den, 1e-20)) ** 0.5 < 1e-3
print("sharded == unsharded OK")
""", n_devices=4)


def test_long_decode_seq_sharding(multidev):
    """long-context decode with the KV cache sharded over 'data'."""
    multidev("""
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_smoke
from repro.models import init_params, init_cache, prefill, decode_step
from repro.parallel import ParallelPlan, make_rules
from repro.parallel.ctx import NO_PARALLEL
cfg = dataclasses.replace(get_smoke("jamba-1.5-large-398b"),
                          compute_dtype="float32")
params = init_params(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(0)
toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 16)), jnp.int32)
cache = init_cache(cfg, 1, 32)
logits0, cache0 = jax.jit(lambda p,b,c: prefill(cfg, NO_PARALLEL, p, b, c))(
    params, {"tokens": toks}, cache)
from conftest import make_test_mesh
mesh = make_test_mesh((4,), ("data",))
plan = ParallelPlan(batch_axes=("data",), model_axis=None, seq_axis=("data",))
ctx = plan.ctx(mesh)
rules = make_rules(mesh, plan)
csh = rules.cache(cache)
c_s = jax.device_put(cache, csh)
logits1, cache1 = jax.jit(lambda p,b,c: prefill(cfg, ctx, p, b, c))(
    params, {"tokens": toks}, c_s)
np.testing.assert_allclose(np.asarray(logits0), np.asarray(logits1),
                           rtol=3e-3, atol=3e-4)
tok = jnp.argmax(logits1[:, -1], -1)[:, None].astype(jnp.int32)
l0, _ = jax.jit(lambda p,c,t: decode_step(cfg, NO_PARALLEL, p, c, t))(params, cache0, tok)
l1, _ = jax.jit(lambda p,c,t: decode_step(cfg, ctx, p, c, t))(params, cache1, tok)
np.testing.assert_allclose(np.asarray(l0), np.asarray(l1), rtol=3e-3, atol=3e-4)
print("seq-sharded decode OK")
""", n_devices=4)
