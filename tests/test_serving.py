"""Ray-query serving subsystem (DESIGN.md §10).

Three layers, three test styles:

* the **coalescer** is a synchronous state machine driven by explicit
  timestamps, so every flush trigger (batch-full, max-wait timer,
  deadline pressure) and the shed path are pinned with a fake clock —
  no sleeps, no event loop;
* **admission control** is plain accounting — verdicts and counters;
* the **server** is pinned to the hard contract: responses to coalesced
  concurrent requests are *bit-identical* — hits, indices, scores, and
  job counters, `rounds` included — to calling ``QueryEngine`` directly
  per request, for every servable method, on 1 device here and on a
  forced 8-device mesh in the multidev test.
"""
import asyncio

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import PointCloudScene, QueryEngine, Scene, make_ray
from repro.serving import (
    FLUSH_DEADLINE,
    FLUSH_FULL,
    FLUSH_TIMER,
    AdmissionController,
    Coalescer,
    QueryServer,
    QueueFull,
    RequestShed,
)
from repro.serving.batching import make_request

TRACE_FIELDS = ("t", "tri_index", "hit", "quadbox_jobs", "triangle_jobs")


# ---------------------------------------------------------------------------
# coalescer: fake-clock unit tests (no sleeps, no event loop)
# ---------------------------------------------------------------------------


def _req(method="trace", params=(("ray_type", "closest"),), rows=4,
         now=0.0, deadline=None):
    return make_request(method, params, {"x": jnp.zeros((rows, 3))}, rows,
                        now, deadline=deadline)


def test_coalescer_batch_full_flush():
    c = Coalescer(max_batch_rows=16, max_wait=10.0)
    assert c.add(_req(rows=6, now=0.0)) is None
    assert c.add(_req(rows=6, now=0.1)) is None
    batch = c.add(_req(rows=6, now=0.2))  # 18 >= 16: the bucket flushes
    assert batch is not None and batch.reason == FLUSH_FULL
    assert batch.rows == 18 and len(batch.requests) == 3
    assert batch.sizes == [6, 6, 6]
    assert c.depth == 0  # flushed bucket is gone


def test_coalescer_oversized_request_flushes_alone():
    c = Coalescer(max_batch_rows=16, max_wait=10.0)
    batch = c.add(_req(rows=100, now=0.0))
    assert batch is not None and batch.reason == FLUSH_FULL
    assert batch.rows == 100 and len(batch.requests) == 1


def test_coalescer_timer_flush():
    c = Coalescer(max_batch_rows=1024, max_wait=5.0)
    c.add(_req(rows=4, now=0.0))
    c.add(_req(rows=4, now=3.0))
    assert c.poll(4.999) == []  # oldest has waited 4.999 < 5
    assert c.next_due() == 5.0  # oldest (t=0) + max_wait
    [batch] = c.poll(5.0)
    assert batch.reason == FLUSH_TIMER and len(batch.requests) == 2
    assert c.poll(100.0) == [] and c.next_due() is None


def test_coalescer_deadline_pressure_flush():
    """A tight deadline overrides the (much longer) max-wait timer."""
    c = Coalescer(max_batch_rows=1024, max_wait=60.0, deadline_margin=1.0)
    c.add(_req(rows=4, now=0.0))
    c.add(_req(rows=4, now=0.0, deadline=5.0))  # earliest deadline t=5
    assert c.next_due() == 4.0  # deadline - margin, not oldest + max_wait
    assert c.poll(3.999) == []
    [batch] = c.poll(4.0)
    assert batch.reason == FLUSH_DEADLINE and len(batch.requests) == 2
    assert c.depth == 0


def test_coalescer_buckets_split_by_method_and_params():
    c = Coalescer(max_batch_rows=1024, max_wait=5.0)
    c.add(_req(params=(("ray_type", "closest"),), now=0.0))
    c.add(_req(params=(("ray_type", "shadow"),), now=0.0))
    c.add(_req(method="nearest", params=(("k", 4),), now=0.0))
    assert c.depth == 3 and len(c._buckets) == 3
    assert c.depth_for("trace") == 2 and c.depth_for("nearest") == 1
    batches = c.poll(5.0)
    assert len(batches) == 3  # one batch per bucket, never mixed
    keys = {(b.method, b.params) for b in batches}
    assert len(keys) == 3


def test_coalescer_evict_oldest_sheds_across_buckets():
    c = Coalescer(max_batch_rows=1024, max_wait=60.0)
    r1 = _req(rows=4, now=1.0)
    r2 = _req(method="nearest", params=(("k", 8),), rows=4, now=0.5)
    r3 = _req(rows=4, now=2.0)
    for r in (r1, r2, r3):
        c.add(r)
    victim = c.evict_oldest()
    assert victim is r2  # globally oldest, whatever the bucket
    assert c.depth == 2 and c.depth_for("nearest") == 0
    assert c.evict_oldest() is r1
    assert c.evict_oldest() is r3
    assert c.evict_oldest() is None  # nothing queued -> nothing sheddable


def test_coalescer_flush_all_drains():
    c = Coalescer(max_batch_rows=1024, max_wait=60.0)
    c.add(_req(now=0.0))
    c.add(_req(method="nearest", params=(("k", 2),), now=0.0))
    batches = c.flush_all()
    assert len(batches) == 2 and c.depth == 0
    assert all(b.reason == "drain" for b in batches)


def test_coalescer_validation():
    with pytest.raises(ValueError, match="max_batch_rows"):
        Coalescer(max_batch_rows=0)
    with pytest.raises(ValueError, match="max_wait"):
        Coalescer(max_wait=-1.0)
    with pytest.raises(ValueError, match="deadline_margin"):
        Coalescer(deadline_margin=-0.1)


# ---------------------------------------------------------------------------
# admission control: verdicts + accounting
# ---------------------------------------------------------------------------


def test_admission_block_policy():
    a = AdmissionController(2, policy="block")
    assert a.try_admit() == "admit" and a.try_admit() == "admit"
    assert a.try_admit() == "wait"  # full: submitter must wait
    assert a.depth == 2 and not a.has_capacity
    a.release()
    assert a.has_capacity
    a.admit_after_wait()
    s = a.stats()
    assert (s.depth, s.admitted, s.blocked) == (2, 3, 1)


def test_admission_reject_policy():
    a = AdmissionController(1, policy="reject")
    assert a.try_admit() == "admit"
    assert a.try_admit() == "reject"
    assert a.stats().rejected == 1
    a.release()
    assert a.try_admit() == "admit"


def test_admission_shed_policy():
    a = AdmissionController(1, policy="shed")
    assert a.try_admit() == "admit"
    assert a.try_admit() == "shed"
    a.admit_after_shed()  # victim's slot transfers: depth unchanged
    s = a.stats()
    assert (s.depth, s.admitted, s.shed) == (1, 2, 1)
    a.shed_failed()  # nothing sheddable -> counted as a rejection
    assert a.stats().rejected == 1


def test_admission_validation():
    with pytest.raises(ValueError, match="limit"):
        AdmissionController(0)
    with pytest.raises(ValueError, match="policy"):
        AdmissionController(4, policy="drop")
    a = AdmissionController(2)
    with pytest.raises(ValueError, match="release"):
        a.release(1)  # nothing admitted yet


# ---------------------------------------------------------------------------
# the server: coalesced == per-request, bit for bit
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def engine():
    """One engine over a triangle scene AND a point cloud, so a single
    server coalesces every servable method."""
    rng = np.random.default_rng(11)
    n_tri = 150
    ctr = rng.uniform(-1, 1, (n_tri, 3)).astype(np.float32)
    d1 = rng.normal(scale=0.12, size=(n_tri, 3)).astype(np.float32)
    d2 = rng.normal(scale=0.12, size=(n_tri, 3)).astype(np.float32)
    scene = Scene.from_triangles(np.stack([ctr, ctr + d1, ctr + d2], 1))
    cloud = PointCloudScene.from_points(
        rng.normal(size=(400, 3)).astype(np.float32))
    return QueryEngine(scene=scene, cloud=cloud, pad_multiple=8, shard=1)


def _rays(n, seed):
    rng = np.random.default_rng(seed)
    org = rng.uniform(-3, -2, (n, 3)).astype(np.float32)
    tgt = rng.uniform(-0.5, 0.5, (n, 3)).astype(np.float32)
    return make_ray(jnp.asarray(org), jnp.asarray(tgt - org))


def _queries(n, seed):
    return jnp.asarray(np.random.default_rng(seed)
                       .normal(size=(n, 3)).astype(np.float32))


def _assert_trace_equal(got, ref, msg=""):
    for f in TRACE_FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(got, f)),
                                      np.asarray(getattr(ref, f)),
                                      err_msg=f"{msg} field={f}")
    assert int(got.rounds) == int(ref.rounds), msg


def test_server_mixed_methods_bitparity(engine):
    """The acceptance bar: many small concurrent mixed-method requests,
    coalesced into shared batches, each response bit-identical to a
    direct engine call — job counters and per-request rounds included."""
    jobs = []  # (kind, payload, kwargs)
    for i in range(9):
        ray_type = ("closest", "any", "shadow")[i % 3]
        jobs.append(("trace", _rays(2 + i % 4, 50 + i),
                     dict(ray_type=ray_type)))
    for i in range(4):
        jobs.append(("nearest", _queries(1 + i % 3, 80 + i), dict(k=5)))
        jobs.append(("within", _queries(2 + i % 2, 90 + i),
                     dict(radius=1.0, k=6)))
        jobs.append(("count_within", _queries(3, 70 + i),
                     dict(radius=0.8)))

    async def serve():
        async with QueryServer(engine, max_batch_rows=64,
                               max_wait=0.02) as server:
            tasks = [asyncio.ensure_future(
                getattr(server, kind)(payload, **kw))
                for kind, payload, kw in jobs]
            results = await asyncio.gather(*tasks)
            return results, server.stats()

    results, stats = asyncio.run(serve())

    for (kind, payload, kw), got in zip(jobs, results):
        ref = getattr(engine, kind)(payload, **kw)
        if kind == "trace":
            _assert_trace_equal(got, ref, msg=f"{kind} {kw}")
        elif kind == "count_within":
            np.testing.assert_array_equal(np.asarray(got), np.asarray(ref),
                                          err_msg=kind)
        else:
            for g, r in zip(got, ref):
                np.testing.assert_array_equal(np.asarray(g), np.asarray(r),
                                              err_msg=f"{kind} {kw}")
    # coalescing demonstrably happened: fewer engine calls than requests
    assert stats["nearest"].requests_per_batch > 1
    assert stats["count_within"].requests_per_batch > 1
    total_batches = sum(s.batches for s in stats.values())
    assert total_batches < len(jobs)
    # flush accounting is consistent
    for s in stats.values():
        assert (s.flush_full + s.flush_timer + s.flush_deadline
                + s.flush_drain) == s.batches
        assert s.queue_depth == 0


def test_server_full_flush_and_param_buckets(engine):
    """Same-params requests share a batch (full-flush fires); different
    static params never mix."""
    async def serve():
        async with QueryServer(engine, max_batch_rows=8,
                               max_wait=30.0) as server:
            # 4 + 4 rows of k=5 fill the 8-row bucket -> full flush, no
            # timer needed despite the 30 s max_wait
            t1 = asyncio.ensure_future(server.nearest(_queries(4, 1), k=5))
            t2 = asyncio.ensure_future(server.nearest(_queries(4, 2), k=5))
            r1, r2 = await asyncio.gather(t1, t2)
            # different k -> different bucket, flushed only by drain
            t3 = asyncio.ensure_future(server.nearest(_queries(4, 3), k=3))
            await asyncio.sleep(0)
            await server.drain()
            r3 = await t3
            return (r1, r2, r3), server.stats()

    (r1, r2, r3), stats = asyncio.run(serve())
    for res, seed, k in ((r1, 1, 5), (r2, 2, 5), (r3, 3, 3)):
        ref = engine.nearest(_queries(4, seed), k=k)
        np.testing.assert_array_equal(np.asarray(res.indices),
                                      np.asarray(ref.indices))
        np.testing.assert_array_equal(np.asarray(res.scores),
                                      np.asarray(ref.scores))
    s = stats["nearest"]
    assert s.flush_full >= 1  # the k=5 pair went out full
    assert s.flush_drain >= 1  # the k=3 singleton went out on drain
    assert s.requests == 3 and s.batches == 2


def test_server_deadline_triggers_early_flush(engine):
    """A request deadline flushes the bucket long before max_wait."""
    async def serve():
        async with QueryServer(engine, max_batch_rows=1024, max_wait=30.0,
                               deadline_margin=0.001) as server:
            res = await asyncio.wait_for(
                server.nearest(_queries(3, 7), k=4, timeout=0.01),
                timeout=10.0)  # must NOT take the 30 s timer path
            return res, server.stats()

    res, stats = asyncio.run(serve())
    ref = engine.nearest(_queries(3, 7), k=4)
    np.testing.assert_array_equal(np.asarray(res.indices),
                                  np.asarray(ref.indices))
    assert stats["nearest"].flush_deadline == 1


def test_server_reject_policy(engine):
    async def serve():
        async with QueryServer(engine, max_batch_rows=1024, max_wait=30.0,
                               queue_limit=2, policy="reject") as server:
            f1 = await server.enqueue("nearest", _queries(2, 1),
                                      (("backend", None), ("k", 3),
                                       ("metric", "euclidean")))
            f2 = await server.enqueue("nearest", _queries(2, 2),
                                      (("backend", None), ("k", 3),
                                       ("metric", "euclidean")))
            with pytest.raises(QueueFull):
                await server.nearest(_queries(2, 3), k=3)
            assert server.admission_stats().rejected == 1
            await server.drain()
            return await asyncio.gather(f1, f2)

    r1, r2 = asyncio.run(serve())
    ref = engine.nearest(_queries(2, 1), k=3)
    np.testing.assert_array_equal(np.asarray(r1.indices),
                                  np.asarray(ref.indices))


def test_server_shed_policy(engine):
    """At the limit, the oldest queued request is dropped (its future
    fails with RequestShed) and the newcomer takes its slot."""
    async def serve():
        async with QueryServer(engine, max_batch_rows=1024, max_wait=30.0,
                               queue_limit=2, policy="shed") as server:
            params = (("backend", None), ("k", 3), ("metric", "euclidean"))
            f1 = await server.enqueue("nearest", _queries(2, 1), params)
            f2 = await server.enqueue("nearest", _queries(2, 2), params)
            f3 = await server.enqueue("nearest", _queries(2, 3), params)
            with pytest.raises(RequestShed):
                await f1  # the oldest was the victim
            await server.drain()
            r2, r3 = await asyncio.gather(f2, f3)
            return r2, r3, server.stats(), server.admission_stats()

    r2, r3, stats, adm = asyncio.run(serve())
    assert adm.shed == 1 and stats["nearest"].shed == 1
    for res, seed in ((r2, 2), (r3, 3)):
        ref = engine.nearest(_queries(2, seed), k=3)
        np.testing.assert_array_equal(np.asarray(res.indices),
                                      np.asarray(ref.indices))


def test_server_empty_request_short_circuits(engine):
    async def serve():
        async with QueryServer(engine) as server:
            return await server.trace(_rays(0, 0))

    res = asyncio.run(serve())
    assert res.t.shape == (0,) and int(res.rounds) == 0


def test_server_rejects_bad_requests_eagerly(engine):
    """Malformed static params fail in the submitter, before they can
    poison a shared batch."""
    async def serve():
        async with QueryServer(engine) as server:
            with pytest.raises(ValueError, match="ray_type"):
                await server.trace(_rays(2, 0), ray_type="laser")
            with pytest.raises(ValueError, match="k must be"):
                await server.nearest(_queries(2, 0), k=0)
            with pytest.raises(ValueError, match="radius"):
                await server.within(_queries(2, 0), radius=float("nan"),
                                    k=3)
            with pytest.raises(ValueError, match="method"):
                await server.enqueue("explode", _queries(2, 0), ())

    asyncio.run(serve())


def test_server_not_running_raises(engine):
    server = QueryServer(engine)

    async def go():
        with pytest.raises(RuntimeError, match="not running"):
            await server.trace(_rays(2, 0))

    asyncio.run(go())


def test_server_quantized_batches_reuse_compiled_fns(engine):
    """The power-of-two row ladder: batches whose row counts differ only
    within a ladder step hit the same compiled program (the jit cache
    gains at most one entry for the second batch)."""
    eng = QueryEngine(scene=engine.scene, cloud=engine.cloud,
                      pad_multiple=8, shard=1)

    async def serve():
        async with QueryServer(eng, max_batch_rows=64,
                               max_wait=0.005) as server:
            await server.nearest(_queries(9, 1), k=4)   # pads to 16-ladder
            before = eng.cache_info().entries
            await server.nearest(_queries(12, 2), k=4)  # same 16-ladder
            await server.nearest(_queries(15, 3), k=4)
            return before, eng.cache_info()

    before, after = asyncio.run(serve())
    assert after.entries == before  # no new programs for 12 or 15 rows
    assert after.hits >= 2


# ---------------------------------------------------------------------------
# the acceptance criterion on a forced 8-device mesh
# ---------------------------------------------------------------------------


def test_server_bitparity_8dev(multidev):
    multidev("""
import asyncio
import numpy as np, jax, jax.numpy as jnp
assert jax.local_device_count() == 8
from repro.api import PointCloudScene, QueryEngine, Scene, make_ray
from repro.serving import QueryServer

rng = np.random.default_rng(5)
n_tri = 200
ctr = rng.uniform(-1, 1, (n_tri, 3)).astype(np.float32)
d1 = rng.normal(scale=0.12, size=(n_tri, 3)).astype(np.float32)
d2 = rng.normal(scale=0.12, size=(n_tri, 3)).astype(np.float32)
scene = Scene.from_triangles(np.stack([ctr, ctr + d1, ctr + d2], 1))
cloud = PointCloudScene.from_points(
    rng.normal(size=(300, 3)).astype(np.float32))
engine = QueryEngine(scene=scene, cloud=cloud, pad_multiple=8,
                     shard="auto")  # sharded over the 8-dev mesh

def rays_of(n, seed):
    r = np.random.default_rng(seed)
    org = r.uniform(-3, -2, (n, 3)).astype(np.float32)
    tgt = r.uniform(-0.5, 0.5, (n, 3)).astype(np.float32)
    return make_ray(jnp.asarray(org), jnp.asarray(tgt - org))

def queries_of(n, seed):
    return jnp.asarray(np.random.default_rng(seed)
                       .normal(size=(n, 3)).astype(np.float32))

jobs = []
for i in range(6):
    jobs.append(("trace", rays_of(3 + i % 4, i),
                 dict(ray_type=("closest", "any", "shadow")[i % 3])))
for i in range(4):
    jobs.append(("nearest", queries_of(2 + i % 3, 40 + i), dict(k=4)))
    jobs.append(("count_within", queries_of(2, 60 + i), dict(radius=0.7)))

async def serve():
    async with QueryServer(engine, max_batch_rows=64,
                           max_wait=0.05) as server:
        tasks = [asyncio.ensure_future(getattr(server, kind)(p, **kw))
                 for kind, p, kw in jobs]
        res = await asyncio.gather(*tasks)
        return res, server.stats()

results, stats = asyncio.run(serve())
for (kind, payload, kw), got in zip(jobs, results):
    ref = getattr(engine, kind)(payload, **kw)
    if kind == "trace":
        for f in ("t", "tri_index", "hit", "quadbox_jobs",
                  "triangle_jobs"):
            np.testing.assert_array_equal(
                np.asarray(getattr(got, f)), np.asarray(getattr(ref, f)),
                err_msg=f"{kind} {kw} {f}")
        assert int(got.rounds) == int(ref.rounds), (kind, kw)
    elif kind == "count_within":
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    else:
        for g, r in zip(got, ref):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(r))
assert sum(s.requests_per_batch > 1 for s in stats.values()) >= 1
print("serving 8-dev bit-parity OK")
""", n_devices=8)
