"""Edge cases of the hardened query surface (regression suite).

Each group here pins one of the hardening fixes and fails on the
pre-fix code:

* ``k > N`` used to crash inside ``lax.top_k``; it is now clamped to the
  database size with the excess slots padded (``inf``/``-inf`` score,
  index ``-1``, ``valid``/``within`` False) — on the brute backends AND
  the tree-backed neighbor path.
* ``k <= 0`` and NaN / negative euclidean radii used to silently produce
  zero-width or empty results; they now raise ``ValueError`` eagerly,
  before anything compiles.
* zero-norm cosine vectors used to score ``0/eps`` garbage (NaN without
  the clamp) that ``top_k`` sorted *first*; they are now pinned to
  ``-inf`` and rank strictly last.

Plus the benign edges that must keep working: ``radius == 0``, empty
query batches, and duplicate database points — across every distance
backend (``mxu`` / ``pallas``) and both tree backends
(``tree_wavefront`` / ``tree_pallas``).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import PointCloudScene, VectorIndex
from repro.core import radius_count, radius_search
from repro.core.knn import (check_k, check_radius, cosine_similarity, knn,
                            select_topk, select_within)

BRUTE = ("mxu", "pallas")
TREE = ("tree_wavefront", "tree_pallas")

N_DB, DIM = 37, 8
N_PTS = 50


@pytest.fixture(scope="module")
def engine():
    rng = np.random.default_rng(11)
    db = jnp.asarray(rng.normal(size=(N_DB, DIM)).astype(np.float32))
    return VectorIndex.from_database(db).engine(pad_multiple=8, shard=1)


@pytest.fixture(scope="module")
def cloud_engine():
    rng = np.random.default_rng(12)
    pts = jnp.asarray(rng.normal(size=(N_PTS, 3)).astype(np.float32))
    return PointCloudScene.from_points(pts).engine(pad_multiple=8, shard=1)


@pytest.fixture(scope="module")
def dup_cloud_engine():
    # integer coordinates: the MXU form ||q||^2 - 2 q.c + ||c||^2 is exact
    # in f32 on small ints, so duplicates sit at *exactly* d^2 == 0 and the
    # radius == 0 / duplicate tests are deterministic, not boundary-lucky
    rng = np.random.default_rng(13)
    pts = rng.integers(0, 7, size=(30, 3)).astype(np.float32)
    pts[0] = pts[1] = pts[2] = (2.0, 3.0, 1.0)  # known triplicate
    return PointCloudScene.from_points(jnp.asarray(pts)).engine(
        pad_multiple=8, shard=1)


def _queries(n=5, dim=DIM, seed=21):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(n, dim)).astype(np.float32))


# ---------------------------------------------------------------------------
# k > N: clamped + padded, never a top_k crash
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BRUTE)
def test_k_exceeds_db_pads_brute(engine, backend):
    q = _queries()
    k = N_DB + 11
    res = engine.nearest(q, k, backend=backend)
    assert res.scores.shape == (5, k)
    got_valid = np.asarray(res.valid)
    assert got_valid[:, :N_DB].all() and not got_valid[:, N_DB:].any()
    assert (np.asarray(res.indices)[:, N_DB:] == -1).all()
    assert np.isposinf(np.asarray(res.scores)[:, N_DB:]).all()
    # the real slots exhaust the database, each index exactly once
    for row in np.asarray(res.indices)[:, :N_DB]:
        assert set(row) == set(range(N_DB))

    big = engine.within(q, 1e6, k, backend=backend)
    assert np.asarray(big.within)[:, :N_DB].all()
    assert not np.asarray(big.within)[:, N_DB:].any()


def test_k_exceeds_db_pads_cosine(engine):
    # cosine is a similarity: pad slots carry -inf, still strictly last
    res = engine.nearest(_queries(), N_DB + 3, "cosine", backend="mxu")
    assert np.isneginf(np.asarray(res.scores)[:, N_DB:]).all()
    assert not np.asarray(res.valid)[:, N_DB:].any()


@pytest.mark.parametrize("backend", TREE)
def test_k_exceeds_cloud_pads_tree(cloud_engine, backend):
    q = _queries(4, 3, seed=22)
    k = N_PTS + 14
    res = cloud_engine.nearest(q, k, backend=backend)
    assert res.scores.shape == (4, k)
    got_valid = np.asarray(res.valid)
    assert got_valid[:, :N_PTS].all() and not got_valid[:, N_PTS:].any()
    assert (np.asarray(res.indices)[:, N_PTS:] == -1).all()
    assert np.isposinf(np.asarray(res.scores)[:, N_PTS:]).all()
    for row in np.asarray(res.indices)[:, :N_PTS]:
        assert set(row) == set(range(N_PTS))

    big = cloud_engine.within(q, 1e3, k, backend=backend)
    assert np.asarray(big.within)[:, :N_PTS].all()
    assert not np.asarray(big.within)[:, N_PTS:].any()


def test_k_exceeds_free_functions():
    rng = np.random.default_rng(23)
    db = jnp.asarray(rng.normal(size=(9, 4)).astype(np.float32))
    q = jnp.asarray(rng.normal(size=(3, 4)).astype(np.float32))
    scores, idx = knn(q, db, k=20)
    assert scores.shape == (3, 20) and (np.asarray(idx)[:, 9:] == -1).all()
    s, i, w = radius_search(q, db, radius=1e6, k=20)
    assert np.asarray(w)[:, :9].all() and not np.asarray(w)[:, 9:].any()


# ---------------------------------------------------------------------------
# k <= 0 and bad radii: eager ValueError on every entry point
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("k", (0, -2))
def test_nonpositive_k_raises(engine, cloud_engine, k):
    q, q3 = _queries(), _queries(3, 3)
    with pytest.raises(ValueError, match="k must be"):
        engine.nearest(q, k)
    with pytest.raises(ValueError, match="k must be"):
        engine.within(q, 1.0, k)
    with pytest.raises(ValueError, match="k must be"):
        cloud_engine.nearest(q3, k, backend="tree_wavefront")
    with pytest.raises(ValueError, match="k must be"):
        cloud_engine.neighbor_search(q3, k, radius=1.0)
    with pytest.raises(ValueError, match="k must be"):
        knn(q, jnp.zeros((4, DIM)), k)
    with pytest.raises(ValueError, match="k must be"):
        select_topk(jnp.zeros((2, 4)), k)
    with pytest.raises(ValueError, match="k must be"):
        check_k(k)


@pytest.mark.parametrize("radius", (float("nan"), -0.25))
def test_bad_euclidean_radius_raises(engine, cloud_engine, radius):
    q, q3 = _queries(), _queries(3, 3)
    db = jnp.zeros((4, DIM))
    for call in (
        lambda: engine.within(q, radius, 4),
        lambda: engine.count_within(q, radius),
        lambda: cloud_engine.within(q3, radius, 4,
                                    backend="tree_wavefront"),
        lambda: cloud_engine.count_within(q3, radius,
                                          backend="tree_pallas"),
        lambda: cloud_engine.neighbor_search(q3, 4, radius=radius),
        lambda: radius_search(q, db, radius, 4),
        lambda: radius_count(q, db, radius),
        lambda: select_within(jnp.zeros((2, 4)), radius, 2),
        lambda: check_radius(radius),
    ):
        with pytest.raises(ValueError, match="radius"):
            call()


def test_negative_cosine_radius_is_legal(engine):
    # a cosine radius is a *minimum similarity*: "at least -0.5 similar"
    q = _queries()
    res = engine.within(q, -0.5, N_DB, "cosine", backend="mxu")
    sims = np.asarray(engine.scores(q, "cosine", backend="mxu"))
    np.testing.assert_array_equal(
        np.asarray(res.within).sum(axis=1), (sims >= -0.5).sum(axis=1))


# ---------------------------------------------------------------------------
# radius == 0 and duplicate points: exact, consistent across paths
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BRUTE + TREE)
def test_radius_zero_and_duplicates(dup_cloud_engine, backend):
    eng = dup_cloud_engine
    q = jnp.asarray([[2.0, 3.0, 1.0], [50.0, 50.0, 50.0]], jnp.float32)
    counts = np.asarray(eng.count_within(q, 0.0, backend=backend))
    assert counts[0] == 3  # the triplicate, at exactly d^2 == 0
    assert counts[1] == 0

    res = eng.within(q, 0.0, 8, backend=backend)
    w = np.asarray(res.within)
    assert set(np.asarray(res.indices)[0][w[0]]) == {0, 1, 2}
    assert not w[1].any()
    assert (np.asarray(res.scores)[0][w[0]] == 0.0).all()


@pytest.mark.parametrize("backend", BRUTE + TREE)
def test_duplicate_points_nearest(dup_cloud_engine, backend):
    res = dup_cloud_engine.nearest(
        jnp.asarray([[2.0, 3.0, 1.0]], jnp.float32), 3, backend=backend)
    assert set(np.asarray(res.indices)[0]) == {0, 1, 2}
    assert (np.asarray(res.scores)[0] == 0.0).all()
    assert np.asarray(res.valid).all()


# ---------------------------------------------------------------------------
# empty query batch: typed empty results, nothing compiled
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BRUTE)
def test_empty_batch_brute(engine, backend):
    q = jnp.zeros((0, DIM), jnp.float32)
    res = engine.nearest(q, 4, backend=backend)
    assert res.scores.shape == (0, 4) and res.valid.shape == (0, 4)
    win = engine.within(q, 1.0, 4, backend=backend)
    assert win.within.shape == (0, 4)
    assert engine.count_within(q, 1.0, backend=backend).shape == (0,)


@pytest.mark.parametrize("backend", TREE)
def test_empty_batch_tree(cloud_engine, backend):
    q = jnp.zeros((0, 3), jnp.float32)
    res = cloud_engine.nearest(q, 4, backend=backend)
    assert res.scores.shape == (0, 4) and res.valid.shape == (0, 4)
    rec = cloud_engine.neighbor_search(q, 4, radius=1.0, backend=backend)
    assert rec.count.shape == (0,) and rec.box_jobs.shape == (0,)
    assert int(rec.rounds) == 0


# ---------------------------------------------------------------------------
# zero-norm cosine vectors: -inf, rank strictly last, never NaN
# ---------------------------------------------------------------------------

ZERO_ROW = 5


@pytest.fixture(scope="module")
def zero_engine():
    rng = np.random.default_rng(31)
    db = rng.normal(size=(24, DIM)).astype(np.float32)
    db[ZERO_ROW] = 0.0
    return VectorIndex.from_database(jnp.asarray(db)).engine(
        pad_multiple=8, shard=1)


@pytest.mark.parametrize("backend", BRUTE)
def test_zero_norm_cosine_ranks_last(zero_engine, backend):
    q = np.random.default_rng(32).normal(size=(6, DIM)).astype(np.float32)
    q[2] = 0.0  # degenerate query row too
    q = jnp.asarray(q)

    sims = np.asarray(zero_engine.scores(q, "cosine", backend=backend))
    assert not np.isnan(sims).any()
    assert np.isneginf(sims[:, ZERO_ROW]).all()  # zero-norm db column
    assert np.isneginf(sims[2]).all()  # zero-norm query row

    res = zero_engine.nearest(q, 24, "cosine", backend=backend)
    idx = np.asarray(res.indices)
    assert not np.isnan(np.asarray(res.scores)).any()
    # the zero-norm vector is in the k-th (last) slot for every
    # well-defined query — strictly below every real similarity
    for row in (0, 1, 3, 4, 5):
        assert idx[row, -1] == ZERO_ROW

    # a minimum-similarity radius, even a negative one, never admits it
    win = zero_engine.within(q, -1.0, 24, "cosine", backend=backend)
    w = np.asarray(win.within)
    assert not w[:, -1].any() or not np.isin(
        ZERO_ROW, np.asarray(win.indices)[w])
    assert not w[2].any()  # degenerate query matches nothing


def test_zero_norm_cosine_free_function():
    db = np.zeros((4, 3), np.float32)
    db[0] = (1.0, 0.0, 0.0)
    q = jnp.asarray([[1.0, 0.0, 0.0], [0.0, 0.0, 0.0]], jnp.float32)
    sims = np.asarray(cosine_similarity(q, jnp.asarray(db)))
    assert not np.isnan(sims).any()
    np.testing.assert_array_equal(np.isneginf(sims[0]),
                                  [False, True, True, True])
    assert np.isneginf(sims[1]).all()
