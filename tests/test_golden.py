"""Golden-trace regression suite: every backend vs *stored* expectations.

The pairwise parity tests (``test_session.py``, ``test_fuzz_backends.py``)
pin backends to each other — which cannot catch *silent arithmetic drift*
where both sides move together (a datapath stage helper edited, an XLA
upgrade changing contraction, a BVH builder reordering leaves).  This
suite pins every registered trace backend × ray type × builder against
hit records and job counters serialized at a known-good commit:

* ``tests/golden/<scene>.npz`` holds a small canonical scene (triangle
  soup + deterministic ray batch) and, per (config, builder, ray_type),
  the expected ``t`` / ``tri_index`` / ``hit`` / ``quadbox_jobs`` /
  ``triangle_jobs`` / ``stack_overflow`` / ``rounds`` produced by the
  wavefront oracle.  The pinned config set spans the datapath twins:
  the BVH4-fp32 default, BVH8-fp32 (arity), and BVH4-compressed (the
  quantized node codec) — so codec or sort-network drift is caught even
  when both engines move together.
* The test traces the stored rays through the session engine with every
  registered backend and bit-compares everything.

Intentional changes regenerate the fixtures::

    PYTHONPATH=src python -m pytest tests/test_golden.py --regen-goldens
    PYTHONPATH=src python -m pytest tests/test_golden.py   # verify

(see ``tests/golden/README.md``; review the diff before committing — a
golden change IS a behavior change).
"""
import os
import zlib

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import Scene, make_ray, trace_backends
from repro.core import Triangle
from repro.core.bvh import DatapathConfig
from repro.core.session import trace_backend_ray_types
from repro.core.wavefront import RAY_TYPES, trace_wavefront

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")
BUILDERS = ("lbvh", "sah")
FIELDS = ("t", "tri_index", "hit", "quadbox_jobs", "triangle_jobs",
          "stack_overflow")
SCENES = ("tetra", "sheet", "cluster")
#: pinned datapath twins: default, the wide-arity twin, the quantized
#: node codec twin (each key is the config's ``tag``)
CONFIGS = (DatapathConfig(),
           DatapathConfig(arity=8),
           DatapathConfig(precision="bf16", node_format="compressed"))


# ---------------------------------------------------------------------------
# Canonical scenes + deterministic ray streams (small on purpose: goldens
# are committed binaries, and a handful of rays per branchy scene already
# covers hit/miss/extent/epsilon paths)
# ---------------------------------------------------------------------------


def _scene_triangles(name: str) -> np.ndarray:
    """(N, 3verts, 3) float32 vertices for a named canonical scene."""
    if name == "tetra":  # 4 exact-coordinate faces: the minimal closed solid
        v = np.asarray([[1, 1, 1], [1, -1, -1], [-1, 1, -1], [-1, -1, 1]],
                       np.float32)
        faces = [(0, 1, 2), (0, 3, 1), (0, 2, 3), (1, 3, 2)]
        return np.stack([np.stack([v[a], v[b], v[c]]) for a, b, c in faces])
    if name == "sheet":  # regular 4x4 quad grid split into 32 triangles:
        # axis-aligned geometry exercises the 0*inf slab boundaries
        tris = []
        for i in range(4):
            for j in range(4):
                x0, x1 = i - 2.0, i - 1.0
                y0, y1 = j - 2.0, j - 1.0
                a, b = [x0, y0, 0.0], [x1, y0, 0.0]
                c, d = [x1, y1, 0.0], [x0, y1, 0.0]
                tris += [[a, b, c], [a, c, d]]
        return np.asarray(tris, np.float32)
    if name == "cluster":  # the canonical non-uniform quality workload
        from repro.core.build.quality import clustered_soup
        tri = clustered_soup(np.random.default_rng(42), n_clusters=4,
                             per_cluster=30)
        return np.stack([np.asarray(tri.a), np.asarray(tri.b),
                         np.asarray(tri.c)], axis=1)
    raise ValueError(name)


def _scene_rays(name: str, tris: np.ndarray):
    """A deterministic mixed ray stream: hits, misses, finite extents."""
    rng = np.random.default_rng(zlib.crc32(name.encode()))  # stable seed
    n = 40
    center = tris.reshape(-1, 3).mean(0)
    span = np.abs(tris.reshape(-1, 3) - center).max() + 1.0
    org = (center + rng.uniform(-1, 1, (n, 3)) * 3 * span).astype(np.float32)
    tgt = (center + rng.uniform(-0.5, 0.5, (n, 3)) * span).astype(np.float32)
    extent = np.where(rng.uniform(size=n) < 0.4,
                      rng.uniform(0.5, 4.0, n) * span, np.inf)
    return org, (tgt - org).astype(np.float32), extent.astype(np.float32)


def _golden_path(name: str) -> str:
    return os.path.join(GOLDEN_DIR, f"{name}.npz")


def _generate(name: str) -> dict:
    """Scene + rays + wavefront-oracle expectations for every
    (builder, ray_type) — the free function, not the engine, so the
    goldens are anchored below the session layer."""
    tris = _scene_triangles(name)
    org, dirs, extent = _scene_rays(name, tris)
    rays = make_ray(jnp.asarray(org), jnp.asarray(dirs),
                    extent=jnp.asarray(extent))
    data = {"tris": tris, "ray_org": org, "ray_dir": dirs,
            "ray_extent": extent}
    for config in CONFIGS:
        for builder in BUILDERS:
            scene = Scene.from_triangles(
                Triangle(jnp.asarray(tris[:, 0]), jnp.asarray(tris[:, 1]),
                         jnp.asarray(tris[:, 2])), builder=builder,
                config=config)
            for ray_type in RAY_TYPES:
                rec = trace_wavefront(scene.bvh, rays, scene.depth,
                                      ray_type=ray_type, config=config)
                stem = f"{config.tag}__{builder}__{ray_type}"
                for f in FIELDS:
                    data[f"{stem}__{f}"] = np.asarray(getattr(rec, f))
                data[f"{stem}__rounds"] = np.asarray(rec.rounds)
    return data


@pytest.mark.parametrize("config", CONFIGS, ids=lambda c: c.tag)
@pytest.mark.parametrize("scene_name", SCENES)
def test_golden_traces(scene_name, config, regen_goldens):
    path = _golden_path(scene_name)
    if regen_goldens:
        if config is not CONFIGS[0]:
            pytest.skip("fixture regenerated once, for all configs")
        os.makedirs(GOLDEN_DIR, exist_ok=True)
        np.savez_compressed(path, **_generate(scene_name))
    if not os.path.exists(path):
        pytest.fail(f"missing golden fixture {path}; generate it with "
                    "pytest tests/test_golden.py --regen-goldens")
    data = np.load(path)

    tris = data["tris"]
    rays = make_ray(jnp.asarray(data["ray_org"]),
                    jnp.asarray(data["ray_dir"]),
                    extent=jnp.asarray(data["ray_extent"]))
    for builder in BUILDERS:
        scene = Scene.from_triangles(
            Triangle(jnp.asarray(tris[:, 0]), jnp.asarray(tris[:, 1]),
                     jnp.asarray(tris[:, 2])), builder=builder,
            config=config)
        engine = scene.engine(pad_multiple=8, shard=1)
        for ray_type in RAY_TYPES:
            stem = f"{config.tag}__{builder}__{ray_type}"
            expected = {f: data[f"{stem}__{f}"] for f in FIELDS}
            exp_rounds = int(data[f"{stem}__rounds"])
            for backend in trace_backends():
                if ray_type not in trace_backend_ray_types(backend):
                    continue
                got = engine.trace(rays, ray_type=ray_type, backend=backend)
                for f in FIELDS:
                    np.testing.assert_array_equal(
                        np.asarray(getattr(got, f)), expected[f],
                        err_msg=(f"golden drift: {scene_name}/{config.tag}/"
                                 f"{builder}/{ray_type}/{backend}: {f}"))
                assert int(got.rounds) == exp_rounds, (
                    f"golden drift: {scene_name}/{config.tag}/{builder}/"
                    f"{ray_type}/{backend}: rounds")


def test_golden_fixtures_self_describing():
    """Every committed fixture carries the scene + rays it was traced
    with, so a drift report can be reproduced standalone."""
    for scene_name in SCENES:
        path = _golden_path(scene_name)
        if not os.path.exists(path):
            pytest.skip("goldens not generated yet")
        data = np.load(path)
        for key in ("tris", "ray_org", "ray_dir", "ray_extent"):
            assert key in data, f"{path} missing {key}"
        assert data["tris"].dtype == np.float32
