"""MoE block: router == datapath angular mode; dispatch == explicit top-k sum."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.knn import angular_scores
from repro.models import ModelConfig, MoEConfig
from repro.models.moe import moe_apply, moe_init, router_scores, router_topk
from repro.parallel.ctx import NO_PARALLEL as ctx


def _cfg(**kw):
    d = dict(name="t", family="moe", num_layers=1, d_model=32, num_heads=2,
             num_kv_heads=2, d_ff=64, vocab_size=64,
             moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=32,
                           capacity_factor=8.0))  # no drops
    d.update(kw)
    return ModelConfig(**d)


def test_router_is_angular_mode():
    """Router scores are literally OpAngular dot products."""
    cfg = _cfg()
    rng = np.random.default_rng(0)
    x = rng.normal(size=(10, 32)).astype(np.float32)
    w = rng.normal(size=(4, 32)).astype(np.float32)
    s = router_scores(cfg.moe, jnp.asarray(x), jnp.asarray(w))
    dots, _ = angular_scores(jnp.asarray(x), jnp.asarray(w))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(dots))


def test_moe_equals_explicit_topk_sum():
    """With capacity ample, MoE output == sum_k w_k * FFN_{e_k}(x)."""
    cfg = _cfg()
    p = moe_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(2, 6, 32)).astype(np.float32))
    y, aux = moe_apply(cfg, ctx, p, x)

    xf = np.asarray(x).reshape(12, 32)
    scores = np.asarray(router_scores(cfg.moe, jnp.asarray(xf), p["router"]))
    w, idx, _ = router_topk(cfg.moe, jnp.asarray(scores))
    w, idx = np.asarray(w), np.asarray(idx)
    wi, wg, wo = (np.asarray(p[k], np.float32) for k in ("wi", "wg", "wo"))

    def ffn(e, v):
        h = v @ wi[e]
        g = v @ wg[e]
        return (g * (1 / (1 + np.exp(-g))) * h) @ wo[e]

    want = np.zeros_like(xf)
    for n in range(12):
        for j in range(cfg.moe.top_k):
            want[n] += w[n, j] * ffn(idx[n, j], xf[n])
    np.testing.assert_allclose(np.asarray(y).reshape(12, 32), want,
                               rtol=2e-3, atol=2e-3)
    assert np.isfinite(float(aux))


def test_capacity_drops_tokens():
    """With capacity_factor << 1 some tokens are dropped (output zeros),
    never corrupted."""
    cfg = _cfg(moe=MoEConfig(num_experts=4, top_k=1, d_ff_expert=32,
                             capacity_factor=0.26))
    p = moe_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(1, 64, 32)).astype(np.float32))
    y, _ = moe_apply(cfg, ctx, p, x)
    y = np.asarray(y)[0]
    norms = np.linalg.norm(y, axis=-1)
    assert (norms < 1e-7).sum() > 0, "expected dropped tokens"
    assert np.isfinite(y).all()


def test_sigmoid_router_normalizes_selected():
    m = MoEConfig(num_experts=8, top_k=3, d_ff_expert=8, router="sigmoid",
                  route_scale=2.5)
    rng = np.random.default_rng(3)
    s = jnp.asarray(rng.normal(size=(5, 8)).astype(np.float32))
    w, idx, aux = router_topk(m, s)
    np.testing.assert_allclose(np.asarray(w).sum(-1), 2.5, rtol=1e-5)


def test_ep_sharded_equals_dense(multidev):
    """Expert-parallel shard_map path == single-device dense path."""
    multidev("""
import jax, jax.numpy as jnp, numpy as np
from repro.models import ModelConfig, MoEConfig
from repro.models.moe import moe_apply, moe_init
from repro.parallel import ParallelPlan
from repro.parallel.ctx import NO_PARALLEL
cfg = ModelConfig(name="t", family="moe", num_layers=1, d_model=32,
                  num_heads=2, num_kv_heads=2, d_ff=64, vocab_size=64,
                  moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=32,
                                capacity_factor=8.0))
p = moe_init(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(1)
x = jnp.asarray(rng.normal(size=(4, 8, 32)).astype(np.float32))
y_dense, _ = moe_apply(cfg, NO_PARALLEL, p, x)
from conftest import make_test_mesh
mesh = make_test_mesh((2, 2), ("data", "model"))
ctx = ParallelPlan(batch_axes=("data",)).ctx(mesh)
y_ep, _ = jax.jit(lambda p, x: moe_apply(cfg, ctx, p, x))(p, x)
np.testing.assert_allclose(np.asarray(y_dense), np.asarray(y_ep),
                           rtol=2e-4, atol=2e-4)
print("EP==dense OK")
""", n_devices=4)
