"""int8 gradient compression: quantization bounds + error-feedback tracking."""
import jax.numpy as jnp
import numpy as np

from repro.train.compress import dequantize, quantize


def test_quantize_bounds():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(256,)).astype(np.float32) * 3)
    q, scale = quantize(x)
    err = np.abs(np.asarray(dequantize(q, scale)) - np.asarray(x))
    assert err.max() <= float(scale) / 2 + 1e-7  # half-step rounding bound
    assert q.dtype == jnp.int8


def test_quantize_preserves_zero_and_max():
    x = jnp.asarray([0.0, 127.0, -127.0, 63.5], jnp.float32)
    q, scale = quantize(x)
    d = np.asarray(dequantize(q, scale))
    assert d[0] == 0.0
    np.testing.assert_allclose(d[1], 127.0, rtol=1e-6)


def test_compressed_crosspod_allreduce(multidev):
    multidev("""
import jax, jax.numpy as jnp, numpy as np
from repro.train.compress import compressed_crosspod_allreduce
from conftest import make_test_mesh
mesh = make_test_mesh((2, 2, 2), ("pod", "data", "model"))
rng = np.random.default_rng(0)

# single-shot error bounded by quantization step
g = {"w": jnp.asarray(rng.normal(size=(2, 128)).astype(np.float32))}
mean_true = np.asarray(g["w"]).mean(0)
synced, efb = compressed_crosspod_allreduce(g, mesh)
step = np.abs(np.asarray(g["w"])).max() / 127.0
err = np.abs(np.asarray(synced["w"])[0] - mean_true)
assert err.max() <= step, (err.max(), step)

# error feedback: cumulative compressed sum tracks the true sum (bounded
# drift, not growing with steps)
tot_t = np.zeros(128); tot_c = np.zeros(128)
efb = None
drifts = []
for s in range(30):
    g = {"w": jnp.asarray(rng.normal(size=(2, 128)).astype(np.float32))}
    synced, efb = compressed_crosspod_allreduce(g, mesh, error_fb=efb)
    tot_t += np.asarray(g["w"]).mean(0)
    tot_c += np.asarray(synced["w"])[0]
    drifts.append(np.abs(tot_t - tot_c).max())
assert drifts[-1] < 5 * (np.abs(np.asarray(g["w"])).max() / 127.0), drifts[-1]
print("compression OK")
""", n_devices=8)
