"""Acceleration-structure construction subsystem (DESIGN.md §7).

The contract: every registered builder emits the same implicit BVH4
layout, so (a) every trace backend bit-matches the per-ray oracle *on that
builder's own tree*, (b) closest-hit results agree *across* builders on
non-tie scenes (t is a pure function of (ray, triangle), whatever tree
found it), (c) ``refit`` with unchanged triangles is bit-identical to a
fresh build and with moved triangles still bounds every triangle, and
(d) an animated scene driven by ``Scene.refit`` re-enters the compiled
cache with zero retracing while every frame's hits bit-match a
from-scratch rebuild.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.obs import CompileTracker

from repro.api import Scene, builders, make_ray, refit
from repro.core import (Triangle, build, sah_cost, trace_rays,
                        trace_wavefront, tree_stats)
from repro.core.build import clustered_soup
from repro.core.bvh import child_boxes, depth_of, level_offset

TRACE_FIELDS = ("t", "tri_index", "hit", "quadbox_jobs", "triangle_jobs")
BUILDERS = ("lbvh", "sah")


def _soup(rng, n_tri, scale=0.15):
    ctr = rng.uniform(-1, 1, (n_tri, 3)).astype(np.float32)
    d1 = rng.normal(scale=scale, size=(n_tri, 3)).astype(np.float32)
    d2 = rng.normal(scale=scale, size=(n_tri, 3)).astype(np.float32)
    return Triangle(a=jnp.asarray(ctr), b=jnp.asarray(ctr + d1),
                    c=jnp.asarray(ctr + d2))


def _rays(rng, n, lo=-0.5, hi=0.5):
    org = rng.uniform(-3, -2, (n, 3)).astype(np.float32)
    tgt = rng.uniform(lo, hi, (n, 3)).astype(np.float32)
    return make_ray(jnp.asarray(org), jnp.asarray(tgt - org))


def _assert_trace_equal(got, ref, fields=TRACE_FIELDS, msg=""):
    for field in fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(got, field)),
            np.asarray(getattr(ref, field)), err_msg=f"{msg}{field}")


# ---------------------------------------------------------------------------
# registry + layout invariants
# ---------------------------------------------------------------------------


def test_builder_registry():
    assert "lbvh" in builders() and "sah" in builders()
    tri = _soup(np.random.default_rng(0), 20)
    with pytest.raises(ValueError, match="unknown builder"):
        build(tri, "octree")
    with pytest.raises(ValueError, match="unknown builder"):
        Scene.from_triangles(tri, builder="octree")
    with pytest.raises(ValueError, match="leaf slots"):
        build(tri, "lbvh", depth=1)  # 4 slots < 20 triangles
    res = build(tri, "sah")
    assert res.builder == "sah" and res.depth == 3
    assert res.bvh.leaf_tri.shape == (64,)


@pytest.mark.parametrize("builder", BUILDERS)
@pytest.mark.parametrize("n_tri", [1, 3, 17, 230])
def test_builder_emits_valid_leaf_permutation(builder, n_tri):
    """Every triangle lands in exactly one leaf slot; every occupied slot
    carries that triangle's exact AABB; empty slots are inverted."""
    tri = _soup(np.random.default_rng(n_tri), n_tri)
    bvh = build(tri, builder).bvh
    leaf = np.asarray(bvh.leaf_tri)
    occ = leaf[leaf >= 0]
    assert sorted(occ.tolist()) == list(range(n_tri))
    depth = depth_of(bvh)
    lo = np.asarray(bvh.node_lo[level_offset(depth):])
    hi = np.asarray(bvh.node_hi[level_offset(depth):])
    v = np.stack([np.asarray(tri.a), np.asarray(tri.b), np.asarray(tri.c)], 1)
    for slot, t in enumerate(leaf):
        if t < 0:
            assert np.all(lo[slot] == np.inf) and np.all(hi[slot] == -np.inf)
        else:
            np.testing.assert_array_equal(lo[slot], v[t].min(0))
            np.testing.assert_array_equal(hi[slot], v[t].max(0))


@pytest.mark.parametrize("builder", BUILDERS)
def test_internal_nodes_are_union_of_children(builder):
    tri = clustered_soup(np.random.default_rng(5))
    bvh = build(tri, builder).bvh
    depth = depth_of(bvh)
    for node in range(level_offset(depth)):  # every internal node
        cb = child_boxes(bvh, jnp.int32(node))
        np.testing.assert_array_equal(
            np.asarray(bvh.node_lo[node]), np.asarray(cb.lo).min(0))
        np.testing.assert_array_equal(
            np.asarray(bvh.node_hi[node]), np.asarray(cb.hi).max(0))


@pytest.mark.parametrize("builder", BUILDERS)
def test_builder_culls_degenerate_triangles(builder):
    """Zero-area triangles become padded leaves for every builder (the
    FMA-residue hazard, tests/test_degenerate.py) — no engine can hit
    them."""
    rng = np.random.default_rng(3)
    tri = _soup(rng, 20)
    a = np.asarray(tri.a).copy()
    b = np.asarray(tri.b).copy()
    c = np.asarray(tri.c).copy()
    b[4] = c[4] = a[4]  # point triangle
    b[11] = a[11] + [1, 0, 0]  # exactly colinear (axis-aligned offsets
    c[11] = a[11] + [2, 0, 0]  # are exact in f32)
    tri = Triangle(jnp.asarray(a), jnp.asarray(b), jnp.asarray(c))
    bvh = build(tri, builder).bvh
    leaf = np.asarray(bvh.leaf_tri)
    assert 4 not in leaf and 11 not in leaf
    rec = trace_rays(bvh, _rays(rng, 64), build(tri, builder).depth)
    assert 4 not in np.asarray(rec.tri_index)
    assert 11 not in np.asarray(rec.tri_index)


# ---------------------------------------------------------------------------
# cross-builder x backend parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("builder", BUILDERS)
@pytest.mark.parametrize("backend,ray_type", [
    ("per_ray", "closest"),
    ("wavefront", "closest"),
    ("wavefront", "any"),
    ("wavefront", "shadow"),
])
def test_every_backend_bitmatches_oracle_per_builder(builder, backend,
                                                     ray_type):
    """Each registered builder x each trace backend bit-matches the free-
    function oracle on that builder's own tree, job counters included."""
    rng = np.random.default_rng(7)
    scene = Scene.from_triangles(_soup(rng, 230), builder=builder)
    rays = _rays(rng, 64)
    got = scene.engine(pad_multiple=16).trace(rays, ray_type=ray_type,
                                              backend=backend)
    if backend == "per_ray":
        ref = trace_rays(scene.bvh, rays, scene.depth)
    else:
        ref = trace_wavefront(scene.bvh, rays, scene.depth,
                              ray_type=ray_type)
    _assert_trace_equal(got, ref, msg=f"{builder}/{backend}/{ray_type}: ")


@pytest.mark.parametrize("n_tri", [3, 230])
def test_closest_hit_agrees_across_builders(n_tri):
    """t / tri_index / hit are tree-independent on non-tie scenes: t is a
    pure function of (ray, triangle), whichever tree found it."""
    rng = np.random.default_rng(11)
    tri = _soup(rng, n_tri)
    # aim at the triangles themselves so tiny scenes still produce hits
    ctr = np.asarray((tri.a + tri.b + tri.c) / 3.0)
    org = rng.uniform(-3, -2, (96, 3)).astype(np.float32)
    tgt = (ctr[rng.integers(0, n_tri, 96)]
           + rng.normal(scale=0.05, size=(96, 3))).astype(np.float32)
    rays = make_ray(jnp.asarray(org), jnp.asarray(tgt - org))
    recs = [Scene.from_triangles(tri, builder=b).engine(
        pad_multiple=16).trace(rays) for b in BUILDERS]
    _assert_trace_equal(recs[1], recs[0], fields=("t", "tri_index", "hit"),
                        msg="sah vs lbvh: ")
    assert int(recs[0].hit.sum()) > 0  # the parity isn't vacuous


# ---------------------------------------------------------------------------
# refit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("builder", BUILDERS)
def test_refit_same_triangles_is_bit_identical(builder):
    tri = _soup(np.random.default_rng(13), 100)
    bvh = build(tri, builder).bvh
    re = refit(bvh, tri)
    np.testing.assert_array_equal(np.asarray(re.node_lo),
                                  np.asarray(bvh.node_lo))
    np.testing.assert_array_equal(np.asarray(re.node_hi),
                                  np.asarray(bvh.node_hi))
    np.testing.assert_array_equal(np.asarray(re.leaf_tri),
                                  np.asarray(bvh.leaf_tri))


@pytest.mark.parametrize("builder", BUILDERS)
def test_refit_after_motion_bounds_every_triangle(builder):
    """After a non-rigid deformation, every node box still bounds every
    descendant triangle (exactly fitted, not just containing)."""
    rng = np.random.default_rng(17)
    tri = _soup(rng, 100)
    bvh = build(tri, builder).bvh
    warp = lambda v: v + 0.3 * np.sin(np.asarray(v) * 3.0).astype(np.float32)
    moved = Triangle(jnp.asarray(warp(tri.a)), jnp.asarray(warp(tri.b)),
                     jnp.asarray(warp(tri.c)))
    re = refit(bvh, moved)
    np.testing.assert_array_equal(np.asarray(re.leaf_tri),
                                  np.asarray(bvh.leaf_tri))
    depth = depth_of(re)
    v = np.stack([warp(tri.a), warp(tri.b), warp(tri.c)], 1)
    lo = np.asarray(re.node_lo)
    hi = np.asarray(re.node_hi)
    leaf = np.asarray(re.leaf_tri)
    # walk each occupied leaf's ancestor chain up to the root
    for slot in np.nonzero(leaf >= 0)[0]:
        tlo, thi = v[leaf[slot]].min(0), v[leaf[slot]].max(0)
        node = level_offset(depth) + int(slot)
        while node > 0:
            node = (node - 1) // 4
            assert np.all(lo[node] <= tlo) and np.all(hi[node] >= thi)


@pytest.mark.parametrize("builder", BUILDERS)
def test_refit_reevaluates_degenerate_cull(builder):
    """The cull is frame-accurate in both directions: a triangle that
    collapses under motion disappears (exactly as a rebuild would cull
    it), and one that was degenerate at build time reappears the moment
    motion gives it area — the pre-cull slot assignment (leaf_perm)
    carried by the BVH4 makes re-culling possible."""
    rng = np.random.default_rng(47)
    tri = _soup(rng, 20)
    a = np.asarray(tri.a).copy()
    b = np.asarray(tri.b).copy()
    c = np.asarray(tri.c).copy()
    b[4] = c[4] = a[4]  # degenerate at build
    built = build(Triangle(jnp.asarray(a), jnp.asarray(b), jnp.asarray(c)),
                  builder).bvh
    assert 4 not in np.asarray(built.leaf_tri)
    assert 4 in np.asarray(built.leaf_perm)  # ...but its slot is reserved
    # motion un-collapses triangle 4 and collapses triangle 9
    b2, c2 = b.copy(), c.copy()
    b2[4] = a[4] + [0.3, 0, 0]
    c2[4] = a[4] + [0, 0.3, 0]
    b2[9] = c2[9] = a[9]
    moved = Triangle(jnp.asarray(a), jnp.asarray(b2), jnp.asarray(c2))
    re = refit(built, moved)
    leaf = np.asarray(re.leaf_tri)
    assert 4 in leaf and 9 not in leaf
    # and the refit tree's hits still bit-match a fresh rebuild's
    rays = _rays(rng, 64)
    rebuilt = build(moved, builder)
    got = trace_rays(re, rays, rebuilt.depth)
    ref = trace_rays(rebuilt.bvh, rays, rebuilt.depth)
    _assert_trace_equal(got, ref, fields=("t", "tri_index", "hit"),
                        msg=f"{builder} re-cull: ")


def test_scene_refit_validation():
    rng = np.random.default_rng(19)
    tri = _soup(rng, 50)
    scene = Scene.from_triangles(tri)
    with pytest.raises(ValueError, match="50 triangles"):
        scene.refit(_soup(rng, 49))
    bad = np.stack([np.asarray(tri.a), np.asarray(tri.b),
                    np.asarray(tri.c)], 1)
    bad[7, 1, 2] = np.nan
    with pytest.raises(ValueError, match="finite"):
        scene.refit(bad)
    assert scene.version == 0  # failed refits don't bump the version
    scene.refit(tri)
    assert scene.version == 1


@pytest.mark.parametrize("builder", BUILDERS)
def test_animated_refit_zero_retrace_and_rebuild_parity(builder):
    """The acceptance contract for dynamic scenes: >= 3 animation frames
    through ``Scene.refit`` trigger ZERO retraces after the first compile,
    and every refit frame's trace bit-matches a from-scratch rebuild's
    hits on the same topology (t / tri_index / hit; job counters are
    tree-dependent and may differ)."""
    rng = np.random.default_rng(23)
    tri = _soup(rng, 120)
    rays = _rays(rng, 64)
    scene = Scene.from_triangles(tri, builder=builder)
    engine = scene.engine(pad_multiple=16, shard=1)

    def frame(k):
        dt = np.float32(0.05 * k)
        shift = jnp.asarray(
            np.stack([np.sin(3.0 * np.asarray(tri.a[:, 0])) * dt,
                      np.zeros(tri.a.shape[0], np.float32),
                      np.cos(2.0 * np.asarray(tri.a[:, 2])) * dt], 1))
        return Triangle(tri.a + shift, tri.b + shift, tri.c + shift)

    engine.trace(rays)  # frame 0: compiles the trace
    scene.refit(frame(1))  # first refit: compiles the refit sweep
    engine.trace(rays)
    frames = []
    with CompileTracker() as tracker:
        for k in range(2, 5):  # three more animation frames
            scene.refit(frame(k))
            frames.append((k, engine.trace(rays)))
    assert tracker.compiles == 0, "animated refit frames retraced"
    assert engine.cache_info().misses == 1  # one compiled trace, reused
    for k, rec in frames:
        rebuilt = Scene.from_triangles(frame(k), builder=builder)
        ref = rebuilt.engine(pad_multiple=16, shard=1).trace(rays)
        _assert_trace_equal(rec, ref, fields=("t", "tri_index", "hit"),
                            msg=f"frame {k}: ")
        assert int(rec.hit.sum()) > 0


def test_sharded_refit_sees_new_boxes_8dev(multidev):
    """Refit bumps ``Scene.version``, so a sharded engine re-places (not
    re-compiles) its replicated BVH copy: post-refit sharded traces
    bit-match the single-device engine on the *current* geometry."""
    multidev("""
import numpy as np, jax, jax.numpy as jnp
assert jax.local_device_count() == 8
from repro.api import Scene, make_ray
from repro.core import Triangle
rng = np.random.default_rng(0)
ctr = rng.uniform(-1, 1, (120, 3)).astype(np.float32)
d1 = rng.normal(scale=0.15, size=(120, 3)).astype(np.float32)
d2 = rng.normal(scale=0.15, size=(120, 3)).astype(np.float32)
tri = Triangle(jnp.asarray(ctr), jnp.asarray(ctr + d1), jnp.asarray(ctr + d2))
org = rng.uniform(-3, -2, (64, 3)).astype(np.float32)
tgt = rng.uniform(-0.5, 0.5, (64, 3)).astype(np.float32)
rays = make_ray(jnp.asarray(org), jnp.asarray(tgt - org))
scene = Scene.from_triangles(tri, builder="sah")
sharded = scene.engine(shard=8, pad_multiple=8)
single = scene.engine(shard=1, pad_multiple=8)
sharded.trace(rays, backend="wavefront"); single.trace(rays)
moved = Triangle(tri.a + 0.1, tri.b + 0.1, tri.c + 0.1)
scene.refit(moved)
a = sharded.trace(rays, backend="wavefront")
b = single.trace(rays)
for f in ("t", "tri_index", "hit", "quadbox_jobs", "triangle_jobs"):
    np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                  np.asarray(getattr(b, f)), err_msg=f)
scene.refit(tri)  # move back: a stale replica would keep the old boxes
c = sharded.trace(rays, backend="wavefront")
np.testing.assert_array_equal(np.asarray(c.t),
                              np.asarray(single.trace(rays).t))
assert not np.array_equal(np.asarray(c.t), np.asarray(a.t))
assert sharded.cache_info().misses == 1  # re-placed, never re-compiled
print("sharded refit parity OK")
""", n_devices=8)


# ---------------------------------------------------------------------------
# from_triangles validation (satellite bugfix: non-finite vertices)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bad_value", [np.nan, np.inf, -np.inf])
def test_from_triangles_rejects_nonfinite(bad_value):
    rng = np.random.default_rng(29)
    tri = _soup(rng, 30)
    verts = np.stack([np.asarray(tri.a), np.asarray(tri.b),
                      np.asarray(tri.c)], 1)
    verts[11, 2, 0] = bad_value
    for builder in BUILDERS:
        with pytest.raises(ValueError, match="finite"):
            Scene.from_triangles(verts, builder=builder)
    Scene.from_triangles(np.nan_to_num(verts, posinf=0.0, neginf=0.0))


def test_builders_stay_jittable():
    """Validation is eager-only: the registered builders and refit still
    trace under jit (the whole point of static-depth construction)."""
    tri = _soup(np.random.default_rng(31), 20)
    for builder in BUILDERS:
        fn = jax.jit(lambda t, b=builder: build(t, b, depth=3).bvh)
        bvh = fn(tri)
        ref = build(tri, builder, depth=3).bvh
        np.testing.assert_array_equal(np.asarray(bvh.leaf_tri),
                                      np.asarray(ref.leaf_tri))
        np.testing.assert_array_equal(np.asarray(bvh.node_lo),
                                      np.asarray(ref.node_lo))


# ---------------------------------------------------------------------------
# tree quality: stats + the SAH-beats-LBVH margin on clustered scenes
# ---------------------------------------------------------------------------


def test_scene_stats_reports_quality_metrics():
    rng = np.random.default_rng(37)
    scene = Scene.from_triangles(_soup(rng, 230), builder="sah")
    st = scene.stats()
    assert st.builder == "sah"
    assert st.n_triangles == 230 and st.depth == 4
    assert st.n_leaves == 256 and st.n_nodes == 341
    assert st.occupancy == pytest.approx(230 / 256)
    assert st.sah_cost > 1.0  # root contributes 1 by definition
    assert st.mean_jobs == st.mean_quadbox_jobs + st.mean_triangle_jobs
    assert st.mean_quadbox_jobs >= 1.0  # every probe enters the root
    # a caller-supplied ray batch is honored
    st2 = scene.stats(rays=_rays(rng, 32))
    assert st2.mean_jobs > 0


def test_sah_beats_lbvh_on_clustered_scene():
    """The reason the subsystem exists: on a non-uniform soup the binned-
    SAH tree must cost measurably fewer datapath jobs per ray than the
    Morton tree — by the model (SAH cost) and by the measurement (mean
    quadbox + triangle jobs on the same probe batch)."""
    rng = np.random.default_rng(41)
    tri = clustered_soup(rng)
    rays = _rays(np.random.default_rng(43), 256, lo=-4.0, hi=4.0)
    stats = {b: Scene.from_triangles(tri, builder=b).stats(rays=rays)
             for b in BUILDERS}
    assert stats["sah"].sah_cost < stats["lbvh"].sah_cost
    # measured: at least 10% fewer jobs/ray (in practice far more)
    assert stats["sah"].mean_jobs < 0.9 * stats["lbvh"].mean_jobs
    assert sah_cost(build(tri, "sah").bvh) == pytest.approx(
        stats["sah"].sah_cost)
    assert tree_stats(build(tri, "lbvh").bvh, "lbvh",
                      rays=rays).mean_jobs == stats["lbvh"].mean_jobs


def test_scene_stats_config_fields_pinned():
    """`Scene.stats()` carries the per-config fields the sweep harness
    depends on: arity, bytes/node, compression ratio and the measured
    mean branching factor — with exactly the pinned values for each
    datapath twin (schema drift here breaks `bench_sweep.py` rows)."""
    from repro.core.bvh import DatapathConfig

    rng = np.random.default_rng(53)
    tri = _soup(rng, 230)

    st4 = Scene.from_triangles(tri, builder="lbvh").stats()
    assert st4.arity == 4
    assert st4.bytes_per_node == 24  # 2 corners x 3 f32
    assert st4.compression_ratio == pytest.approx(1.0)
    assert 1.0 <= st4.mean_branching_factor <= 4.0

    cfg8 = DatapathConfig(arity=8, precision="bf16",
                          node_format="compressed")
    st8 = Scene.from_triangles(tri, builder="lbvh", config=cfg8).stats()
    assert st8.arity == 8
    assert st8.bytes_per_node == 6  # u8 grid + bf16 anchors, amortized
    assert st8.compression_ratio == pytest.approx(4.0)
    assert 1.0 <= st8.mean_branching_factor <= 8.0
    # a complete 8-ary tree of the same soup is shallower, not smaller
    assert st8.depth < st4.depth
    assert st8.n_leaves >= st4.n_triangles

    # field NAMES are part of the schema: bench rows index by keyword
    for f in ("arity", "bytes_per_node", "compression_ratio",
              "mean_branching_factor"):
        assert f in type(st4)._fields
