"""Training substrate: convergence, grad-accum equivalence, schedules."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.data import SyntheticLM
from repro.models import init_params
from repro.optim import adamw
from repro.parallel.ctx import NO_PARALLEL as ctx
from repro.train import make_train_step


def test_training_reduces_loss():
    cfg = get_smoke("smollm-360m")
    data = SyntheticLM(cfg.vocab_size, batch=8, seq_len=32, seed=0)
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw.init(params)
    ocfg = adamw.AdamWConfig(lr=2e-3, warmup_steps=5, total_steps=60)
    step = jax.jit(make_train_step(cfg, ctx, ocfg))
    losses = []
    for i, batch in zip(range(60), data):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    # fresh batches each step: the n-gram structure is learnable, so the
    # loss must move visibly below its start within 60 steps
    assert min(losses[-10:]) < losses[0] - 0.4, (losses[0], losses[-10:])
    assert np.isfinite(losses).all()


def test_grad_accum_matches_full_batch():
    """Mean of microbatch grads == full-batch grad (same loss, same grads).

    Params after one Adam step are NOT compared: at step 1 Adam's update is
    sign(g)*lr, so f32 summation-order noise on near-zero grads flips signs
    — gradient equality is the meaningful invariant.
    """
    from repro.train import make_loss_fn
    cfg = get_smoke("chatglm3-6b")
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 16)), jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    params = init_params(jax.random.PRNGKey(1), cfg)
    loss_fn = make_loss_fn(cfg, ctx)
    (l_full, _), g_full = jax.value_and_grad(loss_fn, has_aux=True)(
        params, batch)
    g_acc = None
    l_acc = 0.0
    for i in range(4):
        mb = {k: v[2 * i:2 * i + 2] for k, v in batch.items()}
        (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
        l_acc += float(l) / 4
        g_acc = g if g_acc is None else jax.tree.map(jnp.add, g_acc, g)
    g_acc = jax.tree.map(lambda x: x / 4, g_acc)
    assert abs(float(l_full) - l_acc) < 2e-3
    flat_f = jax.tree.leaves(g_full)
    flat_a = jax.tree.leaves(g_acc)
    # relative error on the overall gradient vector
    num = sum(float(jnp.sum((a - b) ** 2)) for a, b in zip(flat_f, flat_a))
    den = sum(float(jnp.sum(b ** 2)) for b in flat_f)
    assert (num / max(den, 1e-20)) ** 0.5 < 5e-3


def test_adamw_schedule():
    ocfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110,
                             min_lr_frac=0.1)
    assert float(adamw.schedule(ocfg, jnp.int32(0))) == 0.0
    assert abs(float(adamw.schedule(ocfg, jnp.int32(10))) - 1.0) < 1e-6
    assert abs(float(adamw.schedule(ocfg, jnp.int32(110))) - 0.1) < 1e-6
    mid = float(adamw.schedule(ocfg, jnp.int32(60)))
    assert 0.1 < mid < 1.0


def test_clipping_bounds_update():
    cfg = get_smoke("smollm-360m")
    params = init_params(jax.random.PRNGKey(0), cfg)
    grads = jax.tree.map(lambda p: jnp.full(p.shape, 100.0), params)
    opt = adamw.init(params)
    ocfg = adamw.AdamWConfig(lr=1e-2, clip_norm=1.0, warmup_steps=0,
                             total_steps=10)
    _, _, stats = adamw.update(ocfg, grads, opt, params)
    assert float(stats["grad_norm"]) > 1.0  # raw norm measured pre-clip


def test_bf16_moments_roundtrip():
    cfg = get_smoke("smollm-360m")
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw.init(params, "bfloat16")
    assert all(x.dtype == jnp.bfloat16 for x in jax.tree.leaves(opt.m))
    grads = jax.tree.map(lambda p: jnp.ones(p.shape, jnp.float32) * 1e-3, params)
    ocfg = adamw.AdamWConfig(moments_dtype="bfloat16", warmup_steps=0,
                             total_steps=10)
    p2, opt2, _ = adamw.update(ocfg, grads, opt, params)
    assert all(x.dtype == jnp.bfloat16 for x in jax.tree.leaves(opt2.m))
    assert all(np.isfinite(np.asarray(x, np.float32)).all()
               for x in jax.tree.leaves(p2))
