"""Wavefront traversal engine vs the per-ray oracle and brute force.

``trace_rays`` (per-ray while_loop) is the semantic oracle: the wavefront
engine must *bit-match* it on closest-hit queries, including the per-ray
job counters, so traversal optimizations stay measured rather than guessed.
The brute-force all-triangles oracle pins both engines to the geometry.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Ray, Triangle, build_bvh4, bvh4_depth, make_ray,
                        occlusion_test, ray_triangle_test, trace_rays,
                        trace_wavefront)


def _soup(rng, n_tri, scale=0.15):
    ctr = rng.uniform(-1, 1, (n_tri, 3)).astype(np.float32)
    d1 = rng.normal(scale=scale, size=(n_tri, 3)).astype(np.float32)
    d2 = rng.normal(scale=scale, size=(n_tri, 3)).astype(np.float32)
    return Triangle(a=jnp.asarray(ctr), b=jnp.asarray(ctr + d1),
                    c=jnp.asarray(ctr + d2))


def _rays(rng, n, extent=None):
    org = rng.uniform(-3, -2, (n, 3)).astype(np.float32)
    tgt = rng.uniform(-0.5, 0.5, (n, 3)).astype(np.float32)
    return make_ray(jnp.asarray(org), jnp.asarray(tgt - org), extent)


def _cross(rays, n_tri):
    """(R,) rays x (N,) triangles -> (R, N) batched operands."""
    n_rays = rays.origin.shape[0]
    ray_b = Ray(*[jnp.broadcast_to(f[:, None, ...],
                                   (n_rays, n_tri) + f.shape[1:])
                  for f in rays])
    return ray_b


def brute_force(tri, rays, t_min=0.0):
    """Test every ray against every triangle: (t, tri_index, any_valid)."""
    n_rays, n_tri = rays.origin.shape[0], tri.a.shape[0]
    tri_b = Triangle(*[jnp.broadcast_to(f[None], (n_rays, n_tri, 3))
                       for f in tri])
    tr = ray_triangle_test(_cross(rays, n_tri), tri_b)
    t = np.asarray(tr.t_num) / np.asarray(tr.t_denom)
    valid = (np.asarray(tr.hit) & (t <= np.asarray(rays.extent)[:, None])
             & (t >= t_min))
    t_masked = np.where(valid, t, np.inf)
    best = t_masked.argmin(1)
    t_best = t_masked[np.arange(n_rays), best]
    return (t_best, np.where(np.isfinite(t_best), best, -1), valid.any(1))


def _scene_and_rays(seed, n_tri, n_rays):
    rng = np.random.default_rng(seed)
    tri = _soup(rng, n_tri)
    return tri, build_bvh4(tri), bvh4_depth(n_tri), _rays(rng, n_rays)


# 230/100/513 leave 26/28/511 padded leaves; 3 makes the root a leaf parent.
SCENES = [(7, 230, 64), (11, 100, 64), (13, 513, 48), (17, 3, 32)]


@pytest.mark.parametrize("seed,n_tri,n_rays", SCENES)
def test_closest_hit_bitmatches_per_ray_engine(seed, n_tri, n_rays):
    tri, bvh, depth, rays = _scene_and_rays(seed, n_tri, n_rays)
    ref = trace_rays(bvh, rays, depth)
    got = trace_wavefront(bvh, rays, depth)
    np.testing.assert_array_equal(np.asarray(got.t), np.asarray(ref.t))
    np.testing.assert_array_equal(np.asarray(got.tri_index),
                                  np.asarray(ref.tri_index))
    np.testing.assert_array_equal(np.asarray(got.hit), np.asarray(ref.hit))


@pytest.mark.parametrize("seed,n_tri,n_rays", SCENES[:3])
def test_closest_hit_matches_brute_force(seed, n_tri, n_rays):
    tri, bvh, depth, rays = _scene_and_rays(seed, n_tri, n_rays)
    got = trace_wavefront(bvh, rays, depth)
    t_ref, _, any_ref = brute_force(tri, rays)
    # same stage math, but XLA may fuse mul+add into FMA differently across
    # the two compilations, so the oracle comparison is ulp-tolerant (the
    # engine-vs-engine comparison above stays bit-exact)
    np.testing.assert_array_equal(np.asarray(got.hit), any_ref)
    both = np.isfinite(t_ref)
    np.testing.assert_allclose(np.asarray(got.t)[both], t_ref[both],
                               rtol=1e-6)
    assert np.asarray(got.hit).sum() > 0  # scene actually hit


def test_degenerate_nan_slab_rays():
    """Axis-aligned rays whose origins lie exactly on box planes produce
    0 * inf = NaN slabs; comparator semantics must ignore them."""
    # grid-aligned right triangles: box planes land on exact ray coordinates
    xs, ys = np.meshgrid(np.arange(4, dtype=np.float32),
                         np.arange(4, dtype=np.float32))
    a = np.stack([xs.ravel(), ys.ravel(), np.zeros(16, np.float32)], -1)
    b = a + np.asarray([1, 0, 0], np.float32)
    c = a + np.asarray([0, 1, 0], np.float32)
    tri = Triangle(jnp.asarray(a), jnp.asarray(c), jnp.asarray(b))
    bvh = build_bvh4(tri)
    depth = bvh4_depth(16)
    # origins exactly on the lattice (slab distance 0 * inf), incl. -0.0 dir
    org = np.asarray([[0.0, 0.0, -2.0], [1.0, 1.0, -2.0], [2.0, 0.5, -2.0],
                      [0.5, 3.0, -2.0], [3.0, 3.0, -2.0]], np.float32)
    dirs = np.asarray([[0, 0, 1], [0, 0, 1], [0.0, -0.0, 1],
                       [-0.0, 0.0, 1], [0, 0, 1]], np.float32)
    rays = make_ray(jnp.asarray(org), jnp.asarray(dirs))
    ref = trace_rays(bvh, rays, depth)
    got = trace_wavefront(bvh, rays, depth)
    np.testing.assert_array_equal(np.asarray(got.t), np.asarray(ref.t))
    np.testing.assert_array_equal(np.asarray(got.tri_index),
                                  np.asarray(ref.tri_index))
    # and both engines against the all-triangles oracle on the NaN slabs
    t_ref, _, any_ref = brute_force(tri, rays)
    np.testing.assert_array_equal(np.asarray(got.hit), any_ref)
    both = np.isfinite(t_ref)
    assert both.any()  # the grid-aligned rays really do hit
    np.testing.assert_allclose(np.asarray(got.t)[both], t_ref[both],
                               rtol=1e-6)


@pytest.mark.parametrize("seed,n_tri,n_rays", SCENES[:3])
def test_any_hit_agrees_with_closest(seed, n_tri, n_rays):
    tri, bvh, depth, rays = _scene_and_rays(seed, n_tri, n_rays)
    closest = trace_wavefront(bvh, rays, depth, ray_type="closest")
    anyhit = trace_wavefront(bvh, rays, depth, ray_type="any")
    # same reachable-hit decision, potentially different (earlier) retirement
    np.testing.assert_array_equal(np.asarray(anyhit.hit),
                                  np.asarray(closest.hit))
    h = np.asarray(anyhit.hit)
    # any-hit's t is *some* accepted hit: never closer than the closest one
    assert (np.asarray(anyhit.t)[h] >= np.asarray(closest.t)[h]).all()
    # early termination can only reduce work
    assert (np.asarray(anyhit.quadbox_jobs)
            <= np.asarray(closest.quadbox_jobs)).all()


def test_shadow_rays_extent_limited():
    """Occlusion within extent must match the brute-force oracle, and
    shrinking the extent below the first hit must clear the occlusion."""
    tri, bvh, depth, rays = _scene_and_rays(23, 230, 64)
    closest = trace_wavefront(bvh, rays, depth)
    t_hit = np.where(np.asarray(closest.hit), np.asarray(closest.t), 1.0)

    for scale, expect_hit in ((1.5, True), (0.5, False)):
        limited = make_ray(rays.origin, rays.direction,
                           extent=jnp.asarray(scale * t_hit))
        occ = np.asarray(occlusion_test(bvh, limited, depth, t_min=0.0))
        _, _, oracle = brute_force(tri, limited)
        np.testing.assert_array_equal(occ, oracle)
        h = np.asarray(closest.hit)
        if expect_hit:
            assert occ[h].all()
        else:
            assert not occ[h].any()

    # t_min skips hits at the near end (self-intersection epsilon): with the
    # cutoff between a ray's first and last hit, agreement with the
    # brute-force oracle proves near hits are dropped and far ones kept
    t_med = float(np.median(np.asarray(closest.t)[np.asarray(closest.hit)]))
    shadow = trace_wavefront(bvh, rays, depth, ray_type="shadow",
                             t_min=t_med)
    _, _, oracle = brute_force(tri, rays, t_min=t_med)
    np.testing.assert_array_equal(np.asarray(shadow.hit), oracle)
    h = np.asarray(closest.hit)
    assert (np.asarray(shadow.t)[np.asarray(shadow.hit)] >= t_med).all()
    # the cutoff really bites: some rays lose their only hit
    assert oracle[h].sum() < h.sum()


@pytest.mark.parametrize("seed,n_tri,n_rays", SCENES)
def test_job_accounting_consistent_between_engines(seed, n_tri, n_rays):
    """quadbox/triangle job counters must agree exactly, so future traversal
    optimizations are measured against a trusted baseline."""
    _, bvh, depth, rays = _scene_and_rays(seed, n_tri, n_rays)
    ref = trace_rays(bvh, rays, depth)
    got = trace_wavefront(bvh, rays, depth)
    np.testing.assert_array_equal(np.asarray(got.quadbox_jobs),
                                  np.asarray(ref.quadbox_jobs))
    np.testing.assert_array_equal(np.asarray(got.triangle_jobs),
                                  np.asarray(ref.triangle_jobs))
    # a ray is active for exactly quadbox_jobs consecutive rounds from round
    # 0, so the batch-level round count is the max per-ray job count
    assert int(got.rounds) == int(np.asarray(ref.quadbox_jobs).max())


def test_empty_frontier_early_exit():
    """Rays that miss the scene entirely drain after the root round; the
    loop must stop there instead of running out the fixed bound."""
    tri, bvh, depth, _ = _scene_and_rays(29, 230, 8)
    org = np.tile(np.asarray([[50.0, 50.0, 50.0]], np.float32), (8, 1))
    dirs = np.tile(np.asarray([[1.0, 0.0, 0.0]], np.float32), (8, 1))
    rays = make_ray(jnp.asarray(org), jnp.asarray(dirs))
    rec = trace_wavefront(bvh, rays, depth)
    assert not np.asarray(rec.hit).any()
    assert int(rec.rounds) == 1  # root popped once, frontier empty
    np.testing.assert_array_equal(np.asarray(rec.quadbox_jobs),
                                  np.ones(8, np.int32))


# ---------------------------------------------------------------------------
# Stack-overflow safety (DatapathConfig.stack_size)
# ---------------------------------------------------------------------------


def test_tiny_stack_flags_overflow_identically():
    """Pushing past a tiny stack must *drop the push and raise the per-ray
    ``stack_overflow`` flag* — never silently clobber a slot — and every
    engine must implement the identical drop-and-flag semantics, so the
    wavefront record stays bit-equal to the per-ray oracle even while
    overflowing.  (Regression: overflow used to overwrite the top stack
    slot with no signal at all.)"""
    from repro.core.bvh import DatapathConfig
    from repro.core.build import build

    rng = np.random.default_rng(23)
    tri = _soup(rng, 230)
    cfg = DatapathConfig(stack_size=2)  # depth-4 tree: guaranteed too small
    res = build(tri, "lbvh", config=cfg)
    rays = _rays(rng, 64)

    ref = trace_rays(res.bvh, rays, res.depth, cfg)
    got = trace_wavefront(res.bvh, rays, res.depth, config=cfg)
    ovf = np.asarray(got.stack_overflow)
    assert ovf.dtype == np.bool_ and ovf.shape == (64,)
    assert ovf.any(), "deep scene with stack_size=2 must overflow"
    for f in ("t", "tri_index", "hit", "quadbox_jobs", "triangle_jobs",
              "stack_overflow"):
        np.testing.assert_array_equal(
            np.asarray(getattr(got, f)), np.asarray(getattr(ref, f)),
            err_msg=f"overflowing engines disagree: {f}")

    # overflowing rays degrade gracefully: any hit they do report is a real
    # intersection, so it can never undercut the brute-force closest t
    t_ref, _, _ = brute_force(tri, rays)
    hit = np.asarray(got.hit)
    assert np.all(np.isfinite(t_ref[hit]))
    assert np.all(np.asarray(got.t)[hit] >= t_ref[hit] * (1 - 1e-6))

    # the default config never comes near capacity on this scene: no flag,
    # and the full (unflagged) result set is the brute-force one
    full = trace_wavefront(res.bvh, rays, res.depth)
    assert not np.asarray(full.stack_overflow).any()
