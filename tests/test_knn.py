"""kNN / retrieval on the generalized distance modes vs numpy exact."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cosine_similarity, euclidean_scores, knn
from repro.core.knn import angular_scores


@pytest.mark.parametrize("metric", ["euclidean", "angular", "cosine"])
def test_knn_exact(metric):
    rng = np.random.default_rng(0)
    q = rng.normal(size=(17, 24)).astype(np.float32)
    db = rng.normal(size=(211, 24)).astype(np.float32)
    scores, idx = knn(jnp.asarray(q), jnp.asarray(db), k=5, metric=metric)
    if metric == "euclidean":
        ref = ((q[:, None] - db[None]) ** 2).sum(-1)
        ref_idx = np.argsort(ref, axis=1)[:, :5]
    elif metric == "angular":
        ref = q @ db.T
        ref_idx = np.argsort(-ref, axis=1)[:, :5]
    else:
        ref = (q @ db.T) / (np.linalg.norm(q, axis=1)[:, None]
                            * np.linalg.norm(db, axis=1)[None])
        ref_idx = np.argsort(-ref, axis=1)[:, :5]
    # compare score sets (ties can permute indices)
    got = np.take_along_axis(ref, np.asarray(idx), axis=1)
    want = np.take_along_axis(ref, ref_idx, axis=1)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_mxu_form_equals_beat_form():
    """The MXU expansion ||q||^2 - 2qc + ||c||^2 equals the datapath's
    multi-beat (a-b)^2 accumulation."""
    from repro.core import euclidean_distance_sq
    rng = np.random.default_rng(1)
    q = rng.normal(size=(5, 40)).astype(np.float32)
    c = rng.normal(size=(7, 40)).astype(np.float32)
    mxu = np.asarray(euclidean_scores(jnp.asarray(q), jnp.asarray(c)))
    for i in range(5):
        beat = np.asarray(euclidean_distance_sq(
            jnp.asarray(np.tile(q[i], (7, 1))), jnp.asarray(c)))
        np.testing.assert_allclose(mxu[i], beat, rtol=1e-4, atol=1e-4)


def test_radius_search_matches_numpy():
    """Fixed-radius query: membership and counts vs numpy exact."""
    from repro.core import radius_count, radius_search
    rng = np.random.default_rng(3)
    q = rng.normal(size=(9, 16)).astype(np.float32)
    db = rng.normal(size=(120, 16)).astype(np.float32)
    radius = 5.0
    ref_d = ((q[:, None] - db[None]) ** 2).sum(-1)
    ref_inside = ref_d <= radius ** 2

    counts = np.asarray(radius_count(jnp.asarray(q), jnp.asarray(db), radius))
    np.testing.assert_array_equal(counts, ref_inside.sum(1))

    k = 12
    scores, idx, within = radius_search(jnp.asarray(q), jnp.asarray(db),
                                        radius, k)
    scores, idx, within = (np.asarray(scores), np.asarray(idx),
                           np.asarray(within))
    # every returned in-radius neighbor really is inside, and the valid
    # count per query is min(k, true count)
    for i in range(9):
        got = set(idx[i][within[i]].tolist())
        want = set(np.where(ref_inside[i])[0].tolist())
        assert got <= want
        assert within[i].sum() == min(k, ref_inside[i].sum())
        assert (scores[i][within[i]] <= radius ** 2 + 1e-4).all()


def test_cosine_external_divider():
    """Eq. 8: cosine = dot / (||q|| ||c||) with the datapath outputs."""
    rng = np.random.default_rng(2)
    q = rng.normal(size=(4, 16)).astype(np.float32)
    c = rng.normal(size=(9, 16)).astype(np.float32)
    dots, norms = angular_scores(jnp.asarray(q), jnp.asarray(c))
    cs = np.asarray(dots) / (np.linalg.norm(q, axis=1)[:, None]
                             * np.sqrt(np.asarray(norms))[None])
    np.testing.assert_allclose(
        np.asarray(cosine_similarity(jnp.asarray(q), jnp.asarray(c))), cs,
        rtol=1e-5)
    assert (np.abs(cs) <= 1.0 + 1e-5).all()
