"""kNN / retrieval on the generalized distance modes vs numpy exact."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cosine_similarity, euclidean_scores, knn
from repro.core.knn import angular_scores


@pytest.mark.parametrize("metric", ["euclidean", "angular", "cosine"])
def test_knn_exact(metric):
    rng = np.random.default_rng(0)
    q = rng.normal(size=(17, 24)).astype(np.float32)
    db = rng.normal(size=(211, 24)).astype(np.float32)
    scores, idx = knn(jnp.asarray(q), jnp.asarray(db), k=5, metric=metric)
    if metric == "euclidean":
        ref = ((q[:, None] - db[None]) ** 2).sum(-1)
        ref_idx = np.argsort(ref, axis=1)[:, :5]
    elif metric == "angular":
        ref = q @ db.T
        ref_idx = np.argsort(-ref, axis=1)[:, :5]
    else:
        ref = (q @ db.T) / (np.linalg.norm(q, axis=1)[:, None]
                            * np.linalg.norm(db, axis=1)[None])
        ref_idx = np.argsort(-ref, axis=1)[:, :5]
    # compare score sets (ties can permute indices)
    got = np.take_along_axis(ref, np.asarray(idx), axis=1)
    want = np.take_along_axis(ref, ref_idx, axis=1)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_mxu_form_equals_beat_form():
    """The MXU expansion ||q||^2 - 2qc + ||c||^2 equals the datapath's
    multi-beat (a-b)^2 accumulation."""
    from repro.core import euclidean_distance_sq
    rng = np.random.default_rng(1)
    q = rng.normal(size=(5, 40)).astype(np.float32)
    c = rng.normal(size=(7, 40)).astype(np.float32)
    mxu = np.asarray(euclidean_scores(jnp.asarray(q), jnp.asarray(c)))
    for i in range(5):
        beat = np.asarray(euclidean_distance_sq(
            jnp.asarray(np.tile(q[i], (7, 1))), jnp.asarray(c)))
        np.testing.assert_allclose(mxu[i], beat, rtol=1e-4, atol=1e-4)


def test_cosine_external_divider():
    """Eq. 8: cosine = dot / (||q|| ||c||) with the datapath outputs."""
    rng = np.random.default_rng(2)
    q = rng.normal(size=(4, 16)).astype(np.float32)
    c = rng.normal(size=(9, 16)).astype(np.float32)
    dots, norms = angular_scores(jnp.asarray(q), jnp.asarray(c))
    cs = np.asarray(dots) / (np.linalg.norm(q, axis=1)[:, None]
                             * np.sqrt(np.asarray(norms))[None])
    np.testing.assert_allclose(
        np.asarray(cosine_similarity(jnp.asarray(q), jnp.asarray(c))), cs,
        rtol=1e-5)
    assert (np.abs(cs) <= 1.0 + 1e-5).all()
