"""Telemetry-plane contracts (``repro.obs``, DESIGN.md §11).

What this file pins, in rough order of importance:

* **Disabled is invisible.**  The default registry ships disabled;
  instruments mutate nothing while it is off, and engine results are
  bit-identical with telemetry on vs off — on one device and on a forced
  8-device mesh.
* **Registry semantics.**  Same name -> same instrument object;
  counters/gauges/histograms count what they are told; ``reset`` zeroes
  in place without invalidating held references.
* **Histogram resolution.**  ``percentile(q)`` is within one log2 bucket
  (a factor of 2) of the true order statistic and clamped to the
  observed [min, max].
* **Compile tracking.**  ``CompileTracker`` reads 0 over a warm function
  and > 0 over a fresh tracing.
* **Engine metrics.**  Cache hits/misses, real vs padded rows (pad
  waste), chunk fan-out, and per-backend job counters match values
  computable by hand from the plan.
* **Serving back-compat.**  ``stats()`` still returns the pre-telemetry
  ``ServerStats`` shape (field set pinned), and the server appears as a
  named source in ``obs.snapshot()``.
* **Trace export.**  ``export_chrome_trace`` writes valid JSON in the
  Chrome trace-event format, microsecond-converted, with each request's
  admit -> coalesce -> execute -> split chain internally consistent.
* **Benchmark row schema.**  Census/quality rows carry ``None`` timing
  (JSON ``null``), never a fake ``0.0``.
"""
import gc
import json
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.api import Scene, VectorIndex, make_ray
from repro.core import Triangle
from repro.obs.metrics import HIST_BINS, MetricsRegistry
from repro.obs.trace import TraceBuffer
from repro.serving.query_server import ServerStats


@pytest.fixture
def telemetry():
    """Enable the global plane for one test; restore the prior switch
    (the registry is process-global — tests must measure deltas, not
    absolutes)."""
    reg = obs.registry()
    was = reg.enabled
    obs.enable()
    yield reg
    reg.enabled = was


def _counters():
    return dict(obs.snapshot()["counters"])


def _scene_engine(**kw):
    rng = np.random.default_rng(7)
    ctr = rng.uniform(-1, 1, (80, 3)).astype(np.float32)
    tri = Triangle(
        jnp.asarray(ctr),
        jnp.asarray(ctr + rng.normal(scale=0.1, size=(80, 3)).astype(np.float32)),
        jnp.asarray(ctr + rng.normal(scale=0.1, size=(80, 3)).astype(np.float32)))
    return Scene.from_triangles(tri).engine(**kw)


def _rays(n, seed=1):
    rng = np.random.default_rng(seed)
    org = rng.uniform(-3, -2, (n, 3)).astype(np.float32)
    tgt = rng.uniform(-0.5, 0.5, (n, 3)).astype(np.float32)
    return make_ray(jnp.asarray(org), jnp.asarray(tgt - org))


# ---------------------------------------------------------------------------
# registry semantics
# ---------------------------------------------------------------------------


def test_registry_disabled_is_noop():
    reg = MetricsRegistry()  # disabled is the default
    c, g, h = reg.counter("c"), reg.gauge("g"), reg.histogram("h")
    c.inc()
    c.inc(5)
    g.set(3.5)
    h.observe(1.0)
    assert c.value == 0 and g.value == 0.0 and h.count == 0
    reg.enable()
    c.inc(2)
    g.set(3.5)
    h.observe(1.0)
    assert c.value == 2 and g.value == 3.5 and h.count == 1
    reg.disable()
    c.inc()
    assert c.value == 2  # frozen again


def test_same_name_same_instrument():
    reg = MetricsRegistry(enabled=True)
    assert reg.counter("x") is reg.counter("x")
    assert reg.histogram("x") is reg.histogram("x")
    assert reg.gauge("x") is reg.gauge("x")


def test_reset_preserves_identity():
    reg = MetricsRegistry(enabled=True)
    c, h = reg.counter("c"), reg.histogram("h")
    c.inc(9)
    h.observe(2.0)
    reg.reset()
    assert c is reg.counter("c") and c.value == 0
    assert h.count == 0 and h.buckets == [0] * HIST_BINS
    c.inc()
    assert reg.counter("c").value == 1


def test_registry_snapshot_is_jsonable():
    reg = MetricsRegistry(enabled=True)
    reg.counter("a").inc(3)
    reg.gauge("b").set(1.5)
    reg.histogram("ms").observe(4.2)
    snap = json.loads(json.dumps(reg.snapshot()))
    assert snap["counters"] == {"a": 3}
    assert snap["gauges"] == {"b": 1.5}
    assert snap["histograms"]["ms"]["count"] == 1
    # empty histograms export None, not NaN (NaN is not valid JSON)
    reg.histogram("empty")
    s = reg.snapshot()["histograms"]["empty"]
    assert s["count"] == 0 and s["p50"] is None and s["min"] is None


def test_histogram_percentile_within_bucket_factor():
    reg = MetricsRegistry(enabled=True)
    h = reg.histogram("lat")
    rng = np.random.default_rng(0)
    vals = np.exp(rng.uniform(np.log(1e-3), np.log(1e3), 500))
    for v in vals:
        h.observe(float(v))
    for q in (0.1, 0.5, 0.9, 0.99):
        est, true = h.percentile(q), float(np.quantile(vals, q))
        assert true / 2 <= est <= true * 2, (q, est, true)
        assert h.min <= est <= h.max
    assert h.percentile(0.5) <= h.percentile(0.99)
    assert math.isclose(h.mean(), float(vals.mean()), rel_tol=1e-9)


# ---------------------------------------------------------------------------
# compile tracking
# ---------------------------------------------------------------------------


def test_compile_tracker_counts_fresh_and_warm():
    @jax.jit
    def f(x):
        return x * 2 + 1

    x = jnp.arange(7.0)
    with obs.CompileTracker() as t_cold:
        f(x)
    assert t_cold.available
    assert t_cold.compiles >= 1
    with obs.CompileTracker() as t_warm:
        f(x)
    assert t_warm.compiles == 0
    assert obs.total_compiles() >= t_cold.compiles


# ---------------------------------------------------------------------------
# engine metrics + bit parity
# ---------------------------------------------------------------------------


def test_engine_results_bit_identical_telemetry_on_off(telemetry):
    engine = _scene_engine(pad_multiple=8, shard=1)
    rays = _rays(12)
    obs.disable()
    off = engine.trace(rays)
    obs.enable()
    on = engine.trace(rays)
    obs.disable()
    off2 = engine.trace(rays)
    for field in ("t", "tri_index", "hit", "quadbox_jobs", "triangle_jobs"):
        np.testing.assert_array_equal(
            np.asarray(getattr(off, field)), np.asarray(getattr(on, field)),
            err_msg=field)
        np.testing.assert_array_equal(
            np.asarray(getattr(on, field)), np.asarray(getattr(off2, field)),
            err_msg=field)
    assert int(off.rounds) == int(on.rounds) == int(off2.rounds)


def test_engine_metrics_pinned_against_plan(telemetry):
    rng = np.random.default_rng(3)
    db = rng.normal(size=(64, 16)).astype(np.float32)
    q = rng.normal(size=(12, 16)).astype(np.float32)
    engine = VectorIndex.from_database(jnp.asarray(db)).engine(
        pad_multiple=8, shard=1)
    before = _counters()
    engine.nearest(jnp.asarray(q), 5)
    engine.nearest(jnp.asarray(q), 5)  # second call: cache hit, same plan
    after = _counters()

    def delta(key):
        return after.get(key, 0) - before.get(key, 0)

    # 12 rows pad to one 16-row block: real 12, padded 16, 1 chunk/call
    assert delta("engine.cache.misses") == 1
    assert delta("engine.cache.hits") == 1
    assert delta("engine.rows.real") == 24
    assert delta("engine.rows.padded") == 32
    assert delta("engine.chunks") == 2
    assert delta("engine.calls.nearest.mxu") == 2
    hist = obs.snapshot()["histograms"]["engine.call_ms.nearest"]
    assert hist["count"] >= 2 and hist["min"] >= 0.0

    # snapshot's derived block agrees with its own counters
    snap = obs.snapshot()
    c = snap["counters"]
    real, padded = c["engine.rows.real"], c["engine.rows.padded"]
    assert snap["derived"]["pad_waste_fraction"] == pytest.approx(
        1.0 - real / padded)
    hits, misses = c["engine.cache.hits"], c["engine.cache.misses"]
    assert snap["derived"]["cache_hit_rate"] == pytest.approx(
        hits / (hits + misses))


def test_engine_job_counters_match_result(telemetry):
    engine = _scene_engine(pad_multiple=8, shard=1)
    rays = _rays(10, seed=4)
    before = _counters()
    res = engine.trace(rays, backend="wavefront")
    after = _counters()
    assert (after.get("engine.jobs.quadbox.wavefront", 0)
            - before.get("engine.jobs.quadbox.wavefront", 0)
            ) == int(np.asarray(res.quadbox_jobs).sum())
    assert (after.get("engine.jobs.triangle.wavefront", 0)
            - before.get("engine.jobs.triangle.wavefront", 0)
            ) == int(np.asarray(res.triangle_jobs).sum())


def test_engine_records_nothing_while_disabled():
    assert not obs.is_enabled()  # the process default
    engine = _scene_engine(pad_multiple=8, shard=1)
    before = _counters()
    engine.trace(_rays(9, seed=5))
    after = _counters()
    assert before == after


def test_engine_parity_and_metrics_8dev(multidev):
    multidev("""
import numpy as np, jax, jax.numpy as jnp
from repro import obs
from repro.api import Scene, make_ray
from repro.core import Triangle
rng = np.random.default_rng(11)
ctr = rng.uniform(-1, 1, (90, 3)).astype(np.float32)
tri = Triangle(jnp.asarray(ctr),
               jnp.asarray(ctr + rng.normal(scale=0.1, size=(90, 3)).astype(np.float32)),
               jnp.asarray(ctr + rng.normal(scale=0.1, size=(90, 3)).astype(np.float32)))
engine = Scene.from_triangles(tri).engine(pad_multiple=8, shard=8)
org = rng.uniform(-3, -2, (100, 3)).astype(np.float32)
tgt = rng.uniform(-0.5, 0.5, (100, 3)).astype(np.float32)
rays = make_ray(jnp.asarray(org), jnp.asarray(tgt - org))
off = engine.trace(rays)
obs.enable()
on = engine.trace(rays)
for f in ("t", "tri_index", "hit", "quadbox_jobs", "triangle_jobs"):
    np.testing.assert_array_equal(np.asarray(getattr(off, f)),
                                  np.asarray(getattr(on, f)), err_msg=f)
assert int(off.rounds) == int(on.rounds)
snap = obs.snapshot()
assert snap["gauges"]["engine.shards"] == 8.0, snap["gauges"]
assert snap["counters"]["engine.rows.real"] == 100
assert snap["counters"]["engine.cache.hits"] == 1  # the telemetry-on call
print("8dev telemetry parity OK")
""", n_devices=8)


# ---------------------------------------------------------------------------
# trace spans + Chrome export
# ---------------------------------------------------------------------------


def test_chrome_trace_export_format(tmp_path):
    buf = TraceBuffer(enabled=True)
    buf.record("admit", 1.0, 0.25, tid=42, cat="serving",
               args={"rows": 3})
    buf.record("execute", 1.25, 0.5, tid=42, cat="serving")
    path = tmp_path / "trace.json"
    assert buf.export_chrome_trace(str(path)) == 2
    doc = json.load(open(path))
    assert doc["displayTimeUnit"] == "ms"
    ev = doc["traceEvents"][0]
    assert ev == {"name": "admit", "cat": "serving", "ph": "X",
                  "ts": 1_000_000, "dur": 250_000, "pid": 0, "tid": 42,
                  "args": {"rows": 3}}
    e2 = doc["traceEvents"][1]
    assert e2["ts"] == ev["ts"] + ev["dur"]  # seconds -> integer us


def test_trace_buffer_follows_global_switch(telemetry):
    buf = TraceBuffer()  # enabled=None: follows the default registry
    obs.disable()
    buf.record("x", 0.0, 1.0)
    assert len(buf) == 0
    obs.enable()
    buf.record("x", 0.0, 1.0)
    assert len(buf) == 1


def test_serving_span_chains_consistent(tmp_path, telemetry):
    import asyncio

    from repro.core.session import PointCloudScene
    from repro.serving import QueryServer

    obs.default_buffer().clear()
    rng = np.random.default_rng(0)
    engine = PointCloudScene.from_points(
        jnp.asarray(rng.normal(size=(512, 3)).astype(np.float32))).engine(
            pad_multiple=8, shard=1)

    async def drive():
        async with QueryServer(engine, max_batch_rows=32,
                               max_wait=2e-3) as server:
            qs = [jnp.asarray(rng.normal(size=(3, 3)).astype(np.float32))
                  for _ in range(6)]
            await asyncio.gather(*[server.nearest(q, k=4) for q in qs])
            return server.stats()

    stats = asyncio.run(drive())
    assert stats["nearest"].requests == 6
    path = tmp_path / "trace.json"
    obs.export_chrome_trace(str(path))
    doc = json.load(open(path))
    chains: dict = {}
    for ev in doc["traceEvents"]:
        if ev["cat"] == "serving":
            chains.setdefault(ev["tid"], {})[ev["name"]] = ev
    assert len(chains) == 6
    for tid, evs in chains.items():
        assert set(evs) == {"admit", "coalesce", "execute", "split"}, tid
        # each phase starts no earlier than the previous one ended
        # (1 us slack for integer-microsecond rounding)
        assert evs["admit"]["ts"] <= evs["coalesce"]["ts"] + 1
        assert (evs["coalesce"]["ts"] + evs["coalesce"]["dur"]
                <= evs["execute"]["ts"] + 1)
        assert (evs["execute"]["ts"] + evs["execute"]["dur"]
                <= evs["split"]["ts"] + 1)
        assert all(e["dur"] >= 0 for e in evs.values())
    obs.default_buffer().clear()


# ---------------------------------------------------------------------------
# serving stats back-compat + snapshot sources
# ---------------------------------------------------------------------------


def test_server_stats_shape_pinned():
    """The pre-telemetry ``stats()`` surface: exact field set, in order.
    Extending is fine — renames/removals break bench_serving and every
    stats() consumer, so they must show up here first."""
    assert ServerStats._fields == (
        "requests", "rows", "batches", "queue_depth", "requests_per_batch",
        "mean_batch_rows", "mean_fill", "flush_full", "flush_timer",
        "flush_deadline", "flush_drain", "shed", "p50_ms", "p99_ms")


def test_server_counts_with_global_telemetry_off(tmp_path):
    """Serving accounting predates the telemetry plane: it must keep
    exact counts with the global registry disabled (its registry is
    private and always on), and surface as a snapshot source."""
    import asyncio

    from repro.core.session import PointCloudScene
    from repro.serving import QueryServer

    assert not obs.is_enabled()
    rng = np.random.default_rng(1)
    engine = PointCloudScene.from_points(
        jnp.asarray(rng.normal(size=(512, 3)).astype(np.float32))).engine(
            pad_multiple=8, shard=1)

    async def drive(server_box):
        async with QueryServer(engine, max_batch_rows=32,
                               max_wait=2e-3) as server:
            server_box.append(server)
            qs = [jnp.asarray(rng.normal(size=(2, 3)).astype(np.float32))
                  for _ in range(4)]
            await asyncio.gather(*[server.nearest(q, k=4) for q in qs])
            return server.stats()

    box: list = []
    stats = asyncio.run(drive(box))
    s = stats["nearest"]
    assert s.requests == 4 and s.rows == 8
    assert s.batches >= 1 and s.requests_per_batch >= 1.0
    assert s.p50_ms <= s.p99_ms

    # the server is a named source in the global snapshot, weakly held
    snap = obs.snapshot()
    name = box[0]._source_name
    assert name in snap["sources"]
    section = snap["sources"][name]
    assert section["nearest"]["requests"] == 4
    assert "admission" in section
    json.dumps(snap)  # the whole snapshot must be strictly JSON-able

    box.clear()
    del stats, s, section, snap
    gc.collect()
    assert name not in obs.snapshot()["sources"]


# ---------------------------------------------------------------------------
# benchmark row schema
# ---------------------------------------------------------------------------


def test_census_bench_rows_have_null_timing():
    """Census-style rows report derived metrics only: us_per_call must be
    None (JSON null), never a fake 0.0 that reads as 'measured and
    instantaneous' (benchmarks/run.py documents the row schema)."""
    import os
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks import run as bench_run
    from benchmarks.bench_datapath import bench_fu_census

    rows: list = []
    bench_fu_census(rows)
    assert rows, "census produced no rows"
    for name, us, derived in rows:
        assert name.startswith("fu_census_")
        assert us is None, f"{name}: census rows must not carry a timing"
        assert "ops_vs_tableVIII" in derived
    # and the runner's JSON writer keeps None as null end to end
    payload = json.loads(json.dumps(
        [dict(name=n, us_per_call=None if u is None else round(u, 3),
              derived=bench_run.parse_derived(d)) for n, u, d in rows]))
    assert all(r["us_per_call"] is None for r in payload)
