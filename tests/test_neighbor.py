"""Traversal-backed neighbor search: stage units, engine parity, the
deterministic tree-vs-brute exactness contract, the auto policy, and the
sharded + chunked scale acceptance run (DESIGN.md §9).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import PointCloudScene, VectorIndex
from repro.core import Box
from repro.core.build.points import build_point_bvh, refit_points
from repro.core.bvh import level_offset
from repro.core.datapath import point_box_test
from repro.core.knn import squared_norms
from repro.core.neighbor import (insert_sorted, neighbor_wavefront,
                                 point_queries)

BUILDERS = ("lbvh", "sah")
NEIGHBOR_FIELDS = ("dist_sq", "index", "valid", "count", "box_jobs",
                   "point_jobs")


def _pts(n, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(n, 3)).astype(np.float32))


# ---------------------------------------------------------------------------
# stage units
# ---------------------------------------------------------------------------


def test_point_box_test_hand_values():
    boxes = Box(lo=jnp.asarray([[-1.0, -1, -1], [1, 2, 0],
                                [-3, -3, -3], [0, 0, 2]], jnp.float32),
                hi=jnp.asarray([[1.0, 1, 1], [2, 3, 1],
                                [-2, -2, -2], [1, 1, 3]], jnp.float32))
    res = point_box_test(jnp.zeros((3,), jnp.float32), boxes)
    # containment -> 0; outside -> sum of per-axis gap^2; sorted ascending
    np.testing.assert_allclose(np.asarray(res.dist_sq), [0.0, 4.0, 5.0, 12.0])
    np.testing.assert_array_equal(np.asarray(res.box_index), [0, 3, 1, 2])


def test_point_box_test_batched_matches_per_point():
    rng = np.random.default_rng(3)
    p = jnp.asarray(rng.normal(size=(6, 3)).astype(np.float32))
    lo = rng.uniform(-2, 0, (6, 4, 3)).astype(np.float32)
    boxes = Box(lo=jnp.asarray(lo),
                hi=jnp.asarray(lo + rng.uniform(0, 2, (6, 4, 3))
                               .astype(np.float32)))
    batched = point_box_test(p, boxes)
    for i in range(6):
        one = point_box_test(p[i], Box(boxes.lo[i], boxes.hi[i]))
        np.testing.assert_array_equal(np.asarray(batched.dist_sq[i]),
                                      np.asarray(one.dist_sq))


def test_insert_sorted_matches_sorted_prefix():
    k, lanes = 3, 2
    best_d = jnp.full((k, lanes), jnp.inf, jnp.float32)
    best_i = jnp.full((k, lanes), -1, jnp.int32)
    cands = [(5.0, 0), (3.0, 1), (4.0, 2), (1.0, 3), (2.0, 4)]
    accept1 = [True, False, True, False, False]  # lane 1 stays underfilled
    kept = ([], [])
    for (d, i), a1 in zip(cands, accept1):
        best_d, best_i = insert_sorted(
            best_d, best_i, jnp.full((lanes,), d, jnp.float32),
            jnp.full((lanes,), i, jnp.int32),
            jnp.asarray([True, a1]))
        kept[0].append((d, i))
        if a1:
            kept[1].append((d, i))
    for lane in range(lanes):
        want = sorted(kept[lane])[:k]
        got_d = np.asarray(best_d[:, lane])[:len(want)]
        got_i = np.asarray(best_i[:, lane])[:len(want)]
        np.testing.assert_allclose(got_d, [d for d, _ in want])
        np.testing.assert_array_equal(got_i, [i for _, i in want])
    # unfilled slots stay at the empty sentinel (lane 1 holds 2 of k=3)
    assert int(best_i[-1, 1]) == -1 and np.isinf(float(best_d[-1, 1]))


# ---------------------------------------------------------------------------
# point builds + refit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("builder", BUILDERS)
def test_build_point_bvh_structure(builder):
    n = 37
    pts = _pts(n, seed=5)
    res = build_point_bvh(pts, builder=builder)
    bvh, depth = res.bvh, res.depth
    lt = np.asarray(bvh.leaf_tri)
    assert sorted(lt[lt >= 0]) == list(range(n))  # every point, exactly once
    # live leaf nodes are the degenerate per-point boxes (lo == hi == point)
    base = level_offset(depth)
    p = np.asarray(pts)
    for slot in np.flatnonzero(lt >= 0):
        np.testing.assert_array_equal(
            np.asarray(bvh.node_lo[base + slot]), p[lt[slot]])
        np.testing.assert_array_equal(
            np.asarray(bvh.node_hi[base + slot]), p[lt[slot]])
    np.testing.assert_array_equal(np.asarray(bvh.node_lo[0]), p.min(0))
    np.testing.assert_array_equal(np.asarray(bvh.node_hi[0]), p.max(0))


def test_build_point_bvh_validation():
    with pytest.raises(ValueError, match="point builder"):
        build_point_bvh(_pts(8), builder="nope")
    with pytest.raises(ValueError, match="leaf slots"):
        build_point_bvh(_pts(100), depth=1)
    with pytest.raises(ValueError, match=r"\(N, 3\)"):
        build_point_bvh(jnp.zeros((4, 8)))
    with pytest.raises(ValueError, match="finite"):
        PointCloudScene.from_points(
            jnp.asarray([[0.0, 0.0, jnp.nan]], jnp.float32))


def test_refit_points_preserves_topology():
    pts = _pts(21, seed=6)
    bvh = build_point_bvh(pts).bvh
    moved = pts * 1.5 + jnp.asarray([10.0, -3.0, 0.5])
    new = refit_points(bvh, moved)
    np.testing.assert_array_equal(np.asarray(new.leaf_tri),
                                  np.asarray(bvh.leaf_tri))
    np.testing.assert_array_equal(np.asarray(new.leaf_perm),
                                  np.asarray(bvh.leaf_perm))
    m = np.asarray(moved)
    np.testing.assert_array_equal(np.asarray(new.node_lo[0]), m.min(0))
    np.testing.assert_array_equal(np.asarray(new.node_hi[0]), m.max(0))
    with pytest.raises(ValueError, match="21 points"):
        refit_points(bvh, _pts(22))


def test_point_queries_extent():
    q = _pts(4, seed=7)
    assert float(point_queries(q).extent[0]) == float("inf")
    np.testing.assert_allclose(np.asarray(point_queries(q, 0.25).extent),
                               0.25)


# ---------------------------------------------------------------------------
# engine parity: the fused kernel bit-matches the wavefront loop
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("builder", BUILDERS)
@pytest.mark.parametrize("mode", ("within", "nearest"))
def test_fused_bitmatches_wavefront(builder, mode):
    from repro.kernels.traverse import neighbor_fused

    res = build_point_bvh(_pts(300, seed=8), builder=builder)
    rays = point_queries(_pts(70, seed=9),
                         0.8 if mode == "within" else None)
    a = neighbor_wavefront(res.bvh, squared_norms(res.bvh.triangles.a),
                           rays, res.depth, k=8, mode=mode)
    b = neighbor_fused(res.bvh, rays, res.depth, 8, mode=mode)
    for f in NEIGHBOR_FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)),
                                      err_msg=f)
    assert int(a.rounds) == int(b.rounds)


# ---------------------------------------------------------------------------
# deterministic tree-vs-brute exactness (fixed seeds)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ("tree_wavefront", "tree_pallas"))
def test_tree_matches_brute_exactly(backend):
    n, m = 500, 40
    cloud = PointCloudScene.from_points(_pts(n, seed=10))
    engine = cloud.engine(pad_multiple=8, shard=1)
    q = _pts(m, seed=11)
    oracle = np.asarray(engine.scores(q, "euclidean", backend="mxu"))
    for radius in (0.3, 0.9):
        inside = oracle <= radius * radius
        assert inside.sum(1).max() < n
        rec = engine.neighbor_search(q, n, radius=radius, backend=backend)
        w, idx = np.asarray(rec.valid), np.asarray(rec.index)
        for i in range(m):
            assert set(idx[i][w[i]]) == set(np.flatnonzero(inside[i]))
        np.testing.assert_array_equal(np.asarray(rec.count),
                                      inside.sum(1))
    near = engine.nearest(q, 7, backend=backend)
    brute = engine.nearest(q, 7, backend="mxu")
    np.testing.assert_array_equal(np.asarray(near.indices),
                                  np.asarray(brute.indices))


def test_refit_reroutes_results():
    pts = _pts(200, seed=12)
    cloud = PointCloudScene.from_points(pts)
    engine = cloud.engine(pad_multiple=8, shard=1)
    q = _pts(10, seed=13)
    before = np.asarray(engine.count_within(q, 0.6,
                                            backend="tree_wavefront"))
    cloud.refit(pts + 0.5)
    after = np.asarray(engine.count_within(q, 0.6,
                                           backend="tree_wavefront"))
    want = (np.asarray(engine.scores(q, "euclidean", backend="mxu"))
            <= 0.36).sum(1)
    np.testing.assert_array_equal(after, want)
    assert (before != after).any()


# ---------------------------------------------------------------------------
# the "auto" tree-vs-brute policy
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def big_cloud_engine():
    return PointCloudScene.from_points(_pts(5000, seed=14)).engine(
        pad_multiple=8, shard=1)


def test_auto_policy_routes(big_cloud_engine):
    eng = big_cloud_engine
    brute = eng.resolve_distance_backend()
    # selective queries on a big cloud: the tree wins
    assert eng.resolve_neighbor_backend("nearest", "euclidean",
                                        k=8).startswith("tree_")
    assert eng.resolve_neighbor_backend("within", "euclidean",
                                        radius=0.05).startswith("tree_")
    # unselective queries: the brute matmul wins
    assert eng.resolve_neighbor_backend("nearest", "euclidean",
                                        k=5000) == brute
    assert eng.resolve_neighbor_backend("within", "euclidean",
                                        radius=100.0) == brute
    # non-euclidean metrics never route through the tree
    assert eng.resolve_neighbor_backend("nearest", "cosine", k=8) == brute
    # a small cloud stays brute whatever the query
    small = PointCloudScene.from_points(_pts(64, seed=15)).engine()
    assert small.resolve_neighbor_backend("nearest", "euclidean",
                                          k=4) == brute
    # no cloud at all (plain VectorIndex): brute, and tree backends refuse
    flat = VectorIndex.from_database(_pts(64, seed=16)).engine()
    assert flat.resolve_neighbor_backend("nearest", "euclidean",
                                         k=4) == brute
    with pytest.raises(ValueError, match="PointCloudScene"):
        flat.nearest(_pts(2, seed=17), 4, backend="tree_wavefront")


def test_tree_backend_rejects_non_euclidean(big_cloud_engine):
    with pytest.raises(ValueError, match="euclidean"):
        big_cloud_engine.nearest(_pts(2, seed=18), 4, "cosine",
                                 backend="tree_wavefront")


def test_neighbor_search_reports_pruned_work(big_cloud_engine):
    q = _pts(16, seed=19)
    rec = big_cloud_engine.neighbor_search(q, 32, radius=0.2,
                                           backend="tree_wavefront")
    box_jobs = np.asarray(rec.box_jobs)
    point_jobs = np.asarray(rec.point_jobs)
    assert (box_jobs > 0).all() and int(rec.rounds) > 0
    # the point of the tree: far fewer distance jobs than brute's N per query
    assert point_jobs.mean() < 0.25 * 5000


# ---------------------------------------------------------------------------
# scale acceptance: 1e5-point cloud, shard=8 + chunking, both backends
# ---------------------------------------------------------------------------


def test_neighbor_scale_sharded_8dev(multidev):
    multidev("""
import numpy as np, jax, jax.numpy as jnp
assert jax.local_device_count() == 8
from repro.api import PointCloudScene

N, M = 100_000, 256
rng = np.random.default_rng(77)
pts = jnp.asarray(rng.normal(size=(N, 3)).astype(np.float32))
cloud = PointCloudScene.from_points(pts)
single = cloud.engine(pad_multiple=8, shard=1)
sharded = cloud.engine(pad_multiple=8, shard=8, chunk_size=64)
q = jnp.asarray(rng.normal(size=(M, 3)).astype(np.float32))
radius, k, knn_k = 0.12, 96, 16

oracle = np.asarray(single.scores(q, "euclidean", backend="mxu"))
inside = oracle <= radius * radius
assert 0 < inside.sum(1).max() < k  # k can hold every in-radius set

FIELDS = ("dist_sq", "index", "valid", "count", "box_jobs", "point_jobs")
brute = single.nearest(q, knn_k, backend="mxu")
for backend in ("tree_wavefront", "tree_pallas"):
    rec = sharded.neighbor_search(q, k, radius=radius, backend=backend)
    w, idx = np.asarray(rec.valid), np.asarray(rec.index)
    for i in range(M):
        assert set(idx[i][w[i]]) == set(np.flatnonzero(inside[i])), \\
            (backend, i)
    np.testing.assert_array_equal(np.asarray(rec.count), inside.sum(1),
                                  err_msg=backend)
    # the walk prunes: distance jobs per query are a sliver of brute's N
    assert float(np.asarray(rec.point_jobs).mean()) < 0.05 * N, backend
    # nearest: rank-equivalent vs the brute top-k
    near = sharded.nearest(q, knn_k, backend=backend)
    picked = np.take_along_axis(oracle, np.asarray(near.indices), 1)
    np.testing.assert_allclose(picked, np.asarray(brute.scores),
                               rtol=1e-4, atol=1e-5, err_msg=backend)
    # sharded + chunked == single-device, bit for bit, counters included
    solo = single.neighbor_search(q, k, radius=radius, backend=backend)
    for f in FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(rec, f)),
                                      np.asarray(getattr(solo, f)),
                                      err_msg=f"{backend}: {f}")
print("neighbor scale acceptance OK")
""", n_devices=8)
