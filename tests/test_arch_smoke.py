"""Per-architecture smoke: every assigned arch instantiates a REDUCED
same-family config and runs one train step + prefill + decode on CPU,
asserting output shapes and no NaNs (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke
from repro.models import (count_active_params, count_params, derive_segments,
                          init_cache, init_params)
from repro.models import model as M
from repro.parallel.ctx import NO_PARALLEL as ctx

B, T = 2, 32


def _batch(cfg, rng):
    t_text = T - cfg.vision_tokens if cfg.family == "vlm" else T
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, t_text)), jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder.seq_len, cfg.d_model)), jnp.float32)
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.normal(size=(B, cfg.vision_tokens, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_and_decode(arch):
    cfg = get_smoke(arch)
    assert cfg.family == get_config(arch).family  # same family as full
    rng = np.random.default_rng(0)
    batch = _batch(cfg, rng)
    params = init_params(jax.random.PRNGKey(0), cfg)

    loss, metrics = jax.jit(lambda p, b: M.train_loss(cfg, ctx, p, b))(
        params, batch)
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    assert float(loss) > 0

    cache = init_cache(cfg, B, max_len=T + 4)
    pb = {k: v for k, v in batch.items() if k != "labels"}
    logits, cache = jax.jit(lambda p, b, c: M.prefill(cfg, ctx, p, b, c))(
        params, pb, cache)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all(), f"{arch}: prefill NaN"

    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    logits2, cache = jax.jit(lambda p, c, t: M.decode_step(cfg, ctx, p, c, t))(
        params, cache, tok)
    assert logits2.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits2)).all(), f"{arch}: decode NaN"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_shapes_only(arch):
    """Full configs are touched only abstractly: eval_shape + segments."""
    cfg = get_config(arch)
    segs = derive_segments(cfg)
    assert sum(len(p) * r for p, r in segs) == cfg.num_layers
    n = count_params(cfg)
    na = count_active_params(cfg)
    assert 0 < na <= n
    shapes = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    total = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(shapes))
    assert total == n, f"{arch}: analytic {n} != eval_shape {total}"
