"""Per-kernel shape/dtype sweeps vs the ref.py oracles (interpret mode).

Comparisons: ray-box and the sort network are bit-exact (compare/select
only); paths containing mul->add chains allow one-FMA ULP slack (XLA CPU
contracts FMAs in the interpreted kernel body; Mosaic on real TPU rounds
per-op — see kernels/common.round_stage).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Box, Triangle, make_ray
from repro.core.stream import DatapathJob, make_jobs
from repro.kernels import ref as kref
from repro.kernels.common import LANES
from repro.kernels.ops import (angular_kernel, euclidean_kernel,
                               ray_box_kernel, ray_triangle_kernel,
                               unified_datapath)

SIZES = [1, 7, 128, 300]


def _rand_rays(rng, n):
    org = rng.uniform(-3, 3, (n, 3)).astype(np.float32)
    dirs = rng.normal(size=(n, 3)).astype(np.float32)
    return make_ray(jnp.asarray(org), jnp.asarray(dirs))


@pytest.mark.parametrize("n", SIZES)
def test_raybox_kernel_bitexact(n):
    rng = np.random.default_rng(n)
    ray = _rand_rays(rng, n)
    lo = rng.uniform(-3, 2, (n, 4, 3)).astype(np.float32)
    hi = lo + rng.uniform(0, 3, (n, 4, 3)).astype(np.float32)
    boxes = Box(jnp.asarray(lo), jnp.asarray(hi))
    k = ray_box_kernel(ray, boxes)
    r = kref.ray_box_ref(ray, boxes)
    np.testing.assert_array_equal(np.asarray(k.tmin), np.asarray(r.tmin))
    np.testing.assert_array_equal(np.asarray(k.box_index), np.asarray(r.box_index))
    np.testing.assert_array_equal(np.asarray(k.is_intersect), np.asarray(r.is_intersect))


@pytest.mark.parametrize("n", SIZES)
def test_raytri_kernel_allclose(n):
    rng = np.random.default_rng(100 + n)
    ray = _rand_rays(rng, n)
    tri = Triangle(*(jnp.asarray(rng.normal(size=(n, 3)).astype(np.float32))
                     for _ in range(3)))
    k = ray_triangle_kernel(ray, tri)
    r = kref.ray_triangle_ref(ray, tri)
    np.testing.assert_allclose(np.asarray(k.t_num), np.asarray(r.t_num),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(k.t_denom), np.asarray(r.t_denom),
                               rtol=1e-4, atol=1e-5)
    agree = (np.asarray(k.hit) == np.asarray(r.hit)).mean()
    assert agree > 0.999, f"hit bit agreement {agree}"


@pytest.mark.parametrize("m,n,d", [(8, 8, 8), (55, 91, 37), (128, 128, 128),
                                   (130, 260, 300)])
def test_euclidean_kernel_sweep(m, n, d):
    rng = np.random.default_rng(m * n)
    q = rng.normal(size=(m, d)).astype(np.float32)
    c = rng.normal(size=(n, d)).astype(np.float32)
    k = euclidean_kernel(jnp.asarray(q), jnp.asarray(c))
    r = kref.euclidean_direct_ref(q, c)
    np.testing.assert_allclose(np.asarray(k), np.asarray(r),
                               rtol=1e-4, atol=1e-4 * d ** 0.5)


@pytest.mark.parametrize("m,n,d", [(8, 8, 8), (55, 91, 37), (128, 256, 64)])
def test_angular_kernel_sweep(m, n, d):
    rng = np.random.default_rng(m + n + d)
    q = rng.normal(size=(m, d)).astype(np.float32)
    c = rng.normal(size=(n, d)).astype(np.float32)
    dk, nk_ = angular_kernel(jnp.asarray(q), jnp.asarray(c))
    dr, nr = kref.angular_ref(q, c)
    np.testing.assert_allclose(np.asarray(dk), np.asarray(dr),
                               rtol=1e-4, atol=1e-5 * d ** 0.5)
    np.testing.assert_allclose(np.asarray(nk_), np.asarray(nr), rtol=1e-5)


def _mixed_jobs(rng, t):
    n = t * LANES
    jobs = make_jobs(n)
    org = rng.normal(size=(n, 3)).astype(np.float32)
    dirs = rng.normal(size=(n, 3)).astype(np.float32)
    ray = make_ray(jnp.asarray(org), jnp.asarray(dirs))
    lo = rng.normal(size=(n, 4, 3)).astype(np.float32)
    hi = lo + rng.uniform(0.1, 2, (n, 4, 3)).astype(np.float32)
    tri = Triangle(*(jnp.asarray(rng.normal(size=(n, 3)).astype(np.float32))
                     for _ in range(3)))
    ops = rng.integers(0, 4, size=t).astype(np.int32)
    reset = rng.random(t) < 0.3
    jobs = jobs._replace(
        opcode=jnp.asarray(np.repeat(ops, LANES)), ray=ray,
        boxes=Box(jnp.asarray(lo), jnp.asarray(hi)), triangle=tri,
        vec_a=jnp.asarray(rng.normal(size=(n, 16)).astype(np.float32)),
        vec_b=jnp.asarray(rng.normal(size=(n, 16)).astype(np.float32)),
        reset_accum=jnp.asarray(np.repeat(reset, LANES)))
    return jax.tree.map(lambda x: x.reshape((t, LANES) + x.shape[1:]), jobs)


FIELD_OPCODE = {"tmin": 1, "box_index": 1, "is_intersect": 1,
                "t_num": 0, "t_denom": 0, "triangle_hit": 0,
                "euclidean_accumulator": 2,
                "angular_dot_product": 3, "angular_norm": 3}


def test_unified_kernel_vs_lane_stream_oracle():
    """Mixed-opcode stream through the unified kernel == vmap'd in-order
    scalar stream (per-lane accumulators, cross-beat)."""
    rng = np.random.default_rng(9)
    jobs = _mixed_jobs(rng, t=10)
    out_k = unified_datapath(jobs)
    out_r = kref.unified_ref(jobs)
    op = np.asarray(out_r.opcode)
    for name, valid_op in FIELD_OPCODE.items():
        a = np.asarray(getattr(out_k, name), np.float64)
        b = np.asarray(getattr(out_r, name), np.float64)
        m = (op == valid_op)
        if a.ndim == 3:
            m = m[..., None]
        np.testing.assert_allclose(np.where(m, a, 0), np.where(m, b, 0),
                                   rtol=1e-4, atol=1e-4,
                                   err_msg=f"field {name}")


def test_unified_kernel_accumulator_across_tiles():
    """Beats of a long Euclidean job land in the same lane across tiles."""
    rng = np.random.default_rng(10)
    t = 4
    jobs = _mixed_jobs(rng, t)
    ops = jnp.zeros((t, LANES), jnp.int32) + 2  # all euclidean
    reset = jnp.zeros((t, LANES), bool).at[0].set(True)
    jobs = jobs._replace(opcode=ops, reset_accum=reset)
    out = unified_datapath(jobs)
    a = np.asarray(jobs.vec_a, np.float64)
    b = np.asarray(jobs.vec_b, np.float64)
    expected = ((a - b) ** 2).sum(-1).cumsum(axis=0)
    np.testing.assert_allclose(np.asarray(out.euclidean_accumulator),
                               expected, rtol=1e-4)
