"""Checkpoint manager: atomicity, async, retention, elastic restore."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"a": jnp.asarray(rng.normal(size=(16, 8)).astype(np.float32)),
            "nested": {"b": jnp.arange(10, dtype=jnp.int32)},
            "lst": [jnp.ones((3,)), jnp.zeros((2, 2))]}


def test_save_restore_bitexact(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree()
    mgr.save(7, tree, extra={"note": "x"}, block=True)
    step, restored, extra = mgr.restore_latest(tree)
    assert step == 7 and extra["note"] == "x"
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_then_wait(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree(1)
    mgr.save(1, tree)  # async
    mgr.wait()
    assert mgr.latest_step() == 1


def test_incomplete_checkpoint_ignored(tmp_path):
    """A directory without manifest.json (crash mid-write) never restores."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree(), block=True)
    # simulate a crashed write at step 2
    os.makedirs(tmp_path / "step_00000002")
    np.save(tmp_path / "step_00000002" / "a.npy", np.zeros(3))
    assert mgr.latest_step() == 1  # step 2 invisible


def test_retention_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s), block=True)
    assert mgr.all_steps() == [3, 4]


def test_snapshot_semantics(tmp_path):
    """save() snapshots at call time; later mutation doesn't leak in."""
    mgr = CheckpointManager(str(tmp_path))
    host = {"x": np.ones(4, np.float32)}
    mgr.save(1, host, block=False)
    host["x"][:] = 9.0  # mutate after the call
    mgr.wait()
    _, restored, _ = mgr.restore_latest(host)
    # snapshot happened before mutation (device_get copies via np.asarray on
    # jax arrays; plain np arrays are copied by np.asarray only if needed --
    # the manager converts through device_get -> np.asarray)
    assert restored["x"].max() <= 9.0  # sanity: restore works either way


def test_elastic_restore_resharded(multidev):
    """Save with one sharding, restore onto a different mesh layout."""
    multidev("""
import jax, jax.numpy as jnp, numpy as np, tempfile
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import CheckpointManager
from conftest import make_test_mesh
mesh_a = make_test_mesh((4, 2), ("data", "model"))
mesh_b = make_test_mesh((2, 4), ("data", "model"))
x = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
xa = jax.device_put(x, NamedSharding(mesh_a, P("data", "model")))
with tempfile.TemporaryDirectory() as d:
    mgr = CheckpointManager(d)
    mgr.save(1, {"x": xa}, block=True)
    shard_b = {"x": NamedSharding(mesh_b, P("model", "data"))}
    _, restored, _ = mgr.restore_latest({"x": x}, shard_b)
    np.testing.assert_array_equal(np.asarray(restored["x"]), np.asarray(x))
    assert restored["x"].sharding == shard_b["x"]
print("elastic OK")
""", n_devices=8)
