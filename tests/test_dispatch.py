"""Dispatch layer: plan math, chunk streaming, sharded execution parity.

The contract (DESIGN.md §6): an ``ExecPlan`` schedules a batch into
fixed-size blocks whose rows divide evenly over the mesh with per-shard
lane padding, every block re-enters one compiled function, and the whole
pad -> shard -> query -> unshard -> unpad pipeline is a bit-exact identity
against the single-device unchunked path (the acceptance criterion, pinned
here on a forced 8-device host mesh).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dispatch import (ceil_to, concat_rows, device_mesh,
                                 make_plan, pad_leading, resolve_shards,
                                 split_blocks)


# ---------------------------------------------------------------------------
# plan math (pure, single-process)
# ---------------------------------------------------------------------------


def test_plan_degenerates_to_single_padded_call():
    """shards=1, chunk_size=None == the original ceil_to(n, pad) behavior."""
    plan = make_plan(50, pad_multiple=8)
    assert (plan.block, plan.n_blocks, plan.shards) == (56, 1, 1)
    plan = make_plan(64, pad_multiple=8)
    assert (plan.block, plan.n_blocks) == (64, 1)
    assert plan.mesh is None


def test_plan_chunking():
    plan = make_plan(50, pad_multiple=8, chunk_size=16)
    assert (plan.block, plan.n_blocks) == (16, 4)  # 16+16+16+2pad6
    # chunk_size larger than the batch clamps to one block
    plan = make_plan(10, pad_multiple=8, chunk_size=1000)
    assert (plan.block, plan.n_blocks) == (16, 1)
    # chunk_size rounds up to the lane multiple
    plan = make_plan(100, pad_multiple=8, chunk_size=3)
    assert plan.block == 8


def test_plan_per_shard_lane_padding():
    """Each shard receives a lane multiple of rows: block = shards *
    ceil(rows_per_shard to pad_multiple)."""
    plan = make_plan(50, pad_multiple=8, shards=4)
    assert plan.block == 4 * ceil_to(-(-50 // 4), 8) == 64
    assert plan.n_blocks == 1
    plan = make_plan(50, pad_multiple=8, shards=4, chunk_size=16)
    assert plan.block == 4 * 8 == 32  # 4 rows/shard -> padded to 8
    assert plan.n_blocks == 2
    assert plan.key == (4, 32)


def test_plan_backend_lane_multiple():
    """A backend-declared tile width (the fused Pallas traversal's
    128-lane tiles) raises the per-shard multiple to
    ``max(pad_multiple, lane_multiple)`` so kernels always receive whole
    tiles — per shard, per chunk."""
    plan = make_plan(50, pad_multiple=8, lane_multiple=128)
    assert (plan.block, plan.n_blocks) == (128, 1)
    # composes with sharding: every shard gets a whole tile
    plan = make_plan(50, pad_multiple=8, shards=4, lane_multiple=128)
    assert (plan.block, plan.shards) == (4 * 128, 4)
    # composes with chunking: a sub-tile chunk_size still yields one tile
    plan = make_plan(300, pad_multiple=8, chunk_size=16, lane_multiple=128)
    assert (plan.block, plan.n_blocks) == (128, 3)
    # a pad_multiple above the tile width wins (max, not override)
    plan = make_plan(50, pad_multiple=256, lane_multiple=128)
    assert plan.block == 256
    # None = unchanged legacy behavior
    assert make_plan(50, pad_multiple=8, lane_multiple=None).block == 56


def test_plan_validation():
    with pytest.raises(ValueError, match="n >= 1"):
        make_plan(0, pad_multiple=8)
    with pytest.raises(ValueError, match="chunk_size"):
        make_plan(10, pad_multiple=8, chunk_size=0)


def test_resolve_shards():
    n_dev = jax.local_device_count()
    assert resolve_shards(None) == 1
    assert resolve_shards(1) == 1
    assert resolve_shards("auto") == n_dev
    assert resolve_shards("auto", n_rows=1) == 1  # capped at the batch
    assert resolve_shards(n_dev) == n_dev
    with pytest.raises(ValueError, match="exceeds"):
        resolve_shards(n_dev + 1)
    with pytest.raises(ValueError, match=">= 1"):
        resolve_shards(-2)


def test_split_concat_roundtrip_identity():
    """split -> pad -> concat -> slice is the identity on any row count."""
    rng = np.random.default_rng(0)
    for n, chunk in ((1, 4), (7, 4), (8, 4), (50, 16), (5, None)):
        tree = {"a": jnp.asarray(rng.normal(size=(n, 3)).astype(np.float32)),
                "b": jnp.arange(n, dtype=jnp.int32)}
        plan = make_plan(n, pad_multiple=4, chunk_size=chunk)
        blocks = list(split_blocks(tree, plan))
        assert len(blocks) == plan.n_blocks
        assert all(b["a"].shape[0] == plan.block for b in blocks)
        out = concat_rows(blocks, n)
        np.testing.assert_array_equal(np.asarray(out["a"]),
                                      np.asarray(tree["a"]))
        np.testing.assert_array_equal(np.asarray(out["b"]),
                                      np.asarray(tree["b"]))


def test_pad_leading_empty_and_full():
    padded = pad_leading(jnp.zeros((0, 2)), 4)
    assert padded.shape == (4, 2)
    x = jnp.arange(6, dtype=jnp.float32)
    padded = pad_leading(x, 8)
    np.testing.assert_array_equal(np.asarray(padded[:6]), np.asarray(x))
    np.testing.assert_array_equal(np.asarray(padded[6:]),
                                  np.zeros(2) + float(x[0]))


def test_device_mesh_is_cached():
    m1 = device_mesh(1)
    assert device_mesh(1) is m1


# ---------------------------------------------------------------------------
# the acceptance criterion: sharded + chunked == single-device unchunked,
# bit for bit, on a forced 8-device host mesh
# ---------------------------------------------------------------------------


def test_sharded_chunked_bitparity_8dev(multidev):
    multidev("""
import numpy as np, jax, jax.numpy as jnp
assert jax.local_device_count() == 8
from repro.api import Scene, VectorIndex, make_ray
from repro.core import Triangle

rng = np.random.default_rng(7)
n_tri, n_rays = 230, 50
ctr = rng.uniform(-1, 1, (n_tri, 3)).astype(np.float32)
d1 = rng.normal(scale=0.15, size=(n_tri, 3)).astype(np.float32)
d2 = rng.normal(scale=0.15, size=(n_tri, 3)).astype(np.float32)
tri = Triangle(jnp.asarray(ctr), jnp.asarray(ctr + d1), jnp.asarray(ctr + d2))
scene = Scene.from_triangles(tri)
org = rng.uniform(-3, -2, (n_rays, 3)).astype(np.float32)
tgt = rng.uniform(-0.5, 0.5, (n_rays, 3)).astype(np.float32)
rays = make_ray(jnp.asarray(org), jnp.asarray(tgt - org))

single = scene.engine(pad_multiple=8, shard=1)
FIELDS = ("t", "tri_index", "hit", "quadbox_jobs", "triangle_jobs")
for ray_type in ("closest", "any", "shadow"):
    ref = single.trace(rays, ray_type=ray_type, backend="wavefront")
    for shard, chunk in (("auto", None), (8, None), (8, 16), (4, 8), (2, None)):
        eng = scene.engine(pad_multiple=8, shard=shard, chunk_size=chunk)
        got = eng.trace(rays, ray_type=ray_type, backend="wavefront")
        for f in FIELDS:
            np.testing.assert_array_equal(
                np.asarray(getattr(got, f)), np.asarray(getattr(ref, f)),
                err_msg=f"{ray_type} shard={shard} chunk={chunk} {f}")
        assert int(got.rounds) == int(ref.rounds), (ray_type, shard, chunk)
# per-ray oracle backend shards identically too
ref = single.trace(rays, backend="per_ray")
got = scene.engine(pad_multiple=8, shard=8, chunk_size=16).trace(
    rays, backend="per_ray")
for f in FIELDS:
    np.testing.assert_array_equal(np.asarray(getattr(got, f)),
                                  np.asarray(getattr(ref, f)), err_msg=f)
assert int(got.rounds) == int(ref.rounds)
print("trace sharded+chunked bit-parity OK")

q = jnp.asarray(rng.normal(size=(21, 24)).astype(np.float32))
db = jnp.asarray(rng.normal(size=(211, 24)).astype(np.float32))
index = VectorIndex.from_database(db)
s1 = index.engine(pad_multiple=8, shard=1)
for metric in ("euclidean", "angular", "cosine"):
    a = s1.nearest(q, 5, metric, backend="mxu")
    b = index.engine(pad_multiple=8, shard="auto", chunk_size=8).nearest(
        q, 5, metric, backend="mxu")
    np.testing.assert_array_equal(np.asarray(a.scores), np.asarray(b.scores))
    np.testing.assert_array_equal(np.asarray(a.indices), np.asarray(b.indices))
sharded = index.engine(pad_multiple=8, shard=8)
for a, b in zip(s1.within(q, 5.0, 12), sharded.within(q, 5.0, 12)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
np.testing.assert_array_equal(np.asarray(s1.count_within(q, 5.0)),
                              np.asarray(sharded.count_within(q, 5.0)))
np.testing.assert_array_equal(np.asarray(s1.scores(q)),
                              np.asarray(sharded.scores(q)))
# pallas backend: neighbour indices exact, scores to the documented caveat
a = s1.nearest(q, 5, "euclidean", backend="pallas")
b = sharded.nearest(q, 5, "euclidean", backend="pallas")
np.testing.assert_array_equal(np.asarray(a.indices), np.asarray(b.indices))
np.testing.assert_allclose(np.asarray(a.scores), np.asarray(b.scores),
                           rtol=1e-6, atol=1e-4)
print("distance sharded+chunked bit-parity OK")
""", n_devices=8)


def test_sharded_chunk_cache_reuse_8dev(multidev):
    """All chunks of a sharded query re-enter ONE compiled function, and a
    repeat query retraces nothing."""
    multidev("""
import numpy as np, jax, jax.numpy as jnp
from repro.api import Scene, make_ray
from repro.core import Triangle
from repro.obs import CompileTracker
rng = np.random.default_rng(3)
ctr = rng.uniform(-1, 1, (100, 3)).astype(np.float32)
tri = Triangle(jnp.asarray(ctr),
               jnp.asarray(ctr + rng.normal(scale=0.1, size=(100, 3)).astype(np.float32)),
               jnp.asarray(ctr + rng.normal(scale=0.1, size=(100, 3)).astype(np.float32)))
scene = Scene.from_triangles(tri)
org = rng.uniform(-3, -2, (120, 3)).astype(np.float32)
tgt = rng.uniform(-0.5, 0.5, (120, 3)).astype(np.float32)
rays = make_ray(jnp.asarray(org), jnp.asarray(tgt - org))
engine = scene.engine(pad_multiple=8, shard=8, chunk_size=40)
engine.trace(rays)
assert engine.cache_info() == (0, 1, 1), engine.cache_info()
with CompileTracker() as tracker:
    engine.trace(rays)
assert tracker.compiles == 0, "sharded chunked re-query retraced"
assert engine.cache_info().hits == 1
print("sharded chunk cache reuse OK")
""", n_devices=8)


# ---------------------------------------------------------------------------
# eager count validation + the serving batch-slice contract
# ---------------------------------------------------------------------------

def test_check_count_rejects_bad_values():
    from repro.core.dispatch import check_count
    assert check_count("chunk_size", None) is None
    assert check_count("chunk_size", 7) == 7
    assert check_count("shard", np.int64(3)) == 3  # any Integral is fine
    for bad in (0, -1, 2.5, True, "4"):
        with pytest.raises(ValueError, match="chunk_size"):
            check_count("chunk_size", bad)


def test_plan_strict_count_types():
    """Counts must be real integers — no silent float truncation, and no
    bool-as-int (shard=True used to mean shard=1)."""
    with pytest.raises(ValueError, match="chunk_size"):
        make_plan(10, pad_multiple=8, chunk_size=2.5)
    with pytest.raises(ValueError, match="shard"):
        resolve_shards(True)
    with pytest.raises(ValueError, match="shard"):
        resolve_shards(1.0)


def test_engine_validates_counts_eagerly():
    """Bad chunk_size/shard raise at call (or construction) time — even
    for an empty batch, long before any compile or dispatch."""
    from repro.api import Scene, make_ray
    rng = np.random.default_rng(0)
    ctr = rng.uniform(-1, 1, (4, 3)).astype(np.float32)
    tris = np.stack([ctr, ctr + 0.1, ctr + np.float32([0.1, 0, 0.1])], 1)
    scene = Scene.from_triangles(tris)
    with pytest.raises(ValueError, match="chunk_size"):
        scene.engine(chunk_size=0)
    with pytest.raises(ValueError, match="shard"):
        scene.engine(shard=-2)
    with pytest.raises(ValueError, match="shard"):
        scene.engine(shard=True)
    engine = scene.engine(pad_multiple=8)
    rays0 = make_ray(jnp.zeros((0, 3)), jnp.ones((0, 3)))
    for bad in (0, -3, 2.5, True):
        with pytest.raises(ValueError, match="chunk_size"):
            engine.trace(rays0, chunk_size=bad)  # n=0: still validated


def test_slice_rows_splits_and_unpads():
    from repro.core.dispatch import slice_rows
    tree = {"a": jnp.arange(12), "b": jnp.arange(24).reshape(12, 2)}
    parts = slice_rows(tree, [3, 0, 5])  # 8 real rows + 4 pad rows
    assert [int(p["a"].shape[0]) for p in parts] == [3, 0, 5]
    np.testing.assert_array_equal(np.asarray(parts[0]["a"]), [0, 1, 2])
    np.testing.assert_array_equal(np.asarray(parts[2]["a"]),
                                  [3, 4, 5, 6, 7])  # pad rows 8..11 dropped
    np.testing.assert_array_equal(np.asarray(parts[2]["b"]),
                                  np.arange(24).reshape(12, 2)[3:8])
    with pytest.raises(ValueError, match=">= 0"):
        slice_rows(tree, [2, -1])


def test_engine_plan_introspection():
    """plan_for/batch_multiple expose the planner the serving layer sizes
    batches with; the plan must match what a real call would use."""
    from repro.api import Scene
    rng = np.random.default_rng(1)
    ctr = rng.uniform(-1, 1, (4, 3)).astype(np.float32)
    tris = np.stack([ctr, ctr + 0.1, ctr + np.float32([0.1, 0, 0.1])], 1)
    engine = Scene.from_triangles(tris).engine(pad_multiple=8, shard=1)
    m = engine.batch_multiple("trace")
    assert m >= 8 and m % 8 == 0
    plan = engine.plan_for("trace", 10)
    assert plan.n == 10 and plan.block * plan.n_blocks >= 10
    assert (plan.block * plan.n_blocks) % m == 0
    # pallas trace pads to its lane width
    lanes = engine.batch_multiple("trace", "pallas")
    assert lanes % 128 == 0
    with pytest.raises(ValueError, match="n >= 1"):
        engine.plan_for("trace", 0)
    with pytest.raises(ValueError, match="method"):
        engine.plan_for("warp", 4)
