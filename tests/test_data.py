"""Data pipeline: determinism, sharding, resumability, prefetch."""
import numpy as np

from repro.data import Prefetcher, SyntheticLM


def test_deterministic_by_step():
    a = SyntheticLM(100, batch=4, seq_len=16, seed=3).batch_at(5)
    b = SyntheticLM(100, batch=4, seq_len=16, seed=3).batch_at(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = SyntheticLM(100, batch=4, seq_len=16, seed=4).batch_at(5)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_host_sharding_partitions_batch():
    full = SyntheticLM(100, batch=8, seq_len=16, seed=0)
    parts = [SyntheticLM(100, batch=8, seq_len=16, seed=0, host_id=i,
                         num_hosts=4) for i in range(4)]
    want = full.batch_at(2)
    got = np.concatenate([p.host_slice(p.batch_at(2))["tokens"]
                          for p in parts])
    np.testing.assert_array_equal(got, want["tokens"])


def test_state_resume():
    it = SyntheticLM(100, batch=2, seq_len=8, seed=1)
    [next(it) for _ in range(3)]
    state = it.state_dict()
    want = next(it)
    it2 = SyntheticLM(100, batch=2, seq_len=8, seed=1)
    it2.load_state_dict(state)
    got = next(it2)
    np.testing.assert_array_equal(got["tokens"], want["tokens"])


def test_labels_are_shifted_tokens():
    b = SyntheticLM(100, batch=2, seq_len=8, seed=0).batch_at(0)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])


def test_ngram_structure_learnable():
    """Token stream must have sub-uniform conditional entropy (n-grams)."""
    it = SyntheticLM(64, batch=64, seq_len=64, seed=0, noise=0.1)
    b = it.batch_at(0)["tokens"]
    # bigram predictability: P(next | prev) concentrated vs uniform
    from collections import Counter, defaultdict
    seen = defaultdict(Counter)
    for row in b:
        for x, y in zip(row[:-1], row[1:]):
            seen[int(x)][int(y)] += 1
    top1 = np.mean([c.most_common(1)[0][1] / sum(c.values())
                    for c in seen.values() if sum(c.values()) > 10])
    assert top1 > 2.0 / 64, f"stream looks uniform (top1={top1})"


def test_extra_specs_modalities():
    it = SyntheticLM(100, batch=2, seq_len=8, seed=0,
                     extra_specs={"frames": ((5, 12), np.float32)})
    b = it.batch_at(0)
    assert b["frames"].shape == (2, 5, 12) and b["frames"].dtype == np.float32


def test_prefetcher_order_and_close():
    it = SyntheticLM(100, batch=2, seq_len=8, seed=0)
    pf = Prefetcher(SyntheticLM(100, batch=2, seq_len=8, seed=0), depth=2)
    for i in range(5):
        got = next(pf)
        want = it.batch_at(i)
        np.testing.assert_array_equal(got["tokens"], want["tokens"][:2])
    pf.close()
