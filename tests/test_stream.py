"""Unified-stream semantics (Table V): accumulator isolation, interleaving,
reset behaviour, opcode-selected output validity."""
import jax.numpy as jnp
import numpy as np

from repro.core import (OP_ANGULAR, OP_EUCLIDEAN, OP_QUADBOX, OP_TRIANGLE,
                        init_datapath_state, unified_stream)
from repro.core.stream import make_jobs


def _vec_jobs(seq):
    """Build a job stream from a list of (opcode, a, b, reset) tuples."""
    n = len(seq)
    jobs = make_jobs(n)
    op = jnp.asarray([s[0] for s in seq], jnp.int32)
    va = jnp.zeros((n, 16), jnp.float32)
    vb = jnp.zeros((n, 16), jnp.float32)
    reset = jnp.asarray([bool(s[3]) for s in seq])
    for i, s in enumerate(seq):
        a = np.zeros(16, np.float32); a[:len(s[1])] = s[1]
        b = np.zeros(16, np.float32); b[:len(s[2])] = s[2]
        va = va.at[i].set(jnp.asarray(a))
        vb = vb.at[i].set(jnp.asarray(b))
    return jobs._replace(opcode=op, vec_a=va, vec_b=vb, reset_accum=reset)


def test_multibeat_accumulation():
    """A 32-dim Euclidean job split into two 16-lane beats accumulates."""
    a1, b1 = [1.0] * 16, [0.0] * 16
    a2, b2 = [2.0] * 16, [0.0] * 16
    jobs = _vec_jobs([(OP_EUCLIDEAN, a1, b1, True),
                      (OP_EUCLIDEAN, a2, b2, False)])
    _, out = unified_stream(jobs)
    assert np.isclose(out.euclidean_accumulator[0], 16.0)
    assert np.isclose(out.euclidean_accumulator[1], 16.0 + 64.0)


def test_mode_isolation_interleaved():
    """Interleaving angular jobs (and box/tri jobs) between Euclidean beats
    must not disturb the Euclidean accumulator, and vice versa (Table V:
    'safe to interleave ... over an indefinite time frame')."""
    jobs = _vec_jobs([
        (OP_EUCLIDEAN, [1.0], [0.0], True),     # euclid acc = 1
        (OP_ANGULAR, [3.0], [2.0], True),       # dot=6, norm=4
        (OP_QUADBOX, [], [], False),            # unrelated mode
        (OP_EUCLIDEAN, [2.0], [0.0], False),    # euclid acc = 1+4
        (OP_TRIANGLE, [], [], False),
        (OP_ANGULAR, [1.0], [5.0], False),      # dot=6+5, norm=4+25
    ])
    _, out = unified_stream(jobs)
    assert np.isclose(out.euclidean_accumulator[3], 5.0)
    assert np.isclose(out.angular_dot_product[5], 11.0)
    assert np.isclose(out.angular_norm[5], 29.0)


def test_reset_clears_only_own_mode():
    jobs = _vec_jobs([
        (OP_EUCLIDEAN, [2.0], [0.0], True),   # euclid = 4
        (OP_ANGULAR, [1.0], [1.0], True),     # dot = 1
        (OP_ANGULAR, [1.0], [1.0], True),     # reset again: dot = 1 (not 2)
        (OP_EUCLIDEAN, [1.0], [0.0], False),  # euclid = 5 (untouched by ang resets)
    ])
    _, out = unified_stream(jobs)
    assert np.isclose(out.angular_dot_product[2], 1.0)
    assert np.isclose(out.euclidean_accumulator[3], 5.0)


def test_reset_propagated_to_output():
    jobs = _vec_jobs([(OP_EUCLIDEAN, [1.0], [0.0], True),
                      (OP_EUCLIDEAN, [1.0], [0.0], False)])
    _, out = unified_stream(jobs)
    assert bool(out.reset_accum[0]) and not bool(out.reset_accum[1])


def test_mask_lanes():
    """The validity bitmask drops dead lanes (vectors of lesser dimension)."""
    jobs = _vec_jobs([(OP_EUCLIDEAN, [1.0] * 16, [0.0] * 16, True)])
    mask = jnp.asarray(np.arange(16) < 5)[None]
    jobs = jobs._replace(mask=mask)
    _, out = unified_stream(jobs)
    assert np.isclose(out.euclidean_accumulator[0], 5.0)


def test_angular_uses_eight_lanes():
    """OpAngular processes only 8 lanes/beat (each needs 2 multipliers)."""
    a = [1.0] * 16
    jobs = _vec_jobs([(OP_ANGULAR, a, a, True)])
    _, out = unified_stream(jobs)
    assert np.isclose(out.angular_dot_product[0], 8.0)  # not 16


def test_state_carries_across_streams():
    """Explicit state threading: a stream can be split across calls."""
    jobs1 = _vec_jobs([(OP_EUCLIDEAN, [3.0], [0.0], True)])
    jobs2 = _vec_jobs([(OP_EUCLIDEAN, [4.0], [0.0], False)])
    st, _ = unified_stream(jobs1, init_datapath_state())
    _, out = unified_stream(jobs2, st)
    assert np.isclose(out.euclidean_accumulator[0], 25.0)
