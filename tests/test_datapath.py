"""The paper's special-case suite: 20 hand-constructed ray/box/triangle
cases exercising the edge behaviour the RTL is designed for (§I: "twenty
special ray-box/ray-triangle test cases"), plus Table VII stage semantics."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Box, Triangle, make_ray, quadsort,
                        ray_box_test, ray_triangle_test)


def ray(o, d, extent=None):
    return make_ray(jnp.asarray([o], jnp.float32), jnp.asarray([d], jnp.float32),
                    None if extent is None else jnp.asarray([extent]))


def boxes4(*lohi):
    lo = jnp.asarray([[b[0] for b in lohi]], jnp.float32)
    hi = jnp.asarray([[b[1] for b in lohi]], jnp.float32)
    return Box(lo=lo, hi=hi)


UNIT = ((0, 0, 0), (1, 1, 1))


def unit4():
    return boxes4(UNIT, UNIT, UNIT, UNIT)


def tri(a, b, c):
    return Triangle(a=jnp.asarray([a], jnp.float32),
                    b=jnp.asarray([b], jnp.float32),
                    c=jnp.asarray([c], jnp.float32))


# ---- ray-box special cases (tavianator boundary semantics) -----------------


def test_case01_hit_through_center():
    qb = ray_box_test(ray((-1, .5, .5), (1, 0, 0)), unit4())
    assert bool(qb.is_intersect[0, 0]) and np.isclose(qb.tmin[0, 0], 1.0)


def test_case02_miss_parallel_outside():
    """Parallel to a slab, origin outside it: 0*inf NaN must not leak."""
    qb = ray_box_test(ray((-1, 2.0, .5), (1, 0, 0)), unit4())
    assert not np.asarray(qb.is_intersect).any()


def test_case03_parallel_on_boundary():
    """Ray gliding exactly on the box surface counts as hit (boundary
    convention of the branchless algorithm with comparator NaN-dropping)."""
    qb = ray_box_test(ray((-1, 0.0, .5), (1, 0, 0)), unit4())
    assert bool(qb.is_intersect[0, 0])


def test_case04_origin_inside():
    qb = ray_box_test(ray((.5, .5, .5), (1, 0, 0)), unit4())
    assert bool(qb.is_intersect[0, 0]) and np.isclose(qb.tmin[0, 0], 0.0)


def test_case05_box_behind():
    qb = ray_box_test(ray((2, .5, .5), (1, 0, 0)), unit4())
    assert not np.asarray(qb.is_intersect).any()


def test_case06_negative_direction():
    qb = ray_box_test(ray((2, .5, .5), (-1, 0, 0)), unit4())
    assert bool(qb.is_intersect[0, 0]) and np.isclose(qb.tmin[0, 0], 1.0)


def test_case07_negative_zero_direction():
    """dir = -0.0: the sign-bit swap must treat it as negative (inv = -inf)."""
    qb = ray_box_test(ray((.5, .5, .5), (-0.0, 1, 0)), unit4())
    assert bool(qb.is_intersect[0, 0])


def test_case08_diagonal_corner_hit():
    qb = ray_box_test(ray((-1, -1, -1), (1, 1, 1)), unit4())
    assert bool(qb.is_intersect[0, 0]) and np.isclose(qb.tmin[0, 0], 1.0)


def test_case09_degenerate_flat_box():
    """Zero-thickness box (lo == hi plane) still hits: boundary rule."""
    flat = ((0, 0, 0), (1, 1, 0))
    qb = ray_box_test(ray((.5, .5, -1), (0, 0, 1)), boxes4(flat, flat, flat, flat))
    assert bool(qb.is_intersect[0, 0])


def test_case10_sorted_output_with_indices():
    """Four boxes at different distances: outputs sorted, indices correct."""
    bx = boxes4(((3, 0, 0), (4, 1, 1)), ((1, 0, 0), (2, 1, 1)),
                ((7, 0, 0), (8, 1, 1)), ((5, 0, 0), (6, 1, 1)))
    qb = ray_box_test(ray((0, .5, .5), (1, 0, 0)), bx)
    assert np.asarray(qb.tmin[0]).tolist() == [1.0, 3.0, 5.0, 7.0]
    assert np.asarray(qb.box_index[0]).tolist() == [1, 0, 3, 2]
    assert np.asarray(qb.is_intersect[0]).all()


def test_case11_mixed_hit_miss_sorted():
    bx = boxes4(((3, 0, 0), (4, 1, 1)), ((1, 5, 0), (2, 6, 1)),  # box1 misses
                ((1, 0, 0), (2, 1, 1)), ((5, 5, 5), (6, 6, 6)))  # box3 misses
    qb = ray_box_test(ray((0, .5, .5), (1, 0, 0)), bx)
    hits = np.asarray(qb.is_intersect[0])
    tmin = np.asarray(qb.tmin[0])
    idx = np.asarray(qb.box_index[0])
    assert hits.sum() == 2
    hit_pairs = sorted((tmin[i], idx[i]) for i in range(4) if hits[i])
    assert hit_pairs == [(1.0, 2), (3.0, 0)]


# ---- ray-triangle special cases (Woop watertight, culling variant) ---------


def test_case12_front_face_hit():
    t = tri((0, 0, 1), (0, 1, 1), (1, 0, 1))
    r = ray((0.2, 0.2, 0), (0, 0, 1))
    out = ray_triangle_test(r, t)
    assert bool(out.hit[0])
    assert np.isclose(out.t_num[0] / out.t_denom[0], 1.0)


def test_case13_backface_culled():
    t = tri((0, 0, 1), (1, 0, 1), (0, 1, 1))  # reversed winding
    out = ray_triangle_test(ray((0.2, 0.2, 0), (0, 0, 1)), t)
    assert not bool(out.hit[0])


def test_case14_behind_origin():
    t = tri((0, 0, -1), (0, 1, -1), (1, 0, -1))
    out = ray_triangle_test(ray((0.2, 0.2, 0), (0, 0, 1)), t)
    assert not bool(out.hit[0])  # t_num < 0


def test_case15_edge_hit_watertight():
    """Hit exactly on a shared edge: U==0 boundary must count (>=0)."""
    t = tri((0, 0, 1), (0, 1, 1), (1, 0, 1))
    out = ray_triangle_test(ray((0.0, 0.5, 0), (0, 0, 1)), t)
    assert bool(out.hit[0])


def test_case16_vertex_hit_watertight():
    t = tri((0, 0, 1), (0, 1, 1), (1, 0, 1))
    out = ray_triangle_test(ray((0.0, 0.0, 0), (0, 0, 1)), t)
    assert bool(out.hit[0])


def test_case17_just_outside_edge():
    t = tri((0, 0, 1), (0, 1, 1), (1, 0, 1))
    out = ray_triangle_test(ray((-1e-4, 0.5, 0), (0, 0, 1)), t)
    assert not bool(out.hit[0])


def test_case18_degenerate_triangle_line():
    """Degenerate (zero-area) triangle: t_denom == 0 must not hit."""
    t = tri((0, 0, 1), (1, 0, 1), (2, 0, 1))
    out = ray_triangle_test(ray((0.5, 0.0, 0), (0, 0, 1)), t)
    assert not bool(out.hit[0])


def test_case19_oblique_direction_axis_permutation():
    """Dominant axis = y: exercises the kx/ky/kz permutation + shear."""
    t = tri((0, 2, 0), (1, 2, 0), (0, 2, 1))
    out = ray_triangle_test(ray((0.2, 0, 0.2), (0.1, 1, 0.05)), t)
    assert bool(out.hit[0])
    tt = float(out.t_num[0] / out.t_denom[0])
    assert 1.9 < tt * 1.0 < 2.2  # t ~ 2 along unnormalized dir


def test_case20_negative_dominant_axis():
    """dir[kz] < 0 triggers the kx/ky swap: winding must be preserved.

    Viewed along -z the (0,0)(1,0)(0,1) layout is the front-facing winding
    (mirror of test_case12's +z layout); the swapped-axes path must hit it
    and cull the reverse."""
    t = tri((0, 0, -1), (1, 0, -1), (0, 1, -1))
    out = ray_triangle_test(ray((0.2, 0.2, 0), (0, 0, -1)), t)
    assert bool(out.hit[0])
    assert np.isclose(out.t_num[0] / out.t_denom[0], 1.0)
    t_back = tri((0, 0, -1), (0, 1, -1), (1, 0, -1))
    out_b = ray_triangle_test(ray((0.2, 0.2, 0), (0, 0, -1)), t_back)
    assert not bool(out_b.hit[0])


# ---- stage primitives -------------------------------------------------------


def test_quadsort_all_permutations():
    """The 5-CAS network sorts all 24 permutations of distinct keys and
    carries payloads along."""
    import itertools
    for perm in itertools.permutations([0., 1., 2., 3.]):
        keys = jnp.asarray([perm])
        idx = jnp.asarray([[0, 1, 2, 3]], jnp.int32)
        sk, si = quadsort(keys, idx)
        assert np.asarray(sk[0]).tolist() == [0., 1., 2., 3.]
        assert [perm[i] for i in np.asarray(si[0])] == [0., 1., 2., 3.]


def test_quadsort_with_inf_and_ties():
    keys = jnp.asarray([[jnp.inf, 1.0, 1.0, -jnp.inf]])
    sk, = quadsort(keys)
    out = np.asarray(sk[0])
    assert out[0] == -np.inf and out[3] == np.inf and out[1] == out[2] == 1.0


def test_extent_not_applied_inside_datapath():
    """Table V: the datapath outputs tmin; extent filtering is external."""
    qb = ray_box_test(ray((-10, .5, .5), (1, 0, 0), extent=1.0), unit4())
    # still reports the geometric intersection at t=10
    assert bool(qb.is_intersect[0, 0]) and np.isclose(qb.tmin[0, 0], 10.0)
