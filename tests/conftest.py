import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
if os.path.abspath(SRC) not in sys.path:
    sys.path.insert(0, os.path.abspath(SRC))


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 600) -> str:
    """Run ``code`` in a subprocess with n fake CPU devices.

    Multi-device tests need XLA_FLAGS set before jax import, which cannot
    happen inside an already-initialized test process.
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.abspath(SRC)
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)], env=env,
        capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


@pytest.fixture
def multidev():
    return run_with_devices
