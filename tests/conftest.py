import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
TESTS = os.path.dirname(os.path.abspath(__file__))
if os.path.abspath(SRC) not in sys.path:
    sys.path.insert(0, os.path.abspath(SRC))


def pytest_addoption(parser):
    parser.addoption(
        "--regen-goldens", action="store_true", default=False,
        help="regenerate tests/golden/*.npz from the wavefront oracle "
             "(then re-run without the flag to verify; see "
             "tests/golden/README.md)")


@pytest.fixture
def regen_goldens(request):
    """Whether this run should rewrite the golden-trace fixtures."""
    return request.config.getoption("--regen-goldens")


def make_test_mesh(axis_shape, axis_names):
    """Version-tolerant mesh construction.

    jax >= 0.5 exposes ``jax.sharding.AxisType`` and ``jax.make_mesh`` grew
    an ``axis_types=`` keyword; on 0.4.x neither exists (every axis is
    implicitly Auto).  Feature-detect so the multi-device tests run on both.
    """
    import jax

    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(axis_shape, axis_names,
                             axis_types=(axis_type.Auto,) * len(axis_names))
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(axis_shape, axis_names)
    import math

    import numpy as np

    n = math.prod(axis_shape)
    devices = np.asarray(jax.devices()[:n]).reshape(axis_shape)
    return jax.sharding.Mesh(devices, axis_names)


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 600) -> str:
    """Run ``code`` in a subprocess with n fake CPU devices.

    Multi-device tests need XLA_FLAGS set before jax import, which cannot
    happen inside an already-initialized test process.  The tests directory
    is on the subprocess path so code strings can import helpers from this
    conftest (``from conftest import make_test_mesh``).
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    # prepend (not replace): packages reachable only via the caller's
    # PYTHONPATH (e.g. hypothesis in some setups) stay importable
    inherited = env.get("PYTHONPATH", "")
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.abspath(SRC), TESTS] + ([inherited] if inherited else []))
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)], env=env,
        capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


@pytest.fixture
def multidev():
    return run_with_devices
