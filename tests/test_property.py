"""Hypothesis property tests: datapath invariants + metamorphic traversal.

The first half checks algebraic invariants of single datapath stages.
The second half is *metamorphic*: instead of comparing a backend to an
oracle (which cannot catch a bug both sides share), it compares a
traversal to a transformed re-statement of the same question — triangle
permutation, rigid translation, extent monotonicity — across trace
backends (wavefront / fused pallas) and acceleration-structure builders
(lbvh / sah), all drawn as hypothesis parameters.
"""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="install via requirements-dev.txt")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.api import Scene, make_ray  # noqa: E402
from repro.core import (Box, Triangle, quadsort, ray_box_test,  # noqa: E402
                        euclidean_distance_sq, angular_distance_parts)

# subnormals excluded: XLA (CPU and TPU alike) flushes them to zero, so a
# comparator sees 1.4e-45 == 0.0 — correct under FTZ, "unsorted" to numpy.
finite = st.floats(min_value=-1e6, max_value=1e6, allow_nan=False,
                   width=32, allow_subnormal=False)


@given(st.lists(finite, min_size=4, max_size=4))
@settings(max_examples=200, deadline=None)
def test_quadsort_sorts_and_permutes(keys):
    k = jnp.asarray([keys], jnp.float32)
    idx = jnp.asarray([[0, 1, 2, 3]], jnp.int32)
    sk, si = quadsort(k, idx)
    sk, si = np.asarray(sk[0]), np.asarray(si[0])
    assert (sk[:-1] <= sk[1:]).all()  # sorted
    assert sorted(si.tolist()) == [0, 1, 2, 3]  # a permutation
    # payload consistency: sorted keys are the original keys at si
    np.testing.assert_array_equal(sk, np.asarray(keys, np.float32)[si])


@given(st.integers(1, 64), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=50, deadline=None)
def test_euclidean_nonneg_symmetric_zero(dim, seed):
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(3, dim)).astype(np.float32)
    b = rng.normal(size=(3, dim)).astype(np.float32)
    dab = np.asarray(euclidean_distance_sq(jnp.asarray(a), jnp.asarray(b)))
    dba = np.asarray(euclidean_distance_sq(jnp.asarray(b), jnp.asarray(a)))
    daa = np.asarray(euclidean_distance_sq(jnp.asarray(a), jnp.asarray(a)))
    assert (dab >= 0).all()
    np.testing.assert_allclose(dab, dba, rtol=1e-6)
    np.testing.assert_allclose(daa, 0.0, atol=1e-6)


@given(st.integers(1, 48), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=50, deadline=None)
def test_angular_matches_numpy_any_dim(dim, seed):
    """Multi-beat accumulation == direct sum for arbitrary dimension."""
    rng = np.random.default_rng(seed)
    q = rng.normal(size=(2, dim)).astype(np.float32)
    c = rng.normal(size=(2, dim)).astype(np.float32)
    dot, nrm = angular_distance_parts(jnp.asarray(q), jnp.asarray(c))
    np.testing.assert_allclose(np.asarray(dot), (q * c).sum(-1), rtol=2e-5,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(nrm), (c * c).sum(-1), rtol=2e-5)


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=50, deadline=None)
def test_raybox_scale_invariance(seed):
    """Scaling the scene and ray origin uniformly scales tmin."""
    rng = np.random.default_rng(seed)
    org = rng.uniform(-2, 2, (1, 3)).astype(np.float32)
    dirs = rng.normal(size=(1, 3)).astype(np.float32)
    lo = rng.uniform(-2, 1, (1, 4, 3)).astype(np.float32)
    hi = lo + rng.uniform(0.1, 2, (1, 4, 3)).astype(np.float32)
    s = 4.0  # power of two: exact in fp
    r1 = ray_box_test(make_ray(jnp.asarray(org), jnp.asarray(dirs)),
                      Box(jnp.asarray(lo), jnp.asarray(hi)))
    r2 = ray_box_test(make_ray(jnp.asarray(org * s), jnp.asarray(dirs)),
                      Box(jnp.asarray(lo * s), jnp.asarray(hi * s)))
    np.testing.assert_array_equal(np.asarray(r1.is_intersect),
                                  np.asarray(r2.is_intersect))
    hit = np.asarray(r1.is_intersect)
    np.testing.assert_allclose(np.asarray(r2.tmin)[hit],
                               np.asarray(r1.tmin)[hit] * s, rtol=1e-6)


@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 16))
@settings(max_examples=30, deadline=None)
def test_mask_equals_truncation(seed, dim):
    """Masked 16-lane beat == computing on the truncated vector."""
    from repro.core.datapath import euclidean_partial
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(16,)).astype(np.float32)
    b = rng.normal(size=(16,)).astype(np.float32)
    mask = jnp.asarray(np.arange(16) < dim)
    full = euclidean_partial(jnp.asarray(a), jnp.asarray(b), mask)
    trunc = ((a[:dim] - b[:dim]) ** 2).sum()
    np.testing.assert_allclose(np.asarray(full), trunc, rtol=1e-5)


# ---------------------------------------------------------------------------
# Metamorphic traversal properties (backends × builders)
# ---------------------------------------------------------------------------

TRACE_BACKENDS = ("wavefront", "pallas")
BUILDERS = ("lbvh", "sah")
SCENE_SEEDS = (0, 1)
N_TRI = (7, 60)

_scenes: dict = {}


def _soup(seed, n_tri):
    rng = np.random.default_rng(5000 * seed + n_tri)
    ctr = rng.uniform(-1, 1, (n_tri, 3)).astype(np.float32)
    d1 = rng.normal(scale=0.2, size=(n_tri, 3)).astype(np.float32)
    d2 = rng.normal(scale=0.2, size=(n_tri, 3)).astype(np.float32)
    return np.stack([ctr, ctr + d1, ctr + d2], axis=1)  # (N, 3verts, 3)


def _engine(key, verts, builder):
    """Scene+engine cache so hypothesis examples share compiled traces."""
    if key not in _scenes:
        scene = Scene.from_triangles(
            Triangle(jnp.asarray(verts[:, 0]), jnp.asarray(verts[:, 1]),
                     jnp.asarray(verts[:, 2])), builder=builder)
        _scenes[key] = scene.engine(pad_multiple=8, shard=1)
    return _scenes[key]


def _probe_rays(seed, n_rays=16):
    rng = np.random.default_rng(9000 + seed)
    org = rng.uniform(-3, -2, (n_rays, 3)).astype(np.float32)
    tgt = rng.uniform(-0.6, 0.6, (n_rays, 3)).astype(np.float32)
    return org, (tgt - org).astype(np.float32)


@given(seed=st.sampled_from(SCENE_SEEDS), n_tri=st.sampled_from(N_TRI),
       builder=st.sampled_from(BUILDERS),
       backend=st.sampled_from(TRACE_BACKENDS),
       perm_seed=st.sampled_from((0, 1, 2)))
@settings(max_examples=20, deadline=None)
def test_closest_hit_invariant_under_triangle_permutation(
        seed, n_tri, builder, backend, perm_seed):
    """Shuffling the soup must not change what a ray hits: ``t`` is the
    min over the same per-triangle tests (bit-equal), and the winning
    triangle is the same one modulo the permutation's index remap.  The
    tree differs completely (different Morton/SAH order), so this is a
    real end-to-end property, not a cache artifact."""
    verts = _soup(seed, n_tri)
    perm = np.random.default_rng(perm_seed).permutation(n_tri)
    e1 = _engine(("perm-base", seed, n_tri, builder), verts, builder)
    e2 = _engine(("perm", seed, n_tri, builder, perm_seed), verts[perm],
                 builder)
    org, dirs = _probe_rays(seed)
    rays = make_ray(jnp.asarray(org), jnp.asarray(dirs))
    r1 = e1.trace(rays, backend=backend)
    r2 = e2.trace(rays, backend=backend)
    np.testing.assert_array_equal(np.asarray(r1.hit), np.asarray(r2.hit))
    np.testing.assert_array_equal(np.asarray(r1.t), np.asarray(r2.t))
    hit = np.asarray(r1.hit)
    # scene2's index j holds original triangle perm[j]
    np.testing.assert_array_equal(perm[np.asarray(r2.tri_index)[hit]],
                                  np.asarray(r1.tri_index)[hit])


@given(seed=st.sampled_from(SCENE_SEEDS), n_tri=st.sampled_from(N_TRI),
       builder=st.sampled_from(BUILDERS),
       backend=st.sampled_from(TRACE_BACKENDS),
       shift=st.sampled_from(((1.0, -2.0, 0.5), (4.0, 4.0, -8.0),
                              (-0.25, 2.0, 1.0))))
@settings(max_examples=20, deadline=None)
def test_closest_hit_invariant_under_rigid_translation(
        seed, n_tri, builder, backend, shift):
    """Translating scene and ray origins together is a no-op up to fp
    rounding of the shifted coordinates: same hit set, same winning
    triangle, distances equal to a tight tolerance (exact equality is
    deliberately NOT asserted — the translation itself rounds)."""
    verts = _soup(seed, n_tri)
    t_vec = np.asarray(shift, np.float32)
    e1 = _engine(("shift-base", seed, n_tri, builder), verts, builder)
    e2 = _engine(("shift", seed, n_tri, builder, shift), verts + t_vec,
                 builder)
    org, dirs = _probe_rays(seed)
    rays1 = make_ray(jnp.asarray(org), jnp.asarray(dirs))
    rays2 = make_ray(jnp.asarray(org + t_vec), jnp.asarray(dirs))
    r1 = e1.trace(rays1, backend=backend)
    r2 = e2.trace(rays2, backend=backend)
    np.testing.assert_array_equal(np.asarray(r1.hit), np.asarray(r2.hit))
    hit = np.asarray(r1.hit)
    np.testing.assert_array_equal(np.asarray(r1.tri_index)[hit],
                                  np.asarray(r2.tri_index)[hit])
    np.testing.assert_allclose(np.asarray(r2.t)[hit],
                               np.asarray(r1.t)[hit], rtol=1e-3, atol=1e-3)


@given(seed=st.sampled_from(SCENE_SEEDS), n_tri=st.sampled_from(N_TRI),
       builder=st.sampled_from(BUILDERS),
       backend=st.sampled_from(TRACE_BACKENDS),
       ray_seed=st.integers(0, 2**31 - 1),
       extent=st.floats(0.5, 8.0, allow_nan=False, width=32))
@settings(max_examples=25, deadline=None)
def test_shadow_implies_any_hit_monotone_in_extent(
        seed, n_tri, builder, backend, ray_seed, extent):
    """Occlusion is monotone: a ``shadow`` hit (t >= epsilon) implies an
    ``any`` hit at the same extent (the epsilon only *discards* hits),
    and an ``any`` hit within extent e implies one within 2e (a larger
    search interval is a superset).  Exact set containment — no
    tolerances — for every backend and builder."""
    verts = _soup(seed, n_tri)
    engine = _engine(("mono", seed, n_tri, builder), verts, builder)
    rng = np.random.default_rng(ray_seed)
    org = rng.uniform(-3, -2, (16, 3)).astype(np.float32)
    tgt = rng.uniform(-0.6, 0.6, (16, 3)).astype(np.float32)
    dirs = (tgt - org).astype(np.float32)
    near = make_ray(jnp.asarray(org), jnp.asarray(dirs),
                    extent=jnp.full((16,), extent, jnp.float32))
    far = make_ray(jnp.asarray(org), jnp.asarray(dirs),
                   extent=jnp.full((16,), 2.0 * extent, jnp.float32))
    shadow = np.asarray(engine.trace(near, ray_type="shadow",
                                     backend=backend).hit)
    any_near = np.asarray(engine.trace(near, ray_type="any",
                                       backend=backend).hit)
    any_far = np.asarray(engine.trace(far, ray_type="any",
                                      backend=backend).hit)
    assert not (shadow & ~any_near).any(), "shadow hit without any-hit"
    assert not (any_near & ~any_far).any(), "any-hit lost at larger extent"


@given(seed=st.sampled_from(SCENE_SEEDS), n_tri=st.sampled_from(N_TRI),
       builder=st.sampled_from(BUILDERS),
       backend=st.sampled_from(TRACE_BACKENDS),
       ray_seed=st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_bvh8_closest_hit_bitmatches_bvh4(seed, n_tri, builder, backend,
                                          ray_seed):
    """The arity is pure scheduling: a BVH8 twin of the same soup commits
    the identical closest hit as the BVH4 tree — ``t`` bit-equal for fp32
    configs (both arities visit supersets of the same exact triangle
    tests, and the committed minimum is over the same candidate set)."""
    from repro.core.bvh import DatapathConfig

    verts = _soup(seed, n_tri)
    e4 = _engine(("arity4", seed, n_tri, builder), verts, builder)
    if ("arity8", seed, n_tri, builder) not in _scenes:
        scene8 = Scene.from_triangles(
            Triangle(jnp.asarray(verts[:, 0]), jnp.asarray(verts[:, 1]),
                     jnp.asarray(verts[:, 2])), builder=builder,
            config=DatapathConfig(arity=8))
        _scenes[("arity8", seed, n_tri, builder)] = scene8.engine(
            pad_multiple=8, shard=1)
    e8 = _scenes[("arity8", seed, n_tri, builder)]
    rng = np.random.default_rng(ray_seed)
    org = rng.uniform(-3, -2, (16, 3)).astype(np.float32)
    tgt = rng.uniform(-0.6, 0.6, (16, 3)).astype(np.float32)
    rays = make_ray(jnp.asarray(org), jnp.asarray(tgt - org))
    r4 = e4.trace(rays, backend=backend)
    r8 = e8.trace(rays, backend=backend)
    np.testing.assert_array_equal(np.asarray(r8.hit), np.asarray(r4.hit))
    np.testing.assert_array_equal(np.asarray(r8.t), np.asarray(r4.t))
    np.testing.assert_array_equal(np.asarray(r8.tri_index),
                                  np.asarray(r4.tri_index))
