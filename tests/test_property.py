"""Hypothesis property tests on the datapath's invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="install via requirements-dev.txt")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (Box, make_ray, quadsort, ray_box_test,
                        euclidean_distance_sq, angular_distance_parts)

# subnormals excluded: XLA (CPU and TPU alike) flushes them to zero, so a
# comparator sees 1.4e-45 == 0.0 — correct under FTZ, "unsorted" to numpy.
finite = st.floats(min_value=-1e6, max_value=1e6, allow_nan=False,
                   width=32, allow_subnormal=False)


@given(st.lists(finite, min_size=4, max_size=4))
@settings(max_examples=200, deadline=None)
def test_quadsort_sorts_and_permutes(keys):
    k = jnp.asarray([keys], jnp.float32)
    idx = jnp.asarray([[0, 1, 2, 3]], jnp.int32)
    sk, si = quadsort(k, idx)
    sk, si = np.asarray(sk[0]), np.asarray(si[0])
    assert (sk[:-1] <= sk[1:]).all()  # sorted
    assert sorted(si.tolist()) == [0, 1, 2, 3]  # a permutation
    # payload consistency: sorted keys are the original keys at si
    np.testing.assert_array_equal(sk, np.asarray(keys, np.float32)[si])


@given(st.integers(1, 64), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=50, deadline=None)
def test_euclidean_nonneg_symmetric_zero(dim, seed):
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(3, dim)).astype(np.float32)
    b = rng.normal(size=(3, dim)).astype(np.float32)
    dab = np.asarray(euclidean_distance_sq(jnp.asarray(a), jnp.asarray(b)))
    dba = np.asarray(euclidean_distance_sq(jnp.asarray(b), jnp.asarray(a)))
    daa = np.asarray(euclidean_distance_sq(jnp.asarray(a), jnp.asarray(a)))
    assert (dab >= 0).all()
    np.testing.assert_allclose(dab, dba, rtol=1e-6)
    np.testing.assert_allclose(daa, 0.0, atol=1e-6)


@given(st.integers(1, 48), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=50, deadline=None)
def test_angular_matches_numpy_any_dim(dim, seed):
    """Multi-beat accumulation == direct sum for arbitrary dimension."""
    rng = np.random.default_rng(seed)
    q = rng.normal(size=(2, dim)).astype(np.float32)
    c = rng.normal(size=(2, dim)).astype(np.float32)
    dot, nrm = angular_distance_parts(jnp.asarray(q), jnp.asarray(c))
    np.testing.assert_allclose(np.asarray(dot), (q * c).sum(-1), rtol=2e-5,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(nrm), (c * c).sum(-1), rtol=2e-5)


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=50, deadline=None)
def test_raybox_scale_invariance(seed):
    """Scaling the scene and ray origin uniformly scales tmin."""
    rng = np.random.default_rng(seed)
    org = rng.uniform(-2, 2, (1, 3)).astype(np.float32)
    dirs = rng.normal(size=(1, 3)).astype(np.float32)
    lo = rng.uniform(-2, 1, (1, 4, 3)).astype(np.float32)
    hi = lo + rng.uniform(0.1, 2, (1, 4, 3)).astype(np.float32)
    s = 4.0  # power of two: exact in fp
    r1 = ray_box_test(make_ray(jnp.asarray(org), jnp.asarray(dirs)),
                      Box(jnp.asarray(lo), jnp.asarray(hi)))
    r2 = ray_box_test(make_ray(jnp.asarray(org * s), jnp.asarray(dirs)),
                      Box(jnp.asarray(lo * s), jnp.asarray(hi * s)))
    np.testing.assert_array_equal(np.asarray(r1.is_intersect),
                                  np.asarray(r2.is_intersect))
    hit = np.asarray(r1.is_intersect)
    np.testing.assert_allclose(np.asarray(r2.tmin)[hit],
                               np.asarray(r1.tmin)[hit] * s, rtol=1e-6)


@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 16))
@settings(max_examples=30, deadline=None)
def test_mask_equals_truncation(seed, dim):
    """Masked 16-lane beat == computing on the truncated vector."""
    from repro.core.datapath import euclidean_partial
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(16,)).astype(np.float32)
    b = rng.normal(size=(16,)).astype(np.float32)
    mask = jnp.asarray(np.arange(16) < dim)
    full = euclidean_partial(jnp.asarray(a), jnp.asarray(b), mask)
    trunc = ((a[:dim] - b[:dim]) ** 2).sum()
    np.testing.assert_allclose(np.asarray(full), trunc, rtol=1e-5)
