"""BVH4 build + traversal benchmark: the RayCore-style workload the
datapath serves (quad-box + triangle jobs per ray).

Runs the same ray batch through the session ``QueryEngine``'s traversal
backends side by side:

* ``per_ray``   — vmapped per-ray ``while_loop`` oracle, where the whole
  batch iterates until the slowest ray drains,
* ``wavefront`` — batch-level frontier loop, one batched OpQuadbox job per
  round, with the full SoA loop state a jit carry that round-trips HBM
  every round, and
* ``pallas``    — the fused traversal kernel (``kernels/traverse.py``):
  the same loop runs to completion *inside* one kernel with ray state and
  stacks on-chip; its row reports the loop-state HBM traffic that
  residency removes (bit-identical hits/counters, so the delta is pure
  memory scheduling),

plus the wavefront any-hit mode (occlusion queries retire on first hit).
The engine owns the jit cache, so the second (timed) call measures the
compiled steady state.  Rows report rays/sec and the per-ray datapath job
counts so scheduling improvements show up as measurements, not guesses.

Every row carries ``devices=`` / ``chunk_size=`` so the execution schedule
is part of the measurement; on a multi-device host (or under
``XLA_FLAGS=--xla_force_host_platform_device_count=N``) a sharded-vs-
single-device comparison section is appended (``core/dispatch.py``).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import Scene, Triangle, make_ray


def _time(fn, rays):
    rec = fn(rays)
    jax.block_until_ready(rec.t)
    t0 = time.perf_counter()
    rec = fn(rays)
    jax.block_until_ready(rec.t)
    return rec, time.perf_counter() - t0


def run(rows):
    rng = np.random.default_rng(0)
    n_tri = 2000
    ctr = rng.uniform(-1, 1, (n_tri, 3)).astype(np.float32)
    d1 = rng.normal(scale=0.08, size=(n_tri, 3)).astype(np.float32)
    d2 = rng.normal(scale=0.08, size=(n_tri, 3)).astype(np.float32)
    tri = Triangle(jnp.asarray(ctr), jnp.asarray(ctr + d1),
                   jnp.asarray(ctr + d2))

    t0 = time.perf_counter()
    scene = Scene.from_triangles(tri)
    jax.block_until_ready(scene.bvh.node_lo)
    rows.append(("bvh4_build_2k_tris", (time.perf_counter() - t0) * 1e6,
                 f"nodes={scene.bvh.node_lo.shape[0]}"))

    n_rays = 256
    org = rng.uniform(-3, -2, (n_rays, 3)).astype(np.float32)
    tgt = rng.uniform(-0.5, 0.5, (n_rays, 3)).astype(np.float32)
    rays = make_ray(jnp.asarray(org), jnp.asarray(tgt - org))

    engine = scene.engine(shard=1)
    backends = {
        "per_ray": lambda r: engine.trace(r, backend="per_ray"),
        "wavefront": lambda r: engine.trace(r, backend="wavefront"),
        "wavefront_anyhit": lambda r: engine.trace(r, ray_type="any",
                                                   backend="wavefront"),
    }
    for name, fn in backends.items():
        rec, dt = _time(fn, rays)
        rows.append((f"traversal_{name}_256rays_2k_tris", dt / n_rays * 1e6,
                     f"rays_per_s={n_rays / dt:.3e};"
                     f"quadbox_jobs_per_ray={float(rec.quadbox_jobs.mean()):.1f};"
                     f"tri_jobs_per_ray={float(rec.triangle_jobs.mean()):.1f};"
                     f"hit_rate={float(rec.hit.mean()):.2f};"
                     f"batched_rounds={int(rec.rounds)};"
                     f"devices=1;chunk_size=none"))

    # fused Pallas traversal: the whole round loop inside one kernel.  The
    # wavefront loop's carry (stack + sp + best-hit + counters + done) is
    # HBM-resident state re-materialized every round; the fused kernel
    # keeps it in VMEM/VREGs, so `rounds x state` round trips disappear.
    from repro.core.traversal import STACK_SIZE
    rec, dt = _time(lambda r: engine.trace(r, backend="pallas"), rays)
    state_bytes = STACK_SIZE * 4 + 4 * 5 + 1  # stack + sp/t/tri/qb/ntri + done
    removed_mb = 2 * int(rec.rounds) * n_rays * state_bytes / 1e6  # rd+wr
    rows.append(("traversal_pallas_fused_256rays_2k_tris", dt / n_rays * 1e6,
                 f"rays_per_s={n_rays / dt:.3e};"
                 f"quadbox_jobs_per_ray={float(rec.quadbox_jobs.mean()):.1f};"
                 f"tri_jobs_per_ray={float(rec.triangle_jobs.mean()):.1f};"
                 f"hit_rate={float(rec.hit.mean()):.2f};"
                 f"batched_rounds={int(rec.rounds)};"
                 f"loop_state_bytes_per_ray={state_bytes};"
                 f"hbm_loop_traffic_removed_mb={removed_mb:.2f};"
                 f"devices=1;chunk_size=none"))

    # chunked streaming: same batch through fixed-size microbatch blocks
    # (one compiled function for all chunks; peak memory ~ chunk_size rows)
    chunked = scene.engine(shard=1, chunk_size=64)
    rec, dt = _time(lambda r: chunked.trace(r, backend="wavefront"), rays)
    rows.append(("traversal_wavefront_chunked_256rays_2k_tris",
                 dt / n_rays * 1e6,
                 f"rays_per_s={n_rays / dt:.3e};"
                 f"jit_cache_entries={chunked.cache_info().entries};"
                 f"devices=1;chunk_size=64"))

    # sharded-vs-single-device comparison (data-parallel rays over the
    # host mesh; bit-identical results, so the ratio is pure scheduling)
    n_dev = jax.local_device_count()
    if n_dev > 1:
        _, dt_single = _time(lambda r: engine.trace(r, backend="wavefront"),
                             rays)
        sharded = scene.engine(shard="auto")
        rec, dt_sh = _time(lambda r: sharded.trace(r, backend="wavefront"),
                           rays)
        rows.append((f"traversal_wavefront_sharded_{n_dev}dev_256rays",
                     dt_sh / n_rays * 1e6,
                     f"rays_per_s={n_rays / dt_sh:.3e};"
                     f"speedup_vs_single={dt_single / dt_sh:.2f}x;"
                     f"batched_rounds={int(rec.rounds)};"
                     f"devices={n_dev};chunk_size=none"))
