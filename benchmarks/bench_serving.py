"""Serving benchmarks: continuous batching vs per-request dispatch.

An open-loop synthetic trace (the million-user shape, scaled down):
request arrivals are Poisson at a target rate, each request a *small*
mixed-method query (a few rays to trace, a few points to look up) — far
below the lane multiple the compiled kernels want.  The server coalesces
them (DESIGN.md §10); the baseline calls the engine once per request in
arrival order.  Open loop means arrivals do not wait for responses, so
queueing pressure is real: a slow server accumulates backlog and its
tail latency shows it.

Reported per row: sustained throughput (completed requests / makespan),
p50/p99 response latency, mean requests per executed batch (the
occupancy win — must exceed 1 for coalescing to be doing anything), mean
row fill of the padded batches, and the throughput speedup over the
per-request baseline.

Run standalone: ``python -m benchmarks.bench_serving --quick``.
"""
from __future__ import annotations

import asyncio
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import PointCloudScene, QueryEngine, Scene, make_ray
from repro.obs import CompileTracker
from repro.serving import QueryServer


def _build_engine(rng, n_tri=300, n_pts=2048):
    ctr = rng.uniform(-1, 1, (n_tri, 3)).astype(np.float32)
    d1 = rng.normal(scale=0.12, size=(n_tri, 3)).astype(np.float32)
    d2 = rng.normal(scale=0.12, size=(n_tri, 3)).astype(np.float32)
    scene = Scene.from_triangles(np.stack([ctr, ctr + d1, ctr + d2], 1))
    cloud = PointCloudScene.from_points(
        rng.normal(size=(n_pts, 3)).astype(np.float32))
    return QueryEngine(scene=scene, cloud=cloud, pad_multiple=8, shard=1)


def _make_jobs(rng, n_requests):
    """The mixed open-loop workload: 50% trace, 30% nearest, 20%
    count_within, 1-8 rows each (requests far smaller than a lane)."""
    jobs = []
    for i in range(n_requests):
        n = int(rng.integers(1, 9))
        u = rng.random()
        if u < 0.5:
            org = rng.uniform(-3, -2, (n, 3)).astype(np.float32)
            tgt = rng.uniform(-0.5, 0.5, (n, 3)).astype(np.float32)
            rays = make_ray(jnp.asarray(org), jnp.asarray(tgt - org))
            jobs.append(("trace", rays, {}))
        elif u < 0.8:
            q = jnp.asarray(rng.normal(size=(n, 3)).astype(np.float32))
            jobs.append(("nearest", q, {"k": 8}))
        else:
            q = jnp.asarray(rng.normal(size=(n, 3)).astype(np.float32))
            jobs.append(("count_within", q, {"radius": 0.5}))
    return jobs


def _warm(engine, jobs, max_batch_rows):
    """Compile every (method, ladder-size) program the run will touch, so
    the measured window is steady-state serving, not tracing."""
    sizes = set()
    s = 1
    while s <= max_batch_rows:
        sizes.add(s)
        s *= 2
    sizes.add(max_batch_rows)
    methods = {}
    for kind, payload, kw in jobs:
        methods.setdefault(kind, (payload, kw))
    for kind, (payload, kw) in methods.items():
        for n in sorted(sizes):
            reps = jax.tree_util.tree_map(
                lambda x: jnp.concatenate([x[:1]] * n, axis=0), payload)
            jax.block_until_ready(getattr(engine, kind)(reps, **kw))


def _run_baseline(engine, jobs):
    t0 = time.perf_counter()
    for kind, payload, kw in jobs:
        jax.block_until_ready(getattr(engine, kind)(payload, **kw))
    return time.perf_counter() - t0


def _run_served(engine, jobs, arrivals, *, max_batch_rows, max_wait):
    async def drive():
        async with QueryServer(engine, max_batch_rows=max_batch_rows,
                               max_wait=max_wait,
                               queue_limit=len(jobs) + 1) as server:
            loop = asyncio.get_running_loop()
            t0 = loop.time()

            async def fire(job, at):
                delay = at - (loop.time() - t0)
                if delay > 0:
                    await asyncio.sleep(delay)
                kind, payload, kw = job
                return await getattr(server, kind)(payload, **kw)

            tasks = [asyncio.ensure_future(fire(j, a))
                     for j, a in zip(jobs, arrivals)]
            await asyncio.gather(*tasks)
            return loop.time() - t0, server.stats()

    return asyncio.run(drive())


def run(rows, *, n_requests=400, qps=2000.0, max_batch_rows=64,
        max_wait=2e-3):
    rng = np.random.default_rng(0)
    engine = _build_engine(rng)
    jobs = _make_jobs(rng, n_requests)
    arrivals = np.cumsum(rng.exponential(1.0 / qps, n_requests))

    _warm(engine, jobs, max_batch_rows)

    base_s = _run_baseline(engine, jobs)
    # the served window should be steady state: the quantized ladder was
    # warmed above, so any jit tracing in here is a regression the
    # trajectory should show (compiles_measured in the derived column)
    with CompileTracker() as tracker:
        makespan, stats = _run_served(engine, jobs, arrivals,
                                      max_batch_rows=max_batch_rows,
                                      max_wait=max_wait)

    total_req = sum(s.requests for s in stats.values())
    total_batches = sum(s.batches for s in stats.values())
    occupancy = total_req / max(1, total_batches)
    fill = (sum(s.mean_fill * s.batches for s in stats.values())
            / max(1, total_batches))
    # request-weighted latency percentiles across methods
    p50 = max(s.p50_ms for s in stats.values())
    p99 = max(s.p99_ms for s in stats.values())
    served_qps = total_req / makespan
    base_qps = n_requests / base_s

    rows.append((
        f"serving_openloop_mixed_{n_requests}req", makespan / total_req * 1e6,
        f"offered_qps={qps:.0f};sustained_qps={served_qps:.3e};"
        f"baseline_qps={base_qps:.3e};"
        f"speedup_vs_per_request={served_qps / base_qps:.2f}x;"
        f"requests_per_batch={occupancy:.2f};mean_fill={fill:.2f};"
        f"p50_ms={p50:.2f};p99_ms={p99:.2f};"
        f"batches={total_batches};"
        f"compiles_measured={tracker.compiles};"
        f"devices={jax.local_device_count()};"
        f"max_batch_rows={max_batch_rows}"))

    for method in sorted(stats):
        s = stats[method]
        rows.append((
            f"serving_{method}", (makespan / max(1, s.requests)) * 1e6,
            f"requests={s.requests};batches={s.batches};"
            f"requests_per_batch={s.requests_per_batch:.2f};"
            f"mean_batch_rows={s.mean_batch_rows:.1f};"
            f"mean_fill={s.mean_fill:.2f};"
            f"p50_ms={s.p50_ms:.2f};p99_ms={s.p99_ms:.2f};"
            f"flush_full={s.flush_full};flush_timer={s.flush_timer};"
            f"flush_deadline={s.flush_deadline};"
            f"flush_drain={s.flush_drain}"))
    return rows


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small trace (CI smoke)")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--qps", type=float, default=None)
    args = ap.parse_args()
    n = args.requests or (120 if args.quick else 400)
    qps = args.qps or (1000.0 if args.quick else 2000.0)
    rows: list = []
    run(rows, n_requests=n, qps=qps)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}")
    occ = [d for _, _, d in rows if "requests_per_batch" in d]
    first = dict(kv.split("=", 1) for kv in occ[0].split(";"))
    assert float(first["requests_per_batch"]) > 1.0, \
        "coalescing never batched more than one request"


if __name__ == "__main__":
    main()
