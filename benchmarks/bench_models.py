"""Model-stack micro-benchmarks on CPU smoke configs: step time and
tokens/s for a representative arch of each family (structure check — the
real perf story is the roofline analysis on the production mesh)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.models import init_params
from repro.optim import adamw
from repro.parallel.ctx import NO_PARALLEL as ctx
from repro.train import make_train_step

ARCHS = ["smollm-360m", "jamba-1.5-large-398b", "rwkv6-7b",
         "phi3.5-moe-42b-a6.6b", "deepseek-v3-671b"]


def run(rows):
    rng = np.random.default_rng(0)
    for arch in ARCHS:
        cfg = get_smoke(arch)
        b, t = 4, 64
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, t)), jnp.int32)
        batch = {"tokens": toks, "labels": toks}
        params = init_params(jax.random.PRNGKey(0), cfg)
        opt = adamw.init(params)
        ocfg = adamw.AdamWConfig()
        step = jax.jit(make_train_step(cfg, ctx, ocfg))
        params, opt, _ = step(params, opt, batch)  # compile
        t0 = time.perf_counter()
        iters = 5
        for _ in range(iters):
            params, opt, m = step(params, opt, batch)
        jax.block_until_ready(m["loss"])
        dt = (time.perf_counter() - t0) / iters
        rows.append((f"smoke_train_step_{cfg.name}", dt * 1e6,
                     f"tokens_per_s={b * t / dt:.3e}"))
