"""Datapath benchmarks mirroring the paper's tables.

* Table V (IO/throughput)  -> jobs/s per opcode through the batched core
  ops and through the unified Pallas kernel (interpret mode on CPU — the
  numbers are CPU-relative; the structure is what carries to TPU).
* Table VII (dataflow)     -> stage-for-stage equivalence is asserted by
  tests; here we run the full randomized soak (100k jobs/op) the paper
  describes and report mismatch counts against the f64 oracles.
* Table VIII (FU utilization) -> static functional-unit census: count
  add/mul/compare/select ops in each mode's jaxpr and compare against the
  paper's per-stage totals (adds=24/..., muls=24/9/16/16, ...).
"""
from __future__ import annotations

import time
from collections import Counter

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (Box, Triangle, make_ray, ray_box_test,
                        ray_triangle_test)
from repro.core.datapath import angular_partial, euclidean_partial


def _time(f, *args, iters=20):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else \
        jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def _rand_inputs(n, seed=0):
    rng = np.random.default_rng(seed)
    org = rng.uniform(-3, 3, (n, 3)).astype(np.float32)
    dirs = rng.normal(size=(n, 3)).astype(np.float32)
    ray = make_ray(jnp.asarray(org), jnp.asarray(dirs))
    lo = rng.uniform(-3, 2, (n, 4, 3)).astype(np.float32)
    hi = lo + rng.uniform(0, 3, (n, 4, 3)).astype(np.float32)
    tri = Triangle(*(jnp.asarray(rng.normal(size=(n, 3)).astype(np.float32))
                     for _ in range(3)))
    va = jnp.asarray(rng.normal(size=(n, 16)).astype(np.float32))
    vb = jnp.asarray(rng.normal(size=(n, 16)).astype(np.float32))
    return ray, Box(jnp.asarray(lo), jnp.asarray(hi)), tri, va, vb


def bench_throughput(rows):
    """Table V analogue: per-opcode throughput of the batched datapath."""
    n = 1 << 16
    ray, boxes, tri, va, vb = _rand_inputs(n)
    ops = {
        "quadbox": jax.jit(ray_box_test),
        "triangle": jax.jit(ray_triangle_test),
        "euclidean": jax.jit(euclidean_partial),
        "angular": jax.jit(angular_partial),
    }
    args = {
        "quadbox": (ray, boxes), "triangle": (ray, tri),
        "euclidean": (va, vb), "angular": (va, vb),
    }
    for name, fn in ops.items():
        dt = _time(fn, *args[name])
        rows.append((f"datapath_{name}", dt / n * 1e6,
                     f"jobs_per_s={n / dt:.3e}"))


# paper Table VIII totals per mode (adds, muls, compares incl. sort CAS)
TABLE_VIII = {
    "quadbox": {"add": 24, "mul": 24, "cmp": 36 + 4 + 2 * 5},
    "triangle": {"add": 9 + 6 + 3 + 2 + 2, "mul": 9 + 6 + 3, "cmp": 5},
    "euclidean": {"add": 16 + 8 + 4 + 2 + 1 + 1, "mul": 16, "cmp": 0},
    "angular": {"add": 8 + 4 + 2 + 2, "mul": 16, "cmp": 0},
}

_ADD = {"add", "sub"}
_MUL = {"mul"}
_CMP = {"lt", "gt", "le", "ge", "eq", "ne", "max", "min"}


def _census(fn, *args):
    """Count scalar FP ops per job: each vectorised primitive contributes
    its output element count (one jnp sub over (4,3) = 12 RTL adders)."""
    jaxpr = jax.make_jaxpr(fn)(*args)
    c = Counter()

    def walk(jx):
        for eqn in jx.eqns:
            n = 1
            for v in eqn.outvars:
                if hasattr(v.aval, "shape"):
                    k = 1
                    for s in v.aval.shape:
                        k *= s
                    n = max(n, k)
            c[eqn.primitive.name] += n
            for p in eqn.params.values():
                if hasattr(p, "jaxpr"):
                    walk(p.jaxpr)
    walk(jaxpr.jaxpr)
    return {
        "add": sum(v for k, v in c.items() if k in _ADD),
        "mul": sum(v for k, v in c.items() if k in _MUL),
        "cmp": sum(v for k, v in c.items() if k in _CMP),
    }


def bench_fu_census(rows):
    """Table VIII analogue: static FP functional-unit census per mode.

    Single-job jaxprs; the datapath code vectorises the same op over the
    batch, so op counts per job == FU instances per stage slot in the RTL.
    """
    ray, boxes, tri, va, vb = _rand_inputs(1)
    census = {
        "quadbox": _census(ray_box_test, ray, boxes),
        "triangle": _census(ray_triangle_test, ray, tri),
        "euclidean": _census(euclidean_partial, va, vb),
        "angular": _census(angular_partial, va, vb),
    }
    for mode, got in census.items():
        want = TABLE_VIII[mode]
        ratio = {k: f"{got[k]}/{want[k]}" for k in want}
        # census rows carry no timing: us_per_call=None -> JSON null
        # (0.0 used to read as "measured and instantaneous")
        rows.append((f"fu_census_{mode}", None,
                     f"ops_vs_tableVIII(add;mul;cmp)={ratio}"))
    # Known structural deviations vs Table VIII (documented in DESIGN.md):
    # quadbox sign-swaps lower to signbit+select (not FP compares) on TPU,
    # and make_ray precomputation lives outside the datapath; triangle's
    # kx/ky/kz crossbar lowers to select muxes counted under 'cmp'.


def bench_random_soak(rows):
    """The paper's randomized functional soak, 100k jobs per mode."""
    import sys
    import os
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tests"))
    from test_datapath_random import _f64_box_oracle, _f64_tri_oracle

    n = 100_000
    rng = np.random.default_rng(42)
    org = rng.uniform(-4, 4, (n, 3)).astype(np.float32)
    dirs = rng.normal(size=(n, 3)).astype(np.float32)
    dirs[np.all(dirs == 0, 1)] = (1, 0, 0)
    lo = rng.uniform(-3, 2, (n, 4, 3)).astype(np.float32)
    hi = lo + rng.uniform(0, 3, (n, 4, 3)).astype(np.float32)
    ray = make_ray(jnp.asarray(org), jnp.asarray(dirs))
    t0 = time.perf_counter()
    out = ray_box_test(ray, Box(jnp.asarray(lo), jnp.asarray(hi)))
    jax.block_until_ready(out)
    dt = time.perf_counter() - t0
    _, _, hit64 = _f64_box_oracle(org, dirs, lo, hi)
    got = np.zeros((n, 4), bool)
    bi = np.asarray(out.box_index)
    for s in range(4):
        got[np.arange(n), bi[:, s]] = np.asarray(out.is_intersect[:, s])
    mism = int((got != hit64).sum())
    rows.append(("soak_raybox_100k", dt / n * 1e6,
                 f"hit_bit_mismatches={mism}/{4 * n}"))

    a = rng.normal(size=(n, 3)).astype(np.float32) * 2
    b = a + rng.normal(scale=0.7, size=(n, 3)).astype(np.float32)
    c = a + rng.normal(scale=0.7, size=(n, 3)).astype(np.float32)
    tri = Triangle(jnp.asarray(a), jnp.asarray(b), jnp.asarray(c))
    t0 = time.perf_counter()
    out = ray_triangle_test(ray, tri)
    jax.block_until_ready(out)
    dt = time.perf_counter() - t0
    _, hit64 = _f64_tri_oracle(org, dirs, a, b, c)
    mism = int((np.asarray(out.hit) != hit64).sum())
    rows.append(("soak_raytriangle_100k", dt / n * 1e6,
                 f"hit_bit_mismatches={mism}/{n}"))


def run(rows):
    bench_throughput(rows)
    bench_fu_census(rows)
    bench_random_soak(rows)
