"""Benchmark runner: ``python -m benchmarks.run [--quick] [--json PATH]``.

Prints ``name,us_per_call,derived`` CSV rows — one section per paper
table/figure (datapath throughput = Table V, FU census = Table VIII,
randomized soak = §I, traversal = the RayCore workload, kNN = the
generalized modes, model smoke = framework sanity).  The roofline analysis
(production mesh) is separate: ``python -m benchmarks.roofline --all``.

``--json PATH`` additionally writes the rows as machine-readable JSON
(``name``, ``us_per_call``, parsed ``derived`` metrics) so the perf
trajectory can be tracked across PRs — CI uploads ``BENCH_quick.json`` as
an artifact on every run.
"""
from __future__ import annotations

import argparse
import json


def _split_top_level(s: str, sep: str = ";") -> list:
    """Split on ``sep`` only outside (), {}, [] — metric names/values may
    contain separators (e.g. ``ops_vs_tableVIII(add;mul;cmp)={...}``)."""
    parts, depth, cur = [], 0, []
    for ch in s:
        if ch in "({[":
            depth += 1
        elif ch in ")}]":
            depth -= 1
        if ch == sep and depth == 0:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    parts.append("".join(cur))
    return parts


def parse_derived(derived: str) -> dict:
    """``k=v;k=v`` derived strings -> a metrics dict (floats where they
    parse, strings otherwise; bare fragments collect under ``notes``)."""
    out: dict = {}
    for part in _split_top_level(derived):
        part = part.strip()
        if not part:
            continue
        if "=" not in part:
            out.setdefault("notes", []).append(part)
            continue
        key, val = part.split("=", 1)
        try:
            out[key] = float(val[:-1] if val.endswith("x") else val)
        except ValueError:
            out[key] = val
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="skip the slower model-stack section")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write the rows as machine-readable JSON")
    args = ap.parse_args()

    from . import bench_build, bench_datapath, bench_knn, bench_traversal

    rows: list[tuple] = []

    def flush():
        # incremental JSON: rewrite after every section so a crash in a
        # later benchmark still leaves the completed rows on disk (CI
        # uploads the file unconditionally — a partial trajectory beats
        # an empty artifact)
        if not args.json:
            return
        payload = [{"name": name, "us_per_call": round(us, 3),
                    "derived": parse_derived(derived)}
                   for name, us, derived in rows]
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")

    sections = [bench_datapath.run, bench_traversal.run, bench_build.run,
                bench_knn.run]
    if not args.quick:
        from . import bench_models
        sections.append(bench_models.run)
    for section in sections:
        section(rows)
        flush()

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}")
    if args.json:
        print(f"wrote {len(rows)} rows to {args.json}")


if __name__ == "__main__":
    main()
