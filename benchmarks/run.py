"""Benchmark runner: ``python -m benchmarks.run [--quick]``.

Prints ``name,us_per_call,derived`` CSV rows — one section per paper
table/figure (datapath throughput = Table V, FU census = Table VIII,
randomized soak = §I, traversal = the RayCore workload, kNN = the
generalized modes, model smoke = framework sanity).  The roofline analysis
(production mesh) is separate: ``python -m benchmarks.roofline --all``.
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="skip the slower model-stack section")
    args = ap.parse_args()

    from . import bench_datapath, bench_knn, bench_traversal

    rows: list[tuple] = []
    bench_datapath.run(rows)
    bench_traversal.run(rows)
    bench_knn.run(rows)
    if not args.quick:
        from . import bench_models
        bench_models.run(rows)

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}")


if __name__ == "__main__":
    main()
