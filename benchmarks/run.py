"""Benchmark runner: ``python -m benchmarks.run [--quick] [--json PATH]``.

Prints ``name,us_per_call,derived`` CSV rows — one section per paper
table/figure (datapath throughput = Table V, FU census = Table VIII,
randomized soak = §I, traversal = the RayCore workload, kNN = the
generalized modes, model smoke = framework sanity).  The roofline analysis
(production mesh) is separate: ``python -m benchmarks.roofline --all``.

``--json PATH`` additionally writes the rows as machine-readable JSON so
the perf trajectory can be tracked across PRs; ``--quick`` writes
``BENCH_quick.json`` at the repo root even without ``--json`` (CI uploads
it as an artifact on every run).

**Row schema.** Sections append ``(name, us_per_call, derived)`` tuples:

* ``name`` — stable row identifier (the trajectory joins on it).
* ``us_per_call`` — measured wall microseconds per call, or **None** for
  rows that report derived metrics only (FU censuses, build-quality
  ratios).  None serializes as JSON ``null`` and prints as an empty CSV
  field — never ``0.0``, which would read as "measured and
  instantaneous" to a trajectory diff.
* ``derived`` — ``k=v;k=v`` string, parsed into a dict for JSON by
  :func:`parse_derived`.

Rows from the datapath config sweep (``bench_sweep``) lead their derived
string with ``config=<tag>``; the JSON writer *promotes* that key to a
top-level ``config`` column (null for every other section's rows), so the
trajectory can group by datapath twin without parsing row names — the
``BENCH_quick.json`` schema guard in CI pins the column's presence.

Every JSON row additionally carries the provenance columns the
trajectory needs to be comparable across machines and commits:
``device`` (platform kind + count), ``jax_version``, and ``git_rev`` —
plus an ``obs`` column with the telemetry slice of the section that
produced the row (jit compiles, engine cache hits/misses, pad-waste
fraction), taken from ``repro.obs`` which this runner enables
(DESIGN.md §11).  Timings are therefore measured with telemetry *on* —
the recording overhead is a few counter bumps per engine call, and it is
identical for every row, so the trajectory stays self-consistent.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess


def _split_top_level(s: str, sep: str = ";") -> list:
    """Split on ``sep`` only outside (), {}, [] — metric names/values may
    contain separators (e.g. ``ops_vs_tableVIII(add;mul;cmp)={...}``)."""
    parts, depth, cur = [], 0, []
    for ch in s:
        if ch in "({[":
            depth += 1
        elif ch in ")}]":
            depth -= 1
        if ch == sep and depth == 0:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    parts.append("".join(cur))
    return parts


def parse_derived(derived: str) -> dict:
    """``k=v;k=v`` derived strings -> a metrics dict (floats where they
    parse, strings otherwise; bare fragments collect under ``notes``)."""
    out: dict = {}
    for part in _split_top_level(derived):
        part = part.strip()
        if not part:
            continue
        if "=" not in part:
            out.setdefault("notes", []).append(part)
            continue
        key, val = part.split("=", 1)
        try:
            out[key] = float(val[:-1] if val.endswith("x") else val)
        except ValueError:
            out[key] = val
    return out


def _git_rev() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        ).stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def _obs_slice(before: dict, after: dict) -> dict:
    """The telemetry delta one benchmark section produced: jit compiles,
    engine compiled-fn cache hits/misses, and the section's pad-waste
    fraction (``repro.obs.snapshot()`` keys; DESIGN.md §11)."""
    c0, c1 = before["counters"], after["counters"]

    def delta(key):
        return c1.get(key, 0) - c0.get(key, 0)

    real = delta("engine.rows.real")
    padded = delta("engine.rows.padded")
    return {
        "compiles": after["jit"]["compiles"] - before["jit"]["compiles"],
        "cache_hits": delta("engine.cache.hits"),
        "cache_misses": delta("engine.cache.misses"),
        "pad_waste_fraction": (round(1.0 - real / padded, 6)
                               if padded else None),
    }


def provenance() -> dict:
    """The stable per-row schema columns: where/what produced the row."""
    import jax
    return {
        "device": f"{jax.devices()[0].platform}x{jax.local_device_count()}",
        "jax_version": jax.__version__,
        "git_rev": _git_rev(),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="skip the slower model-stack section and write "
                         "BENCH_quick.json at the repo root")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write the rows as machine-readable JSON")
    args = ap.parse_args()

    json_path = args.json
    if json_path is None and args.quick:
        # --quick always leaves the trajectory artifact behind, wherever
        # it was launched from
        json_path = os.path.join(
            os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
            "BENCH_quick.json")

    from repro import obs

    from . import (bench_build, bench_datapath, bench_knn, bench_serving,
                   bench_sweep, bench_traversal)

    obs.enable()  # every row gets its section's telemetry slice

    rows: list[tuple] = []
    obs_cols: list = []  # parallel to rows: the producing section's slice
    prov = provenance()

    def flush():
        # incremental JSON: rewrite after every section so a crash in a
        # later benchmark still leaves the completed rows on disk (CI
        # uploads the file unconditionally — a partial trajectory beats
        # an empty artifact)
        if not json_path:
            return
        payload = []
        for i, (name, us, derived) in enumerate(rows):
            metrics = parse_derived(derived)
            # the config sweep's datapath-twin tag is a first-class
            # trajectory column, not a buried metric (null elsewhere)
            config = metrics.pop("config", None)
            payload.append(dict(
                name=name,
                us_per_call=None if us is None else round(us, 3),
                config=config, derived=metrics, **prov,
                obs=obs_cols[i] if i < len(obs_cols) else None))
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")

    flush()  # schema-stable empty file exists from the first moment
    sections = [bench_datapath.run, bench_traversal.run, bench_build.run,
                bench_sweep.run, bench_knn.run,
                lambda rows: bench_serving.run(rows, n_requests=120,
                                               qps=1000.0)
                if args.quick else bench_serving.run(rows)]
    if not args.quick:
        from . import bench_models
        sections.append(bench_models.run)
    for section in sections:
        before = obs.snapshot()
        n0 = len(rows)
        section(rows)
        sl = _obs_slice(before, obs.snapshot())
        obs_cols.extend([sl] * (len(rows) - n0))
        flush()

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        us_col = "" if us is None else f"{us:.3f}"
        print(f"{name},{us_col},{derived}")
    if json_path:
        print(f"wrote {len(rows)} rows to {json_path}")


if __name__ == "__main__":
    main()
