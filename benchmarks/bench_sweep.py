"""Datapath config sweep: the arity/stack/precision/codec grid, measured.

The :class:`~repro.core.bvh.DatapathConfig` tentpole makes the paper's
fixed datapath choices (BVH4, fp32 boxes, a 64-deep stack) *knobs*.  This
section answers the question the knobs exist for: what does each twin
actually cost and save?  For every config in the sweep grid it builds the
clustered quality workload, traces a shared probe batch through the
wavefront engine, and emits one row with

* tree quality — ``sah_cost``, measured mean box-test / OpTriangle jobs
  per ray (the conservative-codec job *overhead* is the superset margin
  vs the same-arity exact twin, visible directly in the trajectory),
* memory — ``bytes_per_node`` (what the fused kernel keeps resident) and
  the node ``compression_ratio`` vs plain fp32,
* shape — ``depth``, ``n_nodes``, measured ``mean_branching_factor``,
* latency — steady-state wavefront trace microseconds per ray, and the
  batch-level round count.

Every row's ``derived`` string leads with ``config=<tag>``; the JSON
writer promotes that key to a top-level column (null for rows from other
sections), so the trajectory can group/filter by twin without parsing
names.  Closest-hit results are bit-identical across the whole grid (the
test matrix pins it); the sweep exists to price the *scheduling*
differences that remain.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import Scene, make_ray
from repro.core.build import clustered_soup
from repro.core.bvh import DatapathConfig

#: the sweep grid: arity x node codec (+ one tight-stack probe per arity).
#: fp32/fp32 twins are the exact baselines their codec twins are measured
#: against; the s16 twins price a small on-chip stack (RayCore-style).
SWEEP_CONFIGS = (
    DatapathConfig(),
    DatapathConfig(precision="bf16"),
    DatapathConfig(precision="bf16", node_format="compressed"),
    DatapathConfig(arity=8),
    DatapathConfig(arity=8, precision="bf16"),
    DatapathConfig(arity=8, precision="bf16", node_format="compressed"),
    DatapathConfig(stack_size=16),
    DatapathConfig(arity=8, stack_size=16),
)


def _timed(fn, *args):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    out = fn(*args)
    jax.block_until_ready(out)
    return out, time.perf_counter() - t0


def run(rows, builder: str = "sah"):
    rng = np.random.default_rng(0)
    tri = clustered_soup(rng, n_clusters=12, per_cluster=250)
    n_tri = int(tri.a.shape[0])

    n_rays = 512
    org = rng.uniform(-7, -6, (n_rays, 3)).astype(np.float32)
    tgt = rng.uniform(-4, 4, (n_rays, 3)).astype(np.float32)
    rays = make_ray(jnp.asarray(org), jnp.asarray(tgt - org))

    for config in SWEEP_CONFIGS:
        scene = Scene.from_triangles(tri, builder=builder, config=config)
        engine = scene.engine(shard=1)
        rec, dt_trace = _timed(
            lambda r, e=engine: e.trace(r, backend="wavefront"), rays)
        st = scene.stats(rays=rays)
        node_bytes = st.n_nodes * st.bytes_per_node
        overflow = float(np.asarray(rec.stack_overflow).mean())
        rows.append((
            f"sweep_{config.tag}_{builder}_{n_tri // 1000}k_clustered",
            dt_trace * 1e6,
            f"config={config.tag};"
            f"arity={st.arity};"
            f"depth={st.depth};"
            f"n_nodes={st.n_nodes};"
            f"sah_cost={st.sah_cost:.2f};"
            f"mean_quadbox_jobs={st.mean_quadbox_jobs:.2f};"
            f"mean_tri_jobs={st.mean_triangle_jobs:.2f};"
            f"mean_jobs={st.mean_jobs:.2f};"
            f"mean_branching_factor={st.mean_branching_factor:.2f};"
            f"bytes_per_node={st.bytes_per_node};"
            f"node_bytes_total={node_bytes};"
            f"compression_ratio={st.compression_ratio:.1f}x;"
            f"overflow_fraction={overflow:.4f};"
            f"trace_us_per_ray={dt_trace / n_rays * 1e6:.3f};"
            f"batched_rounds={int(rec.rounds)}"))
