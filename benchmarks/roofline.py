import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Roofline analysis: per (arch x shape x mesh) compute/memory/collective
terms derived from compiled HLO on the production mesh.

Methodology (and why — see DESIGN.md §Roofline-methodology):
XLA's ``cost_analysis`` and the HLO text both count a ``while`` (lax.scan)
body ONCE, so whole-program numbers undercount layer stacks and chunk
loops.  This harness therefore lowers *exact-HLO* pieces and assembles:

  decode cells  : the WHOLE decode step with layers python-unrolled
                  (no while loops remain) — exact, direct.
  prefill cells : per-segment single-pattern forward (layers x1, attention
                  kv-loop and ssm/wkv chunk loops python-unrolled) x repeats
                  + the head (embed / logits, unrolled loss chunks).
  train cells   : per-segment pattern wrapped in jax.checkpoint and
                  differentiated — the lowered HLO then contains forward +
                  remat-recompute + backward, exactly like the production
                  step — x repeats + differentiated head + optimizer sweep
                  + the data-parallel gradient all-reduce (from the
                  whole-program dry-run schedule, which lives outside any
                  loop and is counted exactly there).

Every number that enters the table is from ``compiled.cost_analysis()`` /
``compiled.as_text()`` of an artifact lowered with the SAME sharding rules
and mesh as the dry-run; the assembly multipliers (layer repeats) are
static config facts.  MODEL_FLOPS = 6·N_act·D (train) / 2·N_act·D (fwd)
gives the "useful fraction" column.

Usage:
  python -m benchmarks.roofline --arch rwkv6-7b --shape train_4k
  python -m benchmarks.roofline --all --out experiments/roofline
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import SHAPES, applicable, get_config, input_specs  # noqa: E402
from repro.configs.registry import ARCH_IDS  # noqa: E402
from repro.launch import hlo_analysis as ha  # noqa: E402
from repro.launch.dryrun import model_flops, params_shapes  # noqa: E402
from repro.launch.mesh import make_plan, make_production_mesh  # noqa: E402
from repro.models import derive_segments, count_params  # noqa: E402
from repro.models import model as M  # noqa: E402
from repro.models.layers import lm_loss, logits_apply, norm_apply  # noqa: E402
from repro.models.transformer import block_apply, stack_cache_shapes  # noqa: E402
from repro.parallel.sharding import make_rules  # noqa: E402


def _cost_of(fn, args, in_shardings=None):
    """(flops, hbm_bytes, link_bytes, collectives) of one compiled fn."""
    jitted = jax.jit(fn) if in_shardings is None else jax.jit(
        fn, in_shardings=in_shardings)
    compiled = jitted.lower(*args).compile()
    cost = compiled.cost_analysis() or {}
    txt = compiled.as_text()
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)),
            ha.total_link_bytes(txt), ha.collective_summary(txt))


def _merge(acc, cost, mult=1.0):
    f, b, l, c = cost
    acc["flops"] += f * mult
    acc["hbm_bytes"] += b * mult
    acc["link_bytes"] += l * mult
    for k, v in c.items():
        slot = acc["collectives"].setdefault(
            k, {"count": 0, "result_bytes": 0, "link_bytes": 0.0})
        slot["count"] += v["count"] * mult
        slot["result_bytes"] += v["result_bytes"] * mult
        slot["link_bytes"] += v["link_bytes"] * mult
    return acc


BF16_TRAFFIC_ADJ = 0.5  # see below


def _cost_cfg(cfg):
    """Costing variant: loops unrolled AND compute in f32.

    f32 because the XLA *CPU* backend cannot execute bf16 dots: it wraps
    every matmul in f32<->bf16 converts, which pollute ``bytes accessed``
    (measured: 774 GB of converts on a 5 GB KV cache) and count as FLOPs.
    Costing in f32 removes the pollution; matmul FLOPs are dtype-independent.
    Production traffic on TPU is bf16 for activations/KV (0.5x f32) while
    master weights stay f32 — so the memory/collective terms are reported
    twice: raw f32 (upper bound) and x0.5 bf16-adjusted (lower bound, used
    for the bottleneck call).  Both bounds go in the table.
    """
    return dataclasses.replace(cfg, scan_layers=False, scan_seq=False,
                               attn_unroll=True, compute_dtype="float32")


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _h_spec(cfg, rules, b, t):
    cd = jnp.dtype(cfg.compute_dtype)
    h = _sds((b, t, cfg.d_model), cd)
    sh = rules.batch({"h": h})["h"]
    return h, sh


def _seg_params_spec(cfg, rules, si):
    full = params_shapes(cfg)
    seg = full["segments"][si]
    one = jax.tree.map(lambda x: _sds(x.shape[1:], x.dtype), seg)
    full_sh = rules.params(full)["segments"][si]
    one_sh = jax.tree.map(
        lambda s: NamedSharding(s.mesh, P(*list(s.spec)[1:])), full_sh)
    return one, one_sh


def cost_cell(arch: str, shape_name: str, *, multi_pod=False,
              plan_override=None, cfg_override=None) -> dict:
    cfg = cfg_override or get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    plan = plan_override or make_plan(cfg, shape, multi_pod=multi_pod)
    ctx = plan.ctx(mesh)
    rules = make_rules(mesh, plan)
    ccfg = _cost_cfg(cfg)
    segs = derive_segments(cfg)

    acc = {"flops": 0.0, "hbm_bytes": 0.0, "link_bytes": 0.0,
           "collectives": {}}
    b, t = shape.global_batch, shape.seq_len
    t_text = t - cfg.vision_tokens if cfg.family == "vlm" else t

    if shape.kind == "decode":
        # whole decode step, layers unrolled: exact in one artifact
        cache_s, tok_s = input_specs(ccfg, shape)
        psh = rules.params(params_shapes(ccfg))
        csh = rules.cache(cache_s)
        tsh = rules.batch({"t": tok_s})["t"]

        def fn(p, c, tk):
            return M.decode_step(ccfg, ctx, p, c, tk)

        cost = _cost_of(fn, (params_shapes(ccfg), cache_s, tok_s),
                        (psh, csh, tsh))
        _merge(acc, cost)
    else:
        grad_mode = shape.kind == "train"
        # train runs `accum` microbatches per step: the per-layer body is
        # costed at the MICRO batch and multiplied by repeats*accum, so the
        # per-micro FSDP weight gathers (collectives) are counted each pass
        accum = plan.accum_steps if grad_mode else 1
        b = max(b // accum, 1)
        pass_mult = accum
        pos_s = _sds((b, t), jnp.int32)
        h_s, h_sh = _h_spec(ccfg, rules, b, t)
        pos_sh = rules.batch({"p": pos_s})["p"]
        # enc-dec archs: decoder blocks cross-attend to the encoder memory
        enc_s = enc_sh = None
        if ccfg.encoder is not None:
            enc_s, enc_sh = _h_spec(ccfg, rules, b, ccfg.encoder.seq_len)

        def _seg_cost(cost_cfg, pattern, seg_one, seg_sh, h_s_, h_sh_,
                      pos_s_, pos_sh_, enc=False):
            def seg_fwd(p_list, h, positions, enc_h):
                for spec, p_blk in zip(pattern, p_list):
                    h, _, _ = block_apply(cost_cfg, ctx, spec, p_blk, h,
                                          positions, "train", None, None,
                                          enc_h)
                return h

            if grad_mode:
                # remat='block': fwd + recompute + bwd, exactly the
                # production schedule; remat='none' skips the recompute
                inner = (jax.checkpoint(seg_fwd)
                         if cost_cfg.remat == "block" else seg_fwd)

                def seg_loss(p_list, h, positions, enc_h):
                    return jnp.sum(inner(p_list, h, positions, enc_h)
                                   .astype(jnp.float32) ** 2) * 1e-6

                fn = jax.grad(seg_loss, argnums=(0, 1))
            else:
                fn = seg_fwd
            return _cost_of(fn, (seg_one, h_s_, pos_s_, enc_s),
                            (seg_sh, h_sh_, pos_sh_, enc_sh))

        for si, (pattern, repeats) in enumerate(segs):
            seg_one, seg_sh = _seg_params_spec(ccfg, rules, si)
            cost = _seg_cost(ccfg, pattern, seg_one, seg_sh, h_s, h_sh,
                             pos_s, pos_sh)
            _merge(acc, cost, mult=repeats * pass_mult)

        if ccfg.encoder is not None:
            # encoder tower: uniform attn segments at (b, enc_seq)
            from repro.models.model import encoder_cfg as _ecfg
            ecfg = _ecfg(ccfg)
            epos_s = _sds((b, ccfg.encoder.seq_len), jnp.int32)
            epos_sh = rules.batch({"p": epos_s})["p"]
            full = params_shapes(ccfg)
            full_sh = rules.params(full)
            for si, (pattern, repeats) in enumerate(derive_segments(ecfg)):
                seg = full["encoder"]["segments"][si]
                seg_one = jax.tree.map(
                    lambda x: _sds(x.shape[1:], x.dtype), seg)
                seg_sh = jax.tree.map(
                    lambda s: NamedSharding(s.mesh, P(*list(s.spec)[1:])),
                    full_sh["encoder"]["segments"][si])
                cost = _seg_cost(ecfg, pattern, seg_one, seg_sh, enc_s,
                                 enc_sh, epos_s, epos_sh)
                _merge(acc, cost, mult=repeats * pass_mult)

        # head: embed -> final norm -> loss (train) / last-token logits
        full_p = params_shapes(ccfg)
        head_p = {"embed": full_p["embed"], "final_norm": full_p["final_norm"]}
        head_sh = {k: rules.params(full_p)[k] for k in head_p}
        toks_s = _sds((b, t_text), jnp.int32)
        lbl_s = _sds((b, t_text), jnp.int32)

        if grad_mode:
            def head_fn(hp, h, labels):
                hn = norm_apply(ccfg, hp["final_norm"], h[:, :t_text])
                loss, _ = lm_loss(ccfg, ctx, hp["embed"], hn, labels)
                return loss

            fn = jax.grad(head_fn, argnums=(0, 1))
            cost = _cost_of(fn, (head_p, h_s, lbl_s),
                            (head_sh, h_sh, rules.batch({"l": lbl_s})["l"]))
            _merge(acc, cost, mult=pass_mult)
            # embedding lookup fwd+bwd (vlm: plus the stub patch concat)
            emb_batch = {"tokens": toks_s}
            if ccfg.family == "vlm":
                emb_batch["patches"] = _sds(
                    (b, ccfg.vision_tokens, ccfg.d_model),
                    jnp.dtype(ccfg.compute_dtype))

            def emb_fn(hp, eb):
                return jnp.sum(
                    M._embed_inputs(ccfg, ctx, {"embed": hp["embed"]}, eb)[0]
                    .astype(jnp.float32) ** 2)
            cost = _cost_of(jax.grad(emb_fn), (head_p, emb_batch),
                            (head_sh, rules.batch(emb_batch)))
            _merge(acc, cost, mult=pass_mult)
            # fwd+bwd done; train adds optimizer sweep + DP grad all-reduce
            opt_cost, grad_ar_bytes = _optimizer_cost(cfg, rules, mesh, plan)
            _merge(acc, opt_cost)
            # grads all-reduce in f32 in production too: exempt from the
            # bf16 adjustment
            acc["link_bytes_exact_f32"] = grad_ar_bytes
        else:
            def head_fn(hp, h):
                hn = norm_apply(ccfg, hp["final_norm"], h[:, -1:])
                return logits_apply(ccfg, ctx, hp["embed"], hn)

            cost = _cost_of(head_fn, (head_p, h_s), (head_sh, h_sh))
            _merge(acc, cost)

    # roofline terms: cost numbers are per-device (post-SPMD module).
    # memory: the analytic TPU-fusion model is the roofline term; the
    # CPU-HLO 'bytes accessed' (which materialises every intermediate) is
    # kept as the upper bound.  collectives: HLO-parsed, bf16-adjusted for
    # activations (grad all-reduce stays f32-exact).
    mem_bytes = analytic_memory_bytes(cfg, shape, plan, mesh, rules)
    adj_link = (acc["link_bytes"] - acc.get("link_bytes_exact_f32", 0.0)) \
        * BF16_TRAFFIC_ADJ + acc.get("link_bytes_exact_f32", 0.0)
    terms = ha.roofline_terms(acc["flops"], mem_bytes, adj_link)
    terms_f32 = ha.roofline_terms(acc["flops"], acc["hbm_bytes"],
                                  acc["link_bytes"])
    mf = model_flops(cfg, shape)
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": mesh.size,
        "plan": {"fsdp": bool(plan.fsdp_axes), "accum": plan.accum_steps,
                 "seq_axis": bool(plan.seq_axis),
                 "moments": plan.moments_dtype},
        "hlo_flops_per_device": acc["flops"],
        "hlo_bytes_per_device_f32_bound": acc["hbm_bytes"],
        "analytic_bytes_per_device": mem_bytes,
        "link_bytes_per_device_f32": acc["link_bytes"],
        "link_bytes_per_device": adj_link,
        "collectives": acc["collectives"],
        "compute_s": terms["compute_s"],
        "memory_s": terms["memory_s"],
        "memory_s_cpu_hlo_bound": terms_f32["memory_s"],
        "collective_s": terms["collective_s"],
        "collective_s_f32_bound": terms_f32["collective_s"],
        "bottleneck": ha.dominant_term(terms),
        "model_flops_total": mf,
        "model_flops_per_device": mf / mesh.size,
        "useful_flops_frac": (mf / mesh.size) / max(acc["flops"], 1.0),
        "step_time_lower_bound_s": max(terms.values()),
        "roofline_fraction": ((mf / mesh.size) / ha.PEAK_FLOPS)
        / max(max(terms.values()), 1e-30),
    }
    return rec


def _local_param_bytes(rules, mesh, cfg):
    """Exact per-device parameter bytes under the cell's sharding rules."""
    p_s = params_shapes(cfg)
    flat, _ = jax.tree.flatten(p_s)
    flat_sh, _ = jax.tree.flatten(rules.params(p_s))
    total = 0
    for leaf, sh in zip(flat, flat_sh):
        n = leaf.size
        for part in sh.spec:
            if part is None:
                continue
            for a in (part if isinstance(part, tuple) else (part,)):
                n //= mesh.shape[a]
        total += n * leaf.dtype.itemsize
    return total


def _local_cache_bytes(rules, mesh, cfg, shape):
    specs = input_specs(cfg, shape)
    cache_s = specs[0] if shape.kind == "decode" else specs[1]
    flat, _ = jax.tree.flatten(cache_s)
    flat_sh, _ = jax.tree.flatten(rules.cache(cache_s))
    total = 0
    for leaf, sh in zip(flat, flat_sh):
        n = int(np.prod(leaf.shape)) if leaf.shape else 1
        for part in sh.spec:
            if part is None:
                continue
            for a in (part if isinstance(part, tuple) else (part,)):
                n //= mesh.shape[a]
        total += n * jnp.dtype(leaf.dtype).itemsize
    return total


# Analytic HBM model constants (TPU fusion assumptions, bf16 activations):
ACT_PASSES_FWD = 8  # boundary r/w of the ~4 fused super-ops per block side
ACT_PASSES_BWD = 16  # recompute + dgrad/wgrad boundary traffic


def analytic_memory_bytes(cfg, shape, plan, mesh, rules):
    """Napkin-math per-device HBM bytes per step, documented term by term.

    The CPU-compiled HLO's 'bytes accessed' materialises every intermediate
    (no TPU-style fusion), so it is only an upper bound; this model is the
    TPU-style estimate used for the memory roofline term.  Both are
    reported.
    """
    b_loc = shape.global_batch
    for a in plan.batch_axes:
        if shape.global_batch % mesh.shape[a] == 0:
            b_loc //= mesh.shape[a]
    t = shape.seq_len
    d = cfg.d_model
    L = cfg.num_layers
    pbytes = _local_param_bytes(rules, mesh, cfg)
    act_layer = b_loc * t * d * 2  # bf16 block-boundary activation

    if shape.kind == "train":
        accum = plan.accum_steps
        m_itemsize = 2 if plan.moments_dtype == "bfloat16" else 4
        weights = pbytes * (1 + 1 + 2) * accum  # fwd + remat + dgrad reads,
        # re-read once per microbatch
        opt = pbytes * 2 + 2 * (pbytes // 2 * m_itemsize) * 2 + pbytes  # p rw,
        # m/v rw (scaled by dtype), grads read
        # act_layer covers the WHOLE per-device batch, so activation totals
        # are accum-independent (each micro touches 1/accum of the tokens)
        acts = L * act_layer * (ACT_PASSES_FWD + ACT_PASSES_BWD)
        resid = L * act_layer * 2  # saved residuals: fwd write, bwd read
        head = b_loc * t * (cfg.vocab_size // max(rules.tp, 1)) * 2 * 3
        return weights + opt + acts + resid + head
    if shape.kind == "prefill":
        cache = _local_cache_bytes(rules, mesh, cfg, shape)
        acts = L * act_layer * ACT_PASSES_FWD
        # causal chunked attention re-reads K/V once per q chunk on average
        # S/(2*chunk) times
        qc = cfg.attn_chunk
        kv_heads = max(cfg.num_kv_heads, 1)
        kv_re = L * b_loc * t * kv_heads * cfg.head_dim_ * 2 * (
            t / (2 * max(qc, 1)) / 1e0) if cfg.attention != "mla" else 0
        return pbytes + acts + cache + kv_re
    # decode: weights once + cache read/write + small activations
    cache = _local_cache_bytes(rules, mesh, cfg, shape)
    return pbytes + cache + L * b_loc * d * 2 * ACT_PASSES_FWD


def _optimizer_cost(cfg, rules, mesh, plan):
    """AdamW sweep + cross-data gradient all-reduce, costed on shards.

    The DP grad all-reduce is an analytic schedule fact: each param leaf,
    sharded per its spec, is summed over the batch axes it is NOT sharded
    over.  Ring model: 2·bytes·(S-1)/S.
    """
    from repro.optim import adamw
    p_s = params_shapes(cfg)
    psh = rules.params(p_s)
    opt_s = jax.eval_shape(lambda: adamw.init(p_s, plan.moments_dtype))
    osh = adamw.OptState(rules.opt_state(p_s), rules.opt_state(p_s),
                         NamedSharding(mesh, P()))
    ocfg = adamw.AdamWConfig(moments_dtype=plan.moments_dtype)

    def opt_fn(g, o, p):
        new_p, new_o, _ = adamw.update(ocfg, g, o, p)
        return new_p, new_o

    cost = _cost_of(opt_fn, (p_s, opt_s, p_s), (psh, osh, psh))
    f, bts, l, c = cost

    # analytic DP all-reduce of grads (f32), ring over unused batch axes
    dp = {a: mesh.shape[a] for a in plan.batch_axes}
    extra = 0.0
    flat, _ = jax.tree.flatten(p_s)
    flat_sh, _ = jax.tree.flatten(psh)
    for leaf, sh in zip(flat, flat_sh):
        used = set()
        for part in sh.spec:
            if part is None:
                continue
            used.update(part if isinstance(part, tuple) else (part,))
        s = 1
        for a, n in dp.items():
            if a not in used:
                s *= n
        if s > 1:
            shard_elems = leaf.size
            for part in sh.spec:
                if part is None:
                    continue
                for a in (part if isinstance(part, tuple) else (part,)):
                    shard_elems //= mesh.shape[a]
            bytes_ = shard_elems * 4  # f32 grads
            extra += 2.0 * bytes_ * (s - 1) / s
    coll = dict(c)
    slot = coll.setdefault("all-reduce", {"count": 0, "result_bytes": 0,
                                          "link_bytes": 0.0})
    slot["count"] += 1
    slot["link_bytes"] += extra
    return (f, bts, l + extra, coll), extra


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="experiments/roofline")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    archs = ARCH_IDS if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    for arch in archs:
        cfg = get_config(arch)
        for shape in shapes:
            if not applicable(cfg, SHAPES[shape]):
                continue
            name = f"{arch}__{shape}"
            print(f"=== roofline {name} ===", flush=True)
            t0 = time.time()
            try:
                rec = cost_cell(arch, shape, multi_pod=args.multi_pod)
                rec["analysis_s"] = round(time.time() - t0, 1)
                with open(os.path.join(args.out, name + ".json"), "w") as f:
                    json.dump(rec, f, indent=1)
                print(f"--> {rec['bottleneck']}  "
                      f"c={rec['compute_s']:.4f}s m={rec['memory_s']:.4f}s "
                      f"n={rec['collective_s']:.4f}s "
                      f"roofline={rec['roofline_fraction']:.3f} "
                      f"({rec['analysis_s']}s)", flush=True)
            except Exception as e:
                print(f"--> FAILED {type(e).__name__}: {e}", flush=True)
                traceback.print_exc()


if __name__ == "__main__":
    main()
