"""Generalized-mode benchmarks: kNN / similarity throughput.

Queries flow through the session ``QueryEngine`` over a ``VectorIndex``
built once (precomputed ||c||^2 norms, jit-cached compiled functions).
Compares the paper's beat-form (16 lanes/beat + accumulator) against the
TPU-native MXU backend (DESIGN.md §2) and the Pallas kernel backend: the
ratio is the speedup "reusing the MXU" buys over lane-serial processing.

Every row carries ``devices=`` / ``chunk_size=``; on a multi-device host a
sharded-vs-single-device comparison section is appended (queries
data-parallel over the mesh, database replicated — ``core/dispatch.py``).

The tree-vs-brute section benchmarks the traversal-backed neighbor path
(DESIGN.md §9): a ``PointCloudScene`` per cloud size, fixed-radius
``within`` through the BVH wavefront engine vs the brute MXU matmul, with
the per-query traversal work (``box_jobs + point_jobs``) and the measured
radius selectivity in the derived metrics — the RTNN trade curve
(tree wins as selectivity drops, brute wins as it saturates).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import PointCloudScene, VectorIndex
from repro.core import euclidean_distance_sq


def _t(f, *a, iters=5):
    jax.block_until_ready(f(*a))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = f(*a)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def run(rows):
    rng = np.random.default_rng(0)
    m, n, d = 512, 4096, 256
    q = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))

    index = VectorIndex.from_database(c)
    engine = index.engine(shard=1)

    dt_mxu = _t(lambda qq: engine.scores(qq, "euclidean", backend="mxu"), q)
    rows.append(("euclid_mxu_form_512x4096x256", dt_mxu * 1e6,
                 f"pair_dists_per_s={m * n / dt_mxu:.3e};"
                 f"devices=1;chunk_size=none"))

    # beat form: one query row against the database per call (lane-serial)
    beat = jax.jit(lambda qi, cc: euclidean_distance_sq(
        jnp.broadcast_to(qi, cc.shape), cc))
    dt_beat = _t(beat, q[0], c)
    rows.append(("euclid_beat_form_1x4096x256", dt_beat * 1e6,
                 f"mxu_speedup_vs_beats={dt_beat * m / dt_mxu:.1f}x"))

    dt_k = _t(lambda qq: engine.scores(qq, "euclidean", backend="pallas"), q)
    rows.append(("euclid_pallas_kernel_512x4096x256", dt_k * 1e6,
                 f"interpret_overhead_vs_mxu={dt_k / dt_mxu:.1f}x"))

    dt_a = _t(lambda qq: engine.scores(qq, "angular", backend="mxu"), q)
    rows.append(("angular_mxu_form_512x4096x256", dt_a * 1e6,
                 f"pair_scores_per_s={m * n / dt_a:.3e}"))

    dt_knn = _t(lambda qq: engine.nearest(qq, 8, "euclidean"), q)
    info = engine.cache_info()
    rows.append(("knn_top8_euclidean", dt_knn * 1e6,
                 f"queries_per_s={m / dt_knn:.3e};"
                 f"jit_cache_entries={info.entries};"
                 f"jit_cache_hits={info.hits};"
                 f"devices=1;chunk_size=none"))

    # chunked streaming: the (chunk, N) score matrix is the peak
    # intermediate instead of the full (M, N) — the memory-bounded mode
    chunked = index.engine(shard=1, chunk_size=128)
    dt_ch = _t(lambda qq: chunked.nearest(qq, 8, "euclidean"), q)
    rows.append(("knn_top8_euclidean_chunked", dt_ch * 1e6,
                 f"queries_per_s={m / dt_ch:.3e};"
                 f"overhead_vs_unchunked={dt_ch / dt_knn:.2f}x;"
                 f"jit_cache_entries={chunked.cache_info().entries};"
                 f"devices=1;chunk_size=128"))

    # sharded-vs-single-device comparison (bit-identical results)
    n_dev = jax.local_device_count()
    if n_dev > 1:
        sharded = index.engine(shard="auto")
        dt_sh = _t(lambda qq: sharded.nearest(qq, 8, "euclidean"), q)
        rows.append((f"knn_top8_euclidean_sharded_{n_dev}dev", dt_sh * 1e6,
                     f"queries_per_s={m / dt_sh:.3e};"
                     f"speedup_vs_single={dt_knn / dt_sh:.2f}x;"
                     f"devices={n_dev};chunk_size=none"))

    # -- tree-vs-brute neighbor search (the RTNN trade curve) ---------------
    mq, kq = 256, 64
    cq = jnp.asarray(rng.normal(size=(mq, 3)).astype(np.float32))
    for n_pts in (4096, 32768):
        pts = jnp.asarray(rng.normal(size=(n_pts, 3)).astype(np.float32))
        ceng = PointCloudScene.from_points(pts).engine(shard=1)
        for radius in (0.15, 0.6):
            rec = jax.block_until_ready(ceng.neighbor_search(
                cq, kq, radius=radius, backend="tree_wavefront"))
            sel = float(np.asarray(rec.count).mean()) / n_pts
            jobs = float(np.asarray(rec.box_jobs).mean()
                         + np.asarray(rec.point_jobs).mean())
            dt_tree = _t(lambda qq: ceng.neighbor_search(
                qq, kq, radius=radius, backend="tree_wavefront"), cq)
            dt_brute = _t(lambda qq: ceng.within(
                qq, radius, kq, backend="mxu"), cq)
            rows.append((
                f"within_tree_n{n_pts}_r{radius}", dt_tree * 1e6,
                f"queries_per_s={mq / dt_tree:.3e};"
                f"brute_mxu_us={dt_brute * 1e6:.3f};"
                f"tree_speedup_vs_brute={dt_brute / dt_tree:.2f}x;"
                f"jobs_per_query={jobs:.1f};"
                f"brute_jobs_per_query={n_pts};"
                f"selectivity={sel:.3e};devices=1;chunk_size=none"))
        dt_tn = _t(lambda qq: ceng.nearest(
            qq, 8, backend="tree_wavefront"), cq)
        dt_bn = _t(lambda qq: ceng.nearest(qq, 8, backend="mxu"), cq)
        rows.append((
            f"nearest8_tree_n{n_pts}", dt_tn * 1e6,
            f"queries_per_s={mq / dt_tn:.3e};"
            f"brute_mxu_us={dt_bn * 1e6:.3f};"
            f"tree_speedup_vs_brute={dt_bn / dt_tn:.2f}x;"
            f"devices=1;chunk_size=none"))
