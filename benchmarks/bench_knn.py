"""Generalized-mode benchmarks: kNN / similarity throughput.

Queries flow through the session ``QueryEngine`` over a ``VectorIndex``
built once (precomputed ||c||^2 norms, jit-cached compiled functions).
Compares the paper's beat-form (16 lanes/beat + accumulator) against the
TPU-native MXU backend (DESIGN.md §2) and the Pallas kernel backend: the
ratio is the speedup "reusing the MXU" buys over lane-serial processing.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import VectorIndex
from repro.core import euclidean_distance_sq


def _t(f, *a, iters=5):
    jax.block_until_ready(f(*a))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = f(*a)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def run(rows):
    rng = np.random.default_rng(0)
    m, n, d = 512, 4096, 256
    q = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))

    index = VectorIndex.from_database(c)
    engine = index.engine()

    dt_mxu = _t(lambda qq: engine.scores(qq, "euclidean", backend="mxu"), q)
    rows.append(("euclid_mxu_form_512x4096x256", dt_mxu * 1e6,
                 f"pair_dists_per_s={m * n / dt_mxu:.3e}"))

    # beat form: one query row against the database per call (lane-serial)
    beat = jax.jit(lambda qi, cc: euclidean_distance_sq(
        jnp.broadcast_to(qi, cc.shape), cc))
    dt_beat = _t(beat, q[0], c)
    rows.append(("euclid_beat_form_1x4096x256", dt_beat * 1e6,
                 f"mxu_speedup_vs_beats={dt_beat * m / dt_mxu:.1f}x"))

    dt_k = _t(lambda qq: engine.scores(qq, "euclidean", backend="pallas"), q)
    rows.append(("euclid_pallas_kernel_512x4096x256", dt_k * 1e6,
                 f"interpret_overhead_vs_mxu={dt_k / dt_mxu:.1f}x"))

    dt_a = _t(lambda qq: engine.scores(qq, "angular", backend="mxu"), q)
    rows.append(("angular_mxu_form_512x4096x256", dt_a * 1e6,
                 f"pair_scores_per_s={m * n / dt_a:.3e}"))

    dt_knn = _t(lambda qq: engine.nearest(qq, 8, "euclidean"), q)
    info = engine.cache_info()
    rows.append(("knn_top8_euclidean", dt_knn * 1e6,
                 f"queries_per_s={m / dt_knn:.3e};"
                 f"jit_cache_entries={info.entries};"
                 f"jit_cache_hits={info.hits}"))
