"""Generalized-mode benchmarks: kNN / similarity throughput.

Queries flow through the session ``QueryEngine`` over a ``VectorIndex``
built once (precomputed ||c||^2 norms, jit-cached compiled functions).
Compares the paper's beat-form (16 lanes/beat + accumulator) against the
TPU-native MXU backend (DESIGN.md §2) and the Pallas kernel backend: the
ratio is the speedup "reusing the MXU" buys over lane-serial processing.

Every row carries ``devices=`` / ``chunk_size=``; on a multi-device host a
sharded-vs-single-device comparison section is appended (queries
data-parallel over the mesh, database replicated — ``core/dispatch.py``).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import VectorIndex
from repro.core import euclidean_distance_sq


def _t(f, *a, iters=5):
    jax.block_until_ready(f(*a))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = f(*a)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def run(rows):
    rng = np.random.default_rng(0)
    m, n, d = 512, 4096, 256
    q = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))

    index = VectorIndex.from_database(c)
    engine = index.engine(shard=1)

    dt_mxu = _t(lambda qq: engine.scores(qq, "euclidean", backend="mxu"), q)
    rows.append(("euclid_mxu_form_512x4096x256", dt_mxu * 1e6,
                 f"pair_dists_per_s={m * n / dt_mxu:.3e};"
                 f"devices=1;chunk_size=none"))

    # beat form: one query row against the database per call (lane-serial)
    beat = jax.jit(lambda qi, cc: euclidean_distance_sq(
        jnp.broadcast_to(qi, cc.shape), cc))
    dt_beat = _t(beat, q[0], c)
    rows.append(("euclid_beat_form_1x4096x256", dt_beat * 1e6,
                 f"mxu_speedup_vs_beats={dt_beat * m / dt_mxu:.1f}x"))

    dt_k = _t(lambda qq: engine.scores(qq, "euclidean", backend="pallas"), q)
    rows.append(("euclid_pallas_kernel_512x4096x256", dt_k * 1e6,
                 f"interpret_overhead_vs_mxu={dt_k / dt_mxu:.1f}x"))

    dt_a = _t(lambda qq: engine.scores(qq, "angular", backend="mxu"), q)
    rows.append(("angular_mxu_form_512x4096x256", dt_a * 1e6,
                 f"pair_scores_per_s={m * n / dt_a:.3e}"))

    dt_knn = _t(lambda qq: engine.nearest(qq, 8, "euclidean"), q)
    info = engine.cache_info()
    rows.append(("knn_top8_euclidean", dt_knn * 1e6,
                 f"queries_per_s={m / dt_knn:.3e};"
                 f"jit_cache_entries={info.entries};"
                 f"jit_cache_hits={info.hits};"
                 f"devices=1;chunk_size=none"))

    # chunked streaming: the (chunk, N) score matrix is the peak
    # intermediate instead of the full (M, N) — the memory-bounded mode
    chunked = index.engine(shard=1, chunk_size=128)
    dt_ch = _t(lambda qq: chunked.nearest(qq, 8, "euclidean"), q)
    rows.append(("knn_top8_euclidean_chunked", dt_ch * 1e6,
                 f"queries_per_s={m / dt_ch:.3e};"
                 f"overhead_vs_unchunked={dt_ch / dt_knn:.2f}x;"
                 f"jit_cache_entries={chunked.cache_info().entries};"
                 f"devices=1;chunk_size=128"))

    # sharded-vs-single-device comparison (bit-identical results)
    n_dev = jax.local_device_count()
    if n_dev > 1:
        sharded = index.engine(shard="auto")
        dt_sh = _t(lambda qq: sharded.nearest(qq, 8, "euclidean"), q)
        rows.append((f"knn_top8_euclidean_sharded_{n_dev}dev", dt_sh * 1e6,
                     f"queries_per_s={m / dt_sh:.3e};"
                     f"speedup_vs_single={dt_knn / dt_sh:.2f}x;"
                     f"devices={n_dev};chunk_size=none"))
