"""Generalized-mode benchmarks: kNN / similarity throughput.

Compares the paper's beat-form (16 lanes/beat + accumulator) against the
TPU-native MXU form (DESIGN.md §2) and the Pallas kernel path: the ratio is
the speedup "reusing the MXU" buys over lane-serial processing.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import euclidean_distance_sq, euclidean_scores
from repro.core.knn import angular_scores, knn
from repro.kernels.ops import euclidean_kernel


def _t(f, *a, iters=5):
    jax.block_until_ready(f(*a))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = f(*a)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def run(rows):
    rng = np.random.default_rng(0)
    m, n, d = 512, 4096, 256
    q = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))

    mxu = jax.jit(euclidean_scores)
    dt_mxu = _t(mxu, q, c)
    rows.append(("euclid_mxu_form_512x4096x256", dt_mxu * 1e6,
                 f"pair_dists_per_s={m * n / dt_mxu:.3e}"))

    # beat form: one query row against the database per call (lane-serial)
    beat = jax.jit(lambda qi, c: euclidean_distance_sq(
        jnp.broadcast_to(qi, c.shape), c))
    dt_beat = _t(beat, q[0], c)
    rows.append(("euclid_beat_form_1x4096x256", dt_beat * 1e6,
                 f"mxu_speedup_vs_beats={dt_beat * m / dt_mxu:.1f}x"))

    kern = jax.jit(lambda q, c: euclidean_kernel(q, c))
    dt_k = _t(kern, q, c)
    rows.append(("euclid_pallas_kernel_512x4096x256", dt_k * 1e6,
                 f"interpret_overhead_vs_mxu={dt_k / dt_mxu:.1f}x"))

    ang = jax.jit(angular_scores)
    dt_a = _t(ang, q, c)
    rows.append(("angular_mxu_form_512x4096x256", dt_a * 1e6,
                 f"pair_scores_per_s={m * n / dt_a:.3e}"))

    top = jax.jit(lambda q, c: knn(q, c, 8, "euclidean"))
    dt_knn = _t(top, q, c)
    rows.append(("knn_top8_euclidean", dt_knn * 1e6,
                 f"queries_per_s={m / dt_knn:.3e}"))
