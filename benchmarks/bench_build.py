"""Acceleration-structure builder benchmark: quality as a measured knob.

For each registered builder (``core/build``) on a clustered (non-uniform)
scene — the workload class where tree quality actually matters — one row
reports:

* ``build`` time (compiled steady state: the builders are jittable, so
  the second call is the per-frame rebuild cost),
* the model quality (``sah_cost``) and the measured quality (mean
  OpQuadbox / OpTriangle jobs per ray on a shared probe batch — the
  deterministic, device-free metric every engine bit-agrees on),
* end-to-end wavefront trace latency for the same rays on that tree.

A final row measures ``refit`` (the dynamic-scene path): the O(depth)
AABB re-sweep that ``Scene.refit`` runs per animation frame, orders of
magnitude under any rebuild, plus the refit tree's measured job quality
after one frame of motion.

All rows land in ``BENCH_quick.json`` via ``benchmarks.run --json``, so
the SAH-vs-LBVH margin is tracked across PRs.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import Scene, Triangle, make_ray
from repro.core import build, builders, refit, sah_cost, tree_stats
from repro.core.build import clustered_soup


def _timed(fn, *args):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    out = fn(*args)
    jax.block_until_ready(out)
    return out, time.perf_counter() - t0


def run(rows):
    rng = np.random.default_rng(0)
    tri = clustered_soup(rng, n_clusters=12, per_cluster=250)
    n_tri = int(tri.a.shape[0])

    n_rays = 512
    org = rng.uniform(-7, -6, (n_rays, 3)).astype(np.float32)
    tgt = rng.uniform(-4, 4, (n_rays, 3)).astype(np.float32)
    rays = make_ray(jnp.asarray(org), jnp.asarray(tgt - org))

    jobs = {}
    for name in builders():
        build_jit = jax.jit(lambda t, b=name: build(t, b).bvh)
        bvh, dt_build = _timed(build_jit, tri)

        scene = Scene.from_triangles(tri, builder=name)
        engine = scene.engine(shard=1)
        rec, dt_trace = _timed(
            lambda r: engine.trace(r, backend="wavefront"), rays)

        st = tree_stats(bvh, name, rays=rays)
        jobs[name] = st.mean_jobs
        rows.append((
            f"build_{name}_{n_tri // 1000}k_clustered",
            dt_build * 1e6,
            f"sah_cost={st.sah_cost:.2f};"
            f"mean_quadbox_jobs={st.mean_quadbox_jobs:.2f};"
            f"mean_tri_jobs={st.mean_triangle_jobs:.2f};"
            f"mean_jobs={st.mean_jobs:.2f};"
            f"occupancy={st.occupancy:.3f};"
            f"trace_us_per_ray={dt_trace / n_rays * 1e6:.3f};"
            f"batched_rounds={int(rec.rounds)}"))

    if "lbvh" in jobs and "sah" in jobs:
        # derived-only quality row, no timing: us_per_call=None -> null
        rows.append((
            "build_quality_sah_vs_lbvh", None,
            f"jobs_ratio={jobs['sah'] / jobs['lbvh']:.3f};"
            f"jobs_saved_per_ray={jobs['lbvh'] - jobs['sah']:.2f}"))

    # refit: the per-frame dynamic-scene cost (topology kept, boxes
    # re-swept) vs the full rebuild above
    bvh = build(tri, "sah").bvh
    shift = jnp.asarray(
        rng.normal(scale=0.05, size=(n_tri, 3)).astype(np.float32))
    moved = Triangle(tri.a + shift, tri.b + shift, tri.c + shift)
    refit_jit = jax.jit(refit)
    re, dt_refit = _timed(refit_jit, bvh, moved)
    rows.append((
        f"refit_sah_{n_tri // 1000}k_clustered", dt_refit * 1e6,
        f"sah_cost={sah_cost(re):.2f};"
        f"mean_jobs={tree_stats(re, 'sah', rays=rays).mean_jobs:.2f};"
        "topology=preserved"))
