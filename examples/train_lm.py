"""End-to-end driver: train a ~100M-param llama-family model for a few
hundred steps on the synthetic pipeline with the full production substrate
(supervisor, async checkpoints, straggler tracking), then sample from it.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
(~100M params; on this CPU container a step takes a few seconds — use
--small for a quick pass.)
"""
import argparse
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import Prefetcher, SyntheticLM
from repro.models import ModelConfig, count_params, init_params
from repro.optim import adamw
from repro.parallel.ctx import NO_PARALLEL as ctx
from repro.runtime import Supervisor, SupervisorConfig
from repro.serving import Engine
from repro.train import make_train_step


def model_100m():
    return ModelConfig(
        name="llama-100m", family="dense", num_layers=8, d_model=640,
        num_heads=10, num_kv_heads=5, d_ff=1792, vocab_size=32000,
        head_dim=64, tie_embeddings=True, attn_chunk=256, logit_chunk=256)


def model_small():
    return ModelConfig(
        name="llama-8m", family="dense", num_layers=4, d_model=192,
        num_heads=6, num_kv_heads=2, d_ff=512, vocab_size=2048,
        head_dim=32, tie_embeddings=True, attn_chunk=64, logit_chunk=64)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--small", action="store_true")
    args = ap.parse_args()

    cfg = model_small() if args.small else model_100m()
    print(f"model {cfg.name}: {count_params(cfg) / 1e6:.1f}M params, "
          f"{args.steps} steps @ {args.batch}x{args.seq}")

    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw.init(params)
    ocfg = adamw.AdamWConfig(lr=6e-4, warmup_steps=30,
                             total_steps=args.steps)
    step_fn = jax.jit(make_train_step(cfg, ctx, ocfg))
    data = SyntheticLM(cfg.vocab_size, args.batch, args.seq, seed=0)

    ckpt_dir = os.path.join(tempfile.gettempdir(), "repro_train_lm")
    sup = Supervisor(SupervisorConfig(ckpt_dir=ckpt_dir, ckpt_every=100),
                     step_fn, Prefetcher(data), params, opt)

    def log(step, metrics, dt):
        if step % 20 == 0 or step in (1, 5, 10):
            print(f"step {step:4d}  loss {float(metrics['loss']):.4f}  "
                  f"lr {float(metrics['lr']):.2e}  {dt:.2f}s/step",
                  flush=True)

    params, _ = sup.run(args.steps, metrics_cb=log)
    print(f"training done (restarts={sup.restarts}, "
          f"stragglers={len(sup.stragglers)})")

    # sample: the model should reproduce codebook n-grams far above chance
    eng = Engine(cfg, params, max_len=96)
    prompt_full = data.batch_at(10_001)["tokens"][:2, :32]
    prompt = jnp.asarray(prompt_full[:, :16], jnp.int32)
    gen = eng.generate(prompt, max_new_tokens=16)
    cont = np.asarray(gen)
    match = (cont[:, :16] == prompt_full[:, 16:32]).mean()
    print(f"greedy continuation matches held-out stream at "
          f"{match * 100:.0f}% of positions (noise floor "
          f"{100.0 / cfg.vocab_size:.2f}%)")


if __name__ == "__main__":
    main()
