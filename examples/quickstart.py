"""Quickstart: the four datapath operations, exactly as the paper's IO spec.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.api import Scene, VectorIndex, trace_backends
from repro.core import (OP_ANGULAR, OP_EUCLIDEAN, OP_QUADBOX, OP_TRIANGLE,
                        Box, Triangle, make_ray, unified_stream)
from repro.core.stream import make_jobs


def main():
    print("== OpQuadbox: one ray vs four AABBs ==")
    jobs = make_jobs(4)
    ray = make_ray(jnp.asarray([[-2.0, 0.5, 0.5]] * 4),
                   jnp.asarray([[1.0, 0.0, 0.0]] * 4))
    # four boxes at staggered distances; the datapath sorts hits near-to-far
    lo = jnp.asarray([[[1 + i, 0, 0] for i in (2, 0, 3, 1)]] * 4, jnp.float32)
    hi = lo + 0.8
    jobs = jobs._replace(opcode=jnp.full((4,), OP_QUADBOX, jnp.int32),
                         ray=ray, boxes=Box(lo, hi))
    _, out = unified_stream(jobs)
    print("  sorted tmin   :", np.asarray(out.tmin[0]))
    print("  box indices   :", np.asarray(out.box_index[0]))
    print("  is_intersect  :", np.asarray(out.is_intersect[0]))

    print("== OpTriangle: watertight Woop test ==")
    tri = Triangle(a=jnp.asarray([[0., 0., 1.]] * 4),
                   b=jnp.asarray([[0., 1., 1.]] * 4),
                   c=jnp.asarray([[1., 0., 1.]] * 4))
    ray = make_ray(jnp.asarray([[0.2, 0.2, 0.]] * 4),
                   jnp.asarray([[0., 0., 1.]] * 4))
    jobs = jobs._replace(opcode=jnp.full((4,), OP_TRIANGLE, jnp.int32),
                         ray=ray, triangle=tri)
    _, out = unified_stream(jobs)
    t = out.t_num[0] / out.t_denom[0]  # the division is external (paper!)
    print(f"  hit={bool(out.triangle_hit[0])}  t={float(t):.3f} "
          f"(t_num/t_denom = external division)")

    print("== OpEuclidean: multi-beat accumulation (32-dim vector) ==")
    rng = np.random.default_rng(0)
    a = rng.normal(size=32).astype(np.float32)
    b = rng.normal(size=32).astype(np.float32)
    jobs = make_jobs(2)
    jobs = jobs._replace(
        opcode=jnp.full((2,), OP_EUCLIDEAN, jnp.int32),
        vec_a=jnp.asarray([a[:16], a[16:]]), vec_b=jnp.asarray([b[:16], b[16:]]),
        reset_accum=jnp.asarray([True, False]))
    _, out = unified_stream(jobs)
    print(f"  datapath ||a-b||^2 = {float(out.euclidean_accumulator[1]):.4f} "
          f"(numpy: {((a - b) ** 2).sum():.4f})")

    print("== OpAngular -> cosine similarity (external sqrt+divide) ==")
    # session API: the candidate set is indexed once (||c||^2 precomputed),
    # then every query flows through one jit-cached engine
    q = rng.normal(size=(3, 24)).astype(np.float32)
    c = rng.normal(size=(5, 24)).astype(np.float32)
    engine = VectorIndex.from_database(jnp.asarray(c)).engine()
    sims = engine.similarity(jnp.asarray(q))
    print("  cosine matrix:\n", np.asarray(sims).round(3))
    res = engine.nearest(jnp.asarray(q), k=2, metric="cosine")
    print("  top-2 neighbours per query:", np.asarray(res.indices).tolist())

    print("== Traversal backends: one scene, bit-identical engines ==")
    # a tetrahedron traced by every registered backend — the wavefront
    # batch loop and the fused Pallas kernel (loop on-chip, DESIGN.md §8)
    # return the same hits AND the same per-ray datapath job counters
    v = np.asarray([[1, 1, 1], [1, -1, -1], [-1, 1, -1], [-1, -1, 1]],
                   np.float32)
    faces = [(0, 1, 2), (0, 3, 1), (0, 2, 3), (1, 3, 2)]
    verts = np.stack([np.stack([v[a], v[b], v[c]]) for a, b, c in faces])
    scene = Scene.from_triangles(verts)
    tracer = scene.engine(shard=1)
    org = np.asarray([[-3.0, 0.1 * i, 0.05 * i] for i in range(4)],
                     np.float32)
    rays = make_ray(jnp.asarray(org), jnp.asarray(-org))
    print("  registered:", trace_backends())
    for backend in ("wavefront", "pallas"):
        rec = tracer.trace(rays, backend=backend)
        print(f"  {backend:9s} t={np.asarray(rec.t).round(3)} "
              f"quadbox_jobs={np.asarray(rec.quadbox_jobs).tolist()}")


if __name__ == "__main__":
    main()
