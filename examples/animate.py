"""Animated scene via ``Scene.refit``: dynamic geometry, zero retraces.

A sphere bounces over the ground plane.  The scene is built ONCE (binned-
SAH builder); every animation frame moves the sphere's triangles and calls
``Scene.refit`` — the O(depth) AABB re-sweep that keeps the tree topology
and every static shape, so all frames after the first re-enter the same
compiled trace (watch the engine cache: entries/misses stop growing after
frame 1).  No rebuild, no retrace, per frame.

Run:  PYTHONPATH=src python examples/animate.py [--frames 8] [--res 64]
          [--out /tmp/animate]
      writes frame_00.pgm .. frame_NN.pgm plus per-frame job stats.
"""
import argparse
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from render import ground_plane, icosphere  # noqa: E402  (sibling example)

from repro.api import Scene, Triangle, make_ray  # noqa: E402


def build_soup():
    sphere = icosphere(2)
    ground = ground_plane()
    tris = np.concatenate([sphere, ground], axis=0)
    tris = np.concatenate([tris, tris[:, ::-1, :]], axis=0)  # two-sided
    # which triangles belong to the (animated) sphere, in both windings
    n_sph, n_all = len(sphere), len(sphere) + len(ground)
    animated = np.zeros(2 * n_all, bool)
    animated[:n_sph] = True
    animated[n_all:n_all + n_sph] = True
    return tris, animated


def frame_soup(tris, animated, t):
    """Sphere bounces: y-shift by |sin t|, squash slightly at the bottom."""
    bounce = 0.8 * abs(np.sin(t))
    squash = 1.0 - 0.25 * max(0.0, 0.3 - bounce)
    out = tris.copy()
    ys = out[animated][:, :, 1]
    out[animated] = np.concatenate(
        [out[animated][:, :, :1], (ys * squash + bounce)[:, :, None],
         out[animated][:, :, 2:]], axis=2)
    return Triangle(jnp.asarray(out[:, 0]), jnp.asarray(out[:, 1]),
                    jnp.asarray(out[:, 2]))


def camera_rays(res):
    eye = np.asarray([0.0, 1.2, -4.0], np.float32)
    ys, xs = np.meshgrid(np.linspace(0.8, -0.8, res),
                         np.linspace(-0.8, 0.8, res), indexing="ij")
    fwd = np.asarray([0.0, -0.25, 1.0]); fwd /= np.linalg.norm(fwd)
    right = np.asarray([1.0, 0.0, 0.0])
    up = np.cross(fwd, right)
    dirs = (fwd[None] + xs.ravel()[:, None] * right[None]
            + ys.ravel()[:, None] * up[None]).astype(np.float32)
    org = np.tile(eye[None], (res * res, 1))
    return org, dirs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=8)
    ap.add_argument("--res", type=int, default=64)
    ap.add_argument("--out", default="/tmp/animate")
    args = ap.parse_args()

    tris, animated = build_soup()
    scene = Scene.from_triangles(frame_soup(tris, animated, 0.0),
                                 builder="sah")
    engine = scene.engine(shard=1, chunk_size=4096)
    print(f"{scene!r}: {int(animated.sum())} animated of "
          f"{scene.num_triangles} triangles; builder-quality "
          f"sah_cost={scene.stats().sah_cost:.2f}")

    os.makedirs(args.out, exist_ok=True)
    org, dirs = camera_rays(args.res)
    rays = make_ray(jnp.asarray(org), jnp.asarray(dirs))

    for k in range(args.frames):
        t = k * (np.pi / max(args.frames - 1, 1))
        if k > 0:  # frame 0 traces the tree as built
            scene.refit(frame_soup(tris, animated, t))
        rec = engine.trace(rays)
        img = np.where(np.asarray(rec.hit),
                       40 + np.clip(215 * (1.0 - np.asarray(rec.t) / 8.0),
                                    0, 215),
                       8).reshape(args.res, args.res)
        path = os.path.join(args.out, f"frame_{k:02d}.pgm")
        with open(path, "wb") as f:
            f.write(f"P5\n{args.res} {args.res}\n255\n".encode())
            f.write(np.clip(img, 0, 255).astype(np.uint8).tobytes())
        info = engine.cache_info()
        print(f"frame {k}: hits {int(rec.hit.sum()):5d}  "
              f"jobs/ray {float(rec.quadbox_jobs.mean()) + float(rec.triangle_jobs.mean()):6.1f}  "
              f"rounds {int(rec.rounds):3d}  "
              f"cache entries={info.entries} misses={info.misses}")

    if engine.cache_info().misses != 1:
        raise SystemExit("refit frames recompiled the trace — the "
                         "zero-retrace contract is broken")
    print(f"{args.frames} frames, 1 compiled trace, 0 rebuilds -> "
          f"{args.out}/frame_*.pgm")


if __name__ == "__main__":
    main()
