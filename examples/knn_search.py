"""Vector search with the session query API, both ways (DESIGN.md §9):

* the **brute path** — a high-dimensional ``VectorIndex``, exact kNN
  under all three metrics through the MXU/Pallas distance backends;
* the **tree path** — a 3-D ``PointCloudScene`` whose BVH the neighbor
  queries *traverse* (RTNN mapping: AABB-per-point leaves, radius as ray
  extent), cross-checked against the brute oracle with the per-query
  traversal work it saved.

Plus the MoE-router connection (expert selection IS angular top-k).

Run:  PYTHONPATH=src python examples/knn_search.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import PointCloudScene, VectorIndex


def main():
    rng = np.random.default_rng(0)
    n_db, n_q, dim = 8192, 64, 128
    # clustered database so neighbours are meaningful
    centers = rng.normal(size=(16, dim)).astype(np.float32) * 3
    assign = rng.integers(0, 16, n_db)
    db = (centers[assign] + rng.normal(size=(n_db, dim)).astype(np.float32))
    queries = (centers[rng.integers(0, 16, n_q)]
               + 0.5 * rng.normal(size=(n_q, dim)).astype(np.float32))
    dbj, qj = jnp.asarray(db), jnp.asarray(queries)

    # built once: the index owns the database and its ||c||^2 norms; the
    # engine owns the per-(shape, backend, metric) compiled-function cache.
    # shard="auto" data-parallels query rows over every local device
    # (database replicated, results bit-identical to one device)
    index = VectorIndex.from_database(dbj)
    engine = index.engine(shard="auto")
    print(f"devices: {jax.local_device_count()} "
          f"(shard='auto' data-parallels query batches across them)")

    for metric in ("euclidean", "angular", "cosine"):
        engine.nearest(qj, 8, metric)  # warm the compiled cache
        t0 = time.perf_counter()
        res = engine.nearest(qj, 8, metric)
        jax.block_until_ready(res.scores)
        dt = time.perf_counter() - t0
        # recall@8 vs numpy exact
        if metric == "euclidean":
            ref = ((queries[:, None] - db[None]) ** 2).sum(-1)
            ref_idx = np.argsort(ref, 1)[:, :8]
        else:
            sims = queries @ db.T
            if metric == "cosine":
                sims /= (np.linalg.norm(queries, axis=1)[:, None]
                         * np.linalg.norm(db, axis=1)[None])
            ref_idx = np.argsort(-sims, 1)[:, :8]
        recall = np.mean([len(set(a) & set(b)) / 8
                          for a, b in zip(np.asarray(res.indices), ref_idx)])
        print(f"{metric:10s} top-8: recall@8={recall:.3f}  "
              f"({n_q} queries x {n_db} db in {dt * 1e3:.1f} ms)")

    # radius query (RTNN-style range-limited search: the vector-search twin
    # of the traversal engine's extent-limited shadow rays)
    radius = 18.0  # ~ within-cluster distance at dim=128
    engine.within(qj, radius, 8)  # warm both compiled functions
    engine.count_within(qj, radius)
    t0 = time.perf_counter()
    res = engine.within(qj, radius, 8)
    counts = engine.count_within(qj, radius)
    jax.block_until_ready(counts)
    dt = time.perf_counter() - t0
    # sanity: the returned neighbours really are the nearest in-range ones
    d_near = np.asarray(res.scores)[np.asarray(res.within)]
    nearest = f"{d_near.min() ** 0.5:.1f}" if d_near.size else "n/a (none in range)"
    print(f"radius={radius}: avg {float(counts.mean()):.1f} db points in "
          f"range per query, {float(res.within.mean()):.2f} of top-8 slots "
          f"filled, nearest in-range dist {nearest} "
          f"(idx sample {np.asarray(res.indices)[0, :3].tolist()}) "
          f"in {dt * 1e3:.1f} ms")

    # streaming: the same batch through fixed-size microbatch chunks — the
    # peak intermediate is (chunk, n_db) instead of (n_q, n_db), and every
    # chunk re-enters one compiled function; results are bit-identical.
    # shard=1 pins the block to chunk_size (under shard="auto" the block
    # rounds up to a per-shard lane multiple, merging the chunks)
    chunked = index.engine(shard=1, chunk_size=16)
    res_c = chunked.nearest(qj, 8, "euclidean")
    res_u = engine.nearest(qj, 8, "euclidean")
    assert (np.asarray(res_c.indices) == np.asarray(res_u.indices)).all()
    print(f"chunk_size=16: {n_q} queries in {-(-n_q // 16)} chunks through "
          f"{chunked.cache_info().entries} compiled function(s), "
          f"indices identical to the one-shot batch")

    # pluggable backends: the same query through the Pallas kernel path
    # (tiled multi-beat accumulator) instead of the jnp MXU form
    d_k = engine.scores(qj, "euclidean", backend="pallas")
    ref = ((queries[:, None] - db[None]) ** 2).sum(-1)
    print(f"pallas euclidean backend max rel err: "
          f"{np.abs(np.asarray(d_k) - ref).max() / ref.max():.2e}")
    print(f"compiled-function cache: {engine.cache_info()}")

    # the tree path: a 3-D point cloud becomes a BVH of point-leaves and
    # neighbor queries run as extent-limited *traversals* (DESIGN.md §9).
    # backend="auto" picks tree-vs-brute per query; here we force both and
    # cross-check — membership is exact, and the record reports how much
    # of the brute path's N distance jobs the walk pruned away
    n_pts, n_cq, radius = 50_000, 256, 0.1
    pts = jnp.asarray(rng.normal(size=(n_pts, 3)).astype(np.float32))
    cq = jnp.asarray(rng.normal(size=(n_cq, 3)).astype(np.float32))
    cloud_engine = PointCloudScene.from_points(pts).engine()
    rec = cloud_engine.neighbor_search(cq, 32, radius=radius,
                                       backend="tree_wavefront")
    brute = cloud_engine.within(cq, radius, 32, backend="mxu")
    w_t, w_b = np.asarray(rec.valid), np.asarray(brute.within)
    assert all(set(np.asarray(rec.index)[i][w_t[i]])
               == set(np.asarray(brute.indices)[i][w_b[i]])
               for i in range(n_cq)), "tree vs brute in-radius set mismatch"
    jobs = float(np.asarray(rec.box_jobs).mean()
                 + np.asarray(rec.point_jobs).mean())
    auto = cloud_engine.resolve_neighbor_backend("within", "euclidean",
                                                 radius=radius)
    print(f"tree path: {n_pts} points, radius={radius}: avg "
          f"{float(np.asarray(rec.count).mean()):.1f} in range, "
          f"{jobs:.0f} traversal jobs/query vs {n_pts} brute "
          f"({jobs / n_pts * 100:.2f}%), sets identical to brute "
          f"(auto picks {auto!r} here)")

    # the MoE-router connection: expert selection IS angular-mode top-k
    # (router_scores builds a VectorIndex over the expert embeddings)
    from repro.models.moe import router_scores, router_topk
    from repro.models.config import MoEConfig
    m = MoEConfig(num_experts=16, top_k=2, d_ff_expert=1)
    scores = router_scores(m, qj, jnp.asarray(centers))
    w, experts, aux = router_topk(m, scores)
    top1 = np.asarray(experts)[:, 0]
    true_cluster = np.argmax(queries @ centers.T, axis=1)
    print(f"MoE router (= OpAngular top-k): top-1 expert == nearest "
          f"centroid for {np.mean(top1 == true_cluster) * 100:.0f}% of tokens")


if __name__ == "__main__":
    main()
