"""Render a triangle-mesh sphere over a ground plane with the session query
API and write a PGM image.

The scene is prepared once (``Scene.from_triangles`` owns the BVH4 and its
depth); every query goes through one ``QueryEngine``: primary rays are
closest-hit traces, hard shadows are extent-limited ``"shadow"`` traces
toward a point light — the sphere casts a shadow onto the plane.

The engine is built with ``shard="auto"`` (data-parallel rays across every
local device — replicated scene, bit-identical image) and a ``chunk_size``
so the whole framebuffer streams through fixed-size microbatches of rays
sharing one compiled trace.

``--trace-backend`` selects the traversal engine (``auto`` | ``per_ray``
| ``wavefront`` | ``pallas``); every backend renders the identical image
(the bit-parity contract), so the flag is pure scheduling — ``pallas``
runs the fused kernel that keeps the traversal loop on-chip (DESIGN.md
§8; interpret mode off-TPU).

Run:  PYTHONPATH=src python examples/render.py [out.pgm]
      PYTHONPATH=src python examples/render.py --trace-backend pallas
      XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
          PYTHONPATH=src python examples/render.py  # same image, 8-way
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import Scene, Triangle, make_ray, trace_backends
from repro.core.session import trace_backend_ray_types


def icosphere(subdiv=3):
    """Geodesic sphere triangles via icosahedron subdivision."""
    phi = (1 + 5 ** 0.5) / 2
    verts = np.asarray([
        [-1, phi, 0], [1, phi, 0], [-1, -phi, 0], [1, -phi, 0],
        [0, -1, phi], [0, 1, phi], [0, -1, -phi], [0, 1, -phi],
        [phi, 0, -1], [phi, 0, 1], [-phi, 0, -1], [-phi, 0, 1]],
        np.float64)
    verts /= np.linalg.norm(verts, axis=1, keepdims=True)
    faces = [(0, 11, 5), (0, 5, 1), (0, 1, 7), (0, 7, 10), (0, 10, 11),
             (1, 5, 9), (5, 11, 4), (11, 10, 2), (10, 7, 6), (7, 1, 8),
             (3, 9, 4), (3, 4, 2), (3, 2, 6), (3, 6, 8), (3, 8, 9),
             (4, 9, 5), (2, 4, 11), (6, 2, 10), (8, 6, 7), (9, 8, 1)]
    tris = [tuple(verts[i] for i in f) for f in faces]
    for _ in range(subdiv):
        out = []
        for a, b, c in tris:
            ab, bc, ca = (a + b) / 2, (b + c) / 2, (c + a) / 2
            ab, bc, ca = (v / np.linalg.norm(v) for v in (ab, bc, ca))
            out += [(a, ab, ca), (b, bc, ab), (c, ca, bc), (ab, bc, ca)]
        tris = out
    arr = np.asarray(tris, np.float32)  # (N, 3verts, 3)
    return arr


def ground_plane(y=-1.0, half=6.0):
    """Two triangles spanning a square at height y."""
    c = [[-half, y, -half], [half, y, -half], [half, y, half], [-half, y, half]]
    c = np.asarray(c, np.float32)
    return np.stack([np.stack([c[0], c[2], c[1]]),
                     np.stack([c[0], c[3], c[2]])])


def build_scene():
    tris = np.concatenate([icosphere(3), ground_plane()], axis=0)
    # two-sided: add reversed winding (the datapath culls backfaces)
    tris = np.concatenate([tris, tris[:, ::-1, :]], axis=0)
    tri = Triangle(jnp.asarray(tris[:, 0]), jnp.asarray(tris[:, 1]),
                   jnp.asarray(tris[:, 2]))
    return tris, tri


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("out", nargs="?", default="/tmp/render.pgm")
    # only backends that can serve the shadow pass are offered (per_ray
    # is closest-hit only)
    shadow_capable = tuple(b for b in trace_backends()
                           if "shadow" in trace_backend_ray_types(b))
    ap.add_argument("--trace-backend", default="auto",
                    choices=("auto",) + shadow_capable,
                    help="traversal engine (every choice renders the "
                         "identical image)")
    ap.add_argument("--res", type=int, default=96,
                    help="framebuffer resolution (res x res rays)")
    args = ap.parse_args()
    out_path = args.out
    tris, tri = build_scene()
    scene = Scene.from_triangles(tri)
    # shard="auto": rays data-parallel over every local device (scene
    # replicated, image bit-identical); chunk_size: the framebuffer streams
    # through fixed-size ray microbatches sharing one compiled trace
    engine = scene.engine(shard="auto", chunk_size=4096,
                          backend=args.trace_backend)
    print(f"scene: {scene.num_triangles} triangles (sphere + ground), "
          f"BVH4 depth {scene.depth}, {jax.local_device_count()} device(s), "
          f"chunk_size=4096, trace_backend={args.trace_backend}")

    # pinhole camera above the sphere looking slightly down: sphere, ground
    # and the sphere's cast shadow are all in frame
    res = args.res
    eye = np.asarray([0.0, 1.0, -3.6], np.float32)
    ys, xs = np.meshgrid(np.linspace(0.75, -0.75, res),
                         np.linspace(-0.75, 0.75, res), indexing="ij")
    fwd = np.asarray([0.0, -0.35, 1.0]); fwd /= np.linalg.norm(fwd)
    right = np.asarray([1.0, 0.0, 0.0])
    up = np.cross(fwd, right)
    dirs = (fwd[None] + xs.ravel()[:, None] * right[None]
            + ys.ravel()[:, None] * up[None]).astype(np.float32)
    org = np.tile(eye[None], (res * res, 1))
    rays = make_ray(jnp.asarray(org), jnp.asarray(dirs))
    rec = engine.trace(rays)  # closest-hit, auto backend

    hit = np.asarray(rec.hit)
    t = np.asarray(rec.t)
    tri_idx = np.asarray(rec.tri_index)
    pts = org + np.where(hit, t, 0.0)[:, None] * dirs

    # geometric normal of the hit triangle, flipped toward the camera
    v = tris[np.maximum(tri_idx, 0)]  # (R, 3verts, 3)
    n = np.cross(v[:, 1] - v[:, 0], v[:, 2] - v[:, 0])
    n /= np.maximum(np.linalg.norm(n, axis=1, keepdims=True), 1e-12)
    n = np.where((n * dirs).sum(1, keepdims=True) > 0, -n, n)

    # hard shadows: extent-limited any-hit rays toward a point light
    light_pos = np.asarray([2.0, 3.0, -2.0], np.float32)
    to_light = light_pos - pts
    dist = np.linalg.norm(to_light, axis=1)
    ldir = to_light / np.maximum(dist[:, None], 1e-12)
    shadow_org = (pts + 1e-3 * n).astype(np.float32)
    shadow_rays = make_ray(jnp.asarray(shadow_org), jnp.asarray(ldir),
                           extent=jnp.asarray(dist.astype(np.float32)))
    occluded = np.asarray(engine.occluded(shadow_rays, t_min=1e-3))

    lambert = np.clip((n * ldir).sum(1), 0.0, 1.0)
    shade = 0.12 + 0.88 * lambert * np.where(hit & occluded, 0.15, 1.0)
    img = np.where(hit, 20 + 235 * shade, 8).reshape(res, res)

    with open(out_path, "wb") as f:
        f.write(f"P5\n{res} {res}\n255\n".encode())
        f.write(np.clip(img, 0, 255).astype(np.uint8).tobytes())
    n_shadow = int((hit & occluded).sum())
    print(f"hits: {hit.sum()}/{hit.size}  shadowed: {n_shadow}  "
          f"avg quadbox jobs/ray: {float(rec.quadbox_jobs.mean()):.1f}  "
          f"avg triangle jobs/ray: {float(rec.triangle_jobs.mean()):.1f}  "
          f"wavefront rounds: {int(rec.rounds)}")
    print(f"wrote {out_path}")


if __name__ == "__main__":
    main()
