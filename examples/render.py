"""Render a sphere made of triangles with the BVH4 + unified datapath
(closest-hit traversal; quad-box and triangle jobs) and write a PGM image.

Run:  PYTHONPATH=src python examples/render.py [out.pgm]
"""
import sys

import jax.numpy as jnp
import numpy as np

from repro.core import Triangle, build_bvh4, bvh4_depth, make_ray, trace_rays


def icosphere(subdiv=3):
    """Geodesic sphere triangles via icosahedron subdivision."""
    phi = (1 + 5 ** 0.5) / 2
    verts = np.asarray([
        [-1, phi, 0], [1, phi, 0], [-1, -phi, 0], [1, -phi, 0],
        [0, -1, phi], [0, 1, phi], [0, -1, -phi], [0, 1, -phi],
        [phi, 0, -1], [phi, 0, 1], [-phi, 0, -1], [-phi, 0, 1]],
        np.float64)
    verts /= np.linalg.norm(verts, axis=1, keepdims=True)
    faces = [(0, 11, 5), (0, 5, 1), (0, 1, 7), (0, 7, 10), (0, 10, 11),
             (1, 5, 9), (5, 11, 4), (11, 10, 2), (10, 7, 6), (7, 1, 8),
             (3, 9, 4), (3, 4, 2), (3, 2, 6), (3, 6, 8), (3, 8, 9),
             (4, 9, 5), (2, 4, 11), (6, 2, 10), (8, 6, 7), (9, 8, 1)]
    tris = [tuple(verts[i] for i in f) for f in faces]
    for _ in range(subdiv):
        out = []
        for a, b, c in tris:
            ab, bc, ca = (a + b) / 2, (b + c) / 2, (c + a) / 2
            ab, bc, ca = (v / np.linalg.norm(v) for v in (ab, bc, ca))
            out += [(a, ab, ca), (b, bc, ab), (c, ca, bc), (ab, bc, ca)]
        tris = out
    arr = np.asarray(tris, np.float32)  # (N, 3verts, 3)
    return arr


def main():
    out_path = sys.argv[1] if len(sys.argv) > 1 else "/tmp/render.pgm"
    tris = icosphere(3)
    n = len(tris)
    # two-sided: add reversed winding (the datapath culls backfaces)
    tris = np.concatenate([tris, tris[:, ::-1, :]], axis=0)
    tri = Triangle(jnp.asarray(tris[:, 0]), jnp.asarray(tris[:, 1]),
                   jnp.asarray(tris[:, 2]))
    bvh = build_bvh4(tri)
    depth = bvh4_depth(len(tris))
    print(f"scene: {len(tris)} triangles, BVH4 depth {depth}")

    res = 96
    ys, xs = np.meshgrid(np.linspace(1.4, -1.4, res),
                         np.linspace(-1.4, 1.4, res), indexing="ij")
    org = np.stack([xs.ravel(), ys.ravel(), np.full(res * res, -3.0)],
                   -1).astype(np.float32)
    dirs = np.tile(np.asarray([[0, 0, 1]], np.float32), (res * res, 1))
    rays = make_ray(jnp.asarray(org), jnp.asarray(dirs))
    rec = trace_rays(bvh, rays, depth)

    # shade by normal . light
    hit = np.asarray(rec.hit)
    t = np.asarray(rec.t)
    pts = org + t[:, None] * dirs
    normal = pts / np.maximum(np.linalg.norm(pts, axis=1, keepdims=True), 1e-6)
    light = np.asarray([0.5, 0.7, -0.6])
    light = light / np.linalg.norm(light)
    shade = np.clip(normal @ light, 0.1, 1.0)
    img = np.where(hit, (40 + 215 * shade), 12).reshape(res, res)

    with open(out_path, "wb") as f:
        f.write(f"P5\n{res} {res}\n255\n".encode())
        f.write(img.astype(np.uint8).tobytes())
    print(f"hits: {hit.sum()}/{hit.size}  "
          f"avg quadbox jobs/ray: {float(rec.quadbox_jobs.mean()):.1f}  "
          f"avg triangle jobs/ray: {float(rec.triangle_jobs.mean()):.1f}")
    print(f"wrote {out_path}")


if __name__ == "__main__":
    main()
