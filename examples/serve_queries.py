"""Serve ray queries: continuous batching over a live QueryEngine.

A minimal asyncio client/server demo of the serving subsystem
(DESIGN.md §10).  One ``QueryEngine`` holds both a triangle scene and a
point cloud; a ``QueryServer`` wraps it; many concurrent "users" each
fire a handful of tiny requests — rays to trace, points to look up —
over mixed methods.  The server coalesces them into full lane-multiple
batches, executes each batch as one engine call, and splits the
responses back per request, **bit-identical** to what a direct
per-request engine call returns (this script asserts it for every
response, job counters included).

With ``--telemetry DIR`` the demo also exercises the telemetry plane
(DESIGN.md §11): it enables ``repro.obs``, warms every (method, params,
ladder-size) program the run can touch, serves one warmup pass, then
serves the measured pass inside an ``obs.CompileTracker`` and **asserts
zero steady-state compiles** — the serving ladder's whole point — before
writing ``DIR/obs_snapshot.json`` (metrics) and ``DIR/trace.json``
(Chrome-trace spans, one admit → coalesce → execute → split chain per
request; open in Perfetto).  CI runs this mode on every device matrix
entry and uploads both files as artifacts.

Run:  PYTHONPATH=src python examples/serve_queries.py [--users 12]
          [--requests 4] [--max-wait-ms 5] [--telemetry DIR]
"""
import argparse
import asyncio
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.api import PointCloudScene, QueryEngine, Scene, make_ray
from repro.serving import QueryServer

TRACE_FIELDS = ("t", "tri_index", "hit", "quadbox_jobs", "triangle_jobs")


def build_engine(rng):
    """A triangle soup for trace + a point cloud for neighbor queries,
    served by one engine (sharded over whatever mesh is available)."""
    n_tri = 250
    ctr = rng.uniform(-1, 1, (n_tri, 3)).astype(np.float32)
    d1 = rng.normal(scale=0.12, size=(n_tri, 3)).astype(np.float32)
    d2 = rng.normal(scale=0.12, size=(n_tri, 3)).astype(np.float32)
    scene = Scene.from_triangles(np.stack([ctr, ctr + d1, ctr + d2], 1))
    cloud = PointCloudScene.from_points(
        rng.normal(size=(1024, 3)).astype(np.float32))
    return QueryEngine(scene=scene, cloud=cloud, pad_multiple=8,
                       shard="auto")


def make_jobs(rng, n_users, n_requests):
    """Each user's little mixed workload: some rays, some lookups."""
    jobs = []
    for u in range(n_users):
        for r in range(n_requests):
            n = int(rng.integers(1, 7))
            kind = ("trace", "nearest", "trace", "count_within")[r % 4]
            if kind == "trace":
                org = rng.uniform(-3, -2, (n, 3)).astype(np.float32)
                tgt = rng.uniform(-0.5, 0.5, (n, 3)).astype(np.float32)
                rays = make_ray(jnp.asarray(org), jnp.asarray(tgt - org))
                jobs.append((u, "trace", rays,
                             {"ray_type": ("closest", "any", "shadow")[u % 3]}))
            elif kind == "nearest":
                q = jnp.asarray(rng.normal(size=(n, 3)).astype(np.float32))
                jobs.append((u, "nearest", q, {"k": 4}))
            else:
                q = jnp.asarray(rng.normal(size=(n, 3)).astype(np.float32))
                jobs.append((u, "count_within", q, {"radius": 0.6}))
    return jobs


def warm_ladder(engine, jobs, max_rows=128):
    """Compile every (method, static-params, ladder-size) program the
    serving run can touch — power-of-two sizes up to twice the batch cap,
    one pass per distinct request configuration (``ray_type`` buckets
    compile distinct programs).  After this, a served pass re-enters only
    cached programs: the steady state ``--telemetry`` asserts."""
    combos = {}
    for _, kind, payload, kw in jobs:
        combos.setdefault((kind, tuple(sorted(kw.items()))),
                          (kind, payload, kw))
    sizes = [1 << i for i in range(max_rows.bit_length())]
    for kind, payload, kw in combos.values():
        for n in sizes:
            reps = jax.tree_util.tree_map(
                lambda x: jnp.concatenate([x[:1]] * n, axis=0), payload)
            jax.block_until_ready(getattr(engine, kind)(reps, **kw))


async def user_session(server, my_jobs):
    """One client: fire requests concurrently, await the responses."""
    tasks = [asyncio.ensure_future(
        getattr(server, kind)(payload, **kw))
        for _, kind, payload, kw in my_jobs]
    return await asyncio.gather(*tasks)


def check_parity(engine, jobs, responses):
    """Every served response must be bit-identical to a direct call."""
    for (_, kind, payload, kw), got in zip(jobs, responses):
        ref = getattr(engine, kind)(payload, **kw)
        if kind == "trace":
            for f in TRACE_FIELDS:
                np.testing.assert_array_equal(
                    np.asarray(getattr(got, f)),
                    np.asarray(getattr(ref, f)), err_msg=f"trace {f}")
            assert int(got.rounds) == int(ref.rounds)
        elif kind == "count_within":
            np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
        else:
            np.testing.assert_array_equal(np.asarray(got.indices),
                                          np.asarray(ref.indices))
            np.testing.assert_array_equal(np.asarray(got.scores),
                                          np.asarray(ref.scores))


async def serve_pass(engine, jobs, args):
    """One full client/server pass over ``jobs``."""
    async with QueryServer(engine, max_batch_rows=64,
                           max_wait=args.max_wait_ms * 1e-3) as server:
        per_user = [[j for j in jobs if j[0] == u]
                    for u in range(args.users)]
        results = await asyncio.gather(
            *[user_session(server, mine) for mine in per_user])
        stats = server.stats()
    return per_user, results, stats


async def main_async(args):
    rng = np.random.default_rng(0)
    if args.telemetry:
        obs.enable()
    engine = build_engine(rng)
    jobs = make_jobs(rng, args.users, args.requests)
    print(f"devices={jax.local_device_count()}  "
          f"users={args.users}  requests={len(jobs)}")

    tracker = None
    if args.telemetry:
        # ladder warm + one throwaway served pass: everything the
        # measured pass executes (compiled programs AND eager pad/slice
        # shapes) has been traced once, so the tracker below must read 0
        warm_ladder(engine, jobs)
        await serve_pass(engine, jobs, args)
        tracker = obs.CompileTracker().start()

    per_user, results, stats = await serve_pass(engine, jobs, args)

    if tracker is not None:
        tracker.stop()
        print(f"steady-state compiles in measured pass: {tracker.compiles}")
        assert tracker.compiles == 0, (
            f"{tracker.compiles} jit tracings in the steady-state serving "
            "pass — the quantized ladder should have absorbed them all")

    flat = [r for user in per_user for r in user]
    responses = [r for user_res in results for r in user_res]
    check_parity(engine, flat, responses)
    print("bit-parity vs direct engine calls: OK "
          f"({len(responses)} responses)")

    print(f"{'method':>14} {'reqs':>5} {'batches':>7} {'req/batch':>9} "
          f"{'fill':>5} {'p50ms':>7} {'p99ms':>7}  flushes")
    for method in sorted(stats):
        s = stats[method]
        flushes = (f"full={s.flush_full} timer={s.flush_timer} "
                   f"deadline={s.flush_deadline} drain={s.flush_drain}")
        print(f"{method:>14} {s.requests:>5} {s.batches:>7} "
              f"{s.requests_per_batch:>9.2f} {s.mean_fill:>5.2f} "
              f"{s.p50_ms:>7.2f} {s.p99_ms:>7.2f}  {flushes}")
    occupancy = (sum(s.requests for s in stats.values())
                 / max(1, sum(s.batches for s in stats.values())))
    print(f"overall requests/batch: {occupancy:.2f}")
    assert occupancy > 1.0, "coalescing never batched requests together"

    if args.telemetry:
        os.makedirs(args.telemetry, exist_ok=True)
        snap_path = os.path.join(args.telemetry, "obs_snapshot.json")
        trace_path = os.path.join(args.telemetry, "trace.json")
        obs.write_snapshot(snap_path)
        n_events = obs.export_chrome_trace(trace_path)
        print(f"telemetry: wrote {snap_path} and {trace_path} "
              f"({n_events} trace events)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--users", type=int, default=12)
    ap.add_argument("--requests", type=int, default=4,
                    help="requests per user")
    ap.add_argument("--max-wait-ms", type=float, default=5.0)
    ap.add_argument("--telemetry", metavar="DIR", default=None,
                    help="enable repro.obs, assert steady-state compiles "
                         "== 0, write obs_snapshot.json + trace.json "
                         "(Chrome trace) into DIR")
    args = ap.parse_args()
    asyncio.run(main_async(args))


if __name__ == "__main__":
    main()
